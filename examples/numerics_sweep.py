"""The paper's core experiment, run *automatically*: trace a model's GEMM
call-sites, search the per-site (format x accumulator x backend) space, and
emit a deployable PrecisionPlan — Fig. 3's design-space sweep as a subsystem
(repro.numerics) instead of a hand-picked table.

    PYTHONPATH=src python examples/numerics_sweep.py                # full
    PYTHONPATH=src python examples/numerics_sweep.py --reduced      # CI smoke

(The checked-in ``examples/plans/`` fixtures — paper_mlp.json and the rest of
the per-architecture zoo — are refreshed by ``scripts/refresh_plans.py``,
which adds trace persistence and the MANIFEST; this example stays the
single-model walkthrough of the same pipeline.)

Pipeline: (1) calibrate — one forward pass of the paper-MLP workload records
per-site operand statistics; (2) enumerate + evaluate — each site's pruned
candidate grid is replayed on its captured sample against a bit-exact FDP
oracle; (3) greedy Pareto search meets the end-to-end error budget at
minimum modeled energy, accepted by the ``repro.workloads`` scenario zoo
(logit fidelity vs the uniform ⟨30,30,-30⟩ policy + K-reorder
reproducibility by default — see ``--validators``);
(4) the plan serializes to JSON and loads back into a NumericsPolicy.
"""

import argparse

import jax

from repro.configs import get_config
from repro.core.dispatch import MXU_FP32, use_policy
from repro.models import forward, init, LOCAL
from repro.numerics import calibrate, load_plan, search
from repro.workloads import WorkloadContext, build_validators


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--reduced", action="store_true",
                    help="tiny config + small grid (CI smoke)")
    ap.add_argument("--budget", type=float, default=10.0,
                    help="end-to-end error budget in correct bits")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--out", default=None,
                    help="write the PrecisionPlan JSON here")
    ap.add_argument("--validators", default="logits,repro",
                    help="comma list of repro.workloads validators accepting "
                         "the plan end-to-end (this example calibrates "
                         "forward-only, so the default set is forward-facing)")
    args = ap.parse_args(argv)

    cfg = get_config("paper-mlp")
    if args.reduced:
        cfg = cfg.reduced()
    if args.seq is None:
        args.seq = 8 if args.reduced else 16
    params = init(cfg, jax.random.key(0))
    batch = {"tokens": jax.random.randint(
        jax.random.key(1), (args.batch, args.seq), 0, cfg.vocab_size)}

    # (1) calibration trace: one forward pass under the fast native policy
    print(f"== calibrating {cfg.name} "
          f"(batch={args.batch}, seq={args.seq}) ==")
    with calibrate() as trace, use_policy(MXU_FP32):
        jax.block_until_ready(forward(params, cfg, batch, LOCAL,
                                      remat="none"))
    print(trace.summary())

    # (2)+(3) search, accepted end-to-end by the workload zoo
    ctx = WorkloadContext(budget_bits=args.budget, cfg=cfg, params=params,
                          batch=batch)
    validators = build_validators(
        [n for n in args.validators.split(",") if n and n != "none"], ctx)

    grid = (dict(widths=(32,)) if args.reduced
            else dict(widths=(24, 40, 64)))
    print(f"\n== searching (budget {args.budget} bits, validators "
          f"{[v.name for v in validators]}) ==")
    res = search(trace, budget_bits=args.budget, name=cfg.name,
                 validators=validators, **grid)
    print(res.describe())

    # per-site frontier detail (the Fig. 3 sweep, per call-site)
    print("\n== per-site Pareto frontiers (bits / modeled J) ==")
    for site, d in sorted(res.decisions.items()):
        pts = " | ".join(f"{p.candidate.tag} "
                         f"{p.error_bits:.1f}b {p.energy_j:.1e}J"
                         for p in d.frontier)       # already Pareto-filtered
        print(f"  {site:14s} {pts}")

    # (4) serialize + reload
    if args.out:
        res.plan.save(args.out)
        back = load_plan(args.out)
        assert back.to_policy().lookup(res.plan.sites[0].site).tag() == \
            res.plan.sites[0].cfg.tag()
        print(f"\nplan written to {args.out} (reload OK)")

    print("\n(the paper's point, automated: each site gets the cheapest "
          "accumulator that still meets the workload's accuracy bar)")
    return res


if __name__ == "__main__":
    main()
