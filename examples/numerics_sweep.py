"""The paper's core experiment as an example: sweep ⟨ovf,msb,lsb⟩ for one
workload and print the accuracy/energy trade-off + the generator's datapath
reports (Fig. 3 in miniature).

    PYTHONPATH=src python examples/numerics_sweep.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import AccumulatorSpec, BF16, FP32
from repro.core import energy
from repro.core.dispatch import GemmConfig, NumericsPolicy, use_policy
from repro.core.fdp import fdp_gemm
from repro.core.metrics import correct_bits

rng = np.random.default_rng(0)
M, K, N = 32, 512, 16
a = jnp.asarray(rng.standard_normal((M, K)) * 0.1, jnp.float32)
b = jnp.asarray(rng.standard_normal((K, N)) * 0.1, jnp.float32)
exact = np.asarray(a, np.float64) @ np.asarray(b, np.float64)

print(f"{'accumulator':28s} {'bits':>6s} {'watts':>7s} {'pJ/MAC':>7s}")
for msb, lsb in [(2, -4), (6, -8), (6, -20), (10, -30), (30, -30)]:
    spec = AccumulatorSpec(ovf=9, msb=msb, lsb=lsb)
    got = np.asarray(fdp_gemm(a, b, spec, FP32))
    bits = float(np.median(correct_bits(got, exact, cap=24)))
    p = energy.spec_power(FP32, spec)
    pj = energy.tpu_fdp_pj_per_mac(FP32.precision, spec.num_limbs)
    print(f"<ovf:9, msb:{msb:3d}, lsb:{lsb:3d}>   {bits:6.1f} "
          f"{p.watts:7.3f} {pj:7.1f}")

print("\n(the paper's point: pick the cheapest accumulator that still meets "
      "the workload's accuracy bar)")
