"""Serving example: batched prefill + incremental greedy decode with KV/SSM
caches across three model families (dense, MoE, SSM).

    PYTHONPATH=src python examples/serve_batch.py
"""

import time

import jax

from repro.configs import get_config
from repro.launch.serve import serve
from repro.models import init

for arch in ("qwen3-0.6b", "dbrx-132b", "mamba2-1.3b"):
    cfg = get_config(arch).reduced()
    params = init(cfg, jax.random.key(0))
    prompts = jax.random.randint(jax.random.key(1), (4, 10), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    toks = serve(cfg, params, prompts, gen_len=12)
    dt = time.time() - t0
    print(f"{arch:14s} ({cfg.family:6s}): {4 * 12 / dt:6.1f} tok/s  "
          f"sample={toks[0][:6].tolist()}")
