"""Routed-serving example: the plan zoo picks each request's numerics.

Three clients hit the same served model — a chat client (cheapest passing
plan), a solver (FDP-wide numerics), and a client that demands bit-stable
replies (repro-certified plan) — and the router sends each to a different
plan from the zoo's recorded evidence. The solver's reply streams token by
token; the last request asks for more bits than any plan validated and gets
a typed rejection instead of silently degraded numerics.

    PYTHONPATH=src python examples/serve_routed.py
"""

import jax

from repro.configs import get_config
from repro.models import init
from repro.serving import (BucketedEnginePool, PlanRouter, RoutedFrontend,
                           ServeRequest)

cfg = get_config("paper-mlp")
router = PlanRouter.from_manifest("examples/plans", arch=cfg.name)
cfg = cfg.reduced()
params = init(cfg, jax.random.key(0))

pool = BucketedEnginePool(cfg, params, "2x32,4x64")
front = RoutedFrontend(pool, router, max_live_batches=2)

streamed = []
requests = [
    ServeRequest(uid=0, prompt=[5, 9, 2], max_new=6, workload="chat"),
    ServeRequest(uid=1, prompt=[7, 1, 8, 3], max_new=6, workload="solve",
                 method="stream", on_token=streamed.append),
    ServeRequest(uid=2, prompt=[4, 4, 6], max_new=6, workload="repro"),
    ServeRequest(uid=3, prompt=[2, 2], max_new=4, workload="chat",
                 min_bits=99.0),           # unsatisfiable -> typed rejection
]
comps = [front.submit(r) for r in requests]
front.run()

for c in comps:
    if c.ok:
        print(f"uid={c.request.uid} {c.request.workload:5s} -> {c.plan:18s} "
              f"bucket={c.bucket}  out={c.result()}")
    else:
        print(f"uid={c.request.uid} {c.request.workload:5s} -> REJECTED: "
              f"{c.error}")
print(f"streamed (uid=1, as decoded): {streamed}")
st = front.stats()["pool"]
print(f"pool: {st['compiles']} engines compiled, "
      f"bucket hits {st['bucket_hits']}")
