"""End-to-end driver: train a reduced LM for a few hundred steps on CPU with
checkpointing and fault-tolerant restart, then greedy-decode from it.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

Plan-under-mesh runs are drivable from the CLI:

    PYTHONPATH=src python examples/train_lm.py --mesh 2x4 --profile fsdp \
        --precision-plan plans/zoo/<arch>/<plan>.json
"""

import argparse
import shutil

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.synthetic import SyntheticLM
from repro.launch.serve import serve
from repro.models import LOCAL
from repro.train.loop import Trainer, make_train_step
from repro.train.optimizer import adamw, cosine_schedule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--precision-plan", default=None,
                    help="train under a repro.numerics PrecisionPlan JSON")
    ap.add_argument("--mesh", default=None,
                    help="RxC (data x model) device mesh, e.g. 2x4")
    ap.add_argument("--profile", default="fsdp",
                    choices=["fsdp", "ddp", "decode_tp"],
                    help="sharding profile when --mesh is set")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    opt = adamw(lr=cosine_schedule(3e-3, warmup=20, total=args.steps))
    policy = None
    if args.precision_plan:
        from repro.core.dispatch import policy_from_plan
        policy = policy_from_plan(args.precision_plan)
    dist, place = LOCAL, None
    if args.mesh:
        from repro.launch import sharding as shd
        mesh = shd.make_mesh(args.mesh)
        dist = shd.distribution_for(mesh, args.profile,
                                    numerics_policy=policy)

        def place(carry):
            params, opt_state = carry
            ps = shd.param_shardings(cfg, params, mesh, profile=args.profile)
            oss = shd.opt_state_shardings(cfg, opt_state, ps, mesh,
                                          profile=args.profile)
            return jax.device_put(params, ps), jax.device_put(opt_state, oss)

    step_fn = make_train_step(cfg, opt, dist, remat="none", donate=False,
                              numerics_policy=policy)
    ds = SyntheticLM(cfg.vocab_size, seq_len=64, global_batch=16, seed=0)

    def data(step):
        tb = ds.batch(step)
        return {"tokens": tb.tokens, "targets": tb.targets,
                "loss_mask": tb.loss_mask}

    ckpt = "/tmp/repro_example_ckpt"
    shutil.rmtree(ckpt, ignore_errors=True)
    trainer = Trainer(cfg, opt, data, step_fn, ckpt, save_every=50,
                      place_state=place)
    params, _ = trainer.run(args.steps)
    losses = [m["loss"] for m in trainer.metrics_log]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({args.steps} steps, ckpts at {ckpt})")
    assert losses[-1] < losses[0]

    prompts = jax.random.randint(jax.random.key(7), (2, 8), 0, cfg.vocab_size)
    toks = serve(cfg, params, prompts, gen_len=12)
    print("greedy continuation:", toks[0].tolist())


if __name__ == "__main__":
    main()
