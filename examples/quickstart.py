"""Quickstart: generate a numerically-tailored GEMM kernel, run it, and swap
model numerics at runtime via the BLAS dispatch policy — the paper's two-phase
flow (generate a priori, dispatch at runtime) in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import AccumulatorSpec, FP32, generate_gemm
from repro.core.dispatch import (GemmConfig, NumericsPolicy, use_policy)
from repro.configs import get_config
from repro.models import LOCAL, forward, init

# ---- Phase 1: "hardware generation" — a kernel per numerical spec ----------
spec = AccumulatorSpec.paper_91bit()          # <ovf:30, msb:30, lsb:-30>
gen = generate_gemm(spec, FP32, target="pallas", tile=(32, 32, 128))
print(gen.report.describe())

rng = np.random.default_rng(0)
a = jnp.asarray(rng.standard_normal((64, 256)), jnp.float32)
b = jnp.asarray(rng.standard_normal((256, 32)), jnp.float32)
out = gen.fn(a, b)
ref = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
print("91-bit FDP vs f64 max rel err:",
      float(np.abs((np.asarray(out) - ref) / ref).max()))

# ---- Phase 2: runtime dispatch — swap a model's numerics without touching it
cfg = get_config("qwen3-0.6b").reduced()
params = init(cfg, jax.random.key(0))
tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)

mxu = NumericsPolicy(GemmConfig(FP32, None, "native"), name="mxu")
tailored = NumericsPolicy(
    GemmConfig(FP32, AccumulatorSpec(ovf=9, msb=6, lsb=-20), "simulate"),
    name="resnet50-pick")                     # the paper's Fig.-3 winner

with use_policy(mxu):
    logits_fast = forward(params, cfg, {"tokens": tokens}, LOCAL, remat="none")
with use_policy(tailored):
    logits_tail = forward(params, cfg, {"tokens": tokens}, LOCAL, remat="none")

agree = float((logits_fast.argmax(-1) == logits_tail.argmax(-1)).mean())
print(f"top-1 agreement MXU vs tailored <9,6,-20>: {agree:.3f}")
