"""Roofline table: reads the dry-run JSONs (results/dryrun) and prints the
three-term roofline per (arch x shape x mesh) — EXPERIMENTS.md §Roofline is
generated from this output."""

import glob
import json
import os
import sys


def load(out_dir="results/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def run(out_dir="results/dryrun"):
    rows = load(out_dir)
    print("name,us_per_call,derived")
    done = skipped = 0
    for r in rows:
        tag = f"roofline_{r['arch']}_{r['shape']}_{r.get('mesh', '-')}"
        if "skipped" in r:
            skipped += 1
            print(f"{tag},0,SKIP:{r['skipped']}")
            continue
        done += 1
        rf = r["roofline"]
        dom_t = max(rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"])
        frac = rf["t_compute_s"] / dom_t if dom_t else 0.0
        print(f"{tag},{dom_t*1e6:.0f},"
              f"t_comp={rf['t_compute_s']*1e3:.2f}ms"
              f"|t_mem={rf['t_memory_s']*1e3:.2f}ms"
              f"|t_coll={rf['t_collective_s']*1e3:.2f}ms"
              f"|dom={rf['dominant']}"
              f"|comp_frac={frac:.3f}"
              f"|useful={rf['useful_flops_ratio'] and round(rf['useful_flops_ratio'],3)}"
              f"|mem/dev={r['memory']['per_device_total']/2**30:.2f}GiB")
    print(f"roofline_summary,0,cells={done}|skipped={skipped}")


if __name__ == "__main__":
    run(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
