"""FCCM'22 throughput-table analogue: generated-kernel GEMM benchmark.

Wall-times on this CPU container are *not* TPU numbers; alongside them we
report the generator's datapath model (limbs, int-ops/MAC, modeled pJ/MAC,
modeled FPGA watts) which is the basis of the Fig. 2/3 energy axes, and the
MXU-native baseline for the same shapes.

Three sections:
  * the classic per-shape table (native / simulate / pallas targets),
  * **grad rows**: ``value_and_grad`` over a dispatched GEMM per mode (one
    forward + the two phase-dispatched backward GEMMs through the custom_vjp
    layer) so the regression gate covers gradient-dispatch overhead, and
  * the **hot-path section**: a GemmPlan sweep of the vectorized Pallas
    engine at (M,N,K) = (256, 256, 1024), measured against the seed per-k
    scalar-loop kernel (kept as ``impl="loop"``) with a bit-exactness check —
    the speedup this PR's execution engine is accountable for.

``--json out.json`` additionally writes every row machine-readably
(per-impl/per-shape wall time + modeled energy) so benchmark trajectories
can be tracked across commits (CI uploads it as an artifact); ``--quick``
trims the table and skips the hot-path sweep for bounded CI lanes.
"""

import argparse
import json
import platform
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (AccumulatorSpec, FP32, GemmPlan, generate_gemm,
                        plan_gemm)
from repro.core.energy import FREQ_HZ, gemm_power
from repro.kernels import ops as kops

# Grad rows: value_and_grad over one dispatched GEMM per execution mode —
# one forward plus the two phase-dispatched backward GEMMs (dA = G·Bᵀ,
# dB = Aᵀ·G), i.e. the training hot path through the custom_vjp dispatch.
GRAD_SHAPES = [(64, 256, 64)]
QUICK_GRAD_SHAPES = [(32, 128, 32)]

SHAPES = [(64, 256, 64), (128, 512, 128)]
QUICK_SHAPES = [(32, 128, 32)]
# quick mode adds native-only rows at these shapes: the bench-regression
# gate anchors its cross-machine speed calibration on the native (pure-XLA)
# rows, and sub-millisecond samples are too noisy to anchor on — these run
# several ms per call, comfortably above the gate's noise floor, at
# negligible bench cost (no FDP kernels run for them).
QUICK_NATIVE_ANCHORS = [(256, 1024, 256), (384, 1536, 384), (512, 2048, 512)]
SPECS = [AccumulatorSpec.paper_91bit(), AccumulatorSpec(9, 6, -20)]

# Hot-path acceptance shape and the seed kernel's hardcoded tile.
HOT_SHAPE = (256, 256, 1024)
SEED_TILE = (32, 32, 128)
SWEEP_TILES = [(32, 32, 128), (32, 32, 512), (64, 64, 512), (128, 128, 512),
               (128, 128, 1024)]

ROWS: list = []                 # machine-readable mirror of every CSV line


def emit(name, seconds_per_call, derived, *, shape=None, spec=None,
         impl=None, unit="us"):
    """Print the classic CSV line and mirror it into ROWS for --json."""
    val = seconds_per_call * 1e6 if unit == "us" else seconds_per_call
    fmtv = f"{val:.0f}" if unit == "us" else f"{val:.2f}"
    print(f"{name},{fmtv},{derived}")
    row = {"name": name, "seconds_per_call": seconds_per_call,
           "derived": derived}
    if impl:
        row["impl"] = impl
    if shape is not None:
        M, K, N = shape
        macs = M * K * N
        row["shape"] = {"M": M, "K": K, "N": N}
        if seconds_per_call > 0:
            row["gflops"] = 2 * macs / seconds_per_call / 1e9
        if spec is not None or impl == "native":
            p = gemm_power(FP32, spec)
            row["modeled"] = {
                "watts_fpga": p.watts,
                "energy_j_per_call": p.energy_joules(macs),
                "freq_hz": FREQ_HZ,
            }
    ROWS.append(row)


def timeit(fn, *args, reps=3):
    """Best-of-``reps`` after a compile+warm call: on this container's
    shared CPU a mean absorbs throttling bursts and swings 2-4x between
    runs; the minimum is the stable machine-capability number the
    regression gate can anchor on."""
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def run_table(shapes=SHAPES, specs=SPECS):
    rng = np.random.default_rng(0)
    print("name,us_per_call,derived")
    for (M, K, N) in shapes:
        a = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
        flops = 2 * M * K * N

        g_native = generate_gemm(None, FP32, "native")
        s = timeit(g_native.fn, a, b)
        emit(f"gemm_native_f32_{M}x{K}x{N}", s,
             f"GFLOPs={flops/s/1e9:.2f}|{g_native.report.describe()!r}",
             shape=(M, K, N), impl="native")

        for spec in specs:
            for target in ("simulate", "pallas"):
                g = generate_gemm(spec, FP32, target)       # tile: auto-plan
                s = timeit(g.fn, a, b, reps=3)
                r = g.report
                emit(f"gemm_{target}_w{spec.width}_{M}x{K}x{N}", s,
                     f"GFLOPs={flops/s/1e9:.3f}"
                     f"|limbs={r.num_limbs}|intops/mac={r.int_ops_per_mac}"
                     f"|pJ/MAC={r.pj_per_mac_tpu_model:.1f}"
                     f"|P_fpga={r.watts_fpga_model:.3f}W",
                     shape=(M, K, N), spec=spec, impl=target)
    # bit-exactness cross-check at bench shapes
    spec = AccumulatorSpec.paper_91bit()
    gs = generate_gemm(spec, FP32, "simulate")
    gp = generate_gemm(spec, FP32, "pallas", tile=(32, 32, 128))
    a = jnp.asarray(rng.standard_normal((48, 160)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((160, 24)), jnp.float32)
    same = bool(jnp.array_equal(gs.fn(a, b), gp.fn(a, b)))
    emit("gemm_parity_check", 0, f"bitexact={same}")
    assert same


def run_grad_rows(shapes=GRAD_SHAPES):
    """Backward-pass dispatch rows: ``value_and_grad`` over one dispatched
    GEMM per mode, so the regression gate covers the custom_vjp gradient
    dispatch overhead (policy lookup + two bwd-site GEMMs), not just the
    forward kernels. The ``gflops`` figure counts all three GEMMs."""
    from repro.core.dispatch import (FDP91, MXU_FP32, GemmConfig,
                                     NumericsPolicy, gemm, use_policy)

    spec = AccumulatorSpec.paper_91bit()
    policies = [
        ("native_f32", MXU_FP32, None),
        ("simulate_w91", FDP91, spec),
        ("pallas_w91",
         NumericsPolicy(GemmConfig(FP32, spec, "pallas"), name="pallas91"),
         spec),
    ]
    rng = np.random.default_rng(3)
    for (M, K, N) in shapes:
        a = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
        flops = 3 * 2 * M * K * N              # fwd + dA + dB
        for tag, policy, acc in policies:
            def loss(x, y):
                return gemm(x, y, site="bench_grad").sum()

            with use_policy(policy):
                vg = jax.jit(jax.value_and_grad(loss, argnums=(0, 1)))
                s = timeit(lambda: vg(a, b)[1][0])
            emit(f"gemm_grad_{tag}_{M}x{K}x{N}", s,
                 f"GFLOPs={flops/s/1e9:.3f}|fwd+dA+dB",
                 shape=(M, K, N), spec=acc, impl=f"grad_{tag.split('_')[0]}")
            # emit() assumes one GEMM per call; a grad call runs three
            # (fwd + dA + dB), so both derived figures scale by 3
            ROWS[-1]["gflops"] = flops / s / 1e9
            if "modeled" in ROWS[-1]:
                ROWS[-1]["modeled"]["energy_j_per_call"] *= 3


def run_native_anchors(shapes=QUICK_NATIVE_ANCHORS):
    """Native-only rows for the regression gate's machine-speed anchor."""
    rng = np.random.default_rng(2)
    for (M, K, N) in shapes:
        a = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
        flops = 2 * M * K * N
        g = generate_gemm(None, FP32, "native")
        s = timeit(g.fn, a, b, reps=5)
        emit(f"gemm_native_f32_{M}x{K}x{N}", s, f"GFLOPs={flops/s/1e9:.2f}",
             shape=(M, K, N), impl="native")


def _best_of(fn, reps=2):
    """Compile+warm once, then best wall-clock of ``reps`` (the container's
    cpu-share throttling makes single samples noisy)."""
    out = jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best, out


def run_hotpath():
    """Plan sweep + seed-kernel comparison at HOT_SHAPE (the PR's acceptance
    measurement): vectorized engine vs the seed per-k loop kernel at the
    seed's hardcoded tile, bit-exact, for both seed-bench accumulators."""
    rng = np.random.default_rng(1)
    M, N, K = HOT_SHAPE
    a = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    flops = 2 * M * K * N
    speedups, exact = {}, True

    for spec in SPECS:
        print(f"\n# hot path (M,N,K)=({M},{N},{K}), spec={spec.describe()}")
        print("name,seconds_per_call,derived")

        # the seed kernel: per-k fori_loop body at the seed's hardcoded tile
        t_seed, out_seed = _best_of(
            lambda: kops.fdp_gemm(a, b, spec=spec, plan=GemmPlan(*SEED_TILE),
                                  impl="loop"))
        emit(f"pallas_seed_loop_w{spec.width}_"
             f"{'x'.join(map(str, SEED_TILE))}", t_seed,
             f"GFLOPs={flops/t_seed/1e9:.3f}",
             shape=(M, K, N), spec=spec, impl="pallas_loop", unit="s")

        best = (None, float("inf"), None)
        for bm, bn, bk in SWEEP_TILES:
            t, out = _best_of(
                lambda: kops.fdp_gemm(a, b, spec=spec,
                                      plan=GemmPlan(bm, bn, bk)))
            emit(f"pallas_vector_w{spec.width}_{bm}x{bn}x{bk}", t,
                 f"GFLOPs={flops/t/1e9:.3f}|speedup={t_seed/t:.1f}x",
                 shape=(M, K, N), spec=spec, impl="pallas_vector", unit="s")
            if t < best[1]:
                best = ((bm, bn, bk), t, out)

        plan = plan_gemm(M, N, K, fmt=FP32, spec=spec)
        t_plan, out_plan = _best_of(
            lambda: kops.fdp_gemm(a, b, spec=spec, plan=plan))
        emit(f"pallas_vector_planned_w{spec.width}_"
             f"{plan.bm}x{plan.bn}x{plan.bk}", t_plan,
             f"GFLOPs={flops/t_plan/1e9:.3f}|source={plan.source}"
             f"|speedup={t_seed/t_plan:.1f}x",
             shape=(M, K, N), spec=spec, impl="pallas_vector_planned",
             unit="s")

        exact &= bool(jnp.array_equal(out_seed, out_plan)) and \
            bool(jnp.array_equal(out_seed, best[2]))
        speedups[f"w{spec.width}"] = t_seed / min(t_plan, best[1])
        emit(f"hotpath_w{spec.width}", 0,
             f"best_tile={best[0]}"
             f"|speedup={speedups[f'w{spec.width}']:.1f}x|bitexact={exact}")

    top = max(speedups.values())
    detail = "|".join(f"{k}={v:.1f}x" for k, v in speedups.items())
    print()
    emit("hotpath_summary", 0, f"{detail}|best={top:.1f}x|bitexact={exact}")
    assert exact, "vectorized engine output diverged from the seed kernel"
    assert top >= 5.0, (
        f"hot-path speedup {detail} never reached the 5x acceptance bar")


# Ragged (MoE expert) GEMM: tokens sorted by expert. (T, d, f, E).
RAGGED_CASES = [(256, 128, 128, 8)]
QUICK_RAGGED_CASES = [(128, 64, 64, 4)]


def _uneven_groups(T, E):
    """Deterministic uneven segment sizes summing to T, with one
    intentionally empty expert (the routing edge case the sorted-segment
    kernel must not mis-walk)."""
    w = np.arange(1, E + 1, dtype=np.int64)
    gs = (w * T) // w.sum()
    gs[0] += T - gs.sum()
    if E > 2:
        gs[0] += gs[1]
        gs[1] = 0
    return np.asarray(gs, np.int64)


def run_ragged_rows(cases=RAGGED_CASES):
    """MoE ragged-GEMM rows: XLA's native ragged_dot anchor, the grouped FDP
    reference (every expert over every token, O(T*E*d*f) MACs, then select),
    and the sorted-segment FDP kernel (contiguous segment walk, O(T*d*f)).
    All three gflops figures count the *useful* work 2*T*d*f, so the
    reference row's deficit vs the segment row is exactly the E-fold
    wasted-MAC factor this kernel removes. Reference and segment outputs are
    asserted bit-identical."""
    spec = SPECS[0]
    rng = np.random.default_rng(7)
    for (T, d, f, E) in cases:
        gs_np = _uneven_groups(T, E)
        gs = jnp.asarray(gs_np, jnp.int32)
        x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((E, d, f)), jnp.float32)
        flops = 2 * T * d * f
        tag = f"{T}x{d}x{f}_E{E}"

        if hasattr(jax.lax, "ragged_dot"):
            native = jax.jit(lambda: jax.lax.ragged_dot(x, w, gs))
        else:  # dense one-hot contraction: still pure-XLA, still an anchor
            seg_oh = jnp.asarray(np.repeat(np.arange(E), gs_np))
            oh = jax.nn.one_hot(seg_oh, E, dtype=jnp.float32)
            native = jax.jit(lambda: jnp.einsum("td,te,edf->tf", x, oh, w))
        t_nat, _ = _best_of(native)
        emit(f"ragged_native_{tag}", t_nat, f"GFLOPs={flops/t_nat/1e9:.3f}",
             shape=(T, d, f), impl="native", unit="s")

        # token-axis block at the mean segment size (what the dispatch
        # ragged path deploys): boundary-tile overhead stays O(E*bm) << T
        from repro.core.dispatch import _fit_ragged
        plan = _fit_ragged(plan_gemm(T, f, d, fmt=FP32, spec=spec),
                           "bm", T, E)
        seg = np.repeat(np.arange(E), gs_np)

        def reference():
            outs = jnp.stack([kops.fdp_gemm(x, w[e], spec=spec, plan=plan)
                              for e in range(E)])
            return outs[seg, np.arange(T)]

        t_ref, out_ref = _best_of(reference)
        emit(f"ragged_fdp_reference_w{spec.width}_{tag}", t_ref,
             f"GFLOPs={flops/t_ref/1e9:.3f}|grouped O(T*E) MACs",
             shape=(T, d, f), spec=spec, impl="ragged_reference", unit="s")

        t_seg, out_seg = _best_of(
            lambda: kops.fdp_ragged_gemm(x, w, gs, spec=spec, plan=plan))
        same = bool(jnp.array_equal(out_ref, out_seg))
        emit(f"ragged_fdp_segment_w{spec.width}_{tag}", t_seg,
             f"GFLOPs={flops/t_seg/1e9:.3f}|speedup={t_ref/t_seg:.1f}x"
             f"|bitexact={same}",
             shape=(T, d, f), spec=spec, impl="ragged_segment", unit="s")
        assert same, "sorted-segment kernel diverged from grouped reference"


def run(quick: bool = False, json_path: str | None = None):
    ROWS.clear()
    t0 = time.time()
    if quick:
        run_table(shapes=QUICK_SHAPES, specs=[SPECS[0]])
        run_grad_rows(shapes=QUICK_GRAD_SHAPES)
        run_native_anchors()
        run_ragged_rows(cases=QUICK_RAGGED_CASES)
    else:
        run_table()
        run_grad_rows()
        run_hotpath()
        run_ragged_rows()
    if json_path:
        doc = {
            "bench": "bench_gemm",
            "quick": quick,
            "backend": jax.default_backend(),
            "platform": platform.platform(),
            "wall_seconds": time.time() - t0,
            "rows": ROWS,
        }
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        print(f"# wrote {len(ROWS)} rows to {json_path}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable rows (BENCH_gemm.json)")
    ap.add_argument("--quick", action="store_true",
                    help="small shapes, no hot-path sweep (CI lane)")
    args = ap.parse_args(argv)
    run(quick=args.quick, json_path=args.json)


if __name__ == "__main__":
    main()
