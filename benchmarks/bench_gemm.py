"""FCCM'22 throughput-table analogue: generated-kernel GEMM benchmark.

Wall-times on this CPU container are *not* TPU numbers; alongside them we
report the generator's datapath model (limbs, int-ops/MAC, modeled pJ/MAC,
modeled FPGA watts) which is the basis of the Fig. 2/3 energy axes, and the
MXU-native baseline for the same shapes.
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import AccumulatorSpec, FP32, BF16, generate_gemm

SHAPES = [(64, 256, 64), (128, 512, 128)]
SPECS = [AccumulatorSpec.paper_91bit(), AccumulatorSpec(9, 6, -20)]


def timeit(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rng = np.random.default_rng(0)
    print("name,us_per_call,derived")
    for (M, K, N) in SHAPES:
        a = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
        flops = 2 * M * K * N

        g_native = generate_gemm(None, FP32, "native")
        us = timeit(g_native.fn, a, b)
        print(f"gemm_native_f32_{M}x{K}x{N},{us:.0f},"
              f"GFLOPs={flops/us/1e3:.2f}|{g_native.report.describe()!r}")

        for spec in SPECS:
            for target in ("simulate", "pallas"):
                g = generate_gemm(spec, FP32, target, tile=(32, 32, 128))
                us = timeit(g.fn, a, b, reps=1)
                r = g.report
                print(f"gemm_{target}_w{spec.width}_{M}x{K}x{N},{us:.0f},"
                      f"GFLOPs={flops/us/1e3:.3f}"
                      f"|limbs={r.num_limbs}|intops/mac={r.int_ops_per_mac}"
                      f"|pJ/MAC={r.pj_per_mac_tpu_model:.1f}"
                      f"|P_fpga={r.watts_fpga_model:.3f}W")
    # bit-exactness cross-check at bench shapes
    spec = AccumulatorSpec.paper_91bit()
    gs = generate_gemm(spec, FP32, "simulate")
    gp = generate_gemm(spec, FP32, "pallas", tile=(32, 32, 128))
    a = jnp.asarray(rng.standard_normal((48, 160)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((160, 24)), jnp.float32)
    same = bool(jnp.array_equal(gs.fn(a, b), gp.fn(a, b)))
    print(f"gemm_parity_check,0,bitexact={same}")
    assert same


if __name__ == "__main__":
    run()
