"""FCCM'22 throughput-table analogue: generated-kernel GEMM benchmark.

Wall-times on this CPU container are *not* TPU numbers; alongside them we
report the generator's datapath model (limbs, int-ops/MAC, modeled pJ/MAC,
modeled FPGA watts) which is the basis of the Fig. 2/3 energy axes, and the
MXU-native baseline for the same shapes.

Two sections:
  * the classic per-shape table (native / simulate / pallas targets), and
  * the **hot-path section**: a GemmPlan sweep of the vectorized Pallas
    engine at (M,N,K) = (256, 256, 1024), measured against the seed per-k
    scalar-loop kernel (kept as ``impl="loop"``) with a bit-exactness check —
    the speedup this PR's execution engine is accountable for.
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (AccumulatorSpec, FP32, BF16, GemmPlan, generate_gemm,
                        plan_gemm)
from repro.kernels import ops as kops

SHAPES = [(64, 256, 64), (128, 512, 128)]
SPECS = [AccumulatorSpec.paper_91bit(), AccumulatorSpec(9, 6, -20)]

# Hot-path acceptance shape and the seed kernel's hardcoded tile.
HOT_SHAPE = (256, 256, 1024)
SEED_TILE = (32, 32, 128)
SWEEP_TILES = [(32, 32, 128), (32, 32, 512), (64, 64, 512), (128, 128, 512),
               (128, 128, 1024)]


def timeit(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run_table():
    rng = np.random.default_rng(0)
    print("name,us_per_call,derived")
    for (M, K, N) in SHAPES:
        a = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
        flops = 2 * M * K * N

        g_native = generate_gemm(None, FP32, "native")
        us = timeit(g_native.fn, a, b)
        print(f"gemm_native_f32_{M}x{K}x{N},{us:.0f},"
              f"GFLOPs={flops/us/1e3:.2f}|{g_native.report.describe()!r}")

        for spec in SPECS:
            for target in ("simulate", "pallas"):
                g = generate_gemm(spec, FP32, target)       # tile: auto-plan
                us = timeit(g.fn, a, b, reps=1)
                r = g.report
                print(f"gemm_{target}_w{spec.width}_{M}x{K}x{N},{us:.0f},"
                      f"GFLOPs={flops/us/1e3:.3f}"
                      f"|limbs={r.num_limbs}|intops/mac={r.int_ops_per_mac}"
                      f"|pJ/MAC={r.pj_per_mac_tpu_model:.1f}"
                      f"|P_fpga={r.watts_fpga_model:.3f}W")
    # bit-exactness cross-check at bench shapes
    spec = AccumulatorSpec.paper_91bit()
    gs = generate_gemm(spec, FP32, "simulate")
    gp = generate_gemm(spec, FP32, "pallas", tile=(32, 32, 128))
    a = jnp.asarray(rng.standard_normal((48, 160)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((160, 24)), jnp.float32)
    same = bool(jnp.array_equal(gs.fn(a, b), gp.fn(a, b)))
    print(f"gemm_parity_check,0,bitexact={same}")
    assert same


def _best_of(fn, reps=2):
    """Compile+warm once, then best wall-clock of ``reps`` (the container's
    cpu-share throttling makes single samples noisy)."""
    out = jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best, out


def run_hotpath():
    """Plan sweep + seed-kernel comparison at HOT_SHAPE (the PR's acceptance
    measurement): vectorized engine vs the seed per-k loop kernel at the
    seed's hardcoded tile, bit-exact, for both seed-bench accumulators."""
    rng = np.random.default_rng(1)
    M, N, K = HOT_SHAPE
    a = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    flops = 2 * M * K * N
    speedups, exact = {}, True

    for spec in SPECS:
        print(f"\n# hot path (M,N,K)=({M},{N},{K}), spec={spec.describe()}")
        print("name,seconds_per_call,derived")

        # the seed kernel: per-k fori_loop body at the seed's hardcoded tile
        t_seed, out_seed = _best_of(
            lambda: kops.fdp_gemm(a, b, spec=spec, bm=SEED_TILE[0],
                                  bn=SEED_TILE[1], bk=SEED_TILE[2],
                                  impl="loop"))
        print(f"pallas_seed_loop_w{spec.width}_"
              f"{'x'.join(map(str, SEED_TILE))},{t_seed:.2f},"
              f"GFLOPs={flops/t_seed/1e9:.3f}")

        best = (None, float("inf"), None)
        for bm, bn, bk in SWEEP_TILES:
            t, out = _best_of(
                lambda: kops.fdp_gemm(a, b, spec=spec, bm=bm, bn=bn, bk=bk))
            print(f"pallas_vector_w{spec.width}_{bm}x{bn}x{bk},{t:.2f},"
                  f"GFLOPs={flops/t/1e9:.3f}|speedup={t_seed/t:.1f}x")
            if t < best[1]:
                best = ((bm, bn, bk), t, out)

        plan = plan_gemm(M, N, K, fmt=FP32, spec=spec)
        t_plan, out_plan = _best_of(
            lambda: kops.fdp_gemm(a, b, spec=spec, bm=plan.bm, bn=plan.bn,
                                  bk=plan.bk))
        print(f"pallas_vector_planned_w{spec.width}_"
              f"{plan.bm}x{plan.bn}x{plan.bk},{t_plan:.2f},"
              f"GFLOPs={flops/t_plan/1e9:.3f}|source={plan.source}"
              f"|speedup={t_seed/t_plan:.1f}x")

        exact &= bool(jnp.array_equal(out_seed, out_plan)) and \
            bool(jnp.array_equal(out_seed, best[2]))
        speedups[f"w{spec.width}"] = t_seed / min(t_plan, best[1])
        print(f"hotpath_w{spec.width},0,best_tile={best[0]}"
              f"|speedup={speedups[f'w{spec.width}']:.1f}x|bitexact={exact}")

    top = max(speedups.values())
    detail = "|".join(f"{k}={v:.1f}x" for k, v in speedups.items())
    print(f"\nhotpath_summary,0,{detail}|best={top:.1f}x|bitexact={exact}")
    assert exact, "vectorized engine output diverged from the seed kernel"
    assert top >= 5.0, (
        f"hot-path speedup {detail} never reached the 5x acceptance bar")


def run():
    run_table()
    run_hotpath()


if __name__ == "__main__":
    run()
