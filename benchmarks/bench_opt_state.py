#!/usr/bin/env python
"""Quantized optimizer-state bench: step time, bytes resident, bytes moved.

Times the Adam update (dequant -> update -> requant when state is
quantized) over a model-shaped parameter tree, fp32 state vs block-scaled
``q8b64`` carriers, plus the gradient all-reduce payload through
``quantized_psum`` vs the plain float psum. The fp32 rows carry
``impl="native"`` — plain XLA arithmetic this repo's quantization code
cannot slow down — so the regression gate calibrates cross-machine speed on
them, same as ``bench_gemm``/``bench_serving``.

Alongside the gated throughput rows (``metric="steps_per_s"``), metric-less
info rows record the byte evidence: optimizer bytes resident and psum
payload bytes, each with its ratio vs fp32 (the committed baseline pins
both at ~0.25x, and the bench asserts <= 0.5x).

    PYTHONPATH=src python benchmarks/bench_opt_state.py --quick --json out.json
    python scripts/check_bench_regression.py --baseline BENCH_opt.json \
        --new out.json
"""

import argparse
import json
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.qformat import QuantConfig, quant_bytes
from repro.models import init
from repro.train.optimizer import (adamw, apply_updates,
                                   optimizer_state_bytes)

Q8 = QuantConfig(8, 64)


def build_tree(arch: str, copies: int):
    """A model-shaped parameter tree, replicated ``copies`` times so the
    update stays above the gate's noise floor on fast runners (the reduced
    configs are ~115k params; the quantize/dequant cost scales linearly)."""
    cfg = get_config(arch).reduced()
    base = init(cfg, jax.random.key(0))
    return {f"rep{i}": base for i in range(copies)}


def time_call(fn, *args, reps: int):
    out = jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps, out


def bench_opt_step(arch, params, grads, squant, reps):
    opt = adamw(1e-3, state_quant=squant)
    state = opt.init(params)

    @jax.jit
    def step(p, s, g):
        u, s = opt.update(g, s, p)
        return apply_updates(p, u), s

    sec, (_, state) = time_call(step, params, state, grads, reps=reps)
    tag = "q8b64" if squant else "fp32"
    n = sum(x.size for x in jax.tree.leaves(params))
    return {"name": f"opt_step_{tag}_state_{arch}",
            "impl": "native" if squant is None else "quantized",
            "seconds_per_call": sec, "steps_per_s": 1.0 / sec,
            "state_bytes": optimizer_state_bytes(state),
            "derived": f"adam update over {n} params, {tag} moments"}


def bench_psum(n, cfg, reps):
    """Gradient-mean all-reduce payload path on a 1-device mesh (the wire
    format's quantize/reduce/dequantize cost; payload bytes are modeled by
    ``quant_bytes``, identical at any device count)."""
    from jax.sharding import Mesh, PartitionSpec as P
    import jax.experimental.shard_map as shard_map
    from repro.parallel.collectives import quantized_psum

    mesh = Mesh(np.array(jax.devices()[:1]), ("dp",))
    x = jax.random.normal(jax.random.key(1), (n,)) * 0.1
    f = jax.jit(shard_map.shard_map(
        lambda v: quantized_psum(v, "dp", cfg, mean=True), mesh=mesh,
        in_specs=(P(),), out_specs=P()))
    sec, _ = time_call(f, x, reps=reps)
    tag = cfg.tag()
    return {"name": f"grad_psum_{tag}_{n}",
            "impl": "native" if cfg.mode == "fp32" else "quantized",
            "seconds_per_call": sec, "steps_per_s": 1.0 / sec,
            "payload_bytes": quant_bytes(n, cfg),
            "derived": f"{tag} gradient-mean psum over {n} elements"}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-mlp")
    ap.add_argument("--quick", action="store_true",
                    help="fewer reps/copies for bounded CI lanes")
    ap.add_argument("--copies", type=int, default=None)
    ap.add_argument("--psum-elements", type=int, default=None)
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    copies = args.copies or (4 if args.quick else 8)
    n_psum = args.psum_elements or (1 << 20)
    reps = 3 if args.quick else 10
    t0 = time.perf_counter()

    params = build_tree(args.arch, copies)
    grads = jax.tree.map(
        lambda p: jax.random.normal(jax.random.key(2), p.shape) * 0.01,
        params)
    squant = {"mu": Q8, "nu": Q8}
    rows = [
        bench_opt_step(args.arch, params, grads, None, reps),
        bench_opt_step(args.arch, params, grads, squant, reps),
        bench_psum(n_psum, QuantConfig(mode="fp32"), reps),
        bench_psum(n_psum, Q8, reps),
    ]
    # metric-less info rows: the byte evidence (ignored by the gate's
    # throughput matching, recorded in the committed baseline)
    by = {r["name"]: r for r in rows}
    fp_res = by[f"opt_step_fp32_state_{args.arch}"]["state_bytes"]
    q_res = by[f"opt_step_q8b64_state_{args.arch}"]["state_bytes"]
    fp_wire = by[f"grad_psum_fp32_{n_psum}"]["payload_bytes"]
    q_wire = by[f"grad_psum_q8b64_{n_psum}"]["payload_bytes"]
    rows += [
        {"name": f"opt_state_bytes_resident_{args.arch}", "impl": "info",
         "fp32_bytes": fp_res, "q8b64_bytes": q_res,
         "ratio_vs_fp32": q_res / fp_res,
         "derived": "Adam moment carrier bytes, quantized vs fp32"},
        {"name": f"grad_psum_payload_bytes_{n_psum}", "impl": "info",
         "fp32_bytes": fp_wire, "q8b64_bytes": q_wire,
         "ratio_vs_fp32": q_wire / fp_wire,
         "derived": "all-reduce payload bytes per device, q8b64 vs fp32"},
    ]
    assert q_res <= 0.5 * fp_res, "resident bytes not halved"
    assert q_wire <= 0.5 * fp_wire, "payload bytes not halved"

    for r in rows:
        us = r.get("seconds_per_call", 0.0) * 1e6
        extra = (f"{r['steps_per_s']:.1f} steps/s" if "steps_per_s" in r
                 else f"ratio {r['ratio_vs_fp32']:.3f}x")
        print(f"{r['name']:44s} {us:10.1f} us  {extra}")

    if args.json:
        doc = {"bench": "bench_opt_state", "metric": "steps_per_s",
               "arch": f"{args.arch}-reduced", "quick": args.quick,
               "backend": jax.default_backend(),
               "platform": platform.platform(),
               "wall_seconds": time.perf_counter() - t0, "rows": rows}
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
