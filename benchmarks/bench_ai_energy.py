"""Fig. 3 reproduction — accuracy vs inference energy across
(format x accumulator) combinations.

The paper sweeps ResNets/VGG on ImageNet; offline we keep the experiment
design and swap the workload for a small trained transformer LM (the
"paper-mlp" config): the quality metric is Top-1 *next-token agreement* with
the exact-accumulator (91-bit) reference on a fixed eval batch, and the
energy axis is the VU3P-calibrated power model x modeled cycles (MACs at
II=1), exactly as the paper trades DSP width for watts.

Output: one CSV row per (format, accumulator) with agreement + energy; the
Pareto front (the paper's actual claim) is annotated.
"""

import itertools
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import AccumulatorSpec, BF16, FP32
from repro.core import energy
from repro.core.dispatch import (GemmConfig, NumericsPolicy, use_policy,
                                 MXU_FP32)
from repro.data.synthetic import SyntheticLM
from repro.models import LOCAL, forward, init
from repro.train.loop import make_train_step
from repro.train.optimizer import adamw


def train_tiny(cfg, steps=30, batch=8, seq=32):
    opt = adamw(lr=3e-3)
    step_fn = make_train_step(cfg, opt, LOCAL, remat="none", donate=False)
    params = init(cfg, jax.random.key(0))
    state = (params, opt.init(params))
    ds = SyntheticLM(cfg.vocab_size, seq, batch, seed=0)
    for s in range(steps):
        tb = ds.batch(s)
        state, m = step_fn(state, {"tokens": tb.tokens, "targets": tb.targets,
                                   "loss_mask": tb.loss_mask})
    return state[0], float(m["loss"])


def macs_per_token(cfg):
    # projections + attention + mlp, per token (rough analytical count)
    return cfg.active_param_count()


def run():
    cfg = get_config("paper-mlp").reduced(
        d_model=96, d_ff=192, n_layers=2, vocab_size=128, n_heads=4,
        n_kv_heads=4, head_dim=24)
    params, final_loss = train_tiny(cfg)
    ds = SyntheticLM(cfg.vocab_size, 24, 8, seed=99)
    tb = ds.batch(0)
    batch = {"tokens": tb.tokens}

    # exact reference: 91-bit accumulator, fp32 inputs (simulate mode)
    ref_spec = AccumulatorSpec.paper_91bit()
    ref_pol = NumericsPolicy(GemmConfig(FP32, ref_spec, "simulate"),
                             name="exact_ref")
    with use_policy(ref_pol):
        ref_logits = np.asarray(forward(params, cfg, batch, LOCAL,
                                        remat="none"))
    ref_top1 = ref_logits.argmax(-1)

    n_tokens = int(np.prod(tb.tokens.shape))
    n_macs = macs_per_token(cfg) * n_tokens

    sweeps = []
    for fmt in (FP32, BF16):
        for msb, lsb in itertools.product((2, 6, 10), (-4, -8, -12, -20)):
            sweeps.append((fmt, AccumulatorSpec(ovf=5, msb=msb, lsb=lsb)))

    print("name,us_per_call,derived")
    results = []
    for fmt, spec in sweeps:
        pol = NumericsPolicy(GemmConfig(fmt, spec, "simulate"))
        t0 = time.perf_counter()
        with use_policy(pol):
            logits = np.asarray(forward(params, cfg, batch, LOCAL,
                                        remat="none"))
        dt = (time.perf_counter() - t0) * 1e6
        agree = float((logits.argmax(-1) == ref_top1).mean())
        rep = energy.spec_power(fmt, spec)
        e_j = rep.energy_joules(n_macs)
        results.append((fmt.name, spec, agree, e_j, dt))

    # Pareto front on (energy ascending, agreement descending)
    front = set()
    best = -1.0
    for i, r in sorted(enumerate(results), key=lambda t: t[1][3]):
        if r[2] > best:
            best = r[2]
            front.add(i)
    for i, (fname, spec, agree, e_j, dt) in enumerate(results):
        tag = "PARETO" if i in front else "-"
        print(f"ai_{fname}_ovf{spec.ovf}_msb{spec.msb}_lsb{spec.lsb},"
              f"{dt:.0f},agree={agree:.3f}|energy_J={e_j:.3e}|{tag}")
    return results


if __name__ == "__main__":
    run()
