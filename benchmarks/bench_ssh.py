"""Fig. 2 reproduction — Sea-Surface-Height style reproducibility study.

SSH reduces to long, increasingly ill-conditioned dot products. We generate
Ogita-Rump-Oishi dot products with prescribed condition number, then compare:

    fp64 FMA   : sequential accumulation in float64 (rounds every step)
    fp128 FMA  : double-double compensated accumulation (~106-bit, emulated)
    91-bit FDP : the paper's ⟨ovf:30, msb:30, lsb:-30⟩ exact accumulator

Adaptation note (DESIGN.md §7): inputs are f32 quantized to 12 fractional
bits so every product lies on the 91-bit grid — mirroring the paper's SSH
data, whose f64 products fit the window of its FDP. The FDP is then *exact* and
its correct-bits curve is flat at the 53-bit cap for every N, while the FMA
baselines degrade with N — the paper's headline result. Power numbers come
from the VU3P-calibrated model anchored to the paper's measurements.

Run with JAX_ENABLE_X64=1 (benchmarks/run.py does this).
"""

import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import AccumulatorSpec, fma_dot, dd_dot
from repro.core.fdp import fdp_dot64 as fdp_dot
from repro.core import energy
from repro.core.metrics import correct_bits, exact_dot_fraction
from repro.data.conditioned import gen_dot


def quantize_grid(x, frac_bits=12):
    s = 2.0 ** frac_bits
    return np.asarray(np.rint(x.astype(np.float64) * s) / s, np.float32)


def run(ns=(128, 512, 2048, 8192), cond=1e14, trials=3):
    spec = AccumulatorSpec.paper_91bit()
    rows = []
    for n in ns:
        bits = {"fp64_fma": [], "fp128_fma": [], "fdp91": []}
        dev = {"fp64_fma": [], "fp128_fma": [], "fdp91": []}
        t_fdp = 0.0
        for t in range(trials):
            a, b, _ = gen_dot(n, cond, seed=17 * t + 1)
            a, b = quantize_grid(a), quantize_grid(b)
            exact = float(exact_dot_fraction(a, b))
            if exact == 0.0:
                continue
            a64, b64 = jnp.asarray(a, jnp.float64), jnp.asarray(b, jnp.float64)
            v_fma = float(fma_dot(a64, b64, jnp.float64))
            v_dd = float(dd_dot(a64, b64, jnp.float64))
            t0 = time.perf_counter()
            v_fdp = float(fdp_dot(jnp.asarray(a), jnp.asarray(b), spec))
            t_fdp += time.perf_counter() - t0
            bits["fp64_fma"].append(float(correct_bits(v_fma, exact)))
            bits["fp128_fma"].append(float(correct_bits(v_dd, exact)))
            bits["fdp91"].append(float(correct_bits(v_fdp, exact)))
            # reproducibility: permuted re-run
            perm = np.random.default_rng(t).permutation(n)
            dev["fp64_fma"].append(
                abs(float(fma_dot(a64[perm], b64[perm], jnp.float64)) - v_fma))
            dev["fdp91"].append(
                abs(float(fdp_dot(jnp.asarray(a[perm]), jnp.asarray(b[perm]),
                                  spec)) - v_fdp))
        row = {"n": n}
        for k in bits:
            row[k + "_bits"] = float(np.mean(bits[k])) if bits[k] else None
        row["fp64_repro_dev"] = float(np.max(dev["fp64_fma"])) if dev["fp64_fma"] else 0
        row["fdp_repro_dev"] = float(np.max(dev["fdp91"])) if dev["fdp91"] else 0
        row["fdp_us"] = t_fdp / max(trials, 1) * 1e6
        rows.append(row)

    p64 = energy.fma_power(53).watts
    p128 = energy.fma_power(113).watts
    pfdp = energy.fdp_power(53, 91).watts
    print("name,us_per_call,derived")
    for r in rows:
        print(f"ssh_n{r['n']},{r['fdp_us']:.1f},"
              f"fp64={r['fp64_fma_bits']:.1f}b"
              f"|fp128={r['fp128_fma_bits']:.1f}b"
              f"|fdp91={r['fdp91_bits']:.1f}b"
              f"|fdp_dev={r['fdp_repro_dev']:.1e}"
              f"|fp64_dev={r['fp64_repro_dev']:.1e}")
    # paper's bits-per-watt claims (our analogous ratios)
    last = rows[-1]
    bpw_fdp = last["fdp91_bits"] / pfdp
    bpw_64 = max(last["fp64_fma_bits"], 1e-9) / p64
    bpw_128 = max(last["fp128_fma_bits"], 1e-9) / p128
    print(f"ssh_power,0,P(W):fp64={p64:.3f}|fp128={p128:.3f}|fdp91={pfdp:.3f}"
          f"|bits/W:fdp_vs_fp64={bpw_fdp/bpw_64:.1f}x"
          f"|fdp_vs_fp128={bpw_fdp/bpw_128:.1f}x")
    return rows


if __name__ == "__main__":
    assert jax.config.read("jax_enable_x64"), "run with JAX_ENABLE_X64=1"
    run()
