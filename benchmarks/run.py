"""Benchmark harness — one benchmark per paper table/figure.

    bench_gemm       -> FCCM'22 companion throughput table
    bench_ssh        -> paper Fig. 2 (SSH reproducibility + power)
    bench_ai_energy  -> paper Fig. 3 (accuracy vs energy Pareto)
    bench_roofline   -> EXPERIMENTS.md §Roofline source (from dry-run JSONs)

Each prints ``name,us_per_call,derived`` CSV. Benchmarks run as subprocesses
so each controls its own JAX config (x64 for SSH, single device everywhere).
"""

import os
import subprocess
import sys

BENCHES = [
    ("bench_gemm", {}),
    ("bench_ssh", {"JAX_ENABLE_X64": "1"}),
    ("bench_ai_energy", {}),
    ("bench_roofline", {}),
]


def main() -> None:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    failures = 0
    for mod, env_extra in BENCHES:
        env = dict(os.environ, PYTHONPATH=os.path.join(root, "src"),
                   **env_extra)
        print(f"### {mod}", flush=True)
        r = subprocess.run([sys.executable, "-m", f"benchmarks.{mod}"],
                           env=env, cwd=root)
        if r.returncode != 0:
            failures += 1
            print(f"### {mod} FAILED rc={r.returncode}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
