#!/usr/bin/env python
"""Routed-serving throughput bench: tokens/s per workload class.

Serves a fixed mixed trace (chat / solve / repro, round-robin prompt
lengths) through the full ``repro.serving`` tier — PlanRouter over the
checked-in zoo MANIFEST, bucketed AOT engine pool, routed frontend — and
reports decode throughput per class plus the pool's bucket hit rate. A
warmup pass compiles every (plan, bucket) engine first; the measured pass
reuses the warm pool through a fresh frontend, so the rows measure serving,
not compilation (``trace_count`` is asserted to prove it).

A plain ``jnp.matmul`` anchor row (``impl="native"``) rides along: the
regression gate calibrates cross-machine speed on native rows, same as
``bench_gemm``.

A second, *monitored* measured pass serves the same trace under the live
calibration-envelope monitor (``repro.obs``) on its own warm pool — the
monitor stages its host callbacks at trace time, so the engines must compile
under it. Its rows (``serving_monitored_*``) plus the summary
``serving_monitor_overhead`` row quantify the steady-state monitoring cost;
``scripts/check_obs_snapshot.py --bench`` gates the overhead at <= 5%.

    PYTHONPATH=src python benchmarks/bench_serving.py --quick --json out.json
    python scripts/check_bench_regression.py --baseline BENCH_serving.json \
        --new out.json
"""

import argparse
import json
import platform
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init
from repro.serving import (BucketedEnginePool, PlanRouter, RoutedFrontend,
                           ServeRequest)

CLASSES = ("chat", "solve", "repro")
ANCHOR_SHAPE = (256, 1024, 256)   # several ms/call: above the gate's floor


def build_trace(vocab: int, per_class: int, max_new: int) -> list:
    reqs = []
    for i in range(per_class * len(CLASSES)):
        plen = 3 + (i * 5) % 11
        # deterministic token pattern — the bench must serve the same trace
        # on every machine so rows are comparable across runs
        prompt = [(7 * i + 3 * j + 1) % vocab for j in range(plen)]
        reqs.append(ServeRequest(uid=i, prompt=prompt, max_new=max_new,
                                 workload=CLASSES[i % len(CLASSES)]))
    return reqs


def bench_anchor(reps: int = 5) -> dict:
    m, k, n = ANCHOR_SHAPE
    a = jnp.ones((m, k), jnp.float32)
    b = jnp.ones((k, n), jnp.float32)
    f = jax.jit(jnp.matmul)
    f(a, b).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        f(a, b).block_until_ready()
    sec = (time.perf_counter() - t0) / reps
    return {"name": f"serving_native_matmul_anchor_{m}x{k}x{n}",
            "impl": "native", "seconds_per_call": sec,
            "tokens_per_s": 1.0 / sec,
            "derived": "per-call rate of a plain XLA matmul (machine anchor)"}


def bench_monitor_overhead(reps: int = 20) -> tuple:
    """Per-GEMM monitoring cost at the anchor shape: a warm jitted
    ``dispatch.gemm`` with and without a live envelope monitor installed.
    The monitor's staged reductions are O(mk+kn+mn) against the GEMM's
    O(mnk), so this is the scale-representative overhead the <=5% budget
    applies to (the toy serving trace above is XLA-dispatch-bound and
    reported separately)."""
    from repro.core import dispatch
    from repro.obs import Registry
    from repro.obs.monitor import NumericsMonitor

    m, k, n = ANCHOR_SHAPE
    a = 0.5 * jnp.ones((m, k), jnp.float32)
    b = 0.5 * jnp.ones((k, n), jnp.float32)

    def timed(fn):
        fn(a, b).block_until_ready()               # compile + warm
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn(a, b)
        out.block_until_ready()
        jax.effects_barrier()                      # count landed callbacks
        return (time.perf_counter() - t0) / reps

    probe = lambda x, y: dispatch.gemm(x, y, site="bench_probe",
                                       policy=dispatch.MXU_FP32)
    base = timed(jax.jit(probe))
    env = {"version": 1, "sites": {"bench_probe": {
        "a_exp": [-1, 0], "b_exp": [-1, 0], "out_exp": [None, 8],
        "msb": 127, "lsb": None, "calls": 1, "max_k": k}}}
    mon = NumericsMonitor(env, registry=Registry())
    with mon:
        monitored = timed(jax.jit(probe))          # fresh trace, hooked
    return base, monitored, mon


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-mlp")
    ap.add_argument("--full", action="store_true",
                    help="full (non-reduced) config")
    ap.add_argument("--quick", action="store_true",
                    help="smaller trace for bounded CI lanes")
    ap.add_argument("--plans", default="examples/plans")
    ap.add_argument("--buckets", default="2x32,4x64")
    ap.add_argument("--per-class", type=int, default=None)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    per_class = args.per_class or (2 if args.quick else 4)
    cfg = get_config(args.arch)
    router = PlanRouter.from_manifest(args.plans, arch=cfg.name)
    if not args.full:
        cfg = cfg.reduced()
    params = init(cfg, jax.random.key(0))
    pool = BucketedEnginePool(cfg, params, args.buckets, max_live=8)

    # warmup pass: compile every (plan, bucket) engine the trace will touch
    warm = RoutedFrontend(pool, router, max_live_batches=4)
    for r in build_trace(cfg.vocab_size, 1, 2):
        warm.submit(r)
    warm.run()

    # measured pass: fresh frontend, warm pool — serving cost only
    front = RoutedFrontend(pool, router, max_live_batches=4)
    trace = build_trace(cfg.vocab_size, per_class, args.max_new)
    comps = [front.submit(r) for r in trace]
    front.run()
    bad = [c for c in comps if not c.ok]
    if bad:
        raise SystemExit(f"{len(bad)} request(s) failed: {bad[0].error}")
    retraced = [k for k, e in pool.live().items() if e.trace_count != 1]
    if retraced:
        raise SystemExit(f"engines retraced after warmup: {retraced}")

    stats = front.stats()

    # monitored pass: same trace under the live envelope monitor, on its own
    # pool — monitor callbacks are staged at trace time, so reusing the warm
    # unmonitored engines would measure (and record) nothing
    from repro.numerics import load_plan
    from repro.obs import Registry, monitoring
    base = next((p for p in router.plans if p.derived is None and p.path),
                None)
    plan_doc = load_plan(base.path) if base is not None else None
    with monitoring(plan_doc, registry=Registry()) as mon:
        mpool = BucketedEnginePool(cfg, params, args.buckets, max_live=8)
        mwarm = RoutedFrontend(mpool, router, max_live_batches=4)
        for r in build_trace(cfg.vocab_size, 1, 2):
            mwarm.submit(r)
        mwarm.run()
        mfront = RoutedFrontend(mpool, router, max_live_batches=4)
        mcomps = [mfront.submit(r)
                  for r in build_trace(cfg.vocab_size, per_class,
                                       args.max_new)]
        mfront.run()
    mbad = [c for c in mcomps if not c.ok]
    if mbad:
        raise SystemExit(f"{len(mbad)} monitored request(s) failed: "
                         f"{mbad[0].error}")
    mretraced = [k for k, e in mpool.live().items() if e.trace_count != 1]
    if mretraced:
        raise SystemExit(f"monitored engines retraced: {mretraced}")
    mstats = mfront.stats()

    def _total_tps(st):
        toks = sum(c["decode_tokens"] for c in st["classes"].values())
        return toks / st["wall_seconds"] if st["wall_seconds"] else 0.0

    base_tps, mon_tps = _total_tps(stats), _total_tps(mstats)
    serving_overhead = (max(0.0, 1.0 - mon_tps / base_tps)
                        if base_tps else 0.0)
    anchor_base, anchor_mon, probe_mon = bench_monitor_overhead()
    overhead = max(0.0, anchor_mon / anchor_base - 1.0)

    rows = []
    for wl, st in stats["classes"].items():
        rows.append({
            "name": f"serving_routed_{wl}", "impl": "routed",
            "workload": wl,
            "plans": sorted(st["plans"]),
            "seconds_per_call": (stats["wall_seconds"] / st["decode_tokens"]
                                 if st["decode_tokens"] else None),
            "tokens_per_s": st["tokens_per_s"],
            "decode_tokens": st["decode_tokens"],
            "derived": f"{st['completed']} reqs via "
                       + ",".join(sorted(st["plans"])),
        })
    for wl, st in mstats["classes"].items():
        rows.append({
            # informational (no tokens_per_s: the toy-scale monitored number
            # is dispatch-bound and too noisy for the 25% regression gate;
            # the overhead row below carries the gated anchor-scale cost)
            "name": f"serving_monitored_{wl}", "impl": "monitored",
            "workload": wl,
            "monitored_tokens_per_s": st["tokens_per_s"],
            "decode_tokens": st["decode_tokens"],
            "derived": f"{st['completed']} reqs under the envelope monitor",
        })
    rows.append({   # summary row: scripts/check_obs_snapshot.py --bench
        "name": "serving_monitor_overhead", "impl": "monitored",
        "overhead_frac": overhead,
        "baseline_seconds_per_call": anchor_base,
        "monitored_seconds_per_call": anchor_mon,
        "anchor_shape": "x".join(map(str, ANCHOR_SHAPE)),
        "probe_status": probe_mon.worst_status(),
        "serving_overhead_frac": serving_overhead,
        "baseline_tokens_per_s": base_tps,
        "monitored_tokens_per_s": mon_tps,
        "worst_status": mon.worst_status(),
        "overflow_events": (mon.overflow_events()
                            + probe_mon.overflow_events()),
        "monitored_sites": len(mon.statuses()),
        "derived": f"monitoring costs {overhead:.1%} per anchor-shape GEMM "
                   f"({serving_overhead:.0%} on the dispatch-bound toy "
                   f"serving trace)",
    })
    pool_st = stats["pool"]
    rows.append({   # informational: no throughput metric, the gate skips it
        "name": "serving_bucket_hit_rate", "impl": "routed",
        "bucket_hit_rate": pool_st["bucket_hit_rate"],
        "bucket_hits": pool_st["bucket_hits"],
        "compiles": pool_st["compiles"], "evictions": pool_st["evictions"],
    })
    rows.append(bench_anchor())

    print(f"[bench_serving] {cfg.name}: {len(trace)} reqs, "
          f"buckets={args.buckets}, wall={stats['wall_seconds']:.2f}s")
    for r in rows:
        tps = r.get("tokens_per_s")
        tps = f"{tps:10.2f} tok/s" if tps is not None else " " * 16
        print(f"  {r['name']:32s} {tps}  {r.get('derived', '')}")
    print(f"  bucket hit rate: {pool_st['bucket_hit_rate']:.2f} "
          f"({pool_st['bucket_hits']})")

    if args.json:
        doc = {"bench": "bench_serving", "metric": "tokens_per_s",
               "quick": bool(args.quick), "arch": cfg.name,
               "backend": jax.default_backend(),
               "platform": platform.platform(),
               "wall_seconds": stats["wall_seconds"], "rows": rows}
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        print(f"[bench_serving] wrote {args.json}")


if __name__ == "__main__":
    main()
