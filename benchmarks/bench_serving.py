#!/usr/bin/env python
"""Routed-serving throughput bench: tokens/s per workload class.

Serves a fixed mixed trace (chat / solve / repro, round-robin prompt
lengths) through the full ``repro.serving`` tier — PlanRouter over the
checked-in zoo MANIFEST, bucketed AOT engine pool, routed frontend — and
reports decode throughput per class plus the pool's bucket hit rate. A
warmup pass compiles every (plan, bucket) engine first; the measured pass
reuses the warm pool through a fresh frontend, so the rows measure serving,
not compilation (``trace_count`` is asserted to prove it).

A plain ``jnp.matmul`` anchor row (``impl="native"``) rides along: the
regression gate calibrates cross-machine speed on native rows, same as
``bench_gemm``.

    PYTHONPATH=src python benchmarks/bench_serving.py --quick --json out.json
    python scripts/check_bench_regression.py --baseline BENCH_serving.json \
        --new out.json
"""

import argparse
import json
import platform
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init
from repro.serving import (BucketedEnginePool, PlanRouter, RoutedFrontend,
                           ServeRequest)

CLASSES = ("chat", "solve", "repro")
ANCHOR_SHAPE = (256, 1024, 256)   # several ms/call: above the gate's floor


def build_trace(vocab: int, per_class: int, max_new: int) -> list:
    reqs = []
    for i in range(per_class * len(CLASSES)):
        plen = 3 + (i * 5) % 11
        # deterministic token pattern — the bench must serve the same trace
        # on every machine so rows are comparable across runs
        prompt = [(7 * i + 3 * j + 1) % vocab for j in range(plen)]
        reqs.append(ServeRequest(uid=i, prompt=prompt, max_new=max_new,
                                 workload=CLASSES[i % len(CLASSES)]))
    return reqs


def bench_anchor(reps: int = 5) -> dict:
    m, k, n = ANCHOR_SHAPE
    a = jnp.ones((m, k), jnp.float32)
    b = jnp.ones((k, n), jnp.float32)
    f = jax.jit(jnp.matmul)
    f(a, b).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        f(a, b).block_until_ready()
    sec = (time.perf_counter() - t0) / reps
    return {"name": f"serving_native_matmul_anchor_{m}x{k}x{n}",
            "impl": "native", "seconds_per_call": sec,
            "tokens_per_s": 1.0 / sec,
            "derived": "per-call rate of a plain XLA matmul (machine anchor)"}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-mlp")
    ap.add_argument("--full", action="store_true",
                    help="full (non-reduced) config")
    ap.add_argument("--quick", action="store_true",
                    help="smaller trace for bounded CI lanes")
    ap.add_argument("--plans", default="examples/plans")
    ap.add_argument("--buckets", default="2x32,4x64")
    ap.add_argument("--per-class", type=int, default=None)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    per_class = args.per_class or (2 if args.quick else 4)
    cfg = get_config(args.arch)
    router = PlanRouter.from_manifest(args.plans, arch=cfg.name)
    if not args.full:
        cfg = cfg.reduced()
    params = init(cfg, jax.random.key(0))
    pool = BucketedEnginePool(cfg, params, args.buckets, max_live=8)

    # warmup pass: compile every (plan, bucket) engine the trace will touch
    warm = RoutedFrontend(pool, router, max_live_batches=4)
    for r in build_trace(cfg.vocab_size, 1, 2):
        warm.submit(r)
    warm.run()

    # measured pass: fresh frontend, warm pool — serving cost only
    front = RoutedFrontend(pool, router, max_live_batches=4)
    trace = build_trace(cfg.vocab_size, per_class, args.max_new)
    comps = [front.submit(r) for r in trace]
    front.run()
    bad = [c for c in comps if not c.ok]
    if bad:
        raise SystemExit(f"{len(bad)} request(s) failed: {bad[0].error}")
    retraced = [k for k, e in pool.live().items() if e.trace_count != 1]
    if retraced:
        raise SystemExit(f"engines retraced after warmup: {retraced}")

    stats = front.stats()
    rows = []
    for wl, st in stats["classes"].items():
        rows.append({
            "name": f"serving_routed_{wl}", "impl": "routed",
            "workload": wl,
            "plans": sorted(st["plans"]),
            "seconds_per_call": (stats["wall_seconds"] / st["decode_tokens"]
                                 if st["decode_tokens"] else None),
            "tokens_per_s": st["tokens_per_s"],
            "decode_tokens": st["decode_tokens"],
            "derived": f"{st['completed']} reqs via "
                       + ",".join(sorted(st["plans"])),
        })
    pool_st = stats["pool"]
    rows.append({   # informational: no throughput metric, the gate skips it
        "name": "serving_bucket_hit_rate", "impl": "routed",
        "bucket_hit_rate": pool_st["bucket_hit_rate"],
        "bucket_hits": pool_st["bucket_hits"],
        "compiles": pool_st["compiles"], "evictions": pool_st["evictions"],
    })
    rows.append(bench_anchor())

    print(f"[bench_serving] {cfg.name}: {len(trace)} reqs, "
          f"buckets={args.buckets}, wall={stats['wall_seconds']:.2f}s")
    for r in rows:
        tps = r.get("tokens_per_s")
        tps = f"{tps:10.2f} tok/s" if tps is not None else " " * 16
        print(f"  {r['name']:32s} {tps}  {r.get('derived', '')}")
    print(f"  bucket hit rate: {pool_st['bucket_hit_rate']:.2f} "
          f"({pool_st['bucket_hits']})")

    if args.json:
        doc = {"bench": "bench_serving", "metric": "tokens_per_s",
               "quick": bool(args.quick), "arch": cfg.name,
               "backend": jax.default_backend(),
               "platform": platform.platform(),
               "wall_seconds": stats["wall_seconds"], "rows": rows}
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        print(f"[bench_serving] wrote {args.json}")


if __name__ == "__main__":
    main()
