"""Pallas FDP GEMM kernel vs the pure-jnp oracle: bit-exact across a sweep of
shapes, block sizes, dtypes, formats and accumulator specs (interpret mode)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import AccumulatorSpec, BF16, FP32, GemmPlan, POSIT16_1
from repro.kernels.ops import fdp_gemm as pallas_gemm
from repro.kernels.ref import fdp_gemm_ref

SPECS = [
    AccumulatorSpec.paper_91bit(),
    AccumulatorSpec(ovf=9, msb=6, lsb=-20),
    AccumulatorSpec(ovf=6, msb=10, lsb=-30, round_mode="rne"),
    AccumulatorSpec(ovf=3, msb=5, lsb=-8, overflow_mode="saturate"),
]

SHAPES = [
    (8, 8, 8), (16, 64, 16), (17, 70, 9), (1, 128, 1), (33, 257, 5),
]


@pytest.mark.slow
@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.describe())
@pytest.mark.parametrize("shape", SHAPES, ids=str)
def test_kernel_bitexact_f32(spec, shape, rng):
    M, K, N = shape
    A = (rng.standard_normal((M, K)) * 3).astype(np.float32)
    B = (rng.standard_normal((K, N)) * 3).astype(np.float32)
    got = np.asarray(pallas_gemm(jnp.asarray(A), jnp.asarray(B), spec=spec,
                                 plan=GemmPlan(8, 8, 32)))
    ref = np.asarray(fdp_gemm_ref(jnp.asarray(A), jnp.asarray(B), spec=spec))
    np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("blocks", [(8, 8, 16), (16, 16, 64), (32, 8, 128)])
def test_kernel_block_size_invariance(blocks, rng):
    spec = AccumulatorSpec.paper_91bit()
    M, K, N = 24, 200, 24
    A = rng.standard_normal((M, K)).astype(np.float32)
    B = rng.standard_normal((K, N)).astype(np.float32)
    bm, bn, bk = blocks
    got = np.asarray(pallas_gemm(jnp.asarray(A), jnp.asarray(B), spec=spec,
                                 plan=GemmPlan(bm, bn, bk)))
    ref = np.asarray(fdp_gemm_ref(jnp.asarray(A), jnp.asarray(B), spec=spec))
    np.testing.assert_array_equal(got, ref)


def test_kernel_bf16_inputs(rng):
    spec = AccumulatorSpec(ovf=9, msb=6, lsb=-20)
    A = jnp.asarray(rng.standard_normal((16, 48)), jnp.bfloat16)
    B = jnp.asarray(rng.standard_normal((48, 8)), jnp.bfloat16)
    got = np.asarray(pallas_gemm(A, B, spec=spec, fmt=BF16, plan=GemmPlan(8, 8, 16)))
    ref = np.asarray(fdp_gemm_ref(A, B, spec=spec, fmt=BF16))
    np.testing.assert_array_equal(got, ref)


def test_kernel_posit_inputs(rng):
    """Posit16 bit patterns flow through the same kernel."""
    spec = AccumulatorSpec.paper_91bit()
    av = rng.standard_normal((8, 24)).astype(np.float32)
    bv = rng.standard_normal((24, 8)).astype(np.float32)
    ap = POSIT16_1.from_float(jnp.asarray(av))
    bp = POSIT16_1.from_float(jnp.asarray(bv))
    got = np.asarray(pallas_gemm(ap, bp, spec=spec, fmt=POSIT16_1,
                                 plan=GemmPlan(8, 8, 8)))
    ref = np.asarray(fdp_gemm_ref(ap, bp, spec=spec, fmt=POSIT16_1))
    np.testing.assert_array_equal(got, ref)
    # and the values are close to the f32 product of the posit-rounded inputs
    a_back = np.asarray(POSIT16_1.to_float(ap))
    b_back = np.asarray(POSIT16_1.to_float(bp))
    np.testing.assert_allclose(got, a_back @ b_back, rtol=1e-2, atol=1e-3)


def test_kernel_zero_and_padding(rng):
    spec = AccumulatorSpec.paper_91bit()
    A = np.zeros((5, 7), np.float32)
    B = rng.standard_normal((7, 3)).astype(np.float32)
    got = np.asarray(pallas_gemm(jnp.asarray(A), jnp.asarray(B), spec=spec))
    np.testing.assert_array_equal(got, np.zeros((5, 3), np.float32))


def test_kernel_exactness_vs_f64(rng):
    """91-bit FDP == correctly-rounded f64 GEMM for in-range data."""
    spec = AccumulatorSpec.paper_91bit()
    A = rng.standard_normal((16, 512)).astype(np.float32)
    B = rng.standard_normal((512, 16)).astype(np.float32)
    got = np.asarray(pallas_gemm(jnp.asarray(A), jnp.asarray(B), spec=spec,
                                 plan=GemmPlan(8, 8, 256)))
    ref64 = A.astype(np.float64) @ B.astype(np.float64)
    # per-product RTZ at 2^-30 bounds |err| by K * 2^-30 absolutely; small
    # outputs (random cancellation) need that floor on top of rtol.
    np.testing.assert_allclose(got, ref64, rtol=2e-7, atol=512 * 2.0 ** -30)
