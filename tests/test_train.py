"""Training substrate: loss decreases, grad-accum equivalence, fixed-point
(order-invariant) accumulation, optimizer, schedules."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.accumulator import AccumulatorSpec
from repro.data.synthetic import SyntheticLM
from repro.models import LOCAL, init
from repro.train.loop import make_loss_fn, make_train_step
from repro.train.optimizer import (adamw, apply_updates, clip_by_global_norm,
                                   cosine_schedule, global_norm)


def _cfg():
    return get_config("paper-mlp").reduced(
        d_model=64, d_ff=128, n_layers=2, vocab_size=64, n_heads=4,
        n_kv_heads=4, head_dim=16)


def _data(cfg, steps, batch=8, seq=24):
    ds = SyntheticLM(cfg.vocab_size, seq, batch, seed=0)
    out = []
    for s in range(steps):
        tb = ds.batch(s)
        out.append({"tokens": tb.tokens, "targets": tb.targets,
                    "loss_mask": tb.loss_mask})
    return out


def test_loss_decreases():
    cfg = _cfg()
    opt = adamw(lr=3e-3)
    step = make_train_step(cfg, opt, LOCAL, remat="none", donate=False)
    params = init(cfg, jax.random.key(0))
    state = (params, opt.init(params))
    losses = []
    for batch in _data(cfg, 30):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses[::10]


def test_grad_accum_equivalence():
    """Microbatched accumulated grads == full-batch grads. Compared via an
    identity 'optimizer' (updates == grads): comparing post-Adam params is
    ill-conditioned (step-1 Adam is a sign update, so epsilon-level grad
    noise flips entries by 2*lr)."""
    from repro.train.optimizer import Optimizer
    cfg = _cfg()
    params = init(cfg, jax.random.key(0))
    batch = _data(cfg, 1, batch=8)[0]
    ident = Optimizer(
        init=lambda p: {"grad_norm": jnp.zeros(())},
        update=lambda g, s, p: (g, s))
    s1 = make_train_step(cfg, ident, LOCAL, remat="none", microbatches=1,
                         donate=False)
    s4 = make_train_step(cfg, ident, LOCAL, remat="none", microbatches=4,
                         donate=False)
    st1, m1 = s1((params, ident.init(params)), batch)
    st4, m4 = s4((params, ident.init(params)), batch)
    g1 = jax.tree.map(lambda a, b: a - b, st1[0], params)
    g4 = jax.tree.map(lambda a, b: a - b, st4[0], params)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), g1, g4)
    scale = max(float(jnp.max(jnp.abs(x))) for x in jax.tree.leaves(g1))
    assert max(jax.tree.leaves(d)) < 5e-3 * scale
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-5)


def test_fdp_grad_accum_order_invariant():
    """Fixed-point grad accumulation: permuting the microbatch order gives
    BITWISE identical parameters (the paper's reproducibility property
    applied to training); float accumulation typically does not."""
    cfg = _cfg()
    spec = AccumulatorSpec(ovf=10, msb=10, lsb=-18)
    opt = adamw(lr=1e-3)
    step = make_train_step(cfg, opt, LOCAL, remat="none", microbatches=4,
                           fdp_grad_spec=spec, donate=False)
    params = init(cfg, jax.random.key(0))
    batch = _data(cfg, 1, batch=8)[0]

    def permuted(batch, perm):
        # permute microbatch blocks (mb size 2)
        def p(x):
            xs = x.reshape(4, 2, *x.shape[1:])[perm]
            return xs.reshape(x.shape)
        return jax.tree.map(p, batch)

    st_a, _ = step((params, opt.init(params)), batch)
    st_b, _ = step((params, opt.init(params)),
                   permuted(batch, jnp.array([3, 1, 0, 2])))
    same = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)),
                        st_a[0], st_b[0])
    assert all(jax.tree.leaves(same))


def test_clip_and_norm():
    tree = {"a": jnp.full((10,), 3.0), "b": jnp.full((5,), -4.0)}
    n = float(global_norm(tree))
    assert n == pytest.approx(np.sqrt(10 * 9 + 5 * 16))
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule():
    lr = cosine_schedule(1.0, warmup=10, total=110, floor=0.1)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0)
    assert float(lr(110)) == pytest.approx(0.1, rel=1e-5)
    assert float(lr(60)) == pytest.approx(0.55, rel=1e-2)


def test_adamw_step_shapes():
    opt = adamw(lr=1e-2, weight_decay=0.1)
    params = {"w": jnp.ones((3, 3)), "b": jnp.zeros((3,))}
    state = opt.init(params)
    grads = jax.tree.map(jnp.ones_like, params)
    updates, state = opt.update(grads, state, params)
    new = apply_updates(params, updates)
    assert new["w"].shape == (3, 3)
    assert int(state["step"]) == 1
    # decoupled decay: zero grad still decays weights
    updates2, _ = opt.update(jax.tree.map(jnp.zeros_like, params), state,
                             params)
    assert float(jnp.abs(updates2["w"]).sum()) > 0
