"""Format front-end: exact decode and posit round-trips."""

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import BF16, FP16, FP32, POSIT8_0, POSIT16_1, POSIT32_2


@pytest.mark.parametrize("val", [0.0, 1.0, -1.0, 0.5, 3.14159, -2.75e10,
                                 1.1754944e-38, 1e-40, 65504.0])
def test_fp32_decode_exact(val):
    d = FP32.decode(jnp.float32(val))
    back = float(d.mant) * 2.0 ** int(d.exp) * (-1) ** int(d.sign)
    assert back == np.float32(val)


def test_fp32_decode_specials():
    d = FP32.decode(jnp.array([np.inf, -np.inf, np.nan], jnp.float32))
    assert bool(d.is_inf[0]) and bool(d.is_inf[1]) and bool(d.is_nan[2])
    assert int(d.mant[0]) == 0


@settings(max_examples=200, deadline=None)
@given(st.floats(width=32, allow_nan=False, allow_infinity=False))
def test_fp32_decode_roundtrip_hypothesis(v):
    d = FP32.decode(jnp.float32(v))
    back = np.float64(int(d.mant)) * 2.0 ** int(d.exp) * (-1.0) ** int(d.sign)
    assert np.float32(back) == np.float32(v)


def test_bf16_decode_exact(rng):
    x = jnp.asarray(rng.standard_normal(64), jnp.bfloat16)
    d = BF16.decode(x)
    back = np.asarray(d.mant, np.float64) * 2.0 ** np.asarray(d.exp) \
        * (-1.0) ** np.asarray(d.sign)
    np.testing.assert_array_equal(back.astype(np.float32),
                                  np.asarray(x, np.float32))


@pytest.mark.parametrize("fmt", [POSIT8_0, POSIT16_1, POSIT32_2],
                         ids=lambda f: f.name)
def test_posit_roundtrip_through_float(fmt, rng):
    """to_float(from_float(x)) is idempotent: re-encoding gives same pattern."""
    x = jnp.asarray(rng.standard_normal(256) * 10 ** rng.uniform(-3, 3, 256),
                    jnp.float32)
    p = fmt.from_float(x)
    f = fmt.to_float(p)
    p2 = fmt.from_float(f)
    np.testing.assert_array_equal(np.asarray(p), np.asarray(p2))


@pytest.mark.parametrize("fmt,tol", [(POSIT8_0, 0.07), (POSIT16_1, 2e-3),
                                     (POSIT32_2, 2e-6)], ids=lambda x: str(x))
def test_posit_encode_accuracy(fmt, tol, rng):
    # sample within the format's high-precision band (posits taper off);
    # saturation to ±minpos outside the band is by-design and tested below.
    x = jnp.asarray(np.sign(rng.standard_normal(512))
                    * 10 ** rng.uniform(-0.5, 0.5, 512), jnp.float32)
    f = fmt.to_float(fmt.from_float(x))
    rel = np.abs((np.asarray(f) - np.asarray(x)) / np.asarray(x))
    assert np.max(rel) < tol


def test_posit_saturates_no_underflow():
    # below minpos encodes to minpos (posit spec: no underflow to zero)
    tiny = jnp.float32(1e-6)
    p = POSIT8_0.from_float(tiny)
    assert float(POSIT8_0.to_float(p)) == 2.0 ** -6   # posit8 es=0 minpos
    huge = jnp.float32(1e9)
    p = POSIT8_0.from_float(huge)
    assert float(POSIT8_0.to_float(p)) == 2.0 ** 6    # maxpos


def test_posit16_known_patterns():
    # posit16 es=1: 0x4000 -> 1.0 ; 0x5000 -> 2.0 ; 0x3000 -> 0.5
    f = POSIT16_1.to_float(jnp.array([0x4000, 0x5000, 0x3000], jnp.int32))
    np.testing.assert_array_equal(np.asarray(f), [1.0, 2.0, 0.5])
    # negative: two's complement of 1.0 -> -1.0
    f = POSIT16_1.to_float(jnp.array([(-0x4000) & 0xFFFF], jnp.int32))
    np.testing.assert_array_equal(np.asarray(f), [-1.0])


def test_posit_nar_and_zero():
    f = POSIT16_1.to_float(jnp.array([0, 1 << 15], jnp.int32))
    assert float(f[0]) == 0.0
    assert np.isnan(float(f[1]))


@settings(max_examples=150, deadline=None)
@given(st.floats(-1e4, 1e4, width=32, allow_nan=False), )
def test_posit16_nearest_hypothesis(v):
    """from_float encodes to a pattern whose value is the nearest posit:
    check |encoded - v| <= |neighbor - v| for both bit-neighbors."""
    if v == 0:
        return
    p = int(POSIT16_1.from_float(jnp.float32(v)))
    f0 = float(POSIT16_1.to_float(jnp.array([p], jnp.int32))[0])
    for q in ((p + 1) & 0xFFFF, (p - 1) & 0xFFFF):
        if q in (0, 1 << 15):
            continue
        fq = float(POSIT16_1.to_float(jnp.array([q], jnp.int32))[0])
        if np.isnan(fq):
            continue
        assert abs(f0 - v) <= abs(fq - v) * (1 + 1e-6)
