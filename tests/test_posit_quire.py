"""Posit quire: posit-in/posit-out exact dot product (the posit-native
instance of the paper's accumulator family)."""

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import AccumulatorSpec, POSIT8_0, POSIT16_1
from repro.core.fdp import fdp_dot_posit


def test_quire_sizing():
    q16 = AccumulatorSpec.quire(POSIT16_1, max_terms=1024)
    # posit16 es=1: max_scale 28 -> msb 58, lsb -80: covers maxpos^2..minpos^2
    assert q16.msb >= 2 * 28 and q16.lsb <= -2 * 28 - 13
    assert q16.width >= 128


def test_posit_dot_exact_small_ints(rng):
    """Integer-valued posits: the quire dot must be exactly the integer dot
    rounded to posit16 (which is exact for these magnitudes)."""
    a = rng.integers(-7, 8, 24).astype(np.float32)
    b = rng.integers(-7, 8, 24).astype(np.float32)
    pa = POSIT16_1.from_float(jnp.asarray(a))
    pb = POSIT16_1.from_float(jnp.asarray(b))
    out = fdp_dot_posit(pa, pb)
    got = float(POSIT16_1.to_float(out))
    assert got == float(np.dot(a, b))


def test_posit_dot_beats_sequential(rng):
    """Quire accumulation is at least as accurate as sequential posit
    rounding (round after every add)."""
    a = (rng.standard_normal(64) * 0.5).astype(np.float32)
    b = (rng.standard_normal(64) * 0.5).astype(np.float32)
    pa = POSIT16_1.from_float(jnp.asarray(a))
    pb = POSIT16_1.from_float(jnp.asarray(b))
    av = np.asarray(POSIT16_1.to_float(pa), np.float64)
    bv = np.asarray(POSIT16_1.to_float(pb), np.float64)
    exact = float(av @ bv)
    quire = float(POSIT16_1.to_float(fdp_dot_posit(pa, pb)))
    # sequential: round every partial sum to posit16
    s = 0.0
    for x, y in zip(av, bv):
        s = float(POSIT16_1.to_float(POSIT16_1.from_float(jnp.float32(s + x * y))))
    assert abs(quire - exact) <= abs(s - exact) + 1e-12


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(4, 32))
def test_posit_quire_permutation_invariant(seed, k):
    r = np.random.default_rng(seed)
    a = r.standard_normal(k).astype(np.float32)
    b = r.standard_normal(k).astype(np.float32)
    pa = POSIT16_1.from_float(jnp.asarray(a))
    pb = POSIT16_1.from_float(jnp.asarray(b))
    v0 = int(fdp_dot_posit(pa, pb))
    perm = r.permutation(k)
    v1 = int(fdp_dot_posit(pa[perm], pb[perm]))
    assert v0 == v1


def test_posit8_quire(rng):
    a = (rng.standard_normal(16)).astype(np.float32)
    b = (rng.standard_normal(16)).astype(np.float32)
    pa = POSIT8_0.from_float(jnp.asarray(a))
    pb = POSIT8_0.from_float(jnp.asarray(b))
    out = fdp_dot_posit(pa, pb, fmt=POSIT8_0)
    av = np.asarray(POSIT8_0.to_float(pa), np.float64)
    bv = np.asarray(POSIT8_0.to_float(pb), np.float64)
    exact = av @ bv
    got = float(POSIT8_0.to_float(out))
    # exact accumulate, single posit8 rounding: within 1 posit8 ulp (~6%)
    assert got == pytest.approx(exact, rel=0.07, abs=0.02)
