import numpy as np
import pytest
from fractions import Fraction

import jax


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def clean_sites():
    """Reset the process-global dispatch site registry around a test, so
    ``sites_seen()`` assertions never depend on which tests dispatched GEMMs
    earlier in the session (the registry is process-wide by design)."""
    from repro.core import dispatch
    dispatch.reset_sites_seen()
    yield dispatch.sites_seen
    dispatch.reset_sites_seen()


def frac_to_f32_rne(f: Fraction) -> np.float32:
    """Correct single RNE from Fraction to float32 (test oracle helper)."""
    if f == 0:
        return np.float32(0.0)
    s = -1 if f < 0 else 1
    f = abs(f)
    e = f.numerator.bit_length() - f.denominator.bit_length() - 23
    while f / Fraction(2) ** e >= 2 ** 24:
        e += 1
    while f / Fraction(2) ** e < 2 ** 23:
        e -= 1
    m = f / Fraction(2) ** e
    mi = int(m)
    rem = m - mi
    if rem > Fraction(1, 2) or (rem == Fraction(1, 2) and mi % 2 == 1):
        mi += 1
    return np.float32(s * np.ldexp(np.float64(mi), e))


def fdp_oracle(a, b, spec) -> np.float32:
    """Host-side normative semantics: per-product trunc at 2^lsb, exact sum,
    W-bit wrap, single RNE to f32."""
    exact = Fraction(0)
    scale = Fraction(2) ** spec.lsb
    for x, y in zip(np.asarray(a, np.float64).tolist(),
                    np.asarray(b, np.float64).tolist()):
        p = Fraction(x) * Fraction(y)
        exact += int(abs(p) / scale) * (1 if p >= 0 else -1)
    W = spec.width
    wrapped = ((int(exact) + 2 ** (W - 1)) % 2 ** W) - 2 ** (W - 1)
    return frac_to_f32_rne(Fraction(wrapped) * scale)
