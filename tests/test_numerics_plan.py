"""PrecisionPlan serialization: JSON round-trip, versioning, and the
checked-in paper-MLP fixture."""

import json
import os

import pytest

from repro.core import AccumulatorSpec, BF16, FP32
from repro.core.dispatch import GemmConfig
from repro.numerics import PLAN_VERSION, PrecisionPlan, SitePlan, load_plan

FIXTURE = os.path.join(os.path.dirname(__file__), os.pardir,
                       "examples", "plans", "paper_mlp.json")


def _plan():
    return PrecisionPlan(
        name="unit",
        sites=(
            SitePlan("attn_qk",
                     GemmConfig(FP32, AccumulatorSpec(5, 8, -40), "simulate"),
                     error_bits=24.0, energy_j=1e-4, macs=1 << 20),
            SitePlan("mlp_in", GemmConfig(BF16, None, "native"),
                     error_bits=8.5, energy_j=2e-5, macs=1 << 21),
        ),
        default=GemmConfig(BF16, None, "native"),
        budget_bits=8.0,
        meta={"modeled_energy_j": 1.2e-4, "baseline_energy_j": 4e-4},
    )


def test_round_trip_preserves_everything():
    p = _plan()
    q = PrecisionPlan.from_json(json.loads(json.dumps(p.to_json())))
    assert q.name == p.name and q.version == PLAN_VERSION
    assert q.budget_bits == p.budget_bits
    assert q.meta == p.meta
    assert len(q.sites) == 2
    for a, b in zip(p.sites, q.sites):
        assert a.site == b.site
        assert a.cfg == b.cfg                 # fmt, spec, mode all exact
        assert a.error_bits == b.error_bits
        assert a.macs == b.macs
    assert q.default == p.default


def test_to_policy_overrides():
    pol = _plan().to_policy()
    assert pol.lookup("attn_qk").mode == "simulate"
    assert pol.lookup("attn_qk").acc == AccumulatorSpec(5, 8, -40)
    assert pol.lookup("mlp_in").fmt is BF16
    assert pol.lookup("unlisted_site") == GemmConfig(BF16, None, "native")
    assert pol.name == "plan:unit"


def test_save_load_file(tmp_path):
    path = tmp_path / "plan.json"
    p = _plan()
    p.save(path)
    q = load_plan(path)
    assert q.sites == p.sites
    assert q.to_policy().lookup("attn_qk") == p.sites[0].cfg


def test_newer_version_rejected():
    d = _plan().to_json()
    d["version"] = PLAN_VERSION + 1
    with pytest.raises(ValueError, match="newer"):
        PrecisionPlan.from_json(d)


def test_malformed_document_rejected():
    with pytest.raises(ValueError, match="PrecisionPlan"):
        PrecisionPlan.from_json({"version": 1, "something": "else"})


def test_checked_in_fixture_loads_and_pays_for_itself():
    """The committed paper-MLP plan: valid schema, covers the model's GEMM
    sites, and its modeled energy undercuts the uniform 91-bit baseline."""
    plan = load_plan(FIXTURE)
    assert plan.version == PLAN_VERSION
    assert plan.budget_bits is not None
    sites = {s.site for s in plan.sites}
    assert {"attn_qk", "attn_av", "mlp_in", "mlp_out", "lm_head"} <= sites
    pol = plan.to_policy()
    for s in plan.sites:
        assert pol.lookup(s.site) == s.cfg
        assert s.error_bits is None or s.error_bits >= plan.budget_bits
    m = plan.meta
    assert m["modeled_energy_j"] <= m["baseline_energy_j"]
    assert m.get("validated_bits", plan.budget_bits) >= plan.budget_bits
