"""PrecisionPlan serialization: JSON round-trip, versioning, and the
checked-in paper-MLP fixture."""

import json
import os

import pytest

from repro.core import AccumulatorSpec, BF16, FP32
from repro.core.dispatch import GemmConfig
from repro.numerics import PLAN_VERSION, PrecisionPlan, SitePlan, load_plan

FIXTURE = os.path.join(os.path.dirname(__file__), os.pardir,
                       "examples", "plans", "paper_mlp.json")


def _plan():
    return PrecisionPlan(
        name="unit",
        sites=(
            SitePlan("attn_qk",
                     GemmConfig(FP32, AccumulatorSpec(5, 8, -40), "simulate"),
                     error_bits=24.0, energy_j=1e-4, macs=1 << 20),
            SitePlan("mlp_in", GemmConfig(BF16, None, "native"),
                     error_bits=8.5, energy_j=2e-5, macs=1 << 21),
        ),
        default=GemmConfig(BF16, None, "native"),
        budget_bits=8.0,
        meta={"modeled_energy_j": 1.2e-4, "baseline_energy_j": 4e-4},
    )


def test_round_trip_preserves_everything():
    p = _plan()
    q = PrecisionPlan.from_json(json.loads(json.dumps(p.to_json())))
    assert q.name == p.name and q.version == PLAN_VERSION
    assert q.budget_bits == p.budget_bits
    assert q.meta == p.meta
    assert len(q.sites) == 2
    for a, b in zip(p.sites, q.sites):
        assert a.site == b.site
        assert a.cfg == b.cfg                 # fmt, spec, mode all exact
        assert a.error_bits == b.error_bits
        assert a.macs == b.macs
    assert q.default == p.default


def test_to_policy_overrides():
    pol = _plan().to_policy()
    assert pol.lookup("attn_qk").mode == "simulate"
    assert pol.lookup("attn_qk").acc == AccumulatorSpec(5, 8, -40)
    assert pol.lookup("mlp_in").fmt is BF16
    assert pol.lookup("unlisted_site") == GemmConfig(BF16, None, "native")
    assert pol.name == "plan:unit"


def test_save_load_file(tmp_path):
    path = tmp_path / "plan.json"
    p = _plan()
    p.save(path)
    q = load_plan(path)
    assert q.sites == p.sites
    assert q.to_policy().lookup("attn_qk") == p.sites[0].cfg


def test_newer_version_rejected():
    d = _plan().to_json()
    d["version"] = PLAN_VERSION + 1
    with pytest.raises(ValueError, match="newer"):
        PrecisionPlan.from_json(d)


def test_malformed_document_rejected():
    with pytest.raises(ValueError, match="PrecisionPlan"):
        PrecisionPlan.from_json({"version": 1, "something": "else"})


V1_FIXTURE = os.path.join(os.path.dirname(__file__), os.pardir,
                          "examples", "plans", "fixtures", "paper_mlp.v1.json")


def test_v1_document_migrates_to_v2():
    """Loading a v1 plan up-converts: assignments stay forward-only (bwd
    twins of assigned sites fall through), bwd_default is the widened plan
    default, and provenance lands in meta."""
    from repro.core.dispatch import widen_config
    plan = load_plan(V1_FIXTURE)
    assert plan.version == PLAN_VERSION
    assert plan.meta["migrated_from"] == 1
    assert plan.bwd_default == widen_config(plan.default)
    pol = plan.to_policy()
    for s in plan.sites:
        assert pol.lookup(s.site) == s.cfg                      # fwd intact
        assert pol.lookup(f"{s.site}@bwd.dA") == plan.bwd_default
        assert pol.lookup(f"{s.site}@bwd.dB") == plan.bwd_default
    assert pol.lookup("__unlisted__@bwd.dB") == plan.bwd_default


def test_migrated_plan_round_trips_as_v2(tmp_path):
    plan = load_plan(V1_FIXTURE)
    path = tmp_path / "migrated.json"
    plan.save(path)
    with open(path) as f:
        doc = json.load(f)
    assert doc["version"] == PLAN_VERSION
    assert doc["bwd_default"] is not None
    again = load_plan(path)
    assert again.bwd_default == plan.bwd_default
    assert [(s.site, s.cfg) for s in again.sites] == \
        [(s.site, s.cfg) for s in plan.sites]


def test_v2_plan_with_bwd_sites_round_trips():
    from repro.core.dispatch import widen_config
    base = GemmConfig(BF16, None, "native")
    narrow = GemmConfig(BF16, AccumulatorSpec(2, 4, -6), "simulate")
    p = PrecisionPlan(
        name="phased",
        sites=(SitePlan("mlp_in", GemmConfig(FP32, None, "native")),
               SitePlan("mlp_in@bwd.dA", narrow),
               SitePlan("mlp_in@bwd.dB", narrow)),
        default=base, bwd_default=widen_config(base), budget_bits=4.0)
    q = PrecisionPlan.from_json(json.loads(json.dumps(p.to_json())))
    assert q.phase_sites("bwd") == p.sites[1:]
    pol = q.to_policy()
    assert pol.lookup("mlp_in@bwd.dA") == narrow                # explicit
    assert pol.lookup("mlp_gate@bwd.dA") == q.bwd_default       # fallback
    assert pol.lookup("mlp_in") == GemmConfig(FP32, None, "native")


def test_v2_document_missing_bwd_default_widens():
    """A v2 doc with the key stripped must not let unassigned gradient GEMMs
    inherit the (possibly narrow) forward default — loading synthesizes the
    widened fallback exactly like the v1 migration does."""
    from repro.core.dispatch import widen_config
    d = _plan().to_json()
    assert "bwd_default" not in d          # _plan() carries no bwd_default
    q = PrecisionPlan.from_json(d)
    assert q.bwd_default == widen_config(q.default)
    assert q.to_policy().lookup("attn_qk@bwd.dA") == q.bwd_default
    # and the in-memory plan (bwd_default=None) deploys the same fallback:
    # to_policy and save->load->to_policy agree on every site
    p = _plan()
    assert p.to_policy().lookup("attn_qk@bwd.dA") == widen_config(p.default)


def test_malformed_site_key_rejected():
    d = _plan().to_json()
    d["sites"][0]["site"] = "attn_qk@sideways.dC"
    with pytest.raises(ValueError):
        PrecisionPlan.from_json(d)


def test_checked_in_fixture_loads_and_pays_for_itself():
    """The committed paper-MLP plan: valid schema, covers the model's GEMM
    sites, and its modeled energy undercuts the uniform 91-bit baseline."""
    plan = load_plan(FIXTURE)
    assert plan.version == PLAN_VERSION
    assert plan.budget_bits is not None
    sites = {s.site for s in plan.sites}
    assert {"attn_qk", "attn_av", "mlp_in", "mlp_out", "lm_head"} <= sites
    pol = plan.to_policy()
    for s in plan.sites:
        assert pol.lookup(s.site) == s.cfg
        assert s.error_bits is None or s.error_bits >= plan.budget_bits
    m = plan.meta
    assert m["modeled_energy_j"] <= m["baseline_energy_j"]
    assert m.get("validated_bits", plan.budget_bits) >= plan.budget_bits
