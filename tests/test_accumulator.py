"""Bit-exactness and algebraic properties of the FDP accumulator core."""

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import AccumulatorSpec, fdp_dot, fdp_gemm, FP32, BF16
from repro.core import accumulator as acc
from repro.core import fdp as fdp_mod

from conftest import fdp_oracle, frac_to_f32_rne

SPECS = [
    AccumulatorSpec.paper_91bit(),
    AccumulatorSpec(ovf=9, msb=6, lsb=-20),     # the paper's ResNet50 pick
    AccumulatorSpec(ovf=4, msb=14, lsb=-3),     # aggressive truncation
    AccumulatorSpec(ovf=12, msb=40, lsb=-60),   # wide
]


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.describe())
@pytest.mark.parametrize("scale", [1e-3, 1.0, 1e3])
def test_dot_matches_fraction_oracle(spec, scale, rng):
    K = int(rng.integers(3, 200))
    a = (rng.standard_normal(K) * scale).astype(np.float32)
    b = (rng.standard_normal(K) * scale).astype(np.float32)
    got = np.float32(float(fdp_dot(jnp.asarray(a), jnp.asarray(b), spec)))
    ref = fdp_oracle(a, b, spec)
    assert got == ref


@pytest.mark.slow
def test_91bit_exactness_region(rng):
    """Inside its dynamic range the 91-bit FDP returns the correctly-rounded
    exact dot product (52+ correct bits, the paper's Fig. 2 claim)."""
    spec = AccumulatorSpec.paper_91bit()
    for K in (10, 100, 1000, 10000):
        a = rng.standard_normal(K).astype(np.float32)
        b = rng.standard_normal(K).astype(np.float32)
        got = float(fdp_dot(jnp.asarray(a), jnp.asarray(b), spec))
        ref = float(np.dot(a.astype(np.float64), b.astype(np.float64)))
        # f64 dot of f32 data is itself ~exact here; agreement to f32 ulp,
        # with the K * 2^-30 per-product truncation bound as absolute floor
        assert got == pytest.approx(ref, rel=2e-7, abs=K * 2.0 ** -30)


def test_permutation_invariance(rng):
    """Fixed-point accumulation is associative & commutative => bitwise
    reproducible under any summation order (the paper's core claim)."""
    spec = AccumulatorSpec.paper_91bit()
    K = 4096
    a = (rng.standard_normal(K) * 1e4).astype(np.float32)
    b = (rng.standard_normal(K) * 1e-2).astype(np.float32)
    v0 = float(fdp_dot(jnp.asarray(a), jnp.asarray(b), spec))
    for s in range(5):
        perm = np.random.default_rng(s).permutation(K)
        v = float(fdp_dot(jnp.asarray(a[perm]), jnp.asarray(b[perm]), spec))
        assert v == v0


def test_fp32_sequential_is_not_reproducible(rng):
    """Sanity check of the baseline: conventional rounded accumulation is
    order-dependent on ill-conditioned data (what Fig. 2 shows degrading)."""
    from repro.data.conditioned import gen_dot
    a, b, _ = gen_dot(4096, cond=1e12, seed=3)
    v0 = float(fdp_mod.fma_dot(jnp.asarray(a), jnp.asarray(b)))
    vals = {v0}
    for s in range(6):
        perm = np.random.default_rng(s).permutation(a.shape[0])
        vals.add(float(fdp_mod.fma_dot(jnp.asarray(a[perm]), jnp.asarray(b[perm]))))
    assert len(vals) > 1


def test_wrap_vs_saturate():
    spec_w = AccumulatorSpec(ovf=2, msb=4, lsb=-4, overflow_mode="wrap")
    spec_s = AccumulatorSpec(ovf=2, msb=4, lsb=-4, overflow_mode="saturate")
    a = jnp.full((64,), 16.0, jnp.float32)
    b = jnp.ones((64,), jnp.float32)
    # true sum 1024 >> 2^(4+2): wrap differs from saturate
    vw = float(fdp_dot(a, b, spec_w))
    vs = float(fdp_dot(a, b, spec_s))
    W = spec_w.width
    exact_ulp = int(1024 * 2 ** 4)  # in ulp of 2^-4
    wrapped = ((exact_ulp + 2 ** (W - 1)) % 2 ** W) - 2 ** (W - 1)
    assert vw == wrapped * 2.0 ** spec_w.lsb
    assert vs == (2 ** (W - 1) - 1) * 2.0 ** spec_s.lsb


def test_chunked_reduction_matches_unchunked(rng):
    """Long-K path (lax.scan chunking) is exact too."""
    spec = AccumulatorSpec.paper_91bit()
    K = acc.SAFE_CHUNK * 3 + 77
    a = rng.standard_normal(K).astype(np.float32)
    b = rng.standard_normal(K).astype(np.float32)
    got = float(fdp_dot(jnp.asarray(a), jnp.asarray(b), spec))
    ref = float(np.dot(a.astype(np.float64), b.astype(np.float64)))
    assert got == pytest.approx(ref, rel=2e-7, abs=K * 2.0 ** -30)


def test_bf16_inputs(rng):
    spec = AccumulatorSpec.paper_91bit()
    K = 64
    a = rng.standard_normal(K).astype(np.float32)
    b = rng.standard_normal(K).astype(np.float32)
    a16 = jnp.asarray(a).astype(jnp.bfloat16)
    b16 = jnp.asarray(b).astype(jnp.bfloat16)
    got = float(fdp_dot(a16, b16, spec, BF16))
    ref = float(np.dot(np.asarray(a16, np.float64), np.asarray(b16, np.float64)))
    assert got == pytest.approx(ref, rel=2e-7, abs=K * 2.0 ** -30)


def test_lsb_refinement_monotone(rng):
    """Refining lsb can only reduce (or keep) the truncation error."""
    K = 128
    a = (rng.standard_normal(K) * 0.01).astype(np.float32)
    b = (rng.standard_normal(K) * 0.01).astype(np.float32)
    exact = float(np.dot(a.astype(np.float64), b.astype(np.float64)))
    errs = []
    for lsb in (-8, -16, -24, -32, -48):
        spec = AccumulatorSpec(ovf=10, msb=10, lsb=lsb)
        v = float(fdp_dot(jnp.asarray(a), jnp.asarray(b), spec))
        errs.append(abs(v - exact))
    for e0, e1 in zip(errs, errs[1:]):
        assert e1 <= e0 + 1e-12


@pytest.mark.slow
def test_rne_mode_at_least_as_accurate(rng):
    """Per-product RNE error is U(-u/2,u/2) vs trunc U(-u,u) (signed
    products): the random-walk RMS of the dot error should be ~2x smaller.
    Statistical test over 40 trials with a generous margin."""
    K = 256
    tr = AccumulatorSpec(ovf=10, msb=10, lsb=-12, round_mode="trunc")
    rn = AccumulatorSpec(ovf=10, msb=10, lsb=-12, round_mode="rne")
    et, en = [], []
    r = np.random.default_rng(7)
    for _ in range(40):
        a = r.standard_normal(K).astype(np.float32)
        b = r.standard_normal(K).astype(np.float32)
        exact = float(np.dot(a.astype(np.float64), b.astype(np.float64)))
        et.append(float(fdp_dot(jnp.asarray(a), jnp.asarray(b), tr)) - exact)
        en.append(float(fdp_dot(jnp.asarray(a), jnp.asarray(b), rn)) - exact)
    rms_t = np.sqrt(np.mean(np.square(et)))
    rms_n = np.sqrt(np.mean(np.square(en)))
    assert rms_n < rms_t * 0.9


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 40), st.integers(0, 2 ** 31 - 1))
def test_hypothesis_dot_vs_oracle(k, seed):
    spec = AccumulatorSpec(ovf=8, msb=12, lsb=-24)
    r = np.random.default_rng(seed)
    a = (r.standard_normal(k) * r.choice([1e-2, 1.0, 30.0])).astype(np.float32)
    b = (r.standard_normal(k) * r.choice([1e-2, 1.0, 30.0])).astype(np.float32)
    got = np.float32(float(fdp_dot(jnp.asarray(a), jnp.asarray(b), spec)))
    assert got == fdp_oracle(a, b, spec)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(-1e6, 1e6, width=32), min_size=2, max_size=24),
       st.integers(0, 10 ** 6))
def test_hypothesis_permutation_invariance(vals, seed):
    spec = AccumulatorSpec.paper_91bit()
    a = np.asarray(vals, np.float32)
    b = np.roll(a, 1)
    v0 = float(fdp_dot(jnp.asarray(a), jnp.asarray(b), spec))
    perm = np.random.default_rng(seed).permutation(len(vals))
    v1 = float(fdp_dot(jnp.asarray(a[perm]), jnp.asarray(b[perm]), spec))
    assert v0 == v1


def test_for_exact_sizing(rng):
    """for_exact() must make accumulation exact & overflow-free for f32."""
    spec = AccumulatorSpec.for_exact(FP32, max_terms=1024)
    K = 512
    a = (rng.standard_normal(K) * 1e30).astype(np.float32)
    b = (rng.standard_normal(K) * 1e-30).astype(np.float32)
    got = float(fdp_dot(jnp.asarray(a), jnp.asarray(b), spec))
    ref = float(np.dot(a.astype(np.float64), b.astype(np.float64)))
    assert got == pytest.approx(ref, rel=2e-7)


def test_gemm_matches_dot(rng):
    spec = AccumulatorSpec(ovf=9, msb=6, lsb=-20)
    M, K, N = 5, 67, 3
    A = rng.standard_normal((M, K)).astype(np.float32)
    B = rng.standard_normal((K, N)).astype(np.float32)
    G = np.asarray(fdp_gemm(jnp.asarray(A), jnp.asarray(B), spec))
    for i in range(M):
        for j in range(N):
            d = float(fdp_dot(jnp.asarray(A[i]), jnp.asarray(B[:, j]), spec))
            assert G[i, j] == np.float32(d)
