"""Mesh-aware numerics: fdp_psum / merge_states exactness, sharding-aware
dispatch (reduce_axis), the collective overflow guard, launch profile
plumbing, and the mesh-reshape workload — everything that runs on one device
(the 8-device sweeps live in tests/distributed_worker.py)."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import accumulator as acc
from repro.core import fdp
from repro.core.accumulator import AccumulatorSpec
from repro.core.dispatch import FDP91, MXU_FP32, gemm, use_policy
from repro.parallel.collectives import (fdp_psum, reproducible_psum,
                                        validate_overflow, _grid_quantize)
from repro.parallel.compat import axis_size, shard_map_unchecked

SPEC = AccumulatorSpec(ovf=30, msb=30, lsb=-30)


def _mesh1():
    return jax.make_mesh((1,), ("x",))


# ---------------------------------------------------------------------------
# Partial-K reduction state: fdp_gemm_limbs / merge_states / fdp_psum
# ---------------------------------------------------------------------------
def test_fdp_gemm_limbs_is_the_gemm_register():
    a = jax.random.normal(jax.random.key(0), (4, 32))
    b = jax.random.normal(jax.random.key(1), (32, 8))
    limbs = fdp.fdp_gemm_limbs(a, b, SPEC)
    assert limbs.shape == (4, 8, SPEC.num_limbs)
    assert limbs.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(acc.to_float(SPEC, limbs)),
                                  np.asarray(fdp.fdp_gemm(a, b, SPEC)))


def test_merge_states_bit_identical_for_any_k_split():
    a = jax.random.normal(jax.random.key(2), (4, 64))
    b = jax.random.normal(jax.random.key(3), (64, 8))
    ref = np.asarray(fdp.fdp_gemm(a, b, SPEC))
    for splits in (2, 4, 8):
        s = 64 // splits
        parts = jnp.stack([fdp.fdp_gemm_limbs(a[:, i*s:(i+1)*s],
                                              b[i*s:(i+1)*s], SPEC)
                           for i in range(splits)])
        merged = acc.merge_states(SPEC, parts)
        np.testing.assert_array_equal(
            np.asarray(acc.to_float(SPEC, merged)), ref)


def test_fdp_psum_single_device_identity():
    a = jax.random.normal(jax.random.key(4), (4, 32))
    b = jax.random.normal(jax.random.key(5), (32, 8))
    ref = np.asarray(fdp.fdp_gemm(a, b, SPEC))

    def f(al, bl):
        return acc.to_float(SPEC, fdp_psum(
            fdp.fdp_gemm_limbs(al, bl, SPEC), "x", SPEC))

    out = shard_map_unchecked(f, mesh=_mesh1(),
                              in_specs=(P(None, "x"), P("x", None)),
                              out_specs=P())(a, b)
    np.testing.assert_array_equal(np.asarray(out), ref)


def test_fdp_psum_rejects_wrong_limb_count():
    def f(x):
        return fdp_psum(x, "x", SPEC)

    with pytest.raises(AssertionError):
        shard_map_unchecked(f, mesh=_mesh1(), in_specs=P("x"),
                            out_specs=P())(jnp.zeros((1, 3, 2), jnp.int32))


# ---------------------------------------------------------------------------
# Sharding-aware dispatch: gemm(reduce_axis=...)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", [FDP91, MXU_FP32],
                         ids=["fdp_simulate", "native"])
def test_gemm_reduce_axis_matches_local(policy):
    a = jax.random.normal(jax.random.key(6), (4, 32))
    b = jax.random.normal(jax.random.key(7), (32, 8))
    with use_policy(policy):
        ref = np.asarray(gemm(a, b, site="probe"))

    def f(al, bl):
        return gemm(al, bl, site="probe", policy=policy, reduce_axis="x")

    out = shard_map_unchecked(f, mesh=_mesh1(),
                              in_specs=(P(None, "x"), P("x", None)),
                              out_specs=P())(a, b)
    np.testing.assert_array_equal(np.asarray(out), ref)


def test_gemm_reduce_axis_backward_needs_no_collectives():
    """dA_loc = G·B_locᵀ, dB_loc = A_locᵀ·G are already the local shards of
    the full gradients — a K-sharded fwd must grad exactly like local."""
    a = jax.random.normal(jax.random.key(8), (4, 32))
    b = jax.random.normal(jax.random.key(9), (32, 8))
    loss = lambda x, y, **kw: gemm(x, y, site="probe", policy=FDP91,
                                   **kw).sum()
    gref = jax.grad(loss, argnums=(0, 1))(a, b)

    def f(al, bl):
        return jax.grad(lambda x, y: loss(x, y, reduce_axis="x"),
                        argnums=(0, 1))(al, bl)

    got = shard_map_unchecked(f, mesh=_mesh1(),
                              in_specs=(P(None, "x"), P("x", None)),
                              out_specs=(P(None, "x"), P("x", None)))(a, b)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(gref[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(gref[1]))


def test_gemm_reduce_axis_fdp_rejects_batched():
    def f(al, bl):
        return gemm(al, bl, site="probe", policy=FDP91, reduce_axis="x")

    with pytest.raises(NotImplementedError):
        shard_map_unchecked(f, mesh=_mesh1(),
                            in_specs=(P(None, None, "x"), P("x", None)),
                            out_specs=P())(
            jnp.zeros((2, 4, 8)), jnp.zeros((8, 4)))


# ---------------------------------------------------------------------------
# Collective payload overflow guard + axis_size shim
# ---------------------------------------------------------------------------
def test_overflow_guard_raises_under_validation():
    with validate_overflow():
        with pytest.raises(OverflowError):
            _grid_quantize(jnp.array([1e9]), -16, 16)


def test_overflow_guard_clean_path_and_default_off():
    with validate_overflow():
        q = _grid_quantize(jnp.array([0.25]), -16, 16)
    assert int(q[0]) == 16384
    # off by default: saturating payloads clip silently (production path)
    q = _grid_quantize(jnp.array([1e9]), -16, 16)
    assert int(q[0]) == 2 ** 15 - 1


def test_axis_size_and_mean_psum():
    def f(xl):
        return reproducible_psum(xl[0], "x", AccumulatorSpec(8, 8, -16),
                                 mean=True), axis_size("x")

    x = jax.random.normal(jax.random.key(10), (1, 16))
    out, n = shard_map_unchecked(f, mesh=_mesh1(), in_specs=P("x"),
                                 out_specs=(P(), P()))(x)
    assert int(n) == 1
    np.testing.assert_allclose(np.asarray(out), np.asarray(x[0]),
                               atol=2.0 ** -16)


# ---------------------------------------------------------------------------
# Launch profile plumbing
# ---------------------------------------------------------------------------
def test_parse_mesh():
    from repro.launch.sharding import parse_mesh
    assert parse_mesh("2x4") == (2, 4)
    assert parse_mesh("8") == (8, 1)
    assert parse_mesh("1X8") == (1, 8)
    with pytest.raises(ValueError):
        parse_mesh("2x4x2")
    with pytest.raises(ValueError):
        parse_mesh("ax4")


def test_distribution_for_carries_policy():
    from repro.launch.sharding import distribution_for, make_mesh
    mesh = make_mesh("1x1")
    dist = distribution_for(mesh, "decode_tp", numerics_policy=FDP91)
    assert dist.joint_tp and dist.numerics_policy is FDP91
    assert distribution_for(mesh, "fsdp").numerics_policy is None
    with pytest.raises(ValueError):
        distribution_for(mesh, "nope")
    with pytest.raises(ValueError):
        make_mesh("3x9")


def test_make_train_step_policy_falls_back_to_dist():
    from repro.models.layers import Distribution
    from repro.train.loop import make_train_step
    from repro.train.optimizer import adamw
    from repro.configs import get_config

    cfg = get_config("paper-mlp").reduced()
    from repro.workloads import WorkloadContext
    ctx = WorkloadContext.for_model(cfg)
    dist = Distribution(mesh=None, numerics_policy=MXU_FP32)
    opt = adamw(lr=1e-3)
    step = make_train_step(cfg, opt, dist, remat="none", donate=False)
    (params, _), metrics = step((ctx.params, opt.init(ctx.params)),
                                ctx.grad_batch)
    assert np.isfinite(float(metrics["loss"]))


def test_make_mesh_train_step_1x1_matches_local():
    """On the degenerate 1x1 mesh the sharded step is the local step."""
    from repro.launch.sharding import distribution_for, make_mesh
    from repro.train.loop import make_mesh_train_step
    from repro.train.optimizer import adamw
    from repro.configs import get_config
    from repro.workloads import WorkloadContext

    cfg = get_config("paper-mlp").reduced()
    ctx = WorkloadContext.for_model(cfg)
    opt = adamw(lr=1e-3)
    dist = distribution_for(make_mesh("1x1"), "ddp",
                            numerics_policy=MXU_FP32)
    step = make_mesh_train_step(cfg, opt, dist,
                                fdp_grad_spec=AccumulatorSpec(10, 10, -20))
    (params, _), metrics = step((ctx.params, opt.init(ctx.params)),
                                ctx.grad_batch)
    assert np.isfinite(float(metrics["loss"]))
    changed = jax.tree.map(
        lambda p0, p1: not np.array_equal(np.asarray(p0), np.asarray(p1)),
        ctx.params, params)
    assert any(jax.tree.leaves(changed))


# ---------------------------------------------------------------------------
# Mesh-reshape workload + report provenance
# ---------------------------------------------------------------------------
def test_mesh_workload_registered_and_runs():
    from repro.workloads import (MeshReshapeStability, WorkloadContext,
                                 available_workloads, build_validators)
    assert "mesh" in available_workloads()
    (v,) = build_validators(("mesh",), WorkloadContext(budget_bits=10.0))
    rep = v.run(FDP91)
    assert rep.passed and rep.mesh == "1x1"
    assert rep.to_json()["mesh"] == "1x1"


def test_mesh_shapes_enumerates_factorizations():
    from repro.workloads.mesh import mesh_shapes
    assert mesh_shapes(8) == [(1, 8), (2, 4), (4, 2), (8, 1)]
    assert mesh_shapes(1) == [(1, 1)]


def test_report_mesh_field_absent_by_default():
    from repro.workloads import ValidationReport
    rep = ValidationReport(workload="w", score=1.0, threshold=0.0)
    assert rep.mesh is None and "mesh" not in rep.to_json()
    with_mesh = dataclasses.replace(rep, mesh="2x4")
    assert with_mesh.to_json()["mesh"] == "2x4"
