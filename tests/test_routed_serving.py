"""repro.serving — routing, bucketed engine pool, frontend, and the
batcher's admission-control contract.

Selection tests drive PlanRouter over synthetic evidence (no model needed);
the e2e tests serve mixed workload classes through the full tier on the
tiny paper-mlp arch and require bit-identical outputs against dedicated
single-plan engines, with trace_count proving no recompiles after warmup.
"""

import os

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.dispatch import FDP91
from repro.launch.batching import CacheExhausted, ContinuousBatcher, Request
from repro.models import init
from repro.serving import (AdmissionError, Bucket, BucketedEnginePool,
                           PlanRouter, RoutedFrontend, RoutedPlan,
                           RoutingError, ScoreEngine, ServeRequest,
                           parse_buckets)

PLANS_DIR = os.path.join(os.path.dirname(__file__), "..", "examples", "plans")


# ---------------------------------------------------------------------------
# PlanRouter selection over synthetic evidence
# ---------------------------------------------------------------------------

def _plan(name, energy, *, solve=None, repro=None, passed=True,
          bits=20.0, certified=False):
    scores, ok = {"logits": bits}, {"logits": passed}
    if solve is not None:
        scores["solve"], ok["solve"] = solve, passed
    if repro is not None:
        scores["repro"], ok["repro"] = repro, passed
    return RoutedPlan(name=name, scores=scores, passed=ok, energy=energy,
                      validated_bits=bits, repro_certified=certified,
                      loader=lambda: FDP91)


@pytest.fixture
def router():
    return PlanRouter([
        _plan("cheap", 0.2, solve=18.0, bits=16.0),
        _plan("mid", 0.5, solve=30.0, repro=51.0, bits=24.0, certified=True),
        _plan("wide", 1.0, solve=53.0, repro=53.0, bits=53.0, certified=True),
        _plan("broken", 0.1, solve=40.0, bits=10.0, passed=False),
    ])


def test_chat_routes_cheapest_passing(router):
    # "broken" is cheapest but failed validation; "cheap" is next
    assert router.route("chat").name == "cheap"


def test_solve_routes_highest_score(router):
    # energy is irrelevant for solve: "wide" records the highest solve score
    assert router.route("solve").name == "wide"


def test_repro_routes_certified_only(router):
    # cheapest *certified* plan — "cheap"/"broken" are cheaper but uncertified
    assert router.route("repro").name == "mid"


def test_explicit_plan_name_wins(router):
    assert router.route("wide").name == "wide"


def test_min_bits_escalates_chat(router):
    assert router.route("chat", min_bits=20.0).name == "mid"
    assert router.route("chat", min_bits=40.0).name == "wide"


def test_bit_stable_constraint(router):
    assert router.route("chat", bit_stable=True).name == "mid"


def test_unsatisfiable_raises_typed(router):
    with pytest.raises(RoutingError) as ei:
        router.route("chat", min_bits=99.0)
    assert ei.value.workload == "chat"
    assert "99" in ei.value.reason
    with pytest.raises(RoutingError):
        router.route("cheap", bit_stable=True)   # explicit name, unmet
    with pytest.raises(RoutingError):
        router.route("no-such-class-or-plan")


def test_router_rejects_bad_names():
    with pytest.raises(ValueError, match="shadows"):
        PlanRouter([_plan("chat", 0.5)])
    with pytest.raises(ValueError, match="duplicate"):
        PlanRouter([_plan("a", 0.5), _plan("a", 0.6)])


def test_synthetic_manifest_roundtrip(tmp_path):
    import json
    man = {"plans": {
        "good": {"arch": "x", "file": "good.json", "energy_vs_baseline": 0.3,
                 "validated_bits": 22.0,
                 "validation": {"logits": {"score": 22.0, "passed": True}}},
        "no-scores": {"arch": "x", "energy_vs_baseline": 0.3,
                      "validation": {}},
        "bad-energy": {"arch": "x", "energy_vs_baseline": "cheap",
                       "validation": {"logits": {"score": 9.0,
                                                 "passed": True}}},
    }}
    (tmp_path / "MANIFEST.json").write_text(json.dumps(man))
    from repro.serving import routed_plan_from_entry
    ok = routed_plan_from_entry("good", man["plans"]["good"], str(tmp_path))
    assert ok.scores["logits"] == 22.0 and ok.path.endswith("good.json")
    with pytest.raises(ValueError, match="no validation"):
        routed_plan_from_entry("no-scores", man["plans"]["no-scores"],
                               str(tmp_path))
    with pytest.raises(ValueError, match="energy_vs_baseline"):
        routed_plan_from_entry("bad-energy", man["plans"]["bad-energy"],
                               str(tmp_path))
    with pytest.raises(RoutingError, match="no MANIFEST entry"):
        PlanRouter.from_manifest(tmp_path, arch="unknown-arch", derive=False)


def test_zoo_manifest_distinct_plans_per_class():
    """The real zoo + derived variants: three classes, three distinct
    numerics (the acceptance criterion's routing half)."""
    r = PlanRouter.from_manifest(PLANS_DIR, arch="paper-mlp")
    picks = {wl: r.route(wl).name for wl in ("chat", "solve", "repro")}
    assert len(set(picks.values())) == 3
    assert r.route("solve").scores["solve"] >= 53.0
    assert r.route("repro").repro_certified
    assert r.route("repro").energy < 1.0      # cheaper than the wide variant


# ---------------------------------------------------------------------------
# Buckets
# ---------------------------------------------------------------------------

def test_parse_buckets_sorted_dedup():
    bs = parse_buckets("4x64, 2x32, 4x64")
    assert [b.label for b in bs] == ["2x32", "4x64"]
    assert bs[0].capacity == 31
    with pytest.raises(ValueError, match="degenerate"):
        Bucket(max_len=2, n_slots=1)


def test_bucket_for_smallest_fit(mlp):
    cfg, params = mlp
    pool = BucketedEnginePool(cfg, params, "2x32,4x64")   # engines are lazy
    assert pool.bucket_for(10, 8).label == "2x32"
    assert pool.bucket_for(30, 8).label == "4x64"
    with pytest.raises(AdmissionError, match="largest bucket"):
        pool.bucket_for(60, 8)


# ---------------------------------------------------------------------------
# Batcher admission contract (the fixed cache-exhaustion path)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mlp():
    cfg = get_config("paper-mlp").reduced()
    return cfg, init(cfg, jax.random.key(0))


def test_cache_remaining_and_refusal(mlp):
    cfg, params = mlp
    eng = ContinuousBatcher(cfg, params, n_slots=1, max_len=16)
    assert eng.cache_remaining() == 15
    # needs 4 + 12 = 16 > 15: refused up front, loudly — never truncated
    eng.submit(Request(0, [1, 2, 3, 4], max_new=12))
    with pytest.raises(CacheExhausted, match="16 positions"):
        eng.run()
    assert eng.queue and not eng.queue[0].out   # still queued, untouched


def test_exhaustion_then_reset_recycles(mlp):
    cfg, params = mlp
    eng = ContinuousBatcher(cfg, params, n_slots=1, max_len=16)
    r1 = Request(1, [5, 9, 2], max_new=6)
    eng.submit(r1)
    eng.run()
    assert r1.done and len(r1.out) == 6
    used = 15 - eng.cache_remaining()
    assert used == len(r1.prompt) + r1.max_new - 1   # cursor = steps taken
    # a same-sized request no longer fits the cursor's leftovers
    r2 = Request(2, [5, 9, 2], max_new=6)
    eng.submit(r2)
    with pytest.raises(CacheExhausted):
        eng.run()
    eng.reset_cache()
    assert eng.cache_remaining() == 15
    eng.run()
    assert r2.done and r2.out == r1.out       # fresh cache, same generation


def test_reset_cache_refuses_live_slots(mlp):
    cfg, params = mlp
    eng = ContinuousBatcher(cfg, params, n_slots=1, max_len=32)
    eng.submit(Request(3, [1, 2, 3], max_new=4))
    eng.step()
    with pytest.raises(RuntimeError, match="live slots"):
        eng.reset_cache()


def test_request_step_accounting_and_streaming(mlp):
    cfg, params = mlp
    got = []
    req = Request(4, [7, 1, 8, 3], max_new=5, on_token=got.append)
    eng = ContinuousBatcher(cfg, params, n_slots=2, max_len=32)
    eng.submit(req)
    eng.run()
    # P prompt tokens + M generated, last one never fed: P + M - 1 steps
    assert req.steps == 4 + 5 - 1
    assert req.prefill_tokens == 4
    assert req.decode_tokens == 5
    assert got == req.out                     # streamed as they landed


# ---------------------------------------------------------------------------
# Engine pool + frontend e2e (the acceptance run)
# ---------------------------------------------------------------------------

def test_pool_lru_and_hits(mlp):
    cfg, params = mlp
    r = PlanRouter.from_manifest(PLANS_DIR, arch="paper-mlp")
    pool = BucketedEnginePool(cfg, params, "2x16", max_live=1)
    b = pool.buckets[0]
    e1 = pool.get(r.route("chat"), b, "generate")
    assert pool.get(r.route("chat"), b, "generate") is e1    # cache hit
    pool.get(r.route("solve"), b, "generate")                # evicts idle e1
    st = pool.stats()
    assert st == {**st, "compiles": 2, "hits": 1, "evictions": 1,
                  "resident": 1}
    with pytest.raises(ValueError, match="unknown method"):
        pool.get(r.route("chat"), b, "train")


def test_routed_vs_dedicated_bit_identical(mlp):
    """Two workload classes served concurrently through the routed tier must
    equal dedicated single-plan ContinuousBatchers bit-for-bit, with every
    engine compiled exactly once (trace_count stays 1 after serving)."""
    cfg, params = mlp
    router = PlanRouter.from_manifest(PLANS_DIR, arch="paper-mlp")
    pool = BucketedEnginePool(cfg, params, "2x32", max_live=4)
    front = RoutedFrontend(pool, router, max_live_batches=2)

    prompts = [[5, 9, 2], [7, 1, 8, 3], [4, 4, 6], [9, 2, 2, 7]]
    comps, classes = [], ["chat", "solve", "chat", "solve"]
    for i, (p, wl) in enumerate(zip(prompts, classes)):   # interleaved
        comps.append(front.submit(ServeRequest(uid=i, prompt=p, max_new=5,
                                               workload=wl)))
    front.run()
    assert all(c.ok for c in comps)
    by_class = {wl: [c for c in comps if c.request.workload == wl]
                for wl in ("chat", "solve")}
    assert {c.plan for c in by_class["chat"]} != \
           {c.plan for c in by_class["solve"]}        # distinct zoo plans

    for wl, batch in by_class.items():
        plan = router.route(wl)
        ded = ContinuousBatcher(cfg, params, n_slots=2, max_len=32,
                                warmup=plan.policy())
        refs = [Request(uid=c.request.uid, prompt=list(c.request.prompt),
                        max_new=5) for c in batch]
        for rr in refs:
            ded.submit(rr)
        ded.run()
        for c, rr in zip(batch, refs):
            assert c.result() == rr.out       # bit-identical
            assert c.steps == rr.steps
        assert ded.trace_count == 1

    for eng in pool.live().values():
        assert eng.trace_count == 1           # no recompile after warmup
    st = front.stats()
    assert st["classes"]["chat"]["completed"] == 2
    assert st["classes"]["solve"]["plans"] == {"paper_mlp/fdp91": 2}


def test_frontend_rejections_are_futures(mlp):
    cfg, params = mlp
    router = PlanRouter.from_manifest(PLANS_DIR, arch="paper-mlp")
    pool = BucketedEnginePool(cfg, params, "2x16")
    front = RoutedFrontend(pool, router)
    # unsatisfiable constraint -> RoutingError future
    c1 = front.submit(ServeRequest(uid=0, prompt=[1, 2], max_new=4,
                                   workload="chat", min_bits=99.0))
    # no bucket fits -> AdmissionError future
    c2 = front.submit(ServeRequest(uid=1, prompt=list(range(14)), max_new=8))
    assert c1.done and not c1.ok and isinstance(c1.error, RoutingError)
    assert c2.done and not c2.ok and isinstance(c2.error, AdmissionError)
    with pytest.raises(AdmissionError):
        c2.result()
    front.run()                               # nothing queued: no-op
    st = front.stats()
    assert st["classes"]["chat"]["rejected"] == 2


def test_frontend_metrics_sum_invariant(mlp):
    """RoutedFrontend.metrics(): submitted == routed + parked + rejected at
    every observable point — before run() (work parked), and after (all
    routed work completed, nothing parked)."""
    cfg, params = mlp
    router = PlanRouter.from_manifest(PLANS_DIR, arch="paper-mlp")
    pool = BucketedEnginePool(cfg, params, "2x32")
    front = RoutedFrontend(pool, router)
    comps = [front.submit(ServeRequest(uid=i, prompt=[3 + i, 7, 1],
                                       max_new=4, workload="chat"))
             for i in range(3)]
    front.submit(ServeRequest(uid=9, prompt=[1, 2], max_new=4,
                              workload="chat", min_bits=99.0))   # rejected

    m = front.metrics()
    assert m["submitted"] == 4 and m["rejected"] == 1
    assert m["parked"] == 3 and m["completed"] == 0
    assert m["submitted"] == m["routed"] + m["parked"] + m["rejected"]

    front.run()
    assert all(c.ok for c in comps)
    m = front.metrics()
    assert m["submitted"] == 4 and m["parked"] == 0
    assert m["completed"] == 3 and m["routed"] == 3
    assert m["submitted"] == m["routed"] + m["parked"] + m["rejected"]
    assert m["wall_seconds"] > 0


def test_score_method_matches_forward(mlp):
    import jax.numpy as jnp
    from repro.core.dispatch import use_policy
    from repro.models import forward
    cfg, params = mlp
    router = PlanRouter.from_manifest(PLANS_DIR, arch="paper-mlp")
    plan = router.route("solve")
    bucket = Bucket(max_len=16, n_slots=2)
    eng = ScoreEngine(cfg, params, bucket, plan.policy())
    prompt = [3, 11, 4, 7]
    (got,) = eng.score_batch([prompt])
    toks = np.zeros((2, 16), np.int32)
    toks[0, :4] = prompt
    with use_policy(plan.policy()):
        logits = forward(params, cfg, {"tokens": jnp.asarray(toks)})
    logp = jax.nn.log_softmax(logits[:, :, :cfg.vocab_size], -1)
    want = float(sum(logp[0, j, prompt[j + 1]] for j in range(3)))
    assert got == pytest.approx(want, rel=1e-5)
    assert eng.trace_count == 1
