"""Layer-level correctness: chunked attention vs naive softmax, MoE vs
per-token loop, SSD chunked scan vs naive recurrence."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.dispatch import use_policy, MXU_FP32
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.config import ModelConfig


@pytest.fixture(autouse=True)
def fp32_policy():
    with use_policy(MXU_FP32):
        yield


def naive_attention(q, k, v, causal, prefix_len=0):
    B, H, Sq, hd = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kk) * hd ** -0.5
    if causal:
        Sk = k.shape[2]
        mask = (jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None]) \
            | (jnp.arange(Sk)[None, :] < prefix_len)
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("chunk", [4, 16, 64])
@pytest.mark.parametrize("gqa", [(4, 4), (4, 2), (8, 1)])
def test_chunked_attention_vs_naive(causal, chunk, gqa, rng):
    H, Hkv = gqa
    B, Sq, hd = 2, 24, 16
    q = jnp.asarray(rng.standard_normal((B, H, Sq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, Sq, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, Sq, hd)), jnp.float32)
    got = L.attention(q, k, v, causal=causal, chunk=chunk)
    ref = naive_attention(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_attention_prefix_lm(rng):
    B, H, S, hd = 1, 2, 12, 8
    q = jnp.asarray(rng.standard_normal((B, H, S, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, H, S, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, H, S, hd)), jnp.float32)
    got = L.attention(q, k, v, causal=True, chunk=4, prefix_len=5)
    ref = naive_attention(q, k, v, True, prefix_len=5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_matches_full(rng):
    B, H, Hkv, S, hd = 2, 4, 2, 9, 8
    q = jnp.asarray(rng.standard_normal((B, H, 1, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, 16, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, 16, hd)), jnp.float32)
    got = L.decode_attention(q, k, v, cache_len=jnp.int32(S))
    ref = naive_attention(q, k[:, :, :S], v[:, :, :S], causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def _moe_cfg(E=4, k=2):
    return ModelConfig(name="t", family="moe", n_layers=1, d_model=16,
                       n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=64,
                       n_experts=E, top_k=k)


def test_moe_vs_per_token_loop(rng):
    cfg = _moe_cfg()
    p = MOE.init_moe(jax.random.key(0), cfg.d_model, cfg.d_ff, cfg.n_experts)
    x = jnp.asarray(rng.standard_normal((3, 5, cfg.d_model)), jnp.float32)
    got = MOE.moe_block(x, p, cfg, L.LOCAL)
    # naive: per-token dense expert evaluation
    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    w, ids = jax.lax.top_k(probs, cfg.top_k)
    w = w / w.sum(-1, keepdims=True)
    outs = []
    for t in range(xf.shape[0]):
        acc = jnp.zeros((cfg.d_model,))
        for j in range(cfg.top_k):
            e = int(ids[t, j])
            h_in = xf[t] @ p["w_in"][e]
            h_g = xf[t] @ p["w_gate"][e]
            h = jax.nn.silu(h_g) * h_in
            acc = acc + w[t, j] * (h @ p["w_out"][e])
        outs.append(acc)
    ref = jnp.stack(outs).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_moe_all_tokens_kept(rng):
    """Ragged dispatch drops nothing: with a uniform router and top_k=1 every
    token ties -> expert 0 deterministically; the output must be exactly
    expert 0's FFN for every token (extreme imbalance, zero drops)."""
    cfg = _moe_cfg(E=4, k=1)
    p = MOE.init_moe(jax.random.key(0), cfg.d_model, cfg.d_ff, cfg.n_experts)
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])
    x = jnp.asarray(rng.standard_normal((2, 7, cfg.d_model)), jnp.float32)
    got = MOE.moe_block(x, p, cfg, L.LOCAL)
    xf = x.reshape(-1, cfg.d_model)
    h = jax.nn.silu(xf @ p["w_gate"][0]) * (xf @ p["w_in"][0])
    ref = (h @ p["w_out"][0]).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def _ssm_cfg():
    return ModelConfig(name="t", family="ssm", n_layers=1, d_model=32,
                       n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=64,
                       ssm_state=8, ssm_expand=2, ssm_head_dim=8,
                       ssm_groups=2, ssm_conv=4)


def naive_ssd(x, dt, A, B, C):
    """Token-by-token linear recurrence (the SSD definition)."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    e = h // g
    S = np.zeros((b, g, e, p, n))
    ys = []
    Ar = np.asarray(-np.exp(A)).reshape(g, e)
    for t in range(l):
        da = np.exp(np.asarray(dt[:, t]).reshape(b, g, e) * Ar)
        xt = np.asarray(x[:, t]).reshape(b, g, e, p)
        dtt = np.asarray(dt[:, t]).reshape(b, g, e)
        S = S * da[..., None, None] + np.einsum(
            "bgn,bgep->bgepn", np.asarray(B[:, t]), xt * dtt[..., None])
        y = np.einsum("bgn,bgepn->bgep", np.asarray(C[:, t]), S)
        ys.append(y.reshape(b, h, p))
    return np.stack(ys, 1), S


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_vs_naive(chunk, rng):
    b, l, h, p, g, n = 2, 24, 4, 8, 2, 8
    x = jnp.asarray(rng.standard_normal((b, l, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, (b, l, h)), jnp.float32)
    A = jnp.asarray(rng.uniform(-1, 0.5, (h,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, l, g, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, l, g, n)), jnp.float32)
    y, S = SSM.ssd_chunked(x, dt, A, B, C, chunk=chunk)
    y_ref, S_ref = naive_ssd(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(S), S_ref, rtol=2e-4, atol=2e-4)


def test_ssd_step_matches_chunked(rng):
    b, l, h, p, g, n = 1, 12, 4, 8, 2, 8
    x = jnp.asarray(rng.standard_normal((b, l, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, (b, l, h)), jnp.float32)
    A = jnp.asarray(rng.uniform(-1, 0.5, (h,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, l, g, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, l, g, n)), jnp.float32)
    y_ref, S_ref = SSM.ssd_chunked(x, dt, A, B, C, chunk=4)
    S = jnp.zeros((b, g, h // g, p, n))
    ys = []
    for t in range(l):
        y, S = SSM.ssd_step(S, x[:, t], dt[:, t], A, B[:, t], C[:, t])
        ys.append(y)
    y_inc = jnp.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y_inc), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_ref),
                               rtol=2e-4, atol=2e-4)


def test_causal_conv_decode_parity(rng):
    b, l, c, w = 2, 10, 6, 4
    x = jnp.asarray(rng.standard_normal((b, l, c)), jnp.float32)
    kern = jnp.asarray(rng.standard_normal((w, c)), jnp.float32)
    y_full, _ = SSM._causal_conv(x, kern)
    state = jnp.zeros((b, w - 1, c))
    ys = []
    for t in range(l):
        y, state = SSM._causal_conv(x[:, t:t + 1], kern, state)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), rtol=1e-5, atol=1e-6)


def test_rope_relative_shift_invariance(rng):
    """RoPE inner products depend only on relative positions."""
    B, H, S, hd = 1, 1, 6, 16
    q = jnp.asarray(rng.standard_normal((B, H, S, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, H, S, hd)), jnp.float32)
    q1 = L.rope(q, jnp.arange(S), 10000.0)
    k1 = L.rope(k, jnp.arange(S), 10000.0)
    q2 = L.rope(q, jnp.arange(S) + 17, 10000.0)
    k2 = L.rope(k, jnp.arange(S) + 17, 10000.0)
    s1 = jnp.einsum("bhqd,bhkd->bhqk", q1, k1)
    s2 = jnp.einsum("bhqd,bhkd->bhqk", q2, k2)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)
