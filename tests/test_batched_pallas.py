"""Batched Pallas path: (B,M,K)@(B,K,N) through ``dispatch.gemm`` (pallas
mode, native 4-D grid) must be bit-identical to the per-batch ``fdp.fdp_gemm``
simulation — including non-block-multiple shapes, batch broadcasting and
posit (int32 bit-pattern) inputs — and the GemmPlan cache must serve it."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import AccumulatorSpec, FP32, POSIT16_1
from repro.core import fdp
from repro.core.dispatch import (GemmConfig, GemmPlan, NumericsPolicy, gemm,
                                 plan_cache_stats, plan_gemm, use_policy)
from repro.kernels import ops as kops

SPEC = AccumulatorSpec.paper_91bit()


def _pallas_policy(fmt=FP32, spec=SPEC):
    return NumericsPolicy(GemmConfig(fmt, spec, "pallas"))


@pytest.mark.parametrize("B,M,K,N", [
    (3, 8, 32, 8),          # block-aligned
    (2, 17, 70, 9),         # nothing divides the blocks
    (4, 1, 128, 5),         # degenerate rows
    (1, 33, 257, 3),        # B=1 still goes through the batched grid
], ids=str)
def test_batched_bitexact_vs_simulation(B, M, K, N, rng):
    A = (rng.standard_normal((B, M, K)) * 3).astype(np.float32)
    Bv = (rng.standard_normal((B, K, N)) * 3).astype(np.float32)
    with use_policy(_pallas_policy()):
        got = np.asarray(gemm(jnp.asarray(A), jnp.asarray(Bv), site="t"))
    assert got.shape == (B, M, N)
    for i in range(B):
        ref = np.asarray(fdp.fdp_gemm(jnp.asarray(A[i]), jnp.asarray(Bv[i]),
                                      SPEC, FP32))
        np.testing.assert_array_equal(got[i], ref)


def test_batched_kernel_equals_vmapped_2d(rng):
    """The native 4-D grid == vmap of the 2-D kernel, bit for bit."""
    A = jnp.asarray(rng.standard_normal((3, 24, 96)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((3, 96, 16)), jnp.float32)
    got = kops.fdp_gemm_batched(A, B, spec=SPEC, plan=GemmPlan(8, 8, 32))
    ref = jax.vmap(lambda x, y: kops.fdp_gemm(x, y, spec=SPEC,
                                              plan=GemmPlan(8, 8, 32)))(A, B)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_batch_broadcasting(rng):
    """Leading batch dims broadcast numpy-style before the batched grid."""
    A = jnp.asarray(rng.standard_normal((2, 1, 9, 33)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((3, 33, 7)), jnp.float32)
    with use_policy(_pallas_policy()):
        got = np.asarray(gemm(A, B, site="t"))
    assert got.shape == (2, 3, 9, 7)
    for i in range(2):
        for j in range(3):
            ref = np.asarray(fdp.fdp_gemm(A[i, 0], B[j], SPEC, FP32))
            np.testing.assert_array_equal(got[i, j], ref)


def test_batched_posit_inputs(rng):
    """Posit16 int32 bit patterns flow through the batched grid bit-exactly."""
    av = rng.standard_normal((2, 8, 24)).astype(np.float32)
    bv = rng.standard_normal((2, 24, 8)).astype(np.float32)
    ap = POSIT16_1.from_float(jnp.asarray(av))
    bp = POSIT16_1.from_float(jnp.asarray(bv))
    with use_policy(_pallas_policy(fmt=POSIT16_1)):
        got = np.asarray(gemm(ap, bp, site="t"))
    for i in range(2):
        ref = np.asarray(fdp.fdp_gemm(ap[i], bp[i], SPEC, POSIT16_1))
        np.testing.assert_array_equal(got[i], ref)


def test_batched_under_jit(rng):
    """dispatch.gemm(mode=pallas) plans from static shapes inside a trace."""
    A = jnp.asarray(rng.standard_normal((2, 12, 40)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((2, 40, 6)), jnp.float32)
    pol = _pallas_policy()

    @jax.jit
    def f(x, y):
        return gemm(x, y, site="t", policy=pol)

    got = np.asarray(f(A, B))
    for i in range(2):
        ref = np.asarray(fdp.fdp_gemm(A[i], B[i], SPEC, FP32))
        np.testing.assert_array_equal(got[i], ref)


def test_1d_promotion_matches_matmul(rng):
    """Vector operands follow jnp.matmul semantics through every mode,
    including the vector·vector scalar case."""
    v = jnp.asarray(rng.standard_normal(33), jnp.float32)
    w = jnp.asarray(rng.standard_normal(33), jnp.float32)
    A = jnp.asarray(rng.standard_normal((9, 33)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((2, 33, 7)), jnp.float32)
    for mode in ("pallas", "simulate"):
        with use_policy(_pallas_policy() if mode == "pallas" else
                        NumericsPolicy(GemmConfig(FP32, SPEC, "simulate"))):
            s = gemm(v, w, site="t")          # (33,)@(33,) -> scalar
            mv = gemm(A, w, site="t")         # (9,33)@(33,) -> (9,)
            vb = gemm(v, B, site="t")         # (33,)@(2,33,7) -> (2,7)
        assert s.shape == ()
        assert mv.shape == (9,)
        assert vb.shape == (2, 7)
        # f32-matmul reference carries its own rounding; this checks the
        # promotion plumbing, not exactness (covered by the oracle tests)
        np.testing.assert_allclose(float(s), float(v @ w),
                                   rtol=1e-5, atol=1e-6)


def test_autotune_upgrades_heuristic_cache_entry():
    """plan_gemm(autotune=True) re-measures a cached heuristic plan instead
    of returning it, and the measured result sticks."""
    m, n, k = 16, 16, 32
    p0 = plan_gemm(m, n, k, fmt=FP32, spec=SPEC)
    assert p0.source == "heuristic"
    p1 = plan_gemm(m, n, k, fmt=FP32, spec=SPEC, autotune=True)
    assert p1.source == "measured"
    p2 = plan_gemm(m, n, k, fmt=FP32, spec=SPEC, autotune=True)
    assert p2 == p1                       # measured entry is not re-measured


def test_plan_cache_hits_and_override(rng):
    st0 = plan_cache_stats()
    p1 = plan_gemm(64, 64, 256, fmt=FP32, spec=SPEC)
    p2 = plan_gemm(64, 64, 256, fmt=FP32, spec=SPEC)
    assert p1 == p2
    st1 = plan_cache_stats()
    assert st1.hits >= st0.hits + 1
    # an explicit plan override is honored end-to-end
    A = jnp.asarray(rng.standard_normal((9, 33)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((33, 7)), jnp.float32)
    with use_policy(_pallas_policy()):
        got = gemm(A, B, site="t", plan=GemmPlan(8, 8, 16))
    ref = fdp.fdp_gemm(A, B, SPEC, FP32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
