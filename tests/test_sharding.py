"""Sharding rules: every parameter/cache leaf of every assigned architecture
gets a valid PartitionSpec (sharded dims divisible by their mesh axes) under
every profile — the static half of what the dry-run proves by compiling."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, all_arch_names
from repro.launch.mesh import abstract_mesh
from repro.launch.sharding import param_specs
from repro.models import transformer as T

MESH = abstract_mesh((16, 16), ("data", "model"))
AXIS = dict(MESH.shape)
AXIS_MP = {"pod": 2, **AXIS}


def _check_tree(specs, shapes, axis_sizes):
    def visit(spec, leaf):
        assert isinstance(spec, P), spec
        assert len(spec) <= leaf.ndim, (spec, leaf.shape)
        for d, s in enumerate(spec):
            if s is None:
                continue
            axes = s if isinstance(s, tuple) else (s,)
            n = 1
            for a in axes:
                n *= axis_sizes[a]
            assert leaf.shape[d] % n == 0, \
                f"dim {d} ({leaf.shape[d]}) not divisible by {axes} ({n})"

    jax.tree.map(visit, specs, shapes,
                 is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", all_arch_names())
@pytest.mark.parametrize("profile", ["fsdp", "ddp", "decode_tp"])
def test_param_specs_divisible(arch, profile):
    cfg = get_config(arch)
    aparams = T.init_abstract(cfg)
    specs = param_specs(cfg, aparams, profile=profile, mesh=MESH)
    # same tree structure
    assert jax.tree.structure(
        jax.tree.map(lambda _: 0, aparams)) == jax.tree.structure(
        jax.tree.map(lambda _: 0, specs, is_leaf=lambda x: isinstance(x, P)))
    _check_tree(specs, aparams, AXIS)


@pytest.mark.parametrize("arch", ["grok-1-314b", "mamba2-1.3b",
                                  "zamba2-2.7b", "whisper-large-v3"])
def test_cache_structs_buildable(arch):
    """init_cache builds an abstract cache for every family (no allocation)."""
    cfg = get_config(arch)
    cache = jax.eval_shape(lambda: T.init_cache(cfg, 8, 128))
    assert "len" in cache
    n_leaves = len(jax.tree.leaves(cache))
    assert n_leaves >= 3


def test_input_specs_public_api():
    from repro.launch.dryrun import input_specs
    b = input_specs("llama3.2-3b", "train_4k")
    assert b["tokens"].shape == (256, 4096)
    b = input_specs("whisper-large-v3", "prefill_32k")
    assert b["frames"].shape == (32, 1500, 1280)
    b = input_specs("paligemma-3b", "train_4k")
    assert b["tokens"].shape[1] + b["patches"].shape[1] == 4096
