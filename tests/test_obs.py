"""repro.obs — unified metrics registry, trace spans, and live
calibration-envelope monitors.

The monitor tests drive the real dispatch trace-hook seam: synthetic
envelopes prove the inside / near-edge / violated classification, a jitted
GEMM proves monitoring never retraces (the staged-callback contract), and
the acceptance test loads the checked-in paper_mlp plan's envelope and shows
an injected out-of-envelope dispatch flips exactly the named site to
``violated`` while ordinary traffic stays ``inside``.
"""

import json
import os
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.obs import (MetricError, Registry, chrome_trace, current_span,
                       span, start_span)
from repro.obs.monitor import (INSIDE, NEAR_EDGE, UNMONITORED, VIOLATED,
                               NumericsMonitor, monitoring)
from repro.obs.spans import recorder

PLANS_DIR = os.path.join(os.path.dirname(__file__), "..", "examples", "plans")


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_counter_gauge_histogram_roundtrip():
    reg = Registry()
    c = reg.counter("repro_x_total", "things", ("kind",))
    c.inc(kind="a")
    c.inc(2, kind="b")
    g = reg.gauge("repro_y", "level")
    g.set(4.5)
    h = reg.histogram("repro_z_seconds", "latency")
    h.observe(0.01)
    h.observe(2.0)

    snap = json.loads(json.dumps(reg.snapshot()))   # JSON round-trip
    assert snap["kind"] == "repro.obs.MetricsSnapshot"
    by_name = snap["metrics"]
    assert by_name["repro_x_total"]["kind"] == "counter"
    vals = {tuple(sorted(s["labels"].items())): s["value"]
            for s in by_name["repro_x_total"]["values"]}
    assert vals[(("kind", "a"),)] == 1 and vals[(("kind", "b"),)] == 2
    assert by_name["repro_y"]["values"][0]["value"] == 4.5
    hsample = by_name["repro_z_seconds"]["values"][0]
    assert hsample["count"] == 2 and hsample["sum"] == pytest.approx(2.01)
    assert hsample["buckets"]["+Inf"] == 2

    text = reg.exposition()
    assert '# TYPE repro_x_total counter' in text
    assert 'repro_x_total{kind="a"} 1' in text
    assert 'repro_z_seconds_count 2' in text

    assert c.total() == 3.0
    reg.reset()
    assert c.total() == 0.0 and c.value(kind="a") == 0.0   # handles survive


def test_registry_rejects_mismatched_redeclaration():
    reg = Registry()
    reg.counter("repro_m_total", "x", ("a",))
    with pytest.raises(MetricError):
        reg.gauge("repro_m_total", "x", ("a",))         # kind mismatch
    with pytest.raises(MetricError):
        reg.counter("repro_m_total", "x", ("b",))       # label mismatch
    with pytest.raises(MetricError):
        reg.counter("repro_m_total", "x", ("a",)).inc(-1)   # negative inc


# ---------------------------------------------------------------------------
# spans + chrome trace export
# ---------------------------------------------------------------------------
def test_span_nesting_and_chrome_trace_validity():
    recorder().clear()
    with span("serving.outer", plan="p") as outer:
        assert current_span() is outer
        with span("serving.inner"):
            assert current_span().name == "serving.inner"
        assert current_span() is outer
    sp = start_span("train.lifecycle", uid=7)
    assert current_span() is None          # manual spans stay off the stack
    sp.end(status="done")
    sp.end()                               # idempotent: recorded once

    events = recorder().events()
    names = [e["name"] for e in events]
    assert names == ["serving.inner", "serving.outer", "train.lifecycle"]

    doc = json.loads(json.dumps(chrome_trace()))     # valid JSON
    assert doc["displayTimeUnit"] == "ms"
    assert len(doc["traceEvents"]) == 3
    for ev in doc["traceEvents"]:
        assert ev["ph"] == "X" and ev["dur"] >= 0 and ev["ts"] >= 0
    by_name = {e["name"]: e for e in doc["traceEvents"]}
    assert by_name["serving.outer"]["cat"] == "serving"
    assert by_name["train.lifecycle"]["args"] == {"uid": 7, "status": "done"}
    # inner nests inside outer on the timeline
    o, i = by_name["serving.outer"], by_name["serving.inner"]
    assert o["ts"] <= i["ts"] and i["ts"] + i["dur"] <= o["ts"] + o["dur"]


# ---------------------------------------------------------------------------
# monitor classification vs a synthetic envelope
# ---------------------------------------------------------------------------
def _env(msb=127, lsb=None, a=(-8, 2), b=(-8, 2)):
    return {"version": 1, "sites": {"s": {
        "a_exp": list(a), "b_exp": list(b), "out_exp": [None, None],
        "msb": msb, "lsb": lsb, "calls": 4, "max_k": 8}}}


def _drive(mon, scale_a=1.0, scale_b=1.0):
    from repro.core import dispatch
    with mon:
        out = dispatch.gemm(scale_a * jnp.ones((4, 8), jnp.float32),
                            scale_b * jnp.ones((8, 4), jnp.float32),
                            site="s")
        jax.block_until_ready(out)
    return mon


def test_monitor_inside_on_calibration_like_traffic():
    mon = _drive(NumericsMonitor(_env(), registry=Registry()), 0.5, 0.5)
    info = mon.status("s")
    assert info["status"] == INSIDE
    assert mon.worst_status() == INSIDE and mon.overflow_events() == 0
    assert info["live"]["calls"] == 1 and info["live"]["max_k"] == 8


def test_monitor_near_edge_on_exponent_drift():
    # operands at 2^10 vs traced a_exp hi of 2 (+2 grace): near-edge, and the
    # detail names the excursion
    mon = _drive(NumericsMonitor(_env(), registry=Registry()), 2.0 ** 10, 0.5)
    info = mon.status("s")
    assert info["status"] == NEAR_EDGE
    assert "traced range" in info["detail"]


def test_monitor_low_side_drift_only_flags_fixed_point():
    # tiny operands: harmless on a native site (lsb None) ...
    mon = _drive(NumericsMonitor(_env(), registry=Registry()),
                 2.0 ** -20, 2.0 ** -20)
    assert mon.status("s")["status"] == INSIDE
    # ... but on a fixed-point site they risk quantizing to zero
    mon = _drive(NumericsMonitor(_env(lsb=-30), registry=Registry()),
                 2.0 ** -20, 2.0 ** -20)
    assert mon.status("s")["status"] == NEAR_EDGE


def test_monitor_violated_when_msb_capacity_exceeded():
    # envelope says the deployed accumulator caps at msb=20; live traffic
    # needs ~2*14+growth bits
    mon = _drive(NumericsMonitor(_env(msb=20), registry=Registry()),
                 2.0 ** 14, 2.0 ** 14)
    info = mon.status("s")
    assert info["status"] == VIOLATED
    assert "exceeds deployed capacity 20" in info["detail"]


def test_monitor_nonfinite_counts_overflow_event():
    reg = Registry()
    mon = _drive(NumericsMonitor(_env(), registry=reg), 2.0 ** 70, 2.0 ** 70)
    assert mon.status("s")["status"] == VIOLATED
    assert mon.overflow_events() >= 1
    counted = reg.counter(
        "repro_overflow_events_total", "", ("site", "source"))
    assert counted.value(site="s", source="gemm_nonfinite") == 1


def test_monitor_alert_sink_fires_once_per_escalation():
    fired = []
    mon = NumericsMonitor(_env(msb=20), registry=Registry(),
                          alert_sink=lambda s, status, info:
                          fired.append((s, status)))
    _drive(mon, 2.0 ** 14, 2.0 ** 14)
    _drive(mon, 2.0 ** 14, 2.0 ** 14)      # same level: no second alert
    assert fired == [("s", VIOLATED)]


def test_monitor_unenveloped_site_reports_no_envelope():
    mon = _drive(NumericsMonitor(None, registry=Registry()), 1.0, 1.0)
    assert mon.status("s")["status"] == UNMONITORED


def test_monitor_does_not_retrace():
    from repro.core import dispatch
    reg = Registry()
    mon = NumericsMonitor(_env(), registry=reg)
    traces = []

    @jax.jit
    def f(a, b):
        traces.append(1)                  # python side effect: trace only
        return dispatch.gemm(a, b, site="s")

    with mon:
        for i in range(3):
            jax.block_until_ready(
                f(jnp.ones((4, 8)) * (0.5 + i * 0.1), jnp.ones((8, 4))))
    assert len(traces) == 1               # staged callback, no retrace
    calls = reg.counter("repro_monitor_calls_total", "", ("site",))
    assert calls.value(site="s") == 3     # ...but every execution recorded


def test_monitor_coexists_with_calibration():
    # a monitor stays installed across a set_trace_hook set/restore pair
    from repro.core import dispatch
    reg = Registry()
    mon = NumericsMonitor(_env(), registry=reg).install()
    try:
        prev = dispatch.set_trace_hook(lambda *a: None)
        dispatch.set_trace_hook(prev)
        jax.block_until_ready(dispatch.gemm(
            jnp.ones((4, 8)), jnp.ones((8, 4)), site="s"))
        jax.effects_barrier()
    finally:
        mon.uninstall()
    calls = reg.counter("repro_monitor_calls_total", "", ("site",))
    assert calls.value(site="s") == 1


# ---------------------------------------------------------------------------
# acceptance: the checked-in paper_mlp envelope catches an injected
# out-of-envelope dispatch and names the site
# ---------------------------------------------------------------------------
def test_paper_mlp_envelope_violation_names_site():
    from repro.numerics import load_plan
    plan = load_plan(os.path.join(PLANS_DIR, "paper_mlp.json"))
    env = plan.meta["envelope"]
    assert env["sites"], "checked-in plan must carry an envelope"
    site = "attn_qk"
    assert site in env["sites"]

    pol = plan.to_policy()
    from repro.core import dispatch
    with monitoring(plan, registry=Registry()) as mon:
        # calibration-like traffic: inside
        jax.block_until_ready(dispatch.gemm(
            0.5 * jnp.ones((4, 8), jnp.float32),
            0.5 * jnp.ones((8, 4), jnp.float32), site=site, policy=pol))
        jax.effects_barrier()
        assert mon.status(site)["status"] == INSIDE
        # injected out-of-envelope dispatch: violated, and only this site
        jax.block_until_ready(dispatch.gemm(
            jnp.full((4, 8), 2.0 ** 70, jnp.float32),
            jnp.full((8, 4), 2.0 ** 70, jnp.float32), site=site, policy=pol))
    info = mon.status(site)
    assert info["status"] == VIOLATED and info["site"] == site
    assert mon.worst_status() == VIOLATED
    assert mon.overflow_events() >= 1
    others = {s: i["status"] for s, i in mon.statuses().items()
              if s != site and i["live"] is not None}
    assert all(st == INSIDE for st in others.values())
    snap = json.loads(json.dumps(mon.snapshot()))    # JSON-able
    assert snap["worst_status"] == VIOLATED


# ---------------------------------------------------------------------------
# plan-cache stats migrated onto the registry (deprecated view intact)
# ---------------------------------------------------------------------------
def test_plan_cache_stats_is_registry_view():
    from repro.core import dispatch
    dispatch.clear_plan_cache()
    st0 = dispatch.plan_cache_stats()
    assert st0.hits == 0 and st0.size == 0
    spec = dispatch.AccumulatorSpec(ovf=30, msb=30, lsb=-30)
    dispatch.plan_gemm(16, 16, 32, fmt=dispatch.FP32, spec=spec)   # miss
    dispatch.plan_gemm(16, 16, 32, fmt=dispatch.FP32, spec=spec)   # hit
    st1 = dispatch.plan_cache_stats()
    assert st1.misses == 1 and st1.hits == 1 and st1.size == 1
    from repro.obs import default_registry
    ops = default_registry().counter(
        "repro_plan_cache_ops_total", "", ("op",))
    assert ops.value(op="misses") == st1.misses     # same numbers, one source
    assert ops.value(op="hits") == st1.hits
    dispatch.clear_plan_cache()
    assert dispatch.plan_cache_stats().size == 0


# ---------------------------------------------------------------------------
# validate_overflow ergonomics (collectives satellite)
# ---------------------------------------------------------------------------
def test_validate_overflow_names_site_and_counts():
    from repro.obs import default_registry
    from repro.parallel.collectives import _grid_quantize, validate_overflow
    c = default_registry().counter(
        "repro_overflow_events_total", "", ("site", "source"))
    before = c.value(site="obs_test@coll", source="collective")
    with validate_overflow():
        with pytest.raises(OverflowError, match="obs_test@coll"):
            _grid_quantize(jnp.array([1e9]), -16, 16, site="obs_test@coll")
    assert c.value(site="obs_test@coll", source="collective") == before + 1


def test_validate_overflow_warn_mode_does_not_raise():
    from repro.parallel.collectives import _grid_quantize, validate_overflow
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        with validate_overflow(mode="warn"):
            q = _grid_quantize(jnp.array([1e9]), -16, 16,
                               site="obs_warn@coll")
            jax.block_until_ready(q)
    assert int(q[0]) == 2 ** 15 - 1               # clipped, not crashed
    assert any("obs_warn@coll" in str(x.message) for x in w)
    with pytest.raises(ValueError, match="mode"):
        with validate_overflow(mode="explode"):
            pass
