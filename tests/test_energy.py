"""core.energy: the fitted VU3P power model must reproduce the paper's three
measured wattage anchors exactly and behave monotonically in datapath size
(the properties the numerics search's energy axis depends on)."""

import pytest

from repro.core import AccumulatorSpec, BF16, FP16, FP32
from repro.core.energy import (FREQ_HZ, PAPER_POINTS, fdp_power, fma_power,
                               gemm_power, spec_power, tpu_fdp_pj_per_mac)


def test_reproduces_paper_wattage_anchors():
    """fp64 FMA 0.266 W, fp128 FMA 0.549 W, 91-bit FDP 0.491 W."""
    assert fma_power(53).watts == pytest.approx(0.266, rel=1e-6)
    assert fma_power(113).watts == pytest.approx(0.549, rel=1e-6)
    assert fdp_power(53, 91).watts == pytest.approx(0.491, rel=1e-6)
    for name, (model_w, paper_w) in PAPER_POINTS.items():
        assert model_w == pytest.approx(paper_w, rel=1e-6), name


def test_fdp_power_monotone_in_accumulator_width():
    widths = [16, 24, 40, 64, 91, 128, 256, 512]
    for p in (8, 11, 24, 53):
        watts = [fdp_power(p, w).watts for w in widths]
        assert watts == sorted(watts)
        assert all(w2 > w1 for w1, w2 in zip(watts, watts[1:]))


def test_spec_power_monotone_through_accumulator_specs():
    specs = [AccumulatorSpec(4, 8, lsb) for lsb in (0, -16, -40, -80)]
    watts = [spec_power(FP32, s).watts for s in specs]
    assert all(w2 > w1 for w1, w2 in zip(watts, watts[1:]))


def test_power_monotone_in_input_precision():
    for mk in (lambda p: fma_power(p), lambda p: fdp_power(p, 91)):
        watts = [mk(f.precision).watts for f in (BF16, FP16, FP32)]
        assert watts == sorted(watts)
        assert watts[0] < watts[-1]


def test_gemm_power_selects_datapath_family():
    spec = AccumulatorSpec.paper_91bit()
    assert gemm_power(FP32, None).watts == fma_power(FP32.precision).watts
    assert gemm_power(FP32, spec).watts == fdp_power(FP32.precision,
                                                     spec.width).watts


def test_energy_scales_linearly_with_macs():
    rep = fdp_power(24, 64)
    one = rep.energy_joules(1)
    assert one == pytest.approx(rep.watts / FREQ_HZ)
    assert rep.energy_joules(1000) == pytest.approx(1000 * one)
    assert rep.energy_joules(1000, macs_per_cycle=4) == \
        pytest.approx(250 * one)


def test_tpu_model_monotone_in_limbs():
    pjs = [tpu_fdp_pj_per_mac(24, n) for n in (1, 2, 4, 8)]
    assert all(b > a for a, b in zip(pjs, pjs[1:]))
