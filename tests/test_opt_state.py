"""Quantized optimizer state + low-bit collectives: the byte-tailoring tier.

Claims under test:
  * block-scaled quantize -> dequantize round trips within one grid step per
    element, bit-identically between eager and jit (power-of-two scales keep
    every step exactly representable in f32);
  * a quantized-Adam step's *carriers* (the int payload + exponents that
    persist between steps) are bit-equal eager vs jit, and the resident
    bytes really shrink to <= 50% of the fp32 moments;
  * the second-moment safety contract: nu is stored in sqrt domain, rounded
    up, so the dequantized denominator never understates curvature and a
    quantized step never amplifies an update into a detonation;
  * ``quantized_psum`` error feedback carries the rounding residual across
    steps so the time-average of what was sent converges onto the signal.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import qformat
from repro.core.qformat import (FP32_STATE, QuantConfig, block_dequantize,
                                block_quantize, parse_quant, quant_bytes,
                                quantize_roundtrip, site_kind)
from repro.train.optimizer import (adamw, apply_updates, optimizer_state_bytes,
                                   state_quant_from_policy)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def _bits(tree):
    return [np.asarray(x).view(np.uint32) if np.asarray(x).dtype == np.float32
            else np.asarray(x) for x in jax.tree.leaves(tree)]


def _tree_bit_equal(a, b):
    for x, y in zip(_bits(a), _bits(b)):
        np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# Format round trips
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cfg", [QuantConfig(4, 32), QuantConfig(8, 64),
                                 QuantConfig(16, 64), QuantConfig(8, 32)])
def test_roundtrip_error_within_one_grid_step(rng, cfg):
    # mix scales across blocks so per-block exponents genuinely differ
    x = (rng.standard_normal(1000) *
         np.exp2(rng.integers(-12, 12, size=1000))).astype(np.float32)
    got = np.asarray(quantize_roundtrip(jnp.asarray(x), cfg))
    blocks = np.pad(x, (0, (-x.size) % cfg.block)).reshape(-1, cfg.block)
    amax = np.abs(blocks).max(axis=1)
    step = np.exp2(np.ceil(np.log2(np.maximum(amax, 1e-30))) - (cfg.bits - 1))
    err = np.abs(np.pad(got - x, (0, (-x.size) % cfg.block))
                 ).reshape(-1, cfg.block)
    # <= one grid step, where top-heavy blocks carry the block_scale octave
    # bump (no-clip guarantee), doubling their step
    assert (err <= 2 * step[:, None] + 1e-30).all()


def test_roundtrip_eager_vs_jit_bit_equal(rng):
    cfg = QuantConfig(8, 64)
    x = jnp.asarray(rng.standard_normal(513), jnp.float32)
    eager = quantize_roundtrip(x, cfg)
    jitted = jax.jit(lambda v: quantize_roundtrip(v, cfg))(x)
    np.testing.assert_array_equal(np.asarray(eager).view(np.uint32),
                                  np.asarray(jitted).view(np.uint32))


def test_zero_and_fp32_identity(rng):
    cfg = QuantConfig(8, 64)
    z = jnp.zeros(130, jnp.float32)
    np.testing.assert_array_equal(np.asarray(quantize_roundtrip(z, cfg)), 0.0)
    x = jnp.asarray(rng.standard_normal(17), jnp.float32)
    np.testing.assert_array_equal(np.asarray(quantize_roundtrip(x, FP32_STATE)),
                                  np.asarray(x))


def test_round_up_never_understates(rng):
    cfg = QuantConfig(8, 64)
    x = jnp.asarray(np.abs(rng.standard_normal(512)).astype(np.float32)
                    * np.exp2(rng.integers(-20, 0, 512).astype(np.float32)))
    car = block_quantize(x, cfg, rounding="up")
    got = block_dequantize(car, cfg, x.shape)
    # magnitudes round away from zero: nothing positive lands below itself
    # (up to the one-sided clip at the top of the signed range)
    lim_hit = np.asarray(car["q"]) == 2 ** (cfg.bits - 1) - 1
    slack = np.asarray(got).reshape(-1) - np.asarray(x)
    blocks_hit = lim_hit.any(axis=1)
    mask = ~np.repeat(blocks_hit, cfg.block)[: x.size]
    assert (slack[mask] >= -1e-30).all()


def test_parse_and_bytes():
    assert parse_quant("8x64") == QuantConfig(8, 64)
    assert parse_quant("4x32+ef") == QuantConfig(4, 32, error_feedback=True)
    assert parse_quant("fp32").mode == "fp32"
    with pytest.raises(ValueError):
        parse_quant("banana")
    assert quant_bytes(64, QuantConfig(8, 64)) == 65.0       # 64 int8 + 1 exp
    assert quant_bytes(64, FP32_STATE) == 256.0
    assert QuantConfig(8, 64).bytes_per_element < 4.0 / 2    # < 50% of fp32
    assert site_kind("opt.m@state") == "state"
    assert site_kind("grad_psum@coll") == "collective"
    assert site_kind("attn_qk@bwd.dA") == "gemm"


# ---------------------------------------------------------------------------
# Quantized Adam
# ---------------------------------------------------------------------------
def _toy_params(rng):
    return {"w": jnp.asarray(rng.standard_normal((16, 8)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((8,)), jnp.float32)}


def _toy_grads(rng, params):
    return jax.tree.map(
        lambda p: jnp.asarray(rng.standard_normal(p.shape), jnp.float32) * 0.1,
        params)


def test_quantized_adam_carriers_eager_vs_jit_bit_equal(rng):
    squant = {"mu": QuantConfig(8, 64), "nu": QuantConfig(8, 64)}
    opt = adamw(1e-3, state_quant=squant)
    params = _toy_params(rng)
    grads = _toy_grads(rng, params)

    def step(p, s, g):
        u, s = opt.update(g, s, p)
        return apply_updates(p, u), s

    s0 = opt.init(params)
    p_e, s_e = step(params, s0, grads)
    p_j, s_j = jax.jit(step)(params, opt.init(params), grads)
    # the persistent carriers (int payload + exponents) are bit-equal; the
    # float updates themselves inherit a known 1-ulp eager/jit drift from
    # XLA's reassociation of the fp32 Adam division chain (present in the
    # fp32-state baseline too), so params get a tight tolerance instead
    _tree_bit_equal(s_e["mu"], s_j["mu"])
    _tree_bit_equal(s_e["nu"], s_j["nu"])
    for a, b in zip(jax.tree.leaves(p_e), jax.tree.leaves(p_j)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-8)


def test_quantized_state_bytes_under_half_of_fp32(rng):
    params = _toy_params(rng)
    grads = _toy_grads(rng, params)
    fp = adamw(1e-3)
    q = adamw(1e-3, state_quant={"mu": QuantConfig(8, 64),
                                 "nu": QuantConfig(8, 64)})
    s_fp, s_q = fp.init(params), q.init(params)
    # measure after a real step so carriers hold real data, not init zeros
    _, s_fp = fp.update(grads, s_fp, params)
    _, s_q = q.update(grads, s_q, params)
    assert optimizer_state_bytes(s_q) <= 0.5 * optimizer_state_bytes(s_fp)


def test_quantized_adam_tracks_fp32_and_never_detonates(rng):
    """The second-moment safety contract end to end: 8-bit state tracks the
    fp32-state trajectory closely and no step amplifies into a blow-up
    (the failure mode sqrt-domain round-up nu exists to prevent)."""
    params = _toy_params(rng)
    fp = adamw(1e-2)
    q = adamw(1e-2, state_quant={"mu": QuantConfig(8, 64),
                                 "nu": QuantConfig(8, 64)})
    p_fp, s_fp = params, fp.init(params)
    p_q, s_q = params, q.init(params)
    g_rng = np.random.default_rng(1)
    for _ in range(10):
        grads = _toy_grads(g_rng, params)
        u_fp, s_fp = fp.update(grads, s_fp, p_fp)
        p_fp = apply_updates(p_fp, u_fp)
        u_q, s_q = q.update(grads, s_q, p_q)
        p_q = apply_updates(p_q, u_q)
        for a, b in zip(jax.tree.leaves(u_q), jax.tree.leaves(u_fp)):
            a, b = np.asarray(a), np.asarray(b)
            assert np.isfinite(a).all()
            # quantized updates stay the same magnitude as fp32-state ones —
            # a nu-rounds-to-zero detonation would be orders off
            assert np.abs(a).max() <= 10 * np.abs(b).max() + 1e-12
    for a, b in zip(jax.tree.leaves(p_q), jax.tree.leaves(p_fp)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=5e-3)


def test_state_quant_from_policy(rng):
    from repro.core.dispatch import MXU_FP32
    assert state_quant_from_policy(MXU_FP32) is None
    pol = (MXU_FP32.with_aux("opt.m@state", QuantConfig(8, 64))
                   .with_aux("opt.v@state", QuantConfig(8, 32))
                   .with_aux("grad_psum@coll", QuantConfig(4, 32)))
    sq = state_quant_from_policy(pol)
    assert sq == {"mu": QuantConfig(8, 64), "nu": QuantConfig(8, 32)}
    # fp32 aux entries are "unlisted"
    pol2 = MXU_FP32.with_aux("opt.m@state", FP32_STATE)
    assert state_quant_from_policy(pol2) is None


# ---------------------------------------------------------------------------
# Error feedback (single-device quantized_psum path)
# ---------------------------------------------------------------------------
def test_error_feedback_residual_carries(rng):
    from repro.parallel.collectives import quantized_psum

    cfg = QuantConfig(4, 32)
    x = jnp.asarray(rng.standard_normal(256), jnp.float32)

    # a 1-device mesh: psum over a singleton axis is identity, so the whole
    # quantize -> reduce -> dequantize pipeline runs with exact bookkeeping
    from jax.sharding import Mesh
    import jax.experimental.shard_map as shard_map

    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))
    f = shard_map.shard_map(
        lambda v, r: quantized_psum(v, "d", cfg, residual=r),
        mesh=mesh,
        in_specs=(jax.sharding.PartitionSpec(), jax.sharding.PartitionSpec()),
        out_specs=(jax.sharding.PartitionSpec(),
                   jax.sharding.PartitionSpec()))

    r = jnp.zeros_like(x)
    sent_sum = jnp.zeros_like(x)
    for _ in range(8):
        out, r = f(x, r)
        # the residual is exactly the part of (x + old residual) the grid
        # could not represent: sent + new residual == signal fed in
        sent_sum = sent_sum + out
    np.testing.assert_allclose(np.asarray(sent_sum + r),
                               8 * np.asarray(x), rtol=0, atol=1e-4)
    # time-average of what was sent converges onto the true signal far
    # tighter than a single 4-bit round trip
    avg = np.asarray(sent_sum) / 8
    one_shot = np.asarray(quantize_roundtrip(x, cfg))
    err_avg = np.abs(avg - np.asarray(x)).max()
    err_one = np.abs(one_shot - np.asarray(x)).max()
    assert err_avg < 0.5 * err_one


def test_quantized_psum_overflow_guard(rng):
    """validate_overflow(): an error-feedback spillover that saturates the
    integer payload fires the guard instead of silently clipping."""
    from repro.parallel.collectives import quantized_psum, validate_overflow

    cfg = QuantConfig(4, 32)
    x = jnp.asarray(rng.standard_normal(64), jnp.float32)
    # a residual far larger than x: the payload grid is sized from x alone,
    # so quantizing x + residual overflows the 4-bit range
    big_r = 100.0 * jnp.ones_like(x)

    from jax.sharding import Mesh
    import jax.experimental.shard_map as shard_map
    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))
    f = shard_map.shard_map(
        lambda v, r: quantized_psum(v, "d", cfg, residual=r),
        mesh=mesh,
        in_specs=(jax.sharding.PartitionSpec(), jax.sharding.PartitionSpec()),
        out_specs=(jax.sharding.PartitionSpec(),
                   jax.sharding.PartitionSpec()))
    # benign without the guard (clips), fatal with it
    out, _ = f(x, jnp.zeros_like(x))
    assert np.isfinite(np.asarray(out)).all()
    with validate_overflow():
        with pytest.raises(Exception):
            jax.block_until_ready(f(x, big_r))
