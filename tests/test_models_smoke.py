"""Per-architecture smoke tests: reduced config, one forward + one train step
on CPU, asserting shapes and finiteness; decode parity where applicable."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, all_arch_names
from repro.core.dispatch import use_policy, MXU_FP32
from repro.models import (LOCAL, decode_step, forward, init, init_cache,
                          prefill)

ARCHS = all_arch_names()


def _batch(cfg, B=2, S=16, key=1):
    batch = {"tokens": jax.random.randint(jax.random.key(key), (B, S), 0,
                                          cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            jax.random.key(key + 1), (B, cfg.n_patches, cfg.d_model))
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.key(key + 1), (B, cfg.enc_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = get_config(arch).reduced()
    params = init(cfg, jax.random.key(0))
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    logits = forward(params, cfg, batch, LOCAL, remat="none")
    S_total = S + (cfg.n_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_total, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    # padded vocab entries masked
    if cfg.padded_vocab != cfg.vocab_size:
        assert bool((logits[..., cfg.vocab_size:] == -jnp.inf).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    from repro.train.loop import make_train_step
    from repro.train.optimizer import adamw
    cfg = get_config(arch).reduced()
    params = init(cfg, jax.random.key(0))
    opt = adamw(lr=1e-3)
    opt_state = opt.init(params)
    step_fn = make_train_step(cfg, opt, dist=LOCAL, remat="none",
                              donate=False)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    batch["targets"] = jax.random.randint(jax.random.key(9), (B, S), 0,
                                          cfg.vocab_size)
    batch["loss_mask"] = jnp.ones((B, S), jnp.float32)
    (params2, opt_state2), metrics = step_fn((params, opt_state), batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, x: a + float(jnp.abs(x).sum()),
        jax.tree.map(lambda a, b: a - b, params, params2), 0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if a not in ("paligemma-3b",)])
def test_decode_parity(arch):
    """Incremental decode == full forward (fp32 policy to avoid routing
    tie-flips under bf16)."""
    cfg = get_config(arch).reduced()
    params = init(cfg, jax.random.key(0))
    B, S = 2, 10
    batch = _batch(cfg, B, S)
    toks = batch["tokens"]
    with use_policy(MXU_FP32):
        full = forward(params, cfg, batch, LOCAL, remat="none")
        cache = init_cache(cfg, B, max_len=S + 4, dtype=jnp.float32)
        if cfg.family == "encdec":
            last, cache = prefill(params, cfg, batch, cache, LOCAL)
            np.testing.assert_allclose(np.asarray(last),
                                       np.asarray(full[:, -1]),
                                       rtol=1e-4, atol=1e-4)
            return
        inc = []
        for t in range(S):
            lg, cache = decode_step(params, cfg, cache, toks[:, t:t + 1],
                                    LOCAL)
            inc.append(np.asarray(lg[:, 0]))
    inc = np.stack(inc, 1)
    full = np.asarray(full)
    finite = np.isfinite(full)
    np.testing.assert_allclose(inc[finite], full[finite], rtol=2e-4, atol=2e-4)


def test_vlm_prefix_changes_text_logits():
    """The image prefix must influence text logits (prefix-LM wiring)."""
    cfg = get_config("paligemma-3b").reduced()
    params = init(cfg, jax.random.key(0))
    batch = _batch(cfg, 2, 12)
    l1 = forward(params, cfg, batch, LOCAL, remat="none")
    batch2 = dict(batch)
    batch2["patches"] = batch["patches"] + 1.0
    l2 = forward(params, cfg, batch2, LOCAL, remat="none")
    assert float(np.abs(np.asarray(l1[:, -1]) - np.asarray(l2[:, -1])).max()) > 1e-4


def test_int8_kv_cache_decode_close():
    """Quantized (int8 + per-position scale) KV cache: decode logits stay
    close to the full-precision path and mostly agree on top-1 — the paper's
    tailored-storage knob applied to serving."""
    cfg = get_config("qwen3-0.6b").reduced()
    params = init(cfg, jax.random.key(0))
    B, S = 2, 10
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    with use_policy(MXU_FP32):
        full = forward(params, cfg, {"tokens": toks}, LOCAL, remat="none")
        cache = init_cache(cfg, B, max_len=S + 2, dtype=jnp.float32,
                           quantized=True)
        inc = []
        for t in range(S):
            lg, cache = decode_step(params, cfg, cache, toks[:, t:t + 1],
                                    LOCAL)
            inc.append(np.asarray(lg[:, 0]))
    inc = np.stack(inc, 1)
    fullv = np.asarray(full)
    fin = np.isfinite(fullv)
    rel = np.abs(inc[fin] - fullv[fin]).max() / np.abs(fullv[fin]).max()
    assert rel < 0.05
    agree = (inc.argmax(-1) == fullv.argmax(-1)).mean()
    assert agree > 0.85


def test_param_count_sane():
    """Full-config analytical param counts are in the right ballpark."""
    import math
    expect = {"grok-1-314b": 314e9, "dbrx-132b": 132e9, "llama3.2-3b": 3.2e9,
              "mamba2-1.3b": 1.3e9, "qwen3-0.6b": 0.6e9}
    for arch, n in expect.items():
        cfg = get_config(arch)
        got = cfg.param_count()
        assert 0.5 * n < got < 1.9 * n, (arch, got, n)
