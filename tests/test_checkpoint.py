"""Checkpoint store: atomicity, corruption recovery, retention, async save,
and the fault-tolerant Trainer (failure injection -> restore -> exact replay)."""

import json
import os
import shutil

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.store import CheckpointStore
from repro.configs import get_config
from repro.data.synthetic import SyntheticLM
from repro.models import LOCAL, init
from repro.train.loop import InjectedFailure, Trainer, make_train_step
from repro.train.optimizer import adamw


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"a": jax.random.normal(k, (4, 5)),
            "nested": {"b": jnp.arange(7), "c": (jnp.ones(3), jnp.zeros(2))}}


def test_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path))
    t = _tree()
    store.save(3, t)
    step, got = store.load_latest()
    assert step == 3
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), t, got)
    # tuple structure preserved
    assert isinstance(got["nested"]["c"], tuple)


def test_latest_and_retention(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        store.save(s, _tree(s))
    assert store.all_steps() == [3, 4]
    step, got = store.load_latest()
    assert step == 4


def test_corrupt_checkpoint_skipped(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=5)
    store.save(1, _tree(1))
    store.save(2, _tree(2))
    # corrupt the newest
    path = os.path.join(str(tmp_path), "step_00000002", "leaf_0000.npy")
    with open(path, "wb") as f:
        f.write(b"garbage")
    step, got = store.load_latest()
    assert step == 1


def test_async_save(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(7, _tree(7), async_=True)
    store.wait()
    assert store.all_steps() == [7]


def test_trainer_failure_injection_recovers(tmp_path):
    """Crash at step 7 (after checkpoint at 5) -> restore -> identical final
    params to an uninterrupted run (data is a pure function of step)."""
    cfg = get_config("paper-mlp").reduced(
        d_model=32, d_ff=64, n_layers=1, vocab_size=32, n_heads=2,
        n_kv_heads=2, head_dim=16)
    opt = adamw(lr=1e-3)
    step_fn = make_train_step(cfg, opt, LOCAL, remat="none", donate=False)
    ds = SyntheticLM(cfg.vocab_size, 16, 4, seed=0)

    def data(step):
        tb = ds.batch(step)
        return {"tokens": tb.tokens, "targets": tb.targets,
                "loss_mask": tb.loss_mask}

    crashed = {"done": False}

    def injector(step):
        if step == 7 and not crashed["done"]:
            crashed["done"] = True
            raise InjectedFailure("simulated node failure")

    t1 = Trainer(cfg, opt, data, step_fn, str(tmp_path / "a"), save_every=5,
                 failure_injector=injector)
    params_a, _ = t1.run(10)
    assert crashed["done"]

    t2 = Trainer(cfg, opt, data, step_fn, str(tmp_path / "b"), save_every=5)
    params_b, _ = t2.run(10)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     params_a, params_b)
    assert max(jax.tree.leaves(d)) < 1e-6


def test_elastic_restore_resharding(tmp_path):
    """A checkpoint saved unsharded restores onto a (1,1) mesh sharding —
    the mechanism behind elastic rescale (device_put at load)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    store = CheckpointStore(str(tmp_path))
    t = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    store.save(1, t)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = {"w": NamedSharding(mesh, P("data", "model"))}
    step, got = store.load_latest(shardings=sh)
    assert got["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(t["w"]))


def test_straggler_monitor():
    from repro.train.loop import StragglerMonitor
    m = StragglerMonitor(factor=3.0)
    for i in range(10):
        m.record(i, 1.0)
    assert not m.events
    assert m.record(10, 10.0)
    assert m.events and m.events[0][0] == 10
