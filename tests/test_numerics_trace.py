"""repro.numerics calibration tracing: per-site statistics through the
dispatch hook, including under jit/scan."""

import math

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import dispatch
from repro.core.dispatch import MXU_FP32, gemm, use_policy
from repro.numerics import calibrate
from repro.numerics.search import oracle_output


def _operands(seed, m=8, k=64, n=4):
    # private stream: the session-scoped `rng` fixture is shared with the
    # seed tests, and consuming it here would shift their operand draws
    rng = np.random.default_rng(1000 + seed)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    return a, b


def test_calibrate_records_stats():
    a, b = _operands(1)
    with calibrate() as tr, use_policy(MXU_FP32):
        gemm(a, b, site="t_stats")
        gemm(a, b, site="t_stats")
    p = tr.profile("t_stats")
    assert p.calls == 2
    assert p.macs == 2 * 8 * 64 * 4
    assert p.shapes == {(1, 8, 4, 64): 2}
    assert p.max_k == 64
    # N(0,1) data: extreme magnitudes straddle 1.0
    assert p.a_exp_min < 0 <= p.a_exp_max + 1
    assert p.sample_a.shape == (8, 64) and p.sample_b.shape == (64, 4)
    # msb must cover product bound + sum growth
    assert p.msb_required >= p.prod_exp_max + math.ceil(math.log2(64))


def test_calibrate_under_jit_scan():
    """A scanned layer stack reports one call per iteration."""
    a, b = _operands(2)
    with calibrate() as tr, use_policy(MXU_FP32):
        @jax.jit
        def f(a, b):
            def body(c, _):
                return c + gemm(a, b, site="t_scan"), None
            out, _ = jax.lax.scan(body, jnp.zeros((8, 4)), None, length=3)
            return out
        jax.block_until_ready(f(a, b))
    p = tr.profile("t_scan")
    assert p.calls == 3
    assert p.macs == 3 * 8 * 64 * 4


def test_hook_removed_after_context():
    a, b = _operands(3)
    with calibrate() as tr, use_policy(MXU_FP32):
        gemm(a, b, site="t_inside")
    assert dispatch._TRACE_HOOK is None
    with use_policy(MXU_FP32):
        gemm(a, b, site="t_after")
    assert "t_after" not in tr.profiles()


def test_hook_restored_after_exception():
    a, b = _operands(4)
    try:
        with calibrate(), use_policy(MXU_FP32):
            gemm(a, b, site="t_exc")
            raise RuntimeError("boom")
    except RuntimeError:
        pass
    assert dispatch._TRACE_HOOK is None


def test_exact_spec_oracle_matches_f64():
    """The trace-sized exact accumulator reproduces exact math: oracle output
    == f64 matmul rounded once to f32."""
    a, b = _operands(5, m=6, k=96, n=3)
    with calibrate() as tr, use_policy(MXU_FP32):
        gemm(a, b, site="t_oracle")
    p = tr.profile("t_oracle")
    got = oracle_output(p, jnp.asarray(p.sample_a), jnp.asarray(p.sample_b))
    ref = (np.asarray(p.sample_a, np.float64)
           @ np.asarray(p.sample_b, np.float64)).astype(np.float32)
    np.testing.assert_array_equal(got, ref)


def test_condition_proxy_flags_cancellation():
    a = jnp.asarray([[1000.0, -999.9]], jnp.float32)
    b = jnp.asarray([[1.0], [1.0]], jnp.float32)
    with calibrate() as tr, use_policy(MXU_FP32):
        gemm(a, b, site="t_cancel")
    p = tr.profile("t_cancel")
    # bound ~ 1000*1*2 = 2000 vs |out| ~ 0.1 -> ~14 bits of cancellation
    assert p.cancellation_bits > 10.0


def test_grouped_einsums_are_traced():
    from repro.core.dispatch import grouped_qk
    rng = np.random.default_rng(1042)
    q = jnp.asarray(rng.standard_normal((2, 2, 3, 5, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 2, 7, 8)), jnp.float32)
    with calibrate() as tr, use_policy(MXU_FP32):
        grouped_qk(q, k, site="t_qk")
    p = tr.profile("t_qk")
    assert p.calls == 1
    assert p.max_k == 8                       # contraction over head_dim
    assert p.macs == (2 * 2) * (3 * 5) * 7 * 8
