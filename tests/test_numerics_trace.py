"""repro.numerics calibration tracing: per-site statistics through the
dispatch hook, including under jit/scan."""

import math

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import dispatch
from repro.core.dispatch import MXU_FP32, gemm, use_policy
from repro.numerics import calibrate
from repro.numerics.search import oracle_output


def _operands(seed, m=8, k=64, n=4):
    # private stream: the session-scoped `rng` fixture is shared with the
    # seed tests, and consuming it here would shift their operand draws
    rng = np.random.default_rng(1000 + seed)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    return a, b


def test_calibrate_records_stats():
    a, b = _operands(1)
    with calibrate() as tr, use_policy(MXU_FP32):
        gemm(a, b, site="t_stats")
        gemm(a, b, site="t_stats")
    p = tr.profile("t_stats")
    assert p.calls == 2
    assert p.macs == 2 * 8 * 64 * 4
    assert p.shapes == {(1, 8, 4, 64): 2}
    assert p.max_k == 64
    # N(0,1) data: extreme magnitudes straddle 1.0
    assert p.a_exp_min < 0 <= p.a_exp_max + 1
    assert p.sample_a.shape == (8, 64) and p.sample_b.shape == (64, 4)
    # msb must cover product bound + sum growth
    assert p.msb_required >= p.prod_exp_max + math.ceil(math.log2(64))


def test_calibrate_under_jit_scan():
    """A scanned layer stack reports one call per iteration."""
    a, b = _operands(2)
    with calibrate() as tr, use_policy(MXU_FP32):
        @jax.jit
        def f(a, b):
            def body(c, _):
                return c + gemm(a, b, site="t_scan"), None
            out, _ = jax.lax.scan(body, jnp.zeros((8, 4)), None, length=3)
            return out
        jax.block_until_ready(f(a, b))
    p = tr.profile("t_scan")
    assert p.calls == 3
    assert p.macs == 3 * 8 * 64 * 4


def test_repeated_grad_calibrations_do_not_deadlock():
    """Regression: ``_record`` must materialize incoming jax arrays BEFORE
    taking the trace lock. Eager dispatch runs debug callbacks inline on the
    main thread while compiled scan regions deliver theirs on the runtime's
    host-callback worker; a device sync under the lock deadlocks the second
    calibration (observed as refresh_plans hanging on its second arch)."""
    for seed in (30, 31):
        a, b = _operands(seed, m=4, k=16, n=4)
        with calibrate() as tr, use_policy(MXU_FP32):
            @jax.jit
            def f(a, b):
                def body(c, _):
                    return c + gemm(a, b, site="t_lock"), None
                out, _ = jax.lax.scan(body, jnp.zeros((4, 4)), None, length=2)
                return out
            jax.block_until_ready(f(a, b))        # worker-thread callbacks
            jax.block_until_ready(jax.grad(       # eager + bwd callbacks
                lambda x, y: gemm(x, y, site="t_lock").sum(),
                argnums=(0, 1))(a, b))
        assert tr.profile("t_lock").calls == 3
        assert tr.profile("t_lock@bwd.dA").calls == 1
        assert tr.profile("t_lock@bwd.dB").calls == 1


def test_hook_removed_after_context():
    a, b = _operands(3)
    with calibrate() as tr, use_policy(MXU_FP32):
        gemm(a, b, site="t_inside")
    assert dispatch._TRACE_HOOK is None
    with use_policy(MXU_FP32):
        gemm(a, b, site="t_after")
    assert "t_after" not in tr.profiles()


def test_hook_restored_after_exception():
    a, b = _operands(4)
    try:
        with calibrate(), use_policy(MXU_FP32):
            gemm(a, b, site="t_exc")
            raise RuntimeError("boom")
    except RuntimeError:
        pass
    assert dispatch._TRACE_HOOK is None


def test_exact_spec_oracle_matches_f64():
    """The trace-sized exact accumulator reproduces exact math: oracle output
    == f64 matmul rounded once to f32."""
    a, b = _operands(5, m=6, k=96, n=3)
    with calibrate() as tr, use_policy(MXU_FP32):
        gemm(a, b, site="t_oracle")
    p = tr.profile("t_oracle")
    got = oracle_output(p, jnp.asarray(p.sample_a), jnp.asarray(p.sample_b))
    ref = (np.asarray(p.sample_a, np.float64)
           @ np.asarray(p.sample_b, np.float64)).astype(np.float32)
    np.testing.assert_array_equal(got, ref)


def test_condition_proxy_flags_cancellation():
    a = jnp.asarray([[1000.0, -999.9]], jnp.float32)
    b = jnp.asarray([[1.0], [1.0]], jnp.float32)
    with calibrate() as tr, use_policy(MXU_FP32):
        gemm(a, b, site="t_cancel")
    p = tr.profile("t_cancel")
    # bound ~ 1000*1*2 = 2000 vs |out| ~ 0.1 -> ~14 bits of cancellation
    assert p.cancellation_bits > 10.0


def test_grouped_einsums_are_traced():
    from repro.core.dispatch import grouped_qk
    rng = np.random.default_rng(1042)
    q = jnp.asarray(rng.standard_normal((2, 2, 3, 5, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 2, 7, 8)), jnp.float32)
    with calibrate() as tr, use_policy(MXU_FP32):
        grouped_qk(q, k, site="t_qk")
    p = tr.profile("t_qk")
    assert p.calls == 1
    assert p.max_k == 8                       # contraction over head_dim
    assert p.macs == (2 * 2) * (3 * 5) * 7 * 8


def test_ragged_gemm_expert_sites_traced():
    """MoE expert GEMMs report one aggregate call per site: MACs = T*d*f
    (each sorted row hits exactly one expert) and the sample keeps the
    group-0 weight block."""
    from repro.core.dispatch import ragged_gemm
    rng = np.random.default_rng(1043)
    T, d, f, E = 12, 16, 8, 4
    x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((E, d, f)), jnp.float32)
    sizes = jnp.asarray([3, 3, 3, 3], jnp.int32)
    with calibrate() as tr, use_policy(MXU_FP32):
        out = ragged_gemm(x, w, sizes, site="t_ragged")
    p = tr.profile("t_ragged")
    assert p.calls == 1 and p.macs == T * d * f and p.max_k == d
    assert p.sample_b.shape == (d, f)
    np.testing.assert_array_equal(
        p.sample_b, np.asarray(w[0], np.float32))
    ref = np.concatenate([np.asarray(x[i * 3:(i + 1) * 3] @ w[i])
                          for i in range(E)])
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5, atol=1e-5)


def test_ragged_gemm_fdp_matches_grouping():
    """The FDP per-expert reference path routes each row through its own
    expert under the exact accumulator (parity with per-group np matmul)."""
    from repro.core.accumulator import AccumulatorSpec
    from repro.core.dispatch import (GemmConfig, NumericsPolicy, ragged_gemm,
                                     use_policy as up)
    from repro.core.formats import FP32
    rng = np.random.default_rng(1044)
    T, d, f, E = 8, 8, 4, 2
    x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((E, d, f)), jnp.float32)
    sizes = jnp.asarray([5, 3], jnp.int32)
    cfg = GemmConfig(FP32, AccumulatorSpec(ovf=8, msb=12, lsb=-60),
                     "simulate")
    with up(NumericsPolicy(cfg)):
        got = np.asarray(ragged_gemm(x, w, sizes, site="t_ragged_fdp"))
    ref = np.concatenate([
        (np.asarray(x[:5], np.float64) @ np.asarray(w[0], np.float64)),
        (np.asarray(x[5:], np.float64) @ np.asarray(w[1], np.float64)),
    ]).astype(np.float32)
    np.testing.assert_allclose(got, ref, rtol=1e-6, atol=1e-6)
    # padding rows (beyond sum(group_sizes)) belong to no group: the FDP
    # path must zero them exactly like the native ragged_dot path, so a
    # plan flipping a site between backends never changes padded rows
    short = jnp.asarray([3, 2], jnp.int32)                  # 3 padded rows
    with up(NumericsPolicy(cfg)):
        got_pad = np.asarray(ragged_gemm(x, w, short, site="t_ragged_pad"))
    np.testing.assert_array_equal(got_pad[5:], np.zeros((3, f), np.float32))
    np.testing.assert_allclose(
        got_pad[:3],
        (np.asarray(x[:3], np.float64) @ np.asarray(w[0], np.float64)
         ).astype(np.float32), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# persistence: save -> load round trip (the decoupling of calibration from
# search iterations)
# ---------------------------------------------------------------------------

def _traced(seed, site="t_save"):
    a, b = _operands(seed)
    with calibrate() as tr, use_policy(MXU_FP32):
        gemm(a, b, site=site)
        gemm(a, b, site=site)
    return tr


def test_trace_save_load_round_trip(tmp_path):
    from repro.numerics import config_fingerprint, load_trace
    tr = _traced(20)
    fp = config_fingerprint({"model": "t", "batch": 2})
    path = tmp_path / "t.trace.json"
    tr.save(path, fingerprint=fp, meta={"arch": "t"})
    back = load_trace(path, expect_fingerprint=fp)
    p0, p1 = tr.profile("t_save"), back.profile("t_save")
    # per-site stats preserved exactly
    assert p1.calls == p0.calls and p1.macs == p0.macs
    assert p1.shapes == p0.shapes and p1.max_k == p0.max_k
    assert p1.cfg_tags == p0.cfg_tags
    for attr in ("a_abs_max", "a_abs_min_nz", "b_abs_max", "b_abs_min_nz",
                 "out_abs_max", "out_abs_min_nz"):
        assert getattr(p1, attr) == getattr(p0, attr), attr
    assert p1.msb_required == p0.msb_required
    assert p1.exact_spec() == p0.exact_spec()
    # operand samples preserved bit-for-bit with dtype and shape
    assert p1.sample_a.dtype == p0.sample_a.dtype == np.float32
    assert p1.sample_a.shape == p0.sample_a.shape
    np.testing.assert_array_equal(p1.sample_a, p0.sample_a)
    np.testing.assert_array_equal(p1.sample_b, p0.sample_b)
    assert back.fingerprint == fp and back.meta == {"arch": "t"}
    # load -> save with no arguments must not strip provenance
    path2 = tmp_path / "t2.trace.json"
    back.save(path2)
    again = load_trace(path2, expect_fingerprint=fp)
    assert again.fingerprint == fp and again.meta == {"arch": "t"}


def test_trace_load_searchable(tmp_path):
    """A reloaded trace drives the search exactly like the live one."""
    from repro.numerics import load_trace
    from repro.numerics.search import evaluate_candidates
    from repro.numerics.candidates import enumerate_candidates
    tr = _traced(21)
    tr.save(tmp_path / "t.trace.json")
    back = load_trace(tmp_path / "t.trace.json")
    prof_live, prof_back = tr.profile("t_save"), back.profile("t_save")
    cands = enumerate_candidates(prof_live, widths=(32,))
    live = evaluate_candidates(prof_live, cands)
    reload_ = evaluate_candidates(prof_back, cands)
    for e0, e1 in zip(live, reload_):
        assert e0.error_bits == e1.error_bits
        assert e0.energy_j == e1.energy_j


def test_trace_load_rejects_mismatched_fingerprint(tmp_path):
    import pytest
    from repro.numerics import load_trace
    tr = _traced(22)
    path = tmp_path / "t.trace.json"
    tr.save(path, fingerprint="aaaa")
    with pytest.raises(ValueError, match="fingerprint.*recalibrate"):
        load_trace(path, expect_fingerprint="bbbb")
    # no expectation -> loads fine
    assert load_trace(path).fingerprint == "aaaa"


def test_trace_load_rejects_newer_schema(tmp_path):
    import json
    import pytest
    from repro.numerics import TRACE_VERSION, load_trace
    tr = _traced(23)
    path = tmp_path / "t.trace.json"
    tr.save(path)
    doc = json.loads(path.read_text())
    doc["version"] = TRACE_VERSION + 1
    path.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="newer"):
        load_trace(path)


def test_trace_load_rejects_non_trace_document(tmp_path):
    import pytest
    from repro.numerics import load_trace
    path = tmp_path / "not_a_trace.json"
    path.write_text('{"version": 1, "name": "x", "sites": []}')
    with pytest.raises(ValueError, match="not a CalibrationTrace"):
        load_trace(path)
