"""Sorted-segment FDP MoE kernel + the persisted schedule zoo.

The kernel claims: walking contiguous per-expert segments with a scalar-
prefetched weight index map does O(T·d·f) MACs (not the reference path's
T×E) while staying **bit-identical** — exact ⟨ovf,msb,lsb⟩ limb accumulation
is order-invariant, so any blocking/segmentation of the same products reads
out the same float. The zoo claims: schedules persist with fingerprint +
schema versioning and a warm process takes zero autotune misses.
"""

import json
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import AccumulatorSpec, BF16, FP32
from repro.core.dispatch import (GemmConfig, GemmPlan, NumericsPolicy,
                                 clear_plan_cache, plan_cache_stats,
                                 plan_gemm, ragged_gemm, use_policy)
from repro.core.schedules import (SCHEDULE_KIND, ScheduleZoo,
                                  preload_schedules, schedule_fingerprint)
from repro.kernels import ops as kops

SPEC = AccumulatorSpec.paper_91bit()


def _policy(mode, fmt=FP32):
    return NumericsPolicy(GemmConfig(fmt, SPEC, mode), name=f"t_{mode}")


def _bits(x):
    return np.asarray(x).view(np.uint32)


def _run(mode, x, w, gs, fmt=FP32):
    with use_policy(_policy(mode, fmt)):
        return ragged_gemm(x, w, gs, site="t_seg")


# ---------------------------------------------------------------------------
# bit-equality vs the reference grouped path
# ---------------------------------------------------------------------------
# (T, d, f, group_sizes) — sum(gs) < T means padded trailing rows
SEGMENT_CASES = [
    pytest.param(96, 16, 24, [0, 0, 50, 0, 30, 16, 0], id="zeros_everywhere"),
    pytest.param(40, 16, 8, [12, 9, 11], id="padded_rows"),
    pytest.param(24, 300, 8, [24], id="one_expert_multi_kblock"),
    pytest.param(33, 7, 9, [10, 0, 23], id="odd_dims"),
    pytest.param(16, 8, 8, [0, 0, 0, 0], id="all_empty"),
    pytest.param(48, 16, 16, [16, 16, 16], id="even"),
]


@pytest.mark.parametrize("T,d,f,gs", SEGMENT_CASES)
def test_sorted_segment_forward_bit_identical(rng, T, d, f, gs):
    x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((len(gs), d, f)), jnp.float32)
    gs = jnp.asarray(gs, jnp.int32)
    got = _run("pallas", x, w, gs)
    ref = _run("simulate", x, w, gs)
    np.testing.assert_array_equal(_bits(got), _bits(ref))


def test_sorted_segment_bf16_bit_identical(rng):
    T, d, f = 32, 24, 16
    x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, d, f)), jnp.float32)
    gs = jnp.asarray([20, 0, 12], jnp.int32)
    got = _run("pallas", x, w, gs, fmt=BF16)
    ref = _run("simulate", x, w, gs, fmt=BF16)
    np.testing.assert_array_equal(_bits(got), _bits(ref))


def test_sorted_segment_grads_bit_identical(rng):
    """dA (ragged contraction vs transposed weights) and dB (per-expert
    wgrad) through the sorted-segment kernels match the reference-path
    gradients bit for bit — fwd outputs agree exactly, so both modes see
    the same cotangent and order-invariant limb accumulation does the rest."""
    T, d, f = 40, 12, 10
    x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((4, d, f)), jnp.float32)
    gs = jnp.asarray([11, 0, 20, 5], jnp.int32)   # 4 padded rows

    def loss(mode):
        def fn(x, w):
            with use_policy(_policy(mode)):
                return (ragged_gemm(x, w, gs, site="t_seg_grad") ** 2).sum()
        return fn

    gp = jax.grad(loss("pallas"), argnums=(0, 1))(x, w)
    gr = jax.grad(loss("simulate"), argnums=(0, 1))(x, w)
    np.testing.assert_array_equal(_bits(gp[0]), _bits(gr[0]))
    np.testing.assert_array_equal(_bits(gp[1]), _bits(gr[1]))


def test_sorted_segment_under_jit_traced_group_sizes(rng):
    """group_sizes is data, not a static shape: the meta table builds from
    traced values inside jit (scalar prefetch), so routing can change
    between calls without recompiling."""
    T, d, f = 32, 8, 8
    x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, d, f)), jnp.float32)

    @jax.jit
    def run(gs):
        with use_policy(_policy("pallas")):
            return ragged_gemm(x, w, gs, site="t_seg_jit")

    for sizes in ([16, 8, 8], [0, 32, 0], [10, 0, 22]):
        gs = jnp.asarray(sizes, jnp.int32)
        np.testing.assert_array_equal(
            _bits(run(gs)), _bits(_run("simulate", x, w, gs)))


def test_kernel_level_ops_entry_points(rng):
    """kernels.ops.fdp_ragged_gemm / fdp_ragged_dw against hand-built
    grouped references, with an explicit GemmPlan."""
    T, d, f, E = 24, 16, 8, 3
    gs_np = np.array([10, 0, 14])
    x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((E, d, f)), jnp.float32)
    g = jnp.asarray(rng.standard_normal((T, f)), jnp.float32)
    gs = jnp.asarray(gs_np, jnp.int32)
    plan = GemmPlan(8, 8, 16)
    seg = np.repeat(np.arange(E), gs_np)

    out = kops.fdp_ragged_gemm(x, w, gs, spec=SPEC, plan=plan)
    ref = jnp.stack([kops.fdp_gemm(x, w[e], spec=SPEC, plan=plan)
                     for e in range(E)])[seg, np.arange(T)]
    np.testing.assert_array_equal(_bits(out), _bits(ref))

    dw = kops.fdp_ragged_dw(x, g, gs, num_groups=E, spec=SPEC, plan=plan)
    masks = seg[None, :] == np.arange(E)[:, None]
    dw_ref = jnp.stack([
        kops.fdp_gemm(jnp.where(jnp.asarray(m)[:, None], x, 0.0).T, g,
                      spec=SPEC, plan=plan) for m in masks])
    np.testing.assert_array_equal(_bits(dw), _bits(dw_ref))


# ---------------------------------------------------------------------------
# MAC scaling: O(T), not O(T·E)
# ---------------------------------------------------------------------------
def _pallas_grids(jaxpr):
    grids = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            gm = eqn.params.get("grid_mapping")
            grids.append(tuple(gm.grid) if gm is not None
                         else tuple(eqn.params["grid"]))
        for p in eqn.params.values():
            sub = getattr(p, "jaxpr", None)
            if sub is not None and hasattr(sub, "eqns"):
                grids += _pallas_grids(sub)
    return grids


def test_segment_kernel_mac_count_is_linear_in_tokens(rng):
    """The telescoping tile bound: the jaxpr's pallas grid × block volume is
    f·d·(T + (E−1)·bm) — linear in T — while the reference grouped path
    costs E·T·d·f. Asserted on the lowered jaxpr, not on wall time."""
    T, d, f, E, bm = 64, 32, 32, 4, 8
    x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((E, d, f)), jnp.float32)
    gs = jnp.asarray([16, 16, 16, 16], jnp.int32)
    plan = GemmPlan(bm, f, d)

    jaxpr = jax.make_jaxpr(
        lambda x, w, gs: kops.fdp_ragged_gemm(x, w, gs, spec=SPEC,
                                              plan=plan))(x, w, gs)
    grids = _pallas_grids(jaxpr.jaxpr)
    assert len(grids) == 1, f"expected one pallas_call, saw grids {grids}"
    grid = grids[0]
    macs = int(np.prod(grid)) * bm * f * d
    bound = f * d * (T + (E - 1) * bm)
    reference_macs = E * T * d * f
    assert macs == bound, (grid, macs, bound)
    assert macs < reference_macs / 2


# ---------------------------------------------------------------------------
# schedule zoo: persistence, rejection, warm-load zero-miss
# ---------------------------------------------------------------------------
def _tuned_cache():
    clear_plan_cache()
    plans = {(64, 48, 80): plan_gemm(64, 48, 80, fmt=FP32, spec=SPEC),
             (32, 32, 32): plan_gemm(32, 32, 32, fmt=BF16, spec=SPEC)}
    return plans


def test_schedule_zoo_round_trip(tmp_path):
    plans = _tuned_cache()
    zoo = ScheduleZoo.from_cache(meta={"note": "test"})
    path = tmp_path / f"{zoo.backend}.json"
    zoo.save(path)

    doc = json.loads(path.read_text())
    assert doc["kind"] == SCHEDULE_KIND
    assert doc["fingerprint"] == schedule_fingerprint()

    loaded = ScheduleZoo.load(path)
    assert loaded.backend == zoo.backend
    assert loaded.meta["note"] == "test"
    assert {k[1:4] for k in loaded.entries} == {(64, 48, 80), (32, 32, 32)}
    for key, plan in loaded.entries.items():
        assert plan.tile == zoo.entries[key].tile
    clear_plan_cache()


@pytest.mark.parametrize("field,value,msg", [
    ("kind", "bogus", "not a schedule zoo"),
    ("version", 99, "schema version"),
    ("fingerprint", "deadbeef", "fingerprint"),
])
def test_schedule_zoo_rejects(tmp_path, field, value, msg):
    _tuned_cache()
    zoo = ScheduleZoo.from_cache()
    path = tmp_path / "zoo.json"
    zoo.save(path)
    doc = json.loads(path.read_text())
    doc[field] = value
    path.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match=msg):
        ScheduleZoo.load(path)
    if field == "fingerprint":     # explicit bypass for offline inspection
        assert ScheduleZoo.load(path, check_fingerprint=False).entries
    clear_plan_cache()


def test_warm_process_takes_zero_autotune_misses(tmp_path):
    """The zoo's acceptance property: save → cold process (cleared cache) →
    preload → the same plan lookups all hit, misses stays 0."""
    plans = _tuned_cache()
    ScheduleZoo.from_cache().save(tmp_path / "cpu.json")

    clear_plan_cache()                       # "process restart"
    n = preload_schedules(str(tmp_path))
    assert n == 2
    p1 = plan_gemm(64, 48, 80, fmt=FP32, spec=SPEC)
    p2 = plan_gemm(32, 32, 32, fmt=BF16, spec=SPEC)
    assert p1.tile == plans[(64, 48, 80)].tile
    assert p2.tile == plans[(32, 32, 32)].tile
    assert p1.source == "persisted" and p2.source == "persisted"
    st = plan_cache_stats()
    assert st.misses == 0 and st.hits == 2 and st.persisted_loads == 2
    clear_plan_cache()


def test_preload_missing_zoo_is_zero(tmp_path):
    assert preload_schedules(str(tmp_path / "nowhere")) == 0


def test_checked_in_schedule_zoo_loads():
    """The committed cpu.json must always load against the current autotune
    config — a fingerprint drift here means refresh_plans --schedules was
    skipped after changing the candidate set."""
    import os
    path = os.path.join(os.path.dirname(__file__), os.pardir,
                        "examples", "plans", "schedules", "cpu.json")
    zoo = ScheduleZoo.load(path)
    assert zoo.backend == "cpu" and zoo.entries


# ---------------------------------------------------------------------------
# GemmPlan-first API: the deprecation window is closed
# ---------------------------------------------------------------------------
def test_loose_tile_ints_removed(rng):
    """PR-8 deprecated the loose bm/bn/bk ints for one release; they are now
    hard TypeErrors — plan=GemmPlan(...) is the only tiling spelling."""
    a = jnp.asarray(rng.standard_normal((16, 24)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((24, 8)), jnp.float32)
    with pytest.raises(TypeError):
        kops.fdp_gemm(a, b, spec=SPEC, bm=8, bn=8, bk=16)
    with pytest.raises(TypeError):
        kops.fdp_gemm(a, b, spec=SPEC, plan=GemmPlan(8, 8, 16), bm=8)
    with pytest.raises(TypeError):
        kops.fdp_gemm_nd(a, b, spec=SPEC, bk=16)
    # the plan spelling still works and no deprecation chatter remains
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        kops.fdp_gemm(a, b, spec=SPEC, plan=GemmPlan(8, 8, 16))


def test_plan_cache_info_shim_removed():
    """The plan_cache_info() dict shim is gone; plan_cache_stats() is the
    API."""
    with pytest.raises(ImportError):
        from repro.core.dispatch import plan_cache_info  # noqa: F401
    stats = plan_cache_stats().as_dict()
    assert set(stats) >= {"size", "hits", "misses", "autotuned",
                          "persisted_loads"}


def test_gemm_plan_fit_clamps():
    p = GemmPlan(128, 128, 1 << 20)
    q = p.fit(9, 7, 33)
    assert q.tile == (16, 8, 40)
    assert p.fit(256, 256, 4096) == GemmPlan(128, 128, 4096)
