"""BLAS dispatch layer: policy lookup, mode equivalence, site tracing."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import AccumulatorSpec, BF16, FP32
from repro.core.dispatch import (GemmConfig, NumericsPolicy, current_policy,
                                 gemm, grouped_av, grouped_qk, sites_seen,
                                 use_policy, MXU_BF16, MXU_FP32)


def test_policy_lookup_precedence():
    base = GemmConfig(BF16, None, "native")
    attn = GemmConfig(FP32, AccumulatorSpec(4, 8, -8), "simulate")
    exact = GemmConfig(FP32, AccumulatorSpec.paper_91bit(), "simulate")
    pol = NumericsPolicy(base, overrides=(("attn_qk", exact), ("attn_*", attn)))
    assert pol.lookup("mlp_in") is base
    assert pol.lookup("attn_av") is attn
    assert pol.lookup("attn_qk") is exact          # exact match wins
    pol2 = pol.with_override("mlp_*", attn)
    assert pol2.lookup("mlp_in") is attn


def test_context_manager_restores():
    before = current_policy()
    with use_policy(MXU_FP32) as p:
        assert current_policy() is p
    assert current_policy() is before


def test_use_policy_restores_after_exception():
    """A raising body must not leak its policy into subsequent code."""
    before = current_policy()
    with pytest.raises(RuntimeError):
        with use_policy(MXU_FP32):
            assert current_policy() is MXU_FP32
            raise RuntimeError("boom")
    assert current_policy() is before
    # nested: inner exception unwinds one level only
    with use_policy(MXU_FP32):
        with pytest.raises(ValueError):
            with use_policy(MXU_BF16):
                raise ValueError("inner")
        assert current_policy() is MXU_FP32
    assert current_policy() is before


def test_use_policy_rejects_non_policy():
    with pytest.raises(TypeError):
        with use_policy("mxu_bf16"):
            pass


def test_use_policy_thread_isolation():
    """A policy installed in one thread is invisible to others, and a thread
    that raises under a policy leaves no residue behind."""
    import threading

    from repro.core.dispatch import _state

    results = {}

    def worker():
        results["before"] = current_policy()
        try:
            with use_policy(MXU_FP32):
                results["inside"] = current_policy()
                raise RuntimeError("boom")
        except RuntimeError:
            pass
        results["after"] = current_policy()
        results["residue"] = hasattr(_state, "policy")

    with use_policy(MXU_BF16):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
        assert current_policy() is MXU_BF16       # worker didn't touch us
    assert results["before"] is current_policy()  # fresh thread = default
    assert results["inside"] is MXU_FP32
    assert results["after"] is results["before"]
    assert not results["residue"]                 # thread state fully unwound


def test_native_vs_simulate_agreement(rng):
    """91-bit simulate mode == f64 reference; native f32 close."""
    a = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((64, 4)), jnp.float32)
    ref = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
    sim = NumericsPolicy(GemmConfig(FP32, AccumulatorSpec.paper_91bit(),
                                    "simulate"))
    with use_policy(sim):
        out_sim = gemm(a, b, site="t")
    # per-product RTZ at 2^lsb accumulates: |err| <= K * 2^-30 ~ 6e-8 absolute
    # on top of the single f32 rounding, so small outputs need an atol floor.
    np.testing.assert_allclose(np.asarray(out_sim), ref, rtol=2e-7,
                               atol=64 * 2.0 ** -30)
    with use_policy(MXU_FP32):
        out_nat = gemm(a, b, site="t")
    # native rounds after every f32 FMA: |err| <~ K * eps_f32 * sum|a_k b_k|,
    # a few 1e-6 absolute for K=64 N(0,1) data — small outputs need the floor.
    np.testing.assert_allclose(np.asarray(out_nat), ref, rtol=1e-5, atol=1e-5)


def test_batched_simulate(rng):
    a = jnp.asarray(rng.standard_normal((3, 2, 8, 16)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((3, 2, 16, 4)), jnp.float32)
    pol = NumericsPolicy(GemmConfig(FP32, AccumulatorSpec.paper_91bit(),
                                    "simulate"))
    with use_policy(pol):
        out = gemm(a, b, site="t")
    ref = np.einsum("bcij,bcjk->bcik", np.asarray(a, np.float64),
                    np.asarray(b, np.float64))
    assert out.shape == (3, 2, 8, 4)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-6,
                               atol=16 * 2.0 ** -30)


def test_grouped_einsums_match_modes(rng):
    """grouped_qk/grouped_av native einsum == simulate vmapped-2D path."""
    q = jnp.asarray(rng.standard_normal((2, 2, 3, 5, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 2, 7, 8)), jnp.float32)
    with use_policy(MXU_FP32):
        s_native = grouped_qk(q, k, site="attn_qk")
    sim = NumericsPolicy(GemmConfig(FP32, AccumulatorSpec.paper_91bit(),
                                    "simulate"))
    with use_policy(sim):
        s_sim = grouped_qk(q, k, site="attn_qk")
    np.testing.assert_allclose(np.asarray(s_native), np.asarray(s_sim),
                               rtol=1e-5, atol=1e-5)
    p = jax.nn.softmax(s_native, -1)
    v = jnp.asarray(rng.standard_normal((2, 2, 7, 8)), jnp.float32)
    with use_policy(MXU_FP32):
        o_native = grouped_av(p, v, site="attn_av")
    with use_policy(sim):
        o_sim = grouped_av(p, v, site="attn_av")
    np.testing.assert_allclose(np.asarray(o_native), np.asarray(o_sim),
                               rtol=1e-5, atol=1e-5)


def test_sites_are_traced(clean_sites):
    a = jnp.ones((4, 4))
    with use_policy(MXU_BF16):
        gemm(a, a, site="my_unique_site")
    assert sites_seen() == {"my_unique_site"}   # registry was reset: exact


def test_reset_sites_seen(clean_sites):
    from repro.core.dispatch import reset_sites_seen
    a = jnp.ones((4, 4))
    with use_policy(MXU_BF16):
        gemm(a, a, site="ephemeral")
    assert "ephemeral" in sites_seen()
    reset_sites_seen()
    assert sites_seen() == frozenset()


def test_phase_aware_lookup():
    """v1-style patterns (plain names, trailing *) are forward-only; bwd
    sites resolve via phase-qualified patterns and the *@bwd fallback."""
    from repro.core.dispatch import GemmSite, widen_config
    base = GemmConfig(BF16, None, "native")
    narrow = GemmConfig(FP32, AccumulatorSpec(4, 8, -8), "simulate")
    wide = widen_config(base)
    pol = NumericsPolicy(base, overrides=(
        ("attn_qk@bwd.dA", narrow), ("attn_*", narrow), ("*@bwd", wide)))
    assert pol.lookup("attn_qk") is narrow          # fwd wildcard
    assert pol.lookup("attn_qk@bwd.dA") is narrow   # explicit bwd operand
    assert pol.lookup("attn_qk@bwd.dB") is wide     # attn_* must NOT catch bwd
    assert pol.lookup("mlp_in@bwd.dA") is wide
    assert pol.lookup("mlp_in") is base
    # GemmSite objects and canonical strings are interchangeable
    assert pol.lookup(GemmSite("attn_qk", "bwd", "dA")) is narrow
    s = GemmSite.parse("moe_in@bwd.dB")
    assert (s.name, s.phase, s.operand) == ("moe_in", "bwd", "dB")
    assert s.key == "moe_in@bwd.dB"
    with pytest.raises(ValueError):
        GemmSite.parse("x@sideways")
    with pytest.raises(ValueError):
        GemmSite("x", "fwd", "dA")                  # fwd carries no operand


def test_generator_reports():
    from repro.core import generate_gemm
    g = generate_gemm(AccumulatorSpec(9, 6, -20), FP32, "simulate")
    r = g.report
    assert r.num_limbs == 3 and r.spec.width == 36
    assert r.watts_fpga_model > 0 and "fdp" in r.name
    with pytest.raises(ValueError):
        from repro.core import POSIT16_1
        generate_gemm(None, POSIT16_1, "native")   # no native posit path


def test_energy_model_reproduces_paper_anchors():
    from repro.core.energy import PAPER_POINTS
    for name, (model_w, paper_w) in PAPER_POINTS.items():
        assert model_w == pytest.approx(paper_w, rel=1e-6), name
