"""repro.numerics end-to-end: trace the paper-MLP forward pass, search under
an error budget, emit a PrecisionPlan, reload it, and verify (a) per-site
bit-for-bit reproduction of the chosen candidates and (b) modeled energy
below the uniform ⟨91-bit⟩ baseline while meeting the budget."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import BF16, FP32
from repro.core.dispatch import (FDP91, MXU_FP32, NumericsPolicy, gemm,
                                 use_policy)
from repro.core.metrics import correct_bits
from repro.models import forward, init, LOCAL
from repro.numerics import calibrate, load_plan, pareto_frontier, search
from repro.numerics.search import evaluate_candidates
from repro.numerics.candidates import enumerate_candidates

BUDGET_BITS = 8.0


@pytest.fixture(scope="module")
def mlp_setup():
    cfg = get_config("paper-mlp").reduced()
    params = init(cfg, jax.random.key(0))
    batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 8), 0,
                                          cfg.vocab_size)}
    return cfg, params, batch


@pytest.fixture(scope="module")
def searched(mlp_setup):
    """Trace -> search (with end-to-end validation) once for the module."""
    cfg, params, batch = mlp_setup
    with calibrate() as trace, use_policy(MXU_FP32):
        jax.block_until_ready(forward(params, cfg, batch, LOCAL,
                                      remat="none"))

    with use_policy(FDP91):
        ref = np.asarray(forward(params, cfg, batch, LOCAL, remat="none"))

    def validate(policy):
        with use_policy(policy):
            out = np.asarray(forward(params, cfg, batch, LOCAL,
                                     remat="none"))
        return float(np.median(correct_bits(out, ref, cap=24)))

    res = search(trace, budget_bits=BUDGET_BITS, name="paper-mlp-test",
                 formats=(BF16, FP32), widths=(32,), validate=validate)
    return trace, res


def test_trace_covers_model_sites(searched):
    trace, _ = searched
    sites = set(trace.sites())
    assert {"attn_q", "attn_k", "attn_v", "attn_o", "attn_qk", "attn_av",
            "mlp_in", "mlp_gate", "mlp_out", "lm_head"} <= sites
    for p in trace.profiles().values():
        assert p.sample is not None and p.calls >= 1


def test_search_meets_budget_under_baseline_energy(searched):
    _, res = searched
    assert res.validated_bits is not None
    assert res.validated_bits >= BUDGET_BITS
    m = res.plan.meta
    assert m["modeled_energy_j"] <= m["baseline_energy_j"]
    assert m["total_macs"] > 0
    # every site decision sits on its own Pareto frontier
    for d in res.decisions.values():
        assert d.pick in pareto_frontier(d.frontier)


def test_plan_reload_reproduces_sites_bit_for_bit(searched, tmp_path):
    """Serialize -> reload -> per-site outputs equal the chosen candidates'
    outputs bit for bit (the plan deploys exactly what the search measured)."""
    trace, res = searched
    path = tmp_path / "plan.json"
    res.plan.save(path)
    plan = load_plan(path)
    pol = plan.to_policy()
    for site, d in res.decisions.items():
        prof = d.profile
        a = jnp.asarray(prof.sample_a)
        b = jnp.asarray(prof.sample_b)
        out_plan = np.asarray(gemm(a, b, site=site, policy=pol))
        out_cand = np.asarray(
            gemm(a, b, site=site, policy=NumericsPolicy(d.pick.cfg)))
        np.testing.assert_array_equal(out_plan, out_cand, err_msg=site)


def test_simulate_only_search_is_bit_exact_on_reload(searched, tmp_path):
    """Restricting the grid to the FDP simulate backend: the deployed plan's
    per-site outputs still reproduce the evaluated candidates bit for bit
    (acceptance criterion (a), under the simulate backend specifically)."""
    trace, _ = searched
    res = search(trace, budget_bits=BUDGET_BITS, name="sim-only",
                 formats=(FP32,), widths=(40,), include_native=False)
    path = tmp_path / "sim_plan.json"
    res.plan.save(path)
    pol = load_plan(path).to_policy()
    for site, d in res.decisions.items():
        assert pol.lookup(site).mode == "simulate"
        a = jnp.asarray(d.profile.sample_a)
        b = jnp.asarray(d.profile.sample_b)
        np.testing.assert_array_equal(
            np.asarray(gemm(a, b, site=site, policy=pol)),
            np.asarray(gemm(a, b, site=site,
                            policy=NumericsPolicy(d.pick.cfg))),
            err_msg=site)
    assert res.plan.meta["modeled_energy_j"] <= \
        res.plan.meta["baseline_energy_j"]


def test_candidate_grid_is_pruned_by_trace(searched):
    """Enumerated accumulators never overflow on observed data (msb pinned at
    the traced requirement) and never extend below the bit-exact depth."""
    trace, _ = searched
    prof = trace.profile("mlp_in")
    cands = enumerate_candidates(prof, widths=(16, 32, 64, 2048))
    assert cands
    for c in cands:
        if c.cfg.acc is None or c.cfg.acc.msb == 30:   # native / paper91 ref
            continue
        assert c.cfg.acc.msb == prof.msb_required
        assert c.cfg.acc.lsb >= prof.lsb_exact(c.cfg.fmt.precision)


def test_evaluated_errors_are_ordered_sanely(searched):
    """Wider accumulators never lose correct bits on the same site sample."""
    trace, _ = searched
    prof = trace.profile("attn_qk")
    cands = enumerate_candidates(prof, formats=(FP32,), widths=(16, 32, 64),
                                 include_native=False, include_paper91=False)
    ev = evaluate_candidates(prof, cands)
    by_width = sorted(ev, key=lambda e: e.cfg.acc.width)
    bits = [e.error_bits for e in by_width]
    assert all(b2 >= b1 - 0.5 for b1, b2 in zip(bits, bits[1:]))
    energies = [e.energy_j for e in by_width]
    assert energies == sorted(energies)
