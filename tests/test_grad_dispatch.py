"""Phase-aware gradient dispatch: the custom_vjp on gemm/ragged_gemm/
grouped_qk/grouped_av routes every backward GEMM through its own
phase-qualified site (``<site>@bwd.dA`` / ``<site>@bwd.dB``) — looked up in
the policy, registered in ``sites_seen()``, recorded by calibration traces —
while native-mode gradients stay bit-identical to autodiff through the
forward computation."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import AccumulatorSpec, BF16, FP32
from repro.core.dispatch import (FDP91, MXU_FP32, GemmConfig, NumericsPolicy,
                                 gemm, grouped_av, grouped_qk, ragged_gemm,
                                 sites_seen, use_policy, widen_config)
from repro.numerics import PrecisionPlan, SitePlan, calibrate


# ---------------------------------------------------------------------------
# gemm: bit-identity + site registration
# ---------------------------------------------------------------------------
def test_gemm_native_grads_bitexact_vs_autodiff(rng, clean_sites):
    """custom_vjp output == autodiff-through-forward, bit for bit, for the
    native mode (same casts, same contraction layout, same dtypes)."""
    a = jnp.asarray(rng.standard_normal((3, 8, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 4)), jnp.float32)

    def f_dispatch(a, w):
        with use_policy(MXU_FP32):
            return (gemm(a, w, site="proj") ** 2).sum()

    def f_raw(a, w):
        out = jnp.matmul(a.astype(jnp.float32), w.astype(jnp.float32),
                         preferred_element_type=jnp.float32)
        return (out ** 2).sum()

    ga = jax.grad(f_dispatch, argnums=(0, 1))(a, w)
    gr = jax.grad(f_raw, argnums=(0, 1))(a, w)
    assert jnp.array_equal(ga[0], gr[0]), "dA diverged from autodiff"
    assert jnp.array_equal(ga[1], gr[1]), "dB diverged from autodiff"
    assert {"proj", "proj@bwd.dA", "proj@bwd.dB"} <= sites_seen()


def test_gemm_1d_promotion_grads(rng):
    """jnp.matmul's 1-D promotion survives differentiation: vector-matrix,
    matrix-vector, and the 0-d-cotangent vector-dot case all match autodiff
    of the raw matmul."""
    v = jnp.asarray(rng.standard_normal((16,)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((16,)), jnp.float32)
    m = jnp.asarray(rng.standard_normal((16, 4)), jnp.float32)
    cases = [(v, m), (m.T, u), (v, u)]
    for x, y in cases:
        def f_dispatch(x, y):
            with use_policy(MXU_FP32):
                return gemm(x, y, site="vec").sum()

        def f_raw(x, y):
            return jnp.matmul(x, y, preferred_element_type=jnp.float32).sum()

        gd = jax.grad(f_dispatch, argnums=(0, 1))(x, y)
        gr = jax.grad(f_raw, argnums=(0, 1))(x, y)
        for got, want in zip(gd, gr):
            assert got.shape == want.shape
            assert jnp.array_equal(got, want), (x.shape, y.shape)


def test_gemm_forward_value_unchanged_by_custom_vjp(rng):
    """value_and_grad's primal output is the plain dispatched forward."""
    a = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 4)), jnp.float32)
    with use_policy(MXU_FP32):
        fwd_only = gemm(a, w, site="p")
        val, _ = jax.value_and_grad(
            lambda x, y: gemm(x, y, site="p").sum(), argnums=(0, 1))(a, w)
    assert float(val) == float(fwd_only.sum())


def test_bwd_sites_dispatch_under_their_own_config(rng):
    """A deliberately-narrow bwd override changes gradients but never the
    forward output — proof the backward GEMMs resolve their own configs."""
    a = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
    base = NumericsPolicy(GemmConfig(FP32, None, "native"))
    narrow = base.with_override(
        "p@bwd", GemmConfig(BF16, AccumulatorSpec(2, 4, -4), "simulate"))

    def loss(pol):
        return jax.value_and_grad(
            lambda x, y: (gemm(x, y, site="p", policy=pol) ** 2).sum(),
            argnums=(0, 1))(a, w)

    v0, g0 = loss(base)
    v1, g1 = loss(narrow)
    assert float(v0) == float(v1)                   # forward bit-identical
    assert not jnp.array_equal(g0[0], g1[0])        # bwd really re-dispatched
    assert not jnp.array_equal(g0[1], g1[1])


def test_fdp_simulate_grads_are_finite_and_dispatched(rng, clean_sites):
    """Differentiating a simulate-mode site no longer autodiffs through the
    integer limb algebra: the bwd GEMMs dispatch as sites of their own
    (under FDP91 they run the 91-bit FDP too) and produce usable grads."""
    a = jnp.asarray(rng.standard_normal((8, 32)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((32, 4)), jnp.float32)
    with use_policy(FDP91):
        g = jax.grad(lambda x, y: gemm(x, y, site="s").sum(),
                     argnums=(0, 1))(a, w)
    assert all(bool(jnp.all(jnp.isfinite(t))) for t in g)
    assert {"s", "s@bwd.dA", "s@bwd.dB"} <= sites_seen()
    # the 91-bit bwd GEMM is exact on this data: matches f64 reference
    ref_da = np.ones((8, 4)) @ np.asarray(w, np.float64).T
    np.testing.assert_allclose(np.asarray(g[0]), ref_da, rtol=2e-6,
                               atol=32 * 2.0 ** -30)


# ---------------------------------------------------------------------------
# grouped_qk / grouped_av under jax.grad (satellite)
# ---------------------------------------------------------------------------
def test_grouped_qk_av_grads_bitexact_and_traced(rng, clean_sites):
    q = jnp.asarray(rng.standard_normal((2, 2, 3, 5, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 2, 7, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 2, 7, 8)), jnp.float32)

    def f_dispatch(q, k, v):
        with use_policy(MXU_FP32):
            s = grouped_qk(q, k, site="attn_qk")
            p = jax.nn.softmax(s, axis=-1)
            o = grouped_av(p, v, site="attn_av")
        return (o ** 2).sum()

    def f_raw(q, k, v):
        s = jnp.einsum("bkgqd,bksd->bkgqs", q.astype(jnp.float32),
                       k.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bksd->bkgqd", p.astype(jnp.float32),
                       v.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
        return (o ** 2).sum()

    with calibrate() as trace:
        gd = jax.grad(f_dispatch, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_raw, argnums=(0, 1, 2))(q, k, v)
    for got, want, name in zip(gd, gr, "qkv"):
        assert jnp.array_equal(got, want), f"d{name} diverged from autodiff"

    # bwd sites registered and calibrated with their own profiles + samples
    want_sites = {"attn_qk@bwd.dA", "attn_qk@bwd.dB",
                  "attn_av@bwd.dA", "attn_av@bwd.dB"}
    assert want_sites <= sites_seen()
    assert want_sites <= set(trace.sites("bwd"))
    for s in want_sites:
        prof = trace.profile(s)
        assert prof.calls >= 1 and prof.macs > 0
        assert prof.sample is not None
        assert prof.a_abs_max > 0.0


# ---------------------------------------------------------------------------
# ragged_gemm under jax.grad (satellite)
# ---------------------------------------------------------------------------
def test_ragged_gemm_grads_match_autodiff_and_trace(rng, clean_sites):
    T, d, f, E = 12, 6, 5, 3
    x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((E, d, f)), jnp.float32)
    gs = jnp.asarray([5, 4, 3], jnp.int32)

    def f_dispatch(x, w):
        with use_policy(MXU_FP32):
            return (ragged_gemm(x, w, gs, site="moe_in") ** 2).sum()

    def f_raw(x, w):
        out = jax.lax.ragged_dot(x, w, gs,
                                 preferred_element_type=jnp.float32)
        return (out ** 2).sum()

    with calibrate() as trace:
        gd = jax.grad(f_dispatch, argnums=(0, 1))(x, w)
    gr = jax.grad(f_raw, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gd[0]), np.asarray(gr[0]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gd[1]), np.asarray(gr[1]),
                               rtol=1e-5, atol=1e-5)
    assert {"moe_in", "moe_in@bwd.dA", "moe_in@bwd.dB"} <= sites_seen()
    assert {"moe_in@bwd.dA", "moe_in@bwd.dB"} <= set(trace.sites("bwd"))


def test_ragged_gemm_grads_ignore_padded_rows(rng):
    """Rows beyond sum(group_sizes) belong to no expert: their token grads
    are zero and they contribute nothing to any expert's weight grad."""
    T, d, f, E = 10, 4, 3, 2
    x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((E, d, f)), jnp.float32)
    gs = jnp.asarray([4, 3], jnp.int32)              # 3 padded rows

    def loss(x, w):
        with use_policy(MXU_FP32):
            return (ragged_gemm(x, w, gs, site="moe_pad") ** 2).sum()

    dx, dw = jax.grad(loss, argnums=(0, 1))(x, w)
    assert bool(jnp.all(dx[7:] == 0.0))
    x2 = x.at[8].set(1e6)                            # padded row perturbation
    dw2 = jax.grad(loss, argnums=1)(x2, w)
    assert jnp.array_equal(dw, dw2)


# ---------------------------------------------------------------------------
# Acceptance: a traced train step under a v2 plan with narrow bwd sites
# ---------------------------------------------------------------------------
def _tiny_cfg():
    from repro.configs import get_config
    return get_config("paper-mlp").reduced(
        d_model=64, d_ff=128, n_layers=2, vocab_size=64, n_heads=4,
        n_kv_heads=4, head_dim=16)


def test_train_step_dispatches_bwd_sites_under_v2_plan(clean_sites):
    """The ISSUE acceptance scenario: a v2 plan assigns a deliberately-narrow
    format to paper-mlp bwd sites and the default to fwd sites; a traced
    train step shows the bwd sites dispatched under their own configs
    (``@bwd`` keys in sites_seen, distinct per-phase profiles in the
    calibration trace), and the fwd sites untouched by the narrow configs."""
    from repro.data.synthetic import SyntheticLM
    from repro.train.loop import make_train_step
    from repro.train.optimizer import Optimizer

    cfg = _tiny_cfg()
    default = GemmConfig(FP32, None, "native")
    narrow = GemmConfig(BF16, AccumulatorSpec(3, 6, -6), "simulate")
    plan = PrecisionPlan(
        name="bwd-narrow",
        sites=(SitePlan("mlp_in@bwd.dA", narrow),
               SitePlan("mlp_in@bwd.dB", narrow)),
        default=default, bwd_default=widen_config(default), budget_bits=4.0)

    ident = Optimizer(init=lambda p: {"grad_norm": jnp.zeros(())},
                      update=lambda g, s, p: (g, s))
    step = make_train_step(cfg, ident, remat="none", donate=False,
                           numerics_policy=plan.to_policy())
    from repro.models import init
    params = init(cfg, jax.random.key(0))
    ds = SyntheticLM(cfg.vocab_size, 16, 4, seed=0)
    tb = ds.batch(0)
    batch = {"tokens": tb.tokens, "targets": tb.targets,
             "loss_mask": tb.loss_mask}

    with calibrate() as trace:
        (_, metrics) = step((params, ident.init(params)), batch)[0], None
    seen = sites_seen()
    assert "mlp_in@bwd.dA" in seen and "mlp_in@bwd.dB" in seen
    assert any(s.endswith("@bwd.dA") for s in seen if s.startswith("attn"))

    # the narrow config really served the bwd sites; fwd stayed default
    assert trace.profile("mlp_in@bwd.dA").cfg_tags == {narrow.tag()}
    assert trace.profile("mlp_in").cfg_tags == {default.tag()}
    # unassigned bwd sites fell to the widened fallback, not the narrow one
    assert trace.profile("mlp_out@bwd.dA").cfg_tags == \
        {widen_config(default).tag()}
    # distinct per-phase statistics: gradient operands, not activations
    fwd_prof = trace.profile("mlp_in")
    bwd_prof = trace.profile("mlp_in@bwd.dA")
    assert bwd_prof.calls >= 1 and bwd_prof.macs > 0
    assert fwd_prof.a_abs_max != bwd_prof.a_abs_max


def test_train_step_under_fdp_bwd_plan_trains():
    """One optimizer step with *all* gradient GEMMs forced through the exact
    91-bit FDP runs end to end and produces finite parameter updates (before
    the custom_vjp this would have autodiffed through integer limb ops)."""
    from repro.data.synthetic import SyntheticLM
    from repro.train.loop import make_train_step
    from repro.train.optimizer import adamw

    cfg = _tiny_cfg()
    pol = NumericsPolicy(
        GemmConfig(FP32, None, "native"),
        overrides=(("*@bwd", GemmConfig(
            FP32, AccumulatorSpec.paper_91bit(), "simulate")),))
    step = make_train_step(cfg, adamw(lr=1e-3), remat="none", donate=False,
                           numerics_policy=pol)
    from repro.models import init
    params = init(cfg, jax.random.key(0))
    opt = adamw(lr=1e-3)
    ds = SyntheticLM(cfg.vocab_size, 12, 2, seed=0)
    tb = ds.batch(0)
    batch = {"tokens": tb.tokens, "targets": tb.targets,
             "loss_mask": tb.loss_mask}
    (new_params, _), metrics = step((params, opt.init(params)), batch)
    assert np.isfinite(float(metrics["loss"]))
    moved = jax.tree.map(lambda a, b: bool(jnp.all(jnp.isfinite(b)))
                         and not bool(jnp.array_equal(a, b)),
                         params, new_params)
    assert all(jax.tree.leaves(moved))
