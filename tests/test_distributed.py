"""Multi-device tests: run the distributed worker in a subprocess with 8
placeholder devices (the main test process keeps 1 device)."""

import os
import subprocess
import sys

import pytest

CHECKS = ["reproducible_psum", "moe_tp_parity", "moe_ep_parity",
          "pipeline_parity", "sp_forward_parity", "compressed_grads",
          "quantized_psum", "fdp_limb_psum", "mesh_reshape_logits"]

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("check", CHECKS)
def test_distributed(check):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "distributed_worker.py"),
         check],
        capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert f"CHECK {check} OK" in r.stdout
