"""repro.workloads: the end-to-end scenario zoo.

Covers the Validator protocol + registry, determinism of seeded validators
(bit-identical scores across runs, eager and jit), the ill-conditioned-solve
acceptance property (a widened plan strictly outscores a truncated one), the
91-bit-bwd reference construction, and the search integration — a failing
gradient workload drives ``@bwd`` Pareto upgrades, and every report lands in
the emitted plan's meta.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.accumulator import AccumulatorSpec
from repro.core.dispatch import (FDP91, MXU_FP32, GemmConfig, NumericsPolicy,
                                 use_policy)
from repro.core.formats import FP32
from repro.data.conditioned import gen_linear_system, residual_exact
from repro.models import LOCAL, forward, init
from repro.numerics import calibrate, search
from repro.workloads import (DEFAULT_VALIDATORS, IllConditionedSolve,
                             KReorderStability, LogitFidelity,
                             ValidationReport, WorkloadContext,
                             available_workloads, build_validators,
                             bwd91_reference_policy, get_workload,
                             probed_sites)

BUDGET = 10.0


def _policy(msb=30, lsb=-30, sites=("attn_qk", "mlp_in@bwd.dA")):
    """A plan-shaped policy: exact site overrides + the *@bwd fallback."""
    cfg = GemmConfig(FP32, AccumulatorSpec(ovf=30, msb=msb, lsb=lsb),
                     "simulate")
    overrides = tuple((s, cfg) for s in sites) + (("*@bwd", cfg),)
    return NumericsPolicy(default=GemmConfig(), overrides=overrides,
                          name="test")


@pytest.fixture(scope="module")
def mlp_ctx():
    cfg = get_config("paper-mlp").reduced()
    return WorkloadContext.for_model(cfg, budget_bits=BUDGET, seed=0)


# ---------------------------------------------------------------------------
# registry + protocol
# ---------------------------------------------------------------------------
def test_registry_lists_the_four_scenarios():
    assert {"solve", "grad", "logits", "repro"} <= set(available_workloads())
    assert set(DEFAULT_VALIDATORS) <= set(available_workloads())
    with pytest.raises(KeyError, match="unknown workload"):
        get_workload("nope")


def test_model_bound_workloads_refuse_bare_context():
    with pytest.raises(ValueError, match="model-bound"):
        build_validators(["grad"], WorkloadContext(budget_bits=BUDGET))
    with pytest.raises(ValueError, match="model-bound"):
        build_validators(["logits"], WorkloadContext(budget_bits=BUDGET))
    # synthetic workloads build fine without a model
    vs = build_validators(["solve", "repro"],
                          WorkloadContext(budget_bits=BUDGET))
    assert [v.name for v in vs] == ["solve", "repro"]
    for v in vs:
        assert v.threshold == BUDGET


def test_report_json_round_trip_is_plain_data():
    rep = ValidationReport(workload="x", score=np.float64(12.5),
                           threshold=10.0,
                           site_attribution={"a": np.float32(1.5)},
                           details={"inf": float("inf"), "n": 3})
    d = rep.to_json()
    assert d["passed"] is True and d["score"] == 12.5
    assert d["site_attribution"] == {"a": 1.5}
    assert d["details"]["inf"] is None            # JSON-safe
    import json
    json.dumps(d)


def test_probed_sites_are_the_exact_overrides():
    pol = _policy(sites=("attn_qk", "mlp_in@bwd.dA"))
    assert set(probed_sites(pol)) == {"attn_qk", "mlp_in@bwd.dA"}
    assert probed_sites(MXU_FP32) == []


# ---------------------------------------------------------------------------
# determinism: seeded validators are bit-identical across runs, eager + jit
# ---------------------------------------------------------------------------
def test_synthetic_validators_are_deterministic():
    pol = _policy()
    for v in build_validators(["solve", "repro"],
                              WorkloadContext(budget_bits=BUDGET)):
        r1, r2 = v.run(pol), v.run(pol)
        assert r1.score == r2.score                      # bit-identical
        assert r1.site_attribution == r2.site_attribution


def test_model_validators_are_deterministic(mlp_ctx):
    for v in build_validators(["logits", "grad"], mlp_ctx):
        r1, r2 = v.run(MXU_FP32), v.run(MXU_FP32)
        assert r1.score == r2.score


def test_solve_scores_match_under_jit():
    """The FDP simulate backend scores identically whether the probe GEMM
    runs eagerly or inside jit — workload scores don't depend on how the
    deployment compiles the model."""
    from repro.core.dispatch import gemm
    pol = _policy(sites=("probe",))
    v = IllConditionedSolve(conds=(1e6,), seed=0, threshold=BUDGET)
    kind, cond, a, b, exact = v._cases[0]
    eager = np.asarray(gemm(jnp.asarray(a), jnp.asarray(b), site="probe",
                            policy=pol))
    jitted = np.asarray(jax.jit(
        lambda x, y: gemm(x, y, site="probe", policy=pol))(
            jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(eager, jitted)


# ---------------------------------------------------------------------------
# ill-conditioned solve: the acceptance property
# ---------------------------------------------------------------------------
def test_widened_plan_strictly_outscores_truncated_on_solve():
    """The satellite acceptance test: same site, same format, same backend —
    only the accumulator's lsb depth differs. The widened datapath must win
    outright on ill-conditioned solves."""
    v = IllConditionedSolve(conds=(1e4, 1e6), seed=0, threshold=BUDGET)
    truncated = v.run(_policy(msb=30, lsb=-2, sites=("s",)))
    widened = v.run(_policy(msb=30, lsb=-50, sites=("s",)))
    assert widened.score > truncated.score
    assert widened.score >= 20.0          # near-exact on f32 readout
    assert not truncated.passed and widened.passed


def test_solve_attribution_names_the_guilty_site():
    cfg_ok = GemmConfig(FP32, AccumulatorSpec(ovf=30, msb=30, lsb=-50),
                        "simulate")
    cfg_bad = GemmConfig(FP32, AccumulatorSpec(ovf=30, msb=30, lsb=-2),
                         "simulate")
    pol = NumericsPolicy(default=GemmConfig(),
                         overrides=(("good", cfg_ok), ("bad", cfg_bad)),
                         name="mixed")
    rep = IllConditionedSolve(conds=(1e6,), seed=0, threshold=BUDGET).run(pol)
    assert set(rep.site_attribution) == {"good", "bad"}
    assert rep.site_attribution["bad"] < rep.site_attribution["good"]
    assert rep.details["weakest_site"] == "bad"
    assert rep.score == rep.site_attribution["bad"]


def test_residual_exact_reference():
    """The exact-arithmetic residual reference: against the f32 rounding of
    the exact row values it recovers exactly the rounding residue (sub-ulp,
    nonzero), and against the exact values themselves it is zero."""
    A, x, exact = gen_linear_system(16, 1e4, seed=7)
    b32 = np.float32(exact)
    r = residual_exact(A, x, b32)
    np.testing.assert_allclose(r, exact - b32.astype(np.float64), rtol=1e-12)
    assert np.any(r != 0.0)
    assert np.max(np.abs(r)) < np.max(np.abs(exact)) * 2.0 ** -23


def test_gen_linear_system_condition_sweeps():
    """f32 row dots lose ~log2(cond) bits; exact arithmetic keeps them."""
    bits = []
    for cond in (1e4, 1e8):
        A, x, exact = gen_linear_system(24, cond, seed=3)
        got = (A @ x).astype(np.float64)
        rel = np.abs(got - exact) / np.maximum(np.abs(exact), 1e-300)
        bits.append(float(np.median(-np.log2(np.maximum(rel, 1e-300)))))
        got64 = A.astype(np.float64) @ x.astype(np.float64)
        rel64 = np.abs(got64 - exact) / np.maximum(np.abs(exact), 1e-300)
        assert float(np.median(-np.log2(np.maximum(rel64, 1e-300)))) > 24.0
    assert bits[0] > bits[1] + 8          # harder cond => fewer f32 bits


# ---------------------------------------------------------------------------
# reproducibility probe
# ---------------------------------------------------------------------------
def test_fdp_is_bit_stable_under_reordering_native_is_not():
    v = KReorderStability(seed=0, threshold=BUDGET)
    fdp = v.run(_policy(sites=("s",)))
    assert fdp.score == 53.0              # bit-identical by construction
    assert fdp.details["bit_identical_sites"] == 1
    native = v.run(NumericsPolicy(GemmConfig(FP32, None, "native"),
                                  overrides=(("s", GemmConfig(FP32, None,
                                                              "native")),)))
    assert native.score < 30.0            # some drift, some stability
    assert native.score > 10.0


# ---------------------------------------------------------------------------
# gradient workload: the 91-bit-bwd reference
# ---------------------------------------------------------------------------
def test_bwd91_reference_rewrites_the_whole_bwd_namespace():
    narrow = GemmConfig(FP32, AccumulatorSpec(ovf=4, msb=8, lsb=-4),
                        "simulate")
    pol = NumericsPolicy(
        default=GemmConfig(),
        overrides=(("attn_qk", narrow), ("attn_qk@bwd.dA", narrow),
                   ("mlp_in@*", narrow), ("*@bwd", narrow)))
    ref = bwd91_reference_policy(pol)
    paper = AccumulatorSpec.paper_91bit()
    # fwd lookups survive untouched — including the fwd half of a phase-*
    # pattern (forward error must stay common-mode with the candidate)
    assert ref.lookup("attn_qk").tag() == narrow.tag()
    assert ref.lookup("mlp_in").tag() == narrow.tag()
    # ...while every bwd lookup lands on the 91-bit exact FDP, phase-*
    # patterns' backward halves included
    for site in ("attn_qk@bwd.dA", "attn_qk@bwd.dB", "mlp_in@bwd.dA",
                 "mlp_in@bwd.dB", "other@bwd.dB"):
        got = ref.lookup(site)
        assert got.acc == paper and got.mode == "simulate", site


def test_grad_validator_scores_worst_leaf_and_attributes_bwd(mlp_ctx):
    v = build_validators(["grad"], mlp_ctx)[0]
    rep = v.run(MXU_FP32)
    assert set(rep.site_attribution) == {"*@bwd"}
    assert rep.details["n_leaves"] > 3
    assert rep.score <= rep.details["median_bits"]
    assert 0.99 <= rep.details["cosine"] <= 1.0
    # eligibility: a failing grad report may only spend upgrades on bwd sites
    failing = dataclasses.replace(rep, score=0.0)
    assert v.eligible_site("attn_qk@bwd.dA", failing)
    assert not v.eligible_site("attn_qk", failing)


# ---------------------------------------------------------------------------
# search integration
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_search_with_validators_upgrades_bwd_sites_and_records_reports(
        mlp_ctx):
    """The tentpole acceptance criterion: with the gradient validator
    enabled, a fwd,bwd search on reduced paper-MLP performs at least one
    ``@bwd`` site upgrade, and the emitted plan records every workload's
    report."""
    with calibrate() as trace, use_policy(MXU_FP32):
        jax.block_until_ready(forward(mlp_ctx.params, mlp_ctx.cfg,
                                      mlp_ctx.batch, LOCAL, remat="none"))
        from repro.train.loop import make_loss_fn
        loss_fn = make_loss_fn(mlp_ctx.cfg, LOCAL, remat="none")
        jax.block_until_ready(jax.value_and_grad(loss_fn, has_aux=True)(
            mlp_ctx.params, mlp_ctx.grad_batch))

    validators = build_validators(["grad", "logits"], mlp_ctx)
    res = search(trace, budget_bits=BUDGET, name="wl-test",
                 validators=validators, widths=(32,),
                 phases=("fwd", "bwd"))
    meta = res.plan.meta
    upgrades = meta["validation_upgrades"]
    assert any("@bwd" in s for s in upgrades), upgrades
    assert set(meta["validation"]) == {"grad", "logits"}
    for rep in meta["validation"].values():
        assert {"score", "threshold", "units", "passed"} <= set(rep)
    assert res.reports["grad"].passed
    assert meta["validated_bits"] == res.reports["logits"].score
    # the recorded evidence reproduces against the shipped policy
    rerun = validators[0].run(res.plan.to_policy())
    assert rerun.score == res.reports["grad"].score


def test_search_rejects_both_validation_flavors(mlp_ctx):
    with calibrate() as trace, use_policy(MXU_FP32):
        jax.block_until_ready(forward(mlp_ctx.params, mlp_ctx.cfg,
                                      mlp_ctx.batch, LOCAL, remat="none"))
    with pytest.raises(ValueError, match="not both"):
        search(trace, budget_bits=BUDGET, validate=lambda p: 24.0,
               validators=build_validators(["repro"],
                                           WorkloadContext()))


def test_logit_fidelity_matches_oracle_semantics(mlp_ctx):
    v = build_validators(["logits"], mlp_ctx)[0]
    rep = v.run(FDP91)
    assert rep.score == 24.0              # the oracle agrees with itself
    assert rep.details["top1_agreement"] == 1.0
    assert isinstance(v, LogitFidelity)
