"""Deploying a PrecisionPlan through the serving driver: the reduced
qwen3-0.6b config runs under the checked-in paper-MLP plan (sites are shared
role names, so plans transfer across the zoo) with no accuracy regression
beyond the declared budget."""

import os

import numpy as np
import jax
import pytest

from repro.configs import get_config
from repro.core.dispatch import FDP91, use_policy
from repro.core.metrics import correct_bits
from repro.launch import serve as serve_mod
from repro.models import forward, init, LOCAL
from repro.numerics import load_plan

FIXTURE = os.path.join(os.path.dirname(__file__), os.pardir,
                       "examples", "plans", "paper_mlp.json")


@pytest.fixture(scope="module")
def qwen_setup():
    cfg = get_config("qwen3-0.6b").reduced()
    params = init(cfg, jax.random.key(0))
    batch = {"tokens": jax.random.randint(jax.random.key(1), (1, 8), 0,
                                          cfg.vocab_size)}
    return cfg, params, batch


def test_serve_cli_runs_under_plan(capsys):
    serve_mod.main(["--arch", "qwen3-0.6b", "--reduced", "--batch", "1",
                    "--prompt-len", "4", "--gen", "2",
                    "--precision-plan", FIXTURE])
    out = capsys.readouterr().out
    assert "plan=" in out and "sample:" in out


def test_plan_accuracy_within_budget(qwen_setup):
    """Median correct bits of plan-policy logits vs the uniform 91-bit FDP
    oracle stays above the plan's declared budget."""
    cfg, params, batch = qwen_setup
    plan = load_plan(FIXTURE)
    with use_policy(FDP91):
        ref = np.asarray(forward(params, cfg, batch, LOCAL, remat="none"))
    with use_policy(plan.to_policy()):
        got = np.asarray(forward(params, cfg, batch, LOCAL, remat="none"))
    bits = float(np.median(correct_bits(got, ref, cap=24)))
    assert bits >= plan.budget_bits, (
        f"plan delivers {bits:.1f} bits < declared budget "
        f"{plan.budget_bits}")


def test_plan_tokens_match_uniform_policy(qwen_setup):
    """Greedy decode under the plan tracks the fp32 uniform policy on this
    reduced config (declared budgets sit far above argmax-flip territory;
    a majority agreement floor keeps the test robust to near-tie flips if
    the fixture is ever regenerated with aggressive lowering)."""
    from repro.core.dispatch import MXU_FP32
    import jax.numpy as jnp
    cfg, params, _ = qwen_setup
    prompts = jnp.asarray([[5, 9, 2, 7]], jnp.int32)
    plan = load_plan(FIXTURE)
    with use_policy(plan.to_policy()):
        toks_plan = np.asarray(serve_mod.serve(cfg, params, prompts, 4))
    with use_policy(MXU_FP32):
        toks_ref = np.asarray(serve_mod.serve(cfg, params, prompts, 4))
    agreement = float(np.mean(toks_plan == toks_ref))
    assert agreement >= 0.75, (toks_plan.tolist(), toks_ref.tolist())
