"""Optional-`hypothesis` shim: property tests degrade to skips when the
library is absent (it lives in the package's ``test`` extra), so the module
still collects and its explicit-example tests still run.

Usage (instead of ``from hypothesis import ...``)::

    from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import pytest

    class _StrategyStub:
        """Stands in for ``hypothesis.strategies``: any strategy constructor
        returns an inert placeholder (never drawn from — the test skips)."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = _StrategyStub()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            # deliberately NOT functools.wraps: the replacement must have a
            # zero-arg signature so pytest doesn't resolve the original
            # hypothesis-driven parameters as fixtures.
            def skipper():
                pytest.skip("hypothesis not installed (pip install .[test])")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco
