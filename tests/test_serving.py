"""Continuous batching engine: slot reuse must be isolated (a reused slot
never attends to the previous occupant's KV) and outputs must match the
simple whole-batch serving path."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.dispatch import use_policy, MXU_FP32
from repro.launch.batching import ContinuousBatcher, Request
from repro.launch.serve import serve
from repro.models import init


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-0.6b").reduced()
    params = init(cfg, jax.random.key(0))
    return cfg, params


def _ref_generate(cfg, params, prompt, n):
    """Reference: isolated whole-batch greedy decode."""
    with use_policy(MXU_FP32):
        toks = serve(cfg, params, jnp.asarray([prompt], jnp.int32), n)
    return np.asarray(toks)[0].tolist()


def test_slot_reuse_isolated(setup):
    """Two requests through ONE slot sequentially == each served alone."""
    cfg, params = setup
    r1 = Request(1, [5, 9, 2], max_new=5)
    r2 = Request(2, [7, 1, 8, 3], max_new=5)
    with use_policy(MXU_FP32):
        eng = ContinuousBatcher(cfg, params, n_slots=1, max_len=64)
        eng.submit(r1)
        eng.submit(r2)
        eng.run()
    assert r1.done and r2.done
    assert r1.out == _ref_generate(cfg, params, r1.prompt, 5)
    assert r2.out == _ref_generate(cfg, params, r2.prompt, 5)


def test_parallel_slots_match_reference(setup):
    cfg, params = setup
    reqs = [Request(i, [3 + i, 11, 4 + i], max_new=4) for i in range(3)]
    with use_policy(MXU_FP32):
        eng = ContinuousBatcher(cfg, params, n_slots=4, max_len=48)
        for r in reqs:
            eng.submit(r)
        eng.run()
    for r in reqs:
        assert r.done
        assert r.out == _ref_generate(cfg, params, r.prompt, 4)


def test_more_requests_than_slots(setup):
    """Queue drains through limited slots; all complete."""
    cfg, params = setup
    reqs = [Request(i, [2 + i, 6], max_new=3) for i in range(5)]
    with use_policy(MXU_FP32):
        eng = ContinuousBatcher(cfg, params, n_slots=2, max_len=64)
        for r in reqs:
            eng.submit(r)
        eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 3 for r in reqs)
