"""The plan zoo: every checked-in PrecisionPlan loads, round-trips through
``policy_from_plan``, agrees with its MANIFEST entry, and the plan-aware
continuous-batching warmup compiles decode under a plan exactly once."""

import glob
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.dispatch import policy_from_plan
from repro.launch.batching import ContinuousBatcher, Request
from repro.models import init
from repro.numerics import PLAN_VERSION, load_plan, load_trace

PLANS_DIR = os.path.join(os.path.dirname(__file__), os.pardir,
                         "examples", "plans")
PLAN_PATHS = sorted(p for p in glob.glob(os.path.join(PLANS_DIR, "*.json"))
                    if os.path.basename(p) != "MANIFEST.json")
MANIFEST_PATH = os.path.join(PLANS_DIR, "MANIFEST.json")


def _manifest():
    with open(MANIFEST_PATH) as f:
        return json.load(f)


def test_zoo_has_coverage():
    """≥4 per-architecture plans, with at least one MoE and one SSM — the
    paper's tailoring claim demonstrated beyond a single dense model."""
    assert len(PLAN_PATHS) >= 4, PLAN_PATHS
    families = {e["family"] for e in _manifest()["plans"].values()}
    assert "moe" in families and "ssm" in families, families


@pytest.mark.parametrize("path", PLAN_PATHS,
                         ids=[os.path.basename(p) for p in PLAN_PATHS])
def test_plan_loads_and_round_trips(path):
    plan = load_plan(path)
    assert plan.version <= PLAN_VERSION
    assert plan.sites, f"{path} has no sites"
    policy = policy_from_plan(path)
    for s in plan.sites:
        if s.kind == "gemm":
            assert policy.lookup(s.site).tag() == s.cfg.tag()
        else:                   # aux sites deploy through the aux channel
            assert policy.aux_lookup(s.site) == s.cfg
    assert policy.lookup("__unlisted__").tag() == plan.default.tag()


@pytest.mark.parametrize("path", PLAN_PATHS,
                         ids=[os.path.basename(p) for p in PLAN_PATHS])
def test_manifest_in_sync(path):
    arch_id = os.path.basename(path)[:-len(".json")]
    plan = load_plan(path)
    entry = _manifest()["plans"].get(arch_id)
    assert entry is not None, f"{arch_id} missing from MANIFEST.json"
    assert entry["sites"] == [s.site for s in plan.sites]
    assert entry["budget_bits"] == plan.budget_bits
    assert entry["validated_bits"] == plan.meta.get("validated_bits")
    assert entry["modeled_energy_j"] == plan.meta.get("modeled_energy_j")
    # every plan must beat (or at worst match) the uniform-91-bit baseline
    assert entry["energy_vs_baseline"] is not None
    assert entry["energy_vs_baseline"] <= 1.0


def test_manifest_lists_only_existing_files():
    on_disk = {os.path.basename(p)[:-len(".json")] for p in PLAN_PATHS}
    assert set(_manifest()["plans"]) == on_disk


@pytest.mark.parametrize("path", PLAN_PATHS,
                         ids=[os.path.basename(p) for p in PLAN_PATHS])
def test_every_plan_carries_workload_validation_scores(path):
    """Every checked-in plan records the per-workload end-to-end evidence
    (repro.workloads reports) it was accepted on, and the MANIFEST summary
    matches the plan document."""
    from repro.workloads import SUMMARY_KEYS, validation_summary
    arch_id = os.path.basename(path)[:-len(".json")]
    plan = load_plan(path)
    validation = plan.meta.get("validation") or {}
    assert validation, f"{arch_id} was searched without workload validators"
    for name, rep in validation.items():
        for key in SUMMARY_KEYS:
            assert rep.get(key) is not None, (arch_id, name, key)
    entry = _manifest()["plans"][arch_id]
    assert entry.get("validation") == validation_summary(plan.meta), arch_id
    # the grad workload ran for every arch: bwd assignments are end-to-end
    # validated zoo-wide, not just per-site
    assert "grad" in validation, arch_id


@pytest.mark.parametrize("arch_id", ["dbrx_132b", "mamba2_1p3b"])
def test_zoo_traces_reload_with_expert_and_scan_sites(arch_id):
    """The checked-in calibration traces carry the sites the ROADMAP asked
    for: MoE router + expert sites, SSM scan-block sites."""
    path = os.path.join(PLANS_DIR, "traces", f"{arch_id}.trace.json")
    trace = load_trace(path)
    sites = set(trace.sites())
    if arch_id == "dbrx_132b":
        assert {"moe_router", "moe_in", "moe_gate", "moe_out"} <= sites
    else:
        assert any(s.startswith("ssm_") for s in sites), sites
    for s in sites:
        assert trace.profile(s).sample is not None, (arch_id, s)


# ---------------------------------------------------------------------------
# plan-aware continuous-batching warmup (the ROADMAP "batching under plans"
# bug): warmed-up decode under a plan must compile exactly once — stepping
# never retraces — and produce the same tokens as a cold engine stepping
# under the same policy.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def qwen_reduced():
    cfg = get_config("qwen3-0.6b").reduced()
    return cfg, init(cfg, jax.random.key(0))


def _drive(eng, n=2, max_new=3):
    reqs = [Request(uid=i, prompt=[3, 1, 4, 1], max_new=max_new)
            for i in range(n)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return [r.out for r in reqs]


def test_warmup_under_plan_does_not_recompile(qwen_reduced):
    cfg, params = qwen_reduced
    plan_path = os.path.join(PLANS_DIR, "paper_mlp.json")
    eng = ContinuousBatcher(cfg, params, n_slots=2, max_len=32,
                            warmup=plan_path)
    assert eng.policy is not None and eng.policy.name.startswith("plan:")
    assert eng.trace_count == 1, "warmup should trace the decode step once"
    outs = _drive(eng)
    assert eng.trace_count == 1, \
        f"plan-served decode retraced after warmup ({eng.trace_count} traces)"
    assert all(len(o) == 3 for o in outs)

    # parity: a cold engine stepping under the same policy decodes the same
    cold = ContinuousBatcher(cfg, params, n_slots=2, max_len=32,
                             policy=policy_from_plan(plan_path))
    assert cold.trace_count == 0
    outs_cold = _drive(cold)
    assert outs == outs_cold
    assert cold.trace_count == 1


def test_warmup_accepts_policy_objects(qwen_reduced):
    cfg, params = qwen_reduced
    plan = load_plan(os.path.join(PLANS_DIR, "paper_mlp.json"))
    for arg in (plan, plan.to_policy()):
        eng = ContinuousBatcher(cfg, params, n_slots=1, max_len=16,
                                warmup=arg)
        assert eng.trace_count == 1
        assert eng.numerics_info()["policy"] == f"plan:{plan.name}"
    with pytest.raises(TypeError):
        ContinuousBatcher(cfg, params, n_slots=1, max_len=16, warmup=123)
