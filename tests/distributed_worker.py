"""Multi-device checks, run in a subprocess with 8 placeholder devices
(tests/test_distributed.py drives this). Each check prints 'CHECK <name> OK'
or raises."""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.parallel.compat import shard_map_unchecked

from repro.core.accumulator import AccumulatorSpec
from repro.core.dispatch import use_policy, MXU_FP32
from repro.models.config import ModelConfig
from repro.models.layers import Distribution, LOCAL
from repro.models import moe as MOE
from repro.parallel.collectives import reproducible_psum
from repro.parallel.pipeline import pipeline_apply


def check_reproducible_psum():
    """Integer psum is bitwise order-invariant; check quantize/psum/dequant
    matches a float reference within grid resolution and is deterministic."""
    mesh = jax.make_mesh((8,), ("dp",))
    spec = AccumulatorSpec(ovf=8, msb=8, lsb=-16)
    x = jax.random.normal(jax.random.key(0), (8, 64))

    def f(xl):
        return reproducible_psum(xl[0], "dp", spec)

    out = shard_map_unchecked(f, mesh=mesh, in_specs=P("dp"),
                              out_specs=P())(x)
    ref = np.asarray(x).sum(0)
    np.testing.assert_allclose(np.asarray(out), ref, atol=8 * 2.0 ** -16)
    # determinism across two calls
    out2 = shard_map_unchecked(f, mesh=mesh, in_specs=P("dp"),
                               out_specs=P())(x)
    assert jnp.array_equal(out, out2)
    print("CHECK reproducible_psum OK")


def _moe_cfg(E=4, k=2):
    return ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                       n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                       n_experts=E, top_k=k)


def check_moe_tp_parity():
    """shard_map TP-MoE == local MoE (fp32)."""
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    dist = Distribution(mesh=mesh, dp_axes=("data",), tp_axis="model")
    cfg = _moe_cfg()
    p = MOE.init_moe(jax.random.key(0), cfg.d_model, cfg.d_ff, cfg.n_experts)
    x = jax.random.normal(jax.random.key(1), (4, 8, cfg.d_model))
    with use_policy(MXU_FP32):
        local = MOE.moe_block(x, p, cfg, LOCAL)
        dist_out = jax.jit(lambda x: MOE.moe_block(x, p, cfg, dist))(x)
    np.testing.assert_allclose(np.asarray(local), np.asarray(dist_out),
                               rtol=2e-4, atol=2e-5)
    print("CHECK moe_tp_parity OK")


def check_moe_ep_parity():
    """EP all-to-all MoE == local MoE when capacity is ample (fp32)."""
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    dist = Distribution(mesh=mesh, dp_axes=("data",), tp_axis="model")
    cfg = _moe_cfg(E=8, k=2)
    p = MOE.init_moe(jax.random.key(0), cfg.d_model, cfg.d_ff, cfg.n_experts)
    x = jax.random.normal(jax.random.key(1), (4, 8, cfg.d_model))
    with use_policy(MXU_FP32):
        local = MOE.moe_block(x, p, cfg, LOCAL)
        ep = jax.jit(lambda x: MOE.moe_block_ep(x, p, cfg, dist,
                                                capacity_factor=8.0))(x)
    np.testing.assert_allclose(np.asarray(local), np.asarray(ep),
                               rtol=2e-4, atol=2e-5)
    print("CHECK moe_ep_parity OK")


def check_pipeline_parity():
    """4-stage GPipe == sequential layer stack."""
    mesh = jax.make_mesh((4,), ("stage",))
    S, n_micro, mb, d = 4, 8, 2, 16
    keys = jax.random.split(jax.random.key(0), S)
    params = {"w": jnp.stack([jax.random.normal(k, (d, d)) / d ** 0.5
                              for k in keys])}

    def body(p, x):
        return jnp.tanh(x @ p["w"])

    x = jax.random.normal(jax.random.key(1), (n_micro, mb, d))
    out = pipeline_apply(body, params, x, mesh, "stage")
    ref = x
    for s in range(S):
        ref = jnp.tanh(ref @ params["w"][s])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    print("CHECK pipeline_parity OK")


def check_sp_forward_parity():
    """Sequence-parallel sharded forward == single-device forward (fp32)."""
    from repro.configs import get_config
    from repro.models import forward, init
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    dist = Distribution(mesh=mesh, dp_axes=("data",), tp_axis="model")
    cfg = get_config("llama3.2-3b").reduced()
    params = init(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (4, 16), 0, cfg.vocab_size)
    with use_policy(MXU_FP32):
        local = forward(params, cfg, {"tokens": toks}, LOCAL, remat="none")
        sharded = jax.jit(lambda p, t: forward(
            p, cfg, {"tokens": t}, dist, remat="none"))(params, toks)
    np.testing.assert_allclose(np.asarray(local), np.asarray(sharded),
                               rtol=3e-4, atol=3e-4)
    print("CHECK sp_forward_parity OK")


def check_fdp_limb_psum():
    """K-sharded FDP: limb psum == single-device GEMM, bit-for-bit, for
    every assignment of K-shards to devices (ring order permutations)."""
    from repro.core import accumulator as acc
    from repro.core import fdp
    from repro.parallel.collectives import fdp_psum

    spec = AccumulatorSpec(ovf=30, msb=30, lsb=-30)
    mesh = jax.make_mesh((8,), ("x",))
    a = jax.random.normal(jax.random.key(0), (8, 256))
    b = jax.random.normal(jax.random.key(1), (256, 16))
    ref = np.asarray(fdp.fdp_gemm(a, b, spec))

    def f(al, bl):
        limbs = fdp.fdp_gemm_limbs(al, bl, spec)
        return acc.to_float(spec, fdp_psum(limbs, "x", spec))

    sharded = shard_map_unchecked(f, mesh=mesh,
                                  in_specs=(P(None, "x"), P("x", None)),
                                  out_specs=P())
    rng = np.random.default_rng(0)
    S = a.shape[1] // 8
    for trial in range(3):
        # permute which device owns which K-block: the integer limb psum
        # must land on identical bits for every shard assignment
        perm = np.arange(8) if trial == 0 else rng.permutation(8)
        idx = np.concatenate([np.arange(p * S, (p + 1) * S) for p in perm])
        out = sharded(a[:, idx], b[idx, :])
        assert np.array_equal(np.asarray(out), ref), f"order {trial} drifted"
    print("CHECK fdp_limb_psum OK")


def check_mesh_reshape_logits():
    """Paper-MLP training under the deployed plan: bit-identical logits and
    loss-gradients on 1x8, 2x4 and 8x1 meshes (the mesh workload), plus one
    full make_mesh_train_step step landing on identical params."""
    from repro.configs import get_config
    from repro.core.dispatch import policy_from_plan
    from repro.launch.sharding import distribution_for
    from repro.train.loop import make_mesh_train_step
    from repro.train.optimizer import adamw
    from repro.workloads import (MeshReshapeStability, WorkloadContext,
                                 make_probe_batch)
    from repro.workloads.mesh import MESH_CAP_BITS

    cfg = get_config("paper-mlp").reduced()
    plan_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "..", "examples", "plans", "paper_mlp.json")
    policy = policy_from_plan(plan_path)
    ctx = WorkloadContext.for_model(cfg)
    rep = MeshReshapeStability.from_context(ctx).run(policy)
    assert rep.details["logits_bits"] == MESH_CAP_BITS, rep.details
    assert rep.details["grad_bits"] == MESH_CAP_BITS, rep.details
    assert rep.mesh == "1x8,2x4,4x2,8x1", rep.mesh
    # every FDP-mode site must be bit-identical across mesh factorizations
    # (its cross-device reduction goes through the limb-summed fdp_psum)
    for pat, gcfg in policy.overrides:
        if gcfg.mode != "native" and pat in rep.site_attribution:
            assert rep.site_attribution[pat] == MESH_CAP_BITS, (
                pat, rep.site_attribution[pat])

    opt = adamw(lr=1e-3)
    batch = make_probe_batch(cfg, batch_size=8, seq=8, seed=3,
                             with_targets=True)
    grad_spec = AccumulatorSpec(ovf=10, msb=10, lsb=-20)
    stepped = []
    for shape in ((1, 8), (2, 4), (8, 1)):
        mesh = jax.make_mesh(shape, ("data", "model"))
        dist = distribution_for(mesh, "ddp", numerics_policy=policy)
        step = make_mesh_train_step(cfg, opt, dist, fdp_grad_spec=grad_spec)
        (params, _), _metrics = step((ctx.params, opt.init(ctx.params)),
                                     batch)
        stepped.append(np.concatenate(
            [np.asarray(x).ravel() for x in jax.tree.leaves(params)]))
    assert np.array_equal(stepped[0], stepped[1]), "1x8 vs 2x4 params drift"
    assert np.array_equal(stepped[0], stepped[2]), "1x8 vs 8x1 params drift"
    print("CHECK mesh_reshape_logits OK")


def check_quantized_psum():
    """Block-scaled low-bit all-reduce over 8 devices: the mean lands within
    grid resolution of the float mean, the error-feedback residual stays
    bounded across steps (block_scale's no-clip exponent contract — a
    clipped top-of-block element would grow it linearly), and
    validate_overflow() stays quiet on benign payloads but fires on an
    error-feedback spillover that would saturate the integer range."""
    from repro.core.qformat import QuantConfig
    from repro.parallel.collectives import quantized_psum, validate_overflow

    mesh = jax.make_mesh((8,), ("dp",))
    cfg = QuantConfig(4, 32)
    g = jax.random.normal(jax.random.key(0), (8, 64)) * 0.1

    def f(gl, rl):
        out, new_r = quantized_psum(gl[0], "dp", cfg, mean=True,
                                    residual=rl[0])
        return out, new_r[None]

    run = shard_map_unchecked(f, mesh=mesh, in_specs=(P("dp"), P("dp")),
                              out_specs=(P(), P("dp")))
    r = jnp.zeros_like(g)
    for _ in range(6):
        out, r = run(g, r)
    # grid step per block: shared exponent from the cross-device block amax
    # (one octave of bump headroom), 4-bit payload
    amax = np.abs(np.asarray(g)).reshape(8, -1, cfg.block).max(axis=(0, 2))
    step = np.exp2(np.ceil(np.log2(amax)) - (cfg.bits - 1) + 1)
    ref = np.asarray(g).mean(0)
    err = np.abs(np.asarray(out) - ref).reshape(-1, cfg.block)
    assert (err <= 2 * step[:, None]).all(), "mean outside grid resolution"
    rmax = np.abs(np.asarray(r)).reshape(8, -1, cfg.block).max(axis=(0, 2))
    assert (rmax <= 2 * step).all(), "error-feedback residual not bounded"

    with validate_overflow():                       # benign: must not fire
        jax.block_until_ready(run(g, jnp.zeros_like(g)))
    fired = False
    try:
        with validate_overflow():                   # spillover: must fire
            jax.block_until_ready(run(g, 100.0 * jnp.ones_like(g)))
    except Exception:
        fired = True
    assert fired, "overflow guard silent on saturating spillover"
    print("CHECK quantized_psum OK")


def check_compressed_grads():
    from repro.parallel.collectives import CompressedGradReducer
    mesh = jax.make_mesh((8,), ("dp",))
    spec = AccumulatorSpec(ovf=4, msb=2, lsb=-8)   # coarse grid (compression)
    red = CompressedGradReducer(spec, "dp")
    g = jax.random.normal(jax.random.key(0), (8, 32)) * 0.1

    def f(gl):
        r = jnp.zeros((1, 32))
        out, new_r = red.reduce({"g": gl}, {"g": r})
        return out["g"], new_r["g"]

    out, resid = shard_map_unchecked(f, mesh=mesh, in_specs=P("dp"),
                                     out_specs=(P(), P("dp")))(g)
    ref = np.asarray(g).mean(0)
    # coarse grid: error bounded by grid step; residual carries the rest
    assert np.abs(np.asarray(out) - ref).max() < 2.0 ** -8 * 2
    assert np.abs(np.asarray(resid)).max() <= 2.0 ** -9 + 1e-7
    print("CHECK compressed_grads OK")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    checks = {
        "reproducible_psum": check_reproducible_psum,
        "moe_tp_parity": check_moe_tp_parity,
        "moe_ep_parity": check_moe_ep_parity,
        "pipeline_parity": check_pipeline_parity,
        "sp_forward_parity": check_sp_forward_parity,
        "quantized_psum": check_quantized_psum,
        "compressed_grads": check_compressed_grads,
        "fdp_limb_psum": check_fdp_limb_psum,
        "mesh_reshape_logits": check_mesh_reshape_logits,
    }
    if which == "all":
        for fn in checks.values():
            fn()
    else:
        checks[which]()
