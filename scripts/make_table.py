#!/usr/bin/env python
"""Render EXPERIMENTS.md roofline table from dry-run JSONs."""
import glob, json, sys

rows = []
for p in sorted(glob.glob("results/dryrun/*.json")):
    r = json.load(open(p))
    tag = p.split("/")[-1][:-5]
    variant = ""
    if "_ep_" in tag: variant = " [EP]"
    if tag.endswith("_ddp"): variant = " [DDP]"
    if tag.endswith("_decode_tp"): variant = " [decTP]"
    if tag.endswith("_kvint8"): variant = " [decTP+kv8]"
    if "skipped" in r:
        rows.append((r["arch"], r["shape"], "-", variant, None))
        continue
    rows.append((r["arch"], r["shape"], r["mesh"], variant, r))

print("| arch | shape | mesh | t_comp | t_mem | t_coll | dominant | comp-frac | useful | mem/dev |")
print("|---|---|---|---|---|---|---|---|---|---|")
seen_skip = set()
for arch, shape, mesh, variant, r in rows:
    if r is None:
        if (arch, shape) not in seen_skip:
            seen_skip.add((arch, shape))
            print(f"| {arch} | {shape} | — | — | — | — | SKIP (full-attention; DESIGN §4) | | | |")
        continue
    rf = r["roofline"]
    dom_t = max(rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"])
    frac = rf["t_compute_s"] / dom_t if dom_t else 0
    print(f"| {arch}{variant} | {shape} | {mesh} "
          f"| {rf['t_compute_s']*1e3:.1f}ms | {rf['t_memory_s']*1e3:.1f}ms "
          f"| {rf['t_collective_s']*1e3:.1f}ms | {rf['dominant']} "
          f"| {frac:.2f} | {rf['useful_flops_ratio']:.2f} "
          f"| {r['memory']['per_device_total']/2**30:.1f}GiB |")
