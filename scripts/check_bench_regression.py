#!/usr/bin/env python
"""Bench regression gate: diff a fresh bench ``--json`` run against its
committed baseline and fail on any row whose throughput regressed more than
the threshold (default 25%). Works for any bench document that declares its
kind (``bench``) and throughput key (``metric``, default ``gflops``) —
``bench_gemm``/``BENCH_gemm.json`` and ``bench_serving``/``BENCH_serving.json``
share this gate; baseline and new runs must be the same kind.

Rows are matched by ``name``; throughput is the row's ``metric`` value (rows
without a throughput figure — parity checks, summaries — are ignored). Because the
baseline is committed from one machine and CI runs on another, the default
comparison is **scale-calibrated**: every ratio is divided by the machine
scale measured on the ``impl == "native"`` rows (plain XLA ``jnp.matmul`` —
a workload this repo's kernel code cannot slow down), so a uniformly
slower/faster runner shifts nothing while a regression in the generated FDP
kernels still trips the gate even if it hits *every* FDP row at once.
Falls back to the median ratio across all rows if no native row is shared.
``--absolute`` compares raw ratios for same-machine runs.

``--new`` accepts several files; each row scores its best throughput across
runs (the quick-lane shapes are small enough that single samples are noisy
under shared-CPU runners — CI benches twice and gates on the best).

    python scripts/check_bench_regression.py --baseline BENCH_gemm.json \
        --new BENCH_gemm.ci.json BENCH_gemm.ci2.json
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys


def load_rows(path: str) -> tuple:
    """-> (bench kind, throughput metric key, {name: row with metric})."""
    with open(path) as f:
        doc = json.load(f)
    kind = doc.get("bench")
    if not kind or "rows" not in doc:
        raise SystemExit(f"{path}: not a bench --json document")
    metric = doc.get("metric", "gflops")
    return kind, metric, {r["name"]: r for r in doc["rows"] if metric in r}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_gemm.json")
    ap.add_argument("--new", required=True, nargs="+",
                    help="fresh bench_gemm --json output(s); rows take the "
                         "best throughput across runs")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max tolerated per-row throughput regression")
    ap.add_argument("--absolute", action="store_true",
                    help="raw ratios (same-machine); default calibrates out "
                         "the runner's overall speed via the median ratio")
    ap.add_argument("--min-seconds", type=float, default=1e-3,
                    help="noise floor: rows whose baseline wall time per "
                         "call is below this are reported but not gated "
                         "(sub-ms samples swing several-fold under shared "
                         "CPU and cannot carry a regression verdict)")
    args = ap.parse_args(argv)

    kind, metric, base = load_rows(args.baseline)
    new: dict = {}
    for path in args.new:
        nkind, nmetric, rows = load_rows(path)
        if (nkind, nmetric) != (kind, metric):
            raise SystemExit(
                f"{path}: bench kind/metric ({nkind}, {nmetric}) does not "
                f"match baseline {args.baseline} ({kind}, {metric})")
        for name, row in rows.items():
            if name not in new or row[metric] > new[name][metric]:
                new[name] = row
    common = sorted(set(base) & set(new))
    if not common:
        raise SystemExit("no common throughput rows between baseline and new "
                         "bench output — did the row names change?")
    missing = sorted(set(base) - set(new))
    if missing:
        print(f"[bench-gate] WARNING: {len(missing)} baseline rows absent "
              f"from the new run: {missing}")

    ratios = {n: new[n][metric] / base[n][metric] for n in common}
    gated = [n for n in common
             if base[n]["seconds_per_call"] >= args.min_seconds]
    if args.absolute:
        scale, anchor = 1.0, "absolute"
    else:
        native = [ratios[n] for n in gated
                  if base[n].get("impl") == "native"]
        if native:
            # the *slowest* anchor bounds how much of any row's slowdown is
            # machine rather than code: a conservative scale keeps one lucky
            # anchor burst from tightening the floor under every other row
            scale, anchor = min(native), "native rows (min)"
        else:
            scale, anchor = statistics.median(
                [ratios[n] for n in gated] or list(ratios.values())), \
                "median (!)"
    floor = scale * (1.0 - args.threshold)
    print(f"[bench-gate] {len(gated)}/{len(common)} rows gated "
          f"(noise floor {args.min_seconds * 1e3:.1f}ms), machine scale "
          f"{scale:.2f}x (anchor: {anchor}), fail below {floor:.2f}x of "
          f"baseline throughput")

    failed = []
    for name in common:
        r = ratios[name]
        if name not in gated:
            verdict = "skip (sub-noise-floor sample)"
        elif r < floor:
            verdict = "FAIL"
        else:
            verdict = "ok"
        print(f"  {name:48s} {base[name][metric]:9.3f} -> "
              f"{new[name][metric]:9.3f} {metric}  ({r:5.2f}x) {verdict}")
        if verdict == "FAIL":
            failed.append(name)

    if failed:
        print(f"[bench-gate] FAIL: {len(failed)} row(s) regressed more than "
              f"{args.threshold:.0%}: {failed}")
        sys.exit(1)
    print("[bench-gate] OK: no row regressed beyond the threshold")


if __name__ == "__main__":
    main()
