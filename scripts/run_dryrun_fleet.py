#!/usr/bin/env python
"""Run every (arch x shape x mesh) dry-run cell as a subprocess pool."""
import itertools
import os
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor

ARCHS_SMALL_FIRST = [
    "qwen3-0.6b", "mamba2-1.3b", "zamba2-2.7b", "llama3.2-3b", "paligemma-3b",
    "qwen1.5-4b", "stablelm-12b", "whisper-large-v3", "dbrx-132b",
    "grok-1-314b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
OUT = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
CONC = int(os.environ.get("FLEET_CONCURRENCY", "3"))
MOE_IMPL = os.environ.get("FLEET_MOE_IMPL", "tp")
PROFILE = os.environ.get("FLEET_PROFILE", "fsdp")
REMAT = os.environ.get("FLEET_REMAT", "block")

cells = [(a, s, mp) for a, s, mp in itertools.product(
    ARCHS_SMALL_FIRST, SHAPES, (False, True))]


def run(cell):
    arch, shape, mp = cell
    tag = f"{arch}_{shape}_{'pod2' if mp else 'pod1'}_{MOE_IMPL}_{REMAT}" + (f"_{PROFILE}" if PROFILE != "auto" else "")
    path = os.path.join(OUT, tag + ".json")
    if os.path.exists(path):
        return tag, "cached", 0.0
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", OUT, "--moe-impl", MOE_IMPL, "--param-profile", PROFILE,
           "--remat", REMAT]
    if mp:
        cmd.append("--multi-pod")
    env = dict(os.environ, PYTHONPATH="src")
    t0 = time.time()
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       timeout=7200)
    dt = time.time() - t0
    status = "ok" if r.returncode == 0 else "FAIL"
    if r.returncode != 0:
        with open(path + ".log", "w") as f:
            f.write(r.stdout + "\n" + r.stderr)
    return tag, status, dt


os.makedirs(OUT, exist_ok=True)
t0 = time.time()
with ThreadPoolExecutor(max_workers=CONC) as ex:
    for tag, status, dt in ex.map(run, cells):
        print(f"[fleet {time.time()-t0:7.0f}s] {tag}: {status} ({dt:.0f}s)",
              flush=True)
print(f"[fleet] done in {time.time()-t0:.0f}s")
