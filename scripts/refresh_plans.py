#!/usr/bin/env python
"""Plan-zoo refresh: calibrate + search a PrecisionPlan for every architecture.

The paper tailors one GEMM; ``repro.numerics`` tailors one model; this sweep
tailors the whole zoo. Per architecture it

  1. **calibrates** — one forward pass under the fast fp32 native policy with
     the dispatch trace hook installed, recording every call-site's operand
     statistics and samples (transformer attn/mlp sites, MoE router + expert
     sites, SSM scan-block sites, multimodal prefix sites);
  2. **persists the trace** — a versioned ``CalibrationTrace`` JSON keyed by
     the config fingerprint, so later refreshes (and ``--check`` CI runs)
     search from the saved trace without re-calibrating;
  3. **searches** — the per-site (format x accumulator x backend) Pareto
     sweep against the bit-exact FDP oracle, validated end-to-end vs the
     uniform 91-bit policy;
  4. **emits** ``examples/plans/<arch>.json`` plus a ``MANIFEST.json``
     summarizing modeled-energy savings and validated bits per arch — the
     artifacts the CI ``plan-zoo`` lane guards.

``--phases fwd,bwd`` (the default) additionally calibrates through a
``value_and_grad`` training-loss step, so every gradient GEMM is traced and
searched under its own phase-qualified site (``attn_qk@bwd.dA``) and the
emitted v2 plan carries backward assignments plus a modeled fwd/bwd energy
split in the MANIFEST.

End-to-end acceptance runs through the ``repro.workloads`` scenario zoo:
``--validators grad,logits,repro`` (the default) scores every assembled
policy on a real training-gradient step (vs the 91-bit-bwd reference), logit
fidelity (vs the uniform 91-bit oracle — this is what ``validated_bits``
records), and K-reorder bit-stability; failing workloads drive the greedy
upgrade loop toward the sites they attribute the deficit to (the gradient
workload upgrades ``@bwd`` sites). Every report is serialized into the plan
(``meta.validation``) and summarized per arch in the MANIFEST. The hostile
ill-conditioned ``solve`` workload is opt-in (``--validators solve,...``).

Usage:
    PYTHONPATH=src python scripts/refresh_plans.py --reduced            # all
    PYTHONPATH=src python scripts/refresh_plans.py --only dbrx_132b --reduced
    PYTHONPATH=src python scripts/refresh_plans.py --reduced --jobs 3
    PYTHONPATH=src python scripts/refresh_plans.py --only paper_mlp --reduced \
        --check     # recompute from the saved trace, compare to checked-in
    PYTHONPATH=src python scripts/refresh_plans.py --schedules
        # refresh the GemmPlan schedule zoo (examples/plans/schedules/)
    PYTHONPATH=src python scripts/refresh_plans.py --envelopes
        # derive meta["envelope"] for every checked-in plan from its saved
        # trace (no recalibration, no search) — the live-monitor boundary
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

MANIFEST_VERSION = 1
MANIFEST_KIND = "repro.numerics.PlanManifest"
DEFAULT_OUT = os.path.join(os.path.dirname(__file__), os.pardir,
                           "examples", "plans")

# Calibration shape: small enough for CPU, large enough that every scanned
# site fires and operand extremes are representative. One source of truth
# (repro.workloads.base) shared with WorkloadContext.for_model, so the CI
# workloads smoke recomputes scores on the data the plans recorded them on.
# NOTE: these feed the trace fingerprint — changing them invalidates every
# saved trace. The import costs a few seconds of jax startup on --help-style
# invocations (the package __init__ pulls it in); sweep children pay minutes
# of calibration anyway, and one shared constant beats a silent CI-gate skew.
from repro.workloads.base import (PROBE_BATCH as CAL_BATCH,          # noqa: E402
                                  PROBE_SEQ as CAL_SEQ,
                                  PROBE_SEED as CAL_SEED)


# ---------------------------------------------------------------------------
# --schedules: the GemmPlan schedule zoo (block-size schedules, not numerics)
# ---------------------------------------------------------------------------
# Representative GEMM signatures for the serving/CI hotpaths: decode-step
# (M=batch), prefill (M=batch*seq) and training shapes at the reduced-config
# scale the checked-in zoo serves. Small enough to autotune on CPU interpret
# mode in minutes; the fit() clamp keeps every winner legal at deploy time.
SCHEDULE_SHAPES = (
    (8, 64, 64), (8, 128, 64),          # decode-step projections
    (32, 64, 64), (64, 64, 64),         # small prefill
    (64, 128, 128), (128, 128, 128),    # reduced-config train/prefill
)
SCHEDULE_FMTS = ("ieee_fp32", "bfloat16")


def refresh_schedules(args) -> None:
    """Autotune the representative GEMM signatures and persist the winners
    as ``<out>/schedules/<backend>.json`` — the schedule zoo the launch
    drivers preload so a warm process takes zero autotune misses."""
    import jax

    from repro.core.accumulator import AccumulatorSpec
    from repro.core.dispatch import (clear_plan_cache, plan_cache_stats,
                                     plan_gemm)
    from repro.core.formats import get_format
    from repro.core.schedules import ScheduleZoo, zoo_path

    spec = AccumulatorSpec.paper_91bit()
    backend = jax.default_backend()
    clear_plan_cache()
    t0 = time.time()
    for fmt_name in SCHEDULE_FMTS:
        fmt = get_format(fmt_name)
        for (m, n, k) in SCHEDULE_SHAPES:
            plan = plan_gemm(m, n, k, fmt=fmt, spec=spec, autotune=True)
            print(f"[schedules] {fmt_name} {m}x{n}x{k}: tile={plan.tile} "
                  f"({plan.source})")
    zoo = ScheduleZoo.from_cache(
        backend, meta={"generated_by": "scripts/refresh_plans.py",
                       "shapes": [list(s) for s in SCHEDULE_SHAPES],
                       "fmts": list(SCHEDULE_FMTS),
                       "spec": "paper_91bit",
                       "provenance": _provenance()})
    path = zoo_path(os.path.join(args.out, "schedules"), backend)
    zoo.save(path)
    st = plan_cache_stats()
    print(f"[schedules] {len(zoo.entries)} schedules "
          f"({st.autotuned} autotuned) -> {path} "
          f"({time.time() - t0:.0f}s)")


def refresh_envelopes(args) -> None:
    """Back-fill ``meta["envelope"]`` on every checked-in plan from its saved
    calibration trace — pure derivation (``numerics.build_envelope``), no
    recalibration and no search, so site assignments, scores, and the trace
    fingerprints are untouched. Fresh searches stamp the envelope themselves;
    this path exists for the zoo that predates it."""
    from repro.numerics import build_envelope, load_plan, load_trace

    failures, done = 0, 0
    only = set(args.only or ())
    for fn in sorted(os.listdir(args.out)):
        if not fn.endswith(".json") or fn == "MANIFEST.json":
            continue
        arch_id = fn[:-len(".json")]
        if only and arch_id not in only:
            continue
        path = os.path.join(args.out, fn)
        plan = load_plan(path)
        trace_rel = plan.meta.get("trace")
        if not trace_rel:
            print(f"[{arch_id}] SKIP: plan records no trace path — "
                  "recalibrate before deriving an envelope")
            failures += 1
            continue
        try:
            trace = load_trace(os.path.join(args.out, trace_rel),
                               expect_fingerprint=plan.meta.get("fingerprint"))
        except (OSError, ValueError) as e:
            print(f"[{arch_id}] FAIL: {e}")
            failures += 1
            continue
        plan.meta["envelope"] = build_envelope(trace, plan)
        plan.save(path)
        n = len(plan.meta["envelope"]["sites"])
        print(f"[{arch_id}] envelope derived from {trace_rel} "
              f"({n} gemm sites) -> {fn}")
        done += 1
    if not args.no_manifest:
        rebuild_manifest(args.out)
    print(f"[envelopes] {done} plan(s) updated, {failures} failure(s)")
    if failures:
        sys.exit(1)


def _provenance() -> dict:
    """Where this artifact was measured/searched: backend + device topology.
    Consumers (check_plan_zoo.py) treat an absent record as the historical
    single-device default, so pre-provenance artifacts stay valid."""
    import jax
    return {"backend": jax.default_backend(),
            "devices": jax.device_count(),
            "process_count": jax.process_count()}


def _alias_of(arch_id: str) -> str:
    from repro.configs import _ALIASES
    for alias, mod in _ALIASES.items():
        if mod == arch_id:
            return alias
    return arch_id


def _calibration_spec(cfg, reduced: bool, phases: tuple) -> dict:
    """Everything the trace depends on — hashed into the fingerprint.
    ``phases`` joins the spec only when the backward namespace is calibrated,
    so every pre-phase (fwd-only) trace keeps its original fingerprint and
    the checked-in zoo stays reproducible without a recalibration sweep."""
    import dataclasses
    spec = {"config": dataclasses.asdict(cfg), "reduced": reduced,
            "batch": CAL_BATCH, "seq": CAL_SEQ, "seed": CAL_SEED,
            "calibration_policy": "mxu_fp32"}
    if "bwd" in phases:
        spec["phases"] = sorted(phases)
    return spec


def _calibration_batch(cfg, *, with_targets: bool = False):
    # the bwd calibration step (and the grad workload) runs the real training
    # loss, so gradient sites see CE-shaped cotangents rather than synthetic
    # ones; the recipe lives in repro.workloads so validators probe the same
    # data distribution the plan was calibrated on
    from repro.workloads import make_probe_batch
    return make_probe_batch(cfg, batch_size=CAL_BATCH, seq=CAL_SEQ,
                            seed=CAL_SEED + 1, with_targets=with_targets)


def _profile_aux_sites(trace, cfg, params, *, steps: int = 3,
                       lr: float = 3e-3) -> None:
    """Profile the non-GEMM precision sites — optimizer-moment value streams
    (``opt.m@state`` / ``opt.v@state``) and the gradient-collective payload
    (``grad_psum@coll``) — with a short fp32 Adam run, so the search can
    enumerate block-scaled formats against the magnitudes the sites really
    carry. Runs *outside* the calibration hook (the GEMM profiles' call/mac
    counts must not double-count these extra steps) and only on fresh
    calibrations: the aux profiles persist inside the saved trace, keeping
    ``--check`` reruns deterministic, and pre-aux saved traces simply search
    no aux sites."""
    import jax
    import jax.numpy as jnp

    from repro.core import qformat
    from repro.core.dispatch import MXU_FP32, use_policy
    from repro.models import LOCAL
    from repro.train.loop import make_loss_fn
    from repro.train.optimizer import adamw, apply_updates

    loss_fn = make_loss_fn(cfg, LOCAL, remat="none")
    grad_batch = _calibration_batch(cfg, with_targets=True)
    opt = adamw(lr)
    with use_policy(MXU_FP32):
        p, ostate = params, opt.init(params)
        grads = None
        for _ in range(steps):
            (_, _aux), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(p, grad_batch)
            trace.record_aux(qformat.GRAD_PSUM_SITE, grads)
            updates, ostate = opt.update(grads, ostate, p)
            p = apply_updates(p, updates)
        trace.record_aux(qformat.OPT_M_SITE, ostate["mu"])
        # nu is *stored* in sqrt domain (train.optimizer's second-moment
        # safety contract), so the profiled stream is sqrt(nu)
        trace.record_aux(qformat.OPT_V_SITE,
                         jax.tree.map(jnp.sqrt, ostate["nu"]))


class CheckDrift(Exception):
    """--check failure with a readable per-key drift summary."""

    def __init__(self, arch_id: str, lines: list):
        self.arch_id = arch_id
        self.lines = list(lines)
        super().__init__(f"[{arch_id}] --check FAILED "
                         f"({len(self.lines)} divergence(s))")


def _drift_lines(recomputed, checked_in) -> list:
    """Human-readable divergences between a recomputed plan and the
    checked-in one: which site / score / key moved, and how."""
    lines = []
    got = {s.site: s.cfg.tag() for s in recomputed.sites}
    want = {s.site: s.cfg.tag() for s in checked_in.sites}
    for site in sorted(want.keys() - got.keys()):
        lines.append(f"site {site}: checked-in has {want[site]}, "
                     "recomputed search dropped it")
    for site in sorted(got.keys() - want.keys()):
        lines.append(f"site {site}: recomputed search added {got[site]}, "
                     "not in checked-in plan")
    for site in sorted(got.keys() & want.keys()):
        if got[site] != want[site]:
            lines.append(f"site {site}: recomputed {got[site]} != "
                         f"checked-in {want[site]}")
    if recomputed.budget_bits != checked_in.budget_bits:
        lines.append(f"budget_bits: recomputed {recomputed.budget_bits} != "
                     f"checked-in {checked_in.budget_bits}")
    # end-to-end scores: exact equality is a same-machine property, so the
    # gate allows a small cross-machine tolerance on native-backend noise
    tol = 1.0
    gv = recomputed.meta.get("validation", {})
    wv = checked_in.meta.get("validation", {})
    for name in sorted(gv.keys() ^ wv.keys()):
        side = "recomputed" if name in gv else "checked-in"
        lines.append(f"workload {name!r}: only the {side} plan has a score "
                     "(validator sets differ?)")
    for name in sorted(gv.keys() & wv.keys()):
        g, w = gv[name].get("score"), wv[name].get("score")
        if g is None or w is None:
            if g != w:
                lines.append(f"workload {name!r}: score {g!r} vs {w!r}")
        elif abs(g - w) > tol:
            lines.append(f"workload {name!r}: recomputed score {g:.2f} "
                         f"drifted from checked-in {w:.2f} (> {tol} bits)")
    for key in ("validated_bits",):
        g, w = recomputed.meta.get(key), checked_in.meta.get(key)
        if g is not None and w is not None and abs(g - w) > tol:
            lines.append(f"{key}: recomputed {g:.2f} != checked-in {w:.2f} "
                         f"(> {tol} bits)")
    return lines


def refresh_arch(arch_id: str, args) -> dict:
    """Calibrate (or reload the saved trace) + search one architecture;
    returns the plan's manifest entry. Writes the plan unless --check."""
    import jax

    from repro.configs import get_config
    from repro.core.dispatch import MXU_FP32, use_policy
    from repro.models import LOCAL, forward, init
    from repro.numerics import (calibrate, config_fingerprint, load_plan,
                                load_trace, search)
    from repro.workloads import WorkloadContext, build_validators

    t0 = time.time()
    phases = tuple(args.phases.split(","))
    cfg = get_config(arch_id)
    if args.reduced:
        cfg = cfg.reduced()
    fp = config_fingerprint(_calibration_spec(cfg, args.reduced, phases))
    traces_dir = os.path.join(args.out, "traces")
    os.makedirs(traces_dir, exist_ok=True)
    trace_path = os.path.join(traces_dir, f"{arch_id}.trace.json")
    plan_path = os.path.join(args.out, f"{arch_id}.json")

    params = init(cfg, jax.random.key(CAL_SEED))
    batch = _calibration_batch(cfg)

    trace = None
    if os.path.exists(trace_path) and not args.recalibrate:
        try:
            trace = load_trace(trace_path, expect_fingerprint=fp)
            print(f"[{arch_id}] trace loaded from {trace_path} "
                  f"(calibration skipped, fingerprint {fp})")
        except ValueError as e:
            print(f"[{arch_id}] saved trace is stale: {e}")
    if trace is None and args.check:
        # the reproducibility gate's whole claim is "searched from the saved
        # trace, no recalibration" — a missing/stale trace must fail loudly,
        # not quietly recalibrate into a possibly-matching plan
        raise CheckDrift(arch_id, [
            f"no usable saved trace at {trace_path} (expected fingerprint "
            f"{fp}) — refresh and commit the trace before gating on it"])
    if trace is None:
        print(f"[{arch_id}] calibrating {cfg.name} "
              f"(batch={CAL_BATCH}, seq={CAL_SEQ}, phases={phases})")
        with calibrate() as trace, use_policy(MXU_FP32):
            jax.block_until_ready(
                forward(params, cfg, batch, LOCAL, remat="none"))
            if "bwd" in phases:
                # a real value_and_grad step through the training loss: the
                # dispatch custom_vjp fires every gradient GEMM under its
                # phase-qualified site key, so the trace records the bwd
                # namespace's own exponent ranges / cancellation / samples
                from repro.train.loop import make_loss_fn
                loss_fn = make_loss_fn(cfg, LOCAL, remat="none")
                grad_batch = _calibration_batch(cfg, with_targets=True)
                jax.block_until_ready(jax.value_and_grad(
                    loss_fn, has_aux=True)(params, grad_batch))
        if "bwd" in phases:
            _profile_aux_sites(trace, cfg, params)
        trace.save(trace_path, fingerprint=fp,
                   meta={"arch": arch_id, "arch_alias": _alias_of(arch_id),
                         "config_name": cfg.name, "family": cfg.family,
                         "reduced": args.reduced, "phases": sorted(phases),
                         "batch": CAL_BATCH, "seq": CAL_SEQ})
        n_bwd = len(trace.sites("bwd"))
        print(f"[{arch_id}] trace saved to {trace_path} "
              f"({len(trace.sites('fwd'))} fwd / {n_bwd} bwd / "
              f"{len(trace.aux_sites())} aux sites)")

    # end-to-end acceptance: the workload zoo (grad vs 91-bit-bwd reference,
    # logit fidelity vs the uniform oracle, K-reorder stability, ... per
    # --validators), wired into the search's upgrade loop
    names = [n for n in args.validators.split(",") if n and n != "none"]
    validators = None
    if names:
        ctx = WorkloadContext(
            budget_bits=args.budget, cfg=cfg, params=params, batch=batch,
            grad_batch=_calibration_batch(cfg, with_targets=True),
            dist=LOCAL, seed=CAL_SEED)
        validators = build_validators(names, ctx)

    grid = dict(widths=(32,)) if args.reduced else dict(widths=(24, 40, 64))
    res = search(trace, budget_bits=args.budget, name=cfg.name,
                 validators=validators, phases=phases, **grid)
    plan = res.plan
    plan.meta.update({
        "arch": arch_id, "arch_alias": _alias_of(arch_id),
        "family": cfg.family, "reduced": args.reduced,
        "phases": sorted(phases),
        "validators": names,
        "fingerprint": fp,
        "trace": os.path.join("traces", f"{arch_id}.trace.json"),
        "provenance": _provenance(),
    })
    print(res.describe())

    if args.check:
        try:
            want = load_plan(plan_path)
        except FileNotFoundError:
            raise CheckDrift(arch_id, [f"no checked-in plan at {plan_path}"])
        lines = _drift_lines(plan, want)
        if lines:
            raise CheckDrift(arch_id, lines)
        print(f"[{arch_id}] --check OK: recomputed plan matches {plan_path} "
              f"({len(plan.sites)} sites, {time.time() - t0:.0f}s)")
    else:
        plan.save(plan_path)
        print(f"[{arch_id}] plan written to {plan_path} "
              f"({time.time() - t0:.0f}s)")
    return manifest_entry(arch_id, plan)


def manifest_entry(arch_id: str, plan) -> dict:
    from repro.workloads import validation_summary
    m = plan.meta
    return {
        "file": f"{arch_id}.json",
        "name": plan.name,
        "arch": m.get("arch_alias", arch_id),
        "family": m.get("family"),
        "reduced": m.get("reduced"),
        "phases": m.get("phases", ["fwd"]),
        "budget_bits": plan.budget_bits,
        "validated_bits": m.get("validated_bits"),
        # per-workload end-to-end scores (repro.workloads) this plan was
        # accepted on, plus which searched sites the validators widened
        "validation": validation_summary(m),
        "validation_upgrades": m.get("validation_upgrades", []),
        "modeled_energy_j": m.get("modeled_energy_j"),
        # the measured fwd/bwd energy split (bwd is 0/absent for plans
        # searched before the phase-aware namespaces existed)
        "modeled_energy_fwd_j": m.get("modeled_energy_fwd_j"),
        "modeled_energy_bwd_j": m.get("modeled_energy_bwd_j"),
        "baseline_energy_j": m.get("baseline_energy_j"),
        "energy_vs_baseline": m.get("energy_vs_baseline"),
        # training-memory / comms byte axes (absent for gemm-only plans)
        "bytes_resident_vs_fp32": m.get("bytes_resident_vs_fp32"),
        "bytes_moved_vs_fp32": m.get("bytes_moved_vs_fp32"),
        "n_sites": len(plan.sites),
        # live-monitor coverage: GEMM sites with a serialized calibration
        # envelope (repro.obs compares live traffic against these bounds)
        "n_envelope_sites": len((m.get("envelope") or {}).get("sites", {})),
        "n_bwd_sites": sum(s.phase == "bwd" for s in plan.sites),
        "n_aux_sites": sum(s.kind != "gemm" for s in plan.sites),
        "sites": [s.site for s in plan.sites],
        "fingerprint": m.get("fingerprint"),
        "trace": m.get("trace"),
        # where this plan was searched/validated; absent = single-device
        # (pre-provenance zoo entries)
        "provenance": m.get("provenance"),
    }


def rebuild_manifest(out_dir: str) -> dict:
    """Regenerate MANIFEST.json from the plan files on disk (idempotent, so
    parallel --jobs children don't race on it — only the parent writes)."""
    from repro.numerics import load_plan
    plans = {}
    for fn in sorted(os.listdir(out_dir)):
        if not fn.endswith(".json") or fn == "MANIFEST.json":
            continue
        arch_id = fn[:-len(".json")]
        plans[arch_id] = manifest_entry(arch_id,
                                        load_plan(os.path.join(out_dir, fn)))
    doc = {"kind": MANIFEST_KIND, "version": MANIFEST_VERSION,
           "generated_by": "scripts/refresh_plans.py", "plans": plans}
    path = os.path.join(out_dir, "MANIFEST.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"[manifest] {len(plans)} plans -> {path}")
    return doc


def _spawn(arch_id: str, args) -> tuple:
    """Child process for --jobs fan-out (the calibration hook is process-
    global, so parallelism must be process-level, not threads)."""
    cmd = [sys.executable, os.path.abspath(__file__), "--only", arch_id,
           "--budget", str(args.budget), "--out", args.out, "--no-manifest",
           "--phases", args.phases, "--validators", args.validators]
    for flag in ("reduced", "recalibrate", "check"):
        if getattr(args, flag):
            cmd.append(f"--{flag}")
    env = dict(os.environ)
    t0 = time.time()
    try:
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           timeout=3600)
        rc, out = r.returncode, r.stdout + "\n" + r.stderr
    except subprocess.TimeoutExpired as e:
        # one slow arch is that arch's failure, not the whole sweep's
        rc = -1
        partial = e.stdout if isinstance(e.stdout, str) else ""
        out = f"[{arch_id}] timed out after {e.timeout:.0f}s\n{partial}"
    if rc != 0:
        sys.stderr.write(out)
    return arch_id, rc, time.time() - t0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", action="append", default=None,
                    help="restrict to these arch ids (repeatable)")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced() configs (CPU-sized; what CI checks in)")
    ap.add_argument("--budget", type=float, default=10.0)
    ap.add_argument("--resume", action="store_true",
                    help="skip archs whose plan file already exists")
    ap.add_argument("--recalibrate", action="store_true",
                    help="ignore saved traces, re-run calibration forwards")
    ap.add_argument("--phases", default="fwd,bwd",
                    help="comma list of site namespaces to calibrate+search: "
                         "'fwd,bwd' (default: a value_and_grad step gives "
                         "gradient GEMMs their own traced, searched "
                         "assignments) or 'fwd' (matches pre-phase traces)")
    ap.add_argument("--validators", default="grad,logits,repro",
                    help="comma list of repro.workloads validators gating "
                         "the search end-to-end ('none' disables; the "
                         "ill-conditioned 'solve' workload is opt-in)")
    ap.add_argument("--jobs", type=int, default=1,
                    help="process-parallel arch fan-out")
    ap.add_argument("--check", action="store_true",
                    help="recompute and compare against the checked-in plan "
                         "instead of writing (CI reproducibility gate)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--no-manifest", action="store_true",
                    help="skip the MANIFEST rebuild (used by --jobs children)")
    ap.add_argument("--schedules", action="store_true",
                    help="refresh the GemmPlan schedule zoo "
                         "(<out>/schedules/<backend>.json) instead of the "
                         "precision-plan sweep")
    ap.add_argument("--envelopes", action="store_true",
                    help="derive meta['envelope'] for checked-in plans from "
                         "their saved traces (no recalibration/search)")
    args = ap.parse_args(argv)
    args.out = os.path.abspath(args.out)
    if args.schedules:
        refresh_schedules(args)
        return
    if args.envelopes:
        refresh_envelopes(args)
        return
    bad = set(args.phases.split(",")) - {"fwd", "bwd"}
    if bad:
        raise SystemExit(f"--phases: unknown namespaces {sorted(bad)} "
                         "(expected a comma list of fwd,bwd)")

    from repro.configs import ARCH_IDS
    archs = list(args.only) if args.only else list(ARCH_IDS)
    unknown = [a for a in archs if a not in ARCH_IDS]
    if unknown:
        raise SystemExit(f"unknown arch ids {unknown}; known: {ARCH_IDS}")
    if args.resume:
        archs = [a for a in archs
                 if not os.path.exists(os.path.join(args.out, f"{a}.json"))]
        if not archs:
            print("[refresh] nothing to do (--resume: all plans exist)")
    os.makedirs(args.out, exist_ok=True)

    failures = 0
    drifted: list = []
    if args.jobs > 1 and len(archs) > 1:
        with ThreadPoolExecutor(max_workers=args.jobs) as ex:
            for arch_id, rc, dt in ex.map(lambda a: _spawn(a, args), archs):
                status = "ok" if rc == 0 else f"FAIL rc={rc}"
                print(f"[refresh] {arch_id}: {status} ({dt:.0f}s)",
                      flush=True)
                failures += rc != 0
    else:
        for arch_id in archs:
            try:
                refresh_arch(arch_id, args)
            except CheckDrift as e:         # readable per-arch drift report
                failures += 1
                drifted.append(e)
                print(f"[{e.arch_id}] --check FAILED: recomputed plan "
                      f"diverges from the checked-in one:")
                for line in e.lines:
                    print(f"    - {line}")
            except Exception as e:          # keep sweeping, report at exit
                failures += 1
                import traceback
                print(f"[refresh] {arch_id}: FAIL {type(e).__name__}: {e}")
                traceback.print_exc()

    if drifted:
        print(f"[check] {len(drifted)} arch(es) drifted: "
              + ", ".join(e.arch_id for e in drifted))
    if not args.no_manifest and not args.check:
        rebuild_manifest(args.out)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
