#!/usr/bin/env python
"""Observability gate: the serving drivers' ``--metrics-dump`` snapshot must
prove the monitoring story end to end, in CI, on every PR.

Two dump modes over a ``repro.obs.ServingMetricsDump`` document:

  clean (default)     every monitored site classifies ``inside`` its
                      calibration envelope with zero overflow events, the
                      unified registry carries the monitor/plan-cache
                      families, and the request accounting balances
                      (submitted == routed + parked + rejected, fully
                      drained).
  --expect-violation  the named site — and only that site — classifies
                      ``violated``, with at least one overflow event and a
                      detail string that attributes it (the injected
                      out-of-envelope dispatch was *detected and named*).

``--trace trace.json`` additionally validates a ``--trace-out`` Chrome-trace
export (well-formed complete events, serving request spans present).

``--bench BENCH_serving.json --max-overhead 0.05`` gates the monitoring
overhead row emitted by ``benchmarks/bench_serving.py``: steady-state
monitored throughput must stay within 5% of the unmonitored pass.

    PYTHONPATH=src python -m repro.serving --arch paper-mlp --reduced \
        --requests 6 --metrics-dump obs.json --trace-out trace.json
    python scripts/check_obs_snapshot.py obs.json --trace trace.json
"""
from __future__ import annotations

import argparse
import json
import sys

INSIDE, NEAR_EDGE, VIOLATED, UNMONITORED = (
    "inside", "near-edge", "violated", "no-envelope")

REQUIRED_FAMILIES = ("repro_monitor_calls_total", "repro_envelope_status",
                     "repro_plan_cache_ops_total")


def _counter_total(metrics: dict, name: str) -> float:
    fam = metrics.get("metrics", {}).get(name)
    if fam is None:
        return 0.0
    return sum(s.get("value", 0.0) for s in fam.get("values", []))


def check_dump(doc: dict, expect_violation: str | None) -> list:
    errors = []
    if doc.get("kind") != "repro.obs.ServingMetricsDump":
        errors.append(f"dump kind {doc.get('kind')!r} != "
                      "repro.obs.ServingMetricsDump")
    metrics = doc.get("metrics") or {}
    if metrics.get("kind") != "repro.obs.MetricsSnapshot":
        errors.append("dump carries no registry snapshot under 'metrics'")
    families = metrics.get("metrics", {})
    for name in REQUIRED_FAMILIES:
        if name not in families:
            errors.append(f"registry family {name} missing from snapshot")
    if "serving" in doc and "repro_serving_requests_total" not in families:
        errors.append("serving dump without repro_serving_requests_total")
    if _counter_total(metrics, "repro_monitor_calls_total") <= 0:
        errors.append("monitor recorded no GEMM dispatches "
                      "(repro_monitor_calls_total == 0)")

    mon = doc.get("monitor")
    if not mon:
        errors.append("dump carries no monitor snapshot")
        return errors
    sites = mon.get("sites", {})
    if not sites:
        errors.append("monitor snapshot has no sites")
    live = {s: info for s, info in sites.items() if info.get("live")}
    if not live:
        errors.append("no site saw live traffic")

    if expect_violation is None:
        if mon.get("worst_status") != INSIDE:
            errors.append(f"worst_status {mon.get('worst_status')!r} != "
                          f"{INSIDE!r} on clean traffic")
        if mon.get("overflow_events", -1) != 0:
            errors.append(f"{mon.get('overflow_events')} overflow events on "
                          "clean traffic")
        for s, info in sites.items():
            if info.get("status") not in (INSIDE, UNMONITORED):
                errors.append(f"site {s}: {info.get('status')} "
                              f"({info.get('detail')})")
        if not any(info.get("status") == INSIDE for info in live.values()):
            errors.append("no live site classified against an envelope")
    else:
        bad = sites.get(expect_violation)
        if bad is None:
            errors.append(f"expected violated site {expect_violation!r} "
                          "absent from monitor snapshot")
        elif bad.get("status") != VIOLATED:
            errors.append(f"site {expect_violation}: status "
                          f"{bad.get('status')!r} != {VIOLATED!r}")
        elif not bad.get("detail"):
            errors.append(f"site {expect_violation}: violated without an "
                          "attributing detail string")
        if mon.get("worst_status") != VIOLATED:
            errors.append("worst_status did not escalate to violated")
        if mon.get("overflow_events", 0) < 1:
            errors.append("violation detected without an overflow event")
        for s, info in live.items():
            if s != expect_violation and info.get("status") not in (
                    INSIDE, UNMONITORED):
                errors.append(f"collateral site {s}: {info.get('status')} "
                              f"({info.get('detail')})")

    serving = doc.get("serving")
    if serving is not None:
        total = (serving.get("routed", 0) + serving.get("parked", 0)
                 + serving.get("rejected", 0))
        if serving.get("submitted") != total:
            errors.append(f"accounting broken: submitted="
                          f"{serving.get('submitted')} != routed+parked+"
                          f"rejected={total}")
        if serving.get("parked"):
            errors.append(f"{serving['parked']} request(s) still parked "
                          "after the trace drained")
        if serving.get("completed", 0) > serving.get("routed", 0):
            errors.append("completed exceeds routed")
    return errors


def check_trace(path: str) -> list:
    errors = []
    with open(path) as f:
        doc = json.load(f)
    events = doc.get("traceEvents", [])
    if not events:
        errors.append("trace has no events")
    for ev in events:
        if ev.get("ph") != "X" or ev.get("dur", -1) < 0 or \
                ev.get("ts", -1) < 0:
            errors.append(f"malformed trace event: {ev.get('name')}")
            break
    names = {ev.get("name") for ev in events}
    for want in ("serving.request", "serving.run"):
        if want not in names:
            errors.append(f"no {want!r} span in the trace export")
    return errors


def check_bench(path: str, max_overhead: float) -> list:
    errors = []
    with open(path) as f:
        doc = json.load(f)
    rows = {r.get("name"): r for r in doc.get("rows", [])}
    row = rows.get("serving_monitor_overhead")
    if row is None:
        return [f"{path}: no serving_monitor_overhead row — "
                "bench_serving.py did not run the monitored pass"]
    frac = row.get("overhead_frac")
    if frac is None:
        errors.append("overhead row carries no overhead_frac")
    elif frac > max_overhead:
        errors.append(
            f"monitoring overhead {frac:.1%} > {max_overhead:.0%} budget "
            f"({row.get('monitored_seconds_per_call'):.2e}s vs "
            f"{row.get('baseline_seconds_per_call'):.2e}s per anchor GEMM)")
    for key in ("worst_status", "probe_status"):
        if row.get(key) not in (None, INSIDE):
            errors.append(f"monitored bench pass left the envelope: "
                          f"{key}={row.get(key)}")
    if row.get("overflow_events"):
        errors.append(f"{row['overflow_events']} overflow events during the "
                      "monitored bench pass")
    return errors


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("dump", nargs="?", default=None,
                    help="ServingMetricsDump JSON (--metrics-dump output)")
    ap.add_argument("--expect-violation", default=None, metavar="SITE",
                    help="require SITE (and only SITE) to be violated")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="also validate a --trace-out Chrome-trace export")
    ap.add_argument("--bench", default=None, metavar="PATH",
                    help="gate the serving_monitor_overhead row in a "
                         "bench_serving JSON")
    ap.add_argument("--max-overhead", type=float, default=0.05,
                    help="monitoring overhead budget for --bench "
                         "(fraction, default 0.05)")
    args = ap.parse_args(argv)
    if args.dump is None and args.bench is None:
        ap.error("nothing to check: pass a dump path and/or --bench")

    errors = []
    if args.dump:
        with open(args.dump) as f:
            doc = json.load(f)
        errors += [f"{args.dump}: {e}"
                   for e in check_dump(doc, args.expect_violation)]
    if args.trace:
        errors += [f"{args.trace}: {e}" for e in check_trace(args.trace)]
    if args.bench:
        errors += check_bench(args.bench, args.max_overhead)

    if errors:
        for e in errors:
            print(f"[check_obs_snapshot] FAIL {e}")
        sys.exit(1)
    checked = [p for p in (args.dump, args.trace, args.bench) if p]
    mode = (f"violation at {args.expect_violation}" if args.expect_violation
            else "clean envelope")
    print(f"[check_obs_snapshot] OK ({mode}): {', '.join(checked)}")


if __name__ == "__main__":
    main()
