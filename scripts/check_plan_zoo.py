#!/usr/bin/env python
"""Plan-zoo gate: checked-in precision plans can never silently rot.

For every ``examples/plans/*.json`` (except MANIFEST.json) this

  1. loads the plan and round-trips it through ``policy_from_plan`` (the
     exact entry point the launch drivers use), checking every site's
     assignment survives the JSON -> NumericsPolicy path,
  2. cross-checks the MANIFEST entry (file listed, site list and energy
     bookkeeping in sync with the plan document),
  3. dry-runs the plan's own architecture through the serving driver with
     ``--precision-plan`` on the reduced config — a real forward + decode
     under the plan's numerics, so a plan whose formats/accumulators no
     longer load, dispatch, or produce tokens fails the lane.

    PYTHONPATH=src python scripts/check_plan_zoo.py
    PYTHONPATH=src python scripts/check_plan_zoo.py --no-serve   # fast half
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

PLANS_DIR = os.path.join(os.path.dirname(__file__), os.pardir,
                         "examples", "plans")


def check_plan(path: str, manifest: dict, serve: bool = True) -> list:
    from repro.core.dispatch import policy_from_plan
    from repro.numerics import PLAN_VERSION, load_plan

    errors = []
    arch_id = os.path.basename(path)[:-len(".json")]
    plan = load_plan(path)
    if plan.version > PLAN_VERSION:
        errors.append(f"version {plan.version} > library {PLAN_VERSION}")
    if not plan.sites:
        errors.append("plan has no sites")

    # 1. policy round-trip through the deployment entry point
    policy = policy_from_plan(path)
    for s in plan.sites:
        got = policy.lookup(s.site).tag()
        if got != s.cfg.tag():
            errors.append(f"site {s.site}: policy lookup {got!r} != plan "
                          f"{s.cfg.tag()!r}")
    if policy.lookup("__unlisted__").tag() != plan.default.tag():
        errors.append("default config lost in policy round-trip")

    # 2. MANIFEST consistency
    entry = manifest.get("plans", {}).get(arch_id)
    if entry is None:
        errors.append("no MANIFEST entry")
    else:
        if entry.get("sites") != [s.site for s in plan.sites]:
            errors.append("MANIFEST site list out of sync")
        for key in ("modeled_energy_j", "baseline_energy_j",
                    "validated_bits"):
            if entry.get(key) != plan.meta.get(key):
                errors.append(f"MANIFEST {key} out of sync")
        if entry.get("budget_bits") != plan.budget_bits:
            errors.append("MANIFEST budget_bits out of sync")

    # 3. dry-run the plan's arch under --precision-plan (one plan crashing
    # must not mask whether the rest of the zoo still serves)
    if serve and not errors and entry is not None:
        from repro.launch import serve as serve_mod
        try:
            serve_mod.main(["--arch", entry["arch"], "--reduced",
                            "--batch", "1", "--prompt-len", "4",
                            "--gen", "2", "--precision-plan", path])
        except Exception as e:
            errors.append(f"serve dry-run crashed: {type(e).__name__}: {e}")
    return errors


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--plans", default=PLANS_DIR)
    ap.add_argument("--no-serve", action="store_true",
                    help="skip the serve dry-runs (load/round-trip only)")
    args = ap.parse_args(argv)

    paths = sorted(p for p in glob.glob(os.path.join(args.plans, "*.json"))
                   if os.path.basename(p) != "MANIFEST.json")
    if not paths:
        raise SystemExit(f"no plans found under {args.plans}")
    manifest_path = os.path.join(args.plans, "MANIFEST.json")
    if not os.path.exists(manifest_path):
        raise SystemExit(f"missing {manifest_path} — run "
                         "scripts/refresh_plans.py")
    with open(manifest_path) as f:
        manifest = json.load(f)
    listed = set(manifest.get("plans", {}))
    on_disk = {os.path.basename(p)[:-len('.json')] for p in paths}
    failures = 0
    for stale in sorted(listed - on_disk):
        print(f"[plan-zoo] {stale}: MANIFEST lists a plan with no file")
        failures += 1

    for path in paths:
        name = os.path.basename(path)
        errors = check_plan(path, manifest, serve=not args.no_serve)
        if errors:
            failures += 1
            print(f"[plan-zoo] {name}: FAIL")
            for e in errors:
                print(f"    - {e}")
        else:
            print(f"[plan-zoo] {name}: OK")

    if failures:
        print(f"[plan-zoo] FAIL: {failures} problem(s)")
        sys.exit(1)
    print(f"[plan-zoo] OK: {len(paths)} plans load, round-trip, and serve")


if __name__ == "__main__":
    main()
