#!/usr/bin/env python
"""Plan-zoo gate: checked-in precision plans can never silently rot.

For every ``examples/plans/*.json`` (except MANIFEST.json) this

  1. loads the plan and round-trips it through ``policy_from_plan`` (the
     exact entry point the launch drivers use), checking every site's
     assignment survives the JSON -> NumericsPolicy path — including that
     every site key parses as a valid ``GemmSite`` (phase-qualified
     ``name@bwd.dA`` keys included) and that the backward-namespace fallback
     (``bwd_default`` -> ``*@bwd`` override) deploys,
  2. asserts the plan carries per-workload end-to-end validation evidence
     (``meta.validation``, written by the ``repro.workloads`` validators at
     search time) and that the MANIFEST entry summarizes the same scores,
  3. cross-checks the MANIFEST entry (file listed, site list and energy
     bookkeeping in sync with the plan document) and that it carries the
     routing metadata ``repro.serving.PlanRouter`` ranks by — numeric
     per-workload validation scores and numeric energy,
  4. dry-runs the plan's own architecture through the serving driver with
     ``--precision-plan`` on the reduced config — a real forward + decode
     under the plan's numerics, so a plan whose formats/accumulators no
     longer load, dispatch, or produce tokens fails the lane.

It also asserts the v1 -> current loader migration on the checked-in v1
fixture (``examples/plans/fixtures/paper_mlp.v1.json``): plain-name
assignments stay forward-only, the synthesized widened ``bwd_default``
round-trips, and saving the migrated plan re-loads identically.

    PYTHONPATH=src python scripts/check_plan_zoo.py
    PYTHONPATH=src python scripts/check_plan_zoo.py --no-serve   # fast half
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

PLANS_DIR = os.path.join(os.path.dirname(__file__), os.pardir,
                         "examples", "plans")


def check_plan(path: str, manifest: dict, serve: bool = True) -> list:
    from repro.core.dispatch import GemmSite, policy_from_plan
    from repro.numerics import PLAN_VERSION, load_plan

    errors = []
    arch_id = os.path.basename(path)[:-len(".json")]
    plan = load_plan(path)
    if plan.version > PLAN_VERSION:
        errors.append(f"version {plan.version} > library {PLAN_VERSION}")
    if not plan.sites:
        errors.append("plan has no sites")

    # 0. every site key must be well-formed for its kind — gemm keys parse
    # as (possibly phase-qualified) GemmSites; aux (@state/@coll) keys carry
    # their kind in the document and it must agree with the key's grammar.
    # A typo'd phase/operand/suffix must fail the lane, not get silently
    # treated as an unmatched pattern at serve time
    from repro.core.qformat import site_kind
    for s in plan.sites:
        if s.kind != "gemm":
            if site_kind(s.site) != s.kind:
                errors.append(f"aux site {s.site!r}: key grammar says "
                              f"{site_kind(s.site)!r}, document says "
                              f"{s.kind!r}")
            continue
        try:
            site = GemmSite.parse(s.site)
        except ValueError as e:
            errors.append(f"site key {s.site!r} does not parse: {e}")
            continue
        if site.key != s.site:
            errors.append(f"site key {s.site!r} is not canonical "
                          f"(expected {site.key!r})")

    # 1. policy round-trip through the deployment entry point (aux sites
    # deploy through NumericsPolicy.aux, gemm sites through overrides)
    policy = policy_from_plan(path)
    for s in plan.sites:
        if s.kind != "gemm":
            aux = policy.aux_lookup(s.site)
            if aux is None or aux.tag() != s.cfg.tag():
                errors.append(f"aux site {s.site}: policy aux_lookup "
                              f"{aux and aux.tag()!r} != plan "
                              f"{s.cfg.tag()!r}")
            continue
        got = policy.lookup(s.site).tag()
        if got != s.cfg.tag():
            errors.append(f"site {s.site}: policy lookup {got!r} != plan "
                          f"{s.cfg.tag()!r}")
    if policy.lookup("__unlisted__").tag() != plan.default.tag():
        errors.append("default config lost in policy round-trip")
    # unassigned bwd sites must fall to the widened bwd_default (which every
    # loaded plan has: v2 carries it, v1 synthesizes it in migration)
    if plan.bwd_default is None:
        errors.append("loaded plan has no bwd_default (migration broken?)")
    elif policy.lookup("__unlisted__@bwd.dA").tag() != plan.bwd_default.tag():
        errors.append("bwd_default not deployed as the *@bwd fallback")

    # 2. every checked-in plan must carry the per-workload end-to-end
    # evidence it was accepted on (repro.workloads reports serialized at
    # search time) — a plan with no validation scores is a plan nobody ran
    from repro.workloads import SUMMARY_KEYS
    validation = plan.meta.get("validation") or {}
    if not validation:
        errors.append("plan meta carries no workload validation scores "
                      "(searched without validators?)")
    for name, rep in validation.items():
        bad = [k for k in SUMMARY_KEYS if rep.get(k) is None]
        if bad:
            errors.append(f"validation[{name!r}] is missing {bad}")
        elif not rep["passed"]:
            # the zoo's contract is "accepted by the gate": a plan whose
            # search exhausted its upgrades below threshold must be
            # re-searched (wider grid / higher budget), not checked in
            errors.append(
                f"validation[{name!r}] recorded a FAILING score "
                f"({rep['score']:.2f} < {rep['threshold']:g} {rep['units']})")
        # mesh provenance: a report may record the device-mesh shape(s) its
        # validation ran under ("2x4", or "1x8,2x4,8x1" for the reshape
        # sweep). Absent = single-device — the historical default, tolerated
        # for every pre-mesh zoo entry. Present, it must be well-formed.
        mesh = rep.get("mesh")
        if mesh is not None:
            from repro.launch.sharding import parse_mesh
            try:
                for shape in str(mesh).split(","):
                    parse_mesh(shape)
            except ValueError as e:
                errors.append(f"validation[{name!r}] mesh provenance "
                              f"{mesh!r} does not parse: {e}")

    # 2b. calibration envelope: the runtime boundary the live monitor
    # (repro.obs) checks traffic against. Every checked-in plan must carry
    # one with a sane schema — a plan without an envelope is a plan whose
    # claims can never be verified in production.
    from repro.numerics import ENVELOPE_VERSION
    env = plan.meta.get("envelope")
    if not isinstance(env, dict) or not env.get("sites"):
        errors.append("meta.envelope missing/empty — run "
                      "scripts/refresh_plans.py --envelopes")
    else:
        if int(env.get("version", 0)) > ENVELOPE_VERSION:
            errors.append(f"envelope version {env.get('version')} > "
                          f"library {ENVELOPE_VERSION}")
        want_fp = plan.meta.get("trace_fingerprint") or \
            plan.meta.get("fingerprint")
        if want_fp and env.get("trace_fingerprint") != want_fp:
            errors.append(
                f"envelope trace_fingerprint {env.get('trace_fingerprint')!r}"
                f" does not match the plan's {want_fp!r}")
        for site, e in env["sites"].items():
            for rng_key in ("a_exp", "b_exp"):
                rng = e.get(rng_key)
                if (not isinstance(rng, list) or len(rng) != 2
                        or not all(v is None or isinstance(v, int)
                                   for v in rng)):
                    errors.append(f"envelope[{site!r}].{rng_key} malformed: "
                                  f"{rng!r}")
            if not isinstance(e.get("msb"), int):
                errors.append(f"envelope[{site!r}].msb malformed: "
                              f"{e.get('msb')!r}")
            if not (isinstance(e.get("calls"), int) and e["calls"] > 0):
                errors.append(f"envelope[{site!r}].calls malformed: "
                              f"{e.get('calls')!r}")
            if not (isinstance(e.get("max_k"), int) and e["max_k"] >= 1):
                errors.append(f"envelope[{site!r}].max_k malformed: "
                              f"{e.get('max_k')!r}")
        missing = [s.site for s in plan.gemm_sites()
                   if s.site not in env["sites"]]
        if missing:
            errors.append(f"envelope covers no entry for searched GEMM "
                          f"site(s) {missing} — re-derive from the trace")

    # 3. MANIFEST consistency
    entry = manifest.get("plans", {}).get(arch_id)
    if entry is None:
        errors.append("no MANIFEST entry")
    else:
        if entry.get("sites") != [s.site for s in plan.sites]:
            errors.append("MANIFEST site list out of sync")
        for key in ("modeled_energy_j", "baseline_energy_j",
                    "validated_bits"):
            if entry.get(key) != plan.meta.get(key):
                errors.append(f"MANIFEST {key} out of sync")
        if entry.get("budget_bits") != plan.budget_bits:
            errors.append("MANIFEST budget_bits out of sync")
        n_env = len((plan.meta.get("envelope") or {}).get("sites", {}))
        if entry.get("n_envelope_sites") != n_env:
            errors.append("MANIFEST n_envelope_sites out of sync")
        from repro.workloads import validation_summary
        if entry.get("validation") != validation_summary(plan.meta):
            errors.append("MANIFEST validation scores out of sync "
                          "with plan meta")
        # provenance (backend + device topology the plan was searched on):
        # absent = single-device, the historical default — tolerated for
        # every pre-provenance entry. Present, it must be a record with a
        # backend name and a positive device count, in sync with the plan.
        prov = entry.get("provenance")
        if prov is not None:
            if (not isinstance(prov, dict) or not prov.get("backend")
                    or not isinstance(prov.get("devices"), int)
                    or prov["devices"] < 1):
                errors.append(f"MANIFEST provenance malformed: {prov!r}")
            if prov != plan.meta.get("provenance"):
                errors.append("MANIFEST provenance out of sync with plan")

        # 3b. routing metadata: the serving tier's PlanRouter ranks plans by
        # the MANIFEST's recorded evidence — every entry must carry numeric
        # per-workload scores and numeric energy, or routing silently loses
        # this arch. routed_plan_from_entry raises ValueError on exactly the
        # fields the router reads.
        from repro.serving import routed_plan_from_entry
        try:
            rp = routed_plan_from_entry(arch_id, entry,
                                        os.path.dirname(path))
        except ValueError as e:
            errors.append(f"routing metadata invalid: {e}")
        else:
            if not rp.scores:
                errors.append("routing metadata: no workload scores")

    # 4. dry-run the plan's arch under --precision-plan (one plan crashing
    # must not mask whether the rest of the zoo still serves)
    if serve and not errors and entry is not None:
        from repro.launch import serve as serve_mod
        try:
            serve_mod.main(["--arch", entry["arch"], "--reduced",
                            "--batch", "1", "--prompt-len", "4",
                            "--gen", "2", "--precision-plan", path])
        except Exception as e:
            errors.append(f"serve dry-run crashed: {type(e).__name__}: {e}")
    return errors


def check_v1_migration(fixture_path: str) -> list:
    """The v1 -> current loader migration, asserted on a frozen v1
    document."""
    import json as _json

    from repro.numerics import PLAN_VERSION, PrecisionPlan, load_plan
    from repro.core.dispatch import widen_config

    errors = []
    if not os.path.exists(fixture_path):
        return [f"missing v1 fixture {fixture_path}"]
    with open(fixture_path) as f:
        raw = _json.load(f)
    if int(raw.get("version", 0)) != 1:
        return [f"{fixture_path} is not a v1 document "
                f"(version={raw.get('version')!r}) — the migration gate "
                "needs a real v1 input; do not regenerate this fixture"]
    plan = load_plan(fixture_path)
    if plan.version != PLAN_VERSION:
        errors.append(f"migrated plan reports version {plan.version}")
    if plan.meta.get("migrated_from") != 1:
        errors.append("migration provenance (meta.migrated_from) missing")
    want_bwd = widen_config(plan.default)
    if plan.bwd_default is None or plan.bwd_default.tag() != want_bwd.tag():
        errors.append(f"v1 bwd_default should widen to {want_bwd.tag()!r}, "
                      f"got {plan.bwd_default and plan.bwd_default.tag()!r}")
    pol = plan.to_policy()
    for s in plan.sites:
        # v1 plain-name assignments are forward-only: the bwd twin of every
        # assigned site must fall to the widened default, never inherit
        if pol.lookup(s.site).tag() != s.cfg.tag():
            errors.append(f"fwd lookup changed for {s.site}")
        if pol.lookup(f"{s.site}@bwd.dB").tag() != want_bwd.tag():
            errors.append(f"{s.site}@bwd.dB inherited the fwd assignment")
    # save -> load round-trip of the migrated plan is stable (writes the
    # current schema version)
    reloaded = PrecisionPlan.from_json(plan.to_json())
    if {s.site: s.cfg.tag() for s in reloaded.sites} != \
            {s.site: s.cfg.tag() for s in plan.sites}:
        errors.append("migrated plan round-trip changed site assignments")
    if reloaded.bwd_default.tag() != plan.bwd_default.tag():
        errors.append("migrated plan round-trip lost bwd_default")
    return errors


def check_schedules(schedules_dir: str) -> list:
    """The GemmPlan schedule zoo lane: every checked-in schedule file must
    load (kind/version/fingerprint), carry only deploy-legal fitted
    schedules, and install into a cold plan cache so a warm process really
    takes zero autotune misses on the covered signatures."""
    from repro.core.accumulator import SAFE_CHUNK
    from repro.core.dispatch import (GemmPlan, clear_plan_cache,
                                     plan_cache_stats, plan_gemm)
    from repro.core.formats import get_format
    from repro.core.schedules import ScheduleZoo

    errors = []
    paths = sorted(glob.glob(os.path.join(schedules_dir, "*.json")))
    if not paths:
        return [f"no schedule files under {schedules_dir} — run "
                "scripts/refresh_plans.py --schedules"]
    import jax
    for path in paths:
        name = os.path.basename(path)
        stem = name[:-len(".json")]
        try:
            zoo = ScheduleZoo.load(path)
        except ValueError as e:
            errors.append(f"{name}: {e}")
            continue
        if zoo.backend != stem:
            errors.append(f"{name}: backend {zoo.backend!r} does not match "
                          f"the filename")
        if not zoo.entries:
            errors.append(f"{name}: empty schedule zoo")
        # provenance: absent = single-device (pre-provenance files stay
        # valid); present, it must name a backend and a device count
        prov = zoo.meta.get("provenance")
        if prov is not None and (
                not isinstance(prov, dict) or not prov.get("backend")
                or not isinstance(prov.get("devices"), int)
                or prov["devices"] < 1):
            errors.append(f"{name}: malformed provenance {prov!r}")
        for (batch, m, n, k, fmt_name, spec), plan in zoo.entries.items():
            try:
                get_format(fmt_name)
            except KeyError:
                errors.append(f"{name}: unknown format {fmt_name!r} for "
                              f"{m}x{n}x{k}")
            if plan.bk > SAFE_CHUNK:
                errors.append(f"{name}: {m}x{n}x{k} bk={plan.bk} exceeds "
                              f"the SAFE_CHUNK carry-headroom bound")
            fitted = GemmPlan(plan.bm, plan.bn, plan.bk).fit(m, n, k)
            if fitted.tile != plan.tile:
                errors.append(f"{name}: {m}x{n}x{k} schedule {plan.tile} is "
                              f"not fitted (fit() gives {fitted.tile})")
        # warm-install proof, only meaningful on the file's own backend
        if zoo.backend == jax.default_backend() and not errors:
            clear_plan_cache()
            installed = zoo.install()
            for (batch, m, n, k, fmt_name, spec) in zoo.entries:
                plan_gemm(m, n, k, fmt=get_format(fmt_name), spec=spec,
                          batch=batch)
            st = plan_cache_stats()
            if st.misses != 0 or st.persisted_loads != installed:
                errors.append(
                    f"{name}: warm process still misses "
                    f"({st.misses} misses after installing {installed})")
            clear_plan_cache()
    return errors


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--plans", default=PLANS_DIR)
    ap.add_argument("--no-serve", action="store_true",
                    help="skip the serve dry-runs (load/round-trip only)")
    args = ap.parse_args(argv)

    paths = sorted(p for p in glob.glob(os.path.join(args.plans, "*.json"))
                   if os.path.basename(p) != "MANIFEST.json")
    if not paths:
        raise SystemExit(f"no plans found under {args.plans}")
    manifest_path = os.path.join(args.plans, "MANIFEST.json")
    if not os.path.exists(manifest_path):
        raise SystemExit(f"missing {manifest_path} — run "
                         "scripts/refresh_plans.py")
    with open(manifest_path) as f:
        manifest = json.load(f)
    listed = set(manifest.get("plans", {}))
    on_disk = {os.path.basename(p)[:-len('.json')] for p in paths}
    failures = 0
    for stale in sorted(listed - on_disk):
        print(f"[plan-zoo] {stale}: MANIFEST lists a plan with no file")
        failures += 1

    for path in paths:
        name = os.path.basename(path)
        errors = check_plan(path, manifest, serve=not args.no_serve)
        if errors:
            failures += 1
            print(f"[plan-zoo] {name}: FAIL")
            for e in errors:
                print(f"    - {e}")
        else:
            print(f"[plan-zoo] {name}: OK")

    errors = check_schedules(os.path.join(args.plans, "schedules"))
    if errors:
        failures += 1
        print("[plan-zoo] schedule zoo: FAIL")
        for e in errors:
            print(f"    - {e}")
    else:
        print("[plan-zoo] schedule zoo: OK (loads, fitted, warm-installs "
              "with zero misses)")

    fixture = os.path.join(args.plans, "fixtures", "paper_mlp.v1.json")
    errors = check_v1_migration(fixture)
    if errors:
        failures += 1
        print("[plan-zoo] v1 migration: FAIL")
        for e in errors:
            print(f"    - {e}")
    else:
        print("[plan-zoo] v1 migration: OK "
              "(fwd-only assignments, widened bwd fallback, round-trip)")

    if failures:
        print(f"[plan-zoo] FAIL: {failures} problem(s)")
        sys.exit(1)
    print(f"[plan-zoo] OK: {len(paths)} plans load, round-trip, and serve")


if __name__ == "__main__":
    main()
