"""PrecisionPlan: the deployable output of the tailoring search.

A plan is a versioned JSON document mapping GEMM call-sites to the
⟨format, accumulator, backend⟩ each one earned in the search, plus the
modeled-energy/accuracy bookkeeping that justified the choice. Loading a plan
yields a ``NumericsPolicy`` with per-site overrides, consumed by the launch
drivers via ``--precision-plan`` — the same artifact moves from the search
notebook to serving without translation.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional

from repro.core.accumulator import AccumulatorSpec
from repro.core.dispatch import GemmConfig, NumericsPolicy
from repro.core.formats import get_format

PLAN_VERSION = 1


def _cfg_to_json(cfg: GemmConfig) -> dict:
    acc = None
    if cfg.acc is not None:
        acc = {"ovf": cfg.acc.ovf, "msb": cfg.acc.msb, "lsb": cfg.acc.lsb,
               "round_mode": cfg.acc.round_mode,
               "overflow_mode": cfg.acc.overflow_mode}
    return {"fmt": cfg.fmt.name, "acc": acc, "mode": cfg.mode}


def _cfg_from_json(d: dict) -> GemmConfig:
    acc = None
    if d.get("acc") is not None:
        a = d["acc"]
        acc = AccumulatorSpec(ovf=int(a["ovf"]), msb=int(a["msb"]),
                              lsb=int(a["lsb"]),
                              round_mode=a.get("round_mode", "trunc"),
                              overflow_mode=a.get("overflow_mode", "wrap"))
    return GemmConfig(get_format(d["fmt"]), acc, d.get("mode", "native"))


@dataclasses.dataclass(frozen=True)
class SitePlan:
    """One call-site's assignment plus its search-time evidence."""

    site: str
    cfg: GemmConfig
    error_bits: Optional[float] = None     # vs the site's bit-exact oracle
    energy_j: Optional[float] = None       # modeled, at traced MAC count
    macs: int = 0
    latency_us: Optional[float] = None

    def to_json(self) -> dict:
        d = {"site": self.site, "cfg": _cfg_to_json(self.cfg),
             "macs": self.macs}
        for k in ("error_bits", "energy_j", "latency_us"):
            v = getattr(self, k)
            if v is not None:
                d[k] = v
        return d

    @classmethod
    def from_json(cls, d: dict) -> "SitePlan":
        return cls(site=d["site"], cfg=_cfg_from_json(d["cfg"]),
                   error_bits=d.get("error_bits"),
                   energy_j=d.get("energy_j"), macs=int(d.get("macs", 0)),
                   latency_us=d.get("latency_us"))


@dataclasses.dataclass(frozen=True)
class PrecisionPlan:
    """Versioned, serializable per-site numerics assignment."""

    name: str
    sites: tuple = ()                      # tuple[SitePlan]
    default: GemmConfig = GemmConfig()     # unlisted sites (native bf16)
    budget_bits: Optional[float] = None
    version: int = PLAN_VERSION
    meta: dict = dataclasses.field(default_factory=dict)

    def site(self, name: str) -> Optional[SitePlan]:
        for s in self.sites:
            if s.site == name:
                return s
        return None

    def to_policy(self) -> NumericsPolicy:
        """The NumericsPolicy this plan deploys (exact-match per-site
        overrides over the plan default)."""
        return NumericsPolicy(
            default=self.default,
            overrides=tuple((s.site, s.cfg) for s in self.sites),
            name=f"plan:{self.name}")

    # -- serialization -----------------------------------------------------
    def to_json(self) -> dict:
        return {
            "version": self.version,
            "kind": "repro.numerics.PrecisionPlan",
            "name": self.name,
            "budget_bits": self.budget_bits,
            "default": _cfg_to_json(self.default),
            "sites": [s.to_json() for s in self.sites],
            "meta": self.meta,
        }

    @classmethod
    def from_json(cls, d: dict) -> "PrecisionPlan":
        version = int(d.get("version", 0))
        if version > PLAN_VERSION:
            raise ValueError(
                f"precision plan version {version} is newer than this "
                f"library's {PLAN_VERSION}; refusing to guess its semantics")
        if "sites" not in d or "name" not in d:
            raise ValueError("not a PrecisionPlan document "
                             "(missing 'name'/'sites')")
        return cls(
            name=d["name"],
            sites=tuple(SitePlan.from_json(s) for s in d["sites"]),
            default=_cfg_from_json(d["default"]) if "default" in d
            else GemmConfig(),
            budget_bits=d.get("budget_bits"),
            version=version or PLAN_VERSION,
            meta=dict(d.get("meta", {})),
        )

    def save(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
            f.write("\n")

    def describe(self) -> str:
        lines = [f"PrecisionPlan {self.name!r} v{self.version} "
                 f"(budget {self.budget_bits} bits, "
                 f"default {self.default.tag()})"]
        for s in self.sites:
            bits = f"{s.error_bits:5.1f}b" if s.error_bits is not None else ""
            lines.append(f"  {s.site:14s} {s.cfg.tag():40s} {bits}")
        return "\n".join(lines)


def load_plan(path) -> PrecisionPlan:
    with open(path) as f:
        return PrecisionPlan.from_json(json.load(f))
