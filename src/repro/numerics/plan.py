"""PrecisionPlan: the deployable output of the tailoring search.

A plan is a versioned JSON document mapping GEMM call-sites to the
⟨format, accumulator, backend⟩ each one earned in the search, plus the
modeled-energy/accuracy bookkeeping that justified the choice. Loading a plan
yields a ``NumericsPolicy`` with per-site overrides, consumed by the launch
drivers via ``--precision-plan`` — the same artifact moves from the search
notebook to serving without translation.

Schema v2 (phase-aware sites)
-----------------------------
Site keys are canonical ``GemmSite`` strings: forward sites stay plain names
("attn_qk"), backward sites are phase-qualified ("attn_qk@bwd.dA"). A v2
document additionally carries ``bwd_default`` — the widened fallback config
that the deployed policy installs as a ``*@bwd`` wildcard override, so any
gradient GEMM the search did not assign runs wide instead of silently
inheriting its forward twin's (possibly narrow) datapath.

Schema v3 (aux precision sites)
-------------------------------
v3 adds non-GEMM *aux* sites: optimizer-state (``opt.m@state``) and
collective (``grad_psum@coll``) assignments whose cfg is a block-scaled
``repro.core.qformat.QuantConfig`` (serialized under a ``quant`` key, so a
site's cfg shape says which config family it is). Each ``SitePlan`` carries
``kind`` ("gemm" | "state" | "collective") and, for aux sites,
``bytes_total`` — the modeled resident/wire bytes that are the search's
Pareto cost for that site. ``to_policy`` routes aux assignments into
``NumericsPolicy.aux`` (never ``overrides``: aux keys are not GemmSites).
v2 documents are pure-GEMM and load transparently.

v1 documents load transparently: their plain-name assignments become
forward-only under the phase-aware policy lookup (exactly the v1 dispatch
semantics), ``bwd_default`` is synthesized by widening the plan default
(``repro.core.dispatch.widen_config``), and ``meta.migrated_from`` records
the up-conversion. Saving a migrated plan writes a v2 document.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional

from repro.core.accumulator import AccumulatorSpec
from repro.core.dispatch import (GemmConfig, GemmSite, NumericsPolicy,
                                 widen_config)
from repro.core.formats import get_format
from repro.core.qformat import QuantConfig, site_kind

PLAN_VERSION = 3


def _cfg_to_json(cfg) -> dict:
    if isinstance(cfg, QuantConfig):
        return {"quant": {"bits": cfg.bits, "block": cfg.block,
                          "mode": cfg.mode,
                          "error_feedback": cfg.error_feedback}}
    acc = None
    if cfg.acc is not None:
        acc = {"ovf": cfg.acc.ovf, "msb": cfg.acc.msb, "lsb": cfg.acc.lsb,
               "round_mode": cfg.acc.round_mode,
               "overflow_mode": cfg.acc.overflow_mode}
    return {"fmt": cfg.fmt.name, "acc": acc, "mode": cfg.mode}


def _cfg_from_json(d: dict):
    if "quant" in d:
        q = d["quant"]
        return QuantConfig(bits=int(q.get("bits", 8)),
                           block=int(q.get("block", 64)),
                           mode=q.get("mode", "block"),
                           error_feedback=bool(q.get("error_feedback",
                                                     False)))
    acc = None
    if d.get("acc") is not None:
        a = d["acc"]
        acc = AccumulatorSpec(ovf=int(a["ovf"]), msb=int(a["msb"]),
                              lsb=int(a["lsb"]),
                              round_mode=a.get("round_mode", "trunc"),
                              overflow_mode=a.get("overflow_mode", "wrap"))
    return GemmConfig(get_format(d["fmt"]), acc, d.get("mode", "native"))


@dataclasses.dataclass(frozen=True)
class SitePlan:
    """One call-site's assignment plus its search-time evidence. ``site`` is
    the canonical GemmSite key (phase-qualified for backward sites)."""

    site: str
    cfg: object                            # GemmConfig | QuantConfig (aux)
    kind: str = "gemm"                     # "gemm" | "state" | "collective"
    error_bits: Optional[float] = None     # vs the site's bit-exact oracle
    energy_j: Optional[float] = None       # modeled, at traced MAC count
    macs: int = 0                          # aux sites: element count
    latency_us: Optional[float] = None
    bytes_total: Optional[float] = None    # aux sites: modeled resident/wire

    @property
    def gemm_site(self) -> GemmSite:
        if self.kind != "gemm":
            raise ValueError(f"{self.site!r} is a {self.kind} site, "
                             "not a GemmSite")
        return GemmSite.parse(self.site)

    @property
    def phase(self) -> str:
        """Autodiff phase for GEMM sites; aux sites report their kind (they
        live outside the fwd/bwd namespace, so ``phase_sites`` never
        captures them)."""
        if self.kind != "gemm":
            return self.kind
        return self.gemm_site.phase

    def to_json(self) -> dict:
        d = {"site": self.site, "cfg": _cfg_to_json(self.cfg),
             "macs": self.macs}
        if self.kind != "gemm":
            d["kind"] = self.kind
        for k in ("error_bits", "energy_j", "latency_us", "bytes_total"):
            v = getattr(self, k)
            if v is not None:
                d[k] = v
        return d

    @classmethod
    def from_json(cls, d: dict) -> "SitePlan":
        return cls(site=d["site"], cfg=_cfg_from_json(d["cfg"]),
                   kind=d.get("kind", "gemm"),
                   error_bits=d.get("error_bits"),
                   energy_j=d.get("energy_j"), macs=int(d.get("macs", 0)),
                   latency_us=d.get("latency_us"),
                   bytes_total=d.get("bytes_total"))


@dataclasses.dataclass(frozen=True)
class PrecisionPlan:
    """Versioned, serializable per-site numerics assignment."""

    name: str
    sites: tuple = ()                      # tuple[SitePlan]
    default: GemmConfig = GemmConfig()     # unlisted fwd sites (native bf16)
    bwd_default: Optional[GemmConfig] = None  # unlisted bwd sites (widened)
    budget_bits: Optional[float] = None
    version: int = PLAN_VERSION
    meta: dict = dataclasses.field(default_factory=dict)

    def site(self, name: str) -> Optional[SitePlan]:
        for s in self.sites:
            if s.site == name:
                return s
        return None

    def phase_sites(self, phase: str) -> tuple:
        return tuple(s for s in self.sites if s.phase == phase)

    def gemm_sites(self) -> tuple:
        return tuple(s for s in self.sites if s.kind == "gemm")

    def aux_sites(self) -> tuple:
        return tuple(s for s in self.sites if s.kind != "gemm")

    def to_policy(self) -> NumericsPolicy:
        """The NumericsPolicy this plan deploys: exact-match per-site
        overrides over the plan default, with the ``*@bwd`` widened fallback
        appended last (lowest precedence) so explicitly-searched bwd sites
        always win over it. A plan constructed without ``bwd_default``
        deploys ``widen_config(default)`` there — the invariant holds for
        in-memory plans exactly as for loaded ones, so ``to_policy`` and
        save→load→``to_policy`` agree on every site. Aux (state/collective)
        assignments deploy through the policy's ``aux`` channel, read by the
        optimizer and the mesh train step — never through ``overrides``."""
        overrides = [(s.site, s.cfg) for s in self.gemm_sites()]
        overrides.append(
            ("*@bwd", self.bwd_default or widen_config(self.default)))
        return NumericsPolicy(
            default=self.default,
            overrides=tuple(overrides),
            name=f"plan:{self.name}",
            aux=tuple((s.site, s.cfg) for s in self.aux_sites()))

    # -- serialization -----------------------------------------------------
    def to_json(self) -> dict:
        doc = {
            "version": self.version,
            "kind": "repro.numerics.PrecisionPlan",
            "name": self.name,
            "budget_bits": self.budget_bits,
            "default": _cfg_to_json(self.default),
            "sites": [s.to_json() for s in self.sites],
            "meta": self.meta,
        }
        if self.bwd_default is not None:
            doc["bwd_default"] = _cfg_to_json(self.bwd_default)
        return doc

    @classmethod
    def from_json(cls, d: dict) -> "PrecisionPlan":
        version = int(d.get("version", 0))
        if version > PLAN_VERSION:
            raise ValueError(
                f"precision plan version {version} is newer than this "
                f"library's {PLAN_VERSION}; refusing to guess its semantics")
        if "sites" not in d or "name" not in d:
            raise ValueError("not a PrecisionPlan document "
                             "(missing 'name'/'sites')")
        default = (_cfg_from_json(d["default"]) if "default" in d
                   else GemmConfig())
        sites = tuple(SitePlan.from_json(s) for s in d["sites"])
        for s in sites:
            # reject malformed/mislabeled site keys early: the key's grammar
            # must agree with the stored kind, GEMM keys must parse, and the
            # cfg family must match the kind.
            k = site_kind(s.site)
            if k != s.kind:
                raise ValueError(
                    f"site {s.site!r} is keyed as a {k} site but the "
                    f"document labels it {s.kind!r}")
            if k == "gemm":
                GemmSite.parse(s.site)
                if isinstance(s.cfg, QuantConfig):
                    raise ValueError(f"GEMM site {s.site!r} carries a quant "
                                     "cfg")
            elif not isinstance(s.cfg, QuantConfig):
                raise ValueError(f"aux site {s.site!r} carries a non-quant "
                                 "cfg")
        meta = dict(d.get("meta", {}))
        if version <= 1:
            # v1 -> v2 up-conversion: plain-name assignments are forward-only
            # under phase-aware lookup (no rewrite needed), and the backward
            # namespace falls to the *widened* default — gradients never
            # silently inherit a narrow forward datapath.
            bwd_default = widen_config(default)
            meta.setdefault("migrated_from", version or 1)
        elif version < PLAN_VERSION:
            # v2 -> v3 is additive (aux site kinds + bytes axes); pure-GEMM
            # documents only need the provenance stamp.
            meta.setdefault("migrated_from", version)
            bwd_default = (_cfg_from_json(d["bwd_default"])
                           if d.get("bwd_default") is not None
                           else widen_config(default))
        elif d.get("bwd_default") is not None:
            bwd_default = _cfg_from_json(d["bwd_default"])
        else:
            # a v2 document with the key stripped (hand-authored, tooling)
            # gets the same treatment as v1: loading NEVER yields a policy
            # whose unassigned gradient GEMMs inherit the forward default.
            bwd_default = widen_config(default)
        return cls(
            name=d["name"],
            sites=sites,
            default=default,
            bwd_default=bwd_default,
            budget_bits=d.get("budget_bits"),
            version=PLAN_VERSION,
            meta=meta,
        )

    def save(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
            f.write("\n")

    def describe(self) -> str:
        bwd = (f", bwd default {self.bwd_default.tag()}"
               if self.bwd_default else "")
        lines = [f"PrecisionPlan {self.name!r} v{self.version} "
                 f"(budget {self.budget_bits} bits, "
                 f"default {self.default.tag()}{bwd})"]
        for s in self.sites:
            bits = f"{s.error_bits:5.1f}b" if s.error_bits is not None else ""
            lines.append(f"  {s.site:22s} {s.cfg.tag():40s} {bits}")
        return "\n".join(lines)


def load_plan(path) -> PrecisionPlan:
    with open(path) as f:
        return PrecisionPlan.from_json(json.load(f))
