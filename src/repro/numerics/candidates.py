"""Per-site candidate enumeration: the (format x AccumulatorSpec x backend)
grid, pruned by the exponent ranges observed in the calibration trace.

The pruning is what makes the search tractable and honest at once: the msb is
*derived* from the site's observed product bound plus K-term sum growth (an
accumulator that can wrap on calibration data is never a candidate), and the
lsb never extends below the point where the accumulation is already bit-exact
for the observed operand range (deeper lsb costs energy and buys nothing).
Each candidate carries the generator's datapath report, so the Pareto axes
(modeled watts, pJ/MAC) come from the same model as the generated kernels.

Phase-qualified backward sites (``attn_qk@bwd.dA``) enumerate through the
same grid: their profiles were recorded from real cotangent/operand pairs, so
the msb pin and lsb clamp automatically reflect gradient dynamic range and
cancellation — typically pushing bwd candidates wider than their forward
twins, which is exactly the paper's per-stage tailoring argument.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.accumulator import AccumulatorSpec
from repro.core.dispatch import GemmConfig
from repro.core.formats import BF16, FP32, PositFormat
from repro.core.generator import DatapathReport, datapath_report
from repro.core.qformat import FP32_STATE, QuantConfig, quant_bytes

from .trace import SiteProfile

# Default tailoring grid: accumulator widths swept per site (the paper's
# Fig. 3 x-axis, minus the points the trace prunes), and the input formats
# considered. Native (MXU fp32-accumulate) candidates ride along per format.
DEFAULT_WIDTHS = (24, 40, 64)
DEFAULT_FORMATS = (BF16, FP32)

# Block-scaled grid for aux (state/collective) sites: payload bit widths and
# elements-per-exponent block. fp32 rides along as the identity reference.
QUANT_BITS = (4, 8, 16)
QUANT_BLOCKS = (32, 64)


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the per-site tailoring space."""

    cfg: GemmConfig
    report: DatapathReport

    @property
    def tag(self) -> str:
        return self.cfg.tag()

    @property
    def watts(self) -> float:
        return self.report.watts_fpga_model

    def describe(self) -> str:
        return f"{self.tag} ({self.watts:.3f} W model)"


def _mk(cfg: GemmConfig) -> Candidate:
    return Candidate(cfg, datapath_report(cfg.acc, cfg.fmt, cfg.mode))


def enumerate_candidates(
        profile: SiteProfile, *,
        formats: Sequence = DEFAULT_FORMATS,
        widths: Sequence[int] = DEFAULT_WIDTHS,
        fdp_mode: str = "simulate",
        include_native: bool = True,
        include_paper91: bool = True,
        ovf: Optional[int] = None) -> list[Candidate]:
    """The pruned candidate grid for one traced site.

    * msb is pinned at ``profile.msb_required`` (no overflow on observed data),
    * each requested total width W places lsb at ``msb + ovf + 1 - W``,
      clamped at the site's bit-exact depth (``lsb_exact``) — widths that
      would only add always-zero low bits collapse onto the exact point,
    * native (fp32-accumulate MXU) candidates are included per FloatFormat,
    * the paper's uniform ⟨30,30,-30⟩ is kept as the reference point.
    """
    ovf = profile.sum_growth_bits + 1 if ovf is None else ovf
    msb = profile.msb_required
    out: list[Candidate] = []
    seen: set = set()

    def push(cfg: GemmConfig):
        key = (cfg.fmt.name, cfg.acc, cfg.mode)
        if key not in seen:
            seen.add(key)
            out.append(_mk(cfg))

    for fmt in formats:
        if isinstance(fmt, PositFormat):
            # calibration samples are captured as decoded *floats*; replaying
            # them through a posit config would misread them as int32 bit
            # patterns. Posit tailoring needs an encode step in the eval path
            # (ROADMAP) — refuse loudly rather than score garbage.
            raise ValueError(
                f"posit format {fmt.name!r} is not searchable yet: "
                "candidate evaluation replays float samples")
        if include_native:
            push(GemmConfig(fmt, None, "native"))
        lsb_floor = profile.lsb_exact(fmt.precision)
        for w in sorted(widths):
            lsb = msb + ovf + 1 - w
            lsb = max(lsb, lsb_floor)          # prune: deeper is free of info
            if lsb > msb:
                continue                       # width too small for this msb
            push(GemmConfig(fmt, AccumulatorSpec(ovf=ovf, msb=msb, lsb=lsb),
                            fdp_mode))

    if include_paper91:
        push(GemmConfig(FP32, AccumulatorSpec.paper_91bit(), fdp_mode))
    return out


@dataclasses.dataclass(frozen=True)
class QuantCandidate:
    """One block-scaled format for an aux (state/collective) site, with its
    modeled byte cost at the site's traced element count."""

    cfg: QuantConfig
    bytes_total: float

    @property
    def tag(self) -> str:
        return self.cfg.tag()

    def describe(self) -> str:
        return f"{self.tag} ({self.bytes_total:.2e} B)"


def enumerate_quant_candidates(
        profile: SiteProfile, *,
        bits: Sequence[int] = QUANT_BITS,
        blocks: Sequence[int] = QUANT_BLOCKS,
        include_fp32: bool = True,
        error_feedback: bool = False) -> list[QuantCandidate]:
    """The pruned block-scaled grid for one aux site.

    The trace prunes it the same way operand exponents prune accumulator
    widths: the site's observed value range spans ``spread`` octaves
    (a_exp_max - a_exp_min), and a per-block exponent already absorbs the
    cross-block part of it, so payload widths beyond ``spread + 2`` bits only
    add low bits that are zero on calibration data — those widths collapse
    onto the narrowest sufficient point. Blocks wider than the site's element
    count are dropped (one real exponent would cover everything already).
    """
    ea, eb = profile.a_exp_max, profile.a_exp_min
    spread = (ea - eb) if (ea is not None and eb is not None) else None
    n = max(int(profile.macs), 1)            # macs == elements for aux sites
    all_blocks = sorted(set(int(x) for x in blocks))
    usable = [blk for blk in all_blocks if blk <= n] or all_blocks[:1]
    out, seen = [], set()
    for b in sorted(set(int(x) for x in bits)):
        if spread is not None:
            b = min(b, max(2, spread + 2))
        for blk in usable:
            cfg = QuantConfig(bits=b, block=blk,
                              error_feedback=error_feedback)
            if cfg in seen:
                continue
            seen.add(cfg)
            out.append(QuantCandidate(cfg, quant_bytes(n, cfg)))
    if include_fp32:
        cfg = FP32_STATE
        if cfg not in seen:
            out.append(QuantCandidate(cfg, quant_bytes(n, cfg)))
    return out
