# repro.numerics — automated per-site numerical tailoring.
#
# The software analogue of the paper's Fig. 3 design-space sweep, run
# automatically per model:
#   trace      - calibration mode: dispatch.gemm records per-site operand
#                statistics (shapes, exponent ranges, condition proxies,
#                call counts) into a SiteProfile registry
#   candidates - per-site (format x AccumulatorSpec x backend) grid drawn
#                from core.formats / core.accumulator, pruned by the
#                exponent ranges observed in the trace
#   search     - Pareto frontier over (accuracy vs a bit-exact FDP oracle,
#                modeled energy, optional measured latency) + greedy per-site
#                assignment meeting an end-to-end error budget
#   plan       - serializable PrecisionPlan (JSON, versioned) that loads into
#                a NumericsPolicy with per-site overrides (--precision-plan)
from .trace import (ENVELOPE_VERSION, TRACE_VERSION, CalibrationTrace,
                    SiteProfile, build_envelope, calibrate, cfg_capacity,
                    config_fingerprint, load_trace)
from .candidates import (Candidate, QuantCandidate, enumerate_candidates,
                         enumerate_quant_candidates)
from .search import (Evaluated, SearchResult, evaluate_candidates,
                     evaluate_quant_candidates, pareto_frontier, search)
from .plan import (PLAN_VERSION, PrecisionPlan, SitePlan, load_plan)

__all__ = [
    "ENVELOPE_VERSION", "TRACE_VERSION", "CalibrationTrace", "SiteProfile",
    "build_envelope", "calibrate", "cfg_capacity", "config_fingerprint",
    "load_trace",
    "Candidate", "QuantCandidate", "enumerate_candidates",
    "enumerate_quant_candidates", "evaluate_quant_candidates",
    "Evaluated", "SearchResult", "evaluate_candidates", "pareto_frontier",
    "search",
    "PLAN_VERSION", "PrecisionPlan", "SitePlan", "load_plan",
]
