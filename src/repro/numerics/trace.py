"""Calibration tracing: record what every GEMM call-site actually computes.

``calibrate()`` installs a hook into ``repro.core.dispatch`` so that every
dispatched GEMM — including ones inside ``jax.jit`` / ``jax.lax.scan`` bodies
— reports per-call operand statistics through ``jax.debug.callback`` into a
host-side ``CalibrationTrace``. Each call-site accumulates a ``SiteProfile``:

  * shapes and call counts (a scanned layer stack counts once per layer),
  * exponent ranges of both operands (floor(log2 |x|) of the extreme
    magnitudes), which drive candidate pruning and the exact-oracle sizing,
  * a condition proxy (``cancellation_bits``: how far the output magnitude
    sits below the no-cancellation upper bound — large values mean the site
    needs accumulator headroom below the msb),
  * total MAC count (the energy model's cycle denominator),
  * one captured operand sample per site, on which the search evaluates
    candidate numerics against a bit-exact FDP oracle.

Calibration may also run *backward* passes: differentiating through the
dispatch layer (its ``jax.custom_vjp``) fires the hook for every backward
GEMM under its own phase-qualified site key (``attn_qk@bwd.dA``), so a
``value_and_grad`` step under ``calibrate()`` profiles gradient exponent
ranges and cancellation separately from the forward sites. Re-executed
computations (``jax.remat`` backward recompute, repeated jit calls) fire the
callbacks again and inflate call counts accordingly; trace un-rematted
forwards for clean statistics.
"""

from __future__ import annotations

import base64
import contextlib
import dataclasses
import hashlib
import json
import math
import threading
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch, qformat
from repro.core.accumulator import AccumulatorSpec
from repro.core.formats import PositFormat

TRACE_VERSION = 1
TRACE_KIND = "repro.numerics.CalibrationTrace"


def config_fingerprint(obj) -> str:
    """Stable short hash of a config-like object (dataclass, dict, anything
    JSON-renderable). Saved into trace documents so a trace calibrated under
    one (model config, calibration shape) is never silently reused for
    another."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        obj = dataclasses.asdict(obj)
    blob = json.dumps(obj, sort_keys=True, default=repr)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _encode_array(x: Optional[np.ndarray]) -> Optional[dict]:
    if x is None:
        return None
    x = np.ascontiguousarray(x)
    return {"dtype": str(x.dtype), "shape": list(x.shape),
            "data": base64.b64encode(x.tobytes()).decode("ascii")}


def _decode_array(d: Optional[dict]) -> Optional[np.ndarray]:
    if d is None:
        return None
    raw = base64.b64decode(d["data"])
    return np.frombuffer(raw, dtype=np.dtype(d["dtype"])).reshape(d["shape"]).copy()


def _enc_float(v: float):
    """JSON-safe float: math.inf (the min-tracker's initial value) -> None."""
    return None if not math.isfinite(v) else v


def _dec_float(v, default: float) -> float:
    return default if v is None else float(v)


def _floor_log2(v: float) -> Optional[int]:
    """floor(log2(v)) for a positive finite float, else None."""
    if not (v > 0.0) or not math.isfinite(v):
        return None
    return math.frexp(v)[1] - 1


@dataclasses.dataclass
class SiteProfile:
    """Aggregated calibration statistics for one GEMM call-site."""

    site: str
    calls: int = 0
    macs: int = 0
    max_k: int = 0
    shapes: dict = dataclasses.field(default_factory=dict)
    cfg_tags: set = dataclasses.field(default_factory=set)
    # operand/output magnitude extremes (absolute values, f32 domain)
    a_abs_max: float = 0.0
    a_abs_min_nz: float = math.inf
    b_abs_max: float = 0.0
    b_abs_min_nz: float = math.inf
    out_abs_max: float = 0.0
    out_abs_min_nz: float = math.inf
    # first captured operand sample (rows x K, K x cols) for candidate eval
    sample_a: Optional[np.ndarray] = None
    sample_b: Optional[np.ndarray] = None

    # -- exponent ranges ---------------------------------------------------
    @property
    def a_exp_max(self):
        return _floor_log2(self.a_abs_max)

    @property
    def a_exp_min(self):
        return _floor_log2(self.a_abs_min_nz)

    @property
    def b_exp_max(self):
        return _floor_log2(self.b_abs_max)

    @property
    def b_exp_min(self):
        return _floor_log2(self.b_abs_min_nz)

    @property
    def prod_exp_max(self) -> int:
        """Upper bound on floor(log2 |a_i * b_j|) over observed operands."""
        ea, eb = self.a_exp_max, self.b_exp_max
        if ea is None or eb is None:
            return 0
        return ea + eb + 1                      # |a||b| < 2^(ea+1) * 2^(eb+1)

    @property
    def sum_growth_bits(self) -> int:
        """ceil(log2 K): how many extra magnitude bits a K-term sum can add."""
        return max(1, math.ceil(math.log2(max(self.max_k, 2))))

    @property
    def msb_required(self) -> int:
        """Smallest accumulator msb that cannot overflow on the observed
        operand range (product bound + K-term sum growth)."""
        return self.prod_exp_max + self.sum_growth_bits + 1

    @property
    def cancellation_bits(self) -> float:
        """Condition proxy: log2(no-cancellation output bound / observed
        |out|). ~0 for benign sums; large when the site cancels heavily and
        therefore needs lsb depth to keep correct bits."""
        if self.out_abs_max <= 0.0:
            return 0.0
        bound = self.a_abs_max * self.b_abs_max * max(self.max_k, 1)
        if bound <= 0.0:
            return 0.0
        return max(0.0, math.log2(bound / self.out_abs_max))

    def lsb_exact(self, precision: int = 24) -> int:
        """lsb at (below) which every observed product is captured exactly:
        the smallest product magnitude minus its 2p fraction bits."""
        ea = self.a_exp_min if self.a_exp_min is not None else -126
        eb = self.b_exp_min if self.b_exp_min is not None else -126
        return ea + eb - 2 * precision

    def exact_spec(self, precision: int = 24) -> AccumulatorSpec:
        """A ⟨ovf,msb,lsb⟩ accumulator that is bit-exact and overflow-free on
        this site's observed operand range — the per-site FDP oracle, sized
        by the trace rather than the format's worst case."""
        return AccumulatorSpec(ovf=self.sum_growth_bits + 2,
                               msb=self.prod_exp_max + 1,
                               lsb=self.lsb_exact(precision) - 2)

    @property
    def sample(self):
        if self.sample_a is None or self.sample_b is None:
            return None
        return self.sample_a, self.sample_b

    def to_dict(self) -> dict:
        """JSON-able summary (samples excluded)."""
        return {
            "site": self.site, "calls": self.calls, "macs": self.macs,
            "max_k": self.max_k,
            "shapes": {"x".join(map(str, k)): v
                       for k, v in sorted(self.shapes.items())},
            "cfg_tags": sorted(self.cfg_tags),
            "a_exp": [self.a_exp_min, self.a_exp_max],
            "b_exp": [self.b_exp_min, self.b_exp_max],
            "cancellation_bits": round(self.cancellation_bits, 2),
            "msb_required": self.msb_required,
        }

    def to_full_dict(self) -> dict:
        """Lossless serialization (everything ``_record`` accumulates,
        including the operand samples) — the persistence format behind
        ``CalibrationTrace.save``. ``to_dict`` stays the human summary."""
        return {
            "site": self.site, "calls": self.calls, "macs": self.macs,
            "max_k": self.max_k,
            "shapes": [[list(k), v] for k, v in sorted(self.shapes.items())],
            "cfg_tags": sorted(self.cfg_tags),
            "a_abs_max": self.a_abs_max,
            "a_abs_min_nz": _enc_float(self.a_abs_min_nz),
            "b_abs_max": self.b_abs_max,
            "b_abs_min_nz": _enc_float(self.b_abs_min_nz),
            "out_abs_max": self.out_abs_max,
            "out_abs_min_nz": _enc_float(self.out_abs_min_nz),
            "sample_a": _encode_array(self.sample_a),
            "sample_b": _encode_array(self.sample_b),
        }

    @classmethod
    def from_full_dict(cls, d: dict) -> "SiteProfile":
        return cls(
            site=d["site"], calls=int(d["calls"]), macs=int(d["macs"]),
            max_k=int(d["max_k"]),
            shapes={tuple(k): int(v) for k, v in d["shapes"]},
            cfg_tags=set(d.get("cfg_tags", ())),
            a_abs_max=float(d["a_abs_max"]),
            a_abs_min_nz=_dec_float(d["a_abs_min_nz"], math.inf),
            b_abs_max=float(d["b_abs_max"]),
            b_abs_min_nz=_dec_float(d["b_abs_min_nz"], math.inf),
            out_abs_max=float(d["out_abs_max"]),
            out_abs_min_nz=_dec_float(d["out_abs_min_nz"], math.inf),
            sample_a=_decode_array(d.get("sample_a")),
            sample_b=_decode_array(d.get("sample_b")),
        )

    def describe(self) -> str:
        return (f"{self.site:14s} calls={self.calls:<5d} "
                f"macs={self.macs:.2e} K<={self.max_k} "
                f"a_exp=[{self.a_exp_min},{self.a_exp_max}] "
                f"b_exp=[{self.b_exp_min},{self.b_exp_max}] "
                f"cancel={self.cancellation_bits:.1f}b "
                f"msb_req={self.msb_required}")


class CalibrationTrace:
    """Thread-safe registry of ``SiteProfile``s filled by the dispatch hook."""

    def __init__(self):
        self._lock = threading.Lock()
        self._profiles: dict[str, SiteProfile] = {}
        self.fingerprint: Optional[str] = None     # set by load()/callers
        self.meta: dict = {}

    # -- recording (called from jax.debug.callback on host) ---------------
    def _record(self, site, batch, m, n, k, tag, keep_sample,
                a_max, a_min, b_max, b_min, o_max, o_min,
                sample_a, sample_b):
        # Materialize every incoming value BEFORE taking the lock. Callbacks
        # arrive on two threads at once — the main thread (eager dispatch
        # runs debug callbacks inline) and the runtime's host-callback worker
        # (callbacks staged inside compiled scan/jit regions). Forcing a
        # device sync (float()/np.asarray on a jax.Array) while holding the
        # lock deadlocks: the main thread waits on async work whose pending
        # host callbacks the worker can only run after taking this lock.
        a_max, b_max, o_max = float(a_max), float(b_max), float(o_max)
        mins = {"a_abs_min_nz": float(a_min), "b_abs_min_nz": float(b_min),
                "out_abs_min_nz": float(o_min)}
        if keep_sample and self.has_sample(site):
            # keep_sample is baked in at staging time, so a compiled region
            # re-delivers it on every execution — skip the host copy once
            # the site's sample has landed (has_sample holds the lock only
            # for a dict probe: no device sync, the deadlock fix stands)
            keep_sample = False
        if keep_sample:
            sample_a = np.asarray(sample_a, np.float32).copy()
            sample_b = np.asarray(sample_b, np.float32).copy()
        with self._lock:
            p = self._profiles.setdefault(site, SiteProfile(site))
            p.calls += 1
            p.macs += batch * m * n * k
            p.max_k = max(p.max_k, k)
            key = (batch, m, n, k)
            p.shapes[key] = p.shapes.get(key, 0) + 1
            p.cfg_tags.add(tag)
            p.a_abs_max = max(p.a_abs_max, a_max)
            p.b_abs_max = max(p.b_abs_max, b_max)
            p.out_abs_max = max(p.out_abs_max, o_max)
            for attr, v in mins.items():
                if math.isfinite(v):
                    setattr(p, attr, min(getattr(p, attr), v))
            if keep_sample and p.sample_a is None:
                p.sample_a = sample_a
                p.sample_b = sample_b

    def record_aux(self, site, values, *, sample_max: int = 4096) -> None:
        """Profile a non-GEMM precision site (``opt.m@state``,
        ``grad_psum@coll``) from a host-side pass over its value tree.

        The same ``SiteProfile`` container is reused with the value-stream
        reading: the a_* magnitude extremes hold the *values'* dynamic range
        (which prunes the quant-candidate bit grid exactly as operand
        exponents prune accumulator widths), ``macs`` counts *elements* (the
        bytes denominator), and ``sample_a`` carries a 1-D evenly-strided
        subsample the search round-trips through candidate formats.
        ``sample_b`` stays None — aux sites have one value stream, not an
        operand pair — and persistence handles that unchanged.
        """
        site = getattr(site, "key", site)        # StateSite/CollectiveSite
        if qformat.site_kind(site) == "gemm":
            raise ValueError(f"record_aux got GEMM-keyed site {site!r}; aux "
                             "sites end in '@state' or '@coll'")
        leaves = [np.asarray(v, np.float32).reshape(-1)
                  for v in jax.tree.leaves(values)]
        flat = (np.concatenate(leaves) if leaves
                else np.zeros((0,), np.float32))
        a = np.abs(flat)
        nz = a[a > 0]
        amax = float(a.max()) if a.size else 0.0
        amin = float(nz.min()) if nz.size else math.inf
        stride = max(1, flat.size // sample_max)
        sample = flat[::stride][:sample_max].copy()
        with self._lock:
            p = self._profiles.setdefault(site, SiteProfile(site))
            p.calls += 1
            p.macs += flat.size
            p.max_k = max(p.max_k, 1)
            p.a_abs_max = max(p.a_abs_max, amax)
            p.out_abs_max = max(p.out_abs_max, amax)
            if math.isfinite(amin):
                p.a_abs_min_nz = min(p.a_abs_min_nz, amin)
                p.out_abs_min_nz = min(p.out_abs_min_nz, amin)
            if p.sample_a is None:
                p.sample_a = sample

    # -- queries -----------------------------------------------------------
    def sites(self, phase: Optional[str] = None) -> list[str]:
        """All traced site keys, optionally restricted to one phase
        ("fwd" returns plain names, "bwd" the ``@bwd.*`` keys — aux
        state/collective sites only appear in the unfiltered listing)."""
        with self._lock:
            keys = sorted(self._profiles)
        if phase is None:
            return keys
        return [k for k in keys if qformat.site_kind(k) == "gemm"
                and dispatch.GemmSite.parse(k).phase == phase]

    def aux_sites(self) -> list[str]:
        with self._lock:
            return sorted(k for k in self._profiles
                          if qformat.site_kind(k) != "gemm")

    def has_sample(self, site: str) -> bool:
        with self._lock:
            p = self._profiles.get(site)
            return p is not None and p.sample_a is not None

    def profile(self, site: str) -> SiteProfile:
        with self._lock:
            return self._profiles[site]

    def profiles(self) -> dict[str, SiteProfile]:
        with self._lock:
            return dict(self._profiles)

    def total_macs(self) -> int:
        with self._lock:
            return sum(p.macs for p in self._profiles.values())

    def summary(self) -> str:
        return "\n".join(p.describe()
                         for _, p in sorted(self.profiles().items()))

    def to_dict(self) -> dict:
        return {s: p.to_dict() for s, p in self.profiles().items()}

    # -- persistence -------------------------------------------------------
    # Calibration is the expensive half of the tailoring pipeline (it runs
    # real forwards of the target model); serializing the trace — including
    # the operand samples the search replays — decouples it from search
    # iterations: recalibrate only when the config fingerprint changes.
    def save(self, path, *, fingerprint: Optional[str] = None,
             meta: Optional[dict] = None) -> None:
        if fingerprint is not None:
            # a freshly-calibrated trace becomes fingerprinted the moment it
            # is persisted, so searches from the live trace and from a later
            # reload record identical provenance (plan JSONs stay stable
            # across the two refresh paths)
            self.fingerprint = fingerprint
        if meta is not None:
            self.meta = dict(meta)
        doc = {
            "version": TRACE_VERSION,
            "kind": TRACE_KIND,
            # omitted arguments fall back to the trace's own provenance, so
            # load -> save round-trips never strip fingerprint/meta
            "fingerprint": self.fingerprint,
            "meta": dict(self.meta),
            "profiles": [p.to_full_dict()
                         for _, p in sorted(self.profiles().items())],
        }
        with open(path, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")

    @classmethod
    def load(cls, path, *,
             expect_fingerprint: Optional[str] = None) -> "CalibrationTrace":
        """Load a saved trace. Rejects documents of the wrong kind, a newer
        schema version, or (when ``expect_fingerprint`` is given) a trace
        calibrated under a different config fingerprint."""
        with open(path) as f:
            doc = json.load(f)
        if doc.get("kind") != TRACE_KIND or "profiles" not in doc:
            raise ValueError(
                f"{path}: not a CalibrationTrace document "
                f"(kind={doc.get('kind')!r})")
        version = int(doc.get("version", 0))
        if version > TRACE_VERSION:
            raise ValueError(
                f"{path}: trace schema version {version} is newer than this "
                f"library's {TRACE_VERSION}; refusing to guess its semantics")
        if expect_fingerprint is not None and \
                doc.get("fingerprint") != expect_fingerprint:
            raise ValueError(
                f"{path}: trace fingerprint {doc.get('fingerprint')!r} does "
                f"not match the expected config fingerprint "
                f"{expect_fingerprint!r} — recalibrate (the model config or "
                f"calibration shape changed since this trace was saved)")
        trace = cls()
        trace.fingerprint = doc.get("fingerprint")
        trace.meta = dict(doc.get("meta", {}))
        for pd in doc["profiles"]:
            p = SiteProfile.from_full_dict(pd)
            trace._profiles[p.site] = p
        return trace


def load_trace(path, *, expect_fingerprint: Optional[str] = None
               ) -> CalibrationTrace:
    """Module-level convenience mirror of ``CalibrationTrace.load``."""
    return CalibrationTrace.load(path, expect_fingerprint=expect_fingerprint)


# ---------------------------------------------------------------------------
# Calibration envelope: the runtime-checkable boundary of a plan's claims
# ---------------------------------------------------------------------------
ENVELOPE_VERSION = 1


def _fmt_emax(fmt) -> int:
    """Max representable exponent of a storage format — the overflow
    capacity a *native* (accumulator-less) site actually has."""
    e = getattr(fmt, "emax", None)
    if e is not None:
        return int(e)
    nbits, es = getattr(fmt, "nbits", None), getattr(fmt, "es", 0)
    if nbits is not None:                       # posit maxpos = 2^((n-2)*2^es)
        return (int(nbits) - 2) * (1 << int(es))
    return 127


def cfg_capacity(cfg) -> tuple:
    """(msb, lsb) magnitude capacity of a site's deployed datapath: the
    fixed-point accumulator's bounds when one is configured (beyond msb a
    wrap-mode Kulisch register silently wraps), else the format's exponent
    reach with no lsb floor. This — not the traced operand range — is the
    hard line the live monitor calls ``violated``."""
    acc = getattr(cfg, "acc", None)
    if acc is not None:
        return int(acc.msb), int(acc.lsb)
    return _fmt_emax(cfg.fmt), None


def build_envelope(trace: CalibrationTrace, plan_or_policy) -> dict:
    """Serialize the calibration envelope a deployed plan's claims hold
    within: per GEMM site, the traced operand exponent ranges + sample count
    (the soft boundary — leaving it means the offline validation no longer
    speaks for this traffic) and the deployed ⟨msb,lsb⟩ capacity (the hard
    boundary — exceeding msb wraps the accumulator). Stored in
    ``PrecisionPlan.meta["envelope"]`` and compared against live folds by
    ``repro.obs.monitor.NumericsMonitor``.
    """
    policy = (plan_or_policy.to_policy()
              if hasattr(plan_or_policy, "to_policy") else plan_or_policy)
    sites = {}
    for site, p in sorted(trace.profiles().items()):
        if qformat.site_kind(site) != "gemm":
            continue
        cfg = policy.lookup(site)
        msb_cap, lsb_cap = cfg_capacity(cfg)
        sites[site] = {
            "a_exp": [p.a_exp_min, p.a_exp_max],
            "b_exp": [p.b_exp_min, p.b_exp_max],
            "out_exp": [_floor_log2(p.out_abs_min_nz),
                        _floor_log2(p.out_abs_max)],
            "msb": msb_cap,
            "lsb": lsb_cap,
            "msb_traced": p.msb_required,
            "lsb_exact": p.lsb_exact(cfg.fmt.precision),
            "calls": p.calls,
            "max_k": p.max_k,
        }
    meta = trace.meta or {}
    tokens = None
    if meta.get("batch") and meta.get("seq"):
        tokens = int(meta["batch"]) * int(meta["seq"])
    return {"version": ENVELOPE_VERSION,
            "trace_fingerprint": trace.fingerprint,
            "traced_tokens": tokens,
            "sites": sites}


def _as_float(fmt, x):
    """Stats domain: posit carriers decode to their float values."""
    if isinstance(fmt, PositFormat):
        return fmt.to_float(x)
    return x.astype(jnp.float32)


def _make_hook(trace: CalibrationTrace, sample_rows: int, sample_cols: int):
    staged_sample: set = set()              # sites whose sample is in flight

    def hook(site, cfg, a, b, out):
        if a.ndim < 2 or b.ndim < 2:       # 1-D promotions: skip (not model
            return                          # call-sites; stats would be moot)
        m, k = a.shape[-2], a.shape[-1]
        n = b.shape[-1]
        batch_dims = jnp.broadcast_shapes(a.shape[:-2], b.shape[:-2])
        batch = math.prod(batch_dims) if batch_dims else 1

        af = _as_float(cfg.fmt, a)
        bf = _as_float(cfg.fmt, b)
        of = out.astype(jnp.float32)

        def absmax(x):
            return jnp.max(jnp.abs(x))

        def absmin_nz(x):
            ax = jnp.abs(x)
            return jnp.min(jnp.where(ax > 0, ax, jnp.inf))

        # one operand sample per site: flattened rows of a, first batch
        # element's (K, cols) block of b — enough for the search to replay
        # the site's real data distribution through candidate numerics.
        # Ship it only until a sample lands (a scanned site still transfers
        # once per iteration of its *first* staged computation, since the
        # gate is evaluated at trace time; later retraces skip it).
        keep = site not in staged_sample and not trace.has_sample(site)
        if keep:
            staged_sample.add(site)
            rows = min(sample_rows, int(np.prod(af.shape[:-1])))
            cols = min(sample_cols, n)
            sa = af.reshape(-1, k)[:rows]
            sb = bf.reshape(-1, k, n)[0][:, :cols]
        else:
            sa = sb = jnp.zeros((), jnp.float32)    # placeholder, discarded

        jax.debug.callback(
            partial(trace._record, site, batch, m, n, k, cfg.tag(), keep),
            absmax(af), absmin_nz(af), absmax(bf), absmin_nz(bf),
            absmax(of), absmin_nz(of), sa, sb)

    return hook


@contextlib.contextmanager
def calibrate(trace: Optional[CalibrationTrace] = None, *,
              sample_rows: int = 16, sample_cols: int = 16):
    """Calibration mode: while active, every dispatched GEMM records its
    per-site statistics into the yielded ``CalibrationTrace``.

    Works under jit/scan (stats flow out through ``jax.debug.callback``), but
    note that a function *compiled while calibration is active* keeps its
    callbacks for the lifetime of its jit cache entry — calibrate on fresh
    functions, or call ``.clear_cache()`` on jitted entry points afterwards.
    Not re-entrant across threads (the hook is process-global).
    """
    trace = trace if trace is not None else CalibrationTrace()
    prev = dispatch.set_trace_hook(_make_hook(trace, sample_rows, sample_cols))
    try:
        yield trace
    finally:
        dispatch.set_trace_hook(prev)
        # debug callbacks are asynchronous: make every in-flight record land
        # before the caller reads the trace.
        jax.effects_barrier()
