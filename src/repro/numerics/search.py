"""Pareto search over the per-site tailoring space + greedy budget assignment.

Per site, every candidate from ``enumerate_candidates`` is *replayed* on the
operand sample captured during calibration and scored on three axes:

  * ``error_bits`` — median correct bits vs a bit-exact FDP oracle (the
    site's trace-sized ``exact_spec`` accumulator run through the simulate
    backend: exact accumulation of the f32 sample, one rounding at read-out),
  * ``energy_j`` — the calibrated VU3P power model at the candidate's
    datapath, times the site's traced MAC count (modeled, as everywhere),
  * ``latency_us`` — optional, measured through the GemmPlan autotune hooks
    when ``measure_latency=True``.

The assignment is the classic greedy: per site, the cheapest Pareto-optimal
candidate whose error meets the (margin-adjusted) budget; then, if an
end-to-end validator is supplied and the assembled policy misses the budget,
the weakest site is upgraded along its frontier until validation passes.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core import dispatch, energy, qformat
from repro.core.accumulator import AccumulatorSpec
from repro.core.dispatch import GemmConfig, NumericsPolicy
from repro.core.formats import FP32
from repro.core.metrics import correct_bits

from .candidates import (DEFAULT_FORMATS, DEFAULT_WIDTHS, Candidate,
                         QuantCandidate, enumerate_candidates,
                         enumerate_quant_candidates)
from .plan import PrecisionPlan, SitePlan
from .trace import CalibrationTrace, SiteProfile, build_envelope

ERROR_CAP_BITS = 24.0          # f32 read-out: "exact" caps at full mantissa

# Per-element correct bits an aux (state/collective) site must keep on its
# calibration sample for its initial assignment. Tuned so the 8-bit
# block-scaled point qualifies while 4-bit does not: EMA state and averaged
# gradients tolerate ~2^-6 relative rounding (quant_opt validates the claim
# end to end and upgrades the frontier when it doesn't hold).
AUX_TARGET_BITS = 5.0


@dataclasses.dataclass(frozen=True)
class Evaluated:
    """A candidate with its measured position in the objective space."""

    candidate: Candidate                   # Candidate | QuantCandidate
    error_bits: float
    energy_j: float
    latency_us: Optional[float] = None
    bytes_total: Optional[float] = None    # aux sites: modeled resident/wire

    @property
    def cfg(self):
        return self.candidate.cfg

    def describe(self) -> str:
        lat = f" {self.latency_us:.0f}us" if self.latency_us else ""
        by = f" {self.bytes_total:.2e} B" if self.bytes_total else ""
        return (f"{self.candidate.tag:40s} {self.error_bits:5.1f} bits  "
                f"{self.energy_j:.3e} J{lat}{by}")


def _apply_cfg(cfg: GemmConfig, a, b, site: str = "eval"):
    """Run one GEMM through the real dispatch path under a single-config
    policy — candidate evaluation and plan deployment share every code path,
    so a reloaded plan reproduces the evaluated outputs bit for bit."""
    return dispatch.gemm(a, b, site=site, policy=NumericsPolicy(cfg))


def oracle_output(profile: SiteProfile, a, b):
    """The site's bit-exact FDP oracle on the sample: trace-sized exact
    accumulator through the simulate backend."""
    cfg = GemmConfig(FP32, profile.exact_spec(FP32.precision), "simulate")
    return np.asarray(_apply_cfg(cfg, a, b, site=profile.site))


def _measure_latency_us(cfg: GemmConfig, profile: SiteProfile) -> float:
    """Best-of-2 wall time of the dispatched call at the site's *dominant
    traced shape* (synthetic operands — the tiny calibration sample would
    only measure dispatch overhead). Pallas candidates resolve their block
    plan through the GemmPlan autotuner first."""
    import jax
    import jax.numpy as jnp

    (_, m, n, k), _count = max(profile.shapes.items(),
                               key=lambda kv: kv[1])
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    if cfg.mode == "pallas":
        dispatch.plan_gemm(m, n, k, fmt=cfg.fmt, spec=cfg.acc, autotune=True)
    fn = lambda: _apply_cfg(cfg, a, b, profile.site)
    jax.block_until_ready(fn())                       # compile + warm
    best = float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def evaluate_candidates(profile: SiteProfile,
                        candidates: Sequence[Candidate], *,
                        measure_latency: bool = False) -> list[Evaluated]:
    """Replay each candidate on the site's captured sample and score it."""
    if profile.sample is None:
        raise ValueError(f"site {profile.site!r} has no captured sample "
                         "(was it traced under calibrate()?)")
    import jax.numpy as jnp

    a = jnp.asarray(profile.sample_a)
    b = jnp.asarray(profile.sample_b)
    ref = oracle_output(profile, a, b)
    out = []
    for c in candidates:
        got = np.asarray(_apply_cfg(c.cfg, a, b, site=profile.site))
        bits = float(np.median(correct_bits(got, ref, cap=ERROR_CAP_BITS)))
        e = energy.gemm_power(c.cfg.fmt, c.cfg.acc).energy_joules(profile.macs)
        lat = (_measure_latency_us(c.cfg, profile)
               if measure_latency else None)
        out.append(Evaluated(c, bits, e, lat))
    return out


def evaluate_quant_candidates(profile: SiteProfile,
                              candidates: Sequence[QuantCandidate]
                              ) -> list[Evaluated]:
    """Round-trip the aux site's captured value sample through each
    block-scaled format and score per-element correct bits against the
    original values. Energy stays 0 (no MACs run here) — for aux sites the
    cost axis is ``bytes_total``, the Pareto twin of modeled joules."""
    if profile.sample_a is None:
        raise ValueError(f"aux site {profile.site!r} has no captured sample "
                         "(was it profiled via record_aux?)")
    import jax.numpy as jnp

    x = jnp.asarray(profile.sample_a, jnp.float32)
    ref = np.asarray(x)
    out = []
    for c in candidates:
        got = np.asarray(qformat.quantize_roundtrip(x, c.cfg))
        bits = float(np.median(correct_bits(got, ref, cap=ERROR_CAP_BITS)))
        out.append(Evaluated(c, bits, 0.0, bytes_total=c.bytes_total))
    return out


def pareto_frontier(points: Sequence[Evaluated]) -> list[Evaluated]:
    """Non-dominated subset: maximize error_bits, minimize energy (plus
    latency when measured, and bytes on aux sites), sorted by ascending
    cost (energy, then bytes)."""

    def dominates(x: Evaluated, y: Evaluated) -> bool:
        ge = (x.error_bits >= y.error_bits and x.energy_j <= y.energy_j)
        gt = (x.error_bits > y.error_bits or x.energy_j < y.energy_j)
        if x.latency_us is not None and y.latency_us is not None:
            ge = ge and x.latency_us <= y.latency_us
            gt = gt or x.latency_us < y.latency_us
        if x.bytes_total is not None and y.bytes_total is not None:
            ge = ge and x.bytes_total <= y.bytes_total
            gt = gt or x.bytes_total < y.bytes_total
        return ge and gt

    front = [p for p in points
             if not any(dominates(q, p) for q in points if q is not p)]
    return sorted(front, key=lambda p: (p.energy_j, p.bytes_total or 0.0,
                                        -p.error_bits))


@dataclasses.dataclass
class SiteDecision:
    site: str
    profile: SiteProfile
    frontier: list[Evaluated]          # ascending energy
    chosen: int                        # index into frontier

    @property
    def pick(self) -> Evaluated:
        return self.frontier[self.chosen]

    def _next_better(self):
        """Index of the cheapest later frontier point with strictly more
        correct bits. With a latency axis the frontier is not monotone in
        error along the energy sort, so an upgrade must be accuracy-guarded
        or it could walk to a worse point."""
        for i in range(self.chosen + 1, len(self.frontier)):
            if self.frontier[i].error_bits > self.pick.error_bits:
                return i
        return None

    def can_upgrade(self) -> bool:
        return self._next_better() is not None

    def upgrade(self) -> None:
        nxt = self._next_better()
        assert nxt is not None
        self.chosen = nxt


@dataclasses.dataclass
class SearchResult:
    plan: PrecisionPlan
    decisions: dict[str, SiteDecision]
    validated_bits: Optional[float]
    # workload name -> ValidationReport, when search ran with validators
    reports: Optional[dict] = None

    def describe(self) -> str:
        lines = [f"precision plan {self.plan.name!r} "
                 f"(budget {self.plan.budget_bits} bits)"]
        for site, d in sorted(self.decisions.items()):
            p = d.pick
            lines.append(f"  {site:14s} -> {p.candidate.tag:40s} "
                         f"{p.error_bits:5.1f} bits  {p.energy_j:.3e} J")
        m = self.plan.meta
        lines.append(f"  modeled energy {m['modeled_energy_j']:.3e} J vs "
                     f"uniform 91-bit {m['baseline_energy_j']:.3e} J "
                     f"({m['energy_vs_baseline']:.1%})")
        if self.reports:
            for name in sorted(self.reports):
                lines.append("  workload " + self.reports[name].describe())
            ups = m.get("validation_upgrades", [])
            if ups:
                lines.append(f"  validator-driven upgrades: {', '.join(ups)}")
        elif self.validated_bits is not None:
            lines.append(f"  end-to-end validated: {self.validated_bits:.1f} "
                         "correct bits vs oracle")
        return "\n".join(lines)


def search(trace: CalibrationTrace, budget_bits: float, *,
           name: str = "tailored",
           default: Optional[GemmConfig] = None,
           formats: Sequence = DEFAULT_FORMATS,
           widths: Sequence[int] = DEFAULT_WIDTHS,
           fdp_mode: str = "simulate",
           include_native: bool = True,
           include_paper91: bool = True,
           margin_bits: float = 2.0,
           measure_latency: bool = False,
           validate: Optional[Callable[[NumericsPolicy], float]] = None,
           validators: Optional[Sequence] = None,
           max_upgrades: int = 16,
           phases: Sequence[str] = ("fwd", "bwd"),
           upgrade_phases: Sequence[str] = ("fwd",),
           aux_target_bits: float = AUX_TARGET_BITS) -> SearchResult:
    """Greedy per-site assignment meeting ``budget_bits`` end-to-end correct
    bits at minimum modeled energy.

    ``phases`` restricts which site namespaces are searched: a trace
    calibrated through a ``value_and_grad`` step carries phase-qualified
    backward sites (``attn_qk@bwd.dA``) alongside the forward ones, and each
    traced phase gets its own per-site assignment. Unassigned bwd sites fall
    to the emitted plan's widened ``bwd_default``.

    Aux sites (``opt.m@state`` / ``grad_psum@coll``, profiled via
    ``record_aux``) are searched alongside: their candidate grid is the
    block-scaled quant formats, their cost axis is *bytes* (resident for
    state, moved for collectives) rather than joules, and the initial pick
    is the fewest-bytes frontier point holding ``aux_target_bits`` on the
    calibration sample. The same upgrade loop spends on them when a failing
    validator (e.g. ``quant_opt``) attributes its deficit to their keys.

    End-to-end validation comes in two flavors:

    * ``validators`` — a sequence of ``repro.workloads`` Validators
      (``run(policy) -> ValidationReport``). All of them run on the
      assembled policy; while any reports below its threshold, the upgrade
      loop spends one Pareto-frontier upgrade per iteration on the weakest
      site that failing workload says it can see (its report's
      ``site_attribution`` patterns, else the validator's declared phases) —
      which is how a loss-gradient workload drives ``@bwd`` upgrades while a
      logit probe drives forward ones. Every report lands in
      ``plan.meta["validation"]`` (and the upgrade log in
      ``meta["validation_upgrades"]``), so the plan carries the per-workload
      evidence it was accepted on.
    * ``validate`` — the legacy scalar hook: maps a policy to measured
      end-to-end correct bits; while it reports less than the budget, the
      weakest site whose phase is in ``upgrade_phases`` is upgraded
      (forward-only by default, since a forward validator cannot see bwd
      assignments).

    ``max_upgrades`` caps either loop. Passing both flavors is an error.
    """
    phases = tuple(phases)
    if validate is not None and validators:
        raise ValueError("pass either validate= (legacy scalar hook) or "
                         "validators= (workload zoo), not both")
    all_profiles = trace.profiles()
    profiles = {s: p for s, p in all_profiles.items()
                if qformat.site_kind(s) == "gemm"
                and p.sample is not None
                and dispatch.GemmSite.parse(s).phase in phases}
    # aux (state/collective) profiles ride along whenever the trace carries
    # them — they have no phase namespace to restrict by.
    aux_profiles = {s: p for s, p in all_profiles.items()
                    if qformat.site_kind(s) != "gemm"
                    and p.sample_a is not None}
    if not profiles:
        raise ValueError(
            f"trace has no calibrated sites with samples in phases {phases}")

    decisions: dict[str, SiteDecision] = {}
    site_target = budget_bits + margin_bits
    for site, prof in sorted(profiles.items()):
        cands = enumerate_candidates(prof, formats=formats, widths=widths,
                                     fdp_mode=fdp_mode,
                                     include_native=include_native,
                                     include_paper91=include_paper91)
        evaluated = evaluate_candidates(prof, cands,
                                        measure_latency=measure_latency)
        frontier = pareto_frontier(evaluated)
        chosen = next((i for i, p in enumerate(frontier)
                       if p.error_bits >= site_target), len(frontier) - 1)
        decisions[site] = SiteDecision(site, prof, frontier, chosen)
    for site, prof in sorted(aux_profiles.items()):
        # searched assignments are the stateless formats; error feedback is a
        # deployment choice layered on top (QuantizedGradReducer)
        cands = enumerate_quant_candidates(prof)
        frontier = pareto_frontier(evaluate_quant_candidates(prof, cands))
        chosen = next((i for i, p in enumerate(frontier)
                       if p.error_bits >= aux_target_bits), len(frontier) - 1)
        decisions[site] = SiteDecision(site, prof, frontier, chosen)

    def assemble() -> PrecisionPlan:
        return _plan_from_decisions(name, decisions, budget_bits, default)

    validated = None
    reports = upgrades_log = None
    if validate is not None:
        up_phases = tuple(upgrade_phases)
        for _ in range(max_upgrades + 1):
            validated = float(validate(assemble().to_policy()))
            if validated >= budget_bits:
                break
            upgradable = [
                d for d in decisions.values() if d.can_upgrade()
                and qformat.site_kind(d.site) == "gemm"
                and dispatch.GemmSite.parse(d.site).phase in up_phases]
            if not upgradable:
                break
            weakest = min(upgradable, key=lambda d: d.pick.error_bits)
            weakest.upgrade()
    elif validators:
        reports, upgrades_log = _run_validator_loop(
            validators, decisions, assemble, max_upgrades)

    plan = assemble()
    if validated is not None:
        plan.meta["validated_bits"] = validated
    if reports is not None:
        plan.meta["validation"] = {n: r.to_json()
                                   for n, r in sorted(reports.items())}
        plan.meta["validation_upgrades"] = list(upgrades_log)
        # validated_bits keeps its historical meaning — end-to-end forward
        # correct bits vs the uniform oracle, i.e. the logit-fidelity
        # workload's score. Other workloads score in other units (repro caps
        # at 53 stability bits), so no stand-in: absent logits, it stays
        # unset and the per-workload scores in meta.validation speak.
        if "logits" in reports:
            validated = reports["logits"].score
            plan.meta["validated_bits"] = validated
    if getattr(trace, "fingerprint", None):
        # provenance: which persisted calibration this plan was searched from
        plan.meta["trace_fingerprint"] = trace.fingerprint
    # the runtime-checkable boundary of this plan's claims: traced per-site
    # exponent ranges + the deployed capacity, for the live envelope monitor
    plan.meta["envelope"] = build_envelope(trace, plan)
    return SearchResult(plan, decisions, validated, reports=reports)


def _run_validator_loop(validators, decisions, assemble, max_upgrades):
    """Run the workload zoo on the assembled policy, spending Pareto-frontier
    upgrades on sites the *failing* workloads attribute their deficit to.

    One upgrade per iteration (the first failing validator in the caller's
    order picks the weakest eligible site), and EVERY validator re-runs on
    every iteration: an upgrade raises one site's accuracy but can regress an
    orthogonal workload (e.g. a cheap bit-stable FDP point upgraded onto a
    more-accurate native one loses K-reorder stability), so previously
    passing reports cannot be assumed to stand. The loop always exits with
    reports measured against the exact policy that ships.
    """
    reports: dict = {}
    upgrades_log: list[str] = []
    while True:
        policy = assemble().to_policy()
        for v in validators:
            reports[v.name] = v.run(policy)
        failing = [v for v in validators if not reports[v.name].passed]
        if not failing or len(upgrades_log) >= max_upgrades:
            break
        target = None
        for v in failing:
            rep = reports[v.name]
            eligible = [d for d in decisions.values() if d.can_upgrade()
                        and v.eligible_site(d.site, rep)]
            if eligible:
                # weakest first — by the workload's own per-site attribution
                # when it names exact sites, else by the search-time oracle
                target = min(eligible, key=lambda d: rep.site_attribution.get(
                    d.site, d.pick.error_bits))
                break
        if target is None:
            break                      # failing, but nothing left to widen
        target.upgrade()
        upgrades_log.append(target.site)
    return reports, upgrades_log


def _plan_from_decisions(name, decisions, budget_bits,
                         default: Optional[GemmConfig]) -> PrecisionPlan:
    sites = []
    modeled = baseline = 0.0
    by_phase = {"fwd": 0.0, "bwd": 0.0}
    total_macs = 0
    # bytes Pareto axes: resident (state sites) and moved (collective sites),
    # each against the fp32 carrier of the same element count.
    bytes_axes = {"state": [0.0, 0.0], "collective": [0.0, 0.0]}
    base_power = energy.gemm_power(FP32, AccumulatorSpec.paper_91bit())
    for site, d in sorted(decisions.items()):
        p = d.pick
        kind = qformat.site_kind(site)
        sites.append(SitePlan(site=site, cfg=p.cfg, kind=kind,
                              error_bits=p.error_bits, energy_j=p.energy_j,
                              macs=d.profile.macs, latency_us=p.latency_us,
                              bytes_total=p.bytes_total))
        if kind == "gemm":
            modeled += p.energy_j
            by_phase[dispatch.GemmSite.parse(site).phase] += p.energy_j
            baseline += base_power.energy_joules(d.profile.macs)
            total_macs += d.profile.macs
        else:
            bytes_axes[kind][0] += p.bytes_total or 0.0
            bytes_axes[kind][1] += 4.0 * d.profile.macs
    meta = {
        "modeled_energy_j": modeled,
        "modeled_energy_fwd_j": by_phase["fwd"],
        "modeled_energy_bwd_j": by_phase["bwd"],
        "baseline_energy_j": baseline,
        "energy_vs_baseline": modeled / baseline if baseline else None,
        "total_macs": total_macs,
    }
    for kind, key in (("state", "bytes_resident"), ("collective",
                                                    "bytes_moved")):
        got, fp32 = bytes_axes[kind]
        if fp32:
            meta[key] = got
            meta[f"{key}_fp32"] = fp32
            meta[f"{key}_vs_fp32"] = got / fp32
    default = default or GemmConfig()
    return PrecisionPlan(name=name, sites=tuple(sites),
                         default=default,
                         bwd_default=dispatch.widen_config(default),
                         budget_bits=budget_bits, meta=meta)
