"""Run the workload zoo against a policy — the CI smoke entry point.

    PYTHONPATH=src python -m repro.workloads --plan examples/plans/paper_mlp.json
    PYTHONPATH=src python -m repro.workloads --arch qwen3-0.6b --reduced \
        --validators grad,logits,repro,solve

Loads the plan (arch/reduced are inferred from its meta unless given), builds
the requested validators on a seeded model context, runs each against the
deployed policy, and prints the reports. With ``--tolerance T`` the
recomputed scores are also diffed against the scores the plan recorded at
search time (``meta.validation``): drift beyond T bits exits nonzero, so the
plan-zoo lane catches validators and plans that quietly diverge.
``--require-pass`` additionally fails on any below-threshold workload.
"""

from __future__ import annotations

import argparse
import sys


def main(argv=None):
    ap = argparse.ArgumentParser(prog="python -m repro.workloads")
    ap.add_argument("--plan", default=None,
                    help="PrecisionPlan JSON to deploy (default: the bare "
                         "mxu_fp32 policy)")
    ap.add_argument("--arch", default=None,
                    help="architecture (default: the plan's recorded arch)")
    ap.add_argument("--reduced", action="store_true", default=None)
    ap.add_argument("--validators", default="grad,logits,repro",
                    help="comma list of workload names (see "
                         "repro.workloads.available_workloads)")
    ap.add_argument("--budget", type=float, default=None,
                    help="threshold seed in bits (default: the plan's "
                         "budget_bits, else 10)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tolerance", type=float, default=None,
                    help="max |recomputed - recorded| score drift in bits "
                         "before failing (default: report only)")
    ap.add_argument("--require-pass", action="store_true",
                    help="exit nonzero if any workload scores below its "
                         "threshold")
    args = ap.parse_args(argv)

    from repro.configs import get_config
    from repro.core.dispatch import MXU_FP32
    from repro.numerics import load_plan
    from repro.workloads import WorkloadContext, build_validators

    plan = recorded = None
    if args.plan:
        plan = load_plan(args.plan)
        recorded = plan.meta.get("validation", {})
        if args.arch is None:
            args.arch = plan.meta.get("arch_alias") or plan.meta.get("arch")
        if args.reduced is None:
            args.reduced = bool(plan.meta.get("reduced"))
        if args.budget is None and plan.budget_bits is not None:
            args.budget = float(plan.budget_bits)
    if args.arch is None:
        raise SystemExit("--arch is required when --plan carries no arch")
    policy = plan.to_policy() if plan else MXU_FP32

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    names = [n for n in args.validators.split(",") if n and n != "none"]
    ctx = WorkloadContext.for_model(cfg, budget_bits=args.budget or 10.0,
                                    seed=args.seed)
    validators = build_validators(names, ctx)

    failures = []
    print(f"[workloads] policy {policy.name!r} on {cfg.name} "
          f"(reduced={bool(args.reduced)})")
    for v in validators:
        rep = v.run(policy)
        line = "  " + rep.describe()
        rec = (recorded or {}).get(v.name)
        if rec is not None and rec.get("score") is not None:
            drift = abs(rep.score - float(rec["score"]))
            line += f"  [recorded {rec['score']:.1f}, drift {drift:.2f}]"
            if args.tolerance is not None and drift > args.tolerance:
                failures.append(f"{v.name}: score drifted {drift:.2f} bits "
                                f"from the recorded {rec['score']:.2f} "
                                f"(tolerance {args.tolerance})")
        if args.require_pass and not rep.passed:
            failures.append(f"{v.name}: {rep.score:.2f} < threshold "
                            f"{rep.threshold:g}")
        print(line)

    if failures:
        for f in failures:
            print(f"[workloads] FAIL: {f}")
        sys.exit(1)
    print(f"[workloads] OK: {len(validators)} workload(s) ran")


if __name__ == "__main__":
    main()
