"""Quantized-optimizer validator: what does low-bit training *state* cost?

The per-site search accepts an ``opt.m@state`` / ``opt.v@state`` /
``grad_psum@coll`` assignment from a one-shot round-trip on the calibration
sample; this workload closes the end-to-end loop the ROADMAP named: a short
seeded training run where the Adam moments live in the candidate block-scaled
formats and every gradient goes through the collective format's round-trip,
scored against the *fp32-state reference* — the identical run with the same
GEMM policy but full-precision state and exact collectives. GEMM numerics are
common-mode between the two runs, so the loss-curve divergence isolates
exactly what the quantized state and compressed collectives cost training.

The score is the *worst step's* correct bits of the loss curve (quantization
error in EMA state compounds across steps — the last steps are where a
too-coarse format shows), and the attribution names the exact aux site keys
the policy assigns, so the search's upgrade loop widens the moment or
collective format rather than touching a GEMM.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import qformat
from repro.core.metrics import correct_bits

from .base import ValidationReport, Validator, WorkloadContext, register

QUANT_OPT_CAP_BITS = 24.0
# Loss-curve fidelity floor: an 8-bit block-scaled moment keeps the probe
# curves well above this on the zoo models, a 4-bit one falls under it —
# the threshold is what separates "EMA tail rounding" from "the optimizer is
# following different gradients".
DEFAULT_THRESHOLD_BITS = 4.0


@register
class QuantizedOptimizer(Validator):
    """Worst-step correct bits of a short quantized-state training-loss curve
    vs the fp32-state reference under the same GEMM policy."""

    name = "quant_opt"
    phases = ("state", "collective")

    def __init__(self, cfg, params, grad_batch, *, dist=None,
                 threshold: float = DEFAULT_THRESHOLD_BITS,
                 steps: int = 6, lr: float = 3e-3):
        from repro.models import LOCAL

        self.cfg = cfg
        self.params = params
        self.grad_batch = grad_batch
        self.dist = dist or LOCAL
        self.threshold = float(threshold)
        self.steps = int(steps)
        self.lr = float(lr)
        # single-slot reference cache: the fp32-state curve depends only on
        # the GEMM surface of the policy (aux is stripped from it), so the
        # search's aux-only upgrade iterations reuse one reference run.
        self._ref_key = None
        self._ref_val = None

    @classmethod
    def from_context(cls, ctx: WorkloadContext) -> "QuantizedOptimizer":
        ctx.require_model(cls.name)
        if ctx.grad_batch is None:
            raise ValueError("workload 'quant_opt' needs ctx.grad_batch "
                             "(a batch with targets/loss_mask)")
        return cls(ctx.cfg, ctx.params, ctx.grad_batch, dist=ctx.dist)

    def _curve(self, policy, state_quant, coll_cfg) -> list:
        import jax

        from repro.core.dispatch import use_policy
        from repro.train.loop import make_loss_fn
        from repro.train.optimizer import adamw, apply_updates

        loss_fn = make_loss_fn(self.cfg, self.dist, remat="none")
        opt = adamw(self.lr, state_quant=state_quant)

        def step(params, ostate, batch):
            (loss, _aux), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            if coll_cfg is not None:
                # single-device emulation of quantized_psum's round trip:
                # same block math, axis size 1
                grads = jax.tree.map(
                    lambda g: qformat.quantize_roundtrip(g, coll_cfg), grads)
            updates, ostate = opt.update(grads, ostate, params)
            return apply_updates(params, updates), ostate, loss

        losses = []
        with use_policy(policy):
            step_j = jax.jit(step)
            params, ostate = self.params, opt.init(self.params)
            for _ in range(self.steps):
                params, ostate, loss = step_j(params, ostate,
                                              self.grad_batch)
                losses.append(float(loss))
        return losses

    def run(self, policy) -> ValidationReport:
        from repro.train.optimizer import state_quant_from_policy

        base = dataclasses.replace(policy, aux=(),
                                   name=f"{policy.name}+fp32state")
        key = (policy.default.tag(),
               tuple((pat, cfg.tag()) for pat, cfg in
                     getattr(policy, "overrides", ())))
        if key != self._ref_key:
            # value first, key last: a failed run must not register the new
            # key over the previous policy's cached reference
            self._ref_val = self._curve(base, None, None)
            self._ref_key = key
        ref = self._ref_val

        squant = state_quant_from_policy(policy)
        coll = policy.aux_lookup(qformat.GRAD_PSUM_SITE.key)
        if coll is not None and coll.mode != "block":
            coll = None
        got = self._curve(base, squant, coll)

        per_step = [float(correct_bits(g, r, cap=QUANT_OPT_CAP_BITS))
                    for g, r in zip(got, ref)]
        score = min(per_step)
        quant_keys = [k for k, cfg in getattr(policy, "aux", ())
                      if cfg.mode == "block"]
        attribution = ({k: score for k in quant_keys} if quant_keys
                       else {"*@state": score, "*@coll": score})
        return ValidationReport(
            workload=self.name, score=score, threshold=self.threshold,
            site_attribution=attribution,
            details={"per_step_bits": per_step,
                     "loss_curve": got, "loss_curve_ref": ref,
                     "steps": self.steps,
                     "state_formats": {k: cfg.tag() for k, cfg
                                       in getattr(policy, "aux", ())}})
