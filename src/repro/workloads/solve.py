"""Ill-conditioned solve workload — the paper's Fig. 2 SSH experiment as a
reusable validator.

SSH (and every ill-conditioned solve) reduces to long dot products whose
condition number grows with problem size; stock floating-point loses all
correct bits while the exact FDP accumulator keeps them. This workload
manufactures that regime on demand — Ogita–Rump–Oishi dot products
(``data.conditioned.gen_dot``) and prescribed-condition linear systems
(``gen_linear_system``) at sweepable condition numbers — runs them through
the *deployed* per-site datapaths of the policy under test, and scores each
site in correct bits against the exact-arithmetic oracle.

Honest caveats, by design:

  * a site whose accumulator was calibrated on model activations may *wrap*
    on solve operands (products up to ~sqrt(cond)); the resulting ~0-bit
    score is the real answer to "can this plan serve an ill-conditioned
    solve", which is why this workload is opt-in for the DNN plan zoo
    (``--validators solve,...``) rather than part of its default gate;
  * the linear-system rows cancel from O(1) operands down to O(1/cond)
    values, so resolving them to b relative bits needs absolute accumulator
    resolution ~lsb <= -(b + log2 cond): even the paper's 91-bit <30,30,-30>
    — which holds all 24 bits on the ORO *dots* at every cond here — drops
    to ~14/~6/0 bits on the cond=1e4/1e6/1e8 systems. That is the tailoring
    thesis as a measurement: the accumulator must be sized to the workload's
    cancellation depth, not just its operand range;
  * scores are capped at 24 bits (f32 read-out).
"""

from __future__ import annotations

import numpy as np

from repro.core.metrics import correct_bits

from .base import ValidationReport, Validator, WorkloadContext, probed_sites
from .base import register

SOLVE_CAP_BITS = 24.0


@register
class IllConditionedSolve(Validator):
    """Batched ORO dot products + one prescribed-condition linear system per
    condition number, dispatched through every explicitly-assigned site of
    the policy (falling back to one ``workload_probe`` site on bare
    policies). Score = worst site's worst condition number, in correct bits
    vs the exact oracle; per-site attribution carries each site's own score
    so the search upgrades the site that actually failed the solve."""

    name = "solve"
    phases = ("fwd", "bwd")

    def __init__(self, *, conds=(1e4, 1e6, 1e8), n: int = 64,
                 n_dots: int = 4, system_n: int = 24, seed: int = 0,
                 threshold: float = 10.0):
        from repro.data.conditioned import gen_dot, gen_linear_system

        self.conds = tuple(float(c) for c in conds)
        self.threshold = float(threshold)
        self._cases = []
        for ci, cond in enumerate(self.conds):
            dots = [gen_dot(n, cond, seed + 97 * ci + i)
                    for i in range(n_dots)]
            a = np.stack([d[0] for d in dots])                  # (m, n)
            b = np.stack([d[1] for d in dots]).T                # (n, m)
            exact = np.array([d[2] for d in dots], np.float64)
            self._cases.append(("dot", cond, a, b, exact))
            A, x, bx = gen_linear_system(system_n, cond,
                                         seed=seed + 31 * ci)
            self._cases.append(("system", cond, A, x[:, None], bx))

    @classmethod
    def from_context(cls, ctx: WorkloadContext) -> "IllConditionedSolve":
        return cls(seed=ctx.seed, threshold=ctx.budget_bits)

    def run(self, policy) -> ValidationReport:
        import jax.numpy as jnp

        from repro.core.dispatch import gemm

        sites = probed_sites(policy) or ["workload_probe"]
        attribution, weakest = {}, None
        for site in sites:
            worst = SOLVE_CAP_BITS
            by_cond = {}
            for kind, cond, a, b, exact in self._cases:
                out = np.asarray(gemm(jnp.asarray(a), jnp.asarray(b),
                                      site=site, policy=policy),
                                 np.float64)
                got = np.diagonal(out) if kind == "dot" else out[:, 0]
                bits = float(np.median(correct_bits(got, exact,
                                                    cap=SOLVE_CAP_BITS)))
                key = f"{kind}@cond={cond:.0e}"
                by_cond[key] = min(by_cond.get(key, SOLVE_CAP_BITS), bits)
                worst = min(worst, bits)
            attribution[site] = worst
            if weakest is None or worst < weakest[1]:
                weakest = (site, worst, by_cond)
        site, score, by_cond = weakest
        return ValidationReport(
            workload=self.name, score=score, threshold=self.threshold,
            site_attribution=attribution,
            details={"conds": list(self.conds), "weakest_site": site,
                     "weakest_site_bits": {k: float(v)
                                           for k, v in by_cond.items()},
                     "n_sites_probed": len(sites)})
