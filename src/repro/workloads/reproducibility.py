"""Reproducibility probe: bit-stability under K-reduction reordering.

The FDP's headline property (paper Fig. 2) is not accuracy but *associativity*:
a fixed-point accumulation gives the same bits for every summation order,
where native floating-point drifts. This workload measures exactly that, per
deployed site: the same seeded GEMM is dispatched with the K dimension
permuted several ways (columns of A and rows of B permuted together, so the
mathematical product is unchanged), and the score is the agreement between
orderings in bits — capped at ``REPRO_CAP_BITS`` and awarded in full when
every ordering is bit-identical, which FDP backends achieve by construction.

A native fp32 site typically lands near 20–23 bits of reorder stability on
benign data — above the default (budget-derived) threshold, so this probe
does not force the DNN zoo onto FDP; it *measures* the native drift, records
it in the plan, and fails only datapaths whose results genuinely wander.
"""

from __future__ import annotations

import numpy as np

from .base import ValidationReport, Validator, WorkloadContext, probed_sites
from .base import register

REPRO_CAP_BITS = 53.0


@register
class KReorderStability(Validator):

    name = "repro"
    phases = ("fwd", "bwd")

    def __init__(self, *, m: int = 8, n: int = 8, k: int = 256,
                 n_orders: int = 4, seed: int = 0, threshold: float = 10.0):
        rng = np.random.default_rng(seed)
        self.a = rng.standard_normal((m, k)).astype(np.float32)
        self.b = rng.standard_normal((k, n)).astype(np.float32)
        self.perms = [np.arange(k)] + [rng.permutation(k)
                                       for _ in range(n_orders - 1)]
        self.threshold = float(threshold)

    @classmethod
    def from_context(cls, ctx: WorkloadContext) -> "KReorderStability":
        return cls(seed=ctx.seed, threshold=ctx.budget_bits)

    def _site_bits(self, site: str, policy) -> float:
        import jax.numpy as jnp

        from repro.core.dispatch import gemm

        outs = [np.asarray(gemm(jnp.asarray(self.a[:, p]),
                                jnp.asarray(self.b[p, :]),
                                site=site, policy=policy), np.float64)
                for p in self.perms]
        ref = outs[0]
        dev = max(float(np.max(np.abs(o - ref))) for o in outs[1:])
        if dev == 0.0:
            return REPRO_CAP_BITS
        scale = float(np.max(np.abs(ref)))
        if scale == 0.0:
            return 0.0
        return float(np.clip(-np.log2(dev / scale), 0.0, REPRO_CAP_BITS))

    def run(self, policy) -> ValidationReport:
        sites = probed_sites(policy) or ["workload_probe"]
        attribution = {s: self._site_bits(s, policy) for s in sites}
        weakest = min(attribution, key=attribution.get)
        return ValidationReport(
            workload=self.name, score=attribution[weakest],
            threshold=self.threshold, site_attribution=dict(attribution),
            details={"weakest_site": weakest,
                     "n_orders": len(self.perms),
                     "bit_identical_sites":
                         sum(v >= REPRO_CAP_BITS
                             for v in attribution.values()),
                     "n_sites_probed": len(sites)})
