"""Mesh-reshape stability: the same bits on every factorization of a mesh.

The FDP's associativity property makes one kernel's result independent of its
K-reduction order; this workload lifts the claim to a whole device mesh. Each
deployed site's GEMM is run K-sharded over the FLATTENED (data, model) axes of
every factorization of the available devices (8 -> 1x8, 2x4, 4x2, 8x1) with
the cross-device reduction dispatched through ``gemm(..., reduce_axis=...)``
— FDP sites through the exact limb-summed ``fdp_psum``, native sites through
a stock float psum — and scored in bits of agreement against the UNSHARDED
single-device result. FDP sites land bit-identical by construction; native
sites measure their real topology drift.

When the context is model-bound and more than one device is visible, the
workload also runs the end-to-end contract: forward logits and loss-gradients
of one data-parallel training step (``sharded_value_and_grad`` with
fixed-point gradient reduction) compared across every mesh shape. Per-device
shapes depend only on the joint device count, so local compute is common-mode
and the comparison isolates exactly the collective layer.

Registered as "mesh" — opt-in (like "solve"): ``search(validators=...)`` and
``refresh_plans.py --validators grad,logits,repro,mesh`` act on it; it is not
in DEFAULT_VALIDATORS, so the existing plan zoo needs no regeneration (its
reports simply carry no ``mesh`` provenance = single-device).
"""

from __future__ import annotations

import numpy as np

from .base import (PROBE_SEQ, ValidationReport, Validator, WorkloadContext,
                   make_probe_batch, probed_sites, register)

MESH_CAP_BITS = 53.0

# fixed-point grid for the cross-device gradient mean in the end-to-end
# probe (same spec the train CLI's --fdp-grad uses)
_GRAD_OVF, _GRAD_MSB, _GRAD_LSB = 10, 10, -20


def mesh_shapes(n_devices: int) -> list:
    """Every (R, C) factorization of ``n_devices`` (8 -> 1x8, 2x4, 4x2,
    8x1; 1 -> the degenerate 1x1)."""
    return [(r, n_devices // r) for r in range(1, n_devices + 1)
            if n_devices % r == 0]


def _agreement_bits(ref: np.ndarray, others) -> float:
    """Bits of agreement between ``ref`` and each of ``others`` (the
    K-reorder stability formula, applied across mesh shapes)."""
    dev = max((float(np.max(np.abs(o - ref))) for o in others), default=0.0)
    if dev == 0.0:
        return MESH_CAP_BITS
    scale = float(np.max(np.abs(ref)))
    if scale == 0.0:
        return 0.0
    return float(np.clip(-np.log2(dev / scale), 0.0, MESH_CAP_BITS))


@register
class MeshReshapeStability(Validator):

    name = "mesh"
    phases = ("fwd", "bwd")

    def __init__(self, *, cfg=None, params=None, m: int = 8, n: int = 8,
                 k: int = 256, seed: int = 0, threshold: float = 10.0):
        import jax

        rng = np.random.default_rng(seed)
        self.a = rng.standard_normal((m, k)).astype(np.float32)
        self.b = rng.standard_normal((k, n)).astype(np.float32)
        self.cfg, self.params, self.seed = cfg, params, seed
        self.threshold = float(threshold)
        self.shapes = mesh_shapes(jax.device_count())

    @classmethod
    def from_context(cls, ctx: WorkloadContext) -> "MeshReshapeStability":
        # model binding is optional: without it the workload still probes
        # every deployed site's K-sharded contraction
        return cls(cfg=ctx.cfg, params=ctx.params, seed=ctx.seed,
                   threshold=ctx.budget_bits)

    # -- per-site K-sharded contraction probe -------------------------------
    def _site_bits(self, site: str, policy) -> float:
        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P

        from repro.core.dispatch import gemm
        from repro.parallel.compat import shard_map_unchecked

        a, b = jnp.asarray(self.a), jnp.asarray(self.b)
        ref = np.asarray(gemm(a, b, site=site, policy=policy), np.float64)
        axes = ("data", "model")
        outs = []
        for r, c in self.shapes:
            mesh = jax.make_mesh((r, c), axes)

            def f(al, bl):
                return gemm(al, bl, site=site, policy=policy,
                            reduce_axis=axes)

            out = shard_map_unchecked(
                f, mesh=mesh, in_specs=(P(None, axes), P(axes, None)),
                out_specs=P())(a, b)
            outs.append(np.asarray(out, np.float64))
        return _agreement_bits(ref, outs)

    # -- end-to-end: logits + loss-gradients across mesh shapes -------------
    def _model_bits(self, policy) -> dict:
        import jax
        from jax.sharding import PartitionSpec as P

        from repro.core.accumulator import AccumulatorSpec
        from repro.core.dispatch import use_policy
        from repro.models import forward
        from repro.models.layers import LOCAL
        from repro.parallel.compat import shard_map_unchecked
        from repro.train.loop import make_loss_fn, sharded_value_and_grad

        n = jax.device_count()
        batch = make_probe_batch(self.cfg, batch_size=n, seq=PROBE_SEQ,
                                 seed=self.seed + 1, with_targets=True)
        axes = ("data", "model")
        grad_spec = AccumulatorSpec(ovf=_GRAD_OVF, msb=_GRAD_MSB,
                                    lsb=_GRAD_LSB)
        loss_fn = make_loss_fn(self.cfg, LOCAL, remat="none")
        vg = sharded_value_and_grad(loss_fn, axes, fdp_grad_spec=grad_spec)
        cfg = self.cfg

        def body(params, batch):
            logits = forward(params, cfg, batch, LOCAL, remat="none")
            _, grads = vg(params, batch)
            return logits, grads

        logits_all, grads_all = [], []
        for r, c in self.shapes:
            mesh = jax.make_mesh((r, c), axes)
            sharded = shard_map_unchecked(
                body, mesh=mesh, in_specs=(P(), P(axes)),
                out_specs=(P(axes), P()))
            with use_policy(policy):
                logits, grads = jax.jit(sharded)(self.params, batch)
                jax.block_until_ready((logits, grads))
            logits_all.append(np.asarray(logits, np.float64))
            grads_all.append(np.concatenate(
                [np.asarray(g, np.float64).ravel()
                 for g in jax.tree.leaves(grads)]))
        return {
            "logits_bits": _agreement_bits(logits_all[0], logits_all[1:]),
            "grad_bits": _agreement_bits(grads_all[0], grads_all[1:]),
        }

    def run(self, policy) -> ValidationReport:
        sites = probed_sites(policy) or ["workload_probe"]
        attribution = {s: self._site_bits(s, policy) for s in sites}
        details = {"mesh_shapes": ",".join(f"{r}x{c}"
                                           for r, c in self.shapes),
                   "n_sites_probed": len(sites),
                   "bit_identical_sites":
                       sum(v >= MESH_CAP_BITS for v in attribution.values())}

        import jax
        model_bound = (self.cfg is not None and self.params is not None
                       and jax.device_count() > 1)
        if model_bound:
            mb = self._model_bits(policy)
            details.update(mb)
            # whole-namespace deficits the upgrade loop can act on: forward
            # sites move the logits, backward sites move the gradients
            attribution["*"] = mb["logits_bits"]
            attribution["*@bwd"] = mb["grad_bits"]

        weakest = min(attribution, key=attribution.get)
        details["weakest_site"] = weakest
        return ValidationReport(
            workload=self.name, score=attribution[weakest],
            threshold=self.threshold, site_attribution=dict(attribution),
            details=details,
            mesh=details["mesh_shapes"])
