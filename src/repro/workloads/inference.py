"""Inference-quality probe: logit fidelity vs the uniform 91-bit oracle.

This is the plan zoo's historical end-to-end gate (the stock forward
validator the search used to hard-code), promoted to a first-class workload:
a real model forward under the candidate policy, scored in median correct
bits of the logits against the paper's uniform ⟨30,30,-30⟩ FDP policy, with
top-1 agreement (the paper's Fig. 3 proxy metric) reported alongside. Its
score is what plans record as ``validated_bits``.
"""

from __future__ import annotations

import numpy as np

from repro.core.metrics import correct_bits, top1_agreement

from .base import ValidationReport, Validator, WorkloadContext, register

LOGIT_CAP_BITS = 24.0


@register
class LogitFidelity(Validator):

    name = "logits"
    phases = ("fwd",)

    def __init__(self, cfg, params, batch, *, dist=None,
                 threshold: float = 10.0):
        from repro.models import LOCAL

        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.dist = dist or LOCAL
        self.threshold = float(threshold)
        self._ref = None                      # FDP91 logits, computed once

    @classmethod
    def from_context(cls, ctx: WorkloadContext) -> "LogitFidelity":
        ctx.require_model(cls.name)
        return cls(ctx.cfg, ctx.params, ctx.batch, dist=ctx.dist,
                   threshold=ctx.budget_bits)

    def _forward(self, policy):
        import jax

        from repro.core.dispatch import use_policy
        from repro.models import forward

        with use_policy(policy):
            out = forward(self.params, self.cfg, self.batch, self.dist,
                          remat="none")
            jax.block_until_ready(out)
        return np.asarray(out)

    def reference(self):
        from repro.core.dispatch import FDP91
        if self._ref is None:
            self._ref = self._forward(FDP91)
        return self._ref

    def run(self, policy) -> ValidationReport:
        ref = self.reference()
        got = self._forward(policy)
        bits = correct_bits(got, ref, cap=LOGIT_CAP_BITS)
        score = float(np.median(bits))
        return ValidationReport(
            workload=self.name, score=score, threshold=self.threshold,
            details={"top1_agreement": top1_agreement(got, ref),
                     "min_bits": float(np.min(bits)),
                     "n_logits": int(got.size)})
