"""Loss-gradient validator: what do the backward datapaths cost, end to end?

The per-site search validates bwd assignments against a per-site oracle on a
captured sample; this workload closes the loop the ROADMAP asked for — a real
``value_and_grad`` training-loss step under the candidate policy, scored
against the *91-bit-bwd reference*: the identical policy with every backward
site (explicit assignments and the ``*@bwd`` fallback alike) forced onto the
paper's ⟨30,30,-30⟩ exact accumulator. Forward configs are common to both
runs, so forward error is common-mode and the score isolates precisely what
the searched backward truncations cost the gradients. That is also why the
attribution is ``{"*@bwd": score}``: this validator can only be fixed by
widening backward sites, and the greedy upgrade loop now knows it.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.metrics import correct_bits

from .base import ValidationReport, Validator, WorkloadContext, register

GRAD_CAP_BITS = 24.0


def bwd91_reference_policy(policy):
    """The policy with its entire backward namespace forced to the paper's
    91-bit exact FDP — and *only* the backward namespace, so forward error
    stays common-mode between candidate and reference: bwd-phase patterns
    are rewritten in place (exact keys included — a ``*@bwd`` append would
    lose to them on specificity), phase-``*`` patterns keep their config for
    the forward half and get a higher-specificity ``name@bwd`` pin for the
    backward half, and a ``*@bwd`` catch-all covers the rest."""
    from repro.core.accumulator import AccumulatorSpec
    from repro.core.dispatch import GemmConfig, _parse_pattern
    from repro.core.formats import FP32

    ref_cfg = GemmConfig(FP32, AccumulatorSpec.paper_91bit(), "simulate")
    overrides = []
    for pat, cfg in getattr(policy, "overrides", ()):
        name, phase, _op = _parse_pattern(pat)
        if phase == "bwd":
            overrides.append((pat, ref_cfg))
        else:
            overrides.append((pat, cfg))
            if phase == "*":
                # name@bwd (specificity name+phase) outranks name@* for bwd
                # lookups while leaving the pattern's fwd half untouched
                overrides.append((f"{name}@bwd", ref_cfg))
    overrides.append(("*@bwd", ref_cfg))
    return dataclasses.replace(policy, overrides=tuple(overrides),
                               name=f"{policy.name}+bwd91")


@register
class LossGradient(Validator):
    """Correct bits (plus cosine similarity) of ``value_and_grad`` gradients
    under the policy vs the 91-bit-bwd reference.

    The score is the *worst parameter tensor's* median correct bits, not the
    global median: a training step is only as good as its worst gradient (one
    busted attention tensor ruins the update while the global median — fat
    with healthy embedding/MLP gradients — still looks fine; on the reduced
    paper-MLP the global median sits ~8 bits above the worst tensor). The
    per-leaf breakdown ships in ``details["worst_leaves"]``."""

    name = "grad"
    phases = ("bwd",)

    def __init__(self, cfg, params, grad_batch, *, dist=None,
                 threshold: float = 10.0):
        from repro.models import LOCAL

        self.cfg = cfg
        self.params = params
        self.grad_batch = grad_batch
        self.dist = dist or LOCAL
        self.threshold = float(threshold)
        # single-slot reference-gradient cache: the 91-bit-bwd reference
        # depends only on the policy's forward configuration (its backward
        # namespace is pinned), so the search's @bwd-only upgrade iterations
        # reuse one reference instead of paying the slow simulated-FDP
        # backward again. One slot, not a dict: only consecutive iterations
        # ever share a key, and a dict would pin a param-sized float64
        # gradient copy per forward upgrade for zero reuse.
        self._ref_key = None
        self._ref_val = None

    @classmethod
    def from_context(cls, ctx: WorkloadContext) -> "LossGradient":
        ctx.require_model(cls.name)
        if ctx.grad_batch is None:
            raise ValueError("workload 'grad' needs ctx.grad_batch "
                             "(a batch with targets/loss_mask)")
        return cls(ctx.cfg, ctx.params, ctx.grad_batch, dist=ctx.dist,
                   threshold=ctx.budget_bits)

    def _grads(self, policy):
        import jax
        from jax.tree_util import keystr, tree_flatten_with_path

        from repro.core.dispatch import use_policy
        from repro.train.loop import make_loss_fn

        loss_fn = make_loss_fn(self.cfg, self.dist, remat="none")
        with use_policy(policy):
            (loss, _aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                self.params, self.grad_batch)
            jax.block_until_ready(grads)
        leaves = [(keystr(path), np.asarray(g, np.float64).ravel())
                  for path, g in tree_flatten_with_path(grads)[0]]
        return float(loss), leaves

    def run(self, policy) -> ValidationReport:
        from repro.core.dispatch import _parse_pattern

        # the reference is fully determined by the policy's non-bwd surface
        # (its backward namespace is pinned to ref_cfg no matter what the
        # policy's bwd patterns say), so bwd-only policy changes — exactly
        # what the search's grad-driven upgrades produce — hit the cache
        key = (policy.default.tag(),
               tuple((pat, cfg.tag()) for pat, cfg in
                     getattr(policy, "overrides", ())
                     if _parse_pattern(pat)[1] != "bwd"))
        if key != self._ref_key:
            # value first, key last: a _grads failure must not register the
            # new key over the previous policy's cached reference
            self._ref_val = self._grads(bwd91_reference_policy(policy))
            self._ref_key = key
        loss_ref, ref = self._ref_val
        loss_got, got = self._grads(policy)
        per_leaf = {path: float(np.median(correct_bits(g, r,
                                                       cap=GRAD_CAP_BITS)))
                    for (path, g), (_, r) in zip(got, ref)}
        worst = sorted(per_leaf, key=per_leaf.get)[:4]
        score = per_leaf[worst[0]]
        flat_g = np.concatenate([g for _, g in got])
        flat_r = np.concatenate([r for _, r in ref])
        denom = float(np.linalg.norm(flat_g) * np.linalg.norm(flat_r))
        cosine = float(np.dot(flat_g, flat_r) / denom) if denom else 0.0
        return ValidationReport(
            workload=self.name, score=score, threshold=self.threshold,
            site_attribution={"*@bwd": score},
            details={"cosine": cosine,
                     "median_bits": float(np.median(correct_bits(
                         flat_g, flat_r, cap=GRAD_CAP_BITS))),
                     "worst_leaves": {w: per_leaf[w] for w in worst},
                     "loss": loss_got, "loss_ref": loss_ref,
                     "n_leaves": len(per_leaf)})
