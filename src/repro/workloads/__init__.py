# repro.workloads — the end-to-end scenario zoo.
#
# The layer between the model zoo and the precision search: each workload is
# a complete numerical scenario (ill-conditioned solve, training-loss
# gradients, K-reorder reproducibility, logit fidelity) wrapped in a common
# ``Validator`` protocol (``run(policy) -> ValidationReport``) that
# ``repro.numerics.search`` consumes in place of its old hard-coded forward
# validator — scores, pass thresholds, and per-site attribution the greedy
# upgrade loop can act on (including, at last, ``@bwd`` sites).
#
#   base             - Validator / ValidationReport / registry /
#                      WorkloadContext (model binding) / probe batches
#   solve            - Ogita-Rump-Oishi dots + prescribed-condition linear
#                      systems vs the exact oracle (paper Fig. 2 harness)
#   gradients        - value_and_grad step vs the 91-bit-bwd reference
#   inference        - logit correct-bits + top-1 vs the uniform 91-bit FDP
#   reproducibility  - bit-stability of results under K-reduction reordering
#   mesh             - bit-stability across device-mesh factorizations
#                      (K-sharded sites through fdp_psum + the end-to-end
#                      logits/gradients contract on multi-device hosts)
#   quant_opt        - quantized-optimizer-state + compressed-collective
#                      training-loss curves vs the fp32-state reference
#
# ``python -m repro.workloads --plan examples/plans/<arch>.json`` runs the
# zoo against a checked-in plan (the CI smoke entry point).
from .base import (PROBE_BATCH, PROBE_SEED, PROBE_SEQ, SUMMARY_KEYS,
                   ValidationReport, Validator, WorkloadContext,
                   available_workloads, build_validators, get_workload,
                   make_probe_batch, probed_sites, register,
                   validation_summary)
from .gradients import LossGradient, bwd91_reference_policy
from .inference import LogitFidelity
from .mesh import MeshReshapeStability
from .quant_opt import QuantizedOptimizer
from .reproducibility import KReorderStability
from .solve import IllConditionedSolve

# the plan-zoo refresh's default gate: model-bound end-to-end validators
# (the opt-in "solve", "mesh" and "quant_opt" workloads join via
# --validators ... —
# solve's operand ranges are deliberately hostile to DNN-calibrated
# accumulators, and mesh's multi-shape sweep wants a multi-device host)
DEFAULT_VALIDATORS = ("grad", "logits", "repro")

__all__ = [
    "PROBE_BATCH", "PROBE_SEED", "PROBE_SEQ", "SUMMARY_KEYS",
    "ValidationReport", "Validator", "WorkloadContext",
    "available_workloads", "build_validators", "get_workload",
    "make_probe_batch", "probed_sites", "register", "validation_summary",
    "LossGradient", "bwd91_reference_policy", "LogitFidelity",
    "MeshReshapeStability", "KReorderStability", "IllConditionedSolve",
    "QuantizedOptimizer",
    "DEFAULT_VALIDATORS",
]
