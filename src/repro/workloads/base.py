"""The workload zoo's common substrate: ``Validator`` protocol + registry.

A *workload* is an end-to-end numerical scenario — an ill-conditioned solve,
a training-loss gradient, a reproducibility probe, an inference-quality
probe — that judges a ``NumericsPolicy`` the way a user of the tailored
kernels would, not the way the per-site search oracle does. Every workload
implements the same contract:

    report = validator.run(policy)          # -> ValidationReport

and a ``ValidationReport`` carries a scalar ``score`` (correct bits, unless
the validator says otherwise), the ``threshold`` it must meet, and a
``site_attribution`` map — site *patterns* (``NumericsPolicy`` override
grammar: exact keys, ``name@bwd.dA``, ``*@bwd``) scored by how that slice of
the workload fared. The attribution is what makes validators actionable:
``numerics.search`` upgrades only sites a *failing* validator says it can see,
so a loss-gradient validator drives ``@bwd`` upgrades while a logit probe
drives forward ones.

Validators register by name (``@register``) so callers select them with
strings (``search(validators=build_validators(("grad", "logits"), ctx))``,
``refresh_plans.py --validators grad,logits,repro``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

# The zoo-wide probe-batch shape. scripts/refresh_plans.py calibrates (and
# records plan evidence) on exactly this shape, and WorkloadContext.for_model
# defaults to it, so scores recomputed later (python -m repro.workloads
# --tolerance) are judged on the same data distribution the plan recorded —
# one constant, or the CI drift gate compares apples to oranges.
PROBE_BATCH, PROBE_SEQ, PROBE_SEED = 2, 8, 0

# the per-workload keys a MANIFEST entry summarizes out of a full report
SUMMARY_KEYS = ("score", "threshold", "units", "passed")


def validation_summary(meta: dict) -> dict:
    """Compact per-workload score summary of a plan's ``meta.validation``
    (full reports, with attribution and details, stay in the plan document).
    Shared by the MANIFEST writer and both gates that cross-check it."""
    return {name: {k: rep.get(k) for k in SUMMARY_KEYS}
            for name, rep in sorted((meta.get("validation") or {}).items())}


@dataclasses.dataclass
class ValidationReport:
    """One workload's verdict on one policy."""

    workload: str
    score: float                      # in ``units``; higher is better
    threshold: float                  # pass iff score >= threshold
    units: str = "bits"
    # site pattern -> score for the slice of the workload that pattern
    # dominates (exact site keys when the workload probes sites one by one,
    # namespace wildcards like "*@bwd" when it can only see a phase).
    site_attribution: dict = dataclasses.field(default_factory=dict)
    details: dict = dataclasses.field(default_factory=dict)
    # mesh provenance: the device-mesh shape(s) this validation ran under
    # (e.g. "1x8,2x4,8x1" for the mesh-reshape workload, "2x4" for a
    # mesh-bound run). None = single-device — the historical default, so
    # pre-mesh plan-zoo entries stay valid without regeneration.
    mesh: Optional[str] = None

    @property
    def passed(self) -> bool:
        return self.score >= self.threshold

    def to_json(self) -> dict:
        def _f(v):
            if isinstance(v, (np.floating, np.integer)):
                v = v.item()
            if isinstance(v, float) and not math.isfinite(v):
                return None
            return v

        out = {
            "workload": self.workload,
            "score": _f(float(self.score)),
            "threshold": _f(float(self.threshold)),
            "units": self.units,
            "passed": bool(self.passed),
            "site_attribution": {k: _f(float(v))
                                 for k, v in self.site_attribution.items()},
            "details": {k: _f(v) for k, v in self.details.items()},
        }
        if self.mesh is not None:
            out["mesh"] = str(self.mesh)
        return out

    def describe(self) -> str:
        verdict = "pass" if self.passed else "FAIL"
        return (f"{self.workload:14s} {self.score:6.1f} {self.units} "
                f"(>= {self.threshold:g}: {verdict})")


class Validator:
    """Base class for workload validators.

    Subclasses set ``name`` (registry key), ``phases`` (which site namespaces
    the score is sensitive to — the upgrade loop's fallback when a report
    carries no site attribution) and implement ``run``.
    """

    name: str = "?"
    phases: tuple = ("fwd",)
    threshold: float = 0.0

    def run(self, policy) -> ValidationReport:
        raise NotImplementedError

    # -- search integration -------------------------------------------------
    def eligible_site(self, site_key: str, report: ValidationReport) -> bool:
        """May the upgrade loop spend an upgrade on ``site_key`` to fix this
        validator's deficit?  Attribution patterns win when present; else the
        validator's declared phases.

        Aux (state/collective) site keys never parse as GemmSites, so they
        match only by exact attribution key or the kind wildcards
        ``*@state`` / ``*@coll`` — and only validators that *declare* the
        aux kind in ``phases`` may touch them without attribution."""
        from repro.core.dispatch import GemmSite, _match_score
        from repro.core.qformat import site_kind
        kind = site_kind(site_key)
        if kind != "gemm":
            if report.site_attribution:
                suffix = site_key.rpartition("@")[2]
                return any(pat == site_key or pat == f"*@{suffix}"
                           for pat in report.site_attribution)
            return kind in self.phases
        site = GemmSite.parse(site_key)
        if report.site_attribution:
            gemm_pats = [p for p in report.site_attribution
                         if site_kind(p) == "gemm"]
            return any(_match_score(pat, site) is not None
                       for pat in gemm_pats)
        return site.phase in self.phases


@dataclasses.dataclass
class WorkloadContext:
    """Everything a validator may need to instantiate itself for one model.

    Synthetic workloads (solve, repro) ignore the model fields; model-bound
    ones (grad, logits) refuse to build without them. ``budget_bits`` seeds
    the default thresholds so ``search(budget_bits=B)`` and its validators
    agree on what "good enough" means.
    """

    budget_bits: float = 10.0
    cfg: Optional[object] = None           # repro.models ModelConfig
    params: Optional[object] = None
    batch: Optional[dict] = None           # forward/logit probe batch
    grad_batch: Optional[dict] = None      # batch with targets/loss_mask
    dist: Optional[object] = None          # layers.Distribution (None=LOCAL)
    seed: int = 0

    def require_model(self, who: str) -> None:
        missing = [k for k in ("cfg", "params", "batch")
                   if getattr(self, k) is None]
        if missing:
            raise ValueError(
                f"workload {who!r} needs a model-bound context "
                f"(missing {missing}); build one with "
                "WorkloadContext.for_model(cfg, ...)")

    @classmethod
    def for_model(cls, cfg, *, budget_bits: float = 10.0,
                  seed: int = PROBE_SEED, batch_size: int = PROBE_BATCH,
                  seq: int = PROBE_SEQ) -> "WorkloadContext":
        """Self-contained model context: seeded params + probe batches of the
        same shape family the plan-zoo calibration uses."""
        import jax

        from repro.models import init

        params = init(cfg, jax.random.key(seed))
        batch = make_probe_batch(cfg, batch_size=batch_size, seq=seq,
                                 seed=seed + 1)
        grad_batch = make_probe_batch(cfg, batch_size=batch_size, seq=seq,
                                      seed=seed + 1, with_targets=True)
        return cls(budget_bits=budget_bits, cfg=cfg, params=params,
                   batch=batch, grad_batch=grad_batch, seed=seed)


def make_probe_batch(cfg, *, batch_size: int, seq: int, seed: int,
                     with_targets: bool = False) -> dict:
    """A seeded probe batch for any config family (tokens, plus VLM patches /
    enc-dec frames, plus CE targets when the workload differentiates). This is
    the same recipe the plan-zoo calibration uses, so validator scores are
    judged on data shaped like what the plan was calibrated on."""
    import jax
    import jax.numpy as jnp

    key = jax.random.key(seed)
    ks = jax.random.split(key, 4)
    batch = {"tokens": jax.random.randint(
        ks[0], (batch_size, seq), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patches"] = 0.5 * jax.random.normal(
            ks[1], (batch_size, cfg.n_patches, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = 0.5 * jax.random.normal(
            ks[2], (batch_size, cfg.enc_seq, cfg.d_model), jnp.float32)
    if with_targets:
        batch["targets"] = jax.random.randint(
            ks[3], (batch_size, seq), 0, cfg.vocab_size)
        batch["loss_mask"] = jnp.ones((batch_size, seq), jnp.float32)
    return batch


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: dict = {}


def register(cls):
    """Class decorator: add a Validator subclass to the zoo under its
    ``name``."""
    if not cls.name or cls.name == "?":
        raise ValueError(f"{cls.__name__} must set a registry name")
    if cls.name in _REGISTRY:
        raise ValueError(f"duplicate workload name {cls.name!r}")
    _REGISTRY[cls.name] = cls
    return cls


def available_workloads() -> list:
    return sorted(_REGISTRY)


def get_workload(name: str):
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown workload {name!r}; available: "
                       f"{available_workloads()}") from None


def build_validators(names: Sequence[str],
                     ctx: Optional[WorkloadContext] = None):
    """Instantiate validators by registry name against one context
    (per-validator tuning goes through the class constructors directly)."""
    ctx = ctx or WorkloadContext()
    return [get_workload(n).from_context(ctx) for n in names]


def probed_sites(policy) -> list:
    """The exact (non-wildcard) site keys a policy explicitly assigns — what
    per-site workloads probe. For a deployed PrecisionPlan policy this is
    precisely the searched site list."""
    from repro.core.dispatch import GemmSite
    out = []
    for pat, _ in getattr(policy, "overrides", ()):
        if "*" in pat:
            continue
        try:
            site = GemmSite.parse(pat)
        except ValueError:
            continue
        if site.key == pat:
            out.append(pat)
    return out
