"""Persisted GemmPlan schedules — the schedule zoo.

``GemmPlan`` autotuning measures block-size candidates on the running host
and caches the winners in the process-global plan cache — and forgets
everything at process exit. This module makes those schedules first-class
versioned artifacts next to the plan zoo (the TVM matmul-generator and
GEMMbench treatment: autotuned schedules are worth versioning, not warmup
costs): a ``ScheduleZoo`` snapshots the plan cache for one backend, persists
it as fingerprinted + schema-versioned JSON (mirroring
``repro.numerics.CalibrationTrace``), and installs back into the cache so a
warm process takes **zero** autotune misses.

Layout: one file per backend under ``examples/plans/schedules/<backend>.json``,
refreshed by ``scripts/refresh_plans.py --schedules`` and validated in CI by
``scripts/check_plan_zoo.py``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Optional

from . import dispatch
from .accumulator import SAFE_CHUNK, AccumulatorSpec
from .dispatch import GemmPlan

SCHEDULE_VERSION = 1
SCHEDULE_KIND = "repro.core.ScheduleZoo"

# Default checked-in location, next to the plan zoo.
DEFAULT_SCHEDULE_DIR = os.path.join("examples", "plans", "schedules")


def schedule_fingerprint() -> str:
    """Fingerprint of the autotune configuration a zoo file caches results
    for: the candidate tile set, the carry-headroom bound, and the timing
    discipline. Changing any of these invalidates persisted schedules —
    the measurements would no longer mean the same thing."""
    cfg = {
        "autotune_candidates": dispatch.AUTOTUNE_CANDIDATES,
        "safe_chunk": SAFE_CHUNK,
        "measure": {"reps": dispatch.MEASURE_REPS,
                    "min_seconds": dispatch.MEASURE_MIN_SECONDS},
    }
    blob = json.dumps(cfg, sort_keys=True, default=repr)
    return hashlib.sha256(blob.encode("utf-8")).hexdigest()[:16]


def _spec_doc(spec: AccumulatorSpec) -> dict:
    return {"ovf": spec.ovf, "msb": spec.msb, "lsb": spec.lsb,
            "round_mode": spec.round_mode,
            "overflow_mode": spec.overflow_mode}


@dataclasses.dataclass
class ScheduleZoo:
    """All persisted block-size schedules for one backend.

    ``entries`` maps the plan-cache problem signature — ``(batch, m, n, k,
    fmt_name, AccumulatorSpec)`` — to its ``GemmPlan``. The backend lives on
    the zoo, not the key: schedules measured on one backend say nothing
    about another.
    """

    backend: str
    entries: dict
    meta: dict = dataclasses.field(default_factory=dict)

    @classmethod
    def from_cache(cls, backend: Optional[str] = None,
                   meta: Optional[dict] = None) -> "ScheduleZoo":
        """Snapshot the process-global plan cache for ``backend`` (default:
        the current JAX backend)."""
        import jax
        backend = backend or jax.default_backend()
        entries = {}
        with dispatch._PLAN_LOCK:
            for key, plan in dispatch._PLAN_CACHE.items():
                batch, m, n, k, fmt_name, spec, be = key
                if be == backend:
                    entries[(batch, m, n, k, fmt_name, spec)] = plan
        return cls(backend=backend, entries=entries, meta=dict(meta or {}))

    def install(self, *, source: str = "persisted") -> int:
        """Install this zoo's schedules into the process-global plan cache
        (marked ``source="persisted"``) and count them in
        ``PlanCacheStats.persisted_loads``. Explicit ``register_plan``
        overrides are never clobbered. Returns the number installed."""
        installed = 0
        with dispatch._PLAN_LOCK:
            for (batch, m, n, k, fmt_name, spec), plan in self.entries.items():
                key = (batch, m, n, k, fmt_name, spec, self.backend)
                cached = dispatch._PLAN_CACHE.get(key)
                if cached is not None and cached.source == "override":
                    continue
                dispatch._PLAN_CACHE[key] = dataclasses.replace(
                    plan, source=source)
                installed += 1
            dispatch._PLAN_SIZE.set(len(dispatch._PLAN_CACHE))
        if installed:
            dispatch._plan_stats_inc("persisted_loads", installed)
        return installed

    def save(self, path) -> None:
        """Serialize to versioned JSON (schema + fingerprint headers first,
        entries sorted — byte-stable for a given cache state)."""
        rows = []
        for (batch, m, n, k, fmt_name, spec), plan in sorted(
                self.entries.items(),
                key=lambda kv: (kv[0][4], repr(kv[0][5]), kv[0][:4])):
            rows.append({"batch": batch, "m": m, "n": n, "k": k,
                         "fmt": fmt_name, "spec": _spec_doc(spec),
                         "bm": plan.bm, "bn": plan.bn, "bk": plan.bk,
                         "source": plan.source})
        doc = {
            "version": SCHEDULE_VERSION,
            "kind": SCHEDULE_KIND,
            "fingerprint": schedule_fingerprint(),
            "backend": self.backend,
            "meta": self.meta,
            "entries": rows,
        }
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")

    @classmethod
    def load(cls, path, *, check_fingerprint: bool = True) -> "ScheduleZoo":
        """Load and validate a zoo file. Rejects documents of the wrong
        kind, from a future schema version, or (by default) whose autotune
        configuration no longer matches this build — a stale schedule is a
        measurement of a different search space."""
        with open(path) as f:
            doc = json.load(f)
        kind = doc.get("kind")
        if kind != SCHEDULE_KIND:
            raise ValueError(
                f"{path} is not a schedule zoo (kind={kind!r}, "
                f"expected {SCHEDULE_KIND!r})")
        version = doc.get("version")
        if not isinstance(version, int) or version > SCHEDULE_VERSION:
            raise ValueError(
                f"{path} has schema version {version!r}, this build reads "
                f"<= {SCHEDULE_VERSION} — refusing to guess its semantics")
        fp, want = doc.get("fingerprint"), schedule_fingerprint()
        if check_fingerprint and fp != want:
            raise ValueError(
                f"{path} fingerprint {fp!r} != current autotune config "
                f"{want!r} — the candidate set or timing discipline changed; "
                f"refresh with scripts/refresh_plans.py --schedules")
        entries = {}
        for row in doc.get("entries", []):
            spec = AccumulatorSpec(**row["spec"])
            key = (int(row["batch"]), int(row["m"]), int(row["n"]),
                   int(row["k"]), row["fmt"], spec)
            entries[key] = GemmPlan(int(row["bm"]), int(row["bn"]),
                                    int(row["bk"]),
                                    source=row.get("source", "persisted"))
        return cls(backend=doc["backend"], entries=entries,
                   meta=doc.get("meta", {}))


def zoo_path(directory: Optional[str] = None,
             backend: Optional[str] = None) -> str:
    import jax
    return os.path.join(directory or DEFAULT_SCHEDULE_DIR,
                        f"{backend or jax.default_backend()}.json")


def preload_schedules(directory: Optional[str] = None,
                      backend: Optional[str] = None) -> int:
    """Warm the plan cache from the checked-in schedule zoo for the current
    backend, if a file exists. Returns the number of schedules installed
    (0 when no zoo is checked in for this backend) — after which a process
    serving the covered shapes takes zero autotune misses. Called by the
    serve/train/dryrun launch drivers and the serving CLI at startup."""
    path = zoo_path(directory, backend)
    if not os.path.exists(path):
        return 0
    return ScheduleZoo.load(path).install()
