"""Computer-format front end of the FDP datapath.

The paper's generator is *format agnostic*: IEEE-754, bfloat16 and posit inputs
are all decoded to a (sign, integer-significand, exponent) triple before their
products enter the fixed-point accumulator.  This module is the JAX/TPU
equivalent of that decode stage: branch-free integer bit manipulation
(``lax.bitcast_convert_type`` + shifts/masks) that lowers both in plain XLA and
inside Pallas kernel bodies.

Conventions
-----------
``decode(x) -> Decoded(sign, mant, exp)`` with value ``(-1)^sign * mant * 2^exp``
where ``mant`` is an int32 in ``[0, 2^precision)`` (zero for ±0) and the triple
is exact for every finite input including subnormals.  ``precision`` counts the
implicit bit (24 for fp32, 8 for bf16, 11 for fp16).  NaN/Inf are flagged.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array

_U1 = lambda: jnp.uint32(1)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Decoded:
    """Exact (sign, mantissa, exponent) decomposition: (-1)^s * m * 2^e."""

    sign: Array      # int32, 0 or 1
    mant: Array      # int32, 0 <= m < 2^precision (0 iff value == 0)
    exp: Array       # int32, exponent of the *integer* mantissa
    is_nan: Array    # bool
    is_inf: Array    # bool

    def tree_flatten(self):
        return (self.sign, self.mant, self.exp, self.is_nan, self.is_inf), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def _clz32(x: Array) -> Array:
    """Count leading zeros of a 32-bit value (branch-free binary search)."""
    x = x.astype(jnp.uint32)
    c = jnp.zeros(x.shape, dtype=jnp.int32)
    for shift in (16, 8, 4, 2, 1):
        y = jnp.right_shift(x, jnp.uint32(shift))
        move = y != 0
        c = c + jnp.where(move, shift, 0)
        x = jnp.where(move, y, x)
    return jnp.where(x == 0, 32, 31 - c).astype(jnp.int32)


def _ilog2(m: Array) -> Array:
    """floor(log2(m)) for positive values (int32 domain)."""
    return 31 - _clz32(m.astype(jnp.uint32))


@dataclasses.dataclass(frozen=True)
class FloatFormat:
    """An IEEE-754-style binary interchange format (≤ 32 bits wide)."""

    name: str
    exp_bits: int
    mant_bits: int          # explicit fraction bits (no implicit bit)
    jnp_dtype: object

    @property
    def precision(self) -> int:       # significand incl. implicit bit
        return self.mant_bits + 1

    @property
    def bias(self) -> int:
        return (1 << (self.exp_bits - 1)) - 1

    @property
    def emax(self) -> int:
        return self.bias

    @property
    def emin(self) -> int:           # min normal exponent
        return 1 - self.bias

    def decode(self, x: Array) -> Decoded:
        """Exact (sign, mant, exp). Input is upcast to f32 (exact for every
        format narrower than f32), then decoded with integer bit ops."""
        xf = x.astype(jnp.float32)
        bits = lax.bitcast_convert_type(xf, jnp.uint32)
        sign = (jnp.right_shift(bits, jnp.uint32(31)) & 1).astype(jnp.int32)
        biased = (jnp.right_shift(bits, jnp.uint32(23)) & 0xFF).astype(jnp.int32)
        frac = (bits & 0x7FFFFF).astype(jnp.int32)
        is_sub = biased == 0
        is_special = biased == 0xFF
        mant = jnp.where(is_sub, frac, frac | (1 << 23))
        exp = jnp.where(is_sub, -126 - 23, biased - 127 - 23)
        mant = jnp.where(is_special, 0, mant).astype(jnp.int32)
        is_nan = is_special & (frac != 0)
        is_inf = is_special & (frac == 0)
        return Decoded(sign, mant, exp.astype(jnp.int32), is_nan, is_inf)

    def quantize(self, x: Array) -> Array:
        """Round an f32 array onto this format's grid and return it as f32."""
        return x.astype(jnp.float32).astype(self.jnp_dtype).astype(jnp.float32)


FP32 = FloatFormat("ieee_fp32", 8, 23, jnp.float32)
BF16 = FloatFormat("bfloat16", 8, 7, jnp.bfloat16)
FP16 = FloatFormat("ieee_fp16", 5, 10, jnp.float16)


# ---------------------------------------------------------------------------
# Posit⟨n, es⟩ — stored as int32 bit patterns in the low ``nbits``.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PositFormat:
    """Posit⟨nbits, es⟩ (posit standard 2022). NaR decodes to is_nan; encode
    saturates at ±maxpos (posits have no infinities)."""

    name: str
    nbits: int
    es: int

    @property
    def precision(self) -> int:
        # max significand bits incl. implicit bit (minimal regime of 2 bits)
        return max(1, self.nbits - 3 - self.es) + 1

    @property
    def jnp_dtype(self):
        return jnp.int32  # carrier

    def decode(self, p: Array) -> Decoded:
        n, es = self.nbits, self.es
        mask = jnp.uint32((1 << n) - 1)
        u = p.astype(jnp.uint32) & mask
        sign = (jnp.right_shift(u, jnp.uint32(n - 1)) & 1).astype(jnp.int32)
        is_zero = u == 0
        is_nar = u == jnp.uint32(1 << (n - 1))
        body = jnp.where(sign == 1, (jnp.uint32(0) - u) & mask, u)
        body = body & jnp.uint32((1 << (n - 1)) - 1)          # low n-1 bits
        # regime: run of identical bits starting at bit n-2
        aligned = jnp.left_shift(body, jnp.uint32(33 - n))    # bit n-2 -> bit 31
        first = (jnp.right_shift(aligned, jnp.uint32(31)) & 1).astype(jnp.int32)
        probe = jnp.where(first == 1, ~aligned, aligned)
        run = jnp.minimum(_clz32(probe), n - 1)
        k = jnp.where(first == 1, run - 1, -run)
        rem = jnp.maximum(n - 1 - run - 1, 0)                 # bits for es+frac
        tail = (body & (jnp.left_shift(jnp.uint32(1), rem.astype(jnp.uint32)) - 1)).astype(jnp.int32)
        e_take = jnp.minimum(rem, es)
        e_bits = jnp.right_shift(tail, rem - e_take)
        e_val = jnp.left_shift(e_bits, es - e_take)           # missing low e bits = 0
        f_bits = rem - e_take
        frac = tail & (jnp.left_shift(1, f_bits) - 1)
        mant = jnp.left_shift(1, f_bits) | frac               # 1.frac as integer
        scale = k * (1 << es) + e_val                         # exponent of leading 1
        exp = scale - f_bits
        mant = jnp.where(is_zero | is_nar, 0, mant).astype(jnp.int32)
        return Decoded(sign, mant, exp.astype(jnp.int32), is_nar,
                       jnp.zeros_like(is_nar))

    def to_float(self, p: Array) -> Array:
        d = self.decode(p)
        v = jnp.ldexp(d.mant.astype(jnp.float32), d.exp)
        v = jnp.where(d.sign == 1, -v, v)
        return jnp.where(d.is_nan, jnp.float32(jnp.nan), v)

    def from_float(self, x: Array) -> Array:
        """RNE-encode f32 → nearest posit pattern (saturating, no underflow to 0)."""
        n, es = self.nbits, self.es
        d = FP32.decode(x)
        is_zero = d.mant == 0
        # normalize integer mantissa to [2^23, 2^24)
        up = jnp.maximum(23 - _ilog2(jnp.maximum(d.mant, 1)), 0)
        m = jnp.left_shift(d.mant, up)
        scale = d.exp - up + 23                                # exp of leading 1
        k = jnp.floor_divide(scale, 1 << es)
        e = scale - k * (1 << es)                              # in [0, 2^es)
        run = jnp.where(k >= 0, k + 1, -k)
        run = jnp.clip(run, 1, n - 1)
        reg_len = jnp.minimum(run + 1, n - 1)                  # incl. terminator
        rem = n - 1 - reg_len                                  # bits for e+frac
        e_take = jnp.minimum(rem, es)
        f_bits = jnp.maximum(rem - es, 0)
        # combined (es+23)-bit stream of exponent+fraction bits
        frac23 = (m & ((1 << 23) - 1)).astype(jnp.uint32)
        stream = jnp.left_shift(e.astype(jnp.uint32), jnp.uint32(23)) | frac23
        t = (es + 23) - (e_take + f_bits)                      # dropped low bits
        # t < 0 means the posit has more fraction bits than the f32 source:
        # zero-pad on the right instead of shifting by a negative amount.
        tpos = jnp.maximum(t, 0).astype(jnp.uint32)
        tneg = jnp.maximum(-t, 0).astype(jnp.uint32)
        taken = jnp.where(t >= 0,
                          jnp.right_shift(stream, tpos),
                          jnp.left_shift(stream, tneg))
        guard = jnp.where(
            t >= 1,
            jnp.right_shift(stream, jnp.maximum(t - 1, 0).astype(jnp.uint32)) & 1,
            jnp.uint32(0))
        sticky = jnp.where(
            t >= 1,
            (stream & (jnp.left_shift(jnp.uint32(1),
                                      jnp.maximum(t - 1, 0).astype(jnp.uint32)) - 1)) != 0,
            False)
        # regime field bits (within low n-1): run ones+0 (k>=0) / run zeros+1 (k<0)
        ones = jnp.left_shift(jnp.uint32(1), run.astype(jnp.uint32)) - 1
        reg_bits = jnp.where(k >= 0,
                             jnp.left_shift(ones, (reg_len - run).astype(jnp.uint32)),
                             jnp.where(reg_len > run, jnp.uint32(1), jnp.uint32(0)))
        body = jnp.left_shift(reg_bits, rem.astype(jnp.uint32)) | taken
        rnd = (guard == 1) & (sticky | ((body & 1) == 1))
        body = body + jnp.where(rnd, jnp.uint32(1), jnp.uint32(0))
        maxpos = jnp.uint32((1 << (n - 1)) - 1)
        body = jnp.clip(body, jnp.uint32(1), maxpos)           # saturate, no flush to 0
        mask = jnp.uint32((1 << n) - 1)
        patt = jnp.where(d.sign == 1, (jnp.uint32(0) - body) & mask, body)
        patt = jnp.where(is_zero, jnp.uint32(0), patt)
        patt = jnp.where(d.is_nan | d.is_inf, jnp.uint32(1 << (n - 1)), patt)
        return patt.astype(jnp.int32)


POSIT16_1 = PositFormat("posit16_1", 16, 1)
POSIT32_2 = PositFormat("posit32_2", 32, 2)
POSIT8_0 = PositFormat("posit8_0", 8, 0)

FORMATS = {
    f.name: f for f in (FP32, BF16, FP16, POSIT16_1, POSIT32_2, POSIT8_0)
}


def get_format(name: str):
    return FORMATS[name]
