"""The kernel *generator* — the flopoco analogue.

``generate_gemm(spec, fmt, target)`` returns a compiled GEMM callable plus a
flopoco-style datapath report (resource estimate, power, tiling).  Targets:

    * ``simulate`` — pure-jnp bit-exact FDP (repro.core.fdp)
    * ``pallas``   — the Pallas TPU kernel (repro.kernels), interpret=True off-TPU
    * ``native``   — jnp.dot with fp32 accumulation (the MXU fast path;
                     the "conventional FPU" point in the design space)

The report mirrors what flopoco prints after pipelining a datapath for a
(chip, frequency) pair: here the "chip" is a TPU core and the resources are
limb counts / int-op counts / VMEM bytes / modeled watts.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from . import energy
from .accumulator import AccumulatorSpec, LIMB_BITS
from .formats import FP32, FloatFormat, PositFormat, get_format


@dataclasses.dataclass(frozen=True)
class DatapathReport:
    """What the generator 'synthesized' (flopoco report analogue)."""

    name: str
    fmt: str
    spec: AccumulatorSpec
    target: str
    num_limbs: int
    digit_mults_per_mac: int        # 12x12 partial products per MAC
    int_ops_per_mac: int
    vmem_bytes_per_tile: int
    tile: tuple
    watts_fpga_model: float         # VU3P-calibrated model
    pj_per_mac_tpu_model: float

    def describe(self) -> str:
        return (f"[generator] {self.name}: fmt={self.fmt} {self.spec.describe()} "
                f"target={self.target} tile={self.tile} "
                f"limbs={self.num_limbs} vmem/tile={self.vmem_bytes_per_tile}B "
                f"P_model={self.watts_fpga_model:.3f}W "
                f"E_tpu={self.pj_per_mac_tpu_model:.1f}pJ/MAC")


@dataclasses.dataclass(frozen=True)
class GeneratedGemm:
    fn: Callable                    # (a, b) -> f32 (M,N)
    report: DatapathReport


def generate_gemm(spec: AccumulatorSpec | None,
                  fmt: FloatFormat | PositFormat | str = FP32,
                  target: str = "simulate",
                  tile: tuple | None = None) -> GeneratedGemm:
    """Generate a GEMM kernel for a numerical spec (None = native fp32 acc).

    ``tile=None`` defers block sizes to the ``GemmPlan`` autotuner in
    ``repro.core.dispatch`` (resolved per call shape, cached); an explicit
    (bm, bn, bk) pins them."""
    if isinstance(fmt, str):
        fmt = get_format(fmt)

    if target == "native" or spec is None:
        dtype = getattr(fmt, "jnp_dtype", jnp.float32)
        if isinstance(fmt, PositFormat):
            raise ValueError("posit inputs have no native MXU path")

        @jax.jit
        def native(a, b):
            return jnp.dot(a.astype(dtype), b.astype(dtype),
                           preferred_element_type=jnp.float32)

        rep = _native_report("native_mxu", fmt, spec, tile)
        return GeneratedGemm(native, rep)

    if target == "simulate":
        from . import fdp

        fn = partial(fdp.fdp_gemm, spec=spec, fmt=fmt)
        rep = _report("fdp_sim", fmt, spec, "simulate", tile)
        return GeneratedGemm(jax.jit(fn), rep)

    if target == "pallas":
        from repro.kernels import ops as kops

        from . import dispatch

        if tile is None:

            def fn(a, b):
                p = dispatch.plan_gemm(a.shape[0], b.shape[1], a.shape[1],
                                       fmt=fmt, spec=spec)
                return kops.fdp_gemm(a, b, spec=spec, fmt=fmt, plan=p)
        else:
            fn = partial(kops.fdp_gemm, spec=spec, fmt=fmt,
                         plan=dispatch.GemmPlan(*tile))
        rep = _report("fdp_pallas", fmt, spec, "pallas", tile)
        return GeneratedGemm(fn, rep)

    raise ValueError(f"unknown target {target!r}")


def datapath_report(spec: AccumulatorSpec | None,
                    fmt: FloatFormat | PositFormat | str = FP32,
                    target: str = "simulate",
                    tile: tuple | None = None,
                    name: str | None = None) -> DatapathReport:
    """The generator's report alone, without compiling a kernel — what the
    tailoring search in ``repro.numerics`` attaches to every candidate
    ⟨format, accumulator, backend⟩ point so its Pareto axes (modeled watts,
    pJ/MAC, VMEM) come from the same model as the generated datapaths.

    ``spec=None`` describes the conventional-FPU native path (fp32
    accumulate): FMA power model, MXU pJ/MAC, no limb machinery.
    """
    if isinstance(fmt, str):
        fmt = get_format(fmt)
    if spec is None or target == "native":
        return _native_report(name or "native_mxu", fmt, spec, tile)
    return _report(name or f"fdp_{target}", fmt, spec, target, tile)


def _native_report(name, fmt, spec, tile):
    """Report for the MXU/native fp32-accumulate path: the conventional-FMA
    point of the design space (no limbs, no int-op algebra)."""
    spec_eff = spec or AccumulatorSpec(ovf=8, msb=128, lsb=-126)  # ~fp32 acc
    bm, bn, bk = tile if tile is not None else (128, 128, 1024)
    vmem = (bm * bk + bk * bn) * 4 + bm * bn * 4
    return DatapathReport(
        name=name, fmt=fmt.name, spec=spec_eff, target="native",
        num_limbs=0, digit_mults_per_mac=0, int_ops_per_mac=0,
        vmem_bytes_per_tile=vmem,
        tile=tile if tile is not None else "auto",
        watts_fpga_model=energy.gemm_power(fmt, None).watts,
        pj_per_mac_tpu_model=energy.TPU_PJ_PER_MXU_MAC,
    )


def _report(name, fmt, spec, target, tile):
    digits = -(-fmt.precision // 12)
    L = spec.num_limbs
    int_ops = digits * digits + 2 * digits * L + L
    # tile=None (auto-plan): estimate VMEM with the planner's largest tile
    bm, bn, bk = tile if tile is not None else (128, 128, 1024)
    vmem = (bm * bk + bk * bn) * 4 + bm * bn * L * 4
    return DatapathReport(
        name=name, fmt=fmt.name, spec=spec, target=target,
        num_limbs=L, digit_mults_per_mac=digits * digits,
        int_ops_per_mac=int_ops, vmem_bytes_per_tile=vmem,
        tile=tile if tile is not None else "auto",
        watts_fpga_model=energy.spec_power(fmt, spec).watts,
        pj_per_mac_tpu_model=energy.tpu_fdp_pj_per_mac(fmt.precision, L),
    )
