"""Analytical power/energy model of the generated datapaths.

The paper reports measured watts on a VU3P-2 FPGA @ 200 MHz:
    double-precision FMA : 0.266 W
    quad-precision  FMA  : 0.549 W
    91-bit FDP ⟨30,30,-30⟩: 0.491 W

With no synthesizer in the loop we fit a simple structural model to those
anchors and use it for every ⟨format, ovf, msb, lsb⟩ point of the Fig. 3
sweeps. Dynamic power of an arithmetic datapath is dominated by
(a) the significand multiplier — ~quadratic in significand width p — and
(b) the accumulator/alignment stage — ~linear in accumulator width W:

    P(p, W) = alpha * p^2 + beta * W + gamma        [watts @ 200 MHz]

Three anchors, three parameters (exact fit):
    fp64 FMA:  p=53, W=~106 effective (FMA rounds each step; datapath width
               is mult 2p + normalizer): P = 0.266
    fp128 FMA: p=113, W=226:            P = 0.549
    91-bit FDP (fp64 front end): p=53, W=91: P = 0.491

The FDP's extra cost vs the fp64 FMA at the same p reflects the wide
fixed-point adder + shifter — captured by a separate delta on beta for
fdp-style datapaths (the fit below). Energies are then E = P * cycles / f
with one MAC issued per cycle (the generator's II=1 pipelines).

This is a *model*, clearly labelled as such in every benchmark output; its
purpose is to preserve the paper's accuracy-vs-energy trade-off axis, not to
predict silicon.  A TPUv5e-flavored variant (pJ/MAC) is included for the
roofline discussion.
"""

from __future__ import annotations

import dataclasses

import numpy as np

FREQ_HZ = 200e6


def _fit():
    # unknowns: alpha (mult, p^2), beta_fma (per datapath-width bit for FMA),
    # anchor widths: FMA datapath width ~ 2p (product) ; FDP width = W.
    # Solve with gamma shared:
    #   a*53^2  + b*106 + g = 0.266
    #   a*113^2 + b*226 + g = 0.549
    #   a*53^2  + c*91  + g = 0.491   (c = beta for FDP wide adders/shifter)
    # Underdetermined (4 unknowns, 3 eqs): pin gamma = 0.05 W (static/clock
    # tree floor, typical for small VU3P designs).
    g = 0.05
    A = np.array([[53.0**2, 106.0], [113.0**2, 226.0]])
    y = np.array([0.266 - g, 0.549 - g])
    alpha, beta = np.linalg.solve(A, y)
    c = (0.491 - g - alpha * 53.0**2) / 91.0
    return float(alpha), float(beta), float(c), g


ALPHA, BETA_FMA, BETA_FDP, GAMMA = _fit()


@dataclasses.dataclass(frozen=True)
class PowerReport:
    watts: float
    alpha_term: float
    beta_term: float
    gamma: float
    kind: str

    def energy_joules(self, n_macs: int, macs_per_cycle: int = 1) -> float:
        cycles = n_macs / macs_per_cycle
        return self.watts * cycles / FREQ_HZ


def fma_power(precision: int) -> PowerReport:
    """Conventional FMA unit power (paper baseline). precision = significand
    bits incl. implicit (24 fp32, 53 fp64, 113 fp128)."""
    a = ALPHA * precision**2
    b = BETA_FMA * (2 * precision)
    return PowerReport(a + b + GAMMA, a, b, GAMMA, f"fma_p{precision}")


def fdp_power(precision: int, acc_width: int) -> PowerReport:
    """Tailored FDP unit power: significand multiplier at input precision +
    wide fixed-point accumulate at ``acc_width`` bits."""
    a = ALPHA * precision**2
    b = BETA_FDP * acc_width
    return PowerReport(a + b + GAMMA, a, b, GAMMA, f"fdp_p{precision}_w{acc_width}")


def spec_power(fmt, spec) -> PowerReport:
    """Power of the generated ⟨format, ovf,msb,lsb⟩ GEMM processing element."""
    return fdp_power(fmt.precision, spec.width)


def gemm_power(fmt, spec=None) -> PowerReport:
    """Power of one GEMM processing element for a dispatch-level candidate:
    ``spec=None`` is the conventional-FMA path at the format's precision
    (the MXU/native point of the design space), otherwise the tailored FDP
    at the accumulator's width. This is the per-candidate energy axis of the
    ``repro.numerics`` Pareto search."""
    return fma_power(fmt.precision) if spec is None else spec_power(fmt, spec)


# --- sanity: reproduce the paper's three calibration points ---------------
PAPER_POINTS = {
    "fp64_fma": (fma_power(53).watts, 0.266),
    "fp128_fma": (fma_power(113).watts, 0.549),
    "fdp91_fp64": (fdp_power(53, 91).watts, 0.491),
}


# --- TPU-flavored energy (for roofline discussion only) -------------------
# v5e-class: ~197 TFLOP/s bf16 at ~200 W chip power -> ~1.0 pJ/FLOP ->
# ~2 pJ/MAC on the MXU. VPU int32 ops ~0.5 pJ/op; the limb FDP spends
# ~(digits^2 products + 2*digits*L placement + L adds) int ops per MAC.
TPU_PJ_PER_MXU_MAC = 2.0
TPU_PJ_PER_VPU_OP = 0.5


def tpu_fdp_pj_per_mac(precision: int, num_limbs: int) -> float:
    digits = -(-precision // 12)
    int_ops = digits * digits + 2 * digits * num_limbs + num_limbs
    return int_ops * TPU_PJ_PER_VPU_OP
