# The paper's primary contribution: numerically-tailored GEMM computation.
# - formats:     IEEE-754 / bfloat16 / posit decode-encode front end
# - accumulator: the ⟨ovf,msb,lsb⟩ fixed-point (Kulisch) scratchpad, int32 limbs
# - fdp:         fused dot product / GEMM with exact accumulation
# - generator:   flopoco-analogue kernel generator + datapath report
# - dispatch:    BLAS-style transparent numerics policy (OpenBLAS-swap analogue)
# - metrics:     correct-bits / reproducibility probes (Fig. 2)
# - energy:      VU3P-calibrated power model (Fig. 2/3 energy axis)
from .accumulator import AccumulatorSpec, SAFE_CHUNK
from .formats import (BF16, FP16, FP32, POSIT8_0, POSIT16_1, POSIT32_2,
                      FloatFormat, PositFormat, get_format)
from .fdp import dd_dot, fdp_dot, fdp_gemm, fma_dot
from .generator import (DatapathReport, GeneratedGemm, datapath_report,
                        generate_gemm)
from .dispatch import (FDP91, GemmPlan, GemmSite, PlanCacheStats, plan_gemm,
                       plan_cache_stats, policy_from_plan,
                       register_plan, reset_sites_seen, sites_seen,
                       widen_config)
from .schedules import ScheduleZoo, preload_schedules

__all__ = [
    "AccumulatorSpec", "SAFE_CHUNK", "FP32", "BF16", "FP16",
    "POSIT16_1", "POSIT32_2", "POSIT8_0", "FloatFormat", "PositFormat",
    "get_format", "fdp_dot", "fdp_gemm", "fma_dot", "dd_dot",
    "generate_gemm", "GeneratedGemm", "DatapathReport", "datapath_report",
    "FDP91", "GemmPlan", "GemmSite", "PlanCacheStats", "plan_gemm",
    "plan_cache_stats", "policy_from_plan",
    "register_plan", "reset_sites_seen", "sites_seen", "widen_config",
    "ScheduleZoo", "preload_schedules",
]
