"""BLAS-style transparent dispatch — the OpenBLAS-swap analogue.

High-level model code never calls ``jnp.dot`` directly; it calls
``repro.core.dispatch.gemm(a, b, site="attn_qk")``.  A ``NumericsPolicy``
(installed via context manager, like re-linking OpenBLAS at runtime) maps each
*call-site* to a ``GemmConfig`` ⟨format, accumulator, execution target⟩, so an
unmodified model can be re-run under any numerics without touching its code —
the paper's "runtime execution flow".

Site identity is structured: a ``GemmSite(name, phase, operand)`` names not
just the call-site but the *computation stage* running through it. Model code
keeps passing plain strings ("attn_qk" parses to the forward site); the
dispatch entry points carry a ``jax.custom_vjp`` so the two backward GEMMs of
every site (dL/dA = G·Bᵀ, dL/dB = Aᵀ·G) dispatch as first-class sites of
their own — ``attn_qk@bwd.dA`` / ``attn_qk@bwd.dB`` — with their own policy
lookup, tracing, and plan assignments. Gradients have very different dynamic
range and cancellation behavior than forwards; phase-aware identity is what
lets the tailoring search treat them that way.

Modes:
    native   - MXU fast path: inputs cast to the format's dtype,
               jnp.dot(..., preferred_element_type=f32). Default everywhere;
               this is what the multi-pod dry-run lowers.
    simulate - bit-exact ⟨ovf,msb,lsb⟩ FDP (repro.core.fdp).
    pallas   - the Pallas TPU kernel (interpret on CPU).

Batched inputs (ndim > 2) are supported in all modes (simulate/pallas vmap
over leading dims; native uses dot_general via jnp.matmul semantics).

Autodiff support is *reverse-mode only*: the custom_vjp that makes backward
GEMMs first-class sites has no defjvp, so ``jax.jvp``/``jacfwd`` through the
dispatch entry points raise (forward-mode was never meaningful for the FDP
modes anyway — their integer limb algebra has no useful tangents).
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import threading
from functools import partial
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.registry import default_registry as _obs_registry

from .accumulator import SAFE_CHUNK, AccumulatorSpec
from .formats import BF16, FP32, FloatFormat, PositFormat, get_format

Array = jax.Array


# ---------------------------------------------------------------------------
# Structured site identity
# ---------------------------------------------------------------------------
PHASES = ("fwd", "bwd")
OPERANDS = ("", "dA", "dB")


@dataclasses.dataclass(frozen=True)
class GemmSite:
    """Structured identity of one GEMM computation stage.

    ``name`` is the model-level call-site ("attn_qk"), ``phase`` the autodiff
    stage ("fwd" | "bwd") and ``operand`` which backward GEMM this is
    ("dA" for the input/activation gradient G·Bᵀ, "dB" for the weight
    gradient Aᵀ·G; empty for forward). The canonical string form is what
    every registry (``sites_seen``, calibration traces, precision plans)
    keys on:

        fwd:  "attn_qk"
        bwd:  "attn_qk@bwd.dA", "attn_qk@bwd.dB"
    """

    name: str
    phase: str = "fwd"
    operand: str = ""

    def __post_init__(self):
        if self.phase not in PHASES:
            raise ValueError(f"bad site phase {self.phase!r}")
        if self.operand not in OPERANDS:
            raise ValueError(f"bad site operand {self.operand!r}")
        if self.phase == "fwd" and self.operand:
            raise ValueError("forward sites carry no operand tag")
        if "@" in self.name or "." in self.name:
            raise ValueError(f"site name {self.name!r} may not contain @ or .")

    @property
    def key(self) -> str:
        """Canonical string key (forward sites stay plain names, so every
        pre-existing string-keyed artifact reads unchanged)."""
        if self.phase == "fwd":
            return self.name
        return (f"{self.name}@{self.phase}.{self.operand}"
                if self.operand else f"{self.name}@{self.phase}")

    def bwd(self, operand: str) -> "GemmSite":
        return GemmSite(self.name, "bwd", operand)

    @classmethod
    def parse(cls, site: Union[str, "GemmSite"]) -> "GemmSite":
        """String shim: model call-sites keep passing plain names."""
        if isinstance(site, GemmSite):
            return site
        if "@" not in site:
            return cls(site)
        name, _, rest = site.partition("@")
        phase, _, operand = rest.partition(".")
        return cls(name, phase, operand)


def _parse_pattern(pat: str) -> tuple:
    """Pattern grammar ``NAME[@PHASE[.OPERAND]]``: NAME may end in ``*``
    (prefix match, bare ``*`` matches everything); PHASE/OPERAND may be
    ``*``. A pattern with no ``@`` is *forward-only* — exactly the v1
    semantics, so pre-phase plans never silently capture gradient GEMMs."""
    if "@" in pat:
        name, _, rest = pat.partition("@")
        phase, _, op = rest.partition(".")
        return name, phase, (op or "*")
    return pat, "fwd", "*"


def _match_score(pat: str, site: GemmSite) -> Optional[int]:
    """Specificity of a pattern against a site, or None on no match.
    Exact name beats prefix wildcard; exact phase beats ``*``; exact operand
    beats ``*`` — so ``attn_qk@bwd.dA`` > ``attn_qk@bwd`` > ``attn_*@bwd``
    > ``*@bwd`` for a backward site, and forward lookups behave exactly as
    the flat-string v1 dispatch did."""
    name, phase, op = _parse_pattern(pat)
    if name == site.name:
        score = 8
    elif name.endswith("*") and site.name.startswith(name[:-1]):
        score = 2
    else:
        return None
    if phase == site.phase:
        score += 4
    elif phase != "*":
        return None
    if op == site.operand:
        score += 1
    elif op != "*":
        return None
    return score


@dataclasses.dataclass(frozen=True)
class GemmConfig:
    fmt: FloatFormat | PositFormat = BF16
    acc: Optional[AccumulatorSpec] = None      # None => native fp32 accumulate
    mode: str = "native"                       # native | simulate | pallas

    def __post_init__(self):
        if self.mode not in ("native", "simulate", "pallas"):
            raise ValueError(self.mode)
        if self.mode != "native" and self.acc is None:
            raise ValueError(f"mode={self.mode} requires an AccumulatorSpec")

    def tag(self) -> str:
        acc = (f"<{self.acc.ovf},{self.acc.msb},{self.acc.lsb}>"
               if self.acc else "fp32acc")
        return f"{self.fmt.name}/{acc}/{self.mode}"


def widen_config(cfg: GemmConfig) -> GemmConfig:
    """The gradient-safe fallback for sites with no explicit bwd assignment:
    full-precision inputs, and for FDP modes the paper's ⟨30,30,-30⟩ 91-bit
    accumulator (overflow-free and effectively exact on any sane gradient
    range). Backward GEMMs cancel harder and swing wider than their forward
    twins, so an unassigned bwd site must *widen*, never inherit."""
    if cfg.mode == "native":
        return GemmConfig(FP32, None, "native")
    return GemmConfig(FP32, AccumulatorSpec.paper_91bit(), cfg.mode)


@dataclasses.dataclass(frozen=True)
class NumericsPolicy:
    """Call-site -> GemmConfig mapping. ``default`` covers unlisted sites.

    Patterns are phase-aware (see ``_parse_pattern``): plain names and
    trailing-``*`` prefixes match *forward* sites only; ``name@bwd``,
    ``name@bwd.dA`` and the wildcard fallback ``*@bwd`` address backward
    sites. The most specific matching pattern wins; ties go to the earliest
    override (``with_override`` prepends)."""

    default: GemmConfig = GemmConfig()
    overrides: tuple = ()                      # tuple[(pattern, GemmConfig)]
    name: str = "default"
    # Non-GEMM precision assignments keyed by qformat site keys
    # ("opt.m@state", "grad_psum@coll") mapping to qformat.QuantConfig.
    # Kept out of ``overrides`` on purpose: aux keys don't parse as
    # GemmSites, and GemmConfig consumers must never see them.
    aux: tuple = ()                            # tuple[(site_key, QuantConfig)]

    def lookup(self, site: Union[str, GemmSite]) -> GemmConfig:
        s = GemmSite.parse(site)
        best, best_score = None, -1
        for pat, cfg in self.overrides:
            sc = _match_score(pat, s)
            if sc is not None and sc > best_score:
                best, best_score = cfg, sc
        return best if best is not None else self.default

    def aux_lookup(self, site_key: str):
        """QuantConfig for an aux (state/collective) site key, or None when
        the policy leaves that site at its fp32 default."""
        for key, cfg in self.aux:
            if key == site_key:
                return cfg
        return None

    def with_override(self, pattern: str, cfg: GemmConfig) -> "NumericsPolicy":
        return dataclasses.replace(
            self, overrides=((pattern, cfg),) + tuple(self.overrides))

    def with_aux(self, site_key: str, cfg) -> "NumericsPolicy":
        kept = tuple((k, c) for k, c in self.aux if k != site_key)
        return dataclasses.replace(self, aux=((site_key, cfg),) + kept)


MXU_BF16 = NumericsPolicy(GemmConfig(BF16, None, "native"), name="mxu_bf16")
MXU_FP32 = NumericsPolicy(GemmConfig(FP32, None, "native"), name="mxu_fp32")
# The paper's flagship uniform numerics: every site through the bit-exact
# ⟨30,30,-30⟩ FDP. This is the accuracy oracle the tailoring search in
# ``repro.numerics`` compares candidate plans against.
FDP91 = NumericsPolicy(
    GemmConfig(FP32, AccumulatorSpec(ovf=30, msb=30, lsb=-30), "simulate"),
    name="fdp91_uniform")

_state = threading.local()
_UNSET = object()


def current_policy() -> NumericsPolicy:
    return getattr(_state, "policy", MXU_BF16)


@contextlib.contextmanager
def use_policy(policy: NumericsPolicy):
    """Swap the *per-thread* numerics (the LD_PRELOAD moment).

    Exception-safe and re-entrant: the previous state is restored even when
    the body raises, and a thread that never entered a policy context goes
    back to the process default (rather than having the default pinned onto
    it). The underlying state is ``threading.local`` so a policy installed
    in one thread never leaks into another.
    """
    if not isinstance(policy, NumericsPolicy):
        raise TypeError(f"use_policy expects a NumericsPolicy, got {policy!r}")
    prev = getattr(_state, "policy", _UNSET)
    _state.policy = policy
    try:
        yield policy
    finally:
        if prev is _UNSET:
            del _state.policy
        else:
            _state.policy = prev


# ---------------------------------------------------------------------------
# Site registry (introspection/report)
# ---------------------------------------------------------------------------
# Guarded by its own lock: sites are recorded at trace time from whatever
# thread is staging the computation (the thread-pool serving tests trace
# concurrently), and test fixtures reset it between cases so assertions
# never depend on which test dispatched first.
_SITES_SEEN: set = set()
_SITES_LOCK = threading.Lock()


def sites_seen() -> frozenset:
    """All GEMM call-site keys dispatched so far (canonical strings;
    backward sites appear as ``name@bwd.dA`` / ``name@bwd.dB``)."""
    with _SITES_LOCK:
        return frozenset(_SITES_SEEN)


def reset_sites_seen() -> None:
    """Clear the process-global site registry (test isolation)."""
    with _SITES_LOCK:
        _SITES_SEEN.clear()


def _note_site(key: str) -> None:
    with _SITES_LOCK:
        _SITES_SEEN.add(key)


# ---------------------------------------------------------------------------
# Calibration tracing hook (repro.numerics)
# ---------------------------------------------------------------------------
# When a hook is installed (see repro.numerics.trace.calibrate), every
# dispatched GEMM reports (site_key, cfg, a, b, out) so the tailoring
# subsystem can record per-site operand statistics. Backward GEMMs report
# under their own phase-qualified keys, so a calibration run that includes a
# ``value_and_grad`` step profiles gradient exponent ranges and cancellation
# separately from the forward pass. The hook runs at *trace* time, so it may
# stage jnp ops / jax.debug.callback into the computation; it must be
# None-checked here to keep the production path zero-cost.
_TRACE_HOOK = None          # composed view over the slots below; None-checked
_PRIMARY_HOOK = None        # the calibration slot (set_trace_hook)
_EXTRA_HOOKS: list = []     # additive observers (repro.obs monitors)


def _recompose_hooks() -> None:
    global _TRACE_HOOK
    hooks = ([_PRIMARY_HOOK] if _PRIMARY_HOOK is not None else []) \
        + list(_EXTRA_HOOKS)
    if not hooks:
        _TRACE_HOOK = None
    elif len(hooks) == 1:
        _TRACE_HOOK = hooks[0]
    else:
        def _fanout(site_key, cfg, a, b, out, _hooks=tuple(hooks)):
            for h in _hooks:
                h(site_key, cfg, a, b, out)
        _TRACE_HOOK = _fanout


def set_trace_hook(hook):
    """Install (or clear, with None) the *primary* calibration hook. Returns
    the previously installed primary hook so callers can restore it. Extra
    hooks installed via ``add_trace_hook`` (live monitors) are a separate
    channel and keep firing across set/restore pairs."""
    global _PRIMARY_HOOK
    prev = _PRIMARY_HOOK
    _PRIMARY_HOOK = hook
    _recompose_hooks()
    return prev


def add_trace_hook(hook):
    """Install an *additional* trace hook alongside the calibration slot —
    the seam ``repro.obs.monitor`` uses, so production monitoring and a
    concurrent ``calibrate()`` co-exist. Returns a zero-arg remover."""
    _EXTRA_HOOKS.append(hook)
    _recompose_hooks()

    def _remove():
        try:
            _EXTRA_HOOKS.remove(hook)
        except ValueError:
            pass
        _recompose_hooks()
    return _remove


def _maybe_trace(site_key, cfg, a, b, out):
    if _TRACE_HOOK is not None:
        _TRACE_HOOK(site_key, cfg, a, b, out)
    return out


# ---------------------------------------------------------------------------
# GemmPlan: cached block-size plans for the Pallas execution engine
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class GemmPlan:
    """Block sizes for one (shape, fmt, spec, backend) problem instance.

    ``source`` records provenance: "heuristic" (shape-derived table),
    "measured" (autotuned on this host) or "override" (register_plan).
    """

    bm: int
    bn: int
    bk: int
    source: str = "heuristic"

    @property
    def tile(self) -> tuple:
        return (self.bm, self.bn, self.bk)

    def fit(self, m: int, n: int, k: int) -> "GemmPlan":
        """Clamp this plan to one problem instance: blocks stop at the
        (8-aligned) problem dims and bk at the SAFE_CHUNK carry-headroom
        bound. The ONE place a deployable schedule is constructed — the
        kernel wrappers, the autotuner, and the persisted zoo all fit
        through here, so half-legal schedules cannot exist."""
        bm = min(self.bm, _ceil8(m))
        bn = min(self.bn, _ceil8(n))
        bk = min(min(self.bk, SAFE_CHUNK), _ceil8(k))
        if (bm, bn, bk) == (self.bm, self.bn, self.bk):
            return self
        return dataclasses.replace(self, bm=bm, bn=bn, bk=bk)


@dataclasses.dataclass(frozen=True)
class PlanCacheStats:
    """Typed snapshot of the process-global GemmPlan cache counters.

    ``persisted_loads`` counts entries installed from a ScheduleZoo file —
    a warm process serving entirely out of a checked-in zoo shows
    ``misses == 0`` and ``persisted_loads > 0``.

    .. deprecated:: the counters now live in the unified obs registry
       (``repro_plan_cache_ops_total{op=...}`` / ``repro_plan_cache_size``);
       this class and :func:`plan_cache_stats` are thin views kept for one
       release — read ``repro.obs.default_registry().snapshot()`` instead.
    """

    size: int
    hits: int
    misses: int
    autotuned: int
    persisted_loads: int

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


_PLAN_CACHE: dict = {}
_PLAN_LOCK = threading.Lock()

# Plan-cache counters are registry-backed (repro.obs is stdlib-only at this
# layer): one source of truth for hits/misses/autotunes across the legacy
# stats() views and the Prometheus/JSON exposition.
_PLAN_OPS = _obs_registry().counter(
    "repro_plan_cache_ops_total",
    "GemmPlan cache operations (hit/miss/autotuned/persisted_load)", ("op",))
_PLAN_SIZE = _obs_registry().gauge(
    "repro_plan_cache_size", "resident GemmPlan cache entries")


def _plan_stats_inc(op: str, n: int = 1) -> None:
    _PLAN_OPS.inc(n, op=op)

# Candidate tiles for the measured path (clamped to the problem size).
AUTOTUNE_CANDIDATES = (
    (32, 32, 128), (32, 32, 512), (64, 64, 256), (64, 64, 512),
    (128, 128, 512), (128, 128, 1024), (8, 128, 512),
)


def _ceil8(x: int) -> int:
    return max(8, -(-x // 8) * 8)


def _heuristic_plan(batch: int, m: int, n: int, k: int) -> GemmPlan:
    """Shape-derived default tile (the measured tables on this container put
    the knee at 64..128 square output tiles with the deepest legal K block):
    large bk amortizes the once-per-block carry normalization, and the M/N
    blocks stop at the problem size so padding work stays bounded."""
    bm = min(128, _ceil8(m))
    bn = min(128, _ceil8(n))
    bk = min(1024, min(SAFE_CHUNK, _ceil8(k)))
    return GemmPlan(bm, bn, bk, source="heuristic")


def _plan_key(batch, m, n, k, fmt, spec, backend):
    return (batch, m, n, k, fmt.name, spec, backend)


def plan_gemm(m: int, n: int, k: int, *, fmt, spec: AccumulatorSpec,
              batch: int = 1, backend: Optional[str] = None,
              autotune: bool = False) -> GemmPlan:
    """Resolve (and cache) the block-size plan for one GEMM problem.

    The cache is keyed by (batch, M, N, K, fmt, spec, backend) so a compiled
    pallas_call is reused across calls with the same signature. ``autotune``
    measures AUTOTUNE_CANDIDATES on synthetic data and caches the winner —
    upgrading a previously cached *heuristic* entry in place (measured and
    override entries are never re-measured); the default is the heuristic
    table (no compilation at plan time).
    """
    backend = backend or jax.default_backend()
    key = _plan_key(batch, m, n, k, fmt, spec, backend)
    with _PLAN_LOCK:
        cached = _PLAN_CACHE.get(key)
    if cached is not None and (
            not autotune or cached.source in ("measured", "override")):
        _plan_stats_inc("hits")
        return cached
    if autotune:
        plan = _measure_plan(m, n, k, fmt=fmt, spec=spec)
        _plan_stats_inc("autotuned")
        _plan_stats_inc("misses")
        with _PLAN_LOCK:
            _PLAN_CACHE[key] = plan
            _PLAN_SIZE.set(len(_PLAN_CACHE))
        return plan
    plan = _heuristic_plan(batch, m, n, k)
    _plan_stats_inc("misses")
    with _PLAN_LOCK:
        plan = _PLAN_CACHE.setdefault(key, plan)
        _PLAN_SIZE.set(len(_PLAN_CACHE))
        return plan


def register_plan(m: int, n: int, k: int, plan: GemmPlan, *, fmt,
                  spec: AccumulatorSpec, batch: int = 1,
                  backend: Optional[str] = None) -> None:
    """Pin a plan (e.g. from an offline sweep) for a problem signature."""
    backend = backend or jax.default_backend()
    key = _plan_key(batch, m, n, k, fmt, spec, backend)
    with _PLAN_LOCK:
        _PLAN_CACHE[key] = dataclasses.replace(plan, source="override")
        _PLAN_SIZE.set(len(_PLAN_CACHE))


def plan_cache_stats() -> PlanCacheStats:
    """Deprecated thin view over the obs-registry plan-cache counters (see
    ``PlanCacheStats``); kept so existing callers/tests read unchanged."""
    with _PLAN_LOCK:
        size = len(_PLAN_CACHE)
    return PlanCacheStats(
        size=size,
        hits=int(_PLAN_OPS.value(op="hits")),
        misses=int(_PLAN_OPS.value(op="misses")),
        autotuned=int(_PLAN_OPS.value(op="autotuned")),
        persisted_loads=int(_PLAN_OPS.value(op="persisted_loads")))


def clear_plan_cache() -> None:
    with _PLAN_LOCK:
        _PLAN_CACHE.clear()
        _PLAN_SIZE.set(0)
    _PLAN_OPS.clear()


# Candidate timing discipline (shared with benchmarks/bench_gemm.py and the
# regression gate's --min-seconds floor): best of MEASURE_REPS samples, each
# amortized over enough calls to clear the sub-ms timer noise floor.
MEASURE_REPS = 3
MEASURE_MIN_SECONDS = 1e-3


def _time_candidate(fn, *, reps: int = MEASURE_REPS,
                    min_seconds: float = MEASURE_MIN_SECONDS) -> float:
    """Best-of-``reps`` seconds per call for ``fn`` (already compiled/warm).
    A single post-warmup sample is noise below ~1 ms on this timer, so each
    sample loops the call until it clears ``min_seconds`` of wall time."""
    import time

    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    dt = max(time.perf_counter() - t0, 1e-9)
    inner = max(1, math.ceil(min_seconds / dt))
    best = dt if inner == 1 else float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(inner):
            out = fn()
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / inner)
    return best


def _measure_plan(m: int, n: int, k: int, *, fmt,
                  spec: AccumulatorSpec) -> GemmPlan:
    """Time AUTOTUNE_CANDIDATES on random operands and return the winner."""
    from repro.kernels import ops as kops

    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    if isinstance(fmt, PositFormat):
        a, b = fmt.from_float(a), fmt.from_float(b)

    heur = _heuristic_plan(1, m, n, k)
    cands = {GemmPlan(*t).fit(m, n, k).tile
             for t in AUTOTUNE_CANDIDATES + (heur.tile,)}
    best, best_t = heur.tile, float("inf")
    for tile in sorted(cands):
        plan = GemmPlan(*tile)
        fn = lambda: kops.fdp_gemm(a, b, spec=spec, fmt=fmt, plan=plan)
        try:
            jax.block_until_ready(fn())          # compile + warm
        except Exception:
            continue
        dt = _time_candidate(fn)
        if dt < best_t:
            best, best_t = tile, dt
    return GemmPlan(*best, source="measured")


def _plan_for_operands(a: Array, b: Array, cfg: GemmConfig,
                       autotune: bool = False) -> GemmPlan:
    """Plan lookup from jnp.matmul-shaped operands (1-D promotion, broadcast
    batch dims). Safe under jit tracing: only static shapes are consulted, and
    autotune (which executes kernels) is disabled for tracers."""
    m = a.shape[-2] if a.ndim >= 2 else 1
    k = a.shape[-1]
    n = b.shape[-1] if b.ndim >= 2 else 1
    batch_dims = jnp.broadcast_shapes(
        a.shape[:-2] if a.ndim > 2 else (), b.shape[:-2] if b.ndim > 2 else ())
    batch = math.prod(batch_dims) if batch_dims else 1
    if isinstance(a, jax.core.Tracer) or isinstance(b, jax.core.Tracer):
        autotune = False
    return plan_gemm(m, n, k, fmt=cfg.fmt, spec=cfg.acc, batch=batch,
                     autotune=autotune)


# ---------------------------------------------------------------------------
# Dispatch core
# ---------------------------------------------------------------------------
def _dispatch(site: GemmSite, cfg: GemmConfig, a: Array, b: Array, *,
              plan: Optional[GemmPlan] = None) -> Array:
    """Run one matmul as one *site*: register the key, execute under the
    resolved config, report to the calibration hook. Every entry point —
    forward and backward — funnels through here so phase-qualified sites are
    first-class everywhere (``sites_seen``, traces, plans)."""
    _note_site(site.key)
    out = _execute(cfg, a, b, plan=plan)
    return _maybe_trace(site.key, cfg, a, b, out)


def _execute(cfg: GemmConfig, a: Array, b: Array, *,
             plan: Optional[GemmPlan] = None) -> Array:
    """Run one matmul under a resolved GemmConfig (the mode switch, without
    policy lookup or trace reporting — shared by gemm/ragged_gemm)."""
    if cfg.mode == "native":
        dt = cfg.fmt.jnp_dtype
        return jnp.matmul(a.astype(dt), b.astype(dt),
                          preferred_element_type=jnp.float32)

    # FDP modes: float inputs are rounded onto the format's grid first (the
    # paper's format front end — bf16 under a wide accumulator really sees
    # bf16 operands); posit carriers are already bit patterns.
    if isinstance(cfg.fmt, FloatFormat):
        a, b = cfg.fmt.quantize(a), cfg.fmt.quantize(b)

    if cfg.mode == "simulate":
        from . import fdp
        f = lambda x, y: fdp.fdp_gemm(x, y, cfg.acc, cfg.fmt)
        return _batched_apply(f, a, b)

    # pallas: plan-cached block sizes, native batched grid for N-D inputs
    from repro.kernels import ops as kops
    plan = plan or _plan_for_operands(a, b, cfg)
    return kops.fdp_gemm_nd(a, b, spec=cfg.acc, fmt=cfg.fmt, plan=plan)


def _unbroadcast(x: Array, shape: tuple) -> Array:
    """Sum a cotangent down to a (numpy-broadcast) primal operand shape."""
    shape = tuple(shape)
    if x.shape == shape:
        return x
    extra = x.ndim - len(shape)
    if extra:
        x = jnp.sum(x, axis=tuple(range(extra)))
    axes = tuple(i for i, (xs, ps) in enumerate(zip(x.shape, shape))
                 if ps == 1 and xs != 1)
    if axes:
        x = jnp.sum(x, axis=axes, keepdims=True)
    return x


# -- sharded contraction: cross-device reduction under the site's spec ------
def _execute_reduce(cfg: GemmConfig, a: Array, b: Array, axis_name) -> Array:
    """One K-sharded matmul: local partial contraction + cross-device
    reduction over ``axis_name``, under a resolved GemmConfig.

    native mode reduces the local f32 partials with a float psum (order-
    dependent, like any stock all-reduce). FDP modes reduce the accumulator
    *register*: local limbs from ``fdp.fdp_gemm_limbs``, an exact integer
    ``fdp_psum`` across devices, then the single read-out rounding — so the
    sharded result is bit-identical to the unsharded ``fdp_gemm``, for any
    mesh shape or reduction order (the paper's property lifted to the
    collective layer). pallas mode routes its cross-device reduction through
    the same simulate limb path: the Pallas kernel computes final floats, not
    registers, and the two are validated bit-identical — the limb psum is the
    semantics both implement.
    """
    if cfg.mode == "native":
        return jax.lax.psum(_execute(cfg, a, b), axis_name)

    if a.ndim != 2 or b.ndim != 2:
        raise NotImplementedError(
            "sharded FDP contraction (reduce_axis=...) supports 2-D operands")
    if isinstance(cfg.fmt, FloatFormat):
        a, b = cfg.fmt.quantize(a), cfg.fmt.quantize(b)
    from . import fdp
    from repro.parallel.collectives import fdp_psum  # deferred: imports us
    limbs = fdp.fdp_gemm_limbs(a, b, cfg.acc, cfg.fmt)
    return _acc_to_float(cfg.acc, fdp_psum(limbs, axis_name, cfg.acc))


def _acc_to_float(spec: AccumulatorSpec, limbs: Array) -> Array:
    from . import accumulator as acc_mod
    return acc_mod.to_float(spec, limbs)


def _dispatch_reduce(site: GemmSite, cfg: GemmConfig, a: Array, b: Array,
                     axis_name) -> Array:
    _note_site(site.key)
    out = _execute_reduce(cfg, a, b, axis_name)
    return _maybe_trace(site.key, cfg, a, b, out)


# -- gemm: policy-dispatched matmul with phase-aware gradient dispatch ------
@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _gemm_vjp(ctx, a, b):
    site, pol, plan, reduce_axis = ctx
    if reduce_axis is not None:
        return _dispatch_reduce(site, pol.lookup(site), a, b, reduce_axis)
    return _dispatch(site, pol.lookup(site), a, b, plan=plan)


def _gemm_vjp_fwd(ctx, a, b):
    return _gemm_vjp(ctx, a, b), (a, b)


def _gemm_vjp_bwd(ctx, res, g):
    """The two backward GEMMs of a site, dispatched as sites of their own:
    dL/dA = G·Bᵀ under ``<site>@bwd.dA`` and dL/dB = Aᵀ·G under
    ``<site>@bwd.dB``. The policy captured at the forward call resolves both
    (deterministic: fwd and bwd of one computation always agree on the
    policy, even if the ambient context changed between them).

    A K-sharded forward (``reduce_axis`` set) needs NO backward collectives:
    with the cotangent g replicated (the psum output is), dA_loc = G·B_locᵀ
    and dB_loc = A_locᵀ·G are already exactly the local shards of the full
    gradients — so both backward GEMMs dispatch as ordinary local sites."""
    site, pol, _plan, _reduce_axis = ctx
    a, b = res
    # jnp.matmul 1-D promotion: lift to 2-D, compute, drop the unit dims.
    # Insert the N axis before the M axis so the 1-D x 1-D (vector dot)
    # case — where g is 0-d — lifts cleanly to (1, 1).
    a2 = a[None, :] if a.ndim == 1 else a
    b2 = b[:, None] if b.ndim == 1 else b
    g2 = g
    if b.ndim == 1:
        g2 = g2[..., None]
    if a.ndim == 1:
        g2 = g2[..., None, :]

    da_site, db_site = site.bwd("dA"), site.bwd("dB")
    da_cfg, db_cfg = pol.lookup(da_site), pol.lookup(db_site)

    da = _dispatch(da_site, da_cfg, g2, jnp.swapaxes(b2, -1, -2))
    da = _unbroadcast(da, a2.shape).reshape(a.shape).astype(a.dtype)

    if b2.ndim == 2:
        # weight gradient: one flattened Aᵀ·G GEMM over all leading dims
        # (bit-matches the autodiff contraction order: row-major = batch-major)
        af = a2.reshape(-1, a2.shape[-1])
        gf = g2.reshape(-1, g2.shape[-1])
        db = _dispatch(db_site, db_cfg, jnp.swapaxes(af, -1, -2), gf)
    else:
        db = _dispatch(db_site, db_cfg, jnp.swapaxes(a2, -1, -2), g2)
        db = _unbroadcast(db, b2.shape)
    db = db.reshape(b.shape).astype(b.dtype)
    return da, db


_gemm_vjp.defvjp(_gemm_vjp_fwd, _gemm_vjp_bwd)


def gemm(a: Array, b: Array, *, site: Union[str, GemmSite] = "generic",
         policy: Optional[NumericsPolicy] = None,
         plan: Optional[GemmPlan] = None,
         reduce_axis=None) -> Array:
    """Policy-dispatched matmul. Contracts a's last dim with b's second-to-last
    (jnp.matmul semantics). Output f32 (simulate/pallas) or f32/bf16 (native,
    preferred_element_type=f32 then cast by caller if desired).

    Differentiating through this call dispatches the two backward GEMMs as
    ``<site>@bwd.dA`` / ``<site>@bwd.dB`` under the same policy (see
    ``_gemm_vjp_bwd``). ``plan`` overrides the cached/heuristic block sizes
    for the forward call (pallas mode only; backward calls resolve their own).

    ``reduce_axis`` makes the contraction *sharding-aware*: inside
    shard_map/pmap with the K dim sharded over that mesh axis, each device
    contracts its local K-shard and the cross-device reduction runs under the
    site's resolved config — FDP sites through the exact limb-summed
    ``fdp_psum`` (bit-identical to single-device), native sites through a
    plain float psum. The output is replicated over ``reduce_axis``.
    """
    pol = policy or current_policy()
    return _gemm_vjp((GemmSite.parse(site), pol, plan, reduce_axis), a, b)


# -- grouped attention einsums ----------------------------------------------
def _grouped_qk_execute(site: GemmSite, cfg: GemmConfig,
                        q: Array, k: Array) -> Array:
    """q (B,Kh,G,Sq,hd) x k (B,Kh,Sk,hd) -> (B,Kh,G,Sq,Sk).

    Native mode uses a real einsum so sequence-parallel sharding on Sq
    survives (a reshape that merges (G, Sq) would force XLA to replicate the
    sequence dim). Simulate/pallas modes run the flattened 2D dispatch."""
    _note_site(site.key)
    if cfg.mode == "native":
        dt = cfg.fmt.jnp_dtype
        out = jnp.einsum("bkgqd,bksd->bkgqs", q.astype(dt), k.astype(dt),
                         preferred_element_type=jnp.float32)
        if _TRACE_HOOK is not None:
            # report in jnp.matmul shape so the profiler sees the real
            # contraction: (B,Kh,G*Sq,hd) x (B,Kh,hd,Sk)
            B_, Kh_, G_, Sq_, hd_ = q.shape
            _maybe_trace(site.key, cfg, q.reshape(B_, Kh_, G_ * Sq_, hd_),
                         jnp.swapaxes(k, -1, -2),
                         out.reshape(B_, Kh_, G_ * Sq_, -1))
        return out
    B, Kh, G, Sq, hd = q.shape
    qf = q.reshape(B, Kh, G * Sq, hd)
    out = _dispatch(site, cfg, qf, jnp.swapaxes(k, -1, -2))
    return out.reshape(B, Kh, G, Sq, k.shape[2])


def _grouped_av_execute(site: GemmSite, cfg: GemmConfig,
                        p: Array, v: Array) -> Array:
    """p (B,Kh,G,Sq,Sk) x v (B,Kh,Sk,hd) -> (B,Kh,G,Sq,hd)."""
    _note_site(site.key)
    if cfg.mode == "native":
        dt = cfg.fmt.jnp_dtype
        out = jnp.einsum("bkgqs,bksd->bkgqd", p.astype(dt), v.astype(dt),
                         preferred_element_type=jnp.float32)
        if _TRACE_HOOK is not None:
            B_, Kh_, G_, Sq_, Sk_ = p.shape
            _maybe_trace(site.key, cfg, p.reshape(B_, Kh_, G_ * Sq_, Sk_), v,
                         out.reshape(B_, Kh_, G_ * Sq_, -1))
        return out
    B, Kh, G, Sq, Sk = p.shape
    pf = p.reshape(B, Kh, G * Sq, Sk)
    out = _dispatch(site, cfg, pf, v)
    return out.reshape(B, Kh, G, Sq, v.shape[-1])


def _grouped_dright(site: GemmSite, cfg: GemmConfig,
                    lhs: Array, rhs: Array) -> Array:
    """The shared dK/dV backward contraction
    ``bkgqx,bkgqy->bkxy`` (sum over heads-in-group and query positions) —
    dK = dright(g, q), dV = dright(p, g)."""
    _note_site(site.key)
    if cfg.mode == "native":
        dt = cfg.fmt.jnp_dtype
        out = jnp.einsum("bkgqx,bkgqy->bkxy", lhs.astype(dt), rhs.astype(dt),
                         preferred_element_type=jnp.float32)
        if _TRACE_HOOK is not None:
            B_, Kh_, G_, Sq_, X_ = lhs.shape
            _maybe_trace(site.key, cfg,
                         jnp.swapaxes(lhs.reshape(B_, Kh_, G_ * Sq_, X_),
                                      -1, -2),
                         rhs.reshape(B_, Kh_, G_ * Sq_, -1), out)
        return out
    B, Kh, G, Sq, X = lhs.shape
    lf = jnp.swapaxes(lhs.reshape(B, Kh, G * Sq, X), -1, -2)
    rf = rhs.reshape(B, Kh, G * Sq, -1)
    return _dispatch(site, cfg, lf, rf)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _grouped_qk_vjp(ctx, q, k):
    site, pol = ctx
    return _grouped_qk_execute(site, pol.lookup(site), q, k)


def _grouped_qk_vjp_fwd(ctx, q, k):
    return _grouped_qk_vjp(ctx, q, k), (q, k)


def _grouped_qk_vjp_bwd(ctx, res, g):
    site, pol = ctx
    q, k = res
    dq_site, dk_site = site.bwd("dA"), site.bwd("dB")
    # dQ = einsum("bkgqs,bksd->bkgqd", g, k) — the grouped_av contraction
    dq = _grouped_av_execute(dq_site, pol.lookup(dq_site), g, k)
    # dK = einsum("bkgqs,bkgqd->bksd", g, q)
    dk = _grouped_dright(dk_site, pol.lookup(dk_site), g, q)
    return dq.astype(q.dtype), dk.astype(k.dtype)


_grouped_qk_vjp.defvjp(_grouped_qk_vjp_fwd, _grouped_qk_vjp_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _grouped_av_vjp(ctx, p, v):
    site, pol = ctx
    return _grouped_av_execute(site, pol.lookup(site), p, v)


def _grouped_av_vjp_fwd(ctx, p, v):
    return _grouped_av_vjp(ctx, p, v), (p, v)


def _grouped_av_vjp_bwd(ctx, res, g):
    site, pol = ctx
    p, v = res
    dp_site, dv_site = site.bwd("dA"), site.bwd("dB")
    # dP = einsum("bkgqd,bksd->bkgqs", g, v) — the grouped_qk contraction
    dp = _grouped_qk_execute(dp_site, pol.lookup(dp_site), g, v)
    # dV = einsum("bkgqs,bkgqd->bksd", p, g)
    dv = _grouped_dright(dv_site, pol.lookup(dv_site), p, g)
    return dp.astype(p.dtype), dv.astype(v.dtype)


_grouped_av_vjp.defvjp(_grouped_av_vjp_fwd, _grouped_av_vjp_bwd)


def grouped_qk(q: Array, k: Array, *, site: Union[str, GemmSite] = "attn_qk",
               policy: Optional[NumericsPolicy] = None) -> Array:
    """GQA score einsum  q (B,Kh,G,Sq,hd) x k (B,Kh,Sk,hd) -> (B,Kh,G,Sq,Sk).
    Backward dispatches ``<site>@bwd.dA`` (dQ) / ``<site>@bwd.dB`` (dK)."""
    pol = policy or current_policy()
    return _grouped_qk_vjp((GemmSite.parse(site), pol), q, k)


def grouped_av(p: Array, v: Array, *, site: Union[str, GemmSite] = "attn_av",
               policy: Optional[NumericsPolicy] = None) -> Array:
    """GQA value einsum  p (B,Kh,G,Sq,Sk) x v (B,Kh,Sk,hd) -> (B,Kh,G,Sq,hd).
    Backward dispatches ``<site>@bwd.dA`` (dP) / ``<site>@bwd.dB`` (dV)."""
    pol = policy or current_policy()
    return _grouped_av_vjp((GemmSite.parse(site), pol), p, v)


# -- grouped (expert) GEMM --------------------------------------------------
def _segment_ids(group_sizes: Array, n_rows: int) -> Array:
    """Segment id per sorted row from the group-size prefix sums; rows beyond
    sum(group_sizes) get id E (no group)."""
    bounds = jnp.cumsum(group_sizes)
    return jnp.sum(jnp.arange(n_rows)[:, None] >= bounds[None, :], axis=1)


def _fit_ragged(plan: GemmPlan, axis: str, n_rows: int, n_groups: int
                ) -> GemmPlan:
    """Clamp the plan's token-axis block to the mean segment size (8-aligned).

    The sorted-segment walk revisits one boundary tile per group, so its MAC
    count is ~(T + (E-1)·block)·d·f: a block sized for a dense GEMM (128)
    with many experts burns the entire O(T) advantage on boundary tiles.
    Blocking only changes the summation grouping — exact limb accumulation
    keeps the result bit-identical for any clamp."""
    block = min(getattr(plan, axis),
                _ceil8(max(1, n_rows // max(1, n_groups))))
    if block == getattr(plan, axis):
        return plan
    return dataclasses.replace(plan, **{axis: block})


def _ragged_execute(site: GemmSite, cfg: GemmConfig, x: Array, w: Array,
                    group_sizes: Array) -> Array:
    """The mode switch of ``ragged_gemm`` (shared by fwd and the dx backward,
    which is the same ragged contraction against transposed weights)."""
    _note_site(site.key)
    E, d, f = w.shape
    if cfg.mode == "native":
        dt = cfg.fmt.jnp_dtype
        out = jax.lax.ragged_dot(x.astype(dt), w.astype(dt), group_sizes,
                                 preferred_element_type=jnp.float32)
    elif cfg.mode == "pallas":
        # Sorted-segment kernel: rows are already sorted by group, so the
        # Pallas grid walks contiguous segments with a per-tile expert-weight
        # index map — O(T·d·f) MACs instead of the reference path's T×E.
        # Exact integer limb accumulation is order-invariant, so the result
        # is bit-identical to the reference grouped path below.
        from repro.kernels import ops as kops
        if isinstance(cfg.fmt, FloatFormat):
            x, w = cfg.fmt.quantize(x), cfg.fmt.quantize(w)
        plan = plan_gemm(x.shape[0], f, d, fmt=cfg.fmt, spec=cfg.acc)
        plan = _fit_ragged(plan, "bm", x.shape[0], E)
        out = kops.fdp_ragged_gemm(x, w, group_sizes, spec=cfg.acc,
                                   fmt=cfg.fmt, plan=plan)
    else:
        seg = _segment_ids(group_sizes, x.shape[0])              # (T,)
        per_expert = jax.vmap(lambda we: _execute(cfg, x, we))(w)  # (E,T,f)
        out = jnp.take_along_axis(
            per_expert, jnp.minimum(seg, E - 1)[None, :, None], axis=0)[0]
        # rows beyond sum(group_sizes) (padding) belong to no group: zero
        # them like the native ragged_dot path, so flipping a site between
        # native and FDP candidates never changes padded-row outputs
        out = jnp.where((seg < E)[:, None], out, 0.0)
    # report as one (T, d) x (d, f) call: k/m from x, n and weight stats from
    # the flattened expert stack (the sample decoder reshapes (-1, d, f) and
    # keeps group 0's block)
    return _maybe_trace(site.key, cfg, x, w.reshape(E * d, f), out)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _ragged_vjp(ctx, x, w, group_sizes):
    site, pol = ctx
    return _ragged_execute(site, pol.lookup(site), x, w, group_sizes)


def _ragged_vjp_fwd(ctx, x, w, group_sizes):
    return _ragged_vjp(ctx, x, w, group_sizes), (x, w, group_sizes)


def _ragged_vjp_bwd(ctx, res, g):
    site, pol = ctx
    x, w, group_sizes = res
    E, d, f = w.shape
    dx_site, dw_site = site.bwd("dA"), site.bwd("dB")
    # dX: the same ragged contraction against transposed per-expert weights
    # (row t of g against w[seg(t)]ᵀ) — a first-class ragged site.
    dx = _ragged_execute(dx_site, pol.lookup(dx_site), g,
                         jnp.swapaxes(w, -1, -2), group_sizes)
    # dW[e] = X_eᵀ · G_e. pallas mode runs the sorted-segment wgrad kernel
    # (token-block tiles routed to their expert's output block — O(T·d·f)
    # MACs, bit-identical to the masked reference by exact order-invariant
    # limb accumulation). simulate/native keep the per-expert masked Aᵀ·G
    # reference (T×E work): JAX's own ragged_dot transpose lowers to an
    # E-batched dot_general contracting the full token dim anyway, so even
    # native configs are not asymptotically worse than autodiff here.
    dw_cfg = pol.lookup(dw_site)
    _note_site(dw_site.key)
    if dw_cfg.mode == "pallas":
        from repro.kernels import ops as kops
        xq, gq = x, g
        if isinstance(dw_cfg.fmt, FloatFormat):
            xq, gq = dw_cfg.fmt.quantize(x), dw_cfg.fmt.quantize(g)
        plan = plan_gemm(d, f, x.shape[0], fmt=dw_cfg.fmt, spec=dw_cfg.acc)
        plan = _fit_ragged(plan, "bk", x.shape[0], E)
        dw = kops.fdp_ragged_dw(xq, gq, group_sizes, num_groups=E,
                                spec=dw_cfg.acc, fmt=dw_cfg.fmt, plan=plan)
    else:
        seg = _segment_ids(group_sizes, x.shape[0])
        masks = seg[None, :] == jnp.arange(E)[:, None]           # (E, T)

        def per_expert(m):
            xm = jnp.where(m[:, None], x, jnp.zeros((), x.dtype))
            return _execute(dw_cfg, jnp.swapaxes(xm, -1, -2), g)   # (d, f)

        dw = jax.vmap(per_expert)(masks)                         # (E, d, f)
    _maybe_trace(dw_site.key, dw_cfg, jnp.swapaxes(x, -1, -2), g,
                 dw.reshape(E * d, f))
    zeros_gs = np.zeros(group_sizes.shape, dtype=jax.dtypes.float0)
    return dx.astype(x.dtype), dw.astype(w.dtype), zeros_gs


_ragged_vjp.defvjp(_ragged_vjp_fwd, _ragged_vjp_bwd)


def ragged_gemm(x: Array, w: Array, group_sizes: Array, *,
                site: Union[str, GemmSite] = "moe_expert",
                policy: Optional[NumericsPolicy] = None) -> Array:
    """Grouped (expert) GEMM: ``x (T, d)`` rows sorted by group, ``w (E, d, f)``
    per-group weights, ``group_sizes (E,)`` rows per group. Output ``(T, f)``
    f32 — row t contracts against its group's weight matrix.

    Native mode stays on the fused ``jax.lax.ragged_dot`` fast path (operands
    cast onto the policy format's grid, f32 accumulate — same front end as
    ``gemm``). pallas mode runs the sorted-segment Pallas kernel: the grid
    walks contiguous per-group segments with a scalar-prefetched expert index
    map, so the exact ⟨ovf,msb,lsb⟩ datapath does O(T·d·f) MACs like the
    native path (bit-identical to the reference below — exact limb
    accumulation is order-invariant). simulate mode keeps the reference
    grouped path as the oracle: one dispatched GEMM per group over the full
    token block, rows selected by segment id — T×E work, every expert MAC
    through the site's exact datapath.

    Tracing reports one aggregate call: operand stats over all tokens and all
    group weights, MACs = T·d·f (each sorted row hits exactly one expert).
    Backward dispatches ``<site>@bwd.dA`` (token grads, a ragged contraction
    against transposed weights) and ``<site>@bwd.dB`` (per-expert weight
    grads) as their own sites.
    """
    pol = policy or current_policy()
    return _ragged_vjp((GemmSite.parse(site), pol), x, w, group_sizes)


def _batched_apply(f, a: Array, b: Array) -> Array:
    """Apply a 2D (M,K)x(K,N) kernel over arbitrary leading batch dims with
    numpy broadcasting between a and b batch dims (vmap for the batched
    leaf; the Pallas path has its own native batched grid in kernels.ops)."""
    from repro.kernels.ops import matmul_batching
    return matmul_batching(f, jax.vmap(f))(a, b)


def policy_from_plan(path) -> NumericsPolicy:
    """Load a serialized ``repro.numerics`` PrecisionPlan and return the
    NumericsPolicy it deploys (the ``--precision-plan`` entry point)."""
    from repro.numerics import load_plan       # deferred: numerics imports us
    return load_plan(path).to_policy()


def quantize_inputs(x: Array, site: Union[str, GemmSite] = "generic",
                    policy: Optional[NumericsPolicy] = None) -> Array:
    """Round an activation/weight onto the policy format's grid (keeps f32
    carrier for posit formats)."""
    pol = policy or current_policy()
    cfg = pol.lookup(site)
    fmt = cfg.fmt
    if isinstance(fmt, PositFormat):
        return fmt.to_float(fmt.from_float(x))
    return x.astype(fmt.jnp_dtype).astype(x.dtype)
