"""BLAS-style transparent dispatch — the OpenBLAS-swap analogue.

High-level model code never calls ``jnp.dot`` directly; it calls
``repro.core.dispatch.gemm(a, b, site="attn_qk")``.  A ``NumericsPolicy``
(installed via context manager, like re-linking OpenBLAS at runtime) maps each
*call-site* to a ``GemmConfig`` ⟨format, accumulator, execution target⟩, so an
unmodified model can be re-run under any numerics without touching its code —
the paper's "runtime execution flow".

Modes:
    native   - MXU fast path: inputs cast to the format's dtype,
               jnp.dot(..., preferred_element_type=f32). Default everywhere;
               this is what the multi-pod dry-run lowers.
    simulate - bit-exact ⟨ovf,msb,lsb⟩ FDP (repro.core.fdp).
    pallas   - the Pallas TPU kernel (interpret on CPU).

Batched inputs (ndim > 2) are supported in all modes (simulate/pallas vmap
over leading dims; native uses dot_general via jnp.matmul semantics).
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import threading
from typing import Optional

import jax
import jax.numpy as jnp

from .accumulator import SAFE_CHUNK, AccumulatorSpec
from .formats import BF16, FP32, FloatFormat, PositFormat, get_format

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class GemmConfig:
    fmt: FloatFormat | PositFormat = BF16
    acc: Optional[AccumulatorSpec] = None      # None => native fp32 accumulate
    mode: str = "native"                       # native | simulate | pallas

    def __post_init__(self):
        if self.mode not in ("native", "simulate", "pallas"):
            raise ValueError(self.mode)
        if self.mode != "native" and self.acc is None:
            raise ValueError(f"mode={self.mode} requires an AccumulatorSpec")

    def tag(self) -> str:
        acc = (f"<{self.acc.ovf},{self.acc.msb},{self.acc.lsb}>"
               if self.acc else "fp32acc")
        return f"{self.fmt.name}/{acc}/{self.mode}"


@dataclasses.dataclass(frozen=True)
class NumericsPolicy:
    """Call-site -> GemmConfig mapping. ``default`` covers unlisted sites.
    Site keys support trailing-* prefix matching ("attn_*")."""

    default: GemmConfig = GemmConfig()
    overrides: tuple = ()                      # tuple[(pattern, GemmConfig)]
    name: str = "default"

    def lookup(self, site: str) -> GemmConfig:
        for pat, cfg in self.overrides:
            if pat == site:
                return cfg
        for pat, cfg in self.overrides:
            if pat.endswith("*") and site.startswith(pat[:-1]):
                return cfg
        return self.default

    def with_override(self, pattern: str, cfg: GemmConfig) -> "NumericsPolicy":
        return dataclasses.replace(
            self, overrides=((pattern, cfg),) + tuple(self.overrides))


MXU_BF16 = NumericsPolicy(GemmConfig(BF16, None, "native"), name="mxu_bf16")
MXU_FP32 = NumericsPolicy(GemmConfig(FP32, None, "native"), name="mxu_fp32")
# The paper's flagship uniform numerics: every site through the bit-exact
# ⟨30,30,-30⟩ FDP. This is the accuracy oracle the tailoring search in
# ``repro.numerics`` compares candidate plans against.
FDP91 = NumericsPolicy(
    GemmConfig(FP32, AccumulatorSpec(ovf=30, msb=30, lsb=-30), "simulate"),
    name="fdp91_uniform")

_state = threading.local()
_UNSET = object()


def current_policy() -> NumericsPolicy:
    return getattr(_state, "policy", MXU_BF16)


@contextlib.contextmanager
def use_policy(policy: NumericsPolicy):
    """Swap the *per-thread* numerics (the LD_PRELOAD moment).

    Exception-safe and re-entrant: the previous state is restored even when
    the body raises, and a thread that never entered a policy context goes
    back to the process default (rather than having the default pinned onto
    it). The underlying state is ``threading.local`` so a policy installed
    in one thread never leaks into another.
    """
    if not isinstance(policy, NumericsPolicy):
        raise TypeError(f"use_policy expects a NumericsPolicy, got {policy!r}")
    prev = getattr(_state, "policy", _UNSET)
    _state.policy = policy
    try:
        yield policy
    finally:
        if prev is _UNSET:
            del _state.policy
        else:
            _state.policy = prev


_SITES_SEEN: set = set()


def sites_seen() -> frozenset:
    """All GEMM call-sites traced so far (introspection/report)."""
    return frozenset(_SITES_SEEN)


# ---------------------------------------------------------------------------
# Calibration tracing hook (repro.numerics)
# ---------------------------------------------------------------------------
# When a hook is installed (see repro.numerics.trace.calibrate), every
# dispatched GEMM reports (site, cfg, a, b, out) so the tailoring subsystem
# can record per-site operand statistics. The hook runs at *trace* time, so
# it may stage jnp ops / jax.debug.callback into the computation; it must be
# None-checked here to keep the production path zero-cost.
_TRACE_HOOK = None


def set_trace_hook(hook):
    """Install (or clear, with None) the calibration hook. Returns the
    previously installed hook so callers can restore it."""
    global _TRACE_HOOK
    prev = _TRACE_HOOK
    _TRACE_HOOK = hook
    return prev


def _maybe_trace(site, cfg, a, b, out):
    if _TRACE_HOOK is not None:
        _TRACE_HOOK(site, cfg, a, b, out)
    return out


# ---------------------------------------------------------------------------
# GemmPlan: cached block-size plans for the Pallas execution engine
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class GemmPlan:
    """Block sizes for one (shape, fmt, spec, backend) problem instance.

    ``source`` records provenance: "heuristic" (shape-derived table),
    "measured" (autotuned on this host) or "override" (register_plan).
    """

    bm: int
    bn: int
    bk: int
    source: str = "heuristic"

    @property
    def tile(self) -> tuple:
        return (self.bm, self.bn, self.bk)


_PLAN_CACHE: dict = {}
_PLAN_LOCK = threading.Lock()
_PLAN_STATS = {"hits": 0, "misses": 0, "autotuned": 0}

# Candidate tiles for the measured path (clamped to the problem size).
AUTOTUNE_CANDIDATES = (
    (32, 32, 128), (32, 32, 512), (64, 64, 256), (64, 64, 512),
    (128, 128, 512), (128, 128, 1024), (8, 128, 512),
)


def _ceil8(x: int) -> int:
    return max(8, -(-x // 8) * 8)


def _heuristic_plan(batch: int, m: int, n: int, k: int) -> GemmPlan:
    """Shape-derived default tile (the measured tables on this container put
    the knee at 64..128 square output tiles with the deepest legal K block):
    large bk amortizes the once-per-block carry normalization, and the M/N
    blocks stop at the problem size so padding work stays bounded."""
    bm = min(128, _ceil8(m))
    bn = min(128, _ceil8(n))
    bk = min(1024, min(SAFE_CHUNK, _ceil8(k)))
    return GemmPlan(bm, bn, bk, source="heuristic")


def _plan_key(batch, m, n, k, fmt, spec, backend):
    return (batch, m, n, k, fmt.name, spec, backend)


def plan_gemm(m: int, n: int, k: int, *, fmt, spec: AccumulatorSpec,
              batch: int = 1, backend: Optional[str] = None,
              autotune: bool = False) -> GemmPlan:
    """Resolve (and cache) the block-size plan for one GEMM problem.

    The cache is keyed by (batch, M, N, K, fmt, spec, backend) so a compiled
    pallas_call is reused across calls with the same signature. ``autotune``
    measures AUTOTUNE_CANDIDATES on synthetic data and caches the winner —
    upgrading a previously cached *heuristic* entry in place (measured and
    override entries are never re-measured); the default is the heuristic
    table (no compilation at plan time).
    """
    backend = backend or jax.default_backend()
    key = _plan_key(batch, m, n, k, fmt, spec, backend)
    with _PLAN_LOCK:
        cached = _PLAN_CACHE.get(key)
    if cached is not None and (
            not autotune or cached.source in ("measured", "override")):
        with _PLAN_LOCK:
            _PLAN_STATS["hits"] += 1
        return cached
    if autotune:
        plan = _measure_plan(m, n, k, fmt=fmt, spec=spec)
        with _PLAN_LOCK:
            _PLAN_STATS["autotuned"] += 1
            _PLAN_STATS["misses"] += 1
            _PLAN_CACHE[key] = plan
        return plan
    plan = _heuristic_plan(batch, m, n, k)
    with _PLAN_LOCK:
        _PLAN_STATS["misses"] += 1
        return _PLAN_CACHE.setdefault(key, plan)


def register_plan(m: int, n: int, k: int, plan: GemmPlan, *, fmt,
                  spec: AccumulatorSpec, batch: int = 1,
                  backend: Optional[str] = None) -> None:
    """Pin a plan (e.g. from an offline sweep) for a problem signature."""
    backend = backend or jax.default_backend()
    key = _plan_key(batch, m, n, k, fmt, spec, backend)
    with _PLAN_LOCK:
        _PLAN_CACHE[key] = dataclasses.replace(plan, source="override")


def plan_cache_info() -> dict:
    with _PLAN_LOCK:
        return {"size": len(_PLAN_CACHE), **_PLAN_STATS}


def clear_plan_cache() -> None:
    with _PLAN_LOCK:
        _PLAN_CACHE.clear()
        for k in _PLAN_STATS:
            _PLAN_STATS[k] = 0


def _measure_plan(m: int, n: int, k: int, *, fmt,
                  spec: AccumulatorSpec) -> GemmPlan:
    """Time AUTOTUNE_CANDIDATES on random operands and return the winner."""
    import time

    import numpy as np

    from repro.kernels import ops as kops

    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    if isinstance(fmt, PositFormat):
        a, b = fmt.from_float(a), fmt.from_float(b)

    heur = _heuristic_plan(1, m, n, k)
    cands = {kops._fit_blocks(m, n, k, *t)
             for t in AUTOTUNE_CANDIDATES + (heur.tile,)}
    best, best_t = heur.tile, float("inf")
    for bm, bn, bk in sorted(cands):
        fn = lambda: kops.fdp_gemm(a, b, spec=spec, fmt=fmt,
                                   bm=bm, bn=bn, bk=bk)
        try:
            jax.block_until_ready(fn())          # compile + warm
        except Exception:
            continue
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        dt = time.perf_counter() - t0
        if dt < best_t:
            best, best_t = (bm, bn, bk), dt
    return GemmPlan(*best, source="measured")


def _plan_for_operands(a: Array, b: Array, cfg: GemmConfig,
                       autotune: bool = False) -> GemmPlan:
    """Plan lookup from jnp.matmul-shaped operands (1-D promotion, broadcast
    batch dims). Safe under jit tracing: only static shapes are consulted, and
    autotune (which executes kernels) is disabled for tracers."""
    m = a.shape[-2] if a.ndim >= 2 else 1
    k = a.shape[-1]
    n = b.shape[-1] if b.ndim >= 2 else 1
    batch_dims = jnp.broadcast_shapes(
        a.shape[:-2] if a.ndim > 2 else (), b.shape[:-2] if b.ndim > 2 else ())
    batch = math.prod(batch_dims) if batch_dims else 1
    if isinstance(a, jax.core.Tracer) or isinstance(b, jax.core.Tracer):
        autotune = False
    return plan_gemm(m, n, k, fmt=cfg.fmt, spec=cfg.acc, batch=batch,
                     autotune=autotune)


def gemm(a: Array, b: Array, *, site: str = "generic",
         policy: Optional[NumericsPolicy] = None,
         plan: Optional[GemmPlan] = None) -> Array:
    """Policy-dispatched matmul. Contracts a's last dim with b's second-to-last
    (jnp.matmul semantics). Output f32 (simulate/pallas) or f32/bf16 (native,
    preferred_element_type=f32 then cast by caller if desired).

    ``plan`` overrides the cached/heuristic block sizes (pallas mode only).
    """
    pol = policy or current_policy()
    cfg = pol.lookup(site)
    _SITES_SEEN.add(site)
    out = _execute(cfg, a, b, plan=plan)
    return _maybe_trace(site, cfg, a, b, out)


def _execute(cfg: GemmConfig, a: Array, b: Array, *,
             plan: Optional[GemmPlan] = None) -> Array:
    """Run one matmul under a resolved GemmConfig (the mode switch, without
    policy lookup or trace reporting — shared by gemm/ragged_gemm)."""
    if cfg.mode == "native":
        dt = cfg.fmt.jnp_dtype
        return jnp.matmul(a.astype(dt), b.astype(dt),
                          preferred_element_type=jnp.float32)

    # FDP modes: float inputs are rounded onto the format's grid first (the
    # paper's format front end — bf16 under a wide accumulator really sees
    # bf16 operands); posit carriers are already bit patterns.
    if isinstance(cfg.fmt, FloatFormat):
        a, b = cfg.fmt.quantize(a), cfg.fmt.quantize(b)

    if cfg.mode == "simulate":
        from . import fdp
        f = lambda x, y: fdp.fdp_gemm(x, y, cfg.acc, cfg.fmt)
        return _batched_apply(f, a, b)

    # pallas: plan-cached block sizes, native batched grid for N-D inputs
    from repro.kernels import ops as kops
    plan = plan or _plan_for_operands(a, b, cfg)
    return kops.fdp_gemm_nd(a, b, spec=cfg.acc, fmt=cfg.fmt,
                            bm=plan.bm, bn=plan.bn, bk=plan.bk)


def ragged_gemm(x: Array, w: Array, group_sizes: Array, *,
                site: str = "moe_expert",
                policy: Optional[NumericsPolicy] = None) -> Array:
    """Grouped (expert) GEMM: ``x (T, d)`` rows sorted by group, ``w (E, d, f)``
    per-group weights, ``group_sizes (E,)`` rows per group. Output ``(T, f)``
    f32 — row t contracts against its group's weight matrix.

    Native mode stays on the fused ``jax.lax.ragged_dot`` fast path (operands
    cast onto the policy format's grid, f32 accumulate — same front end as
    ``gemm``). FDP modes run the reference grouped path: one dispatched GEMM
    per group over the full token block, rows selected by segment id — T×E
    work instead of T, but every expert MAC goes through the site's exact
    ⟨ovf,msb,lsb⟩ datapath, which is what makes MoE *expert* sites (not just
    the router) tailorable and plan-servable.

    Tracing reports one aggregate call: operand stats over all tokens and all
    group weights, MACs = T·d·f (each sorted row hits exactly one expert).
    """
    pol = policy or current_policy()
    cfg = pol.lookup(site)
    _SITES_SEEN.add(site)
    E, d, f = w.shape
    if cfg.mode == "native":
        dt = cfg.fmt.jnp_dtype
        out = jax.lax.ragged_dot(x.astype(dt), w.astype(dt), group_sizes,
                                 preferred_element_type=jnp.float32)
    else:
        # segment id per sorted row from the group-size prefix sums
        bounds = jnp.cumsum(group_sizes)
        seg = jnp.sum(jnp.arange(x.shape[0])[:, None] >= bounds[None, :],
                      axis=1)                                       # (T,)
        per_expert = jax.vmap(lambda we: _execute(cfg, x, we))(w)   # (E,T,f)
        out = jnp.take_along_axis(
            per_expert, jnp.minimum(seg, E - 1)[None, :, None], axis=0)[0]
        # rows beyond sum(group_sizes) (padding) belong to no group: zero
        # them like the native ragged_dot path, so flipping a site between
        # native and FDP candidates never changes padded-row outputs
        out = jnp.where((seg < E)[:, None], out, 0.0)
    # report as one (T, d) x (d, f) call: k/m from x, n and weight stats from
    # the flattened expert stack (the sample decoder reshapes (-1, d, f) and
    # keeps group 0's block)
    return _maybe_trace(site, cfg, x, w.reshape(E * d, f), out)


def _batched_apply(f, a: Array, b: Array) -> Array:
    """Apply a 2D (M,K)x(K,N) kernel over arbitrary leading batch dims with
    numpy broadcasting between a and b batch dims (vmap for the batched
    leaf; the Pallas path has its own native batched grid in kernels.ops)."""
    from repro.kernels.ops import matmul_batching
    return matmul_batching(f, jax.vmap(f))(a, b)


def grouped_qk(q: Array, k: Array, *, site: str = "attn_qk",
               policy: Optional[NumericsPolicy] = None) -> Array:
    """GQA score einsum  q (B,Kh,G,Sq,hd) x k (B,Kh,Sk,hd) -> (B,Kh,G,Sq,Sk).

    Native mode uses a real einsum so sequence-parallel sharding on Sq
    survives (a reshape that merges (G, Sq) would force XLA to replicate the
    sequence dim). Simulate/pallas modes vmap the 2D FDP kernel."""
    pol = policy or current_policy()
    cfg = pol.lookup(site)
    _SITES_SEEN.add(site)
    if cfg.mode == "native":
        dt = cfg.fmt.jnp_dtype
        out = jnp.einsum("bkgqd,bksd->bkgqs", q.astype(dt), k.astype(dt),
                         preferred_element_type=jnp.float32)
        if _TRACE_HOOK is not None:
            # report in jnp.matmul shape so the profiler sees the real
            # contraction: (B,Kh,G*Sq,hd) x (B,Kh,hd,Sk)
            B_, Kh_, G_, Sq_, hd_ = q.shape
            _maybe_trace(site, cfg, q.reshape(B_, Kh_, G_ * Sq_, hd_),
                         jnp.swapaxes(k, -1, -2),
                         out.reshape(B_, Kh_, G_ * Sq_, -1))
        return out
    B, Kh, G, Sq, hd = q.shape
    qf = q.reshape(B, Kh, G * Sq, hd)
    out = gemm(qf, jnp.swapaxes(k, -1, -2), site=site, policy=pol)
    return out.reshape(B, Kh, G, Sq, k.shape[2])


def grouped_av(p: Array, v: Array, *, site: str = "attn_av",
               policy: Optional[NumericsPolicy] = None) -> Array:
    """GQA value einsum  p (B,Kh,G,Sq,Sk) x v (B,Kh,Sk,hd) -> (B,Kh,G,Sq,hd)."""
    pol = policy or current_policy()
    cfg = pol.lookup(site)
    _SITES_SEEN.add(site)
    if cfg.mode == "native":
        dt = cfg.fmt.jnp_dtype
        out = jnp.einsum("bkgqs,bksd->bkgqd", p.astype(dt), v.astype(dt),
                         preferred_element_type=jnp.float32)
        if _TRACE_HOOK is not None:
            B_, Kh_, G_, Sq_, Sk_ = p.shape
            _maybe_trace(site, cfg, p.reshape(B_, Kh_, G_ * Sq_, Sk_), v,
                         out.reshape(B_, Kh_, G_ * Sq_, -1))
        return out
    B, Kh, G, Sq, Sk = p.shape
    pf = p.reshape(B, Kh, G * Sq, Sk)
    out = gemm(pf, v, site=site, policy=pol)
    return out.reshape(B, Kh, G, Sq, v.shape[-1])


def policy_from_plan(path) -> NumericsPolicy:
    """Load a serialized ``repro.numerics`` PrecisionPlan and return the
    NumericsPolicy it deploys (the ``--precision-plan`` entry point)."""
    from repro.numerics import load_plan       # deferred: numerics imports us
    return load_plan(path).to_policy()


def quantize_inputs(x: Array, site: str = "generic",
                    policy: Optional[NumericsPolicy] = None) -> Array:
    """Round an activation/weight onto the policy format's grid (keeps f32
    carrier for posit formats)."""
    pol = policy or current_policy()
    cfg = pol.lookup(site)
    fmt = cfg.fmt
    if isinstance(fmt, PositFormat):
        return fmt.to_float(fmt.from_float(x))
    return x.astype(fmt.jnp_dtype).astype(x.dtype)
