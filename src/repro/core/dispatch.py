"""BLAS-style transparent dispatch — the OpenBLAS-swap analogue.

High-level model code never calls ``jnp.dot`` directly; it calls
``repro.core.dispatch.gemm(a, b, site="attn_qk")``.  A ``NumericsPolicy``
(installed via context manager, like re-linking OpenBLAS at runtime) maps each
*call-site* to a ``GemmConfig`` ⟨format, accumulator, execution target⟩, so an
unmodified model can be re-run under any numerics without touching its code —
the paper's "runtime execution flow".

Modes:
    native   - MXU fast path: inputs cast to the format's dtype,
               jnp.dot(..., preferred_element_type=f32). Default everywhere;
               this is what the multi-pod dry-run lowers.
    simulate - bit-exact ⟨ovf,msb,lsb⟩ FDP (repro.core.fdp).
    pallas   - the Pallas TPU kernel (interpret on CPU).

Batched inputs (ndim > 2) are supported in all modes (simulate/pallas vmap
over leading dims; native uses dot_general via jnp.matmul semantics).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional

import jax
import jax.numpy as jnp

from .accumulator import AccumulatorSpec
from .formats import BF16, FP32, FloatFormat, PositFormat, get_format

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class GemmConfig:
    fmt: FloatFormat | PositFormat = BF16
    acc: Optional[AccumulatorSpec] = None      # None => native fp32 accumulate
    mode: str = "native"                       # native | simulate | pallas

    def __post_init__(self):
        if self.mode not in ("native", "simulate", "pallas"):
            raise ValueError(self.mode)
        if self.mode != "native" and self.acc is None:
            raise ValueError(f"mode={self.mode} requires an AccumulatorSpec")

    def tag(self) -> str:
        acc = (f"<{self.acc.ovf},{self.acc.msb},{self.acc.lsb}>"
               if self.acc else "fp32acc")
        return f"{self.fmt.name}/{acc}/{self.mode}"


@dataclasses.dataclass(frozen=True)
class NumericsPolicy:
    """Call-site -> GemmConfig mapping. ``default`` covers unlisted sites.
    Site keys support trailing-* prefix matching ("attn_*")."""

    default: GemmConfig = GemmConfig()
    overrides: tuple = ()                      # tuple[(pattern, GemmConfig)]
    name: str = "default"

    def lookup(self, site: str) -> GemmConfig:
        for pat, cfg in self.overrides:
            if pat == site:
                return cfg
        for pat, cfg in self.overrides:
            if pat.endswith("*") and site.startswith(pat[:-1]):
                return cfg
        return self.default

    def with_override(self, pattern: str, cfg: GemmConfig) -> "NumericsPolicy":
        return dataclasses.replace(
            self, overrides=((pattern, cfg),) + tuple(self.overrides))


MXU_BF16 = NumericsPolicy(GemmConfig(BF16, None, "native"), name="mxu_bf16")
MXU_FP32 = NumericsPolicy(GemmConfig(FP32, None, "native"), name="mxu_fp32")

_state = threading.local()


def current_policy() -> NumericsPolicy:
    return getattr(_state, "policy", MXU_BF16)


@contextlib.contextmanager
def use_policy(policy: NumericsPolicy):
    """Swap the process-wide numerics (the LD_PRELOAD moment)."""
    prev = current_policy()
    _state.policy = policy
    try:
        yield policy
    finally:
        _state.policy = prev


_SITES_SEEN: set = set()


def sites_seen() -> frozenset:
    """All GEMM call-sites traced so far (introspection/report)."""
    return frozenset(_SITES_SEEN)


def gemm(a: Array, b: Array, *, site: str = "generic",
         policy: Optional[NumericsPolicy] = None) -> Array:
    """Policy-dispatched matmul. Contracts a's last dim with b's second-to-last
    (jnp.matmul semantics). Output f32 (simulate/pallas) or f32/bf16 (native,
    preferred_element_type=f32 then cast by caller if desired)."""
    pol = policy or current_policy()
    cfg = pol.lookup(site)
    _SITES_SEEN.add(site)

    if cfg.mode == "native":
        dt = cfg.fmt.jnp_dtype
        return jnp.matmul(a.astype(dt), b.astype(dt),
                          preferred_element_type=jnp.float32)

    if cfg.mode == "simulate":
        from . import fdp
        f = lambda x, y: fdp.fdp_gemm(x, y, cfg.acc, cfg.fmt)
    else:  # pallas
        from repro.kernels import ops as kops
        f = lambda x, y: kops.fdp_gemm(x, y, spec=cfg.acc, fmt=cfg.fmt)

    return _batched_apply(f, a, b)


def _batched_apply(f, a: Array, b: Array) -> Array:
    """Apply a 2D (M,K)x(K,N) kernel over arbitrary leading batch dims with
    numpy broadcasting between a and b batch dims."""
    if a.ndim == 1:
        a = a[None, :]
        out = _batched_apply(f, a, b)
        return out[..., 0, :]
    if b.ndim == 1:
        b = b[:, None]
        out = _batched_apply(f, a, b)
        return out[..., :, 0]
    if a.ndim == 2 and b.ndim == 2:
        return f(a, b)
    # broadcast batch dims
    batch = jnp.broadcast_shapes(a.shape[:-2], b.shape[:-2])
    a = jnp.broadcast_to(a, batch + a.shape[-2:])
    b = jnp.broadcast_to(b, batch + b.shape[-2:])
    af = a.reshape((-1,) + a.shape[-2:])
    bf = b.reshape((-1,) + b.shape[-2:])
    out = jax.vmap(f)(af, bf)
    return out.reshape(batch + out.shape[-2:])


def grouped_qk(q: Array, k: Array, *, site: str = "attn_qk",
               policy: Optional[NumericsPolicy] = None) -> Array:
    """GQA score einsum  q (B,Kh,G,Sq,hd) x k (B,Kh,Sk,hd) -> (B,Kh,G,Sq,Sk).

    Native mode uses a real einsum so sequence-parallel sharding on Sq
    survives (a reshape that merges (G, Sq) would force XLA to replicate the
    sequence dim). Simulate/pallas modes vmap the 2D FDP kernel."""
    pol = policy or current_policy()
    cfg = pol.lookup(site)
    _SITES_SEEN.add(site)
    if cfg.mode == "native":
        dt = cfg.fmt.jnp_dtype
        return jnp.einsum("bkgqd,bksd->bkgqs", q.astype(dt), k.astype(dt),
                          preferred_element_type=jnp.float32)
    B, Kh, G, Sq, hd = q.shape
    qf = q.reshape(B, Kh, G * Sq, hd)
    out = gemm(qf, jnp.swapaxes(k, -1, -2), site=site, policy=pol)
    return out.reshape(B, Kh, G, Sq, k.shape[2])


def grouped_av(p: Array, v: Array, *, site: str = "attn_av",
               policy: Optional[NumericsPolicy] = None) -> Array:
    """GQA value einsum  p (B,Kh,G,Sq,Sk) x v (B,Kh,Sk,hd) -> (B,Kh,G,Sq,hd)."""
    pol = policy or current_policy()
    cfg = pol.lookup(site)
    _SITES_SEEN.add(site)
    if cfg.mode == "native":
        dt = cfg.fmt.jnp_dtype
        return jnp.einsum("bkgqs,bksd->bkgqd", p.astype(dt), v.astype(dt),
                          preferred_element_type=jnp.float32)
    B, Kh, G, Sq, Sk = p.shape
    pf = p.reshape(B, Kh, G * Sq, Sk)
    out = gemm(pf, v, site=site, policy=pol)
    return out.reshape(B, Kh, G, Sq, v.shape[-1])


def quantize_inputs(x: Array, site: str = "generic",
                    policy: Optional[NumericsPolicy] = None) -> Array:
    """Round an activation/weight onto the policy format's grid (keeps f32
    carrier for posit formats)."""
    pol = policy or current_policy()
    cfg = pol.lookup(site)
    fmt = cfg.fmt
    if isinstance(fmt, PositFormat):
        return fmt.to_float(fmt.from_float(x))
    return x.astype(fmt.jnp_dtype).astype(x.dtype)
