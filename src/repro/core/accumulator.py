"""The numerically-tailored fixed-point accumulator (Kulisch scratchpad).

This is the paper's central object: a two's-complement fixed-point register
parameterized by ``⟨ovf, msb, lsb⟩`` into which exact floating-point products
are accumulated **without intermediate rounding**.  On the FPGA this is a wide
carry-save register; on TPU we represent it as a vector of int32 *limbs*, each
carrying a 16-bit digit plus carry headroom, so the whole algebra runs on the
vector unit (VPU) with plain int32 adds/shifts — exactly the kind of substrate
the MXU-adjacent VPU is good at.

Normative semantics (see DESIGN.md §2.2):
  * value(limbs) = Σ_l limbs[l] · 2^(lsb + 16·l)   (limbs int32, signed)
  * products are quantized ONCE at entry: round-toward-zero at 2^lsb
    (``trunc``, hardware default — drops the wires below lsb) or RNE,
  * additions are exact; carries are propagated lazily (≤ SAFE_CHUNK = 2^13
    products between normalizations, enforced by callers via chunking),
  * the register wraps (or saturates) at W = ovf + msb - lsb + 1 bits.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp

from .formats import Decoded, _ilog2

Array = jax.Array

LIMB_BITS = 16
LIMB_MASK = (1 << LIMB_BITS) - 1
# Max products safely accumulated between carry normalizations:
# per product, a limb receives < 2^17 in magnitude (two 16-bit digit halves);
# int32 headroom 2^31 -> stay strictly below: 2^13 * 2^17 = 2^30.
SAFE_CHUNK = 1 << 13


@dataclasses.dataclass(frozen=True)
class AccumulatorSpec:
    """⟨ovf, msb, lsb⟩ accumulator. Width W = ovf + msb - lsb + 1 bits.

    ``msb``: weight of the largest magnitude bit kept (2^msb).
    ``lsb``: weight of the smallest bit kept (2^lsb), lsb <= msb.
    ``ovf``: carry headroom bits on top of msb.
    """

    ovf: int
    msb: int
    lsb: int
    round_mode: str = "trunc"        # product-entry quantization: trunc | rne
    overflow_mode: str = "wrap"      # wrap | saturate

    def __post_init__(self):
        if self.lsb > self.msb:
            raise ValueError(f"lsb ({self.lsb}) > msb ({self.msb})")
        if self.round_mode not in ("trunc", "rne"):
            raise ValueError(self.round_mode)
        if self.overflow_mode not in ("wrap", "saturate"):
            raise ValueError(self.overflow_mode)

    @property
    def width(self) -> int:
        return self.ovf + self.msb - self.lsb + 1

    @property
    def num_limbs(self) -> int:
        return -(-self.width // LIMB_BITS)

    def describe(self) -> str:
        return (f"FDP<ovf:{self.ovf}, msb:{self.msb}, lsb:{self.lsb}> "
                f"({self.width}-bit, {self.num_limbs} limbs, {self.round_mode}/"
                f"{self.overflow_mode})")

    @classmethod
    def paper_91bit(cls) -> "AccumulatorSpec":
        """The paper's flagship 91-bit ⟨ovf:30, msb:30, lsb:-30⟩ instance."""
        return cls(ovf=30, msb=30, lsb=-30)

    @classmethod
    def for_exact(cls, fmt, max_terms: int) -> "AccumulatorSpec":
        """Size an accumulator so that accumulating ``max_terms`` products of
        ``fmt`` values is EXACT and overflow-free (FCCM'22 §IV sizing rule)."""
        p = fmt.precision
        emax, emin = fmt.emax, getattr(fmt, "emin", -fmt.emax)
        msb = 2 * emax + 2                   # |a*b| < 2^(2emax+2)
        lsb = 2 * (emin - (p - 1))           # smallest product bit (subnormal²)
        ovf = max(1, math.ceil(math.log2(max(max_terms, 2))))
        return cls(ovf=ovf, msb=msb, lsb=lsb)

    @classmethod
    def quire(cls, posit_fmt, max_terms: int = 1 << 20) -> "AccumulatorSpec":
        """The posit standard's *quire* for posit⟨n,es⟩: an accumulator wide
        enough that any dot product of posits is exact (maxpos² down to
        minpos²) with carry headroom — the posit-native instance of the
        paper's ⟨ovf,msb,lsb⟩ family."""
        n, es = posit_fmt.nbits, posit_fmt.es
        max_scale = (n - 2) * (1 << es)      # exponent of maxpos
        msb = 2 * max_scale + 2
        lsb = -2 * max_scale - 2 * posit_fmt.precision
        ovf = max(1, math.ceil(math.log2(max(max_terms, 2))))
        return cls(ovf=ovf, msb=msb, lsb=lsb)


def zeros(spec: AccumulatorSpec, shape: Sequence[int] = ()) -> Array:
    """Fresh accumulator state: shape (*shape, num_limbs) int32."""
    return jnp.zeros((*shape, spec.num_limbs), dtype=jnp.int32)


# ---------------------------------------------------------------------------
# Product entry: quantize an exact product onto the grid, as limb contributions
# ---------------------------------------------------------------------------
def _product_digits(a: Decoded, b: Decoded) -> tuple:
    """Exact 48-bit significand product a.mant*b.mant as three base-2^16
    digits (d0, d1, d2), computed in int32 via 12-bit digit splitting
    (24x24 -> 48 bits with exact carries)."""
    a_hi, a_lo = a.mant >> 12, a.mant & 0xFFF
    b_hi, b_lo = b.mant >> 12, b.mant & 0xFFF
    p0 = a_lo * b_lo                      # weight 2^0 , < 2^24
    p1 = a_lo * b_hi + a_hi * b_lo        # weight 2^12, < 2^25
    p2 = a_hi * b_hi                      # weight 2^24, < 2^24
    # digits of m = p0 + p1*2^12 + p2*2^24 in base 2^16 (exact carries)
    d0_raw = (p0 & 0xFFFF) + ((p1 & 0xF) << 12)
    d1_raw = (p0 >> 16) + ((p1 >> 4) & 0xFFFF) + ((p2 & 0xFF) << 8)
    d2_raw = (p1 >> 20) + (p2 >> 8)
    c0 = d0_raw >> 16
    d0 = d0_raw & 0xFFFF
    d1_raw = d1_raw + c0
    c1 = d1_raw >> 16
    d1 = d1_raw & 0xFFFF
    d2 = d2_raw + c1                      # < 2^17 is fine (top digit)
    return d0, d1, d2


def product_limbs(spec: AccumulatorSpec, a: Decoded, b: Decoded) -> Array:
    """Exact limb contributions of the products a*b (elementwise), quantized
    at 2^lsb per ``spec.round_mode``. Result: int32 (*batch, num_limbs); each
    limb's magnitude is < 2^17, so up to SAFE_CHUNK results may be summed
    before ``carry_normalize``.

    The significand product is computed exactly in int32 via 12-bit digit
    splitting (24x24 -> 48 bits as three 16-bit digits), then aligned to the
    grid with a uniform shift. Dropping the bits below position 0 of the
    aligned non-negative magnitude implements round-toward-zero of the signed
    product exactly.
    """
    L = spec.num_limbs
    digits = jnp.stack(_product_digits(a, b), axis=-1)    # (*batch, 3)

    e_prod = a.exp + b.exp                                # exponent of digit 0
    q = e_prod - spec.lsb                                 # grid bit offset
    sign = 1 - 2 * (a.sign ^ b.sign)                      # +1 / -1

    limbs = _place_digits(digits, q, sign, L, spec)
    # zero / special handling: zero mantissa -> all-zero contribution already.
    return limbs


def product_limb_block_sum(spec: AccumulatorSpec, a: Decoded, b: Decoded,
                           axis: int = 0) -> Array:
    """``jnp.sum(product_limbs(spec, a, b), axis=axis)`` without ever
    materializing the (*batch, L) contribution tensor — the GEMM hot path.

    The sum is computed limb-by-limb over small (*batch) slabs so the working
    set stays cache-resident on CPU (and VMEM-bounded on TPU); int32 addition
    is exact and commutative, so the result is bit-identical to the
    materialized form. The caller owns the SAFE_CHUNK headroom budget for
    the reduced axis."""
    assert axis == 0, "the fused block sum reduces the leading axis"
    L = spec.num_limbs
    digits = _product_digits(a, b)                        # 3 x (*batch)
    e_prod = a.exp + b.exp
    q = e_prod - spec.lsb
    sign = 1 - 2 * (a.sign ^ b.sign)
    j0 = jnp.floor_divide(q, LIMB_BITS)                   # limb of digit 0
    r = (q - j0 * LIMB_BITS).astype(jnp.int32)            # 0..15 sub-shift
    inc = (_rne_increment(digits, q) * sign
           if spec.round_mode == "rne" else None)         # lands on limb 0
    # compact 4-piece form: digit k's low part lands at limb j0+k, its high
    # part at j0+k+1, so piece i = lo[i] + hi[i-1] (|piece| < 2^17, the
    # headroom contract behind SAFE_CHUNK) — 4 placements per limb instead of
    # 6 (lo, hi) ones. Pieces are placed as MAGNITUDES and the sign applied
    # after: dropping below-limb-0 pieces of the non-negative form implements
    # round-toward-zero exactly (a sign-folded two's-complement form would
    # floor instead, off by 1 ulp for negative products with dropped bits).
    lo = [jnp.left_shift(d, r) & LIMB_MASK for d in digits]
    hi = [jnp.right_shift(jnp.left_shift(d, r), LIMB_BITS) for d in digits]
    pieces = [lo[0], lo[1] + hi[0], lo[2] + hi[1], hi[2]]
    pieces = [p * sign for p in pieces]
    # Placement masks are shared across limbs (piece i of limb l needs
    # j0 == l-i, which only depends on l-i): 0/1 multiplies through shared
    # int32 masks measure ~1.5x faster than per-(l,i) compare+select chains
    # on XLA:CPU, and each piece can only land on limbs -3..L-1.
    npieces = len(pieces)
    mask = {d: (j0 == d).astype(jnp.int32) for d in range(1 - npieces, L)}
    out = []
    for l in range(L):
        acc_l = jnp.zeros(j0.shape, jnp.int32)
        for i, piece in enumerate(pieces):
            if l - i in mask:
                acc_l = acc_l + piece * mask[l - i]
        if inc is not None and l == 0:
            acc_l = acc_l + inc
        out.append(jnp.sum(acc_l, axis=axis))
    return jnp.stack(out, axis=-1)


def _place_digits(digits: Array, q: Array, sign: Array, L: int,
                  spec: AccumulatorSpec) -> Array:
    """Place base-2^16 ``digits`` (non-negative, weight 2^(16k)) at grid bit
    offset ``q`` into L limbs, truncating below limb 0 (toward zero), with
    optional RNE correction, then apply ``sign``."""
    nd = digits.shape[-1]
    j0 = jnp.floor_divide(q, LIMB_BITS)                   # limb of digit 0
    r = q - j0 * LIMB_BITS                                # 0..15 sub-shift
    r = r.astype(jnp.int32)
    shifted_lo = jnp.left_shift(digits, r[..., None]) & LIMB_MASK
    shifted_hi = jnp.right_shift(jnp.left_shift(digits, r[..., None]), LIMB_BITS)
    # digit k contributes shifted_lo[k] at limb j0+k and shifted_hi[k] at j0+k+1
    out = jnp.zeros((*digits.shape[:-1], L), dtype=jnp.int32)
    for k in range(nd):
        for off, part in ((k, shifted_lo[..., k]), (k + 1, shifted_hi[..., k])):
            idx = j0 + off
            onehot = (idx[..., None] == jnp.arange(L, dtype=jnp.int32))
            out = out + jnp.where(onehot, part[..., None], 0)
    if spec.round_mode == "rne":
        out = out + _rne_correction(digits, q, L)
    out = out * sign[..., None]
    return out


def _rne_increment(digits, q: Array) -> Array:
    """The +1 ulp RNE increment (int32 0/1, magnitude) for products whose
    base-2^16 ``digits`` (sequence of arrays) sit at grid bit offset ``q``.

    guard = product bit at grid position -1, sticky = OR of bits below,
    lsb_bit = product bit at position 0 (pre-round). The increment applies to
    limb 0 (as magnitude; caller multiplies by sign afterwards, which matches
    round-half-away-from-zero-on-ties-odd — for RNE of the magnitude this is
    correct since negation of an RNE-magnitude equals RNE of the negation).
    """
    nd = len(digits)

    # bit at absolute product position p (0 <= p < 16*nd): p relative to grid = q + p
    # guard: grid pos -1 -> product bit pb = -1 - q ; valid if 0 <= pb < 16*nd
    def product_bit(pb):
        k = jnp.floor_divide(pb, LIMB_BITS)
        s = pb - k * LIMB_BITS
        val = jnp.zeros(pb.shape, jnp.int32)
        for kk in range(nd):
            val = val + jnp.where(k == kk,
                                  jnp.right_shift(digits[kk], s) & 1, 0)
        return jnp.where((pb >= 0) & (pb < LIMB_BITS * nd), val, 0)

    def bits_below(pb):   # OR of product bits strictly below pb
        any_below = jnp.zeros(pb.shape, jnp.bool_)
        for kk in range(nd):
            lo = pb - kk * LIMB_BITS     # bits of digit kk strictly below pb
            nbits = jnp.clip(lo, 0, LIMB_BITS)
            mask = jnp.left_shift(1, nbits) - 1
            any_below = any_below | ((digits[kk] & mask) != 0)
        return any_below

    pb_guard = -1 - q
    guard = product_bit(pb_guard)
    sticky = bits_below(pb_guard)
    lsb_bit = product_bit(-q)
    # entirely-below-grid products: guard position above all digits -> pb_guard >= 16nd
    # handled by product_bit bounds (guard=0 -> no correction; trunc-like).
    inc = (guard == 1) & (sticky | (lsb_bit == 1))
    return inc.astype(jnp.int32)


def _rne_correction(digits: Array, q: Array, L: int) -> Array:
    """RNE increment as a (*batch, L) limb tensor (limb 0 carries it)."""
    nd = digits.shape[-1]
    inc = _rne_increment(tuple(digits[..., kk] for kk in range(nd)), q)
    corr = jnp.zeros((*digits.shape[:-1], L), dtype=jnp.int32)
    corr = corr.at[..., 0].set(inc)
    return corr


# ---------------------------------------------------------------------------
# Carry normalization, wrap/saturate, read-out
# ---------------------------------------------------------------------------
def carry_normalize(spec: AccumulatorSpec, limbs: Array) -> Array:
    """Propagate carries so limbs 0..L-2 are in [0, 2^16); the top limb keeps
    the full signed remainder (NOT masked to W bits).

    Keeping the intermediate state exact in the extended (16L + int32
    headroom)-bit range makes the result independent of chunk/block
    boundaries; the W-bit wrap/saturation is applied ONCE at read-out
    (``finalize``/``to_float``), which for wrap is equivalent (mod-2^W is a
    ring homomorphism) and for saturate is the only order-invariant
    definition."""
    L = spec.num_limbs
    out = []
    carry = jnp.zeros(limbs.shape[:-1], dtype=jnp.int32)
    for l in range(L - 1):
        t = limbs[..., l] + carry
        carry = jnp.right_shift(t, LIMB_BITS)      # arithmetic shift = floor
        out.append(t & LIMB_MASK)
    out.append(limbs[..., L - 1] + carry)          # top limb: full int32
    return jnp.stack(out, axis=-1)


def finalize(spec: AccumulatorSpec, limbs: Array) -> Array:
    """Apply the register's W-bit wrap or saturation to a carry-normalized
    state (read-out step)."""
    L = spec.num_limbs
    return _apply_overflow(spec, limbs, limbs[..., L - 1])


def _apply_overflow(spec: AccumulatorSpec, norm: Array, top: Array) -> Array:
    """Wrap or saturate the register at W bits (two's complement)."""
    L, W = spec.num_limbs, spec.width
    top_bits = W - LIMB_BITS * (L - 1)              # 1..16 significant top bits
    # wrap: sign-extend the top limb from top_bits
    shift = 32 - top_bits
    wrapped_top = jnp.right_shift(jnp.left_shift(top, shift), shift)
    if spec.overflow_mode == "wrap":
        return jnp.concatenate([norm[..., :L - 1], wrapped_top[..., None]], axis=-1)
    # saturate: detect overflow (top limb outside signed top_bits range)
    lo, hi = -(1 << (top_bits - 1)), (1 << (top_bits - 1)) - 1
    over = top > hi
    under = top < lo
    sat_hi = jnp.full(norm.shape[:-1] + (L,), LIMB_MASK, jnp.int32)
    sat_hi = sat_hi.at[..., L - 1].set(hi)
    sat_lo = jnp.zeros(norm.shape[:-1] + (L,), jnp.int32)
    sat_lo = sat_lo.at[..., L - 1].set(lo)
    base = jnp.concatenate([norm[..., :L - 1],
                            jnp.clip(top, lo, hi)[..., None]], axis=-1)
    base = jnp.where(over[..., None], sat_hi, base)
    base = jnp.where(under[..., None], sat_lo, base)
    return base


def add(spec: AccumulatorSpec, acc: Array, contributions: Array) -> Array:
    """Exact add of limb contributions (no normalization)."""
    del spec
    return acc + contributions


def merge_states(spec: AccumulatorSpec, states: Array, axis: int = 0) -> Array:
    """Merge carry-normalized partial accumulator states (e.g. per-K-shard
    registers from ``fdp.fdp_gemm_limbs``) into one normalized register.

    Integer limb addition is exact, associative and commutative, so the
    merged register is bit-identical to accumulating all products on one
    device — for ANY partition of the reduction and ANY merge order. This is
    the single-host form of ``repro.parallel.collectives.fdp_psum``. Up to
    SAFE_CHUNK normalized states may be merged in one call (normalized digit
    magnitudes are < 2^16; int32 headroom covers 2^13 of them)."""
    assert states.shape[axis] <= SAFE_CHUNK
    return carry_normalize(spec, jnp.sum(states, axis=axis))


def to_float(spec: AccumulatorSpec, limbs: Array, out_precision: int = 24) -> Array:
    """Round the accumulator ONCE to a float (RNE at ``out_precision`` bits)
    and return f32. ``limbs`` must be carry-normalized. Exact for
    out_precision <= 24."""
    L = spec.num_limbs
    limbs = finalize(spec, limbs)
    sign_neg = limbs[..., L - 1] < 0
    # magnitude digits: conditional two's-complement negate across limbs
    mag = _negate_where(limbs, sign_neg)
    # position of highest set bit
    any_nz = jnp.any(mag != 0, axis=-1)
    top_idx = jnp.zeros(mag.shape[:-1], jnp.int32)
    for l in range(L):
        top_idx = jnp.where(mag[..., l] != 0, l, top_idx)
    top_val = jnp.take_along_axis(mag, top_idx[..., None], axis=-1)[..., 0]
    hb = _ilog2(jnp.maximum(top_val, 1)) + top_idx * LIMB_BITS  # highest bit pos
    # extract out_precision bits [hb-p+1 .. hb], guard at hb-p, sticky below
    p = out_precision
    take_from = hb - p + 1                                      # may be < 0
    mant = _extract_bits(mag, take_from, p)
    guard = _extract_bits(mag, take_from - 1, 1)
    sticky = _any_below(mag, take_from - 2)   # strictly below the guard bit
    rnd = (guard == 1) & (sticky | ((mant & 1) == 1))
    mant = mant + rnd.astype(jnp.int32)
    # mantissa overflow (2^p) -> exact power of two, bump exponent
    ovf = mant == (1 << p)
    mant = jnp.where(ovf, 1 << (p - 1), mant)
    exp = take_from + spec.lsb + jnp.where(ovf, 1, 0)
    v = jnp.ldexp(mant.astype(jnp.float32), exp)
    v = jnp.where(sign_neg, -v, v)
    return jnp.where(any_nz, v, jnp.float32(0.0))


def to_float64(spec: AccumulatorSpec, limbs: Array) -> Array:
    """Round the accumulator ONCE to float64 (53-bit RNE). Requires x64 to be
    enabled (benchmark processes); the mantissa is assembled from two int32
    pieces so the limb algebra itself stays int32/TPU-shaped."""
    L = spec.num_limbs
    limbs = finalize(spec, limbs)
    sign_neg = limbs[..., L - 1] < 0
    mag = _negate_where(limbs, sign_neg)
    any_nz = jnp.any(mag != 0, axis=-1)
    top_idx = jnp.zeros(mag.shape[:-1], jnp.int32)
    for l in range(L):
        top_idx = jnp.where(mag[..., l] != 0, l, top_idx)
    top_val = jnp.take_along_axis(mag, top_idx[..., None], axis=-1)[..., 0]
    hb = _ilog2(jnp.maximum(top_val, 1)) + top_idx * LIMB_BITS
    p = 53
    take_from = hb - p + 1
    lo_bits = 29
    hi = _extract_bits(mag, take_from + lo_bits, p - lo_bits)   # 24 bits
    lo = _extract_bits(mag, take_from, lo_bits)                 # 29 bits
    guard = _extract_bits(mag, take_from - 1, 1)
    sticky = _any_below(mag, take_from - 2)
    mant = hi.astype(jnp.float64) * (1 << lo_bits) + lo.astype(jnp.float64)
    rnd = (guard == 1) & (sticky | ((lo & 1) == 1))
    mant = mant + rnd.astype(jnp.float64)
    v = jnp.ldexp(mant, take_from + spec.lsb)
    v = jnp.where(sign_neg, -v, v)
    return jnp.where(any_nz, v, jnp.float64(0.0))


def _negate_where(limbs: Array, cond: Array) -> Array:
    """Two's-complement negate across base-2^16 limbs where ``cond``.

    Input must be carry-normalized (digits 0..L-2 in [0,2^16), top limb a
    small signed value). Output where cond: magnitude digits, all in
    [0, 2^16)."""
    L = limbs.shape[-1]
    out = []
    borrow = jnp.zeros(limbs.shape[:-1], jnp.int32)
    for l in range(L):
        t = -limbs[..., l] - borrow
        neg = (t < 0).astype(jnp.int32)
        t = t + neg * (1 << LIMB_BITS)
        borrow = neg
        out.append(t)
    negated = jnp.stack(out, axis=-1)
    return jnp.where(cond[..., None], negated, limbs)


def _canon(limbs: Array) -> Array:
    """Canonicalize a normalized non-negative register to digits in [0,2^16).
    (After carry_normalize, limbs 0..L-2 already are; the top limb of a
    non-negative value is >= 0 and < 2^16 by width.)"""
    return limbs


def _extract_bits(mag: Array, start: Array, nbits: int) -> Array:
    """Bits [start, start+nbits) of the magnitude register as int32.
    start may be negative (those bits read as 0). nbits <= 24."""
    # value >> start, truncated to nbits: gathered from 3 adjacent limbs.
    j = jnp.floor_divide(start, LIMB_BITS)
    s = start - j * LIMB_BITS                     # 0..15
    part0 = jnp.right_shift(_limb_at(mag, j), s)
    part1 = jnp.left_shift(_limb_at(mag, j + 1), LIMB_BITS - s)
    # part2 only matters when s > 8 (bits 32-s .. < 24); clamp the shift.
    sh2 = jnp.clip(2 * LIMB_BITS - s, 0, 31)
    part2 = jnp.where(s > 2 * LIMB_BITS - nbits,
                      jnp.left_shift(_limb_at(mag, j + 2), sh2), 0)
    res = part0 | part1 | part2
    return res & ((1 << nbits) - 1)


def _limb_at(mag: Array, idx: Array) -> Array:
    L = mag.shape[-1]
    out = jnp.zeros(mag.shape[:-1], jnp.int32)
    for l in range(L):
        out = out + jnp.where(idx == l, mag[..., l], 0)
    return jnp.where((idx >= 0) & (idx < L), out, 0)


def _any_below(mag: Array, below: Array) -> Array:
    """True where any magnitude bit strictly below position ``below``+1 is set
    — i.e. bits [0, below] inclusive... (sticky for positions < take_from-? )
    Concretely: OR of bits at positions <= below."""
    L = mag.shape[-1]
    any_set = jnp.zeros(mag.shape[:-1], jnp.bool_)
    for l in range(L):
        lo = below + 1 - l * LIMB_BITS            # #bits of limb l at pos <= below
        nbits = jnp.clip(lo, 0, LIMB_BITS)
        mask = jnp.left_shift(1, nbits) - 1
        any_set = any_set | ((mag[..., l] & mask) != 0)
    return any_set


def value_as_float2(spec: AccumulatorSpec, limbs: Array) -> tuple[Array, Array]:
    """Lossier helper: accumulator value as a head+tail f32 pair (for quick
    diagnostics; NOT used in correctness paths)."""
    L = spec.num_limbs
    scale = [jnp.float32(2.0) ** (spec.lsb + LIMB_BITS * l) for l in range(L)]
    hi = jnp.zeros(limbs.shape[:-1], jnp.float32)
    for l in reversed(range(L)):
        hi = hi + limbs[..., l].astype(jnp.float32) * scale[l]
    return hi, jnp.zeros_like(hi)
