"""Fused Dot Product (FDP) — the paper's operator, as composable JAX functions.

``fdp_dot``/``fdp_gemm`` compute dot products / GEMMs whose products are
accumulated in a ⟨ovf,msb,lsb⟩ fixed-point register with NO intermediate
rounding (one quantization at product entry, one rounding at read-out).

These are the *simulation-mode* (pure jnp, bit-exact) implementations; the
Pallas TPU kernel in ``repro.kernels.fdp_gemm`` implements identical semantics
and is validated against this module, which in turn is validated against a
python-``Fraction`` oracle in the tests.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import accumulator as acc
from .accumulator import SAFE_CHUNK, AccumulatorSpec
from .formats import FP32, FloatFormat, PositFormat

Array = jax.Array


def _decode(fmt, x: Array):
    """Decode an array to (sign, mant, exp) per the format. Float formats take
    float arrays; posit formats take int32 bit-pattern arrays."""
    return fmt.decode(x)


@partial(jax.jit, static_argnums=(2, 3))
def fdp_dot(a: Array, b: Array, spec: AccumulatorSpec,
            fmt: FloatFormat | PositFormat = FP32) -> Array:
    """Exact-accumulation dot product of 1-D vectors, -> f32 (RNE once)."""
    limbs = fdp_dot_limbs(a, b, spec, fmt)
    return acc.to_float(spec, limbs)


@partial(jax.jit, static_argnums=(2, 3))
def fdp_dot64(a: Array, b: Array, spec: AccumulatorSpec,
              fmt: FloatFormat | PositFormat = FP32) -> Array:
    """Exact-accumulation dot product with 53-bit (f64) read-out.
    Requires jax x64 mode (used by the SSH benchmark's correct-bits axis)."""
    limbs = fdp_dot_limbs(a, b, spec, fmt)
    return acc.to_float64(spec, limbs)


def fdp_dot_limbs(a: Array, b: Array, spec: AccumulatorSpec,
                  fmt: FloatFormat | PositFormat = FP32) -> Array:
    """Accumulator register (carry-normalized limbs) of dot(a, b)."""
    assert a.shape == b.shape and a.ndim == 1
    da, db = _decode(fmt, a), _decode(fmt, b)
    contrib = acc.product_limbs(spec, da, db)        # (K, L)
    return _reduce_contribs(spec, contrib, axis=0)


def _reduce_contribs(spec: AccumulatorSpec, contrib: Array, axis: int) -> Array:
    """Sum limb contributions along ``axis`` exactly, normalizing carries
    every SAFE_CHUNK partial sums (int32 overflow discipline)."""
    n = contrib.shape[axis]
    if n <= SAFE_CHUNK:
        return acc.carry_normalize(spec, jnp.sum(contrib, axis=axis))
    # chunked reduction: pad to a multiple of SAFE_CHUNK, scan over chunks
    pad = (-n) % SAFE_CHUNK
    contrib = jnp.moveaxis(contrib, axis, 0)
    if pad:
        contrib = jnp.concatenate(
            [contrib, jnp.zeros((pad, *contrib.shape[1:]), contrib.dtype)], 0)
    chunks = contrib.reshape(-1, SAFE_CHUNK, *contrib.shape[1:])

    def step(carry, chunk):
        # carry is normalized (digit magnitudes < 2^16) -> safe to add a chunk
        s = carry + jnp.sum(chunk, axis=0)
        return acc.carry_normalize(spec, s), None

    init = jnp.zeros(chunks.shape[2:], jnp.int32)
    out, _ = jax.lax.scan(step, init, chunks)
    return out


@partial(jax.jit, static_argnums=(2, 3))
def fdp_gemm_limbs(a: Array, b: Array, spec: AccumulatorSpec,
                   fmt: FloatFormat | PositFormat = FP32) -> Array:
    """The accumulator register of a GEMM: (M,K) @ (K,N) -> (M,N,L) int32
    carry-normalized limbs, with NO read-out rounding applied.

    This is the *partial-K reduction state*: because limb addition is exact
    integer arithmetic, the register of a full-K GEMM equals the limb-wise sum
    of the registers of any K-partition — ``carry_normalize(spec, Σ_k
    fdp_gemm_limbs(a_k, b_k))`` is bit-identical to
    ``fdp_gemm_limbs(a, b)`` for every split. That is what lets a K-sharded
    contraction reduce across devices through an integer ``psum`` of limbs
    (``repro.parallel.collectives.fdp_psum``) and land on exactly the bits a
    single device would produce. Up to SAFE_CHUNK normalized partial states
    may be summed before the next ``carry_normalize`` (digit magnitudes are
    < 2^16 after normalization; int32 headroom covers 2^13 of them — far more
    devices than any mesh).
    """
    assert a.ndim == 2 and b.ndim == 2 and a.shape[1] == b.shape[0]
    M, K = a.shape
    _, N = b.shape
    da, db = _decode(fmt, a), _decode(fmt, b)

    # chunk K to bound both memory and int32 carry headroom
    kc = min(K, 512)
    pad = (-K) % kc
    def padk(d, fill=0):
        return jax.tree.map(
            lambda x: jnp.concatenate(
                [x, jnp.full((pad, *x.shape[1:]), fill, x.dtype)], 0) if pad else x, d)

    da_k = jax.tree.map(lambda x: x.T if x.ndim == 2 else x, da)   # (K, M)
    db_k = db                                                      # (K, N)
    da_k, db_k = padk(da_k), padk(db_k)
    nchunks = (K + pad) // kc
    da_c = jax.tree.map(lambda x: x.reshape(nchunks, kc, *x.shape[1:]), da_k)
    db_c = jax.tree.map(lambda x: x.reshape(nchunks, kc, *x.shape[1:]), db_k)

    L = spec.num_limbs

    def step(carry, chunk):
        dac, dbc = chunk
        # broadcast to (kc, M, N): sign/mant/exp combine elementwise
        def bc(d, which):
            return jax.tree.map(
                lambda x: x[:, :, None] if which == "a" else x[:, None, :], d)
        s = carry + acc.product_limb_block_sum(
            spec, bc(dac, "a"), bc(dbc, "b"), axis=0)      # limb-fused (M,N,L)
        return acc.carry_normalize(spec, s), None

    init = jnp.zeros((M, N, L), jnp.int32)
    out, _ = jax.lax.scan(step, init, (da_c, db_c))
    return out


@partial(jax.jit, static_argnums=(2, 3))
def fdp_gemm(a: Array, b: Array, spec: AccumulatorSpec,
             fmt: FloatFormat | PositFormat = FP32) -> Array:
    """GEMM with FDP accumulation: (M,K) @ (K,N) -> (M,N) f32.

    Memory note: materializes per-K limb contributions in K-chunks of size
    min(K, SAFE_CHUNK); intended for numerics experiments (simulation mode),
    not as the production fast path. ``fdp_gemm_limbs`` is the same
    computation stopped before the single read-out rounding — the partial-K
    state a sharded reduction merges across devices.
    """
    return acc.to_float(spec, fdp_gemm_limbs(a, b, spec, fmt))


def quantize_products(a: Array, b: Array, spec: AccumulatorSpec,
                      fmt=FP32) -> Array:
    """The per-product entry quantization alone (diagnostic): q(a*b) * 2^lsb."""
    da, db = _decode(fmt, a), _decode(fmt, b)
    limbs = acc.product_limbs(spec, da, db)
    limbs = acc.carry_normalize(spec, limbs)
    return acc.to_float(spec, limbs)


def fdp_dot_posit(a: Array, b: Array, spec: AccumulatorSpec | None = None,
                  fmt=None, out_fmt=None) -> Array:
    """Posit-in, posit-out fused dot product through the quire: posit bit
    patterns are decoded, products accumulate exactly in the ⟨ovf,msb,lsb⟩
    register (default: the format's standard quire), and the result is
    rounded ONCE to the output posit format.

    Read-out goes through f32 (exact for posit16's <=13 fraction bits; for
    posit32's deepest regimes this is a documented double rounding)."""
    from .formats import POSIT16_1
    fmt = fmt or POSIT16_1
    out_fmt = out_fmt or fmt
    spec = spec or AccumulatorSpec.quire(fmt, max_terms=a.shape[0])
    limbs = fdp_dot_limbs(a, b, spec, fmt)
    return out_fmt.from_float(acc.to_float(spec, limbs))


# ---------------------------------------------------------------------------
# Baseline accumulators the paper compares against (ordered FMA chains)
# ---------------------------------------------------------------------------
def fma_dot(a: Array, b: Array, dtype=jnp.float32) -> Array:
    """Sequential FMA accumulation in ``dtype`` (rounds after every add) —
    the conventional-FPU baseline of Fig. 2."""
    a = a.astype(dtype)
    b = b.astype(dtype)

    def step(s, ab):
        x, y = ab
        return (s + x * y).astype(dtype), None

    s, _ = jax.lax.scan(step, jnp.zeros((), dtype), (a, b))
    return s


def two_sum(x, y):
    s = x + y
    bb = s - x
    err = (x - (s - bb)) + (y - bb)
    return s, err


def two_prod(x, y):
    """Exact product via Dekker splitting: x*y = p + e (p = rounded product)."""
    p = x * y
    return p, _dekker_err(x, y, p)


def _dekker_err(x, y, p):
    # split constant 2^ceil(prec/2)+1: f32 -> 4097, f64 -> 2^27+1
    c = jnp.asarray(134217729.0 if x.dtype == jnp.float64 else 4097.0, x.dtype)
    xh = (x * c) - (x * c - x); xl = x - xh
    yh = (y * c) - (y * c - y); yl = y - yh
    return ((xh * yh - p) + xh * yl + xl * yh) + xl * yl


def dd_dot(a: Array, b: Array, dtype=jnp.float64) -> Array:
    """Double-double (compensated) dot product in ``dtype`` — the emulated
    quad-precision FMA baseline of Fig. 2 (~2x mantissa bits)."""
    a = a.astype(dtype)
    b = b.astype(dtype)

    def step(carry, xy):
        s, c = carry
        x, y = xy
        p, pe = two_prod(x, y)
        s, se = two_sum(s, p)
        c = c + (se + pe)
        return (s, c), None

    (s, c), _ = jax.lax.scan(step, (jnp.zeros((), dtype),) * 2, (a, b))
    return s + c
