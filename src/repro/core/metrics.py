"""Numerical-quality metrics used throughout the paper's evaluation.

``correct_bits`` is the paper's Fig. 2 y-axis: the number of leading mantissa
bits of a result that agree with the infinitely-precise reference.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from fractions import Fraction

Array = jax.Array


def correct_bits(value, reference, cap: float = 53.0):
    """-log2(|v - ref| / |ref|), clipped to [0, cap]; cap when exact.

    Accepts python floats / numpy / jax arrays; computed in float64 on host
    (metrics are an offline reduction, never part of a jitted path).
    """
    v = np.asarray(jax.device_get(value), dtype=np.float64)
    r = np.asarray(jax.device_get(reference), dtype=np.float64)
    err = np.abs(v - r)
    denom = np.maximum(np.abs(r), np.finfo(np.float64).tiny)
    rel = err / denom
    with np.errstate(divide="ignore"):
        bits = -np.log2(rel)
    bits = np.where(rel == 0.0, cap, bits)
    return np.clip(bits, 0.0, cap)


def exact_dot_fraction(a, b) -> Fraction:
    """Infinitely-precise dot product via python Fractions (host oracle)."""
    a = np.asarray(jax.device_get(a), dtype=np.float64)
    b = np.asarray(jax.device_get(b), dtype=np.float64)
    s = Fraction(0)
    for x, y in zip(a.tolist(), b.tolist()):
        s += Fraction(x) * Fraction(y)
    return s


def fraction_to_float(f: Fraction) -> float:
    return float(f)


def reproducibility_deviation(fn, a, b, n_orders: int = 8, seed: int = 0):
    """Max absolute deviation of fn(a,b) across random input permutations —
    the paper's reproducibility probe (0.0 for the FDP by construction)."""
    rng = np.random.default_rng(seed)
    a = np.asarray(jax.device_get(a))
    b = np.asarray(jax.device_get(b))
    vals = []
    for i in range(n_orders):
        perm = rng.permutation(a.shape[0]) if i else np.arange(a.shape[0])
        vals.append(float(jax.device_get(fn(jnp.asarray(a[perm]),
                                            jnp.asarray(b[perm])))))
    vals = np.asarray(vals, dtype=np.float64)
    return float(np.max(np.abs(vals - vals[0]))), vals


def top1_agreement(logits, ref_logits) -> float:
    """Fig. 3 proxy metric: fraction of samples whose argmax matches the
    exact-accumulator reference."""
    l = np.asarray(jax.device_get(logits))
    r = np.asarray(jax.device_get(ref_logits))
    return float(np.mean(l.argmax(-1) == r.argmax(-1)))
