"""Block-scaled low-bit storage formats for non-GEMM precision sites.

The paper tailors the accumulator of each GEMM; this module extends the same
site-identity discipline to the two dominant *byte* consumers of training —
optimizer state (bytes resident: fp32 Adam moments are ~2x params) and
gradient collectives (bytes moved: the all-reduce payload) — so the tailoring
search can trade them on a Pareto frontier exactly like accumulator energy.

Site identity
-------------
Non-GEMM sites get their own canonical key grammar, disjoint from
``GemmSite`` keys by construction (GemmSite names may not contain ``.`` or
``@``, and its phases are only fwd/bwd):

  * ``StateSite("opt.m")``  -> ``"opt.m@state"``   (bytes *resident*)
  * ``CollectiveSite("grad_psum")`` -> ``"grad_psum@coll"`` (bytes *moved*)

``site_kind`` classifies any site key ("gemm" / "state" / "collective"), so
plan documents, the search and the policy layer can mix the three kinds
without ambiguity.

Format
------
``QuantConfig(bits, block)`` is a block-scaled integer format: values are
grouped into blocks of ``block`` elements, each block carries one power-of-two
exponent sized to its max magnitude, and elements are rounded onto that 2^lsb
grid as signed ``bits``-wide integers. Power-of-two scales keep every step of
quantize -> dequantize exactly representable in f32, so the round trip is
deterministic and bit-identical between eager and jit execution — the same
property the fixed-point accumulators are built on. ``mode="fp32"`` is the
identity format (the un-quantized reference point on the byte axis).

The emulation carries the integer payload in int8/int16 device arrays (the
resident-byte saving is real, not modeled); the per-block exponent rides as
int8. Modeled wire/resident bytes per element are ``bits/8 + 1/block``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

# Emulation floor for an all-zero block's exponent (any value works — the
# payload is all zeros — but it must be the SAME value everywhere for the
# eager/jit and cross-device bit-equality contracts).
ZERO_BLOCK_EXP = -126

# ---------------------------------------------------------------------------
# Site identity
# ---------------------------------------------------------------------------
STATE_SUFFIX = "@state"
COLL_SUFFIX = "@coll"


def site_kind(key: str) -> str:
    """Classify a site key: "state" / "collective" for the aux grammars
    above, else "gemm" (the key may still fail GemmSite.parse — kind says
    which parser is responsible, not that the key is well-formed)."""
    if key.endswith(STATE_SUFFIX):
        return "state"
    if key.endswith(COLL_SUFFIX):
        return "collective"
    return "gemm"


def _check_aux_name(name: str, who: str) -> None:
    if not name or "@" in name or "*" in name:
        raise ValueError(f"{who} name {name!r} must be non-empty and free of "
                         "'@'/'*' (dots are allowed: 'opt.m')")


@dataclasses.dataclass(frozen=True)
class StateSite:
    """Identity of one persistent-state tensor family (e.g. the Adam first
    moment across the whole parameter tree). ``namespace`` groups sites for
    attribution/wiring; the canonical key carries only the name."""

    name: str                       # "opt.m", "opt.v", "ema"
    namespace: str = "opt"

    def __post_init__(self):
        _check_aux_name(self.name, "StateSite")

    @property
    def key(self) -> str:
        return f"{self.name}{STATE_SUFFIX}"


@dataclasses.dataclass(frozen=True)
class CollectiveSite:
    """Identity of one cross-device reduction payload (e.g. the gradient
    all-reduce of the data-parallel train step)."""

    name: str                       # "grad_psum"
    namespace: str = "train"

    def __post_init__(self):
        _check_aux_name(self.name, "CollectiveSite")

    @property
    def key(self) -> str:
        return f"{self.name}{COLL_SUFFIX}"


# The train loop's canonical aux sites.
OPT_M_SITE = StateSite("opt.m")
OPT_V_SITE = StateSite("opt.v")
GRAD_PSUM_SITE = CollectiveSite("grad_psum")


# ---------------------------------------------------------------------------
# Format
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """One block-scaled integer format (or the fp32 identity).

    ``error_feedback`` only matters for collective sites: the residual of
    each quantization is carried and added back next step (1-bit-Adam-style),
    so the *time-average* of what was sent converges onto the true signal.
    """

    bits: int = 8                   # signed integer payload width
    block: int = 64                 # elements per shared exponent
    mode: str = "block"             # "block" | "fp32"
    error_feedback: bool = False

    def __post_init__(self):
        if self.mode not in ("block", "fp32"):
            raise ValueError(f"QuantConfig mode {self.mode!r}")
        if self.mode == "block":
            if not 2 <= self.bits <= 16:
                raise ValueError(f"bits={self.bits} outside the int8/int16 "
                                 "emulation range [2, 16]")
            if self.block < 1 or self.block & (self.block - 1):
                raise ValueError(f"block={self.block} must be a power of two")

    def tag(self) -> str:
        if self.mode == "fp32":
            return "fp32"
        ef = "+ef" if self.error_feedback else ""
        return f"q{self.bits}b{self.block}{ef}"

    @property
    def bytes_per_element(self) -> float:
        """Modeled resident/wire bytes per element (int payload + one int8
        exponent per block)."""
        if self.mode == "fp32":
            return 4.0
        return self.bits / 8.0 + 1.0 / self.block

    def storage_dtype(self):
        return jnp.int8 if self.bits <= 8 else jnp.int16

    def widen(self) -> "QuantConfig":
        """The next point up the fidelity ladder (the upgrade loop's
        fallback direction): more payload bits, then fp32."""
        if self.mode == "fp32":
            return self
        if self.bits < 8:
            return dataclasses.replace(self, bits=8)
        if self.bits < 16:
            return dataclasses.replace(self, bits=16)
        return QuantConfig(mode="fp32", error_feedback=self.error_feedback)


FP32_STATE = QuantConfig(mode="fp32")


def parse_quant(text: str) -> QuantConfig:
    """CLI spelling: "fp32", or "BITSxBLOCK" ("8x64"), with an optional
    "+ef" error-feedback suffix ("4x32+ef")."""
    t = text.strip().lower()
    ef = t.endswith("+ef")
    if ef:
        t = t[:-len("+ef")]
    if t == "fp32":
        return QuantConfig(mode="fp32", error_feedback=ef)
    try:
        bits, block = t.split("x")
        return QuantConfig(bits=int(bits), block=int(block),
                           error_feedback=ef)
    except (ValueError, TypeError):
        raise ValueError(
            f"bad quant format {text!r}: expected 'fp32' or 'BITSxBLOCK' "
            "like '8x64' (optional '+ef' suffix)") from None


def quant_bytes(n_elements: int, cfg: QuantConfig) -> float:
    """Modeled bytes for ``n_elements`` under ``cfg`` (whole blocks)."""
    if cfg.mode == "fp32":
        return 4.0 * n_elements
    n_blocks = -(-n_elements // cfg.block)
    return n_blocks * (cfg.block * cfg.bits / 8.0 + 1.0)


# ---------------------------------------------------------------------------
# Block quantization math
# ---------------------------------------------------------------------------
def block_exponent(amax: jax.Array) -> jax.Array:
    """int32 exponent e with 2^(e-1) <= amax < 2^e (frexp convention), so a
    ``bits``-wide integer at lsb = e - (bits-1) covers the block. Zero blocks
    land on ZERO_BLOCK_EXP; the result is clipped into int8 range."""
    _, e = jnp.frexp(amax)
    e = jnp.where(amax > 0, e, ZERO_BLOCK_EXP)
    return jnp.clip(e, -126, 127).astype(jnp.int32)


def _to_blocks(x: jax.Array, block: int):
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, block)


def block_scale(amax: jax.Array, bits: int):
    """Per-block exponent + power-of-two scale such that every magnitude up
    to ``amax`` is representable in ``bits`` signed integers WITHOUT
    clipping: lsb = e - (bits-1), with the exponent bumped one octave when
    ``amax`` itself would land past the signed limit (frexp mantissa above
    ``1 - 2^-(bits-1)``). The no-clip guarantee is what keeps error feedback
    bounded — a clipped top-of-block element would re-carry its unsent mass
    every step and grow the residual linearly, never converging. All
    comparisons are exact f32, so the choice is deterministic eager vs jit.
    Returns ``(exp, scale)`` with ``scale = exp2(exp - (bits - 1))``."""
    e = block_exponent(amax)
    lsb = e - (bits - 1)
    scale = jnp.exp2(lsb.astype(jnp.float32))
    lim = 2.0 ** (bits - 1) - 1
    e = jnp.clip(e + (amax > lim * scale).astype(jnp.int32), -126, 127)
    scale = jnp.exp2((e - (bits - 1)).astype(jnp.float32))
    return e, scale


def block_quantize(x: jax.Array, cfg: QuantConfig, *,
                   rounding: str = "nearest") -> dict:
    """-> {"q": int8/int16 (n_blocks, block), "exp": int8 (n_blocks,)}.

    ``rounding="nearest"`` rounds onto each block's 2^lsb grid. The
    ``block_scale`` exponent guarantees the block maximum itself never
    clips, so |x - dequant(quantize(x))| <= 2^lsb per element, where lsb may
    sit one octave above the frexp baseline for top-heavy blocks.
    ``rounding="up"`` rounds magnitudes away from zero — the conservative
    direction for quantities that sit in a denominator (a quantized Adam
    second moment must never *understate* curvature, or the update blows up
    by amax/eps where the true moment rounded to zero).
    """
    assert cfg.mode == "block", "fp32 mode has no quantized carrier"
    blocks = _to_blocks(x, cfg.block)
    e, scale = block_scale(jnp.max(jnp.abs(blocks), axis=1), cfg.bits)
    lim = 2.0 ** (cfg.bits - 1) - 1
    y = blocks / scale[:, None]
    if rounding == "up":
        y = jnp.sign(y) * jnp.ceil(jnp.abs(y))
    elif rounding == "nearest":
        y = jnp.round(y)
    else:
        raise ValueError(f"rounding {rounding!r}")
    q = jnp.clip(y, -lim, lim)
    return {"q": q.astype(cfg.storage_dtype()),
            "exp": e.astype(jnp.int8)}


def block_dequantize(carrier: dict, cfg: QuantConfig, shape,
                     dtype=jnp.float32) -> jax.Array:
    """Inverse of ``block_quantize`` back onto ``shape`` (drops padding).
    int * power-of-two is exact in f32, so dequantization adds no error of
    its own."""
    lsb = carrier["exp"].astype(jnp.float32) - (cfg.bits - 1)
    flat = (carrier["q"].astype(jnp.float32) * jnp.exp2(lsb)[:, None]
            ).reshape(-1)
    n = math.prod(shape) if shape else 1
    return flat[:n].reshape(shape).astype(dtype)


def quantize_roundtrip(x: jax.Array, cfg: QuantConfig) -> jax.Array:
    """x projected onto the format's representable grid (what a reader of
    the stored/sent payload reconstructs). Identity for fp32 mode."""
    if cfg.mode == "fp32":
        return x.astype(jnp.float32)
    return block_dequantize(block_quantize(x, cfg), cfg, x.shape, x.dtype)
