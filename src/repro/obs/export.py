"""repro.obs.export — Chrome-trace/Perfetto JSON export for recorded spans.

The Trace Event Format's complete-event (``"ph": "X"``) flavour: one object
per finished span with microsecond ``ts``/``dur``. The output loads directly
in ``chrome://tracing`` and https://ui.perfetto.dev — the launch drivers
write it via ``--trace-out trace.json``.
"""

from __future__ import annotations

import json

from .spans import recorder


def chrome_trace(events=None) -> dict:
    """Render span events (default: the process recorder's) as a Chrome
    trace document. Span attrs become the event's ``args`` payload, shown in
    the viewer's detail pane."""
    from_recorder = events is None
    if from_recorder:
        events = recorder().events()
    trace_events = [{
        "name": ev["name"],
        "cat": ev["name"].split(".", 1)[0],
        "ph": "X",
        "ts": ev["ts_us"],
        "dur": ev["dur_us"],
        "pid": ev["pid"],
        "tid": ev["tid"],
        "args": ev.get("args", {}),
    } for ev in events]
    doc = {"traceEvents": trace_events, "displayTimeUnit": "ms"}
    dropped = recorder().dropped if from_recorder else 0
    if dropped:
        doc["otherData"] = {"dropped_spans": dropped}
    return doc


def save_chrome_trace(path: str, events=None) -> int:
    """Write the trace document; returns the event count."""
    doc = chrome_trace(events)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    return len(doc["traceEvents"])


def start_metrics_server(port: int, registry=None):
    """Serve the unified registry over HTTP on a daemon thread (stdlib only):
    ``/metrics`` is Prometheus text exposition, ``/metrics.json`` the typed
    snapshot. Returns the ``http.server`` instance — call ``.shutdown()`` to
    stop; pass ``port=0`` to bind an ephemeral port (``server_port`` has the
    real one)."""
    import http.server
    import threading

    from .registry import default_registry
    reg = registry if registry is not None else default_registry()

    class Handler(http.server.BaseHTTPRequestHandler):
        def do_GET(self):
            if self.path.startswith("/metrics.json"):
                body = json.dumps(reg.snapshot(), indent=1,
                                  sort_keys=True).encode()
                ctype = "application/json"
            elif self.path.startswith("/metrics"):
                body = reg.exposition().encode()
                ctype = "text/plain; version=0.0.4"
            else:
                self.send_error(404)
                return
            self.send_response(200)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):           # keep the CLI output clean
            pass

    srv = http.server.ThreadingHTTPServer(("127.0.0.1", port), Handler)
    threading.Thread(target=srv.serve_forever, daemon=True,
                     name="repro-obs-metrics").start()
    return srv
