"""repro.obs.registry — the unified metrics registry.

One process-wide, thread-safe home for every operational number the system
emits: typed counter/gauge/histogram families with labels, a Prometheus-style
text exposition, and a JSON snapshot. The scattered hand-rolled stat dicts
(``PlanCacheStats``, batcher/pool ``stats()``, serving-CLI summaries) are
views over this registry, so there is exactly one way to read system health.

Zero dependencies beyond the stdlib by design: the registry must be importable
from ``repro.core.dispatch`` (the lowest layer) without dragging jax in, and
must keep working in stripped-down deployment images.

Conventions
-----------
* Metric names are ``repro_``-prefixed snake_case; counters end in ``_total``,
  histograms carry a unit suffix (``_seconds``).
* Label values are stringified; a family's label *names* are fixed at creation
  and re-registration with a different shape is a :class:`MetricError` — the
  registry is the schema.
* ``Registry.reset()`` zeroes values but keeps families, so long-lived handles
  held by components survive test isolation. Counters are therefore only
  monotonic *between* resets; exposition notes this is a process-local
  registry, not a durable time series.
"""

from __future__ import annotations

import json
import threading

REGISTRY_KIND = "repro.obs.MetricsSnapshot"
REGISTRY_VERSION = 1

# latency-flavoured default buckets (seconds): sub-ms dispatch up to minute-
# scale AOT compiles land in distinct buckets on CPU CI machines
DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)


class MetricError(ValueError):
    """Schema violation: kind/label mismatch or unknown label key."""


class Metric:
    """One metric family: a name, fixed label names, and per-labelset values.

    Subclasses define the value shape; all mutation goes through the owning
    registry's lock so concurrent serving/train threads and jax host-callback
    workers can hit the same family safely.
    """

    kind = "untyped"

    def __init__(self, registry: "Registry", name: str, help: str = "",
                 labels: tuple = ()):
        self._registry = registry
        self._lock = registry._lock
        self.name = name
        self.help = help
        self.label_names = tuple(labels)
        self._values: dict = {}

    def _key(self, labels: dict) -> tuple:
        if set(labels) != set(self.label_names):
            raise MetricError(
                f"{self.name}: labels {sorted(labels)} do not match the "
                f"registered label names {sorted(self.label_names)}")
        return tuple(str(labels[k]) for k in self.label_names)

    def _labelset(self, key: tuple) -> dict:
        return dict(zip(self.label_names, key))

    def clear(self) -> None:
        with self._lock:
            self._values.clear()

    # subclass API ---------------------------------------------------------
    def _sample_json(self, key: tuple, value) -> dict:
        raise NotImplementedError

    def _sample_text(self, key: tuple, value) -> list:
        raise NotImplementedError

    def to_json(self) -> dict:
        with self._lock:
            items = sorted(self._values.items())
            return {"kind": self.kind, "help": self.help,
                    "label_names": list(self.label_names),
                    "values": [self._sample_json(k, v) for k, v in items]}

    def _label_text(self, key: tuple, extra: tuple = ()) -> str:
        pairs = list(zip(self.label_names, key)) + list(extra)
        if not pairs:
            return ""
        body = ",".join(f'{k}="{_escape(v)}"' for k, v in pairs)
        return "{" + body + "}"


def _escape(v) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"').replace("\n",
                                                                   r"\n")


class Counter(Metric):
    """Monotonic event count (until ``Registry.reset()``)."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise MetricError(f"{self.name}: counters only go up "
                              f"(inc({amount}))")
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        key = self._key(labels)
        with self._lock:
            return self._values.get(key, 0.0)

    def total(self) -> float:
        """Sum over every labelset — 'how many, regardless of breakdown'."""
        with self._lock:
            return sum(self._values.values())

    def _sample_json(self, key, value) -> dict:
        return {"labels": self._labelset(key), "value": value}

    def _sample_text(self, key, value) -> list:
        return [f"{self.name}{self._label_text(key)} {_fmt(value)}"]


class Gauge(Metric):
    """Point-in-time value (set/add; last write wins)."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._values[key] = float(value)

    def add(self, amount: float, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels):
        key = self._key(labels)
        with self._lock:
            return self._values.get(key)

    def _sample_json(self, key, value) -> dict:
        return {"labels": self._labelset(key), "value": value}

    def _sample_text(self, key, value) -> list:
        return [f"{self.name}{self._label_text(key)} {_fmt(value)}"]


class Histogram(Metric):
    """Cumulative-bucket distribution (Prometheus semantics: each ``le``
    bucket counts observations ≤ its bound, plus ``+Inf``/sum/count)."""

    kind = "histogram"

    def __init__(self, registry, name, help="", labels=(),
                 buckets: tuple = DEFAULT_BUCKETS):
        super().__init__(registry, name, help, labels)
        self.buckets = tuple(sorted(float(b) for b in buckets))

    def observe(self, value: float, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            state = self._values.get(key)
            if state is None:
                state = {"counts": [0] * (len(self.buckets) + 1),
                         "sum": 0.0, "count": 0}
                self._values[key] = state
            for i, b in enumerate(self.buckets):
                if value <= b:
                    state["counts"][i] += 1
                    break
            else:
                state["counts"][-1] += 1
            state["sum"] += value
            state["count"] += 1

    def value(self, **labels):
        key = self._key(labels)
        with self._lock:
            state = self._values.get(key)
            if state is None:
                return None
            return {"sum": state["sum"], "count": state["count"]}

    def _sample_json(self, key, state) -> dict:
        cum, buckets = 0, {}
        for b, n in zip(self.buckets, state["counts"]):
            cum += n
            buckets[str(b)] = cum
        buckets["+Inf"] = state["count"]
        return {"labels": self._labelset(key), "count": state["count"],
                "sum": state["sum"], "buckets": buckets}

    def _sample_text(self, key, state) -> list:
        lines, cum = [], 0
        for b, n in zip(self.buckets, state["counts"]):
            cum += n
            lines.append(f"{self.name}_bucket"
                         f"{self._label_text(key, (('le', _fmt(b)),))} {cum}")
        lines.append(f"{self.name}_bucket"
                     f"{self._label_text(key, (('le', '+Inf'),))} "
                     f"{state['count']}")
        lines.append(f"{self.name}_sum{self._label_text(key)} "
                     f"{_fmt(state['sum'])}")
        lines.append(f"{self.name}_count{self._label_text(key)} "
                     f"{state['count']}")
        return lines


def _fmt(v) -> str:
    if isinstance(v, float) and v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


class Registry:
    """A named collection of metric families with atomic get-or-create.

    ``counter``/``gauge``/``histogram`` are idempotent: the first call fixes
    the family's kind + label names, later calls return the same handle and
    any mismatch is a loud :class:`MetricError` rather than a silently forked
    schema.
    """

    def __init__(self):
        self._lock = threading.RLock()
        self._metrics: dict = {}

    def _get_or_create(self, cls, name, help, labels, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(self, name, help=help, labels=tuple(labels), **kw)
                self._metrics[name] = m
                return m
            if not isinstance(m, cls):
                raise MetricError(f"{name} is registered as a {m.kind}, "
                                  f"not a {cls.kind}")
            if m.label_names != tuple(labels):
                raise MetricError(
                    f"{name} is registered with labels {m.label_names}, "
                    f"not {tuple(labels)}")
            return m

    def counter(self, name: str, help: str = "", labels=()) -> Counter:
        return self._get_or_create(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", labels=()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "", labels=(),
                  buckets: tuple = DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_create(Histogram, name, help, labels,
                                   buckets=buckets)

    def get(self, name: str):
        with self._lock:
            return self._metrics.get(name)

    def names(self) -> list:
        with self._lock:
            return sorted(self._metrics)

    def reset(self) -> None:
        """Zero every family's values, keeping the families (and any handles
        components hold) alive — the test-isolation primitive."""
        with self._lock:
            for m in self._metrics.values():
                m.clear()

    # -- exposition --------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-able snapshot of every family (the ``--metrics-dump``
        payload; ``scripts/check_obs_snapshot.py`` gates on this shape)."""
        with self._lock:
            metrics = {name: m.to_json()
                       for name, m in sorted(self._metrics.items())}
        return {"kind": REGISTRY_KIND, "version": REGISTRY_VERSION,
                "metrics": metrics}

    def snapshot_json(self, indent: int = 1) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    def exposition(self) -> str:
        """Prometheus text exposition (process-local; counters reset with
        ``Registry.reset()``, so scrapers should treat restarts normally)."""
        lines = []
        with self._lock:
            metrics = sorted(self._metrics.items())
        for name, m in metrics:
            if m.help:
                lines.append(f"# HELP {name} {_escape(m.help)}")
            lines.append(f"# TYPE {name} {m.kind}")
            with self._lock:
                items = sorted(m._values.items())
            for key, value in items:
                lines.extend(m._sample_text(key, value))
        return "\n".join(lines) + ("\n" if lines else "")


# The process default: components resolve this unless handed an explicit
# registry (tests pass their own for isolation).
_DEFAULT = Registry()


def default_registry() -> Registry:
    return _DEFAULT
