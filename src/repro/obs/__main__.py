"""``python -m repro.obs`` — dump the process metrics registry.

Default output is the Prometheus text exposition; ``--json`` emits the JSON
snapshot (the same document ``--metrics-dump`` writes from the launch
drivers and ``scripts/check_obs_snapshot.py`` gates on). A fresh interpreter
has an empty registry, so this entry point is mostly useful embedded after
in-process work (``python -m repro.obs --demo`` shows the formats on a tiny
synthetic workload).
"""

from __future__ import annotations

import argparse
import sys

from repro.obs.registry import default_registry


def _demo(reg) -> None:
    c = reg.counter("repro_demo_requests_total", "demo requests",
                    ("workload", "outcome"))
    c.inc(3, workload="chat", outcome="completed")
    c.inc(1, workload="chat", outcome="rejected")
    g = reg.gauge("repro_demo_live_requests", "demo live requests")
    g.set(2)
    h = reg.histogram("repro_demo_latency_seconds", "demo latency",
                      ("workload",))
    for v in (0.004, 0.011, 0.270):
        h.observe(v, workload="chat")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="dump the repro.obs metrics registry")
    ap.add_argument("--json", action="store_true",
                    help="JSON snapshot instead of Prometheus text")
    ap.add_argument("--out", default=None,
                    help="write to this path instead of stdout")
    ap.add_argument("--demo", action="store_true",
                    help="populate a few demo metrics first (format tour)")
    args = ap.parse_args(argv)

    reg = default_registry()
    if args.demo:
        _demo(reg)
    text = reg.snapshot_json() + "\n" if args.json else reg.exposition()
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    else:
        sys.stdout.write(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
