"""repro.obs.monitor — live calibration-envelope monitoring per GEMM site.

Every guarantee a deployed ``PrecisionPlan`` makes (validated correct bits,
overflow-free accumulation, modeled energy) was established offline against a
calibration trace. This module makes those claims *checkable at runtime*: a
cheap, jit-compatible monitor installs through the same dispatch trace-hook
seam ``CalibrationTrace`` uses and, per :class:`~repro.core.dispatch.GemmSite`,

  * accumulates live operand exponent ranges and MAC counts,
  * counts overflow events — accumulator wrap risk (the live msb requirement
    exceeding the deployed ⟨ovf,msb,lsb⟩ capacity) and non-finite outputs,
  * tracks a cancellation proxy (live product bound vs observed |out|),

then compares the fold against the plan's recorded calibration envelope
(``meta["envelope"]``, keyed by ``trace_fingerprint``) to classify each site:

  ``inside``     live traffic within the traced operand ranges with msb
                 headroom beyond the margin — every offline claim stands;
  ``near-edge``  live exponents beyond the traced range (plus grace bits) or
                 msb headroom within the margin — claims still hold but the
                 deployment is leaving its validated envelope;
  ``violated``   an overflow event fired or the live msb requirement exceeds
                 the deployed accumulator capacity — recorded
                 ``validated_bits`` are no longer trustworthy for this
                 traffic. A pluggable alert sink makes this a loud,
                 attributed event instead of silent wrong bits.

Device-side cost is a handful of fused reductions per dispatched GEMM plus
one ``jax.debug.callback`` (the calibration-hook recipe) — staged at trace
time, so monitored functions compile once (``trace_count`` stays 1) and the
callbacks re-fire per execution without retracing.
"""

from __future__ import annotations

import contextlib
import math
import threading
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import dispatch
from repro.numerics.trace import _as_float, cfg_capacity
from repro.obs import registry as _registry

ENVELOPE_VERSION = 1

# EnvelopeStatus values (strings, so snapshots/JSON read naturally; the
# registry gauge uses the code below)
INSIDE = "inside"
NEAR_EDGE = "near-edge"
VIOLATED = "violated"
UNMONITORED = "no-envelope"

STATUS_CODE = {UNMONITORED: -1, INSIDE: 0, NEAR_EDGE: 1, VIOLATED: 2}


def _floor_log2(v: float) -> Optional[int]:
    if not (v > 0.0) or not math.isfinite(v):
        return None
    return math.frexp(v)[1] - 1


class SiteStats:
    """Host-side fold of one site's live traffic."""

    __slots__ = ("site", "calls", "macs", "max_k", "a_exp_min", "a_exp_max",
                 "b_exp_min", "b_exp_max", "out_exp_max", "cancel_bits_max",
                 "wrap_events", "nonfinite_events", "msb_capacity")

    def __init__(self, site: str):
        self.site = site
        self.calls = 0
        self.macs = 0
        self.max_k = 0
        self.a_exp_min: Optional[int] = None
        self.a_exp_max: Optional[int] = None
        self.b_exp_min: Optional[int] = None
        self.b_exp_max: Optional[int] = None
        self.out_exp_max: Optional[int] = None
        self.cancel_bits_max = 0.0
        self.wrap_events = 0
        self.nonfinite_events = 0
        self.msb_capacity: Optional[int] = None

    @property
    def prod_exp_max(self) -> Optional[int]:
        if self.a_exp_max is None or self.b_exp_max is None:
            return None
        return self.a_exp_max + self.b_exp_max + 1

    @property
    def msb_required(self) -> Optional[int]:
        """Live analogue of ``SiteProfile.msb_required``: the accumulator msb
        this traffic needs to be provably overflow-free."""
        p = self.prod_exp_max
        if p is None:
            return None
        growth = max(1, math.ceil(math.log2(max(self.max_k, 2))))
        return p + growth + 1

    def to_dict(self) -> dict:
        return {"calls": self.calls, "macs": self.macs, "max_k": self.max_k,
                "a_exp": [self.a_exp_min, self.a_exp_max],
                "b_exp": [self.b_exp_min, self.b_exp_max],
                "out_exp_max": self.out_exp_max,
                "msb_required": self.msb_required,
                "msb_capacity": self.msb_capacity,
                "cancellation_bits": round(self.cancel_bits_max, 2),
                "wrap_events": self.wrap_events,
                "nonfinite_events": self.nonfinite_events}


def _exp_outside(lo, hi, env_range, grace: int, check_lo: bool) -> bool:
    """True when a live exponent range leaves the traced one by more than
    ``grace`` bits (ordinary data variation stays inside the grace band).

    The high side always counts — larger operands than calibrated are the
    overflow direction. The low side only matters on fixed-point sites
    (``check_lo``: the deployed config has a finite lsb, so operands smaller
    than traced risk quantizing to zero); on native float sites, smaller
    operands are harmless and would make same-distribution traffic flap."""
    if not env_range:
        return False
    elo, ehi = env_range
    if hi is not None and ehi is not None and hi > ehi + grace:
        return True
    if check_lo and lo is not None and elo is not None and lo < elo - grace:
        return True
    return False


class NumericsMonitor:
    """Per-site live monitor + envelope comparator.

    ``envelope`` is a plan's ``meta["envelope"]`` document (or any dict of
    the same shape); sites absent from it report ``no-envelope`` rather than
    guessing. ``margin_bits`` is the near-edge headroom threshold against
    accumulator capacity; ``exp_grace`` the tolerated excursion (in exponent
    bits) beyond the traced operand ranges before a site leaves ``inside``.

    Use as a context manager, or ``install()``/``uninstall()`` for
    long-running servers. Multiple monitors (and a concurrent
    ``calibrate()``) co-exist: installation goes through
    ``dispatch.add_trace_hook``.
    """

    def __init__(self, envelope: Optional[dict] = None, *,
                 registry: Optional[_registry.Registry] = None,
                 margin_bits: int = 2, exp_grace: int = 2,
                 alert_sink=None):
        self._lock = threading.Lock()
        self._stats: dict = {}
        self._alerted: dict = {}
        self.envelope = dict((envelope or {}).get("sites", envelope or {}))
        self.margin_bits = margin_bits
        self.exp_grace = exp_grace
        self.alert_sinks = [alert_sink] if alert_sink else []
        self._remove = None
        reg = registry or _registry.default_registry()
        self.registry = reg
        self._calls = reg.counter(
            "repro_monitor_calls_total",
            "GEMM dispatches folded by the numerics monitor", ("site",))
        self._macs = reg.counter(
            "repro_monitor_macs_total",
            "MACs observed by the numerics monitor", ("site",))
        self._overflow = reg.counter(
            "repro_overflow_events_total",
            "overflow/saturation events (accumulator wrap risk, non-finite "
            "outputs, quantized-collective spillover)", ("site", "source"))
        self._status_g = reg.gauge(
            "repro_envelope_status",
            "per-site envelope status (0 inside, 1 near-edge, 2 violated, "
            "-1 no envelope)", ("site",))

    # -- alerting ----------------------------------------------------------
    def add_alert_sink(self, sink) -> None:
        """``sink(site, status, detail)`` fires on every status escalation
        (inside -> near-edge -> violated), once per site per level."""
        self.alert_sinks.append(sink)

    def _maybe_alert(self, site: str) -> None:
        # called with self._lock NOT held (sinks are user code)
        info = self.status(site)
        status = info["status"]
        rank = STATUS_CODE.get(status, -1)
        with self._lock:
            prev = self._alerted.get(site, 0)
            if rank <= prev:
                return
            self._alerted[site] = rank
        if rank >= STATUS_CODE[NEAR_EDGE]:
            for sink in list(self.alert_sinks):
                sink(site, status, info)

    # -- recording (jax.debug.callback target) -----------------------------
    def _record(self, site, batch, m, n, k, msb_cap,
                a_max, a_min, b_max, b_min, o_max, finite):
        # Materialize BEFORE taking the lock: callbacks arrive on both the
        # main thread (eager) and the runtime's host-callback worker
        # (compiled regions); a device sync under the lock deadlocks (see
        # CalibrationTrace._record for the full story).
        a_max, a_min = float(a_max), float(a_min)
        b_max, b_min = float(b_max), float(b_min)
        o_max, finite = float(o_max), bool(finite)

        ea_hi, ea_lo = _floor_log2(a_max), _floor_log2(a_min)
        eb_hi, eb_lo = _floor_log2(b_max), _floor_log2(b_min)
        eo_hi = _floor_log2(o_max)
        growth = max(1, math.ceil(math.log2(max(k, 2))))
        msb_req = (None if ea_hi is None or eb_hi is None
                   else ea_hi + eb_hi + 1 + growth + 1)
        wrapped = (msb_cap is not None and msb_req is not None
                   and msb_req > msb_cap)
        cancel = 0.0
        if o_max > 0.0 and a_max > 0.0 and b_max > 0.0:
            ratio = a_max * b_max * max(k, 1) / o_max
            if ratio > 0.0 and math.isfinite(ratio):   # inf/inf -> nan guard
                cancel = max(0.0, math.log2(ratio))

        with self._lock:
            st = self._stats.get(site)
            if st is None:
                st = self._stats[site] = SiteStats(site)
            st.calls += 1
            st.macs += batch * m * n * k
            st.max_k = max(st.max_k, k)
            st.msb_capacity = msb_cap
            for attr, v, hi in (("a_exp_max", ea_hi, True),
                                ("a_exp_min", ea_lo, False),
                                ("b_exp_max", eb_hi, True),
                                ("b_exp_min", eb_lo, False),
                                ("out_exp_max", eo_hi, True)):
                if v is None:
                    continue
                cur = getattr(st, attr)
                setattr(st, attr, v if cur is None
                        else (max(cur, v) if hi else min(cur, v)))
            st.cancel_bits_max = max(st.cancel_bits_max, cancel)
            if wrapped:
                st.wrap_events += 1
            if not finite:
                st.nonfinite_events += 1
        self._calls.inc(site=site)
        self._macs.inc(batch * m * n * k, site=site)
        if wrapped:
            self._overflow.inc(site=site, source="gemm_wrap")
        if not finite:
            self._overflow.inc(site=site, source="gemm_nonfinite")
        self._status_g.set(STATUS_CODE[self.status(site)["status"]],
                           site=site)
        self._maybe_alert(site)

    def hook(self, site, cfg, a, b, out):
        """Dispatch trace hook: stage the reductions + one host callback.
        Runs at trace time only; the staged callback re-fires per execution."""
        if a.ndim < 2 or b.ndim < 2:
            return
        m, k = a.shape[-2], a.shape[-1]
        n = b.shape[-1]
        batch_dims = jnp.broadcast_shapes(a.shape[:-2], b.shape[:-2])
        batch = math.prod(batch_dims) if batch_dims else 1
        msb_cap, _ = cfg_capacity(cfg)

        af = _as_float(cfg.fmt, a)                   # posit carriers decode
        bf = _as_float(cfg.fmt, b)
        of = out.astype(jnp.float32)

        def absmax(x):
            return jnp.max(jnp.abs(x))

        def absmin_nz(x):
            ax = jnp.abs(x)
            return jnp.min(jnp.where(ax > 0, ax, jnp.inf))

        # Low-side tracking (smallest nonzero magnitude) only matters on
        # fixed-point sites — a finite envelope lsb, where tiny operands risk
        # quantizing to zero. Native float sites skip those two reductions
        # (the where+min pair is the hook's most expensive staged op).
        env = self._site_envelope(site)
        need_lo = env is not None and env.get("lsb") is not None
        zero = jnp.float32(0.0)
        jax.debug.callback(
            partial(self._record, site, batch, m, n, k, msb_cap),
            absmax(af), absmin_nz(af) if need_lo else zero,
            absmax(bf), absmin_nz(bf) if need_lo else zero,
            absmax(of), jnp.all(jnp.isfinite(of)))

    # -- installation ------------------------------------------------------
    def install(self) -> "NumericsMonitor":
        if self._remove is None:
            self._remove = dispatch.add_trace_hook(self.hook)
        return self

    def uninstall(self) -> None:
        if self._remove is not None:
            self._remove()
            self._remove = None

    def __enter__(self) -> "NumericsMonitor":
        return self.install()

    def __exit__(self, *exc) -> None:
        self.uninstall()
        jax.effects_barrier()       # land in-flight records before readers

    # -- classification ----------------------------------------------------
    def _site_envelope(self, site: str) -> Optional[dict]:
        env = self.envelope.get(site)
        if env is None and "@" in site:
            # backward/aux-qualified keys may monitor under a fwd-only
            # envelope; no guess — absent means absent
            return None
        return env

    def status(self, site: str) -> dict:
        """Classify one site's live fold against its envelope entry."""
        with self._lock:
            st = self._stats.get(site)
            live = st.to_dict() if st is not None else None
        env = self._site_envelope(site)
        if env is None:
            return {"site": site, "status": UNMONITORED, "live": live,
                    "detail": "no calibration envelope for this site"}
        if live is None:
            return {"site": site, "status": INSIDE, "envelope": env,
                    "live": None, "detail": "no live traffic yet"}

        detail = []
        status = INSIDE
        if live["wrap_events"] or live["nonfinite_events"]:
            status = VIOLATED
            detail.append(f"{live['wrap_events']} accumulator-wrap and "
                          f"{live['nonfinite_events']} non-finite events")
        msb_cap = env.get("msb")
        msb_req = live["msb_required"]
        if status != VIOLATED and msb_cap is not None and \
                msb_req is not None:
            if msb_req > msb_cap:
                status = VIOLATED
                detail.append(f"live msb requirement {msb_req} exceeds "
                              f"deployed capacity {msb_cap}")
            elif msb_req > msb_cap - self.margin_bits:
                status = NEAR_EDGE
                detail.append(f"msb headroom {msb_cap - msb_req} bits "
                              f"< margin {self.margin_bits}")
        if status == INSIDE:
            check_lo = env.get("lsb") is not None
            for op, rng in (("a", env.get("a_exp")), ("b", env.get("b_exp"))):
                lo, hi = live[f"{op}_exp"]
                if _exp_outside(lo, hi, rng, self.exp_grace, check_lo):
                    status = NEAR_EDGE
                    detail.append(
                        f"{op} exponents [{lo},{hi}] left the traced range "
                        f"{rng} (+{self.exp_grace} grace bits)")
        return {"site": site, "status": status, "envelope": env,
                "live": live,
                "detail": "; ".join(detail) or "within calibrated envelope"}

    def statuses(self) -> dict:
        """Every known site (live or enveloped) -> status document."""
        with self._lock:
            sites = set(self._stats)
        sites |= set(self.envelope)
        return {s: self.status(s) for s in sorted(sites)}

    def worst_status(self) -> str:
        worst = INSIDE
        for info in self.statuses().values():
            if STATUS_CODE[info["status"]] > STATUS_CODE[worst]:
                worst = info["status"]
        return worst

    def overflow_events(self) -> int:
        with self._lock:
            return sum(s.wrap_events + s.nonfinite_events
                       for s in self._stats.values())

    def snapshot(self) -> dict:
        """JSON-able monitor summary (embedded in ``--metrics-dump``)."""
        return {"kind": "repro.obs.MonitorSnapshot",
                "version": ENVELOPE_VERSION,
                "worst_status": self.worst_status(),
                "overflow_events": self.overflow_events(),
                "sites": {s: {k: v for k, v in info.items() if k != "site"}
                          for s, info in self.statuses().items()}}


@contextlib.contextmanager
def monitoring(plan=None, *, envelope: Optional[dict] = None, **kw):
    """Monitor every dispatched GEMM in the block against ``plan``'s
    calibration envelope (``plan.meta['envelope']``); yields the monitor for
    status queries after (or during) the block."""
    if envelope is None and plan is not None:
        envelope = (getattr(plan, "meta", None) or {}).get("envelope")
    mon = NumericsMonitor(envelope, **kw)
    with mon:
        yield mon
