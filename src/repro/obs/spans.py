"""repro.obs.spans — lightweight trace spans for serving and training.

A span is a named, attributed time interval: ``with span("prefill", plan=...,
bucket=...):`` for scoped work, or ``sp = start_span(...); ...; sp.end()``
for lifecycles that cross function boundaries (a serving request lives from
``submit`` to harvest across many ``run()`` iterations). Completed spans land
in a bounded in-process recorder and export as Chrome-trace/Perfetto JSON via
:mod:`repro.obs.export` (``--trace-out trace.json`` on the launch drivers;
open in ``chrome://tracing`` or https://ui.perfetto.dev).

Cost model: recording is a perf_counter pair, a dict, and a deque append —
cheap enough to leave on per decode step. The recorder is a ring buffer
(default 20k events) so long-running servers never grow without bound; the
drop count is reported so truncation is visible, not silent.

Energy attribution: :func:`plan_energy_per_token` folds a deployed
``PrecisionPlan``'s per-site MAC counts through ``core.energy.gemm_power``
into joules per token, so harvest-time spans (and the
``repro_serving_energy_joules_total`` counter) carry a live energy meter per request
class — the paper's modeled-energy axis, running against production traffic.
"""

from __future__ import annotations

import contextlib
import os
import threading
import time
from collections import deque

_T0 = time.perf_counter()          # process-relative epoch for trace ts


def _now_us() -> float:
    return (time.perf_counter() - _T0) * 1e6


class SpanRecorder:
    """Bounded, thread-safe store of completed span events."""

    def __init__(self, limit: int = 20000):
        self._lock = threading.Lock()
        self._events: deque = deque(maxlen=limit)
        self.dropped = 0
        self.enabled = True

    def record(self, event: dict) -> None:
        if not self.enabled:
            return
        with self._lock:
            if len(self._events) == self._events.maxlen:
                self.dropped += 1
            self._events.append(event)

    def events(self) -> list:
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0


_RECORDER = SpanRecorder()
_TLS = threading.local()


def recorder() -> SpanRecorder:
    return _RECORDER


def _stack() -> list:
    st = getattr(_TLS, "stack", None)
    if st is None:
        st = _TLS.stack = []
    return st


class Span:
    """One in-flight interval. ``end()`` is idempotent; extra keyword args
    to ``end`` merge into the recorded attributes (steps, tokens, energy)."""

    __slots__ = ("name", "args", "_t0", "_ts_us", "_tid", "_ended",
                 "_recorder", "_on_stack")

    def __init__(self, name: str, args: dict, rec: SpanRecorder,
                 on_stack: bool):
        self.name = name
        self.args = args
        self._recorder = rec
        self._t0 = time.perf_counter()
        self._ts_us = _now_us()
        self._tid = threading.get_ident()
        self._ended = False
        self._on_stack = on_stack

    @property
    def elapsed(self) -> float:
        return time.perf_counter() - self._t0

    def annotate(self, **kw) -> "Span":
        self.args.update(kw)
        return self

    def end(self, **kw) -> float:
        """Close the span, record it, return its duration in seconds."""
        dur = self.elapsed
        if self._ended:
            return dur
        self._ended = True
        if kw:
            self.args.update(kw)
        if self._on_stack:
            st = _stack()
            if st and st[-1] is self:
                st.pop()
        self._recorder.record({
            "name": self.name,
            "ts_us": self._ts_us,
            "dur_us": dur * 1e6,
            "pid": os.getpid(),
            "tid": self._tid,
            "args": {k: v for k, v in self.args.items() if v is not None},
        })
        return dur


def start_span(name: str, **args) -> Span:
    """Open a span whose end crosses scopes (request lifecycles). Manually
    started spans do not join the thread-local nesting stack — nesting is a
    lexical-scope concept and these are not lexically scoped."""
    return Span(name, dict(args), _RECORDER, on_stack=False)


@contextlib.contextmanager
def span(name: str, **args):
    """Scoped span; nests via a thread-local stack (``current_span()`` lets
    inner code annotate the enclosing interval)."""
    sp = Span(name, dict(args), _RECORDER, on_stack=True)
    _stack().append(sp)
    try:
        yield sp
    finally:
        sp.end()


def current_span():
    st = _stack()
    return st[-1] if st else None


# ---------------------------------------------------------------------------
# energy attribution
# ---------------------------------------------------------------------------
def plan_energy_per_token(plan) -> float:
    """Joules/token a deployed ``PrecisionPlan`` models: each GEMM site's
    traced MAC count folded through ``core.energy.gemm_power`` for the site's
    ⟨format, accumulator⟩, divided by the calibration token count recorded in
    ``meta["envelope"]["traced_tokens"]``. Returns 0.0 when the plan predates
    envelopes (no traced token count → no honest per-token rate)."""
    env = (plan.meta or {}).get("envelope") or {}
    tokens = env.get("traced_tokens")
    if not tokens:
        return 0.0
    from repro.core.energy import gemm_power   # lazy: keep obs import-light
    total = 0.0
    for s in plan.gemm_sites():
        if s.energy_j is not None:
            total += s.energy_j
        elif s.macs:
            total += gemm_power(s.cfg.fmt, s.cfg.acc).energy_joules(s.macs)
    return total / float(tokens)
