# repro.obs — production numerics observability.
#
# Three pillars, one import:
#   registry - unified typed metrics (counters/gauges/histograms with labels,
#              Prometheus text exposition + JSON snapshot); every scattered
#              stats() dict in serving/launch/dispatch is a view over it
#   monitor  - live calibration-envelope monitoring per GEMM site through the
#              dispatch trace-hook seam: inside / near-edge / violated, with
#              overflow counting and pluggable alert sinks
#   spans    - lightweight trace spans (serving request lifecycle, train
#              steps, AOT compiles) exporting Chrome-trace/Perfetto JSON,
#              with per-plan energy attribution
#
# ``registry``/``spans`` import eagerly (stdlib-only, safe from the lowest
# layers — core.dispatch mirrors its plan-cache stats here). ``monitor`` and
# ``export`` resolve lazily: monitor pulls in jax + dispatch, and eager
# loading would cycle through core.dispatch's own import of this package.
from .registry import (Counter, Gauge, Histogram, MetricError, Registry,
                       default_registry)
from .spans import (Span, SpanRecorder, current_span, plan_energy_per_token,
                    recorder, span, start_span)

_LAZY = {
    "monitor": ".monitor", "export": ".export",
    "NumericsMonitor": ".monitor", "monitoring": ".monitor",
    "SiteStats": ".monitor", "cfg_capacity": ".monitor",
    "INSIDE": ".monitor", "NEAR_EDGE": ".monitor", "VIOLATED": ".monitor",
    "UNMONITORED": ".monitor", "STATUS_CODE": ".monitor",
    "chrome_trace": ".export", "save_chrome_trace": ".export",
    "start_metrics_server": ".export",
}

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricError", "Registry",
    "default_registry",
    "Span", "SpanRecorder", "current_span", "plan_energy_per_token",
    "recorder", "span", "start_span",
    *sorted(set(_LAZY) - {"monitor", "export"}),
]


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")
    import importlib
    module = importlib.import_module(mod, __name__)
    if name in ("monitor", "export"):
        return module
    return getattr(module, name)
