"""Transformer layer substrate. Every matmul routes through the paper's BLAS
dispatch layer (repro.core.dispatch.gemm) so numerics policies apply
transparently to the whole zoo.

Sites are threaded as plain strings (``site + "_qk"`` composition below);
``GemmSite.parse`` in the dispatch layer lifts them to structured identities,
and differentiating through any of these layers dispatches each backward GEMM
under its own phase-qualified site (``attn_qk@bwd.dA`` / ``@bwd.dB``) — so a
PrecisionPlan can give training gradients wider numerics than the forward
pass without this file changing at all."""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import dispatch

Array = jax.Array


# ---------------------------------------------------------------------------
# Distribution context: optional mesh + constraint helper
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Distribution:
    mesh: object = None                       # jax.sharding.Mesh | None
    dp_axes: tuple = ("data",)                # batch axes (may include "pod")
    tp_axis: Optional[str] = "model"          # tensor/sequence-parallel axis
    # MLP activation pattern (§Perf hillclimb #2):
    #  "megatron": x gathered over tp, f-sharded compute, reduce at output
    #  "sp":       x stays sequence-sharded, weights ZeRO-gathered per layer
    #              (no per-layer activation collectives on the tp axis)
    mlp_pattern: str = "sp"
    # decode_tp profile (§Perf hillclimb: weights-stay-put serving): MoE
    # weights are sharded over the JOINT (dp..., tp) axes and activations
    # replicated; moe_block psums over all axes instead of gathering weights.
    joint_tp: bool = False
    # NumericsPolicy riding with the distribution: launch profiles carry the
    # deployed plan's policy here so make_train_step / serve pick it up
    # without a separate argument (None = caller's ambient policy).
    numerics_policy: object = None

    @property
    def dp(self):
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]

    def constrain(self, x: Array, *spec) -> Array:
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, P(*spec)))


LOCAL = Distribution()


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------
def rms_norm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def layer_norm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps) * scale + bias).astype(x.dtype)


def activate(x: Array, kind: str) -> Array:
    return jax.nn.silu(x) if kind == "silu" else jax.nn.gelu(x)


# ---------------------------------------------------------------------------
# Dense projection through the numerics dispatch layer
# ---------------------------------------------------------------------------
def dense(x: Array, w: Array, site: str, bias: Optional[Array] = None,
          plan: Optional["dispatch.GemmPlan"] = None) -> Array:
    """x (..., K) @ w (K, N) via the BLAS dispatch; returns x.dtype.

    Leading dims are passed through un-flattened: a reshape that merged a
    data-sharded batch dim with a model-sharded sequence dim would force XLA
    to all-gather the activations (unrepresentable merged sharding).

    Under ``jax.grad`` the activation gradient dispatches as ``<site>@bwd.dA``
    and the weight gradient (one flattened Aᵀ·G GEMM) as ``<site>@bwd.dB``.

    ``plan`` pins Pallas block sizes for this call-site; by default the
    dispatch layer resolves one from its GemmPlan cache per operand shape."""
    out = dispatch.gemm(x, w, site=site, plan=plan)
    if bias is not None:
        out = out + bias
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, H, S, hd), positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,S,hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    xr = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return xr.reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, chunked online-softmax for long sequences)
# ---------------------------------------------------------------------------
def _grouped_scores(q: Array, k: Array, site: str) -> Array:
    """q (B,Kh,G,Sq,hd) x k (B,Kh,Sk,hd) -> (B,Kh,G,Sq,Sk) via dispatch."""
    return dispatch.grouped_qk(q, k, site=site)


def _grouped_values(p: Array, v: Array, site: str) -> Array:
    """p (B,Kh,G,Sq,Sk) x v (B,Kh,Sk,hd) -> (B,Kh,G,Sq,hd)."""
    return dispatch.grouped_av(p, v, site=site)


def attention(q: Array, k: Array, v: Array, *, causal: bool,
              chunk: int = 1024, prefix_len: int = 0,
              q_offset: int | Array = 0, site: str = "attn") -> Array:
    """Chunked (flash-style) attention with online softmax.

    q: (B, H, Sq, hd); k, v: (B, Hkv, Sk, hd). GQA via head grouping (no kv
    materialized repeat). ``prefix_len``: bidirectional prefix (VLM prefix-LM).
    ``q_offset``: absolute position of q[0] (incremental decode).
    Returns (B, H, Sq, hd) in q.dtype.
    """
    B, H, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = H // Hkv
    q = q.reshape(B, Hkv, G, Sq, hd)
    scale = hd ** -0.5

    nc = -(-Sk // chunk)
    pad = nc * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kc = jnp.moveaxis(k.reshape(B, Hkv, nc, chunk, hd), 2, 0)
    vc = jnp.moveaxis(v.reshape(B, Hkv, nc, chunk, hd), 2, 0)

    q_pos = q_offset + jnp.arange(Sq)

    def step(carry, xs):
        m, l, acc = carry
        ci, kci, vci = xs
        s = _grouped_scores(q, kci, site + "_qk").astype(jnp.float32) * scale
        k_pos = ci * chunk + jnp.arange(chunk)
        valid = k_pos < Sk
        if causal:
            ok = (k_pos[None, :] <= q_pos[:, None]) | (k_pos[None, :] < prefix_len)
        else:
            ok = jnp.ones((Sq, chunk), jnp.bool_)
        ok = ok & valid[None, :]
        s = jnp.where(ok[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(-1))
        # guard fully-masked rows (m_new == -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(ok[None, None, None], p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l = l * alpha + p.sum(-1)
        pv = _grouped_values(p.astype(v.dtype), vci, site + "_av")
        acc = acc * alpha[..., None] + pv.astype(jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hkv, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, hd), jnp.float32)
    # checkpoint the chunk step: backward recomputes the (Sq x chunk) score
    # block per chunk instead of materializing all of them (flash-attn bwd)
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(step), (m0, l0, a0), (jnp.arange(nc), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, H, Sq, hd).astype(q.dtype)


def decode_attention(q: Array, k: Array, v: Array, *, cache_len: Array,
                     k_scale: Optional[Array] = None,
                     v_scale: Optional[Array] = None,
                     start: Optional[Array] = None,
                     site: str = "attn") -> Array:
    """Single-step attention against a (possibly longer-than-valid) KV cache.
    q: (B, H, 1, hd); k, v: (B, Hkv, Smax, hd); cache_len: valid prefix.

    Quantized cache (the paper's ⟨msb,lsb⟩ tailoring applied to KV storage):
    k/v int8 with per-position scales (B, Hkv, Smax); dequantization is
    folded into the einsums (scores x k_scale; probs x v_scale)."""
    B, H, Sq, hd = q.shape
    Hkv, Smax = k.shape[1], k.shape[2]
    qv = q.reshape(B, Hkv, H // Hkv, Sq, hd)
    kk = k.astype(q.dtype) if k.dtype == jnp.int8 else k
    s = _grouped_scores(qv, kk, site + "_qk").astype(jnp.float32) * hd ** -0.5
    if k_scale is not None:
        s = s * k_scale.astype(jnp.float32)[:, :, None, None, :]
    valid = jnp.arange(Smax)[None, :] < jnp.atleast_1d(cache_len)[:, None]
    if start is not None:
        # continuous batching: slots reused mid-stream only attend to their
        # own request's prefix [start, len)
        valid = valid & (jnp.arange(Smax)[None, :]
                         >= jnp.atleast_1d(start)[:, None])
    s = jnp.where(valid[:, None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    if v_scale is not None:
        p = p * v_scale.astype(jnp.float32)[:, :, None, None, :]
    vv = v.astype(q.dtype) if v.dtype == jnp.int8 else v
    out = _grouped_values(p.astype(vv.dtype), vv, site + "_av")
    return out.reshape(B, H, Sq, hd).astype(q.dtype)


def quantize_kv(x: Array):
    """Per-position symmetric int8 quantization: x (B, Hkv, S, hd) ->
    (int8 values, scales (B, Hkv, S))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.round(x.astype(jnp.float32) / scale[..., None])
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


# ---------------------------------------------------------------------------
# Attention block (projections + rope + norm options)
# ---------------------------------------------------------------------------
def init_attention(key, cfg, dtype=jnp.float32):
    d, H, Kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    scale = d ** -0.5
    p = {
        "wq": jax.random.normal(ks[0], (d, H * hd), dtype) * scale,
        "wk": jax.random.normal(ks[1], (d, Kh * hd), dtype) * scale,
        "wv": jax.random.normal(ks[2], (d, Kh * hd), dtype) * scale,
        "wo": jax.random.normal(ks[3], (H * hd, d), dtype) * (H * hd) ** -0.5,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((Kh * hd,), dtype)
        p["bv"] = jnp.zeros((Kh * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def attention_block(x: Array, p, cfg, dist: Distribution, *,
                    causal: bool = True, prefix_len: int = 0,
                    positions: Optional[Array] = None,
                    kv_cache: Optional[dict] = None,
                    kv_override: Optional[tuple] = None,
                    site: str = "attn"):
    """Full attention sub-block. Returns (out, new_kv_cache | None).

    kv_cache: {"k": (B,Hkv,Smax,hd), "v": ..., "len": int32[B?]} for decode.
    kv_override: precomputed (k, v) (whisper cross-attention).
    """
    B, S, d = x.shape
    H, Kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = dense(x, p["wq"], site + "_q", p.get("bq"))
    q = q.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    if kv_override is not None:
        k, v = kv_override
    else:
        k = dense(x, p["wk"], site + "_k", p.get("bk"))
        v = dense(x, p["wv"], site + "_v", p.get("bv"))
        k = k.reshape(B, S, Kh, hd).transpose(0, 2, 1, 3)
        v = v.reshape(B, S, Kh, hd).transpose(0, 2, 1, 3)

    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)

    if positions is None:
        positions = jnp.arange(S)
    if kv_override is None:   # no rope on cross-attention
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    if kv_cache is None and S > 1:
        # SP: q stays sequence-sharded; K/V are the (all-gathered) small side
        q = dist.constrain(q, dist.dp, None, dist.tp_axis, None)
        k = dist.constrain(k, dist.dp, None, None, None)
        v = dist.constrain(v, dist.dp, None, None, None)

    new_cache = None
    if kv_cache is not None:
        # incremental decode: write k,v at position len, attend to prefix
        ln = kv_cache["len"]
        if "k_scale" in kv_cache:      # int8 tailored cache
            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            kfull = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["k"], kq, ln, axis=2)
            vfull = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["v"], vq, ln, axis=2)
            ksf = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["k_scale"], ks.astype(kv_cache["k_scale"].dtype),
                ln, axis=2)
            vsf = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["v_scale"], vs.astype(kv_cache["v_scale"].dtype),
                ln, axis=2)
            out = decode_attention(q, kfull, vfull, cache_len=ln + S,
                                   k_scale=ksf, v_scale=vsf,
                                   start=kv_cache.get("start"), site=site)
            new_cache = {"k": kfull, "v": vfull, "k_scale": ksf,
                         "v_scale": vsf, "len": ln + S}
        else:
            kfull = jax.lax.dynamic_update_slice_in_dim(kv_cache["k"], k, ln,
                                                        axis=2)
            vfull = jax.lax.dynamic_update_slice_in_dim(kv_cache["v"], v, ln,
                                                        axis=2)
            out = decode_attention(q, kfull, vfull, cache_len=ln + S,
                                   start=kv_cache.get("start"), site=site)
            new_cache = {"k": kfull, "v": vfull, "len": ln + S}
    else:
        out = attention(q, k, v, causal=causal, chunk=cfg.attn_chunk,
                        prefix_len=prefix_len, site=site)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    return dense(out, p["wo"], site + "_o"), new_cache


# ---------------------------------------------------------------------------
# MLP (GLU)
# ---------------------------------------------------------------------------
def init_mlp(key, d: int, f: int, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {
        "w_in": jax.random.normal(ks[0], (d, f), dtype) * d ** -0.5,
        "w_gate": jax.random.normal(ks[1], (d, f), dtype) * d ** -0.5,
        "w_out": jax.random.normal(ks[2], (f, d), dtype) * f ** -0.5,
    }


def mlp_block(x: Array, p, cfg, dist: Distribution, site: str = "mlp") -> Array:
    S = x.shape[1]
    sp = (dist.mlp_pattern == "sp" and dist.mesh is not None
          and S > 1 and S % dist.mesh.shape[dist.tp_axis] == 0)
    if sp:
        # sequence stays sharded over tp; the (small) per-layer weights are
        # gathered just-in-time instead of the (huge) full-sequence
        # activations — force XLA onto the weight-gather side by pinning
        # both matmul inputs (x seq-sharded, w replicated).
        x = dist.constrain(x, dist.dp, dist.tp_axis, None)
        w_in = dist.constrain(p["w_in"], None, None)
        w_gate = dist.constrain(p["w_gate"], None, None)
        w_out = dist.constrain(p["w_out"], None, None)
        h = dense(x, w_in, site + "_in")
        g = dense(x, w_gate, site + "_gate")
        h = activate(g, cfg.act) * h
        h = dist.constrain(h, dist.dp, dist.tp_axis, None)
        return dense(h, w_out, site + "_out")
    h = dense(x, p["w_in"], site + "_in")
    g = dense(x, p["w_gate"], site + "_gate")
    h = activate(g, cfg.act) * h
    h = dist.constrain(h, dist.dp, None, dist.tp_axis)
    return dense(h, p["w_out"], site + "_out")
