"""Model configuration covering all assigned architecture families."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int                  # 0 for attention-free
    n_kv_heads: int
    d_ff: int                     # 0 => no MLP block (pure SSM)
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    # transformer details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    act: str = "silu"                       # silu (GLU) | gelu (GLU)
    norm_eps: float = 1e-5
    # MoE
    n_experts: int = 0
    top_k: int = 0
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    # hybrid (zamba2-style shared attention block)
    attn_every: int = 0                     # 0 => not hybrid
    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 0                        # fixed encoder frames
    # vlm
    n_patches: int = 0
    # numerics / sizes
    param_dtype: str = "float32"
    # attention chunking for long sequences
    attn_chunk: int = 1024

    def __post_init__(self):
        if self.n_heads:
            object.__setattr__(
                self, "head_dim", self.head_dim or self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:               # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 32 (TP-shardable; logits for padded
        ids are masked to -inf)."""
        return -(-self.vocab_size // 32) * 32

    def reduced(self, **over) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        base = dict(
            name=self.name + "-reduced",
            family=self.family,
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=0,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            head_dim=16 if self.n_heads else None,
            qkv_bias=self.qkv_bias,
            qk_norm=self.qk_norm,
            act=self.act,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            ssm_state=min(self.ssm_state, 16),
            ssm_expand=self.ssm_expand,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_groups=self.ssm_groups,
            ssm_conv=self.ssm_conv,
            attn_every=1 if self.attn_every else 0,
            n_enc_layers=min(self.n_enc_layers, 2),
            enc_seq=16 if self.n_enc_layers else 0,
            n_patches=8 if self.n_patches else 0,
            attn_chunk=32,
        )
        if self.n_heads:
            base["n_kv_heads"] = min(self.n_kv_heads, base["n_heads"])
            if self.n_kv_heads == 1:
                base["n_kv_heads"] = 1
        base.update(over)
        return ModelConfig(**base)

    def param_count(self) -> int:
        """Analytical parameter count (for 6ND roofline bookkeeping)."""
        d, f, V = self.d_model, self.d_ff, self.padded_vocab
        n_attn = 0
        if self.n_heads:
            hd = self.head_dim
            n_attn = d * (self.n_heads * hd) * 2 \
                + d * (self.n_kv_heads * hd) * 2
        n_mlp = 3 * d * f if f else 0
        if self.n_experts:
            n_mlp *= self.n_experts
        n_ssm = 0
        if self.ssm_state:
            di, g, n, h = self.d_inner, self.ssm_groups, self.ssm_state, self.ssm_heads
            n_ssm = d * di * 2 + 2 * d * g * n + d * h + di * d \
                + self.ssm_conv * (di + 2 * g * n)
        per_layer = {
            "dense": n_attn + n_mlp, "moe": n_attn + n_mlp,
            "vlm": n_attn + n_mlp, "encdec": n_attn + n_mlp,
            "ssm": n_ssm, "hybrid": n_ssm,
        }[self.family]
        total = self.n_layers * per_layer
        if self.family == "encdec":
            # encoder layers + decoder cross-attention
            total += self.n_enc_layers * (n_attn + n_mlp) \
                + self.n_layers * n_attn
        if self.family == "hybrid" and self.attn_every:
            total += n_attn + 3 * d * f          # one shared attn+MLP block
        total += 2 * V * d                        # embed + head
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_moe = self.n_layers * 3 * d * f * self.n_experts
        active_moe = self.n_layers * 3 * d * f * self.top_k
        return self.param_count() - dense_moe + active_moe


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Which (arch x shape) dry-run cells exist (DESIGN.md §4)."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, "long_500k needs sub-quadratic context (SSM/hybrid only)"
    return True, ""
