"""Mixture-of-Experts block: sort-based ragged dispatch + lax.ragged_dot.

No capacity factor and no token dropping in the default (TP-MoE) path: tokens
are sorted by expert and fed through grouped matmuls with exact ragged group
sizes — FLOPs proportional to top_k (not n_experts), which keeps the roofline
compute term faithful.

Distribution (dist.mesh set): TP-MoE inside shard_map —
    tokens stay sharded over the dp axes; the sequence shards (SP) are
    all-gathered over the model axis, each model shard computes ALL local
    tokens against its 1/TP slice of every expert's FFN, and the partial
    outputs are reduce-scattered back to sequence shards. Collectives:
    1 all-gather + 1 reduce-scatter per MoE layer (same as a Megatron MLP).

An EP (expert-parallel, all-to-all) variant is provided for the §Perf
comparison: ``moe_block_ep`` — each model shard owns n_experts/TP full
experts and tokens are exchanged with two all_to_all hops.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import dispatch

from .layers import Distribution, activate

from repro.parallel.compat import shard_map_unchecked


def init_moe(key, d: int, f: int, n_experts: int, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    return {
        "router": jax.random.normal(ks[0], (d, n_experts), dtype) * d ** -0.5,
        "w_in": jax.random.normal(ks[1], (n_experts, d, f), dtype) * d ** -0.5,
        "w_gate": jax.random.normal(ks[2], (n_experts, d, f), dtype) * d ** -0.5,
        "w_out": jax.random.normal(ks[3], (n_experts, f, d), dtype) * f ** -0.5,
    }


def _route(x_flat, router_w, cfg):
    """Top-k routing. Returns (weights (T,k) f32, ids (T,k) i32)."""
    logits = dispatch.gemm(x_flat, router_w, site="moe_router")
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, ids = jax.lax.top_k(probs, cfg.top_k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    return weights, ids.astype(jnp.int32)


def _moe_ffn(x_sorted, group_sizes, cfg, wi, wg, wo):
    """Grouped GLU FFN over expert-sorted tokens, dispatched per call-site
    (moe_in / moe_gate / moe_out) so expert GEMMs are calibratable and
    plan-tailorable like every other site; the default native policy stays
    on the fused ragged_dot fast path. Training gradients dispatch as the
    phase-qualified twins (moe_in@bwd.dA = token grads, moe_in@bwd.dB =
    per-expert weight grads) via ragged_gemm's custom_vjp."""
    h_in = dispatch.ragged_gemm(x_sorted, wi, group_sizes, site="moe_in")
    h_gate = dispatch.ragged_gemm(x_sorted, wg, group_sizes, site="moe_gate")
    h = activate(h_gate, cfg.act) * h_in
    return dispatch.ragged_gemm(h.astype(x_sorted.dtype), wo, group_sizes,
                                site="moe_out")


def _moe_inner(x_flat, router_w, wi, wg, wo, cfg):
    """Dense tokens (T, d) -> (T, d). Pure local computation."""
    T, d = x_flat.shape
    k = cfg.top_k
    weights, ids = _route(x_flat, router_w, cfg)
    flat_ids = ids.reshape(-1)                            # (T*k,)
    order = jnp.argsort(flat_ids, stable=True)
    token_of = order // k                                 # source token per slot
    x_sorted = jnp.take(x_flat, token_of, axis=0)
    group_sizes = jnp.bincount(flat_ids, length=cfg.n_experts).astype(jnp.int32)
    out_sorted = _moe_ffn(x_sorted, group_sizes, cfg, wi, wg, wo)
    w_sorted = jnp.take(weights.reshape(-1), order)
    contrib = out_sorted.astype(jnp.float32) * w_sorted[:, None]
    out = jnp.zeros((T, d), jnp.float32).at[token_of].add(contrib)
    return out.astype(x_flat.dtype)


def moe_block(x, p, cfg, dist: Distribution, site: str = "moe"):
    """x: (B, S, d) -> (B, S, d). TP-MoE (see module docstring)."""
    B, S, d = x.shape
    if dist.mesh is None:
        return _moe_inner(x.reshape(-1, d), p["router"], p["w_in"],
                          p["w_gate"], p["w_out"], cfg).reshape(B, S, d)

    dp, tp = dist.dp, dist.tp_axis
    tp_size = dist.mesh.shape[tp]
    seq_sharded = S > 1 and S % tp_size == 0

    if seq_sharded:
        def f(x_loc, rw, wi, wg, wo):
            # x_loc: (B_loc, S_loc, d) — seq-sharded (SP); gather seq over TP
            xg = jax.lax.all_gather(x_loc, tp, axis=1, tiled=True)
            bl, s, _ = xg.shape
            y = _moe_inner(xg.reshape(-1, d), rw, wi, wg, wo, cfg)
            y = y.reshape(bl, s, d)
            # partial over the f-shards -> reduce + re-scatter seq
            return jax.lax.psum_scatter(y, tp, scatter_dimension=1, tiled=True)

        x_spec, y_spec = P(dp, tp, None), P(dp, tp, None)
    elif dist.joint_tp:
        # weights-stay-put decode: experts' f-dim sharded over ALL axes;
        # every device computes every token against its 1/(dp*tp) slice,
        # partials psum'd over the whole mesh — zero weight movement.
        axes = tuple(dist.dp_axes) + (tp,)

        def f(x_loc, rw, wi, wg, wo):
            bl, s, _ = x_loc.shape
            y = _moe_inner(x_loc.reshape(-1, d), rw, wi, wg, wo, cfg)
            return jax.lax.psum(y.reshape(bl, s, d), axes)

        return shard_map_unchecked(
            f, mesh=dist.mesh,
            in_specs=(P(None, None, None), P(None, None),
                      P(None, None, axes), P(None, None, axes),
                      P(None, axes, None)),
            out_specs=P(None, None, None),
        )(x, p["router"], p["w_in"], p["w_gate"], p["w_out"])
    else:
        def f(x_loc, rw, wi, wg, wo):
            # decode path: sequence too short to shard; every TP shard
            # computes the local tokens against its f-slice, then psum
            bl, s, _ = x_loc.shape
            y = _moe_inner(x_loc.reshape(-1, d), rw, wi, wg, wo, cfg)
            return jax.lax.psum(y.reshape(bl, s, d), tp)

        x_spec, y_spec = P(dp, None, None), P(dp, None, None)

    return shard_map_unchecked(
        f, mesh=dist.mesh,
        in_specs=(x_spec, P(None, None),
                  P(None, None, tp), P(None, None, tp), P(None, tp, None)),
        out_specs=y_spec,
    )(x, p["router"], p["w_in"], p["w_gate"], p["w_out"])


def moe_block_ep(x, p, cfg, dist: Distribution, site: str = "moe",
                 capacity_factor: float = 2.0):
    """Expert-parallel variant (§Perf): experts sharded over the TP axis
    (each shard owns n_experts/TP FULL experts); tokens move over two
    all_to_all hops. Tokens beyond the per-destination capacity
    (cf * T_loc*k / tp) are dropped — standard EP semantics.

    Collective bytes per layer ~ 3 * all_to_all(T_loc*k*d) vs TP-MoE's
    all_gather(T*d) + reduce_scatter(T*d)."""
    B, S, d = x.shape
    if dist.mesh is None:
        return moe_block(x, p, cfg, dist, site)
    dp, tp = dist.dp, dist.tp_axis
    tp_size = dist.mesh.shape[tp]
    E, k = cfg.n_experts, cfg.top_k
    assert E % tp_size == 0, "EP requires n_experts % tp == 0"
    e_loc = E // tp_size

    def f(x_loc, rw, wi, wg, wo):
        bl, sl, _ = x_loc.shape
        xf = x_loc.reshape(-1, d)
        T = xf.shape[0]
        weights, ids = _route(xf, rw, cfg)
        flat_ids = ids.reshape(-1)                        # (T*k,)
        order = jnp.argsort(flat_ids, stable=True)        # expert(=>shard)-sorted
        token_of = order // k
        ids_sorted = jnp.take(flat_ids, order)
        sizes_shard = jnp.bincount(flat_ids // e_loc,
                                   length=tp_size).astype(jnp.int32)
        offs = jnp.concatenate([jnp.zeros(1, jnp.int32),
                                jnp.cumsum(sizes_shard)[:-1]])
        cap = int(capacity_factor * (T * k) / tp_size) + 1
        slot = jnp.arange(tp_size * cap)
        shard_of = slot // cap
        j = slot % cap
        src = offs[shard_of] + j                          # sorted-index per slot
        valid = j < sizes_shard[shard_of]
        srcc = jnp.minimum(src, T * k - 1)
        send_x = jnp.where(valid[:, None],
                           jnp.take(xf, jnp.take(token_of, srcc), axis=0), 0.0)
        send_id = jnp.where(valid, jnp.take(ids_sorted, srcc), -1)
        recv_x = jax.lax.all_to_all(send_x.reshape(tp_size, cap, d), tp,
                                    split_axis=0, concat_axis=0)
        recv_id = jax.lax.all_to_all(send_id.reshape(tp_size, cap), tp,
                                     split_axis=0, concat_axis=0)
        my = jax.lax.axis_index(tp)
        loc_id = jnp.where(recv_id >= 0, recv_id - my * e_loc,
                           e_loc).reshape(-1)
        lorder = jnp.argsort(loc_id, stable=True)
        lsorted = jnp.take(recv_x.reshape(-1, d), lorder, axis=0)
        lsizes = jnp.bincount(loc_id, length=e_loc + 1).astype(jnp.int32)[:e_loc]
        out_sorted = _moe_ffn(lsorted, lsizes, cfg, wi, wg, wo)
        row = jnp.arange(out_sorted.shape[0])
        out_sorted = jnp.where((row < jnp.sum(lsizes))[:, None], out_sorted, 0.0)
        back = jnp.zeros_like(out_sorted).at[lorder].set(out_sorted)
        ret = jax.lax.all_to_all(back.reshape(tp_size, cap, d), tp,
                                 split_axis=0, concat_axis=0).reshape(-1, d)
        w_sorted = jnp.take(weights.reshape(-1), order)
        dest_tok = jnp.where(valid, jnp.take(token_of, srcc), T)  # T = drop row
        contrib = ret.astype(jnp.float32) \
            * jnp.where(valid, jnp.take(w_sorted, srcc), 0.0)[:, None]
        out_tok = jnp.zeros((T + 1, d), jnp.float32).at[dest_tok].add(contrib)[:T]
        return out_tok.astype(x_loc.dtype).reshape(bl, sl, d)

    return shard_map_unchecked(
        f, mesh=dist.mesh,
        in_specs=(P(dp, tp, None), P(None, None),
                  P(tp, None, None), P(tp, None, None), P(tp, None, None)),
        out_specs=P(dp, tp, None),
    )(x, p["router"], p["w_in"], p["w_gate"], p["w_out"])
