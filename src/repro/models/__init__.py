from .config import (LONG_500K, PREFILL_32K, SHAPES, TRAIN_4K, DECODE_32K,
                     ModelConfig, ShapeConfig, shape_applicable)
from .layers import Distribution, LOCAL
from .transformer import (decode_step, forward, init, init_abstract,
                          init_cache, prefill)
