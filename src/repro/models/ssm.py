"""Mamba-2 (SSD — state-space duality) block, chunked algorithm.

Follows the minimal SSD reference of arXiv:2405.21060 §6: the sequence is
split into chunks; within a chunk the dual quadratic (attention-like) form is
used, across chunks a linear recurrence carries the (heads, head_dim, state)
SSM state. Heads are kept factored as (groups g, heads-per-group e) so B/C
(shared per group, GVA-style) never materialize per-head.

Sharding: d_inner (= g*e*head_dim) channels shard over the TP axis on the
``e`` dimension; all SSD einsums are batched over (g, e) so the layer is
embarrassingly TP-parallel with no collectives (the projections in/out carry
the usual Megatron pattern).

Numerics: the in/out projections (``ssm_x``/``ssm_z``/``ssm_B``/``ssm_C``/
``ssm_dt``/``ssm_out``) run through the dispatch layer, so SSM scan-block
sites calibrate and plan-serve like attention/MLP sites — and under training
their gradients dispatch as ``ssm_*@bwd.dA``/``@bwd.dB`` phase sites.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import Distribution, dense, rms_norm

Array = jax.Array


def init_ssm(key, cfg, dtype=jnp.float32):
    d, di = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    w = cfg.ssm_conv
    ks = jax.random.split(key, 10)
    s = d ** -0.5
    return {
        "in_x": jax.random.normal(ks[0], (d, di), dtype) * s,
        "in_z": jax.random.normal(ks[1], (d, di), dtype) * s,
        "in_B": jax.random.normal(ks[2], (d, g * n), dtype) * s,
        "in_C": jax.random.normal(ks[3], (d, g * n), dtype) * s,
        "in_dt": jax.random.normal(ks[4], (d, h), dtype) * s,
        "conv_x": jax.random.normal(ks[5], (w, di), dtype) * w ** -0.5,
        "conv_B": jax.random.normal(ks[6], (w, g * n), dtype) * w ** -0.5,
        "conv_C": jax.random.normal(ks[7], (w, g * n), dtype) * w ** -0.5,
        "A_log": jnp.zeros((h,), dtype),          # A = -exp(A_log) = -1
        "D": jnp.ones((h,), dtype),
        "dt_bias": jnp.zeros((h,), dtype),
        "norm": jnp.ones((di,), dtype),
        "out": jax.random.normal(ks[8], (di, d), dtype) * di ** -0.5,
    }


def _causal_conv(x: Array, kern: Array, state: Array | None = None):
    """Depthwise causal conv. x: (B, S, C), kern: (w, C).
    state: (B, w-1, C) trailing inputs from the previous segment (decode).
    Returns (y, new_state)."""
    w = kern.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], w - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * kern[i] for i in range(w))
    new_state = xp[:, -(w - 1):, :] if w > 1 else state
    return jax.nn.silu(y), new_state


def _segsum(a: Array) -> Array:
    """a: (..., Q) -> lower-triangular pairwise sums L[q,k] = sum_{k<i<=q} a_i,
    -inf above the diagonal (exp -> 0)."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    dlt = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, dlt, -jnp.inf)


def ssd_chunked(x: Array, dt: Array, A: Array, B: Array, C: Array,
                chunk: int, init_state: Array | None = None):
    """SSD scan. x: (b, l, h, p); dt: (b, l, h); A: (h,) (negative);
    B, C: (b, l, g, n). Returns (y (b,l,h,p), final_state (b,g,e,p,n))."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    e = h // g
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    lc = x.shape[1]
    c = lc // chunk
    xc = x.reshape(b, c, chunk, g, e, p)
    dtc = dt.reshape(b, c, chunk, g, e)
    Bc = B.reshape(b, c, chunk, g, n)
    Cc = C.reshape(b, c, chunk, g, n)
    Ac = (dtc * (-jnp.exp(A.astype(jnp.float32))).reshape(g, e))  # (b,c,Q,g,e)
    x_dt = xc * dtc[..., None]

    A_cum = jnp.cumsum(Ac, axis=2)                       # (b,c,Q,g,e)
    # intra-chunk (dual quadratic form)
    Lt = jnp.exp(_segsum(jnp.moveaxis(Ac, 2, -1)))       # (b,c,g,e,Q,Q)
    scores = jnp.einsum("bcqgn,bckgn->bcgqk", Cc, Bc)
    y_diag = jnp.einsum("bcgqk,bcgeqk,bckgep->bcqgep", scores, Lt,
                        x_dt.astype(jnp.float32))
    # chunk -> state contributions
    decay_states = jnp.exp(A_cum[:, :, -1:, ...] - A_cum)  # (b,c,Q,g,e)
    states = jnp.einsum("bckgn,bckge,bckgep->bcgepn", Bc, decay_states,
                        x_dt.astype(jnp.float32))
    chunk_decay = jnp.exp(A_cum[:, :, -1])               # (b,c,g,e)

    def scanf(S, inp):
        st, dec = inp
        S_new = S * dec[..., None, None] + st
        return S_new, S                                   # emit state BEFORE chunk

    S0 = (init_state.astype(jnp.float32) if init_state is not None
          else jnp.zeros((b, g, e, p, n), jnp.float32))
    final, prev_states = jax.lax.scan(
        scanf, S0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)        # (b,c,g,e,p,n)
    # inter-chunk contribution
    state_decay = jnp.exp(A_cum)                         # (b,c,Q,g,e)
    y_off = jnp.einsum("bcqgn,bcgepn,bcqge->bcqgep", Cc, prev_states,
                       state_decay)
    y = (y_diag + y_off).reshape(b, lc, h, p)[:, :l]
    return y.astype(x.dtype), final


def ssd_step(state: Array, x_t: Array, dt_t: Array, A: Array, B_t: Array,
             C_t: Array):
    """Single-token SSD recurrence. state: (b,g,e,p,n); x_t: (b,h,p);
    dt_t: (b,h); B_t, C_t: (b,g,n). Returns (y (b,h,p), new_state)."""
    b, g, e, p, n = state.shape
    xg = x_t.reshape(b, g, e, p).astype(jnp.float32)
    dtg = dt_t.reshape(b, g, e)
    Ag = (-jnp.exp(A.astype(jnp.float32))).reshape(g, e)
    da = jnp.exp(dtg * Ag)                               # (b,g,e)
    upd = jnp.einsum("bgn,bgep->bgepn", B_t.astype(jnp.float32), xg * dtg[..., None])
    state = state * da[..., None, None] + upd
    y = jnp.einsum("bgn,bgepn->bgep", C_t.astype(jnp.float32), state)
    return y.reshape(b, g * e, p).astype(x_t.dtype), state


def ssm_block(x: Array, p, cfg, dist: Distribution, *,
              cache: dict | None = None, site: str = "ssm"):
    """Full Mamba-2 block. x: (B, S, d). cache (decode):
    {"conv_x","conv_B","conv_C": (B,w-1,·), "state": (B,g,e,p,n)}.
    Returns (out, new_cache | None)."""
    B_, S, d = x.shape
    g, n, h, pdim = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    xz = dense(x, p["in_x"], site + "_x")
    z = dense(x, p["in_z"], site + "_z")
    Bp = dense(x, p["in_B"], site + "_B")
    Cp = dense(x, p["in_C"], site + "_C")
    dt = jax.nn.softplus(
        dense(x, p["in_dt"], site + "_dt").astype(jnp.float32) + p["dt_bias"])

    if cache is None and S > 1 and dist.mesh is not None:
        # SSD is sequential over seq: run it with the sequence GATHERED and
        # the d_inner channels sharded over tp instead (every SSD einsum is
        # batched over (g, e), so channel sharding is collective-free); the
        # block output is reduce-scattered back to seq shards by the
        # transformer-level constraint. Without this pin XLA shuffles the
        # big (b, c, h, Q, K) intra-chunk tensors across the mesh.
        xz = dist.constrain(xz, dist.dp, None, dist.tp_axis)
        z = dist.constrain(z, dist.dp, None, dist.tp_axis)
        Bp = dist.constrain(Bp, dist.dp, None, None)
        Cp = dist.constrain(Cp, dist.dp, None, None)
        dt = dist.constrain(dt, dist.dp, None, dist.tp_axis)

    cc = cache or {}
    xz, cx = _causal_conv(xz, p["conv_x"], cc.get("conv_x"))
    Bp, cb = _causal_conv(Bp, p["conv_B"], cc.get("conv_B"))
    Cp, cv = _causal_conv(Cp, p["conv_C"], cc.get("conv_C"))

    xh = xz.reshape(B_, S, h, pdim)
    Bh = Bp.reshape(B_, S, g, n)
    Ch = Cp.reshape(B_, S, g, n)

    if cache is not None and S == 1:
        y, state = ssd_step(cc["state"], xh[:, 0], dt[:, 0], p["A_log"],
                            Bh[:, 0], Ch[:, 0])
        y = y[:, None]
    else:
        y, state = ssd_chunked(xh, dt, p["A_log"], Bh, Ch,
                               chunk=min(64, max(8, S)),
                               init_state=cc.get("state"))
    y = y.reshape(B_, S, h * pdim) + xz * jnp.repeat(
        p["D"], pdim).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = dense(y, p["out"], site + "_out")
    new_cache = None
    if cache is not None:
        new_cache = {"conv_x": cx, "conv_B": cb, "conv_C": cv, "state": state}
    return out, new_cache
