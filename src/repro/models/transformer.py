"""Model assembly for every architecture family.

One uniform API across families:

    params = init(cfg, key)                        (pure; eval_shape-able)
    logits = forward(params, cfg, batch, dist)     (train / prefill logits)
    cache  = init_cache(cfg, B, max_len)           (serving)
    logits, cache = decode_step(params, cfg, cache, tokens, dist)

Layers are scanned (stacked parameters) so the lowered HLO stays compact for
every depth; hybrid models scan groups (inner scan over SSM layers, shared
attention block between groups); encoder-decoder runs two scans.

Every GEMM site in this file is a *forward* site name; differentiating
``forward`` (training, calibration with ``--phases fwd,bwd``) dispatches the
matching ``<site>@bwd.dA``/``<site>@bwd.dB`` gradient sites automatically
through the dispatch layer's custom_vjp — model assembly never names a phase.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from . import layers as L
from . import moe as MOE
from . import ssm as SSM
from .config import ModelConfig
from .layers import Distribution

Array = jax.Array


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_block(key, cfg, dtype, *, cross: bool = False):
    """One decoder block's params (attention [+cross] + mlp/moe/ssm)."""
    ks = jax.random.split(key, 8)
    p = {}
    if cfg.family in ("ssm", "hybrid"):
        p["ssm_norm"] = jnp.ones((cfg.d_model,), dtype)
        p["ssm"] = SSM.init_ssm(ks[0], cfg, dtype)
        return p
    p["attn_norm"] = jnp.ones((cfg.d_model,), dtype)
    p["attn"] = L.init_attention(ks[0], cfg, dtype)
    if cross:
        p["cross_norm"] = jnp.ones((cfg.d_model,), dtype)
        p["cross"] = L.init_attention(ks[1], cfg, dtype)
    p["mlp_norm"] = jnp.ones((cfg.d_model,), dtype)
    if cfg.n_experts:
        p["moe"] = MOE.init_moe(ks[2], cfg.d_model, cfg.d_ff, cfg.n_experts,
                                dtype)
    elif cfg.d_ff:
        p["mlp"] = L.init_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype)
    return p


def init(cfg: ModelConfig, key) -> dict:
    """Full parameter pytree (layer params stacked for scan)."""
    dtype = jnp.dtype(cfg.param_dtype)
    k_embed, k_layers, k_head, k_extra = jax.random.split(key, 4)
    V, d = cfg.padded_vocab, cfg.d_model
    params = {
        "embed": jax.random.normal(k_embed, (V, d), dtype) * d ** -0.5,
        "final_norm": jnp.ones((d,), dtype),
        "lm_head": jax.random.normal(k_head, (d, V), dtype) * d ** -0.5,
    }

    def stack_init(key, n, fn):
        return jax.vmap(fn)(jax.random.split(key, n))

    if cfg.family == "encdec":
        enc_cfg = cfg
        params["enc_layers"] = stack_init(
            k_extra, cfg.n_enc_layers, lambda k: _init_block(k, enc_cfg, dtype))
        params["dec_layers"] = stack_init(
            k_layers, cfg.n_layers,
            lambda k: _init_block(k, cfg, dtype, cross=True))
        params["enc_norm"] = jnp.ones((d,), dtype)
    elif cfg.family == "hybrid":
        ng = cfg.n_layers // cfg.attn_every
        flat = stack_init(k_layers, cfg.n_layers,
                          lambda k: _init_block(k, cfg, dtype))
        params["layers"] = jax.tree.map(
            lambda x: x.reshape(ng, cfg.attn_every, *x.shape[1:]), flat)
        # the weight-tied shared attention + MLP block
        ks = jax.random.split(k_extra, 3)
        shared_cfg = cfg
        params["shared"] = {
            "attn_norm": jnp.ones((d,), dtype),
            "attn": L.init_attention(ks[0], shared_cfg, dtype),
            "mlp_norm": jnp.ones((d,), dtype),
            "mlp": L.init_mlp(ks[1], d, cfg.d_ff, dtype),
        }
    else:
        params["layers"] = stack_init(k_layers, cfg.n_layers,
                                      lambda k: _init_block(k, cfg, dtype))
    return params


def init_abstract(cfg: ModelConfig) -> dict:
    """ShapeDtypeStruct pytree of params (no allocation; for dry-runs)."""
    return jax.eval_shape(lambda: init(cfg, jax.random.key(0)))


# ---------------------------------------------------------------------------
# Blocks (forward)
# ---------------------------------------------------------------------------
def _decoder_block(x, p, cfg, dist, *, positions, prefix_len=0,
                   kv_cache=None, enc_out=None, moe_impl="tp"):
    """Returns (x, new_kv_cache)."""
    new_cache = None
    if cfg.family in ("ssm", "hybrid"):
        h, new_cache = SSM.ssm_block(
            L.rms_norm(x, p["ssm_norm"], cfg.norm_eps), p["ssm"], cfg, dist,
            cache=kv_cache)
        x = x + h
        return x, new_cache

    h, new_cache = L.attention_block(
        L.rms_norm(x, p["attn_norm"], cfg.norm_eps), p["attn"], cfg, dist,
        causal=True, prefix_len=prefix_len, positions=positions,
        kv_cache=kv_cache)
    x = x + h
    if enc_out is not None:
        kc, vc = enc_out
        h, _ = L.attention_block(
            L.rms_norm(x, p["cross_norm"], cfg.norm_eps), p["cross"], cfg,
            dist, causal=False, kv_override=(kc, vc))
        x = x + h
    if cfg.n_experts:
        fn = MOE.moe_block_ep if moe_impl == "ep" else MOE.moe_block
        x = x + fn(L.rms_norm(x, p["mlp_norm"], cfg.norm_eps), p["moe"], cfg,
                   dist)
    elif cfg.d_ff:
        x = x + L.mlp_block(L.rms_norm(x, p["mlp_norm"], cfg.norm_eps),
                            p["mlp"], cfg, dist)
    return x, new_cache


def _encoder_block(x, p, cfg, dist):
    h, _ = L.attention_block(
        L.rms_norm(x, p["attn_norm"], cfg.norm_eps), p["attn"], cfg, dist,
        causal=False)
    x = x + h
    x = x + L.mlp_block(L.rms_norm(x, p["mlp_norm"], cfg.norm_eps), p["mlp"],
                        cfg, dist)
    return x


def _shared_block(x, p, cfg, dist, *, positions, kv_cache=None):
    """Zamba2-style weight-shared full-attention + MLP block."""
    h, new_cache = L.attention_block(
        L.rms_norm(x, p["attn_norm"], cfg.norm_eps), p["attn"], cfg, dist,
        causal=True, positions=positions, kv_cache=kv_cache)
    x = x + h
    x = x + L.mlp_block(L.rms_norm(x, p["mlp_norm"], cfg.norm_eps), p["mlp"],
                        cfg, dist)
    return x, new_cache


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------
def _embed(params, cfg, tokens, dist):
    x = jnp.take(params["embed"], tokens, axis=0)
    return dist.constrain(x, dist.dp, dist.tp_axis, None)


def _logits(params, cfg, x, dist):
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.dense(x.astype(jnp.float32), params["lm_head"].astype(jnp.float32),
                     "lm_head")
    if cfg.padded_vocab != cfg.vocab_size:
        pad = cfg.padded_vocab - cfg.vocab_size
        mask = jnp.concatenate([jnp.zeros((cfg.vocab_size,), jnp.float32),
                                jnp.full((pad,), -jnp.inf, jnp.float32)])
        logits = logits + mask
    return logits


# ---------------------------------------------------------------------------
# Forward (train / prefill): full-sequence logits
# ---------------------------------------------------------------------------
def forward(params, cfg: ModelConfig, batch: dict, dist: Distribution = L.LOCAL,
            *, remat: str = "block", moe_impl: str = "tp",
            return_hidden: bool = False) -> Array:
    """batch: {"tokens": (B, S_text)} plus family extras:
    vlm: {"patches": (B, n_patches, d)}; encdec: {"frames": (B, enc_seq, d)}.
    Returns logits (B, S_total, padded_vocab) f32 (or final-norm hidden
    states (B, S_total, d) when return_hidden — used by the chunked loss)."""
    tokens = batch["tokens"]
    x = _embed(params, cfg, tokens, dist)
    prefix_len = 0

    if cfg.family == "vlm":
        patches = batch["patches"].astype(x.dtype)
        x = jnp.concatenate([patches, x], axis=1)
        prefix_len = cfg.n_patches
    x = dist.constrain(x, dist.dp, dist.tp_axis, None)
    B, S, _ = x.shape
    positions = jnp.arange(S)

    enc_kv = None
    if cfg.family == "encdec":
        enc = batch["frames"].astype(x.dtype)
        enc = dist.constrain(enc, dist.dp, None, None)

        def enc_body(h, lp):
            return _encoder_block(h, lp, cfg, dist), None

        enc_body = _maybe_remat(enc_body, remat)
        enc, _ = jax.lax.scan(enc_body, enc, params["enc_layers"])
        enc = L.rms_norm(enc, params["enc_norm"], cfg.norm_eps)

    def body_raw(h, lp):
        if cfg.family == "encdec":
            # per-layer cross K/V from encoder output
            kc = L.dense(enc, lp["cross"]["wk"], "cross_k")
            vc = L.dense(enc, lp["cross"]["wv"], "cross_v")
            Bk = kc.shape[0]
            kc = kc.reshape(Bk, -1, cfg.n_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
            vc = vc.reshape(Bk, -1, cfg.n_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
            h, _ = _decoder_block(h, lp, cfg, dist, positions=positions,
                                  enc_out=(kc, vc), moe_impl=moe_impl)
        else:
            h, _ = _decoder_block(h, lp, cfg, dist, positions=positions,
                                  prefix_len=prefix_len, moe_impl=moe_impl)
        h = dist.constrain(h, dist.dp, dist.tp_axis, None)
        return h, None

    body = _maybe_remat(body_raw, remat)

    if cfg.family == "hybrid":
        def group_body(h, gp):
            h, _ = jax.lax.scan(body_raw, h, gp)     # remat at group level
            h, _ = _shared_block(h, params["shared"], cfg, dist,
                                 positions=positions)
            h = dist.constrain(h, dist.dp, dist.tp_axis, None)
            return h, None

        x, _ = jax.lax.scan(_maybe_remat(group_body, remat), x,
                            params["layers"])
    elif cfg.family == "encdec":
        x, _ = jax.lax.scan(body, x, params["dec_layers"])
    else:
        x, _ = jax.lax.scan(body, x, params["layers"])

    if return_hidden:
        return L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _logits(params, cfg, x, dist)


def _maybe_remat(fn, remat: str):
    if remat in ("block", "full"):
        return jax.checkpoint(fn)
    return fn


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode_step
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, quantized: bool = False) -> dict:
    """Abstract-safe cache pytree for incremental decoding.

    quantized=True: int8 KV with per-position scales — the paper's
    numerically-tailored storage applied to the cache (halves HBM).
    Supported for the decoder-only families (dense/moe/vlm)."""
    Bq = batch
    quantized = quantized and cfg.family in ("dense", "moe", "vlm")

    def attn_cache(n):
        kv_dtype = jnp.int8 if quantized else dtype
        c = {
            "k": jnp.zeros((n, Bq, cfg.n_kv_heads, max_len, cfg.head_dim),
                           kv_dtype),
            "v": jnp.zeros((n, Bq, cfg.n_kv_heads, max_len, cfg.head_dim),
                           kv_dtype),
        }
        if quantized:
            c["k_scale"] = jnp.zeros((n, Bq, cfg.n_kv_heads, max_len),
                                     jnp.float32)
            c["v_scale"] = jnp.zeros((n, Bq, cfg.n_kv_heads, max_len),
                                     jnp.float32)
        return c

    def ssm_cache(n):
        g, e, p, s = (cfg.ssm_groups, cfg.ssm_heads // cfg.ssm_groups,
                      cfg.ssm_head_dim, cfg.ssm_state)
        w, di, gn = cfg.ssm_conv, cfg.d_inner, cfg.ssm_groups * cfg.ssm_state
        return {
            "conv_x": jnp.zeros((n, Bq, w - 1, di), dtype),
            "conv_B": jnp.zeros((n, Bq, w - 1, gn), dtype),
            "conv_C": jnp.zeros((n, Bq, w - 1, gn), dtype),
            "state": jnp.zeros((n, Bq, g, e, p, s), jnp.float32),
        }

    cache = {"len": jnp.zeros((), jnp.int32)}
    if cfg.family in ("dense", "moe", "vlm"):
        cache["layers"] = attn_cache(cfg.n_layers)
    elif cfg.family == "ssm":
        cache["layers"] = ssm_cache(cfg.n_layers)
    elif cfg.family == "hybrid":
        ng = cfg.n_layers // cfg.attn_every
        inner = ssm_cache(cfg.n_layers)
        cache["layers"] = jax.tree.map(
            lambda x: x.reshape(ng, cfg.attn_every, *x.shape[1:]), inner)
        shared = attn_cache(ng)
        cache["shared"] = shared
    elif cfg.family == "encdec":
        cache["layers"] = attn_cache(cfg.n_layers)
        cache["cross"] = {
            "k": jnp.zeros((cfg.n_layers, Bq, cfg.n_kv_heads, cfg.enc_seq,
                            cfg.head_dim), dtype),
            "v": jnp.zeros((cfg.n_layers, Bq, cfg.n_kv_heads, cfg.enc_seq,
                            cfg.head_dim), dtype),
        }
    return cache


def decode_step(params, cfg: ModelConfig, cache: dict, tokens: Array,
                dist: Distribution = L.LOCAL, *, moe_impl: str = "tp"):
    """One incremental decode step. tokens: (B, 1) int32.
    Returns (logits (B, 1, V), new_cache)."""
    x = _embed(params, cfg, tokens, dist)
    pos = cache["len"] + jnp.zeros((x.shape[0], 1), jnp.int32)
    ln = cache["len"]

    def layer_cache(sl, dtype_tree):
        return jax.tree.map(lambda c: c, sl)

    kv_keys = [k for k in ("k", "v", "k_scale", "v_scale")
               if k in cache.get("layers", {})]
    slot_start = cache.get("start")      # (B,) continuous-batching lower bound

    if cfg.family in ("dense", "moe", "vlm"):
        def body(h, lc):
            kv = {k: lc[k] for k in kv_keys} | {"len": ln,
                                                "start": slot_start}
            h, nc = _decoder_block(h, lc["p"], cfg, dist, positions=pos,
                                   kv_cache=kv, moe_impl=moe_impl)
            return h, {k: nc[k] for k in kv_keys}

        carry, new_layers = jax.lax.scan(
            body, x, {"p": params["layers"], **cache["layers"]})
        new_cache = {"len": ln + 1, "layers": new_layers}
        if slot_start is not None:
            new_cache["start"] = slot_start

    elif cfg.family == "ssm":
        def body(h, lc):
            sc = {k: lc[k] for k in ("conv_x", "conv_B", "conv_C", "state")}
            h, nc = _decoder_block(h, lc["p"], cfg, dist, positions=pos,
                                   kv_cache=sc)
            return h, nc

        carry, new_layers = jax.lax.scan(
            body, x, {"p": params["layers"], **cache["layers"]})
        new_cache = {"len": ln + 1, "layers": new_layers}

    elif cfg.family == "hybrid":
        def group_body(h, gc):
            def body(hh, lc):
                sc = {k: lc[k] for k in ("conv_x", "conv_B", "conv_C", "state")}
                hh, nc = _decoder_block(hh, lc["p"], cfg, dist, positions=pos,
                                        kv_cache=sc)
                return hh, nc

            h, new_inner = jax.lax.scan(
                body, h, {"p": gc["p"], **gc["ssm"]})
            kv = {"k": gc["shared"]["k"], "v": gc["shared"]["v"], "len": ln}
            h, nkv = _shared_block(h, params["shared"], cfg, dist,
                                   positions=pos, kv_cache=kv)
            return h, {"ssm": new_inner,
                       "shared": {"k": nkv["k"], "v": nkv["v"]}}

        gc = {"p": params["layers"],
              "ssm": cache["layers"], "shared": cache["shared"]}
        carry, new_groups = jax.lax.scan(group_body, x, gc)
        new_cache = {"len": ln + 1, "layers": new_groups["ssm"],
                     "shared": new_groups["shared"]}

    elif cfg.family == "encdec":
        def body(h, lc):
            kv = {"k": lc["k"], "v": lc["v"], "len": ln}
            h, nc = _decoder_block(h, lc["p"], cfg, dist, positions=pos,
                                   kv_cache=kv,
                                   enc_out=(lc["ck"], lc["cv"]))
            return h, {"k": nc["k"], "v": nc["v"]}

        carry, new_layers = jax.lax.scan(
            body, x, {"p": params["dec_layers"], **cache["layers"],
                      "ck": cache["cross"]["k"], "cv": cache["cross"]["v"]})
        new_cache = {"len": ln + 1, "layers": new_layers,
                     "cross": cache["cross"]}
    else:
        raise ValueError(cfg.family)

    logits = _logits(params, cfg, carry, dist)
    return logits, new_cache


def prefill(params, cfg: ModelConfig, batch: dict, cache: dict,
            dist: Distribution = L.LOCAL):
    """Fill the cache from a prompt by running decode_step over positions.
    (Small-scale serving helper; the big prefill shapes lower ``forward``.)"""
    tokens = batch["tokens"]
    B, S = tokens.shape

    if cfg.family == "encdec":
        enc = batch["frames"]

        def enc_body(h, lp):
            return _encoder_block(h, lp, cfg, dist), None

        enc_out, _ = jax.lax.scan(enc_body, enc, params["enc_layers"])
        enc_out = L.rms_norm(enc_out, params["enc_norm"], cfg.norm_eps)

        def cross_kv(lp):
            kc = L.dense(enc_out, lp["cross"]["wk"], "cross_k")
            vc = L.dense(enc_out, lp["cross"]["wv"], "cross_v")
            kc = kc.reshape(B, -1, cfg.n_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
            vc = vc.reshape(B, -1, cfg.n_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
            return kc, vc

        ck, cv = jax.lax.map(cross_kv, params["dec_layers"])
        cache["cross"] = {"k": ck.astype(cache["cross"]["k"].dtype),
                          "v": cv.astype(cache["cross"]["v"].dtype)}

    def step(carry, t):
        cache, last = carry
        logits, cache = decode_step(params, cfg, cache, t[:, None], dist)
        return (cache, logits[:, 0]), None

    (cache, last), _ = jax.lax.scan(step, (cache, jnp.zeros(
        (B, cfg.padded_vocab), jnp.float32)), tokens.T)
    return last, cache
