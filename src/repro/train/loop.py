"""Training loop substrate: loss, train step (with microbatched gradient
accumulation — optionally in the paper's fixed-point grid for bitwise
order-invariant accumulation), and a fault-tolerant Trainer driver."""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.accumulator import AccumulatorSpec
from repro.core.dispatch import NumericsPolicy, use_policy
from repro.models import layers as L
from repro.models import transformer as T

from .optimizer import Optimizer, apply_updates


def make_loss_fn(cfg, dist: L.Distribution = L.LOCAL, *, z_loss: float = 0.0,
                 remat: str = "block", moe_impl: str = "tp",
                 loss_chunk: int = 512):
    """Next-token CE over batch {"tokens","targets","loss_mask", extras}.

    The CE is computed in sequence chunks with a checkpointed step so the
    (B, S, vocab) logits tensor is never materialized — each chunk's logits
    are recomputed from the hidden states during backward (vocab-TP friendly).
    """

    def loss_fn(params, batch):
        hidden = T.forward(params, cfg, batch, dist, remat=remat,
                           moe_impl=moe_impl, return_hidden=True)
        if cfg.family == "vlm":                 # text positions only
            hidden = hidden[:, cfg.n_patches:]
        targets = batch["targets"]
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones(targets.shape, jnp.float32)
        B, S, d = hidden.shape
        ck = min(loss_chunk, S)
        pad = (-S) % ck
        if pad:
            hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
            targets = jnp.pad(targets, ((0, 0), (0, pad)))
            mask = jnp.pad(mask, ((0, 0), (0, pad)))
        nc = hidden.shape[1] // ck
        hc = jnp.moveaxis(hidden.reshape(B, nc, ck, d), 1, 0)
        tc = jnp.moveaxis(targets.reshape(B, nc, ck), 1, 0)
        mc = jnp.moveaxis(mask.reshape(B, nc, ck), 1, 0)
        head = params["lm_head"]

        def chunk_step(carry, xs):
            nll_sum, zsum, correct = carry
            h, t, m = xs
            # keep lm_head vocab-TP: gather the (small) h chunk over tp, NOT
            # the (huge) vocab-sharded head — logits stay vocab-sharded and
            # the logsumexp reduces with a psum (§Perf hillclimb #2)
            h = dist.constrain(h, dist.dp, None, None)
            logits = L.dense(h.astype(jnp.float32), head.astype(jnp.float32),
                             "lm_head")
            logits = dist.constrain(logits, dist.dp, None, dist.tp_axis)
            logits = logits[..., :cfg.vocab_size]
            lse = jax.scipy.special.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
            nll_sum = nll_sum + jnp.sum((lse - gold) * m)
            zsum = zsum + jnp.sum(jnp.square(lse) * m)
            correct = correct + jnp.sum((logits.argmax(-1) == t) * m)
            return (nll_sum, zsum, correct), None

        (nll_sum, zsum, correct), _ = jax.lax.scan(
            jax.checkpoint(chunk_step),
            (jnp.float32(0), jnp.float32(0), jnp.float32(0)), (hc, tc, mc))
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        loss = nll_sum / denom
        if z_loss:
            loss = loss + z_loss * zsum / denom
        acc = correct / denom
        return loss, {"loss": loss, "accuracy": acc}

    return loss_fn


def make_train_step(cfg, opt: Optimizer, dist: L.Distribution = L.LOCAL, *,
                    remat: str = "block", microbatches: int = 1,
                    fdp_grad_spec: Optional[AccumulatorSpec] = None,
                    z_loss: float = 0.0, moe_impl: str = "tp",
                    donate: bool = True,
                    numerics_policy: Optional[NumericsPolicy] = None):
    """Returns jitted ((params, opt_state), batch) -> ((params, opt_state),
    metrics).

    microbatches > 1: gradients accumulated over a scan of microbatches.
    fdp_grad_spec: accumulate microbatch gradients on the paper's fixed-point
    grid (int32) — bitwise identical results for ANY microbatch split.
    numerics_policy: trace the whole step (forward AND the value_and_grad
    backward) under this policy, so a PrecisionPlan's phase-qualified bwd
    assignments (``attn_qk@bwd.dA``) actually dispatch in training — no
    reliance on an ambient ``use_policy`` context being live at first call.
    Defaults to the policy riding on ``dist`` (launch profiles put the
    deployed plan's policy there — see ``launch.sharding.distribution_for``),
    so the same plan survives into shard_map'd mesh runs unchanged.
    """
    if numerics_policy is None:
        numerics_policy = getattr(dist, "numerics_policy", None)
    loss_fn = make_loss_fn(cfg, dist, z_loss=z_loss, remat=remat,
                           moe_impl=moe_impl)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return grads, metrics

    def accumulate(params, batch):
        # split leading batch dim into microbatches
        def split(x):
            b = x.shape[0]
            assert b % microbatches == 0
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])

        mb = jax.tree.map(split, batch)
        lsb = fdp_grad_spec.lsb if fdp_grad_spec else 0
        scale = 2.0 ** lsb

        def quant(g):
            return jnp.round(g.astype(jnp.float32) / scale).astype(jnp.int32)

        def body(acc, b1):
            grads, metrics = single(params, b1)
            if fdp_grad_spec is not None:
                acc = jax.tree.map(lambda a, g: a + quant(g), acc, grads)
            else:
                acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                   acc, grads)
            return acc, metrics

        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape,
                                jnp.int32 if fdp_grad_spec else jnp.float32),
            params)
        acc, ms = jax.lax.scan(body, zero, mb)
        if fdp_grad_spec is not None:
            grads = jax.tree.map(
                lambda a, p: (a.astype(jnp.float32) * scale / microbatches
                              ).astype(p.dtype), acc, params)
        else:
            grads = jax.tree.map(lambda a, p: (a / microbatches).astype(p.dtype),
                                 acc, params)
        metrics = jax.tree.map(lambda m: m.mean(), ms)
        return grads, metrics

    def step(carry, batch):
        params, opt_state = carry
        # policy context at *trace* time: dispatch lookups (fwd and bwd —
        # custom_vjp rules trace inside the same context) resolve under the
        # plan's policy, and a later retrace (new shapes, donated buffers)
        # re-applies it instead of depending on the ambient thread state.
        # The obs span brackets the trace (step compilation shows up in
        # --trace-out timelines); execution cost lives in the Trainer's
        # per-step span/histogram.
        from repro.obs.spans import span as _span
        ctx = (use_policy(numerics_policy) if numerics_policy is not None
               else contextlib.nullcontext())
        with _span("train.step_trace", microbatches=microbatches,
                   policy=getattr(numerics_policy, "name", None)), ctx:
            if microbatches > 1:
                grads, metrics = accumulate(params, batch)
            else:
                grads, metrics = single(params, batch)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = dict(metrics)
        metrics["grad_norm"] = opt_state["grad_norm"]
        return (params, opt_state), metrics

    return jax.jit(step, donate_argnums=(0,) if donate else ())


# ---------------------------------------------------------------------------
# Mesh-sharded data parallelism with exact gradient reduction
# ---------------------------------------------------------------------------
def sharded_value_and_grad(loss_fn, axis_names, *,
                           fdp_grad_spec: Optional[AccumulatorSpec] = None,
                           grad_quant=None):
    """Data-parallel value_and_grad for shard_map bodies: local gradients,
    cross-device mean over ``axis_names`` (a name or tuple of names).

    With ``fdp_grad_spec``, each device's local gradient is quantized onto
    the fixed-point grid and the mean runs as an integer psum with ONE
    dequantize against a constant denominator — bitwise identical for any
    reduction order or mesh factorization of the same device set (integer
    addition is associative and commutative). Without a spec, a plain float
    psum (fast, order-dependent). Loss/aux metrics reduce with float pmean
    either way — they are diagnostics, not part of the bit-equality contract.

    ``grad_quant`` (a block-mode ``qformat.QuantConfig``) instead sends the
    gradient mean through ``parallel.collectives.quantized_psum`` — a
    block-scaled low-bit payload that moves ~``bits/32`` of the fp32 wire
    bytes (the ``grad_psum@coll`` precision site). ``fdp_grad_spec`` takes
    precedence: the repro-certified fixed-point path stays bit-exact and a
    plan that pins it is never silently downgraded. Error feedback is a
    stateful deployment concern — carry it with
    ``parallel.collectives.QuantizedGradReducer``, not here.
    """
    from repro.parallel.compat import axis_size

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def fn(params, batch):
        (loss, aux), grads = grad_fn(params, batch)
        n = axis_size(axis_names)
        if fdp_grad_spec is not None:
            scale = 2.0 ** fdp_grad_spec.lsb

            def one(g):
                q = jnp.round(g.astype(jnp.float32) / scale).astype(jnp.int32)
                s = jax.lax.psum(q, axis_names)
                return (s.astype(jnp.float32) * scale / n).astype(g.dtype)
        elif grad_quant is not None and grad_quant.mode == "block":
            from repro.parallel.collectives import quantized_psum

            def one(g):
                return quantized_psum(g, axis_names, grad_quant, mean=True)
        else:
            def one(g):
                return (jax.lax.psum(g, axis_names) / n).astype(g.dtype)

        grads = jax.tree.map(one, grads)
        loss = jax.lax.pmean(loss, axis_names)
        aux = jax.tree.map(lambda m: jax.lax.pmean(m, axis_names), aux)
        return (loss, aux), grads

    return fn


def make_mesh_train_step(cfg, opt: Optimizer, dist: L.Distribution, *,
                         remat: str = "none", z_loss: float = 0.0,
                         fdp_grad_spec: Optional[AccumulatorSpec] = None,
                         numerics_policy: Optional[NumericsPolicy] = None,
                         grad_quant=None):
    """Train step sharded over the FLATTENED mesh (pure data parallelism):
    the global batch is split over ALL mesh axes jointly, each device runs
    the full (unsharded) model on its slice under the plan's policy, and
    gradients reduce through ``sharded_value_and_grad``.

    Per-device shapes depend only on the joint device COUNT, never on the
    mesh factorization — so every device's local compute is bit-identical on
    1x8, 2x4 and 8x1 meshes of the same 8 devices, and with ``fdp_grad_spec``
    the cross-device gradient reduction is an exact integer psum: one step
    produces bit-identical logits, loss-gradients and updated params for any
    mesh reshape (the contract ``repro.workloads.mesh`` validates and the
    ``mesh_reshape_logits`` distributed check guards). PrecisionPlans apply
    unchanged: ``use_policy`` resolves at trace time, inside shard_map.

    ``grad_quant=None`` reads the collective format off the policy's
    ``grad_psum@coll`` aux assignment (searched plans wire themselves);
    ``fdp_grad_spec`` still takes precedence inside
    ``sharded_value_and_grad``, preserving the mesh-reshape bit-identity
    contract on the repro path.

    Returns jitted ((params, opt_state), global_batch) -> ((params,
    opt_state), metrics); params/opt_state replicated, batch global.
    """
    from jax.sharding import PartitionSpec as P

    from repro.core import qformat
    from repro.parallel.compat import shard_map_unchecked

    if numerics_policy is None:
        numerics_policy = dist.numerics_policy
    if grad_quant is None and numerics_policy is not None:
        grad_quant = numerics_policy.aux_lookup(qformat.GRAD_PSUM_SITE.key)
    mesh = dist.mesh
    axes = tuple(mesh.axis_names)
    loss_fn = make_loss_fn(cfg, L.LOCAL, z_loss=z_loss, remat=remat)
    vg = sharded_value_and_grad(loss_fn, axes, fdp_grad_spec=fdp_grad_spec,
                                grad_quant=grad_quant)

    def body(carry, batch):
        params, opt_state = carry
        (loss, metrics), grads = vg(params, batch)
        # grads/params replicated after the psum: the update runs identically
        # on every device, so the new state stays (bitwise) replicated
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = dict(metrics)
        metrics["grad_norm"] = opt_state["grad_norm"]
        return (params, opt_state), metrics

    sharded = shard_map_unchecked(
        body, mesh=mesh,
        in_specs=((P(), P()), P(axes)),
        out_specs=((P(), P()), P()))

    def step(carry, batch):
        from repro.obs.spans import span as _span
        ctx = (use_policy(numerics_policy) if numerics_policy is not None
               else contextlib.nullcontext())
        with _span("train.mesh_step_trace", axes=",".join(axes),
                   policy=getattr(numerics_policy, "name", None)), ctx:
            return sharded(carry, batch)

    return jax.jit(step)


# ---------------------------------------------------------------------------
# Fault-tolerant driver
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time outlier detector. On a real fleet the `on_straggler`
    hook would trigger re-scheduling; here it records and logs."""

    factor: float = 3.0
    alpha: float = 0.1
    ewma: float = 0.0
    events: list = dataclasses.field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        if self.ewma == 0.0:
            self.ewma = dt
            return False
        is_straggler = dt > self.factor * self.ewma
        if is_straggler:
            self.events.append((step, dt, self.ewma))
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler


class Trainer:
    """Checkpointed, restartable training driver.

    Fault tolerance: every step runs under a catch-and-restore guard; a crash
    (or injected failure) rolls back to the last durable checkpoint and
    replays. Data is a pure function of step, so replay is exact.
    """

    def __init__(self, cfg, opt, data, step_fn, checkpoint_dir: str,
                 save_every: int = 50, keep: int = 3,
                 failure_injector: Optional[Callable[[int], None]] = None,
                 place_state: Optional[Callable] = None):
        from repro.checkpoint.store import CheckpointStore
        self.cfg, self.opt, self.data, self.step_fn = cfg, opt, data, step_fn
        self.store = CheckpointStore(checkpoint_dir, keep=keep)
        self.save_every = save_every
        self.monitor = StragglerMonitor()
        self.failure_injector = failure_injector
        self.place_state = place_state
        self.metrics_log: list = []
        from repro.obs.registry import default_registry
        self._m_step = default_registry().histogram(
            "repro_train_step_seconds", "Trainer per-step wall time")
        self._m_restarts = default_registry().counter(
            "repro_train_restarts_total", "fault-tolerant restore events")

    def init_or_restore(self, key):
        from repro.models import init as minit
        restored = self.store.load_latest()
        if restored is not None:
            step, carry = restored[0], (restored[1]["params"],
                                        restored[1]["opt_state"])
        else:
            params = minit(self.cfg, key)
            step, carry = 0, (params, self.opt.init(params))
        if self.place_state is not None:
            # launch profiles device_put the (params, opt_state) carry onto
            # their mesh shardings here — both at cold start and on every
            # post-failure restore, so replay resumes sharded
            carry = self.place_state(carry)
        return step, carry

    def run(self, n_steps: int, key=None, max_restarts: int = 3):
        key = key if key is not None else jax.random.key(0)
        step, carry = self.init_or_restore(key)
        restarts = 0
        from repro.obs.spans import span as _span
        while step < n_steps:
            try:
                t0 = time.perf_counter()
                if self.failure_injector is not None:
                    self.failure_injector(step)
                batch = self.data(step)
                with _span("train.step", step=step):
                    carry, metrics = self.step_fn(carry, batch)
                dt = time.perf_counter() - t0
                self.monitor.record(step, dt)
                self._m_step.observe(dt)
                self.metrics_log.append(
                    {k: float(v) for k, v in metrics.items()} | {"step": step})
                step += 1
                if step % self.save_every == 0 or step == n_steps:
                    self.store.save(step, {"params": carry[0],
                                           "opt_state": carry[1]})
            except (RuntimeError, InjectedFailure) as e:  # node failure
                restarts += 1
                if restarts > max_restarts:
                    raise
                self._m_restarts.inc()
                step, carry = self.init_or_restore(key)
        return carry


class InjectedFailure(RuntimeError):
    pass
