"""From-scratch optimizers (no optax dependency): AdamW with decoupled weight
decay, global-norm clipping, LR schedules, and optional fixed-point
(paper-style) deterministic state dtypes.

Optimizer state is a precision *site*: pass ``state_quant`` (a mapping from
moment name to ``repro.core.qformat.QuantConfig``) and the Adam moments live
in block-scaled low-bit carriers between steps — dequantize, EMA-update,
requantize — cutting the dominant training-memory consumer (fp32 moments are
2x params) to ``bits/32`` of its fp32 bytes. The quantize/dequantize math is
all power-of-two-exact f32, so a quantized step is deterministic and
bit-identical between eager and jit execution. The site identities
(``opt.m@state`` / ``opt.v@state``) let searched ``PrecisionPlan``s assign
these formats the same way they assign GEMM accumulators; use
``state_quant_from_policy`` to read the assignment off a deployed policy."""

from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..core import qformat
from ..core.qformat import QuantConfig


class Optimizer(NamedTuple):
    init: Callable
    update: Callable      # (grads, state, params) -> (updates, state)


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * jnp.minimum(1.0, step / jnp.maximum(warmup, 1))
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: x * scale, tree), norm


def state_quant_from_policy(policy) -> Optional[dict]:
    """Map a ``NumericsPolicy``'s aux assignments onto ``adamw``'s
    ``state_quant`` argument (None when the policy holds both moments at
    fp32 — i.e. no aux entries or explicit fp32 ones)."""
    if policy is None or not getattr(policy, "aux", ()):
        return None
    out = {}
    for moment, site in (("mu", qformat.OPT_M_SITE), ("nu", qformat.OPT_V_SITE)):
        cfg = policy.aux_lookup(site.key)
        if cfg is not None and cfg.mode == "block":
            out[moment] = cfg
    return out or None


def _quantize_moment(tree, cfg: QuantConfig, *, sqrt_domain: bool = False):
    """``sqrt_domain`` is the second-moment safety contract: nu is stored as
    sqrt(nu) (halving the block exponent spread that squaring doubled) and
    rounded *up* on the grid, so the dequantized denominator never
    understates curvature. Without it, a dead parameter whose mu rounds up
    to half a grid step while its nu rounds down to zero takes an
    ``amax/eps``-sized update and the loss curve detonates within a step."""
    if sqrt_domain:
        quant = lambda x: qformat.block_quantize(
            jnp.sqrt(jnp.maximum(x, 0.0).astype(jnp.float32)), cfg,
            rounding="up")
    else:
        quant = lambda x: qformat.block_quantize(x, cfg)
    return jax.tree.map(quant, tree)


def _dequantize_moment(qtree, cfg: QuantConfig, params, *,
                       sqrt_domain: bool = False):
    def deq(c, p):
        x = qformat.block_dequantize(c, cfg, p.shape)
        return jnp.square(x) if sqrt_domain else x
    return jax.tree.map(
        deq, qtree, params,
        is_leaf=lambda x: isinstance(x, dict) and "q" in x)


def optimizer_state_bytes(state, state_quant: Optional[Mapping] = None) -> int:
    """Actual resident bytes of the moment carriers (device array nbytes,
    so the saving is measured, not modeled)."""
    total = 0
    for moment in ("mu", "nu"):
        for leaf in jax.tree.leaves(state[moment]):
            total += leaf.nbytes
    return total


def adamw(lr: float | Callable = 1e-3, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          clip_norm: Optional[float] = 1.0,
          state_dtype=jnp.float32,
          state_quant: Optional[Mapping[str, QuantConfig]] = None) -> Optimizer:
    """``state_quant`` maps moment names ("mu", "nu") to block-scaled
    ``QuantConfig``s; listed moments persist as int8/int16 carriers and go
    through dequant -> EMA update -> requant each step. Unlisted moments
    keep ``state_dtype``. fp32-mode configs are treated as unlisted."""
    lr_fn = lr if callable(lr) else (lambda _: lr)
    squant = {k: v for k, v in (state_quant or {}).items()
              if v.mode == "block"}
    for k in squant:
        if k not in ("mu", "nu"):
            raise ValueError(f"state_quant key {k!r} (expected 'mu'/'nu')")

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        state = {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
            "grad_norm": jnp.zeros((), jnp.float32),
        }
        for moment, cfg in squant.items():
            state[moment] = _quantize_moment(state[moment], cfg,
                                             sqrt_domain=moment == "nu")
        return state

    def update(grads, state, params):
        step = state["step"] + 1
        gnorm = jnp.float32(0)
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        mom = {}
        for moment in ("mu", "nu"):
            cfg = squant.get(moment)
            mom[moment] = (state[moment] if cfg is None else
                           _dequantize_moment(state[moment], cfg, params,
                                              sqrt_domain=moment == "nu"))
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(state_dtype),
                          mom["mu"], grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(state_dtype)),
            mom["nu"], grads)
        t = step.astype(jnp.float32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t
        lr_t = lr_fn(step)

        def upd(m, v, p):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(state_dtype)
            return (-lr_t * u).astype(p.dtype)

        updates = jax.tree.map(upd, mu, nu, params)
        # Requantize *after* the update is computed from the full-precision
        # moments, so the parameter step sees this step's gradient exactly;
        # only the carried-over EMA tail is rounded.
        if "mu" in squant:
            mu = _quantize_moment(mu, squant["mu"])
        if "nu" in squant:
            nu = _quantize_moment(nu, squant["nu"], sqrt_domain=True)
        return updates, {"mu": mu, "nu": nu, "step": step,
                         "grad_norm": gnorm}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u, params, updates)
