"""From-scratch optimizers (no optax dependency): AdamW with decoupled weight
decay, global-norm clipping, LR schedules, and optional fixed-point
(paper-style) deterministic state dtypes."""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable      # (grads, state, params) -> (updates, state)


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * jnp.minimum(1.0, step / jnp.maximum(warmup, 1))
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: x * scale, tree), norm


def adamw(lr: float | Callable = 1e-3, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          clip_norm: Optional[float] = 1.0,
          state_dtype=jnp.float32) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
            "grad_norm": jnp.zeros((), jnp.float32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        gnorm = jnp.float32(0)
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(state_dtype),
                          state["mu"], grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(state_dtype)),
            state["nu"], grads)
        t = step.astype(jnp.float32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t
        lr_t = lr_fn(step)

        def upd(m, v, p):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(state_dtype)
            return (-lr_t * u).astype(p.dtype)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, {"mu": mu, "nu": nu, "step": step,
                         "grad_norm": gnorm}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u, params, updates)
