from .optimizer import adamw, cosine_schedule, Optimizer
from .loop import make_train_step, make_loss_fn, Trainer
