from .optimizer import (adamw, cosine_schedule, Optimizer,
                        state_quant_from_policy, optimizer_state_bytes)
from .loop import make_train_step, make_loss_fn, Trainer
