"""Bucketed AOT engine pool — the saxml ``ServableMethod`` shape.

A serving deployment cannot compile one executable per request shape, and it
cannot keep every (plan x shape x method) executable resident either. This
module does what saxml's servable models do: a small *sorted* table of
(batch-slots, sequence-length) **buckets**, per-(plan, bucket, method)
AOT-compiled executables created **lazily** on first traffic, padded-shape
dispatch to the smallest fitting bucket, and **LRU eviction** under a
live-engine cap so the pool's device footprint stays bounded no matter how
many plans the router serves.

Methods (the saxml trio):
    ``generate`` - fixed-slot continuous batching (``ContinuousBatcher``)
    ``stream``   - same engine shape, tokens delivered through per-request
                   ``on_token`` callbacks as each decode step lands
    ``score``    - teacher-forced log-probability of the prompt, one padded
                   whole-batch forward per bucket

Every engine warms up under its plan's ``NumericsPolicy`` (the plan-zoo
contract: numerics bind at trace time) and exposes ``trace_count`` so tests
can prove padded dispatch reuses the bucket executable instead of retracing.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import NumericsPolicy, use_policy
from repro.launch.batching import ContinuousBatcher, Request
from repro.models import forward
from repro.obs.registry import default_registry
from repro.obs.spans import span

METHODS = ("score", "generate", "stream")


class AdmissionError(RuntimeError):
    """The request can never be served by this pool/frontend: no bucket fits
    its ``prompt + max_new``, or the queue is at its backpressure cap."""


@dataclasses.dataclass(frozen=True, order=True)
class Bucket:
    """One (slots, padded sequence length) serving shape. Ordering is by
    sequence capacity first — ``bucket_for`` picks the smallest fit."""

    max_len: int
    n_slots: int

    def __post_init__(self):
        if self.n_slots < 1 or self.max_len < 4:
            raise ValueError(f"degenerate bucket {self.label}")

    @property
    def label(self) -> str:
        return f"{self.n_slots}x{self.max_len}"

    @property
    def capacity(self) -> int:
        """Positions a request may consume (the engine keeps one sentinel)."""
        return self.max_len - 1


def parse_buckets(spec: str) -> tuple:
    """``"2x32,4x64"`` -> sorted (Bucket(32,2), Bucket(64,4)). The textual
    order is slots x len (the saxml batch-size-table convention)."""
    buckets = []
    for part in spec.split(","):
        ns, _, ml = part.strip().partition("x")
        buckets.append(Bucket(max_len=int(ml), n_slots=int(ns)))
    return tuple(sorted(set(buckets)))


class GenerateEngine:
    """A ``ContinuousBatcher`` bound to one (plan, bucket): the ``generate``
    and ``stream`` executables. Streaming is the same compiled step — tokens
    leave through ``Request.on_token`` as they land."""

    def __init__(self, cfg, params, bucket: Bucket,
                 policy: Optional[NumericsPolicy], method: str,
                 eos_id: Optional[int] = None):
        self.bucket, self.method = bucket, method
        self.batcher = ContinuousBatcher(
            cfg, params, n_slots=bucket.n_slots, max_len=bucket.max_len,
            eos_id=eos_id, warmup=policy if policy is not None else True)

    @property
    def trace_count(self) -> int:
        return self.batcher.trace_count

    def idle(self) -> bool:
        return (not self.batcher.queue
                and all(r is None for r in self.batcher.active))

    def cache_remaining(self) -> int:
        return self.batcher.cache_remaining()

    def recycle_if_exhausted(self, need: int) -> None:
        """Fresh KV room for a request needing ``need`` positions — only
        possible while drained; the compiled step survives the reset."""
        if self.idle() and self.batcher.cache_remaining() < need:
            self.batcher.reset_cache()

    def admit(self, req: Request) -> None:
        self.batcher.submit(req)

    def step(self) -> bool:
        return self.batcher.step()


class ScoreEngine:
    """Teacher-forced prompt log-probability, AOT-compiled at the bucket
    shape: one padded (n_slots, max_len) forward, per-row masked sum of
    next-token log-probs."""

    def __init__(self, cfg, params, bucket: Bucket,
                 policy: Optional[NumericsPolicy]):
        self.bucket = bucket
        self.method = "score"
        self.trace_count = 0

        def fn(tokens, mask):
            self.trace_count += 1            # python side effect: trace only
            batch = {"tokens": tokens}
            if cfg.family == "vlm":
                batch["patches"] = jnp.zeros(
                    (bucket.n_slots, cfg.n_patches, cfg.d_model))
            if cfg.family == "encdec":
                batch["frames"] = jnp.zeros(
                    (bucket.n_slots, cfg.enc_seq, cfg.d_model))
            logits = forward(params, cfg, batch)
            # keep the text positions (vlm prepends patch positions)
            logits = logits[:, -tokens.shape[1]:, :cfg.vocab_size]
            logp = jax.nn.log_softmax(logits, axis=-1)
            lp = jnp.take_along_axis(
                logp[:, :-1], tokens[:, 1:, None], axis=-1)[..., 0]
            return jnp.sum(lp * mask[:, 1:], axis=-1)

        tok0 = jnp.zeros((bucket.n_slots, bucket.max_len), jnp.int32)
        mask0 = jnp.zeros((bucket.n_slots, bucket.max_len), jnp.float32)
        ctx = use_policy(policy) if policy is not None else _nullctx()
        with ctx:
            self._fn = jax.jit(fn).lower(tok0, mask0).compile()

    def idle(self) -> bool:
        return True                          # one-shot: no resident state

    def score_batch(self, prompts: Sequence[Sequence[int]]) -> list:
        """Score up to ``n_slots`` prompts in one padded executable call."""
        if len(prompts) > self.bucket.n_slots:
            raise ValueError(f"{len(prompts)} prompts > bucket "
                             f"{self.bucket.label}")
        toks = np.zeros((self.bucket.n_slots, self.bucket.max_len), np.int32)
        mask = np.zeros((self.bucket.n_slots, self.bucket.max_len),
                        np.float32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
            mask[i, :len(p)] = 1.0
        out = np.asarray(self._fn(jnp.asarray(toks), jnp.asarray(mask)))
        return [float(out[i]) for i in range(len(prompts))]


def _nullctx():
    import contextlib
    return contextlib.nullcontext()


class BucketedEnginePool:
    """Lazy (plan, bucket, method) -> engine cache with LRU eviction.

    ``max_live`` bounds resident engines; eviction only takes *idle* engines
    (a live engine holds in-flight KV state), so the pool may transiently
    exceed the cap when every engine is mid-generation — it shrinks back on
    the next miss. All bookkeeping is exposed via ``stats()``:
    compiles/hits/evictions plus per-bucket dispatch counts (the bench's
    bucket hit rate)."""

    def __init__(self, cfg, params, buckets: Union[str, Sequence[Bucket]],
                 max_live: int = 4, eos_id: Optional[int] = None):
        if isinstance(buckets, str):
            buckets = parse_buckets(buckets)
        self.buckets = tuple(sorted(set(buckets)))
        if not self.buckets:
            raise ValueError("pool needs at least one bucket")
        self.cfg, self.params, self.eos_id = cfg, params, eos_id
        self.max_live = max_live
        self._engines: OrderedDict = OrderedDict()
        self._stats = {"compiles": 0, "hits": 0, "evictions": 0}
        self._bucket_hits: dict = {b.label: 0 for b in self.buckets}
        # process-wide mirror of the per-instance counters (the dicts above
        # stay this pool's exact source of truth)
        self._m_ops = default_registry().counter(
            "repro_engine_pool_ops_total",
            "bucketed engine pool events", ("op",))
        self._m_resident = default_registry().gauge(
            "repro_engine_pool_resident", "engines resident in the pool")

    def bucket_for(self, prompt_len: int, max_new: int) -> Bucket:
        """Smallest bucket whose capacity fits ``prompt + max_new`` (padded
        dispatch: the request runs at the bucket shape, reusing its
        executable)."""
        need = prompt_len + max_new
        for b in self.buckets:
            if need <= b.capacity:
                return b
        raise AdmissionError(
            f"request needs {need} positions; largest bucket is "
            f"{self.buckets[-1].label} (capacity {self.buckets[-1].capacity})")

    def get(self, plan, bucket: Bucket, method: str):
        """The engine for (plan, bucket, method), compiling on first use.
        ``plan`` is a ``RoutedPlan`` (anything with ``.name``/``.policy()``)."""
        if method not in METHODS:
            raise ValueError(f"unknown method {method!r}; have {METHODS}")
        if bucket not in self.buckets:
            raise ValueError(f"bucket {bucket.label} not in this pool")
        key = (plan.name, bucket, method)
        eng = self._engines.get(key)
        if eng is not None:
            self._engines.move_to_end(key)
            self._stats["hits"] += 1
            self._m_ops.inc(op="hits")
            self._bucket_hits[bucket.label] += 1
            return eng
        self._evict_idle()
        policy = plan.policy()
        with span("serving.aot_compile", plan=plan.name, bucket=bucket.label,
                  method=method):
            if method == "score":
                eng = ScoreEngine(self.cfg, self.params, bucket, policy)
            else:
                eng = GenerateEngine(self.cfg, self.params, bucket, policy,
                                     method, eos_id=self.eos_id)
        self._engines[key] = eng
        self._stats["compiles"] += 1
        self._m_ops.inc(op="compiles")
        self._m_resident.set(float(len(self._engines)))
        self._bucket_hits[bucket.label] += 1
        return eng

    def _evict_idle(self) -> None:
        """Drop least-recently-used *idle* engines until under the cap."""
        while len(self._engines) >= self.max_live:
            victim = next((k for k, e in self._engines.items() if e.idle()),
                          None)
            if victim is None:
                return                       # everything is mid-generation
            del self._engines[victim]
            self._stats["evictions"] += 1
            self._m_ops.inc(op="evictions")
            self._m_resident.set(float(len(self._engines)))

    def live(self) -> dict:
        return dict(self._engines)

    def stats(self) -> dict:
        """Per-instance pool bookkeeping (exact counts for this pool).

        .. deprecated:: the process-wide scrape surface is the ``repro.obs``
           registry (``repro_engine_pool_ops_total`` /
           ``repro_engine_pool_resident``); this dict remains the exact
           per-instance view.
        """
        from repro.core.dispatch import plan_cache_stats
        total = sum(self._bucket_hits.values())
        return {**self._stats, "resident": len(self._engines),
                "bucket_hits": dict(self._bucket_hits),
                "bucket_hit_rate": (self._stats["hits"] / total
                                    if total else 0.0),
                # GemmPlan cache counters (process-global): the serving-tier
                # health signal for the schedule zoo — warm pools show
                # misses == 0, persisted_loads > 0
                "plans": plan_cache_stats().as_dict()}
