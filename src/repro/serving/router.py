"""PlanRouter: workload-conditional plan selection over the plan zoo.

The MANIFEST already records, for every plan, the per-workload validation
scores the ``repro.workloads`` zoo earned it (solve/grad/repro/logits) plus
the modeled-energy bookkeeping. This module turns that recorded evidence into
a request-time routing table: a request declares a *workload class* —
``chat`` (cheapest passing plan), ``solve`` (highest solve-workload score;
FDP-wide numerics), ``repro`` (bit-stable replies: repro-certified plans
only) — or an explicit plan name, plus optional constraints (minimum
validated bits, bit-stability), and the router answers with a concrete
``RoutedPlan`` whose ``policy()`` the engine pool compiles under. Requests
whose constraints no zoo plan satisfies get a typed ``RoutingError``, never a
silent fallback.

Derived variants
----------------
A zoo entry is one tailored plan per architecture, but one served model wants
*several* numerics on the menu. ``from_manifest(..., derive=True)`` therefore
registers, next to each tailored plan, two derived variants whose numerics
come from the plan document itself:

``<name>/fdp91``
    The paper's flagship uniform numerics (fp32 operands through the
    ⟨30,30,-30⟩ 91-bit FDP) — the solve-class oracle. Bit-stable by
    construction (wrap-mode integer accumulation is exactly associative), at
    baseline energy (1.0 — it *is* the energy normalization).

``<name>/repro``
    Bit-stable serving at chat-grade fidelity: the plan's default serving
    format (bf16 for every zoo plan) through the same 91-bit wrap
    accumulator, simulate mode everywhere. Reorder-exact like the wide
    variant but with the cheap multiplier, so the repro class routes here
    instead of paying solve-class energy for stability.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Callable, Optional, Sequence, Union

from repro.core.accumulator import AccumulatorSpec
from repro.core.dispatch import FDP91, GemmConfig, NumericsPolicy
from repro.core.energy import gemm_power
from repro.core.formats import FP32

WORKLOAD_CLASSES = ("chat", "solve", "repro")

# Bit-exact FDP accumulation scores at the f64-reference measurement cap in
# the workload zoo (reproducibility.py probes against float64); a recorded
# repro score at/above this certifies bit-stability under reordering.
FDP_CAP_BITS = 53.0
REPRO_CERT_BITS = 50.0


class RoutingError(ValueError):
    """No zoo plan satisfies the request's workload class + constraints.
    ``workload`` names the class (or explicit plan) that failed to route,
    ``reason`` says why — the typed rejection the frontend surfaces."""

    def __init__(self, workload: str, reason: str):
        super().__init__(f"cannot route {workload!r}: {reason}")
        self.workload = workload
        self.reason = reason


def _numeric(x) -> Optional[float]:
    """A score usable for routing: a real, finite number or None."""
    if isinstance(x, bool) or not isinstance(x, (int, float)):
        return None
    if x != x or x in (float("inf"), float("-inf")):
        return None
    return float(x)


@dataclasses.dataclass
class RoutedPlan:
    """One routable entry: recorded per-workload evidence plus a lazy policy
    source (a plan path, a ready NumericsPolicy, or a loader callable)."""

    name: str
    arch: Optional[str] = None
    scores: dict = dataclasses.field(default_factory=dict)   # workload -> bits
    passed: dict = dataclasses.field(default_factory=dict)   # workload -> bool
    energy: float = 1.0                # energy_vs_baseline (1.0 = FDP91-wide)
    validated_bits: Optional[float] = None
    repro_certified: bool = False
    derived: Optional[str] = None      # "fdp91" | "repro" | None (zoo plan)
    path: Optional[str] = None
    loader: Optional[Callable[[], NumericsPolicy]] = None
    _policy: Optional[NumericsPolicy] = dataclasses.field(
        default=None, repr=False)

    def policy(self) -> NumericsPolicy:
        """Resolve (and cache) the NumericsPolicy this entry deploys."""
        if self._policy is None:
            if self.loader is not None:
                self._policy = self.loader()
            elif self.path is not None:
                from repro.core.dispatch import policy_from_plan
                self._policy = policy_from_plan(self.path)
            else:
                raise RoutingError(
                    self.name, "entry has no policy source (path or loader)")
        return self._policy

    def unsatisfied(self, min_bits: Optional[float],
                    bit_stable: bool) -> Optional[str]:
        """Why this plan fails the request's constraints (None = satisfies)."""
        if min_bits is not None:
            got = _numeric(self.validated_bits)
            if got is None or got < min_bits:
                return (f"validated_bits={self.validated_bits} < "
                        f"required {min_bits}")
        if bit_stable and not self.repro_certified:
            return "not repro-certified (replies not bit-stable)"
        return None

    def all_passed(self) -> bool:
        return bool(self.passed) and all(self.passed.values())


class PlanRouter:
    """Index the zoo's recorded evidence; answer workload-class routes."""

    def __init__(self, plans: Sequence[RoutedPlan]):
        self._plans = list(plans)
        self._by_name = {}
        for p in self._plans:
            if p.name in self._by_name:
                raise ValueError(f"duplicate routable plan name {p.name!r}")
            if p.name in WORKLOAD_CLASSES:
                raise ValueError(
                    f"plan name {p.name!r} shadows a workload class")
            self._by_name[p.name] = p
        if not self._plans:
            raise ValueError("router needs at least one routable plan")

    @property
    def plans(self) -> tuple:
        return tuple(self._plans)

    def names(self) -> tuple:
        return tuple(p.name for p in self._plans)

    def __getitem__(self, name: str) -> RoutedPlan:
        return self._by_name[name]

    # -- selection ---------------------------------------------------------
    def route(self, workload: str = "chat", *,
              min_bits: Optional[float] = None,
              bit_stable: bool = False) -> RoutedPlan:
        """Map (workload class | explicit plan name) + constraints to a
        concrete plan; raise ``RoutingError`` when nothing satisfies."""
        if workload in self._by_name:           # explicit plan name wins
            plan = self._by_name[workload]
            reason = plan.unsatisfied(min_bits, bit_stable)
            if reason:
                raise RoutingError(workload, reason)
            return plan
        if workload not in WORKLOAD_CLASSES:
            raise RoutingError(
                workload, f"unknown workload class / plan name; classes are "
                          f"{WORKLOAD_CLASSES}, plans are {self.names()}")

        cands, rejects = [], []
        for p in self._plans:
            reason = p.unsatisfied(min_bits, bit_stable)
            (rejects if reason else cands).append((p, reason))
        cands = [p for p, _ in cands]

        if workload == "repro":
            # bit-stable replies: repro-certified entries only, cheapest
            # first (stability is binary once certified; don't pay solve-
            # class energy for it), strongest repro score on ties
            cands = [p for p in cands if p.repro_certified]
            if not cands:
                raise RoutingError(workload, self._why_empty(rejects,
                                   "no repro-certified plan in the zoo"))
            return min(cands, key=lambda p: (
                p.energy, -(p.scores.get("repro") or 0.0), p.name))

        if workload == "solve":
            # accuracy-critical dots/systems: highest recorded solve-workload
            # score (the derived FDP-wide variant always records the cap),
            # cheapest on ties
            scored = [(p, _numeric(p.scores.get("solve"))) for p in cands]
            scored = [(p, s) for p, s in scored if s is not None]
            if not scored:
                raise RoutingError(workload, self._why_empty(rejects,
                                   "no plan records a solve-workload score"))
            return min(scored, key=lambda ps: (
                -ps[1], ps[0].energy, ps[0].name))[0]

        # chat: cheapest plan whose recorded validations all passed
        cands = [p for p in cands if p.all_passed()]
        if not cands:
            raise RoutingError(workload, self._why_empty(rejects,
                               "no plan with all validations passing"))
        return min(cands, key=lambda p: (
            p.energy, -(_numeric(p.validated_bits) or 0.0), p.name))

    @staticmethod
    def _why_empty(rejects, fallback: str) -> str:
        if rejects:
            detail = "; ".join(f"{p.name}: {r}" for p, r in rejects[:4])
            return f"{fallback} (constraint rejections: {detail})"
        return fallback

    # -- construction from the zoo ------------------------------------------
    @classmethod
    def from_manifest(cls, plans_dir: Union[str, os.PathLike],
                      arch: Optional[str] = None,
                      derive: bool = True) -> "PlanRouter":
        """Build a router from ``<plans_dir>/MANIFEST.json``. ``arch``
        restricts to one served architecture's plans (entry key or the
        recorded ``arch`` alias); ``derive`` adds the fdp91/repro variants
        every served model wants on the menu."""
        manifest_path = os.path.join(os.fspath(plans_dir), "MANIFEST.json")
        with open(manifest_path) as f:
            manifest = json.load(f)
        plans: list = []
        for key, entry in sorted(manifest.get("plans", {}).items()):
            if arch is not None and arch not in (key, entry.get("arch")):
                continue
            rp = routed_plan_from_entry(key, entry, os.fspath(plans_dir))
            plans.append(rp)
            if derive:
                plans.extend(derive_variants(rp))
        if not plans:
            raise RoutingError(
                arch or "*", f"no MANIFEST entry matches arch={arch!r} "
                             f"in {manifest_path}")
        return cls(plans)


def routed_plan_from_entry(key: str, entry: dict,
                           plans_dir: str) -> RoutedPlan:
    """One MANIFEST entry -> one routable plan. Raises ValueError when the
    entry is missing the routing metadata the router reads (the plan-zoo
    gate calls this for exactly that check)."""
    validation = entry.get("validation")
    if not isinstance(validation, dict) or not validation:
        raise ValueError(f"{key}: MANIFEST entry carries no validation "
                         "scores — the router has nothing to rank it by")
    scores, passed = {}, {}
    for w, rep in validation.items():
        score = _numeric(rep.get("score")) if isinstance(rep, dict) else None
        if score is None:
            raise ValueError(f"{key}: validation[{w!r}] score is not a "
                             f"finite number: {rep!r}")
        scores[w] = score
        passed[w] = bool(rep.get("passed"))
    energy = _numeric(entry.get("energy_vs_baseline"))
    if energy is None:
        raise ValueError(f"{key}: energy_vs_baseline is not numeric "
                         f"({entry.get('energy_vs_baseline')!r})")
    certified = bool(entry.get("repro_certified", (
        passed.get("repro", False) and
        (scores.get("repro") or 0.0) >= REPRO_CERT_BITS)))
    return RoutedPlan(
        name=key, arch=entry.get("arch"),
        scores=scores, passed=passed, energy=energy,
        validated_bits=_numeric(entry.get("validated_bits")),
        repro_certified=certified,
        path=os.path.join(plans_dir, entry.get("file", f"{key}.json")))


def derive_variants(rp: RoutedPlan) -> list:
    """The two derived serving variants of one tailored zoo plan (module
    docstring). Numerics and metadata come from the plan document: the repro
    variant runs the plan *default's* format (the serving grade the plan was
    searched around) through the paper's 91-bit wrap accumulator."""
    from repro.numerics import load_plan      # deferred: numerics imports core
    plan = load_plan(rp.path)
    spec = AccumulatorSpec.paper_91bit()
    fmt = plan.default.fmt
    repro_policy = NumericsPolicy(
        GemmConfig(fmt, spec, "simulate"), name=f"repro_pinned:{rp.name}")
    # modeled energy of the pinned variant relative to the FDP91 baseline:
    # same 91-bit accumulate, multiplier at the serving format's precision
    pinned = (gemm_power(fmt, spec).watts /
              gemm_power(FP32, spec).watts)
    wide = RoutedPlan(
        name=f"{rp.name}/fdp91", arch=rp.arch,
        scores={"solve": FDP_CAP_BITS, "repro": FDP_CAP_BITS,
                "logits": FDP_CAP_BITS},
        passed={"solve": True, "repro": True, "logits": True},
        energy=1.0, validated_bits=FDP_CAP_BITS, repro_certified=True,
        derived="fdp91", loader=lambda: FDP91)
    stable = RoutedPlan(
        name=f"{rp.name}/repro", arch=rp.arch,
        scores={"repro": FDP_CAP_BITS,
                # fidelity floor is the serving format's significand: the
                # multiplier quantizes operands onto fmt's grid before the
                # (exact) accumulation
                "logits": float(min(rp.validated_bits or FDP_CAP_BITS,
                                    fmt.precision))},
        passed={"repro": True, "logits": True},
        energy=min(1.0, pinned), validated_bits=float(fmt.precision),
        repro_certified=True, derived="repro",
        loader=lambda: repro_policy)
    return [wide, stable]
