"""Serving frontend: request queue, admission control, backpressure, futures.

The layer between clients and the engine pool. A ``ServeRequest`` declares
its workload class (or an explicit plan), its method, and its constraints;
``submit`` routes it (``PlanRouter``), picks its bucket (padded dispatch),
and returns a ``Completion`` future immediately. ``run`` is the cooperative
event loop: it activates (plan, bucket, method) groups under a
``max_live_batches`` backpressure cap, feeds engines only what their KV
budget admits (parking the rest — never the old silent truncation), recycles
drained engines whose cursor ran out of room, steps every live engine, and
resolves futures as requests finish. Streaming requests get their tokens
through ``on_token`` callbacks from inside the decode step that produced
them.

Typed failure surface: ``RoutingError`` (no plan satisfies the request) and
``AdmissionError`` (no bucket fits / queue at cap) resolve the future as
rejected — one bad request never takes the loop down.
"""

from __future__ import annotations

import dataclasses
import time
from collections import Counter, OrderedDict, deque
from typing import Callable, Optional

from repro.launch.batching import Request
from .engine import AdmissionError, BucketedEnginePool, GenerateEngine
from .router import PlanRouter, RoutingError


@dataclasses.dataclass
class ServeRequest:
    """One client request. ``workload`` is a class (chat/solve/repro) or an
    explicit plan name; ``method`` one of score/generate/stream."""

    uid: int
    prompt: list
    max_new: int = 16
    workload: str = "chat"
    method: str = "generate"
    min_bits: Optional[float] = None
    bit_stable: bool = False
    on_token: Optional[Callable[[int], None]] = None   # stream delivery


class Completion:
    """Per-request completion future (host-side: the loop is cooperative).
    ``result()`` returns generated tokens (generate/stream) or the prompt
    log-probability (score); rejected/failed requests re-raise their typed
    error."""

    def __init__(self, request: ServeRequest):
        self.request = request
        self.done = False
        self.error: Optional[Exception] = None
        self.tokens: Optional[list] = None
        self.score: Optional[float] = None
        self.plan: Optional[str] = None
        self.bucket: Optional[str] = None
        self.steps = 0
        self.prefill_tokens = 0
        self.decode_tokens = 0

    @property
    def ok(self) -> bool:
        return self.done and self.error is None

    def result(self):
        if not self.done:
            raise RuntimeError(f"request {self.request.uid} still pending — "
                               "drive the frontend with run()")
        if self.error is not None:
            raise self.error
        return self.score if self.request.method == "score" else self.tokens

    def _reject(self, err: Exception) -> "Completion":
        self.error, self.done = err, True
        return self


class RoutedFrontend:
    """Routing + buckets + backpressure in front of a BucketedEnginePool."""

    def __init__(self, pool: BucketedEnginePool, router: PlanRouter,
                 max_live_batches: int = 2, max_queue: int = 256):
        self.pool, self.router = pool, router
        self.max_live_batches = max_live_batches
        self.max_queue = max_queue
        # (plan_name, bucket, method) -> deque[Completion]; OrderedDict so
        # group activation is FIFO in first-arrival order
        self._groups: OrderedDict = OrderedDict()
        self._live: dict = {}                 # group key -> engine
        self._inflight: dict = {}             # uid -> (Completion, Request)
        self._completed: list = []
        self.stats_by_class: dict = {}
        self._wall = 0.0

    # -- submission ---------------------------------------------------------
    def submit(self, req: ServeRequest) -> Completion:
        comp = Completion(req)
        st = self._class_stats(req.workload)
        st["submitted"] += 1
        try:
            if req.method not in ("score", "generate", "stream"):
                raise AdmissionError(f"unknown method {req.method!r}")
            plan = self.router.route(req.workload, min_bits=req.min_bits,
                                     bit_stable=req.bit_stable)
            bucket = self.pool.bucket_for(len(req.prompt), (
                0 if req.method == "score" else req.max_new))
            if self._queued() >= self.max_queue:
                raise AdmissionError(
                    f"queue at backpressure cap ({self.max_queue}); retry")
        except (RoutingError, AdmissionError) as e:
            st["rejected"] += 1
            return comp._reject(e)
        comp.plan, comp.bucket = plan.name, bucket.label
        st["plans"][plan.name] += 1
        key = (plan.name, bucket, req.method)
        self._groups.setdefault(key, deque()).append(comp)
        return comp

    def _queued(self) -> int:
        return sum(len(q) for q in self._groups.values())

    def _class_stats(self, workload: str) -> dict:
        return self.stats_by_class.setdefault(workload, {
            "submitted": 0, "rejected": 0, "completed": 0, "steps": 0,
            "prefill_tokens": 0, "decode_tokens": 0, "plans": Counter()})

    # -- the event loop -----------------------------------------------------
    def run(self, max_steps: int = 100_000) -> list:
        """Drive until every submitted request resolves. Returns the
        completions resolved during this call."""
        t0 = time.perf_counter()
        resolved_before = len(self._completed)
        idle_ticks = 0
        for _ in range(max_steps):
            if not self._groups and not self._inflight:
                break
            activated = self._activate_groups()
            self._feed_live()
            progressed = self._step_live()
            self._harvest()
            if progressed or activated:
                idle_ticks = 0
                continue
            # one idle tick is legal (an engine retired this tick; a parked
            # group activates on the next); two in a row means nothing can
            # ever move — e.g. max_live_batches=0
            idle_ticks += 1
            if idle_ticks > 1:
                raise RuntimeError(
                    "frontend stalled: queued groups but nothing live "
                    f"(max_live_batches={self.max_live_batches})")
        else:
            raise RuntimeError(f"frontend did not drain in {max_steps} steps")
        self._wall += time.perf_counter() - t0
        return self._completed[resolved_before:]

    def _activate_groups(self) -> int:
        """Bring queued groups live under the max-live-batches cap. Score
        groups execute immediately (one-shot, no resident decode state).
        Returns how many groups made progress (activated or scored)."""
        n = 0
        for key in list(self._groups):
            plan_name, bucket, method = key
            if key in self._live:
                continue
            if method == "score":
                self._run_score_group(key)
                n += 1
                continue
            if len(self._live) >= self.max_live_batches:
                continue                      # backpressure: stay parked
            self._live[key] = self.pool.get(self.router[plan_name], bucket,
                                            method)
            n += 1
        return n

    def _run_score_group(self, key) -> None:
        plan_name, bucket, _ = key
        q = self._groups.pop(key)
        eng = self.pool.get(self.router[plan_name], bucket, "score")
        while q:
            batch = [q.popleft() for _ in range(min(len(q), bucket.n_slots))]
            scores = eng.score_batch([c.request.prompt for c in batch])
            for comp, s in zip(batch, scores):
                comp.score, comp.done = s, True
                st = self._class_stats(comp.request.workload)
                st["completed"] += 1
                st["prefill_tokens"] += len(comp.request.prompt)
                self._completed.append(comp)

    def _feed_live(self) -> None:
        """Admit queued requests into their live engines — only what the
        engine's remaining KV budget fits; recycle a drained engine whose
        cursor ran out; park the rest for the next tick."""
        for key, eng in self._live.items():
            if not isinstance(eng, GenerateEngine):
                continue
            q = self._groups.get(key)
            if not q:
                continue
            while q:
                comp = q[0]
                need = len(comp.request.prompt) + comp.request.max_new
                eng.recycle_if_exhausted(need)
                free = (sum(r is None for r in eng.batcher.active)
                        - len(eng.batcher.queue))
                if need > eng.cache_remaining() or free <= 0:
                    break                     # parked, not truncated
                q.popleft()
                raw = Request(uid=comp.request.uid,
                              prompt=list(comp.request.prompt),
                              max_new=comp.request.max_new,
                              on_token=comp.request.on_token)
                self._inflight[comp.request.uid] = (comp, raw)
                eng.admit(raw)
            if not q:
                self._groups.pop(key, None)

    def _step_live(self) -> bool:
        progressed = False
        for eng in self._live.values():
            if eng.step():
                progressed = True
        return progressed

    def _harvest(self) -> None:
        """Resolve futures for finished requests; retire drained engines
        whose group queue is empty (frees a live-batch slot)."""
        done_uids = [uid for uid, (_, raw) in self._inflight.items()
                     if raw.done]
        for uid in done_uids:
            comp, raw = self._inflight.pop(uid)
            comp.tokens, comp.done = raw.out, True
            comp.steps, comp.prefill_tokens = raw.steps, raw.prefill_tokens
            comp.decode_tokens = raw.decode_tokens
            st = self._class_stats(comp.request.workload)
            st["completed"] += 1
            st["steps"] += raw.steps
            st["prefill_tokens"] += raw.prefill_tokens
            st["decode_tokens"] += raw.decode_tokens
            self._completed.append(comp)
        for key in [k for k, e in self._live.items()
                    if e.idle() and not self._groups.get(k)]:
            self._groups.pop(key, None)
            del self._live[key]

    # -- reporting ----------------------------------------------------------
    def stats(self) -> dict:
        """Per-class routing/latency/throughput plus pool bookkeeping."""
        classes = {}
        for wl, st in sorted(self.stats_by_class.items()):
            n = st["completed"]
            classes[wl] = {
                **{k: v for k, v in st.items() if k != "plans"},
                "plans": dict(st["plans"]),
                "mean_steps": (st["steps"] / n if n else 0.0),
                "tokens_per_s": (st["decode_tokens"] / self._wall
                                 if self._wall > 0 else 0.0),
            }
        return {"classes": classes, "pool": self.pool.stats(),
                "wall_seconds": self._wall}
