"""Serving frontend: request queue, admission control, backpressure, futures.

The layer between clients and the engine pool. A ``ServeRequest`` declares
its workload class (or an explicit plan), its method, and its constraints;
``submit`` routes it (``PlanRouter``), picks its bucket (padded dispatch),
and returns a ``Completion`` future immediately. ``run`` is the cooperative
event loop: it activates (plan, bucket, method) groups under a
``max_live_batches`` backpressure cap, feeds engines only what their KV
budget admits (parking the rest — never the old silent truncation), recycles
drained engines whose cursor ran out of room, steps every live engine, and
resolves futures as requests finish. Streaming requests get their tokens
through ``on_token`` callbacks from inside the decode step that produced
them.

Typed failure surface: ``RoutingError`` (no plan satisfies the request) and
``AdmissionError`` (no bucket fits / queue at cap) resolve the future as
rejected — one bad request never takes the loop down.
"""

from __future__ import annotations

import dataclasses
import time
from collections import Counter, OrderedDict, deque
from typing import Callable, Optional

from repro.launch.batching import Request
from repro.obs.registry import default_registry
from repro.obs.spans import plan_energy_per_token, span, start_span
from .engine import AdmissionError, BucketedEnginePool, GenerateEngine
from .router import PlanRouter, RoutingError


@dataclasses.dataclass
class ServeRequest:
    """One client request. ``workload`` is a class (chat/solve/repro) or an
    explicit plan name; ``method`` one of score/generate/stream."""

    uid: int
    prompt: list
    max_new: int = 16
    workload: str = "chat"
    method: str = "generate"
    min_bits: Optional[float] = None
    bit_stable: bool = False
    on_token: Optional[Callable[[int], None]] = None   # stream delivery


class Completion:
    """Per-request completion future (host-side: the loop is cooperative).
    ``result()`` returns generated tokens (generate/stream) or the prompt
    log-probability (score); rejected/failed requests re-raise their typed
    error."""

    def __init__(self, request: ServeRequest):
        self.request = request
        self.done = False
        self.error: Optional[Exception] = None
        self.tokens: Optional[list] = None
        self.score: Optional[float] = None
        self.plan: Optional[str] = None
        self.bucket: Optional[str] = None
        self.steps = 0
        self.prefill_tokens = 0
        self.decode_tokens = 0
        self._span = None                 # serving.request lifecycle span

    @property
    def ok(self) -> bool:
        return self.done and self.error is None

    def result(self):
        if not self.done:
            raise RuntimeError(f"request {self.request.uid} still pending — "
                               "drive the frontend with run()")
        if self.error is not None:
            raise self.error
        return self.score if self.request.method == "score" else self.tokens

    def _reject(self, err: Exception) -> "Completion":
        self.error, self.done = err, True
        return self


class RoutedFrontend:
    """Routing + buckets + backpressure in front of a BucketedEnginePool."""

    def __init__(self, pool: BucketedEnginePool, router: PlanRouter,
                 max_live_batches: int = 2, max_queue: int = 256):
        self.pool, self.router = pool, router
        self.max_live_batches = max_live_batches
        self.max_queue = max_queue
        # (plan_name, bucket, method) -> deque[Completion]; OrderedDict so
        # group activation is FIFO in first-arrival order
        self._groups: OrderedDict = OrderedDict()
        self._live: dict = {}                 # group key -> engine
        self._inflight: dict = {}             # uid -> (Completion, Request)
        self._completed: list = []
        self.stats_by_class: dict = {}
        self._wall = 0.0
        # unified-registry mirrors of the per-instance dicts (the dicts stay
        # the exact source of truth for this frontend; the registry is the
        # process-wide scrape surface shared with monitors/pools/collectives)
        reg = default_registry()
        self._m_requests = reg.counter(
            "repro_serving_requests_total",
            "request lifecycle events", ("workload", "event"))
        self._m_tokens = reg.counter(
            "repro_serving_tokens_total",
            "tokens processed by the serving loop", ("workload", "kind"))
        self._m_parked = reg.gauge(
            "repro_serving_parked", "requests parked in group queues")
        self._m_run = reg.histogram(
            "repro_serving_run_seconds", "RoutedFrontend.run() wall time")
        self._m_energy = reg.counter(
            "repro_serving_energy_joules_total",
            "modeled GEMM energy attributed to completed requests", ("plan",))
        self._energy_per_token: dict = {}     # plan name -> J/token (cached)

    # -- submission ---------------------------------------------------------
    def submit(self, req: ServeRequest) -> Completion:
        comp = Completion(req)
        st = self._class_stats(req.workload)
        st["submitted"] += 1
        self._m_requests.inc(workload=req.workload, event="submitted")
        comp._span = start_span("serving.request", uid=req.uid,
                                workload=req.workload, method=req.method)
        try:
            if req.method not in ("score", "generate", "stream"):
                raise AdmissionError(f"unknown method {req.method!r}")
            with span("serving.route", uid=req.uid, workload=req.workload):
                plan = self.router.route(req.workload, min_bits=req.min_bits,
                                         bit_stable=req.bit_stable)
                bucket = self.pool.bucket_for(len(req.prompt), (
                    0 if req.method == "score" else req.max_new))
            if self._queued() >= self.max_queue:
                raise AdmissionError(
                    f"queue at backpressure cap ({self.max_queue}); retry")
        except (RoutingError, AdmissionError) as e:
            st["rejected"] += 1
            self._m_requests.inc(workload=req.workload, event="rejected")
            comp._span.end(status="rejected", reason=type(e).__name__)
            return comp._reject(e)
        comp.plan, comp.bucket = plan.name, bucket.label
        comp._span.annotate(plan=plan.name, bucket=bucket.label)
        st["plans"][plan.name] += 1
        key = (plan.name, bucket, req.method)
        self._groups.setdefault(key, deque()).append(comp)
        self._m_parked.set(float(self._queued()))
        return comp

    def _queued(self) -> int:
        return sum(len(q) for q in self._groups.values())

    def _class_stats(self, workload: str) -> dict:
        return self.stats_by_class.setdefault(workload, {
            "submitted": 0, "rejected": 0, "completed": 0, "steps": 0,
            "prefill_tokens": 0, "decode_tokens": 0, "plans": Counter()})

    # -- the event loop -----------------------------------------------------
    def run(self, max_steps: int = 100_000) -> list:
        """Drive until every submitted request resolves. Returns the
        completions resolved during this call."""
        t0 = time.perf_counter()
        resolved_before = len(self._completed)
        idle_ticks = 0
        with span("serving.run"):
            for _ in range(max_steps):
                if not self._groups and not self._inflight:
                    break
                activated = self._activate_groups()
                self._feed_live()
                progressed = self._step_live()
                self._harvest()
                if progressed or activated:
                    idle_ticks = 0
                    continue
                # one idle tick is legal (an engine retired this tick; a
                # parked group activates on the next); two in a row means
                # nothing can ever move — e.g. max_live_batches=0
                idle_ticks += 1
                if idle_ticks > 1:
                    raise RuntimeError(
                        "frontend stalled: queued groups but nothing live "
                        f"(max_live_batches={self.max_live_batches})")
            else:
                raise RuntimeError(
                    f"frontend did not drain in {max_steps} steps")
        dt = time.perf_counter() - t0
        self._wall += dt
        self._m_run.observe(dt)
        self._m_parked.set(float(self._queued()))
        return self._completed[resolved_before:]

    def _activate_groups(self) -> int:
        """Bring queued groups live under the max-live-batches cap. Score
        groups execute immediately (one-shot, no resident decode state).
        Returns how many groups made progress (activated or scored)."""
        n = 0
        for key in list(self._groups):
            plan_name, bucket, method = key
            if key in self._live:
                continue
            if method == "score":
                self._run_score_group(key)
                n += 1
                continue
            if len(self._live) >= self.max_live_batches:
                continue                      # backpressure: stay parked
            self._live[key] = self.pool.get(self.router[plan_name], bucket,
                                            method)
            n += 1
        return n

    def _run_score_group(self, key) -> None:
        plan_name, bucket, _ = key
        q = self._groups.pop(key)
        eng = self.pool.get(self.router[plan_name], bucket, "score")
        while q:
            batch = [q.popleft() for _ in range(min(len(q), bucket.n_slots))]
            scores = eng.score_batch([c.request.prompt for c in batch])
            for comp, s in zip(batch, scores):
                comp.score, comp.done = s, True
                st = self._class_stats(comp.request.workload)
                st["completed"] += 1
                st["prefill_tokens"] += len(comp.request.prompt)
                self._completed.append(comp)
                wl = comp.request.workload
                self._m_requests.inc(workload=wl, event="routed")
                self._m_requests.inc(workload=wl, event="completed")
                self._m_tokens.inc(len(comp.request.prompt),
                                   workload=wl, kind="prefill")
                self._attribute_energy(comp, len(comp.request.prompt))
                if comp._span is not None:
                    comp._span.end(status="completed")

    def _feed_live(self) -> None:
        """Admit queued requests into their live engines — only what the
        engine's remaining KV budget fits; recycle a drained engine whose
        cursor ran out; park the rest for the next tick."""
        for key, eng in self._live.items():
            if not isinstance(eng, GenerateEngine):
                continue
            q = self._groups.get(key)
            if not q:
                continue
            while q:
                comp = q[0]
                need = len(comp.request.prompt) + comp.request.max_new
                eng.recycle_if_exhausted(need)
                free = (sum(r is None for r in eng.batcher.active)
                        - len(eng.batcher.queue))
                if need > eng.cache_remaining() or free <= 0:
                    break                     # parked, not truncated
                q.popleft()
                raw = Request(uid=comp.request.uid,
                              prompt=list(comp.request.prompt),
                              max_new=comp.request.max_new,
                              on_token=comp.request.on_token)
                self._inflight[comp.request.uid] = (comp, raw)
                self._m_requests.inc(workload=comp.request.workload,
                                     event="routed")
                if comp._span is not None:
                    comp._span.annotate(admitted=True)
                eng.admit(raw)
            if not q:
                self._groups.pop(key, None)

    def _step_live(self) -> bool:
        progressed = False
        for eng in self._live.values():
            if eng.step():
                progressed = True
        return progressed

    def _harvest(self) -> None:
        """Resolve futures for finished requests; retire drained engines
        whose group queue is empty (frees a live-batch slot)."""
        done_uids = [uid for uid, (_, raw) in self._inflight.items()
                     if raw.done]
        for uid in done_uids:
            comp, raw = self._inflight.pop(uid)
            comp.tokens, comp.done = raw.out, True
            comp.steps, comp.prefill_tokens = raw.steps, raw.prefill_tokens
            comp.decode_tokens = raw.decode_tokens
            st = self._class_stats(comp.request.workload)
            st["completed"] += 1
            st["steps"] += raw.steps
            st["prefill_tokens"] += raw.prefill_tokens
            st["decode_tokens"] += raw.decode_tokens
            self._completed.append(comp)
            wl = comp.request.workload
            self._m_requests.inc(workload=wl, event="completed")
            self._m_tokens.inc(raw.prefill_tokens, workload=wl,
                               kind="prefill")
            self._m_tokens.inc(raw.decode_tokens, workload=wl, kind="decode")
            self._attribute_energy(comp,
                                   raw.prefill_tokens + raw.decode_tokens)
            if comp._span is not None:
                comp._span.end(status="completed", steps=raw.steps,
                               decode_tokens=raw.decode_tokens)
        for key in [k for k, e in self._live.items()
                    if e.idle() and not self._groups.get(k)]:
            self._groups.pop(key, None)
            del self._live[key]

    # -- reporting ----------------------------------------------------------
    def _attribute_energy(self, comp: Completion, tokens: int) -> None:
        """Charge a completed request's modeled GEMM energy to its plan:
        per-token joules come from the plan's calibration envelope
        (``obs.plan_energy_per_token``). Derived variants without a plan
        document on disk attribute 0 — they carry no envelope."""
        if not comp.plan or tokens <= 0:
            return
        jpt = self._energy_per_token.get(comp.plan)
        if jpt is None:
            jpt = 0.0
            rp = self.router._by_name.get(comp.plan)
            if rp is not None and rp.path is not None:
                try:
                    from repro.numerics import load_plan
                    jpt = plan_energy_per_token(load_plan(rp.path))
                except (OSError, ValueError, KeyError):
                    jpt = 0.0
            self._energy_per_token[comp.plan] = jpt
        if jpt:
            self._m_energy.inc(jpt * tokens, plan=comp.plan)

    def metrics(self) -> dict:
        """Request-accounting snapshot with a closed-sum invariant:
        ``submitted == routed + parked + rejected`` — every submitted request
        is exactly one of dispatched-into-an-engine (``routed``), still
        queued in a group (``parked``), or rejected at admission. After a
        clean ``run()``, ``parked == 0`` and ``completed == routed``."""
        submitted = sum(st["submitted"] for st in self.stats_by_class.values())
        rejected = sum(st["rejected"] for st in self.stats_by_class.values())
        completed = sum(st["completed"] for st in self.stats_by_class.values())
        parked = self._queued()
        routed = len(self._inflight) + completed
        self._m_parked.set(float(parked))
        return {
            "submitted": submitted, "routed": routed, "parked": parked,
            "rejected": rejected, "completed": completed,
            "inflight": len(self._inflight),
            "energy_joules": self._m_energy.total(),
            "wall_seconds": self._wall,
        }

    def stats(self) -> dict:
        """Per-class routing/latency/throughput plus pool bookkeeping."""
        classes = {}
        for wl, st in sorted(self.stats_by_class.items()):
            n = st["completed"]
            classes[wl] = {
                **{k: v for k, v in st.items() if k != "plans"},
                "plans": dict(st["plans"]),
                "mean_steps": (st["steps"] / n if n else 0.0),
                "tokens_per_s": (st["decode_tokens"] / self._wall
                                 if self._wall > 0 else 0.0),
            }
        return {"classes": classes, "pool": self.pool.stats(),
                "wall_seconds": self._wall}
