# repro.serving — multi-bucket AOT serving with workload-conditional routing.
#
# The tier above ``launch.batching`` that turns the plan zoo into a service:
#
#   router    - PlanRouter: MANIFEST-recorded per-workload scores -> a
#               concrete plan per request (chat/solve/repro classes or an
#               explicit plan name; constraints reject with RoutingError)
#   engine    - BucketedEnginePool: sorted (slots x len) buckets, lazy
#               per-(plan, bucket, method) AOT executables for
#               score/generate/stream, LRU eviction under a live-engine cap
#   frontend  - RoutedFrontend: request queue with max-live-batches
#               backpressure, KV-budget admission control (park, never
#               truncate), completion futures, token streaming callbacks
#
# ``python -m repro.serving`` serves a mixed trace and prints per-class
# routing/latency stats (the CI smoke entry point).
from .engine import (METHODS, AdmissionError, Bucket, BucketedEnginePool,
                     GenerateEngine, ScoreEngine, parse_buckets)
from .frontend import Completion, RoutedFrontend, ServeRequest
from .router import (FDP_CAP_BITS, REPRO_CERT_BITS, WORKLOAD_CLASSES,
                     PlanRouter, RoutedPlan, RoutingError, derive_variants,
                     routed_plan_from_entry)

__all__ = [
    "METHODS", "AdmissionError", "Bucket", "BucketedEnginePool",
    "GenerateEngine", "ScoreEngine", "parse_buckets",
    "Completion", "RoutedFrontend", "ServeRequest",
    "FDP_CAP_BITS", "REPRO_CERT_BITS", "WORKLOAD_CLASSES",
    "PlanRouter", "RoutedPlan", "RoutingError", "derive_variants",
    "routed_plan_from_entry",
]
