"""Serve a mixed workload trace through the routed serving tier.

    PYTHONPATH=src python -m repro.serving --arch paper-mlp --reduced \
        --requests 12 --buckets 2x32,4x64 --max-live 2

Builds the architecture, loads the plan zoo's MANIFEST for it (with the
derived fdp91/repro variants), synthesizes a mixed trace — chat (generate),
solve (generate under wide numerics), repro (bit-stable generate), a
streamed chat request and a score request — serves it through
``RoutedFrontend``, and prints per-class routing/latency stats plus the
engine pool's compile/eviction/bucket-hit bookkeeping.

``--require-complete`` exits nonzero if any request failed or was rejected
(the CI gate mode).

Observability flags: ``--monitor`` serves under live calibration-envelope
monitors (the base zoo plan's envelope), ``--metrics-dump out.json`` writes
the unified registry + monitor + request-accounting snapshot (implies
``--monitor``), ``--inject-violation SITE`` fires one deliberately
out-of-envelope GEMM at the named plan site after the trace drains (the CI
check that a violation is *detected and named*), ``--trace-out trace.json``
exports the span timeline as Chrome-trace JSON.
"""

from __future__ import annotations

import argparse
import contextlib
import json
import sys

import jax

from repro.configs import get_config
from repro.models import init
from repro.serving import (BucketedEnginePool, PlanRouter, RoutedFrontend,
                           ServeRequest, parse_buckets)

CLASS_CYCLE = ("chat", "solve", "repro")


def build_trace(rng, vocab: int, n: int, max_new: int) -> list:
    """A deterministic mixed trace: classes round-robin over varied prompt
    lengths; one streamed request and one score request ride along."""
    reqs = []
    for i in range(n):
        wl = CLASS_CYCLE[i % len(CLASS_CYCLE)]
        plen = 3 + (i * 5) % 11
        prompt = [int(t) for t in
                  jax.random.randint(jax.random.fold_in(rng, i),
                                     (plen,), 0, vocab)]
        method = "generate"
        if i == 1:
            method = "stream"
        elif i == 2:
            method = "score"
        reqs.append(ServeRequest(uid=i, prompt=prompt, max_new=max_new,
                                 workload=wl, method=method))
    return reqs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-mlp")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--plans", default="examples/plans",
                    help="plan zoo directory (MANIFEST.json inside)")
    ap.add_argument("--requests", type=int, default=9)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--buckets", default="2x32,4x64")
    ap.add_argument("--max-live", type=int, default=2,
                    help="max concurrently live decode batches (backpressure)")
    ap.add_argument("--max-engines", type=int, default=6,
                    help="resident-engine cap for the LRU pool")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None,
                    help="also dump the stats dict to this path")
    ap.add_argument("--require-complete", action="store_true",
                    help="exit 1 unless every request completed (CI gate)")
    ap.add_argument("--monitor", action="store_true",
                    help="serve under live calibration-envelope monitors")
    ap.add_argument("--metrics-dump", default=None, metavar="PATH",
                    help="write registry+monitor+serving snapshot JSON "
                         "(implies --monitor)")
    ap.add_argument("--inject-violation", default=None, metavar="SITE",
                    help="after serving, dispatch one out-of-envelope GEMM "
                         "at SITE (implies --monitor)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export span timeline as Chrome-trace JSON")
    args = ap.parse_args(argv)

    import os

    from repro.core.schedules import preload_schedules
    n_sched = preload_schedules(os.path.join(args.plans, "schedules"))

    cfg = get_config(args.arch)
    # plans are recorded per base arch; the reduced config only shrinks shapes
    router = PlanRouter.from_manifest(args.plans, arch=cfg.name)
    if args.reduced:
        cfg = cfg.reduced()
    params = init(cfg, jax.random.key(args.seed))

    monitor_on = bool(args.monitor or args.metrics_dump
                      or args.inject_violation)
    mon_ctx, plan_doc = contextlib.nullcontext(None), None
    if monitor_on:
        from repro.numerics import load_plan
        from repro.obs import monitoring
        base = next((p for p in router.plans
                     if p.derived is None and p.path), None)
        if base is None:
            print("[repro.serving] no zoo plan with a document on disk — "
                  "cannot monitor", file=sys.stderr)
            sys.exit(2)
        plan_doc = load_plan(base.path)
        mon_ctx = monitoring(plan_doc)

    with mon_ctx as mon:
        pool = BucketedEnginePool(cfg, params, parse_buckets(args.buckets),
                                  max_live=args.max_engines)
        front = RoutedFrontend(pool, router, max_live_batches=args.max_live)

        streamed: list = []
        reqs = build_trace(jax.random.key(args.seed + 1), cfg.vocab_size,
                           args.requests, args.max_new)
        for r in reqs:
            if r.method == "stream":
                r.on_token = streamed.append
        comps = [front.submit(r) for r in reqs]
        front.run()

        if args.inject_violation:
            _inject_violation(args.inject_violation, plan_doc)

    stats = front.stats()
    print(f"[repro.serving] {cfg.name}: {len(reqs)} requests, "
          f"buckets={args.buckets}, max_live={args.max_live}")
    for wl, st in stats["classes"].items():
        plans = ", ".join(f"{p} x{n}" for p, n in sorted(st["plans"].items()))
        print(f"  {wl:8s} {st['completed']}/{st['submitted']} ok "
              f"({st['rejected']} rejected)  mean_steps={st['mean_steps']:.1f}"
              f"  decode_toks={st['decode_tokens']}"
              f"  tok/s={st['tokens_per_s']:.1f}  -> {plans}")
    pool_st = stats["pool"]
    print(f"  pool: {pool_st['compiles']} compiles, {pool_st['hits']} hits, "
          f"{pool_st['evictions']} evictions, resident={pool_st['resident']},"
          f" bucket_hits={pool_st['bucket_hits']}")
    ps = pool_st["plans"]
    print(f"  plans: {n_sched} preloaded from zoo; cache size={ps['size']} "
          f"hits={ps['hits']} misses={ps['misses']} "
          f"autotuned={ps['autotuned']} persisted={ps['persisted_loads']}")
    if streamed:
        print(f"  streamed uid=1: {streamed}")
    if mon is not None:
        worst = mon.worst_status()
        n_sites = len(mon.statuses())
        print(f"  monitor: worst={worst} over {n_sites} sites, "
              f"overflow_events={mon.overflow_events()}")

    failures = [c for c in comps if not c.ok]
    for c in failures:
        print(f"  FAILED uid={c.request.uid} class={c.request.workload}: "
              f"{c.error}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(stats, f, indent=1, sort_keys=True, default=str)
    if args.metrics_dump:
        from repro.obs.registry import default_registry
        dump = {
            "kind": "repro.obs.ServingMetricsDump",
            "version": 1,
            "arch": cfg.name,
            "metrics": default_registry().snapshot(),
            "monitor": mon.snapshot() if mon is not None else None,
            "serving": front.metrics(),
        }
        with open(args.metrics_dump, "w") as f:
            json.dump(dump, f, indent=1, sort_keys=True, default=str)
        print(f"  metrics dump -> {args.metrics_dump}")
    if args.trace_out:
        from repro.obs.export import save_chrome_trace
        n_ev = save_chrome_trace(args.trace_out)
        print(f"  chrome trace ({n_ev} events) -> {args.trace_out}")
    if args.require_complete and failures:
        sys.exit(1)


def _inject_violation(site: str, plan_doc) -> None:
    """One deliberately out-of-envelope dispatch at ``site`` under the
    deployed plan's policy: operands at ~2^70 push the product past every
    traced exponent range (and past f32 overflow → a non-finite event), so
    the monitor must flip exactly this site to ``violated``."""
    import jax.numpy as jnp

    from repro.core import dispatch
    out = dispatch.gemm(jnp.full((8, 16), 2.0 ** 70, jnp.float32),
                        jnp.full((16, 8), 2.0 ** 70, jnp.float32),
                        site=site, policy=plan_doc.to_policy())
    jax.block_until_ready(out)
    print(f"  injected out-of-envelope dispatch at site {site!r}")


if __name__ == "__main__":
    main()
