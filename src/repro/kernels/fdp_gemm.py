"""Pallas TPU kernel: GEMM with a ⟨ovf,msb,lsb⟩ fixed-point FDP accumulator.

TPU adaptation of the paper's FPGA systolic GEMM (FCCM'22): the MXU cannot be
re-wired, so the exact accumulator lives in **VMEM scratch as int32 limbs** and
the per-product decode/align/accumulate micro-ops run on the VPU. Tiling is
classic Pallas matmul: grid (M/bm, N/bn, K/bk) with K innermost; the limb
register (bm, bn, L) persists in scratch across the K grid dimension and is
rounded to f32 once, on the last K step — "never round between accumulations".

The hot path is *limb-vectorized*: all ``bk`` product contributions of a K
block are computed as one ``(kc, bm, bn, L)`` tensor op per K sub-chunk (no
per-k scalar loop), summed exactly in int32, and carry-normalized ONCE per K
block. A batched variant runs ``(B, M, K) @ (B, K, N)`` as a single
``pallas_call`` over a 4-D grid instead of a vmap of the 2-D kernel.

Int32 carry discipline: each product contributes < 2^17 per limb, so a K block
of ``bk <= SAFE_CHUNK`` (= 2^13) products is safe between carry
normalizations; the bound is derived in ``repro.core.accumulator`` and
enforced here via ``MAX_BK`` (callers: ops.py).

Block sizes are chosen MXU/VPU-aligned (multiples of 8×128 lanes); the kernel
is validated bit-exactly against the pure-jnp oracle (ref.py) in interpret
mode, which executes this same body on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import accumulator as acc
from repro.core.accumulator import SAFE_CHUNK, AccumulatorSpec

# Single source of truth for the carry-headroom contract: a K block may
# accumulate at most SAFE_CHUNK products between carry normalizations.
MAX_BK = SAFE_CHUNK

# Slab memory budget for the vectorized inner op, per K sub-chunk. The fused
# limb reduction (product_limb_block_sum) keeps ~a dozen (kc, bm, bn) int32
# temporaries live, never a (kc, bm, bn, L) tensor, so the budget is per
# single slab. Interpret mode runs through XLA:CPU where the sweet spot is
# L2/L3-cache-sized slabs; on a real TPU the temporaries must share ~16 MB of
# VMEM with the operand blocks.
_SLAB_BYTES_INTERPRET = 16 << 20
_SLAB_BYTES_TPU = 128 << 10
_MAX_K_SUBCHUNKS = 16            # unroll cap for the static sub-chunk loop


def _k_subchunk(bm: int, bn: int, bk: int, num_limbs: int,
                interpret: bool) -> int:
    """Pick the K sub-chunk size kc: as large as the slab budget allows so
    each (kc, bm, bn) slab stays one vector op, but capped so the static
    sub-chunk loop unrolls at most _MAX_K_SUBCHUNKS times."""
    del num_limbs  # the fused reduction's slabs are L-independent
    budget = _SLAB_BYTES_INTERPRET if interpret else _SLAB_BYTES_TPU
    per_k = bm * bn * 4
    kc = max(1, budget // per_k)
    kc = max(kc, -(-bk // _MAX_K_SUBCHUNKS))
    return min(kc, bk)


def fdp_gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, spec: AccumulatorSpec,
                    fmt, bk: int, k_grid: int, kc: int, batched: bool):
    """Vectorized kernel body (2-D and batched grids).

    2-D:     a (bm, bk), b (bk, bn), o (bm, bn) f32, grid (Mg, Ng, Kg).
    batched: a (1, bm, bk), b (1, bk, bn), o (1, bm, bn), grid (B, Mg, Ng, Kg).
    acc scratch: (bm, bn, L) int32, persists across the (innermost) K axis.
    """
    kidx = pl.program_id(3 if batched else 2)

    @pl.when(kidx == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    if batched:
        a, b = a[0], b[0]
    da = fmt.decode(a)                                 # fields (bm, bk)
    db = fmt.decode(b)                                 # fields (bk, bn)
    da = jax.tree.map(lambda x: x.T, da)               # fields (bk, bm)

    # All bk contributions of this K block, reduced limb-by-limb over
    # (kc, bm, bn) slabs (never materializing a (kc, bm, bn, L) tensor);
    # one carry normalization per K block (bk <= SAFE_CHUNK).
    total = acc_ref[...]
    for k0 in range(0, bk, kc):
        dak = jax.tree.map(lambda x: x[k0:k0 + kc, :, None], da)   # (kc, bm, 1)
        dbk = jax.tree.map(lambda x: x[k0:k0 + kc, None, :], db)   # (kc, 1, bn)
        total = total + acc.product_limb_block_sum(spec, dak, dbk, axis=0)
    acc_ref[...] = acc.carry_normalize(spec, total)

    @pl.when(kidx == k_grid - 1)
    def _emit():
        out = acc.to_float(spec, acc_ref[...])
        o_ref[...] = out[None] if batched else out


def fdp_gemm_kernel_looped(a_ref, b_ref, o_ref, acc_ref, *,
                           spec: AccumulatorSpec, fmt, bk: int, k_grid: int):
    """The seed per-k scalar loop body, kept as the benchmark baseline
    (benchmarks/bench_gemm.py measures the vectorized kernel against it)."""
    kidx = pl.program_id(2)

    @pl.when(kidx == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    da = fmt.decode(a)          # fields (bm, bk)
    db = fmt.decode(b)          # fields (bk, bn)

    def body(k, limbs):
        dak = jax.tree.map(lambda x: jax.lax.dynamic_slice_in_dim(x, k, 1, 1)[:, 0], da)
        dbk = jax.tree.map(lambda x: jax.lax.dynamic_slice_in_dim(x, k, 1, 0)[0, :], db)
        dak = jax.tree.map(lambda x: x[:, None], dak)     # (bm, 1)
        dbk = jax.tree.map(lambda x: x[None, :], dbk)     # (1, bn)
        contrib = acc.product_limbs(spec, dak, dbk)       # (bm, bn, L)
        return limbs + contrib

    limbs = jax.lax.fori_loop(0, bk, body, acc_ref[...])
    limbs = acc.carry_normalize(spec, limbs)              # once per K block
    acc_ref[...] = limbs

    @pl.when(kidx == k_grid - 1)
    def _emit():
        o_ref[...] = acc.to_float(spec, acc_ref[...])


def _scratch(bm: int, bn: int, L: int):
    try:
        from jax.experimental.pallas import tpu as pltpu
        return [pltpu.VMEM((bm, bn, L), jnp.int32)]
    except Exception:  # pragma: no cover
        return [pl.MemorySpace.ANY((bm, bn, L), jnp.int32)]


def fdp_gemm_pallas(a: jax.Array, b: jax.Array, *, spec: AccumulatorSpec,
                    fmt, bm: int = 128, bn: int = 128, bk: int = 512,
                    interpret: bool = True, impl: str = "vector") -> jax.Array:
    """Raw pallas_call wrapper; shapes must be multiples of the block sizes
    (ops.py pads). Inputs: f32/bf16 arrays, or int32 posit patterns.
    ``impl``: "vector" (default hot path) or "loop" (seed baseline)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    assert bk <= MAX_BK, (
        f"bk={bk} exceeds SAFE_CHUNK={SAFE_CHUNK} (= 2^13): int32 limbs take "
        f"< 2^17 per product, so at most SAFE_CHUNK products may accumulate "
        f"between carry normalizations")
    L = spec.num_limbs
    grid = (M // bm, N // bn, K // bk)

    if impl == "vector":
        kc = _k_subchunk(bm, bn, bk, L, interpret)
        kernel = functools.partial(
            fdp_gemm_kernel, spec=spec, fmt=fmt, bk=bk, k_grid=grid[2],
            kc=kc, batched=False)
    elif impl == "loop":
        kernel = functools.partial(
            fdp_gemm_kernel_looped, spec=spec, fmt=fmt, bk=bk, k_grid=grid[2])
    else:
        raise ValueError(f"unknown impl {impl!r}")

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=_scratch(bm, bn, L),
        interpret=interpret,
    )(a, b)


# ---------------------------------------------------------------------------
# Sorted-segment (ragged / MoE) kernels
#
# Tokens arrive sorted by expert (models/moe.py sort-based dispatch), so a
# grouped GEMM need not run every expert over every row: the grid walks one
# tile per (row-block, expert) *segment intersection* — at most
# ``T/bm + E - 1`` tiles by telescoping, since the segment id is
# non-decreasing — and a scalar-prefetched metadata table steers each tile's
# block index maps to its expert's weight (or output) block. Rows outside a
# tile's segment are masked to the zero pattern before decode; zero products
# contribute nothing to the limb register, so accumulating tiles of one
# output block in sequence is exact and order-invariant (bit-identical to
# one dispatched GEMM per expert).
# ---------------------------------------------------------------------------
_META_ROWS = 6            # (block, group, row_lo, row_hi, first, last)


def ragged_num_tiles(n_rows: int, block: int, num_groups: int) -> int:
    """Static tile count of the sorted-segment grids: one tile per
    (row-block, group) intersection, ≤ n_rows/block + num_groups - 1."""
    assert n_rows % block == 0, (n_rows, block)
    return n_rows // block + num_groups - 1


def _ragged_meta(group_sizes: jax.Array, n_rows: int, block: int, *,
                 cover_all_groups: bool) -> jax.Array:
    """Build the (6, NT) int32 scalar-prefetch table for a sorted-segment
    grid over ``n_rows`` (padded, block-multiple) rows in ``num_groups``
    groups. Rows: tile's row-block index, its group index, the global row
    bounds [lo, hi) it owns, and first/last markers for its accumulation
    window (per row-block for the forward, per group when
    ``cover_all_groups`` — the wgrad layout, where every group's output
    block must be visited even for zero-size groups).

    Shapes are static (NT from the telescoping bound); values are data.
    Tiles beyond the used count collapse to empty [0, 0) windows on the last
    block/group with first=last=0, so they accumulate nothing and never
    emit."""
    E = int(group_sizes.shape[0])
    Bg = n_rows // block
    NT = ragged_num_tiles(n_rows, block, E)
    gs = group_sizes.astype(jnp.int32)
    bounds = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(gs, dtype=jnp.int32)])      # (E+1,)
    row0 = jnp.arange(Bg, dtype=jnp.int32) * block
    seg = lambda r: jnp.clip(
        jnp.searchsorted(bounds[1:], r, side="right"), 0, E - 1
    ).astype(jnp.int32)
    e_first = seg(row0)
    e_last = seg(row0 + block - 1)
    if cover_all_groups:
        # wgrad: groups skipped between consecutive row-blocks (zero-size
        # groups) attach to the later block, and the end blocks stretch to
        # group 0 / E-1, so every output block gets (at least) one tile.
        e_first = jnp.concatenate([jnp.zeros((1,), jnp.int32), e_last[:-1]])
        e_last = e_last.at[-1].set(E - 1)

    tiles = e_last - e_first + 1                                     # (Bg,)
    off = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                           jnp.cumsum(tiles, dtype=jnp.int32)])      # (Bg+1,)
    n_used = off[Bg]
    t_ids = jnp.arange(NT, dtype=jnp.int32)
    blk = jnp.clip(jnp.searchsorted(off[1:], t_ids, side="right"),
                   0, Bg - 1).astype(jnp.int32)
    grp = e_first[blk] + (t_ids - off[blk])
    valid = t_ids < n_used
    # spare tiles park on the last block/group (output index maps stay
    # non-decreasing) with an empty row window
    grp = jnp.where(valid, grp, E - 1)
    lo = jnp.where(valid, jnp.maximum(bounds[grp], blk * block), 0)
    hi = jnp.where(valid,
                   jnp.minimum(bounds[grp + 1], (blk + 1) * block), 0)
    if cover_all_groups:
        prev_grp = jnp.concatenate([jnp.full((1,), -1, jnp.int32), grp[:-1]])
        first = valid & (grp != prev_grp)
        last = valid & ((t_ids == n_used - 1) | (t_ids + 1 >= NT)
                        | (jnp.concatenate(
                            [grp[1:], jnp.full((1,), -1, jnp.int32)]) != grp))
    else:
        first = valid & (t_ids == off[blk])
        last = valid & (t_ids == off[blk] + tiles[blk] - 1)
    return jnp.stack([blk, grp, lo, hi,
                      first.astype(jnp.int32), last.astype(jnp.int32)])


def _masked_rows(ref, block_idx, block: int, lo, hi):
    """Zero rows of a (block, ...) operand tile outside its segment's global
    [lo, hi) window. Exact for every format: 0.0 is the zero float carrier
    and 0 the zero posit pattern, and zero products add nothing to the limb
    register."""
    rows = block_idx * block + jax.lax.broadcasted_iota(
        jnp.int32, (block, 1), 0)
    mask = (rows >= lo) & (rows < hi)
    x = ref[...]
    return jnp.where(mask, x, jnp.zeros((), x.dtype))


def fdp_ragged_kernel(meta_ref, x_ref, w_ref, o_ref, acc_ref, *,
                      spec: AccumulatorSpec, fmt, bm: int, bk: int,
                      k_grid: int, kc: int):
    """Sorted-segment forward body. Grid (Ng, NT, Kg), K innermost:
    x (bm, bk) at (block[t], k), w (1, bk, bn) at (group[t], k, j),
    o (bm, bn) at (block[t], j). The limb scratch spans all tiles of one
    row-block (their row windows are disjoint): zeroed on the block's first
    tile, emitted on its last."""
    t = pl.program_id(1)
    kidx = pl.program_id(2)
    tm = meta_ref[0, t]
    lo = meta_ref[2, t]
    hi = meta_ref[3, t]
    first = meta_ref[4, t]
    last = meta_ref[5, t]

    @pl.when((first == 1) & (kidx == 0))
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = _masked_rows(x_ref, tm, bm, lo, hi)            # (bm, bk)
    da = fmt.decode(x)                                 # fields (bm, bk)
    db = fmt.decode(w_ref[0])                          # fields (bk, bn)
    da = jax.tree.map(lambda v: v.T, da)               # fields (bk, bm)

    total = acc_ref[...]
    for k0 in range(0, bk, kc):
        dak = jax.tree.map(lambda v: v[k0:k0 + kc, :, None], da)
        dbk = jax.tree.map(lambda v: v[k0:k0 + kc, None, :], db)
        total = total + acc.product_limb_block_sum(spec, dak, dbk, axis=0)
    acc_ref[...] = acc.carry_normalize(spec, total)

    @pl.when((last == 1) & (kidx == k_grid - 1))
    def _emit():
        o_ref[...] = acc.to_float(spec, acc_ref[...])


def fdp_ragged_gemm_pallas(x: jax.Array, w: jax.Array,
                           group_sizes: jax.Array, *, spec: AccumulatorSpec,
                           fmt, bm: int = 32, bn: int = 32, bk: int = 128,
                           interpret: bool = True) -> jax.Array:
    """Raw sorted-segment grouped GEMM: x (T, d) @ w[group(t)] -> (T, f).
    T/d/f must be block multiples (ops.py pads); rows beyond
    sum(group_sizes) yield zeros."""
    from jax.experimental.pallas import tpu as pltpu

    T, d = x.shape
    E, d2, f = w.shape
    assert d == d2, (x.shape, w.shape)
    assert T % bm == 0 and f % bn == 0 and d % bk == 0, (T, d, f, bm, bn, bk)
    assert bk <= MAX_BK, (
        f"bk={bk} exceeds SAFE_CHUNK={SAFE_CHUNK} carry headroom")
    L = spec.num_limbs
    NT = ragged_num_tiles(T, bm, E)
    k_grid = d // bk
    meta = _ragged_meta(group_sizes, T, bm, cover_all_groups=False)
    kc = _k_subchunk(bm, bn, bk, L, interpret)

    kernel = functools.partial(
        fdp_ragged_kernel, spec=spec, fmt=fmt, bm=bm, bk=bk, k_grid=k_grid,
        kc=kc)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(f // bn, NT, k_grid),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda j, t, k, meta: (meta[0, t], k)),
            pl.BlockSpec((1, bk, bn),
                         lambda j, t, k, meta: (meta[1, t], k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn),
                               lambda j, t, k, meta: (meta[0, t], j)),
        scratch_shapes=_scratch(bm, bn, L),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, f), jnp.float32),
        interpret=interpret,
    )(meta, x, w)


def fdp_ragged_dw_kernel(meta_ref, x_ref, g_ref, o_ref, acc_ref, *,
                         spec: AccumulatorSpec, fmt, bkt: int, kc: int):
    """Sorted-segment wgrad body. Grid (Mg, Ng, NT), tiles innermost:
    x (bkt, bm) at (block[t], i), g (bkt, bn) at (block[t], j),
    o (1, bm, bn) at (group[t], i, j). The contraction dim is the ragged
    token dim; the limb scratch spans all tiles of one *group* (first/last
    markers are per group), so zero-size groups emit exact zeros from their
    single empty tile."""
    t = pl.program_id(2)
    tb = meta_ref[0, t]
    lo = meta_ref[2, t]
    hi = meta_ref[3, t]
    first = meta_ref[4, t]
    last = meta_ref[5, t]

    @pl.when(first == 1)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xm = _masked_rows(x_ref, tb, bkt, lo, hi)          # (bkt, bm), k-major
    da = fmt.decode(xm)
    db = fmt.decode(g_ref[...])                        # fields (bkt, bn)

    total = acc_ref[...]
    for k0 in range(0, bkt, kc):
        dak = jax.tree.map(lambda v: v[k0:k0 + kc, :, None], da)
        dbk = jax.tree.map(lambda v: v[k0:k0 + kc, None, :], db)
        total = total + acc.product_limb_block_sum(spec, dak, dbk, axis=0)
    acc_ref[...] = acc.carry_normalize(spec, total)

    @pl.when(last == 1)
    def _emit():
        o_ref[...] = acc.to_float(spec, acc_ref[...])[None]


def fdp_ragged_dw_pallas(x: jax.Array, g: jax.Array, group_sizes: jax.Array,
                         *, spec: AccumulatorSpec, fmt, bm: int = 32,
                         bn: int = 32, bk: int = 128,
                         interpret: bool = True) -> jax.Array:
    """Raw sorted-segment grouped weight gradient:
    dW[e] = x[rows of e]ᵀ @ g[rows of e] -> (E, d, f). ``bk`` blocks the
    ragged token dim (T must be a bk multiple; ops.py pads)."""
    from jax.experimental.pallas import tpu as pltpu

    T, d = x.shape
    T2, f = g.shape
    assert T == T2, (x.shape, g.shape)
    E = int(group_sizes.shape[0])
    assert T % bk == 0 and d % bm == 0 and f % bn == 0, (T, d, f, bm, bn, bk)
    assert bk <= MAX_BK, (
        f"bk={bk} exceeds SAFE_CHUNK={SAFE_CHUNK} carry headroom")
    L = spec.num_limbs
    NT = ragged_num_tiles(T, bk, E)
    meta = _ragged_meta(group_sizes, T, bk, cover_all_groups=True)
    kc = _k_subchunk(bm, bn, bk, L, interpret)

    kernel = functools.partial(
        fdp_ragged_dw_kernel, spec=spec, fmt=fmt, bkt=bk, kc=kc)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(d // bm, f // bn, NT),
        in_specs=[
            pl.BlockSpec((bk, bm), lambda i, j, t, meta: (meta[0, t], i)),
            pl.BlockSpec((bk, bn), lambda i, j, t, meta: (meta[0, t], j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn),
                               lambda i, j, t, meta: (meta[1, t], i, j)),
        scratch_shapes=_scratch(bm, bn, L),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((E, d, f), jnp.float32),
        interpret=interpret,
    )(meta, x, g)


def fdp_gemm_pallas_batched(a: jax.Array, b: jax.Array, *,
                            spec: AccumulatorSpec, fmt, bm: int = 128,
                            bn: int = 128, bk: int = 512,
                            interpret: bool = True) -> jax.Array:
    """Native batched grid: (B, M, K) @ (B, K, N) -> (B, M, N) as ONE
    pallas_call over grid (B, M/bm, N/bn, K/bk) — no vmap-of-kernel. The limb
    scratch persists across the innermost K axis only, so each (batch, i, j)
    tile accumulates independently."""
    B, M, K = a.shape
    B2, K2, N = b.shape
    assert B == B2 and K == K2, (a.shape, b.shape)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    assert bk <= MAX_BK, (
        f"bk={bk} exceeds SAFE_CHUNK={SAFE_CHUNK} carry headroom")
    L = spec.num_limbs
    grid = (B, M // bm, N // bn, K // bk)
    kc = _k_subchunk(bm, bn, bk, L, interpret)

    kernel = functools.partial(
        fdp_gemm_kernel, spec=spec, fmt=fmt, bk=bk, k_grid=grid[3],
        kc=kc, batched=True)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda g, i, j, k: (g, i, k)),
            pl.BlockSpec((1, bk, bn), lambda g, i, j, k: (g, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda g, i, j, k: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, M, N), jnp.float32),
        scratch_shapes=_scratch(bm, bn, L),
        interpret=interpret,
    )(a, b)
