"""Pallas TPU kernel: GEMM with a ⟨ovf,msb,lsb⟩ fixed-point FDP accumulator.

TPU adaptation of the paper's FPGA systolic GEMM (FCCM'22): the MXU cannot be
re-wired, so the exact accumulator lives in **VMEM scratch as int32 limbs** and
the per-product decode/align/accumulate micro-ops run on the VPU. Tiling is
classic Pallas matmul: grid (M/bm, N/bn, K/bk) with K innermost; the limb
register (bm, bn, L) persists in scratch across the K grid dimension and is
rounded to f32 once, on the last K step — "never round between accumulations".

The hot path is *limb-vectorized*: all ``bk`` product contributions of a K
block are computed as one ``(kc, bm, bn, L)`` tensor op per K sub-chunk (no
per-k scalar loop), summed exactly in int32, and carry-normalized ONCE per K
block. A batched variant runs ``(B, M, K) @ (B, K, N)`` as a single
``pallas_call`` over a 4-D grid instead of a vmap of the 2-D kernel.

Int32 carry discipline: each product contributes < 2^17 per limb, so a K block
of ``bk <= SAFE_CHUNK`` (= 2^13) products is safe between carry
normalizations; the bound is derived in ``repro.core.accumulator`` and
enforced here via ``MAX_BK`` (callers: ops.py).

Block sizes are chosen MXU/VPU-aligned (multiples of 8×128 lanes); the kernel
is validated bit-exactly against the pure-jnp oracle (ref.py) in interpret
mode, which executes this same body on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import accumulator as acc
from repro.core.accumulator import SAFE_CHUNK, AccumulatorSpec

# Single source of truth for the carry-headroom contract: a K block may
# accumulate at most SAFE_CHUNK products between carry normalizations.
MAX_BK = SAFE_CHUNK

# Slab memory budget for the vectorized inner op, per K sub-chunk. The fused
# limb reduction (product_limb_block_sum) keeps ~a dozen (kc, bm, bn) int32
# temporaries live, never a (kc, bm, bn, L) tensor, so the budget is per
# single slab. Interpret mode runs through XLA:CPU where the sweet spot is
# L2/L3-cache-sized slabs; on a real TPU the temporaries must share ~16 MB of
# VMEM with the operand blocks.
_SLAB_BYTES_INTERPRET = 16 << 20
_SLAB_BYTES_TPU = 128 << 10
_MAX_K_SUBCHUNKS = 16            # unroll cap for the static sub-chunk loop


def _k_subchunk(bm: int, bn: int, bk: int, num_limbs: int,
                interpret: bool) -> int:
    """Pick the K sub-chunk size kc: as large as the slab budget allows so
    each (kc, bm, bn) slab stays one vector op, but capped so the static
    sub-chunk loop unrolls at most _MAX_K_SUBCHUNKS times."""
    del num_limbs  # the fused reduction's slabs are L-independent
    budget = _SLAB_BYTES_INTERPRET if interpret else _SLAB_BYTES_TPU
    per_k = bm * bn * 4
    kc = max(1, budget // per_k)
    kc = max(kc, -(-bk // _MAX_K_SUBCHUNKS))
    return min(kc, bk)


def fdp_gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, spec: AccumulatorSpec,
                    fmt, bk: int, k_grid: int, kc: int, batched: bool):
    """Vectorized kernel body (2-D and batched grids).

    2-D:     a (bm, bk), b (bk, bn), o (bm, bn) f32, grid (Mg, Ng, Kg).
    batched: a (1, bm, bk), b (1, bk, bn), o (1, bm, bn), grid (B, Mg, Ng, Kg).
    acc scratch: (bm, bn, L) int32, persists across the (innermost) K axis.
    """
    kidx = pl.program_id(3 if batched else 2)

    @pl.when(kidx == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    if batched:
        a, b = a[0], b[0]
    da = fmt.decode(a)                                 # fields (bm, bk)
    db = fmt.decode(b)                                 # fields (bk, bn)
    da = jax.tree.map(lambda x: x.T, da)               # fields (bk, bm)

    # All bk contributions of this K block, reduced limb-by-limb over
    # (kc, bm, bn) slabs (never materializing a (kc, bm, bn, L) tensor);
    # one carry normalization per K block (bk <= SAFE_CHUNK).
    total = acc_ref[...]
    for k0 in range(0, bk, kc):
        dak = jax.tree.map(lambda x: x[k0:k0 + kc, :, None], da)   # (kc, bm, 1)
        dbk = jax.tree.map(lambda x: x[k0:k0 + kc, None, :], db)   # (kc, 1, bn)
        total = total + acc.product_limb_block_sum(spec, dak, dbk, axis=0)
    acc_ref[...] = acc.carry_normalize(spec, total)

    @pl.when(kidx == k_grid - 1)
    def _emit():
        out = acc.to_float(spec, acc_ref[...])
        o_ref[...] = out[None] if batched else out


def fdp_gemm_kernel_looped(a_ref, b_ref, o_ref, acc_ref, *,
                           spec: AccumulatorSpec, fmt, bk: int, k_grid: int):
    """The seed per-k scalar loop body, kept as the benchmark baseline
    (benchmarks/bench_gemm.py measures the vectorized kernel against it)."""
    kidx = pl.program_id(2)

    @pl.when(kidx == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    da = fmt.decode(a)          # fields (bm, bk)
    db = fmt.decode(b)          # fields (bk, bn)

    def body(k, limbs):
        dak = jax.tree.map(lambda x: jax.lax.dynamic_slice_in_dim(x, k, 1, 1)[:, 0], da)
        dbk = jax.tree.map(lambda x: jax.lax.dynamic_slice_in_dim(x, k, 1, 0)[0, :], db)
        dak = jax.tree.map(lambda x: x[:, None], dak)     # (bm, 1)
        dbk = jax.tree.map(lambda x: x[None, :], dbk)     # (1, bn)
        contrib = acc.product_limbs(spec, dak, dbk)       # (bm, bn, L)
        return limbs + contrib

    limbs = jax.lax.fori_loop(0, bk, body, acc_ref[...])
    limbs = acc.carry_normalize(spec, limbs)              # once per K block
    acc_ref[...] = limbs

    @pl.when(kidx == k_grid - 1)
    def _emit():
        o_ref[...] = acc.to_float(spec, acc_ref[...])


def _scratch(bm: int, bn: int, L: int):
    try:
        from jax.experimental.pallas import tpu as pltpu
        return [pltpu.VMEM((bm, bn, L), jnp.int32)]
    except Exception:  # pragma: no cover
        return [pl.MemorySpace.ANY((bm, bn, L), jnp.int32)]


def fdp_gemm_pallas(a: jax.Array, b: jax.Array, *, spec: AccumulatorSpec,
                    fmt, bm: int = 128, bn: int = 128, bk: int = 512,
                    interpret: bool = True, impl: str = "vector") -> jax.Array:
    """Raw pallas_call wrapper; shapes must be multiples of the block sizes
    (ops.py pads). Inputs: f32/bf16 arrays, or int32 posit patterns.
    ``impl``: "vector" (default hot path) or "loop" (seed baseline)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    assert bk <= MAX_BK, (
        f"bk={bk} exceeds SAFE_CHUNK={SAFE_CHUNK} (= 2^13): int32 limbs take "
        f"< 2^17 per product, so at most SAFE_CHUNK products may accumulate "
        f"between carry normalizations")
    L = spec.num_limbs
    grid = (M // bm, N // bn, K // bk)

    if impl == "vector":
        kc = _k_subchunk(bm, bn, bk, L, interpret)
        kernel = functools.partial(
            fdp_gemm_kernel, spec=spec, fmt=fmt, bk=bk, k_grid=grid[2],
            kc=kc, batched=False)
    elif impl == "loop":
        kernel = functools.partial(
            fdp_gemm_kernel_looped, spec=spec, fmt=fmt, bk=bk, k_grid=grid[2])
    else:
        raise ValueError(f"unknown impl {impl!r}")

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=_scratch(bm, bn, L),
        interpret=interpret,
    )(a, b)


def fdp_gemm_pallas_batched(a: jax.Array, b: jax.Array, *,
                            spec: AccumulatorSpec, fmt, bm: int = 128,
                            bn: int = 128, bk: int = 512,
                            interpret: bool = True) -> jax.Array:
    """Native batched grid: (B, M, K) @ (B, K, N) -> (B, M, N) as ONE
    pallas_call over grid (B, M/bm, N/bn, K/bk) — no vmap-of-kernel. The limb
    scratch persists across the innermost K axis only, so each (batch, i, j)
    tile accumulates independently."""
    B, M, K = a.shape
    B2, K2, N = b.shape
    assert B == B2 and K == K2, (a.shape, b.shape)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    assert bk <= MAX_BK, (
        f"bk={bk} exceeds SAFE_CHUNK={SAFE_CHUNK} carry headroom")
    L = spec.num_limbs
    grid = (B, M // bm, N // bn, K // bk)
    kc = _k_subchunk(bm, bn, bk, L, interpret)

    kernel = functools.partial(
        fdp_gemm_kernel, spec=spec, fmt=fmt, bk=bk, k_grid=grid[3],
        kc=kc, batched=True)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda g, i, j, k: (g, i, k)),
            pl.BlockSpec((1, bk, bn), lambda g, i, j, k: (g, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda g, i, j, k: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, M, N), jnp.float32),
        scratch_shapes=_scratch(bm, bn, L),
        interpret=interpret,
    )(a, b)
