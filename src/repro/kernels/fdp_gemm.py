"""Pallas TPU kernel: GEMM with a ⟨ovf,msb,lsb⟩ fixed-point FDP accumulator.

TPU adaptation of the paper's FPGA systolic GEMM (FCCM'22): the MXU cannot be
re-wired, so the exact accumulator lives in **VMEM scratch as int32 limbs** and
the per-product decode/align/accumulate micro-ops run on the VPU. Tiling is
classic Pallas matmul: grid (M/bm, N/bn, K/bk) with K innermost; the limb
register (bm, bn, L) persists in scratch across the K grid dimension and is
rounded to f32 once, on the last K step — "never round between accumulations".

Block sizes are chosen MXU/VPU-aligned (multiples of 8×128 lanes); the kernel
is validated bit-exactly against the pure-jnp oracle (ref.py) in interpret
mode, which executes this same body on CPU.

Int32 carry discipline: each product contributes < 2^17 per limb, so a K-block
of bk ≤ 2^13 products is safe between carry normalizations; we normalize once
per K-block (enforced in ops.py: bk <= 4096).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import accumulator as acc
from repro.core.accumulator import AccumulatorSpec
from repro.core.formats import FloatFormat, PositFormat


def fdp_gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, spec: AccumulatorSpec,
                    fmt, bk: int, k_grid: int):
    """Kernel body. a: (bm, bk), b: (bk, bn), o: (bm, bn) f32,
    acc scratch: (bm, bn, L) int32."""
    kidx = pl.program_id(2)

    @pl.when(kidx == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    da = fmt.decode(a)          # fields (bm, bk)
    db = fmt.decode(b)          # fields (bk, bn)

    def body(k, limbs):
        dak = jax.tree.map(lambda x: jax.lax.dynamic_slice_in_dim(x, k, 1, 1)[:, 0], da)
        dbk = jax.tree.map(lambda x: jax.lax.dynamic_slice_in_dim(x, k, 1, 0)[0, :], db)
        dak = jax.tree.map(lambda x: x[:, None], dak)     # (bm, 1)
        dbk = jax.tree.map(lambda x: x[None, :], dbk)     # (1, bn)
        contrib = acc.product_limbs(spec, dak, dbk)       # (bm, bn, L)
        return limbs + contrib

    limbs = jax.lax.fori_loop(0, bk, body, acc_ref[...])
    limbs = acc.carry_normalize(spec, limbs)              # once per K block
    acc_ref[...] = limbs

    @pl.when(kidx == k_grid - 1)
    def _emit():
        o_ref[...] = acc.to_float(spec, acc_ref[...])


def fdp_gemm_pallas(a: jax.Array, b: jax.Array, *, spec: AccumulatorSpec,
                    fmt, bm: int = 128, bn: int = 128, bk: int = 512,
                    interpret: bool = True) -> jax.Array:
    """Raw pallas_call wrapper; shapes must be multiples of the block sizes
    (ops.py pads). Inputs: f32/bf16 arrays, or int32 posit patterns."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    assert bk <= 4096, "bk must respect int32 carry headroom (<= 2^12)"
    L = spec.num_limbs
    grid = (M // bm, N // bn, K // bk)

    kernel = functools.partial(
        fdp_gemm_kernel, spec=spec, fmt=fmt, bk=bk, k_grid=grid[2])

    try:
        from jax.experimental.pallas import tpu as pltpu
        scratch = [pltpu.VMEM((bm, bn, L), jnp.int32)]
    except Exception:  # pragma: no cover
        scratch = [pl.MemorySpace.ANY((bm, bn, L), jnp.int32)]

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=scratch,
        interpret=interpret,
    )(a, b)
