"""Jitted public wrappers around the Pallas FDP GEMM kernels.

Handles non-block-multiple shapes by zero padding (exact: zero products
contribute nothing to the fixed-point register in either rounding mode),
batch-dim broadcasting for N-D inputs, and picks interpret mode automatically
off-TPU. Block sizes come from the caller — normally a ``GemmPlan`` resolved
by ``repro.core.dispatch`` — and are validated against the ``SAFE_CHUNK``
carry-headroom bound shared with the kernel.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.accumulator import AccumulatorSpec
from repro.core.formats import FP32

from .fdp_gemm import MAX_BK, fdp_gemm_pallas, fdp_gemm_pallas_batched


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _ceil(x: int, base: int = 8) -> int:
    return -(-x // base) * base


def _fit_blocks(M: int, N: int, K: int, bm: int, bn: int, bk: int):
    """Clamp requested blocks to the (8-aligned) problem size and the
    SAFE_CHUNK carry-headroom bound."""
    return (min(bm, _ceil(M)), min(bn, _ceil(N)),
            min(min(bk, MAX_BK), _ceil(K)))


@partial(jax.jit,
         static_argnames=("spec", "fmt", "bm", "bn", "bk", "interpret", "impl"))
def fdp_gemm(a: jax.Array, b: jax.Array, *, spec: AccumulatorSpec, fmt=FP32,
             bm: int = 32, bn: int = 32, bk: int = 128,
             interpret: bool | None = None, impl: str = "vector") -> jax.Array:
    """GEMM with tailored FDP accumulation: (M,K)@(K,N) -> (M,N) f32."""
    M, K = a.shape
    _, N = b.shape
    bm_, bn_, bk_ = _fit_blocks(M, N, K, bm, bn, bk)
    pm, pn, pk = (-M) % bm_, (-N) % bn_, (-K) % bk_
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    interp = (not _on_tpu()) if interpret is None else interpret
    out = fdp_gemm_pallas(a, b, spec=spec, fmt=fmt, bm=bm_, bn=bn_, bk=bk_,
                          interpret=interp, impl=impl)
    return out[:M, :N]


@partial(jax.jit,
         static_argnames=("spec", "fmt", "bm", "bn", "bk", "interpret"))
def fdp_gemm_batched(a: jax.Array, b: jax.Array, *, spec: AccumulatorSpec,
                     fmt=FP32, bm: int = 32, bn: int = 32, bk: int = 128,
                     interpret: bool | None = None) -> jax.Array:
    """Batched GEMM through the native 4-D grid: (B,M,K)@(B,K,N) -> (B,M,N)
    f32 as one pallas_call (the batch dim needs no padding — its block is 1)."""
    B, M, K = a.shape
    B2, K2, N = b.shape
    assert B == B2 and K == K2, (a.shape, b.shape)
    bm_, bn_, bk_ = _fit_blocks(M, N, K, bm, bn, bk)
    pm, pn, pk = (-M) % bm_, (-N) % bn_, (-K) % bk_
    if pm or pk:
        a = jnp.pad(a, ((0, 0), (0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, 0), (0, pk), (0, pn)))
    interp = (not _on_tpu()) if interpret is None else interpret
    out = fdp_gemm_pallas_batched(a, b, spec=spec, fmt=fmt, bm=bm_, bn=bn_,
                                  bk=bk_, interpret=interp)
    return out[:, :M, :N]


def matmul_batching(f2d, f3d):
    """Wrap a 2-D kernel and a flat-batched 3-D kernel into one
    jnp.matmul-shaped callable: 1-D operands are promoted (and the result
    squeezed back, down to a scalar for vector·vector), leading batch dims
    broadcast numpy-style and flatten into the 3-D kernel's batch axis."""
    def call(a: jax.Array, b: jax.Array) -> jax.Array:
        squeeze_a = a.ndim == 1
        squeeze_b = b.ndim == 1
        if squeeze_a:
            a = a[None, :]
        if squeeze_b:
            b = b[:, None]
        if a.ndim == 2 and b.ndim == 2:
            out = f2d(a, b)
        else:
            batch = jnp.broadcast_shapes(a.shape[:-2], b.shape[:-2])
            a = jnp.broadcast_to(a, batch + a.shape[-2:])
            b = jnp.broadcast_to(b, batch + b.shape[-2:])
            out = f3d(a.reshape((-1,) + a.shape[-2:]),
                      b.reshape((-1,) + b.shape[-2:]))
            out = out.reshape(batch + out.shape[-2:])
        if squeeze_a:
            out = out[..., 0, :]
        if squeeze_b:
            out = out[..., 0] if squeeze_a else out[..., :, 0]
        return out

    return call


def fdp_gemm_nd(a: jax.Array, b: jax.Array, *, spec: AccumulatorSpec,
                fmt=FP32, bm: int = 32, bn: int = 32, bk: int = 128,
                interpret: bool | None = None) -> jax.Array:
    """jnp.matmul-shaped entry point: 1-D promotion, numpy broadcasting of
    leading batch dims, then the 2-D kernel or the native batched grid."""
    f2d = lambda x, y: fdp_gemm(x, y, spec=spec, fmt=fmt, bm=bm, bn=bn,
                                bk=bk, interpret=interpret)
    f3d = lambda x, y: fdp_gemm_batched(x, y, spec=spec, fmt=fmt, bm=bm,
                                        bn=bn, bk=bk, interpret=interpret)
    return matmul_batching(f2d, f3d)(a, b)
