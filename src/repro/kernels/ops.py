"""Jitted public wrapper around the Pallas FDP GEMM kernel.

Handles non-block-multiple shapes by zero padding (exact: zero products
contribute nothing to the fixed-point register in either rounding mode) and
picks interpret mode automatically off-TPU.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.accumulator import AccumulatorSpec
from repro.core.formats import FP32

from .fdp_gemm import fdp_gemm_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("spec", "fmt", "bm", "bn", "bk", "interpret"))
def fdp_gemm(a: jax.Array, b: jax.Array, *, spec: AccumulatorSpec, fmt=FP32,
             bm: int = 32, bn: int = 32, bk: int = 128,
             interpret: bool | None = None) -> jax.Array:
    """GEMM with tailored FDP accumulation: (M,K)@(K,N) -> (M,N) f32."""
    M, K = a.shape
    _, N = b.shape
    bm_, bn_, bk_ = min(bm, _ceil(M)), min(bn, _ceil(N)), min(bk, _ceil(K))
    pm, pn, pk = (-M) % bm_, (-N) % bn_, (-K) % bk_
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    interp = (not _on_tpu()) if interpret is None else interpret
    out = fdp_gemm_pallas(a, b, spec=spec, fmt=fmt, bm=bm_, bn=bn_, bk=bk_,
                          interpret=interp)
    return out[:M, :N]


def _ceil(x: int, base: int = 8) -> int:
    return -(-x // base) * base
