"""Jitted public wrappers around the Pallas FDP GEMM kernels.

Handles non-block-multiple shapes by zero padding (exact: zero products
contribute nothing to the fixed-point register in either rounding mode),
batch-dim broadcasting for N-D inputs, and picks interpret mode automatically
off-TPU.

Tiling is **GemmPlan-first**: every entry point takes ``plan: GemmPlan``
(normally resolved by ``repro.core.dispatch`` from the plan cache / schedule
zoo) and clamps it through ``GemmPlan.fit`` — the one place a deployable
schedule is constructed, enforcing the ``SAFE_CHUNK`` carry-headroom bound
shared with the kernel. (The pre-zoo loose ``bm``/``bn``/``bk`` ints rode
one release behind a DeprecationWarning and are gone: passing them now is a
TypeError.)
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.accumulator import AccumulatorSpec
from repro.core.dispatch import GemmPlan
from repro.core.formats import FP32

from .fdp_gemm import (MAX_BK, fdp_gemm_pallas, fdp_gemm_pallas_batched,
                       fdp_ragged_dw_pallas, fdp_ragged_gemm_pallas)

# Default tile when a caller passes no plan (matches the historical
# keyword defaults).
_DEFAULT_TILE = (32, 32, 128)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_plan(plan, M: int, N: int, K: int) -> GemmPlan:
    """Normalize the tiling argument of one kernel call into a fitted
    GemmPlan — the one deployable-schedule constructor."""
    if plan is None:
        plan = GemmPlan(*_DEFAULT_TILE)
    return plan.fit(M, N, K)


@partial(jax.jit,
         static_argnames=("spec", "fmt", "bm", "bn", "bk", "interpret", "impl"))
def _fdp_gemm_jit(a, b, *, spec, fmt, bm, bn, bk, interpret, impl):
    M, K = a.shape
    _, N = b.shape
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    interp = (not _on_tpu()) if interpret is None else interpret
    out = fdp_gemm_pallas(a, b, spec=spec, fmt=fmt, bm=bm, bn=bn, bk=bk,
                          interpret=interp, impl=impl)
    return out[:M, :N]


def fdp_gemm(a: jax.Array, b: jax.Array, *, spec: AccumulatorSpec, fmt=FP32,
             plan: GemmPlan | None = None, interpret: bool | None = None,
             impl: str = "vector") -> jax.Array:
    """GEMM with tailored FDP accumulation: (M,K)@(K,N) -> (M,N) f32."""
    M, K = a.shape
    _, N = b.shape
    p = resolve_plan(plan, M, N, K)
    return _fdp_gemm_jit(a, b, spec=spec, fmt=fmt, bm=p.bm, bn=p.bn, bk=p.bk,
                         interpret=interpret, impl=impl)


@partial(jax.jit,
         static_argnames=("spec", "fmt", "bm", "bn", "bk", "interpret"))
def _fdp_gemm_batched_jit(a, b, *, spec, fmt, bm, bn, bk, interpret):
    B, M, K = a.shape
    B2, K2, N = b.shape
    assert B == B2 and K == K2, (a.shape, b.shape)
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, 0), (0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, 0), (0, pk), (0, pn)))
    interp = (not _on_tpu()) if interpret is None else interpret
    out = fdp_gemm_pallas_batched(a, b, spec=spec, fmt=fmt, bm=bm, bn=bn,
                                  bk=bk, interpret=interp)
    return out[:, :M, :N]


def fdp_gemm_batched(a: jax.Array, b: jax.Array, *, spec: AccumulatorSpec,
                     fmt=FP32, plan: GemmPlan | None = None,
                     interpret: bool | None = None) -> jax.Array:
    """Batched GEMM through the native 4-D grid: (B,M,K)@(B,K,N) -> (B,M,N)
    f32 as one pallas_call (the batch dim needs no padding — its block is 1)."""
    _, M, K = a.shape
    _, _, N = b.shape
    p = resolve_plan(plan, M, N, K)
    return _fdp_gemm_batched_jit(a, b, spec=spec, fmt=fmt, bm=p.bm, bn=p.bn,
                                 bk=p.bk, interpret=interpret)


def matmul_batching(f2d, f3d):
    """Wrap a 2-D kernel and a flat-batched 3-D kernel into one
    jnp.matmul-shaped callable: 1-D operands are promoted (and the result
    squeezed back, down to a scalar for vector·vector), leading batch dims
    broadcast numpy-style and flatten into the 3-D kernel's batch axis."""
    def call(a: jax.Array, b: jax.Array) -> jax.Array:
        squeeze_a = a.ndim == 1
        squeeze_b = b.ndim == 1
        if squeeze_a:
            a = a[None, :]
        if squeeze_b:
            b = b[:, None]
        if a.ndim == 2 and b.ndim == 2:
            out = f2d(a, b)
        else:
            batch = jnp.broadcast_shapes(a.shape[:-2], b.shape[:-2])
            a = jnp.broadcast_to(a, batch + a.shape[-2:])
            b = jnp.broadcast_to(b, batch + b.shape[-2:])
            out = f3d(a.reshape((-1,) + a.shape[-2:]),
                      b.reshape((-1,) + b.shape[-2:]))
            out = out.reshape(batch + out.shape[-2:])
        if squeeze_a:
            out = out[..., 0, :]
        if squeeze_b:
            out = out[..., 0] if squeeze_a else out[..., :, 0]
        return out

    return call


def fdp_gemm_nd(a: jax.Array, b: jax.Array, *, spec: AccumulatorSpec,
                fmt=FP32, plan: GemmPlan | None = None,
                interpret: bool | None = None) -> jax.Array:
    """jnp.matmul-shaped entry point: 1-D promotion, numpy broadcasting of
    leading batch dims, then the 2-D kernel or the native batched grid."""
    f2d = lambda x, y: fdp_gemm(x, y, spec=spec, fmt=fmt, plan=plan,
                                interpret=interpret)
    f3d = lambda x, y: fdp_gemm_batched(x, y, spec=spec, fmt=fmt, plan=plan,
                                        interpret=interpret)
    return matmul_batching(f2d, f3d)(a, b)


# ---------------------------------------------------------------------------
# Sorted-segment (ragged / MoE) entry points
# ---------------------------------------------------------------------------
@partial(jax.jit,
         static_argnames=("spec", "fmt", "bm", "bn", "bk", "interpret"))
def _fdp_ragged_gemm_jit(x, w, group_sizes, *, spec, fmt, bm, bn, bk,
                         interpret):
    T, d = x.shape
    E, d2, f = w.shape
    assert d == d2, (x.shape, w.shape)
    pm, pn, pk = (-T) % bm, (-f) % bn, (-d) % bk
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, 0), (0, pk), (0, pn)))
    interp = (not _on_tpu()) if interpret is None else interpret
    out = fdp_ragged_gemm_pallas(x, w, group_sizes.astype(jnp.int32),
                                 spec=spec, fmt=fmt, bm=bm, bn=bn, bk=bk,
                                 interpret=interp)
    return out[:T, :f]


def fdp_ragged_gemm(x: jax.Array, w: jax.Array, group_sizes: jax.Array, *,
                    spec: AccumulatorSpec, fmt=FP32,
                    plan: GemmPlan | None = None,
                    interpret: bool | None = None) -> jax.Array:
    """Sorted-segment grouped GEMM: ``x (T, d)`` rows sorted by group,
    ``w (E, d, f)``, ``group_sizes (E,)`` -> ``(T, f)`` f32.

    Row ``t`` contracts against its group's weight matrix through the exact
    ⟨ovf,msb,lsb⟩ datapath in O(T·d·f) MACs: the Pallas grid walks one tile
    per (row-block, group) segment intersection — ``T/bm + E - 1`` tiles, not
    ``E`` passes over all ``T`` rows — with a scalar-prefetched index map
    picking each tile's expert weight block. Rows beyond ``sum(group_sizes)``
    produce zeros (matching ``jax.lax.ragged_dot``). Bit-identical to
    dispatching one GEMM per group: exact limb accumulation is
    order-invariant and rounds once at read-out.
    """
    T, d = x.shape
    f = w.shape[2]
    p = resolve_plan(plan, T, f, d)
    return _fdp_ragged_gemm_jit(x, w, group_sizes, spec=spec, fmt=fmt,
                                bm=p.bm, bn=p.bn, bk=p.bk, interpret=interpret)


@partial(jax.jit,
         static_argnames=("spec", "fmt", "bm", "bn", "bk", "interpret"))
def _fdp_ragged_dw_jit(x, g, group_sizes, *, spec, fmt, bm, bn, bk,
                       interpret):
    T, d = x.shape
    T2, f = g.shape
    assert T == T2, (x.shape, g.shape)
    pm, pn, pk = (-d) % bm, (-f) % bn, (-T) % bk
    if pk or pm:
        x = jnp.pad(x, ((0, pk), (0, pm)))
    if pk or pn:
        g = jnp.pad(g, ((0, pk), (0, pn)))
    interp = (not _on_tpu()) if interpret is None else interpret
    out = fdp_ragged_dw_pallas(x, g, group_sizes.astype(jnp.int32),
                               spec=spec, fmt=fmt, bm=bm, bn=bn, bk=bk,
                               interpret=interp)
    return out[:, :d, :f]


def fdp_ragged_dw(x: jax.Array, g: jax.Array, group_sizes: jax.Array, *,
                  num_groups: int, spec: AccumulatorSpec, fmt=FP32,
                  plan: GemmPlan | None = None,
                  interpret: bool | None = None) -> jax.Array:
    """Sorted-segment grouped weight gradient: ``dW[e] = X_eᵀ · G_e`` for
    ``x (T, d)`` / ``g (T, f)`` rows sorted by group -> ``(E, d, f)`` f32.

    The contraction dim is the ragged token dim: one tile per (token-block,
    group) intersection, routed to its group's output block — O(T·d·f) MACs.
    Zero-size groups (including leading/trailing ones) get exact-zero
    gradients. ``plan`` is fitted to the (d, f, T) problem, so ``plan.bk``
    is the token-block size (carry-safe by ``GemmPlan.fit``).
    """
    T, d = x.shape
    f = g.shape[1]
    if group_sizes.shape != (num_groups,):
        raise ValueError(f"group_sizes {group_sizes.shape} != ({num_groups},)")
    p = resolve_plan(plan, d, f, T)
    return _fdp_ragged_dw_jit(x, g, group_sizes, spec=spec, fmt=fmt,
                              bm=p.bm, bn=p.bn, bk=p.bk, interpret=interpret)
