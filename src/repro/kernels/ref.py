"""Pure-jnp oracle for the FDP GEMM kernel.

This is the normative implementation (repro.core.fdp), validated against a
python-``Fraction`` oracle in tests/test_accumulator.py; the Pallas kernel
must agree with it bit-for-bit.
"""

from __future__ import annotations

import jax

from repro.core import fdp
from repro.core.accumulator import AccumulatorSpec
from repro.core.formats import FP32


def fdp_gemm_ref(a: jax.Array, b: jax.Array, *, spec: AccumulatorSpec,
                 fmt=FP32) -> jax.Array:
    """(M,K) @ (K,N) -> (M,N) f32 with exact ⟨ovf,msb,lsb⟩ accumulation."""
    return fdp.fdp_gemm(a, b, spec, fmt)
