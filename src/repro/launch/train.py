"""End-to-end training driver.

Local (CPU) example:
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
        --steps 50 --batch 8 --seq 64 --ckpt /tmp/ckpt
On a real fleet the same driver runs with --mesh pod/multipod (the mesh is
only built when requested so CPU runs stay single-device).
"""

from __future__ import annotations

import argparse
import contextlib
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.accumulator import AccumulatorSpec
from repro.core.dispatch import policy_from_plan, use_policy
from repro.data.synthetic import SyntheticLM
from repro.models.layers import Distribution, LOCAL
from repro.core.qformat import parse_quant
from repro.train.loop import Trainer, make_train_step
from repro.train.optimizer import adamw, cosine_schedule, state_quant_from_policy


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--fdp-grad", action="store_true",
                    help="fixed-point (order-invariant) grad accumulation")
    ap.add_argument("--precision-plan", default=None,
                    help="train under a repro.numerics PrecisionPlan JSON "
                         "(v3 plans may also assign optimizer-state and "
                         "collective formats — honored automatically)")
    ap.add_argument("--opt-precision", default=None,
                    help="store Adam moments block-scaled: 'fp32', "
                         "'BITSxBLOCK' ('8x64'), or 'M,V' per-moment "
                         "('8x64,8x32'); overrides the plan's @state sites")
    ap.add_argument("--mesh", default=None,
                    help="RxC (data x model) device mesh, e.g. 2x4")
    ap.add_argument("--profile", default="fsdp",
                    choices=["fsdp", "ddp", "decode_tp"],
                    help="sharding profile when --mesh is set")
    ap.add_argument("--log", default=None)
    args = ap.parse_args(argv)

    from repro.core.schedules import preload_schedules
    from repro.launch.xla_flags import apply_xla_flags
    apply_xla_flags()
    n_sched = preload_schedules()
    if n_sched:
        print(f"[train] schedule zoo: {n_sched} GEMM schedules preloaded")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    fdp_spec = AccumulatorSpec(ovf=10, msb=10, lsb=-20) if args.fdp_grad else None
    policy = (policy_from_plan(args.precision_plan)
              if args.precision_plan else None)
    # optimizer-state formats: --opt-precision wins, else the plan's
    # opt.m@state / opt.v@state assignments (state_quant_from_policy)
    squant = state_quant_from_policy(policy)
    if args.opt_precision:
        parts = [p.strip() for p in args.opt_precision.split(",")]
        if len(parts) not in (1, 2):
            raise SystemExit("--opt-precision takes 'FMT' or 'M_FMT,V_FMT'")
        cfgs = [parse_quant(p) for p in parts]
        if len(cfgs) == 1:
            cfgs = cfgs * 2
        squant = {m: c for m, c in zip(("mu", "nu"), cfgs)
                  if c.mode == "block"} or None
    opt = adamw(lr=cosine_schedule(args.lr, warmup=10, total=args.steps),
                state_quant=squant)
    if squant:
        print("[train] quantized optimizer state: "
              + ", ".join(f"{m}={c.tag()}" for m, c in sorted(squant.items())))
    dist, place = LOCAL, None
    if args.mesh:
        from repro.launch import sharding as shd
        mesh = shd.make_mesh(args.mesh)
        dist = shd.distribution_for(mesh, args.profile,
                                    numerics_policy=policy)

        def place(carry):
            params, opt_state = carry
            ps = shd.param_shardings(cfg, params, mesh, profile=args.profile)
            oss = shd.opt_state_shardings(cfg, opt_state, ps, mesh,
                                          profile=args.profile)
            return jax.device_put(params, ps), jax.device_put(opt_state, oss)

    step_fn = make_train_step(cfg, opt, dist, remat="none",
                              microbatches=args.microbatches,
                              fdp_grad_spec=fdp_spec, donate=False,
                              numerics_policy=policy)
    data_src = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=0)

    def data(step):
        tb = data_src.batch(step)
        batch = {"tokens": tb.tokens, "targets": tb.targets,
                 "loss_mask": tb.loss_mask}
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (args.batch, cfg.n_patches, cfg.d_model), jnp.float32)
        if cfg.family == "encdec":
            batch["frames"] = jax.random.normal(
                jax.random.key(step), (args.batch, cfg.enc_seq, cfg.d_model))
        return batch

    trainer = Trainer(cfg, opt, data, step_fn, args.ckpt,
                      save_every=args.save_every, place_state=place)
    # the step carries the policy itself (make_train_step numerics_policy);
    # keep the ambient context too so any dispatch outside the jitted step
    # (debug probes, future eval hooks) agrees with it.
    ctx = use_policy(policy) if policy is not None else contextlib.nullcontext()
    t0 = time.time()
    with ctx:
        trainer.run(args.steps)
    dt = time.time() - t0
    losses = [m["loss"] for m in trainer.metrics_log]
    plan_note = f" plan={policy.name}" if policy is not None else ""
    if args.log:
        with open(args.log, "w") as f:
            json.dump(trainer.metrics_log, f)
    if not losses:
        # resumed from a checkpoint that already reached --steps: a no-op
        # run is a successful (idempotent) outcome, not a crash (the --log
        # file above still gets written — as an empty list — so sweep
        # runners never read a stale log from a previous run)
        print(f"[train] {args.arch}: checkpoint at {args.ckpt} already "
              f"covers {args.steps} steps; nothing to do")
        return
    print(f"[train] {args.arch}{' (reduced)' if args.reduced else ''}: "
          f"{args.steps} steps in {dt:.1f}s;{plan_note} "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    assert losses[-1] < losses[0], "training did not reduce loss"


if __name__ == "__main__":
    main()
