import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh (16x16 single pod / 2x16x16 multi-pod) with 512 host
placeholder devices, and extract the roofline terms from the compiled module.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
Each cell writes a JSON with memory_analysis, cost_analysis, and the summed
collective bytes (parsed from the post-SPMD HLO, scan-body collectives
multiplied by their while-loop trip counts).
"""  # noqa: E402

import argparse
import dataclasses
import json
import re
import sys
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, all_arch_names
from repro.models import SHAPES, shape_applicable
from repro.models import transformer as T
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.layers import Distribution
from repro.train.loop import make_loss_fn
from repro.train.optimizer import adamw

from .mesh import make_production_mesh, dp_axes_of
from .sharding import (batch_shardings, cache_shardings, opt_state_shardings,
                       param_shardings)

# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per chip (aggregate link budget)


def _dist(mesh, joint_tp: bool = False) -> Distribution:
    return Distribution(mesh=mesh, dp_axes=dp_axes_of(mesh), tp_axis="model",
                        joint_tp=joint_tp)


def _abstract_batch(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        batch = {"tokens": sds((B, 1), jnp.int32)}
    else:
        batch = {"tokens": sds((B, S), jnp.int32)}
        if shape.kind == "train":
            batch["targets"] = sds((B, S), jnp.int32)
            batch["loss_mask"] = sds((B, S), jnp.float32)
    if cfg.family == "vlm" and shape.kind != "decode":
        # image prefix is part of the sequence budget
        n_text = S - cfg.n_patches
        batch["tokens"] = sds((B, n_text), jnp.int32)
        if shape.kind == "train":
            batch["targets"] = sds((B, n_text), jnp.int32)
            batch["loss_mask"] = sds((B, n_text), jnp.float32)
        batch["patches"] = sds((B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec" and shape.kind != "decode":
        batch["frames"] = sds((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    return batch


def input_specs(arch: str, shape_name: str = "train_4k"):
    """Public API: ShapeDtypeStruct stand-ins for every model input of a
    given (architecture, shape) cell — weak-type-correct, shardable, no
    device allocation."""
    cfg = get_config(arch) if isinstance(arch, str) else arch
    return _abstract_batch(cfg, SHAPES[shape_name])


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, moe_impl="tp",
               remat="block", profile: str = "auto", kv_cache: str = "bf16"):
    """Returns (jitted_fn, example_args_avals) ready to lower.

    profile: parameter-sharding profile (launch.sharding.param_specs);
    "auto" = decode_tp for decode cells, fsdp otherwise."""
    if profile == "auto":
        profile = "decode_tp" if shape.kind == "decode" else "fsdp"
    dist = _dist(mesh, joint_tp=(profile == "decode_tp"))
    cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    aparams = T.init_abstract(cfg)
    pshard = param_shardings(cfg, aparams, mesh, profile=profile)
    bshard = batch_shardings(cfg, shape, mesh)
    abatch = _abstract_batch(cfg, shape)
    bshard = {k: bshard[k] for k in abatch}

    if shape.kind == "train":
        opt = adamw(lr=1e-4)
        aopt = jax.eval_shape(opt.init, aparams)
        oshard = opt_state_shardings(cfg, aopt, pshard, mesh, profile=profile)
        loss_fn = make_loss_fn(cfg, dist, remat=remat, moe_impl=moe_impl)

        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            updates, opt_state = opt.update(grads, opt_state, params)
            params = jax.tree.map(lambda p, u: p + u, params, updates)
            return params, opt_state, metrics

        fn = jax.jit(
            train_step,
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard,
                           NamedSharding(mesh, P())),
            donate_argnums=(0, 1),
        )
        return fn, (aparams, aopt, abatch)

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            logits = T.forward(params, cfg, batch, dist, remat=remat,
                               moe_impl=moe_impl)
            return logits[:, -1, :]                     # next-token logits

        dp = dist.dp
        fn = jax.jit(prefill_step,
                     in_shardings=(pshard, bshard),
                     out_shardings=NamedSharding(mesh, P(dp, "model")))
        return fn, (aparams, abatch)

    # decode
    acache = jax.eval_shape(
        lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len,
                             quantized=(kv_cache == "int8")))
    cshard = cache_shardings(cfg, shape, mesh, acache, profile=profile)

    def serve_step(params, cache, tokens):
        logits, cache = T.decode_step(params, cfg, cache, tokens, dist,
                                      moe_impl=moe_impl)
        return logits, cache

    fn = jax.jit(serve_step,
                 in_shardings=(pshard, cshard, bshard["tokens"]),
                 out_shardings=(NamedSharding(mesh, P()), cshard),
                 donate_argnums=(1,))
    return fn, (aparams, acache, _abstract_batch(cfg, shape)["tokens"])


# ---------------------------------------------------------------------------
# HLO collective analysis (exact: call graph + known_trip_count)
# ---------------------------------------------------------------------------
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_OP_RE = re.compile(
    r"=\s+(\([^=]*?\)|\S+)\s+(all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)(-start)?\(")
_TRIP_RE = re.compile(r'known_trip_count[":{ ]+n["\s:]+"?(\d+)')

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "f64": 8, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f8e4m3": 1,
                "f8e5m2": 1, "s16": 2, "u16": 2}


def _shape_bytes(shape_str: str) -> int:
    """Bytes of an HLO shape string like 'bf16[16,128]' or a tuple of them."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _parse_computations(hlo_text: str):
    """Split compiled HLO into computations; return (comps, entry_name)."""
    comps, cur, entry = {}, None, None
    for line in hlo_text.splitlines():
        ls = line.strip()
        if (ls.startswith("%") or ls.startswith("ENTRY")) and \
                ls.endswith("{") and "(" in ls:
            name = ls.split()[1] if ls.startswith("ENTRY") else ls.split()[0]
            name = name.lstrip("%").split("(")[0].strip()
            cur = name
            comps[cur] = []
            if ls.startswith("ENTRY"):
                entry = cur
        elif cur is not None:
            comps[cur].append(line)
    return comps, entry


def collective_bytes(hlo_text: str, loop_trip_counts: dict | None = None):
    """Exact per-device collective payload bytes of a compiled module.

    Builds the computation call graph (while bodies with their
    ``known_trip_count``, fusions/calls/conditionals with x1) and propagates
    execution multipliers from the entry, so a collective inside the layer
    scan counts n_layers times, one inside a nested scan counts the product,
    etc. Returns (total_bytes, per_kind dict, details list).
    """
    comps, entry = _parse_computations(hlo_text)
    default_trip = (loop_trip_counts or {}).get("default", 1)

    edges = {}
    for cname, lines in comps.items():
        out = []
        for ln in lines:
            trip = None
            mt = _TRIP_RE.search(ln)
            if mt:
                trip = int(mt.group(1))
            mb = re.search(r"body=%?([\w.\-]+)", ln)
            if mb:
                out.append((mb.group(1), trip or default_trip))
            for pat in (r"condition=%?([\w.\-]+)", r"calls=%?([\w.\-]+)",
                        r"to_apply=%?([\w.\-]+)"):
                for m in re.finditer(pat, ln):
                    out.append((m.group(1), 1))
            bc = re.search(r"branch_computations=\{([^}]*)\}", ln)
            if bc:
                for n in bc.group(1).split(","):
                    out.append((n.strip().lstrip("%"), 1))
        edges[cname] = out

    mult = {c: 0 for c in comps}
    if entry:
        mult[entry] = 1
    changed, iters = True, 0
    while changed and iters < 64:          # call graph is a DAG; converges
        changed, iters = False, iters + 1
        for caller, m_c in list(mult.items()):
            if not m_c:
                continue
            for callee, trip in edges.get(caller, []):
                new = m_c * trip
                if callee in mult and new > mult[callee]:
                    mult[callee] = new
                    changed = True

    per_kind, details, total = {}, [], 0
    for cname, lines in comps.items():
        m_c = max(mult.get(cname, 0), 1) if mult.get(cname, 0) else 1
        m_c = mult.get(cname, 0) or 1
        for ln in lines:
            m = _COLL_OP_RE.search(ln)
            if not m:
                continue
            nbytes = _shape_bytes(m.group(1)) * m_c
            kind = m.group(2)
            total += nbytes
            per_kind[kind] = per_kind.get(kind, 0) + nbytes
            details.append({"comp": cname, "kind": kind,
                            "bytes": nbytes, "mult": m_c})
    return total, per_kind, details


def _call_multipliers(comps, entry, default_trip=1):
    """Execution-count multiplier per computation from the call graph
    (while bodies x known_trip_count, everything else x1). Also returns the
    set of fusion-internal computations (targets of calls=)."""
    edges, fusion_targets = {}, set()
    for cname, lines in comps.items():
        out = []
        for ln in lines:
            mt = _TRIP_RE.search(ln)
            trip = int(mt.group(1)) if mt else None
            mb = re.search(r"body=%?([\w.\-]+)", ln)
            if mb:
                out.append((mb.group(1), trip or default_trip))
            for pat in (r"condition=%?([\w.\-]+)", r"to_apply=%?([\w.\-]+)"):
                for m in re.finditer(pat, ln):
                    out.append((m.group(1), 1))
            for m in re.finditer(r"calls=%?([\w.\-]+)", ln):
                out.append((m.group(1), 1))
                fusion_targets.add(m.group(1))
            bc = re.search(r"branch_computations=\{([^}]*)\}", ln)
            if bc:
                for n in bc.group(1).split(","):
                    out.append((n.strip().lstrip("%"), 1))
        edges[cname] = out
    mult = {c: 0 for c in comps}
    if entry:
        mult[entry] = 1
    changed, iters = True, 0
    while changed and iters < 64:
        changed, iters = False, iters + 1
        for caller, m_c in list(mult.items()):
            if not m_c:
                continue
            for callee, trip in edges.get(caller, []):
                new = m_c * trip
                if callee in mult and new > mult[callee]:
                    mult[callee] = new
                    changed = True
    return mult, fusion_targets


_OP_RE = re.compile(r"^\s*%?([\w.\-]+)\s*=\s*((?:\([^=]*?\)|\S+))\s+(\w[\w\-]*)\(")
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_dims(shape_str):
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None, []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


def hlo_cost(hlo_text: str, default_trip: int = 1):
    """Exact-ish per-device (flops, dot_traffic_bytes) of a compiled module.

    flops: every dot op (2 x output elements x contraction size), weighted by
    its computation's execution count — fixing XLA cost_analysis's
    loop-body-counted-once behaviour.
    dot_traffic_bytes: lhs+rhs+out bytes of every dot, likewise weighted — a
    matmul-traffic lower bound on HBM movement (the memory roofline term is
    max(this, XLA's whole-module bytes-accessed)).
    """
    comps, entry = _parse_computations(hlo_text)
    mult, _fusion_targets = _call_multipliers(comps, entry, default_trip)

    flops = 0
    dot_bytes = 0
    for cname, lines in comps.items():
        m_c = mult.get(cname, 0)
        if not m_c:
            continue
        syms = {}
        for ln in lines:
            mo = _OP_RE.match(ln)
            if mo:
                syms[mo.group(1)] = mo.group(2)
        for ln in lines:
            mo = _OP_RE.match(ln)
            if not mo:
                continue
            _name, out_shape, op = mo.groups()
            if op != "dot":
                continue
            args = re.findall(r"%([\w.\-]+)", ln.split("(", 1)[1])
            cd = _DOT_DIMS_RE.search(ln)
            lhs_shape = syms.get(args[0]) if args else None
            rhs_shape = syms.get(args[1]) if len(args) > 1 else None
            csize = 1
            if cd and lhs_shape:
                _, dims = _shape_dims(lhs_shape)
                for d in cd.group(1).split(","):
                    if d and int(d) < len(dims):
                        csize *= dims[int(d)]
            out_elems = 1
            _, odims = _shape_dims(out_shape)
            for d in odims:
                out_elems *= d
            flops += 2 * out_elems * csize * m_c
            b = _shape_bytes(out_shape)
            for s in (lhs_shape, rhs_shape):
                if s:
                    b += _shape_bytes(s)
            dot_bytes += b * m_c
    return flops, dot_bytes


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------
def run_cell(arch: str, shape_name: str, multi_pod: bool, moe_impl="tp",
             remat="block", profile: str = "auto", kv_cache: str = "bf16",
             precision_plan: str | None = None):
    if precision_plan:
        # a numerics plan changes what lowers (native sites stay MXU dots,
        # simulate/pallas sites lower their FDP limb algebra), so the whole
        # build+compile runs under the plan's policy
        from repro.core.dispatch import policy_from_plan, use_policy
        with use_policy(policy_from_plan(precision_plan)):
            return run_cell(arch, shape_name, multi_pod, moe_impl=moe_impl,
                            remat=remat, profile=profile, kv_cache=kv_cache)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = len(mesh.devices.reshape(-1))
    fn, avals = build_cell(cfg, shape, mesh, moe_impl=moe_impl, remat=remat,
                           profile=profile, kv_cache=kv_cache)
    lowered = fn.lower(*avals)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):        # newer jax: one dict per device
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    scan_len = {"dense": cfg.n_layers, "moe": cfg.n_layers,
                "vlm": cfg.n_layers, "ssm": cfg.n_layers,
                "encdec": cfg.n_layers + cfg.n_enc_layers,
                "hybrid": cfg.n_layers}[cfg.family]
    coll_total, coll_kinds, _ = collective_bytes(
        hlo, {"default": scan_len})

    # exact per-device flops from the compiled HLO with while-loop trip-count
    # multipliers (XLA's cost_analysis counts loop bodies once); memory term
    # = max(XLA whole-module bytes-accessed, matmul-traffic bound)
    flops, dot_bytes = hlo_cost(hlo, default_trip=scan_len)
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    flops = float(max(flops, xla_flops))
    bytes_accessed = float(max(dot_bytes, xla_bytes))
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    n_active = cfg.active_param_count()
    model_flops = (6 if shape.kind == "train" else 2) * n_active * tokens

    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_accessed / HBM_BW
    t_coll = coll_total / ICI_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "kind": shape.kind,
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "per_device_total": int(mem.argument_size_in_bytes
                                    + mem.output_size_in_bytes
                                    + mem.temp_size_in_bytes
                                    - mem.alias_size_in_bytes),
        },
        "cost": {"flops": flops, "bytes_accessed": bytes_accessed,
                 "xla_flops_no_trip": xla_flops,
                 "xla_bytes_no_trip": xla_bytes},
        "collectives": {"total_bytes": int(coll_total), "by_kind": coll_kinds},
        "roofline": {
            "t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dominant,
            "model_flops_total": float(model_flops),
            "model_flops_per_chip": float(model_flops / n_chips),
            "useful_flops_ratio": float(
                (model_flops / n_chips) / flops) if flops else None,
        },
    }
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--moe-impl", default="tp", choices=["tp", "ep"])
    ap.add_argument("--remat", default="block")
    ap.add_argument("--param-profile", default="auto",
                    choices=["auto", "fsdp", "ddp", "decode_tp"])
    ap.add_argument("--kv-cache", default="bf16", choices=["bf16", "int8"])
    ap.add_argument("--precision-plan", default=None,
                    help="lower under a repro.numerics PrecisionPlan JSON")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    # XLA_FLAGS merge is a no-op for flags already set (the module top pins
    # the 512 host devices before jax import); schedules warm the plan cache
    # so plan-lowered cells never autotune mid-sweep.
    from repro.core.schedules import preload_schedules
    from repro.launch.xla_flags import apply_xla_flags
    apply_xla_flags()
    n_sched = preload_schedules()
    if n_sched:
        print(f"[dryrun] schedule zoo: {n_sched} GEMM schedules preloaded")

    cells = []
    if args.all:
        for arch in all_arch_names():
            for shape in SHAPES:
                for mp in (False, True):
                    cells.append((arch, shape, mp))
    else:
        cells = [(args.arch, args.shape, args.multi_pod)]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape, mp in cells:
        tag = (f"{arch}_{shape}_{'pod2' if mp else 'pod1'}_{args.moe_impl}_"
               f"{args.remat}")
        if args.param_profile != "auto":
            tag += f"_{args.param_profile}"
        if args.kv_cache != "bf16":
            tag += f"_kv{args.kv_cache}"
        if args.precision_plan:
            tag += "_planned"
        path = os.path.join(args.out, tag + ".json")
        if args.skip_existing and os.path.exists(path):
            print(f"[dryrun] {tag}: cached")
            continue
        try:
            res = run_cell(arch, shape, mp, moe_impl=args.moe_impl,
                           remat=args.remat, profile=args.param_profile,
                           kv_cache=args.kv_cache,
                           precision_plan=args.precision_plan)
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            if "skipped" in res:
                print(f"[dryrun] {tag}: SKIP ({res['skipped']})")
            else:
                r = res["roofline"]
                print(f"[dryrun] {tag}: OK mem/dev="
                      f"{res['memory']['per_device_total']/2**30:.2f}GiB "
                      f"t_comp={r['t_compute_s']*1e3:.1f}ms "
                      f"t_mem={r['t_memory_s']*1e3:.1f}ms "
                      f"t_coll={r['t_collective_s']*1e3:.1f}ms "
                      f"dom={r['dominant']}")
        except Exception as e:
            failures += 1
            print(f"[dryrun] {tag}: FAIL {type(e).__name__}: {e}")
            traceback.print_exc()
            with open(path + ".fail", "w") as f:
                f.write(traceback.format_exc())
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
