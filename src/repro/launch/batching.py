"""Continuous batching for the serving path.

A slot-based scheduler in the vLLM style, shaped for JAX: the decode step is
compiled ONCE for a fixed (n_slots, max_len) cache; requests stream in and
out of slots between steps (host-side bookkeeping, device-side state is
donated through the jitted step). Finished slots are refilled immediately —
the decode batch never drains while work is queued.

This is the production serving loop for the framework; `examples/serve_batch`
uses the simple whole-batch variant, `tests/test_serving.py` exercises this
scheduler end-to-end.
"""

from __future__ import annotations

import contextlib
import dataclasses
from collections import deque
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import NumericsPolicy, policy_from_plan, use_policy
from repro.models import decode_step, init_cache
from repro.models.layers import LOCAL


def _resolve_policy(policy) -> Optional[NumericsPolicy]:
    """Normalize the engine's numerics argument: a NumericsPolicy passes
    through, a PrecisionPlan deploys itself, a str/path loads a plan JSON."""
    if policy is None or isinstance(policy, NumericsPolicy):
        return policy
    if hasattr(policy, "to_policy"):               # PrecisionPlan duck-type
        return policy.to_policy()
    if isinstance(policy, (str, bytes)) or hasattr(policy, "__fspath__"):
        return policy_from_plan(policy)
    raise TypeError(
        f"policy must be a NumericsPolicy, PrecisionPlan, or plan path; "
        f"got {type(policy).__name__}")


class CacheExhausted(RuntimeError):
    """The engine's global KV write cursor can no longer fit any queued
    request. The cursor (``cache["len"]``) is shared across slots and never
    rewinds, so once the queue head's ``prompt + max_new`` exceeds
    ``cache_remaining()`` nothing will ever be admitted again — call
    ``reset_cache()`` between drained generations, or serve through the
    ``repro.serving`` frontend, whose admission control parks requests and
    recycles engines instead of stalling."""


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list              # token ids
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    # scheduling evidence, recorded by ContinuousBatcher.step: how many
    # engine steps this request was live in, and how its token budget split
    # between prefill (prompt tokens fed) and decode (tokens generated).
    # The serving tier's per-class stats read these; tests assert on them.
    steps: int = 0
    prefill_tokens: int = 0
    decode_tokens: int = 0
    # streaming hook: called with each freshly decoded token id, from inside
    # the engine step that produced it (the serving tier's `stream` method)
    on_token: Optional[Callable[[int], None]] = None


class ContinuousBatcher:
    """Fixed-slot continuous batching engine.

    The cache is allocated for n_slots sequences of max_len. Prompt tokens
    are fed through the same decode_step (one token per step per slot —
    chunked prefill); slots whose request finished are re-assigned without
    recompiling anything.
    """

    def __init__(self, cfg, params, n_slots: int = 4, max_len: int = 128,
                 dist=LOCAL, eos_id: Optional[int] = None,
                 warmup: Union[bool, NumericsPolicy, str, object] = False,
                 policy=None):
        self.cfg, self.params, self.dist = cfg, params, dist
        self.n_slots, self.max_len = n_slots, max_len
        self.eos_id = eos_id
        assert cfg.family in ("dense", "moe", "vlm"), \
            "continuous batching engine supports KV-cache families"
        # ``warmup`` doubles as the numerics argument: passing a
        # NumericsPolicy / PrecisionPlan / plan path both installs the policy
        # AND warms up under it (the common plan-serving call shape).
        if not isinstance(warmup, bool):
            if policy is not None:
                raise TypeError(
                    "pass the numerics either as warmup=<plan/policy> or as "
                    "policy=..., not both — silently preferring one would "
                    "bake the other's formats out of the compiled step")
            policy = warmup
            warmup = True
        self.policy = _resolve_policy(policy)
        self.queue: deque[Request] = deque()
        self.active: list[Optional[Request]] = [None] * n_slots
        # per-slot progress: how many prompt tokens already fed
        self._fed = np.zeros(n_slots, dtype=np.int64)
        self.cache = init_cache(cfg, n_slots, max_len, dtype=jnp.float32)
        # the write cursor cache["len"] is global; each slot masks its
        # attention to [start[slot], len) so reused slots never see the
        # previous occupant's KV. ``_len`` mirrors the cursor host-side so
        # admission control never forces a device sync.
        self._len = 0
        self._start = np.zeros(n_slots, dtype=np.int32)
        self.cache["start"] = jnp.zeros((n_slots,), jnp.int32)
        # traced exactly once per engine when warmed up — the regression
        # guard for "warmup must compile under the serving policy"
        self.trace_count = 0

        def _step_fn(c, t):
            self.trace_count += 1            # python side effect: trace-time only
            return decode_step(params, cfg, c, t, dist)

        self._step = jax.jit(_step_fn)
        if warmup:
            # AOT-compile the decode step before the first request arrives.
            # Tracing it resolves every GEMM call-site's GemmPlan (the plan
            # cache is keyed on static shapes), so serving never pays plan
            # resolution or compilation inside the request loop. Numerics
            # policies bind at *trace* time (dispatch.gemm looks the site up
            # while tracing), so warmup must happen inside the policy context
            # — a warmup under the wrong policy would bake the wrong formats
            # into the compiled step and silently ignore the plan at serve
            # time. This is the ROADMAP "batching under plans" fix.
            tok0 = jnp.zeros((n_slots, 1), jnp.int32)
            with self._policy_ctx():
                self._step = self._step.lower(self.cache, tok0).compile()

    def _policy_ctx(self):
        return use_policy(self.policy) if self.policy is not None \
            else contextlib.nullcontext()

    def cache_remaining(self) -> int:
        """Writable KV positions left before the global write cursor hits the
        cache wall. The cursor advances one position per engine step (shared
        by every slot) and never rewinds, so this is the budget any newly
        admitted request's ``prompt + max_new`` must fit inside."""
        return max(0, self.max_len - 1 - self._len)

    def reset_cache(self) -> None:
        """Reclaim KV room without recompiling: reallocate the cache and
        rewind the cursor. The compiled decode step is shape-stable — the
        cache is data — so this is the cheap lifecycle move for long-running
        engines. Only legal while no slot is live (a live slot's KV would be
        destroyed mid-generation)."""
        if any(r is not None for r in self.active):
            raise RuntimeError("reset_cache with live slots would destroy "
                               "in-flight generations; drain first")
        self.cache = init_cache(self.cfg, self.n_slots, self.max_len,
                                dtype=jnp.float32)
        self._len = 0
        self._start[:] = 0
        self.cache["start"] = jnp.zeros((self.n_slots,), jnp.int32)

    def stats(self):
        """Typed ``PlanCacheStats`` for the process-global GemmPlan cache —
        the serving-health counters (a warm engine over a preloaded schedule
        zoo shows ``misses == 0``, ``persisted_loads > 0``).

        .. deprecated:: a view over the ``repro.obs`` registry
           (``repro_plan_cache_ops_total`` / ``repro_plan_cache_size``);
           scrape the registry for monitoring."""
        from repro.core import dispatch
        return dispatch.plan_cache_stats()

    def numerics_info(self) -> dict:
        """GemmPlan cache + call-site report for this engine's decode step
        (introspection: what the dispatch layer planned for serving)."""
        from repro.core import dispatch
        return {"plans": self.stats().as_dict(),
                "sites": sorted(dispatch.sites_seen()),
                "policy": self.policy.name if self.policy else None}

    def submit(self, req: Request):
        self.queue.append(req)

    def _fill_slots(self):
        changed = False
        for i in range(self.n_slots):
            if self.active[i] is None and self.queue:
                head = self.queue[0]
                if len(head.prompt) + head.max_new > self.cache_remaining():
                    # the cursor has outrun the cache: admitting this request
                    # would silently truncate its generation (the historical
                    # bug). Refuse the slot and leave it queued — FIFO, so
                    # later smaller requests never starve the head.
                    break
                self.active[i] = self.queue.popleft()
                self._fed[i] = 0
                self._start[i] = self._len
                changed = True
        if changed:
            self.cache["start"] = jnp.asarray(self._start)

    def _next_tokens(self):
        toks = np.zeros((self.n_slots, 1), dtype=np.int32)
        for i, req in enumerate(self.active):
            if req is None:
                continue
            if self._fed[i] < len(req.prompt):        # still prefilling
                toks[i, 0] = req.prompt[self._fed[i]]
            elif req.out:
                toks[i, 0] = req.out[-1]
            else:
                toks[i, 0] = req.prompt[-1]
        return jnp.asarray(toks)

    def step(self):
        """One engine step: feed one token per active slot."""
        self._fill_slots()
        if all(r is None for r in self.active):
            return False
        toks = self._next_tokens()
        # non-warmed engines trace lazily on the first step; entering the
        # policy context here keeps that trace (and any retrace) under the
        # same numerics the warmup path compiles with
        with self._policy_ctx():
            logits, self.cache = self._step(self.cache, toks)
        self._len += 1
        nxt = np.asarray(jnp.argmax(logits[:, 0, :self.cfg.vocab_size], -1))
        for i, req in enumerate(self.active):
            if req is None:
                continue
            self._fed[i] += 1
            req.steps += 1
            if self._fed[i] <= len(req.prompt):
                req.prefill_tokens += 1          # this step fed a prompt token
            if self._fed[i] < len(req.prompt):
                continue                                # still prefilling
            req.out.append(int(nxt[i]))
            req.decode_tokens += 1
            if req.on_token is not None:
                req.on_token(req.out[-1])
            hit_eos = self.eos_id is not None and req.out[-1] == self.eos_id
            # the cursor wall: the next feed would write past the cache.
            # Admission control (cache_remaining) guarantees this never fires
            # for admitted requests; it stays as the last-ditch guard.
            at_wall = self._len >= self.max_len - 1
            if len(req.out) >= req.max_new or hit_eos or at_wall:
                req.done = True
                self.active[i] = None                   # slot freed
        return True

    def run(self, max_steps: int = 10_000) -> None:
        """Drive until the queue and all slots drain (or max_steps).

        Raises ``CacheExhausted`` when the queue is non-empty but nothing can
        ever be admitted (the global cursor has outrun the cache) — loud
        refusal instead of the old silent truncation."""
        from repro.obs.spans import span
        with span("serving.batcher_run", n_slots=self.n_slots,
                  max_len=self.max_len) as sp:
            steps = 0
            for _ in range(max_steps):
                if not self.step():
                    if self.queue:
                        head = self.queue[0]
                        raise CacheExhausted(
                            f"{len(self.queue)} queued request(s) can no "
                            f"longer fit: head needs "
                            f"{len(head.prompt) + head.max_new} positions, "
                            f"cache_remaining()={self.cache_remaining()} "
                            f"of max_len={self.max_len}")
                    break
                steps += 1
            sp.annotate(steps=steps)


def serve_requests(cfg, params, requests: list[Request], n_slots: int = 4,
                   max_len: int = 128, dist=LOCAL, warmup=False,
                   policy=None) -> list[Request]:
    """Convenience: run a list of requests to completion."""
    eng = ContinuousBatcher(cfg, params, n_slots, max_len, dist,
                            warmup=warmup, policy=policy)
    for r in requests:
        eng.submit(r)
    eng.run()
    return requests
