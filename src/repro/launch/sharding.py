"""Sharding rules: parameter pytree -> PartitionSpec tree, per (arch, shape).

Strategy (DESIGN.md §5):
  * FSDP/ZeRO-3: every weight matrix shards its d_model-sized axis over
    "data"; per-layer slices are all-gathered just-in-time inside the layer
    scan (XLA SPMD inserts the gather on the scan body's slice).
  * TP: d_ff / vocab / d_inner / expert-ffn shard over "model".
  * SP: activations between blocks are sequence-sharded over "model"
    (constraints in the model code).
  * Decode caches shard (batch over dp when divisible) + head_dim over
    "model" (head_dim is a multiple of 16 for every assigned arch); the
    single-sequence long-context cells shard kv-heads over "data".
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

PROFILES = ("fsdp", "ddp", "decode_tp")


def parse_mesh(spec: str) -> tuple:
    """Parse an ``RxC`` CLI mesh spec ("2x4" -> (2, 4); "8" -> (8, 1))."""
    parts = spec.lower().replace("×", "x").split("x")
    if len(parts) == 1:
        parts = parts + ["1"]
    if len(parts) != 2 or not all(p.strip().isdigit() for p in parts):
        raise ValueError(f"bad mesh spec {spec!r}; expected RxC like 2x4")
    return int(parts[0]), int(parts[1])


def make_mesh(shape) -> jax.sharding.Mesh:
    """(data, model) mesh over the available devices; shape may be a
    ``parse_mesh`` tuple or an ``RxC`` string."""
    if isinstance(shape, str):
        shape = parse_mesh(shape)
    r, c = shape
    n = jax.device_count()
    if r * c != n:
        raise ValueError(f"mesh {r}x{c} wants {r * c} devices, have {n}")
    return jax.make_mesh((r, c), ("data", "model"))


def distribution_for(mesh, profile: str = "fsdp", numerics_policy=None):
    """The Distribution a launch profile runs the model under, with the
    deployed plan's NumericsPolicy riding along (threaded into shard_map'd
    train/serve steps by make_train_step / serve)."""
    from repro.models.layers import Distribution
    if profile not in PROFILES:
        raise ValueError(f"unknown profile {profile!r}; one of {PROFILES}")
    return Distribution(mesh=mesh, dp_axes=("data",), tp_axis="model",
                        joint_tp=profile == "decode_tp",
                        numerics_policy=numerics_policy)


def _leaf_spec(path: str, ndim: int, extra_lead: int) -> P:
    """PartitionSpec for a parameter leaf; ``extra_lead`` = # stacked layer
    dims to leave unsharded (1 for scanned layers, 2 for hybrid groups)."""
    lead = (None,) * extra_lead

    def pad(spec):                     # right-pad with None to ndim
        spec = lead + spec
        return P(*(spec + (None,) * (ndim - len(spec))))

    name = path.split("/")[-1]
    # --- non-layer params (extra_lead == 0) -------------------------------
    if name == "embed":
        return P("model", "data")
    if name == "lm_head":
        return P("data", "model")
    # --- norms / scalars / biases ------------------------------------------
    if "norm" in name or name in ("A_log", "D", "dt_bias", "bq", "bk", "bv"):
        if name == "norm" and ndim - extra_lead == 1:
            return pad(("model",) if _is_ssm_norm(path) else (None,))
        return pad((None,) * (ndim - extra_lead))
    # --- attention ----------------------------------------------------------
    if name in ("wq", "wk", "wv"):
        return pad(("data", None))
    if name == "wo":
        return pad((None, "data"))
    # --- dense MLP -----------------------------------------------------------
    if name in ("w_in", "w_gate") and ndim - extra_lead == 2:
        return pad(("data", "model"))
    if name == "w_out" and ndim - extra_lead == 2:
        return pad(("model", "data"))
    # --- MoE ------------------------------------------------------------------
    if name == "router":
        return pad(("data", None))
    if name in ("w_in", "w_gate") and ndim - extra_lead == 3:
        return pad((None, "data", "model"))
    if name == "w_out" and ndim - extra_lead == 3:
        return pad((None, "model", "data"))
    # --- SSM -------------------------------------------------------------------
    if name in ("in_x", "in_z"):
        return pad(("data", "model"))
    if name in ("in_B", "in_C", "in_dt"):
        return pad(("data", None))
    if name == "conv_x":
        return pad((None, "model"))
    if name in ("conv_B", "conv_C"):
        return pad((None, None))
    if name == "out":
        return pad(("model", "data"))
    return pad((None,) * (ndim - extra_lead))


def _is_ssm_norm(path: str) -> bool:
    return path.endswith("ssm/norm")


def _lead_of(path: str, cfg) -> int:
    """How many stacked leading dims a leaf has."""
    parts = path.split("/")
    if parts[0] in ("layers", "enc_layers", "dec_layers"):
        return 2 if (cfg.family == "hybrid" and parts[0] == "layers") else 1
    return 0


def param_specs(cfg, abstract_params, profile: str = "fsdp", mesh=None):
    """PartitionSpec pytree matching the params pytree.

    Profiles (§Perf):
      fsdp      — ZeRO-3: weights sharded over data (largest axis) + TP over
                  model; per-layer just-in-time gathers. Right for models
                  whose weights don't fit replicated.
      ddp       — weights replicated (embed/lm_head stay vocab-TP), optimizer
                  state sharded over data (ZeRO-1). Right for small models
                  where per-step weight gathers dominate the collective term.
      decode_tp — weights-stay-put serving: every projection sharded over the
                  JOINT (data, model) axes on a 256-divisible dim, so decode
                  reads weights in place with zero gathers (activations are
                  tiny and psum'd).
    """

    def visit(tree, prefix):
        if isinstance(tree, dict):
            return {k: visit(v, f"{prefix}/{k}" if prefix else k)
                    for k, v in tree.items()}
        lead = _lead_of(prefix, cfg)
        if profile == "ddp":
            return _leaf_spec_ddp(prefix, tree.ndim, lead)
        if profile == "decode_tp":
            return _leaf_spec_decode_tp(prefix, tree, lead, mesh)
        return _leaf_spec(prefix, tree.ndim, lead)

    return visit(abstract_params, "")


def _leaf_spec_ddp(path: str, ndim: int, lead: int) -> P:
    name = path.split("/")[-1]
    if name == "embed":
        return P("model", None)
    if name == "lm_head":
        return P(None, "model")
    return P(*([None] * ndim))


def _leaf_spec_decode_tp(path: str, leaf, lead: int, mesh) -> P:
    name = path.split("/")[-1]
    joint = tuple(a for a in mesh.axis_names)        # all axes combined
    n_joint = 1
    for a in joint:
        n_joint *= mesh.shape[a]
    shape = leaf.shape
    spec = [None] * leaf.ndim
    if name in ("embed", "lm_head"):
        v_dim = 0 if name == "embed" else 1
        if shape[v_dim] % n_joint == 0:
            spec[v_dim] = joint
        else:
            spec[v_dim] = "model"
        return P(*spec)
    if leaf.ndim - lead < 2:                          # norms/bias/scalars
        return P(*spec)
    # prefer col-parallel on the last dim, else row-parallel, else model-only
    for dims, axes in (((-1,), joint), ((-2,), joint),
                       ((-1,), "model"), ((-2,), "model")):
        d = dims[0]
        n = n_joint if axes == joint else mesh.shape["model"]
        if shape[d] % n == 0:
            spec[d] = axes
            return P(*spec)
    return P(*spec)


def param_shardings(cfg, abstract_params, mesh, profile: str = "fsdp"):
    specs = param_specs(cfg, abstract_params, profile=profile, mesh=mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def opt_state_shardings(cfg, abstract_opt_state, param_shardings_tree, mesh,
                        profile: str = "fsdp"):
    """fsdp/decode_tp: mu/nu shadow the param shardings. ddp (ZeRO-1): mu/nu
    shard over data on each leaf's first data-divisible dim even though the
    params are replicated. Scalars replicated."""
    rep = NamedSharding(mesh, P())
    n_data = mesh.shape["data"]

    def zero1(leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim == 0:
            return rep
        for d, size in enumerate(leaf.shape):
            if size % n_data == 0 and size >= n_data:
                spec = [None] * leaf.ndim
                spec[d] = "data"
                return NamedSharding(mesh, P(*spec))
        return rep

    def shadow(node, params_node):
        return jax.tree.map(
            lambda l, s: s if hasattr(l, "ndim") and l.ndim > 0 else rep,
            node, params_node)

    out = {}
    for k, v in abstract_opt_state.items():
        if k in ("mu", "nu"):
            out[k] = (jax.tree.map(zero1, v) if profile == "ddp"
                      else shadow(v, param_shardings_tree))
        else:
            out[k] = jax.tree.map(lambda _: rep, v)
    return out


# ---------------------------------------------------------------------------
# Batch / cache shardings per shape kind
# ---------------------------------------------------------------------------
def batch_shardings(cfg, shape, mesh):
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp = dp if len(dp) > 1 else dp[0]
    B = shape.global_batch
    dp_size = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        dp_size *= mesh.shape[a]
    bspec = dp if B % dp_size == 0 else None

    def ns(*spec):
        return NamedSharding(mesh, P(*spec))

    out = {"tokens": ns(bspec, "model"), "targets": ns(bspec, "model"),
           "loss_mask": ns(bspec, "model")}
    if shape.kind == "decode":
        out = {"tokens": ns(bspec, None)}
    if cfg.family == "vlm":
        out["patches"] = ns(bspec, None, None)
    if cfg.family == "encdec":
        out["frames"] = ns(bspec, None, None)
    return out


def cache_shardings(cfg, shape, mesh, abstract_cache, profile: str = "fsdp"):
    """Decode-cache shardings (see module docstring).

    decode_tp profile: the KV cache shards its SEQUENCE dim over "model"
    (flash-decode partition): scores stay seq-sharded, the softmax reduces
    with tiny scalar psums and the PV contraction psums one (B,H,hd) vector
    per layer — instead of psumming (B,H,S)-sized score tensors when the
    head_dim is the sharded contraction. The size-1 cache write at position
    `len` lowers to a masked in-place update on the owning shard."""
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp = dp if len(dp) > 1 else dp[0]
    B = shape.global_batch
    dp_size = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        dp_size *= mesh.shape[a]
    b_ok = B % dp_size == 0
    bspec = dp if b_ok else None
    head_axis = None if b_ok else "data"   # B=1 cells: kv heads over data

    def ns(*spec):
        return NamedSharding(mesh, P(*spec))

    def visit(path, leaf):
        name = path[-1]
        nd = leaf.ndim
        spec = [None] * nd
        if name in ("k", "v"):
            # (..., B, Hkv, S, hd): hd over model (fsdp) or seq over model
            # (decode_tp flash-decode); batch over dp (or kv heads over data
            # for the B=1 long-context cells when divisible)
            if profile == "decode_tp" and _div(leaf.shape[-2],
                                               mesh.shape["model"]):
                spec[-2] = "model"
            elif _div(cfg.head_dim, mesh.shape["model"]):
                spec[-1] = "model"
            if b_ok:
                spec[-4] = bspec
            elif cfg.n_kv_heads % mesh.shape["data"] == 0:
                spec[-3] = "data"
            return ns(*spec)
        if name in ("k_scale", "v_scale"):
            # (..., B, Hkv, S): follow the cache's batch/seq sharding
            if profile == "decode_tp" and _div(leaf.shape[-1],
                                               mesh.shape["model"]):
                spec[-1] = "model"
            if b_ok:
                spec[-3] = bspec
            return ns(*spec)
        if name == "state":      # (..., B, g, e, p, n): e over model
            if _div(cfg.ssm_heads // cfg.ssm_groups, mesh.shape["model"]):
                spec[-3] = "model"
            if b_ok:
                spec[-5] = bspec
            return ns(*spec)
        if name.startswith("conv_"):  # (..., B, w-1, C)
            if name == "conv_x" and _div(cfg.d_inner, mesh.shape["model"]):
                spec[-1] = "model"
            if b_ok:
                spec[-3] = bspec
            return ns(*spec)
        if name == "len":
            return ns()
        return ns(*spec)

    return _map_with_path(visit, abstract_cache)


def _div(a, b):
    return a % b == 0


def _map_with_path(fn, tree, path=()):
    if isinstance(tree, dict):
        return {k: _map_with_path(fn, v, path + (k,)) for k, v in tree.items()}
    return fn(path, tree)
