"""Batched serving driver: prefill + greedy incremental decode with a KV/SSM
cache, request batching, and per-request length masks.

Local (CPU) example:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
        --batch 4 --prompt-len 12 --gen 16

``--precision-plan plan.json`` serves under a numerics plan produced by the
``repro.numerics`` tailoring search instead of the default uniform policy.
``--engine continuous`` routes the same requests through the fixed-slot
``ContinuousBatcher`` with plan-aware AOT warmup (the decode step compiles
under the plan's formats before the first request arrives, so plan-served
decode hits the compile cache instead of retracing mid-request).
``--engine routed`` goes through the full serving tier (``repro.serving``):
the plan zoo's MANIFEST picks each request's numerics by workload class
(``--workload``), a bucketed AOT engine pool serves it, and per-class
routing/latency stats print at the end.
"""

from __future__ import annotations

import argparse
import contextlib
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.dispatch import policy_from_plan, use_policy
from repro.models import decode_step, forward, init, init_cache, LOCAL
from repro.models.transformer import prefill


def serve(cfg, params, prompts, gen_len: int, dist=LOCAL):
    """prompts: (B, S) int32. Greedy decode gen_len tokens. Returns (B, gen)."""
    B, S = prompts.shape
    cache = init_cache(cfg, B, max_len=S + gen_len, dtype=jnp.float32)
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch["patches"] = jnp.zeros((B, cfg.n_patches, cfg.d_model))
    if cfg.family == "encdec":
        batch["frames"] = jnp.zeros((B, cfg.enc_seq, cfg.d_model))
    last_logits, cache = prefill(params, cfg, batch, cache, dist)

    step = jax.jit(lambda c, t: decode_step(params, cfg, c, t, dist))

    out = []
    tok = jnp.argmax(last_logits, axis=-1)[:, None].astype(jnp.int32)
    for _ in range(gen_len):
        out.append(tok)
        logits, cache = step(cache, tok)
        tok = jnp.argmax(logits[:, 0], axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--precision-plan", default=None,
                    help="serve under a repro.numerics PrecisionPlan JSON")
    ap.add_argument("--mesh", default=None,
                    help="RxC (data x model) device mesh, e.g. 2x4")
    ap.add_argument("--profile", default="decode_tp",
                    choices=["fsdp", "ddp", "decode_tp"],
                    help="sharding profile when --mesh is set")
    ap.add_argument("--engine", default="simple",
                    choices=["simple", "continuous", "routed"],
                    help="simple whole-batch decode, the fixed-slot "
                         "ContinuousBatcher with plan-aware warmup, or the "
                         "workload-routed bucketed serving tier")
    ap.add_argument("--workload", default="chat",
                    help="workload class (chat/solve/repro) or explicit plan "
                         "name for --engine routed")
    ap.add_argument("--plans", default="examples/plans",
                    help="plan zoo directory for --engine routed")
    ap.add_argument("--buckets", default=None,
                    help="slots x len bucket table for --engine routed, "
                         "e.g. 2x32,4x64 (default: one bucket sized to fit)")
    ap.add_argument("--monitor", action="store_true",
                    help="serve under live calibration-envelope monitors "
                         "(envelope from --precision-plan or the zoo plan)")
    ap.add_argument("--metrics-dump", default=None, metavar="PATH",
                    help="write the unified metrics registry (+ monitor "
                         "snapshot) as JSON when serving finishes")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="expose /metrics (Prometheus text) and "
                         "/metrics.json on this local port while serving")
    ap.add_argument("--metrics-hold", type=float, default=0.0,
                    help="keep the --metrics-port server up this many "
                         "seconds after serving completes")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export the span timeline as Chrome-trace JSON")
    args = ap.parse_args(argv)

    from repro.core.schedules import preload_schedules
    from repro.launch.xla_flags import apply_xla_flags
    apply_xla_flags()
    n_sched = preload_schedules(os.path.join(args.plans, "schedules"))
    if n_sched:
        print(f"[serve] schedule zoo: {n_sched} GEMM schedules preloaded "
              f"(warm plan cache, zero autotune misses)")

    cfg = get_config(args.arch)
    base_arch = cfg.name
    if args.reduced:
        cfg = cfg.reduced()
    params = init(cfg, jax.random.key(0))
    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    policy = (policy_from_plan(args.precision_plan)
              if args.precision_plan else None)
    dist = LOCAL
    if args.mesh:
        if args.engine != "simple":
            raise SystemExit("--mesh is supported with --engine simple only")
        from repro.launch import sharding as shd
        mesh = shd.make_mesh(args.mesh)
        dist = shd.distribution_for(mesh, args.profile,
                                    numerics_policy=policy)
        params = jax.device_put(
            params, shd.param_shardings(cfg, params, mesh,
                                        profile=args.profile))
    srv = None
    if args.metrics_port is not None:
        from repro.obs import start_metrics_server
        srv = start_metrics_server(args.metrics_port)
        print(f"[serve] metrics at http://127.0.0.1:{srv.server_port}"
              f"/metrics (+ /metrics.json)")

    mon_ctx = contextlib.nullcontext(None)
    if args.monitor or args.metrics_dump:
        from repro.obs import monitoring
        envelope = None
        if args.precision_plan:
            from repro.numerics import load_plan
            envelope = (load_plan(args.precision_plan).meta
                        or {}).get("envelope")
        elif args.engine == "routed":
            import json as _json
            with open(os.path.join(args.plans, "MANIFEST.json")) as f:
                manifest = _json.load(f)
            for key, entry in sorted(manifest.get("plans", {}).items()):
                if base_arch in (key, entry.get("arch")):
                    from repro.numerics import load_plan
                    envelope = (load_plan(os.path.join(
                        args.plans, entry.get("file", f"{key}.json"))).meta
                        or {}).get("envelope")
                    break
        mon_ctx = monitoring(envelope=envelope)

    t0 = time.time()
    stack = contextlib.ExitStack()
    mon = stack.enter_context(mon_ctx)
    if args.engine == "routed":
        from repro.serving import (BucketedEnginePool, PlanRouter,
                                   RoutedFrontend, ServeRequest)
        if cfg.family not in ("dense", "moe", "vlm"):
            raise SystemExit(
                f"--engine routed supports KV-cache families "
                f"(dense/moe/vlm); {args.arch} is family={cfg.family!r} — "
                f"use the default --engine simple")
        if args.precision_plan:
            raise SystemExit("--engine routed picks plans from the zoo "
                             "MANIFEST; use --workload, not --precision-plan")
        router = PlanRouter.from_manifest(args.plans, arch=base_arch)
        buckets = args.buckets or (
            f"{args.batch}x{args.prompt_len + args.gen + 2}")
        pool = BucketedEnginePool(cfg, params, buckets)
        front = RoutedFrontend(pool, router)
        comps = [front.submit(ServeRequest(uid=i, prompt=row.tolist(),
                                           max_new=args.gen,
                                           workload=args.workload))
                 for i, row in enumerate(jnp.asarray(prompts))]
        front.run()
        toks = jnp.asarray([c.result() for c in comps])
        dt = time.time() - t0
        st = front.stats()
        for wl, cs in st["classes"].items():
            plans = ", ".join(sorted(cs["plans"]))
            print(f"[serve:routed] {wl}: {cs['completed']}/{cs['submitted']} "
                  f"ok via {plans}  mean_steps={cs['mean_steps']:.1f} "
                  f"tok/s={cs['tokens_per_s']:.1f}")
        print(f"[serve:routed] pool: {st['pool']['compiles']} compiles, "
              f"buckets={st['pool']['bucket_hits']}")
    elif args.engine == "continuous":
        from repro.launch.batching import ContinuousBatcher, Request
        if cfg.family not in ("dense", "moe", "vlm"):
            raise SystemExit(
                f"--engine continuous supports KV-cache families "
                f"(dense/moe/vlm); {args.arch} is family={cfg.family!r} — "
                f"use the default --engine simple")
        eng = ContinuousBatcher(
            cfg, params, n_slots=args.batch,
            max_len=args.prompt_len + 2 * args.gen + 2,
            warmup=policy if policy is not None else True)
        reqs = [Request(uid=i, prompt=row.tolist(), max_new=args.gen)
                for i, row in enumerate(jnp.asarray(prompts))]
        for r in reqs:
            eng.submit(r)
        eng.run()
        toks = jnp.asarray([r.out for r in reqs])
    else:
        ctx = use_policy(policy) if policy is not None \
            else contextlib.nullcontext()
        with ctx:
            toks = serve(cfg, params, prompts, args.gen, dist=dist)
    stack.close()                      # uninstall monitors, land callbacks
    dt = time.time() - t0
    plan_note = f" plan={args.precision_plan}" if args.precision_plan else ""
    print(f"[serve] {args.arch}: engine={args.engine} batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s){plan_note}")
    print("sample:", toks[0].tolist())
    if mon is not None:
        print(f"[serve] monitor: worst={mon.worst_status()} over "
              f"{len(mon.statuses())} sites, "
              f"overflow_events={mon.overflow_events()}")
    if args.metrics_dump:
        import json as _json

        from repro.obs import default_registry
        dump = {"kind": "repro.obs.ServingMetricsDump", "version": 1,
                "arch": args.arch, "engine": args.engine,
                "metrics": default_registry().snapshot(),
                "monitor": mon.snapshot() if mon is not None else None}
        with open(args.metrics_dump, "w") as f:
            _json.dump(dump, f, indent=1, sort_keys=True, default=str)
        print(f"[serve] metrics dump -> {args.metrics_dump}")
    if args.trace_out:
        from repro.obs import save_chrome_trace
        n_ev = save_chrome_trace(args.trace_out)
        print(f"[serve] chrome trace ({n_ev} events) -> {args.trace_out}")
    if srv is not None:
        if args.metrics_hold > 0:
            time.sleep(args.metrics_hold)
        srv.shutdown()


if __name__ == "__main__":
    main()
