"""Per-backend tuned XLA flag dictionaries, applied at launch.

The serving-stack idiom (saxml's ``llm_xla_flags.py``): XLA tuning lives in
named flag dictionaries, merged into ``XLA_FLAGS`` before the first JAX
import touches the backend. Flags the user already set in the environment
always win — a launch driver must never silently override an operator's
hand-tuned value.

These dictionaries complement the schedule zoo: the zoo removes autotune
misses from *our* Pallas plan cache, the flags remove known-bad defaults
from *XLA's* side of the same serving processes.
"""

from __future__ import annotations

import os

# Inference-lean TPU set: serving-shaped programs (small batch, latency
# bound) want prefetch ordering enforced and the loop optimizer on; RWB
# fusion and auto cross-replica sharding pessimize decode-step latency.
TPU_SERVE_FLAGS = {
    "xla_tpu_rwb_fusion": "false",
    "xla_jf_auto_cross_replica_sharding": "false",
    "xla_tpu_perform_spmd_cse_prevention": "true",
    "xla_tpu_enforce_prefetch_fifo_order": "true",
    "xla_tpu_memory_bound_loop_optimizer_options": "enabled:true",
}

# CPU (the interpret-mode development backend): pin fast-math OFF so the
# bit-exactness claims the FDP tests make are never at the mercy of a
# toolchain default flip. No layout/fusion tuning — interpret mode doesn't
# reward it and surprises aren't worth it.
CPU_FLAGS = {
    "xla_cpu_enable_fast_math": "false",
}

BACKEND_FLAGS = {
    "tpu": TPU_SERVE_FLAGS,
    "cpu": CPU_FLAGS,
}


def xla_flag_tokens(backend: str) -> list:
    """The ``--flag=value`` tokens for one backend ([] if untuned)."""
    return [f"--{k}={v}" for k, v in
            sorted(BACKEND_FLAGS.get(backend, {}).items())]


def apply_xla_flags(backend: str | None = None) -> str:
    """Merge the tuned flag dict for ``backend`` into ``XLA_FLAGS``.

    Existing user-set tokens take precedence: a flag already present in the
    environment (by name) is left exactly as the user wrote it. Must run
    before the backend initializes to take effect — call it at the top of a
    launch ``main()``, not after the first ``jax.device_put``. Returns the
    resulting ``XLA_FLAGS`` string.
    """
    if backend is None:
        # Cheap backend sniff without initializing jax: respect JAX_PLATFORMS
        # when set, else assume the baked-in toolchain's CPU backend.
        backend = (os.environ.get("JAX_PLATFORMS", "cpu")
                   .split(",")[0].strip() or "cpu")
    existing = os.environ.get("XLA_FLAGS", "").split()
    have = {tok.lstrip("-").split("=", 1)[0] for tok in existing}
    merged = list(existing)
    for tok in xla_flag_tokens(backend):
        if tok.lstrip("-").split("=", 1)[0] not in have:
            merged.append(tok)
    flags = " ".join(merged)
    if flags:
        os.environ["XLA_FLAGS"] = flags
    return flags
