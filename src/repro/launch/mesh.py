"""Production mesh construction (function, not module constant: importing
this module never touches jax device state)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a 2-pod leading axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for subprocess multi-device tests."""
    return jax.make_mesh(shape, axes)


def abstract_mesh(shape, axes):
    """Version-compatible ``jax.sharding.AbstractMesh`` constructor.

    JAX changed the signature across releases: 0.4.x takes a single tuple of
    (name, size) pairs, newer versions take positional (sizes, names). Build
    from pairs first and fall back, so callers never touch the raw API."""
    from jax.sharding import AbstractMesh
    pairs = tuple(zip(axes, shape))
    try:
        return AbstractMesh(pairs)
    except TypeError:
        return AbstractMesh(tuple(shape), tuple(axes))


def dp_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
