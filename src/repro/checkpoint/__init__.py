from .store import CheckpointStore
