"""Atomic, restartable, elastic checkpointing (no external deps).

Layout:  <dir>/step_00000123/
             manifest.json       (treedef, shapes, dtypes, per-leaf checksum)
             leaf_000.npy ...
Written to a tmp dir then os.rename'd (atomic on POSIX) — a crash mid-save
never corrupts the latest checkpoint. ``load_latest`` skips manifests that
fail validation (torn writes on shared filesystems).

Elasticity: ``load_latest(shardings=...)`` device_puts each leaf with the
given sharding, so a checkpoint taken on one mesh restores onto another
(different device count / topology) — the reshard happens at load.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Optional

import jax
import numpy as np


class CheckpointStore:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._async_thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, pytree, async_: bool = False):
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 pytree)
        if async_:
            self.wait()
            self._async_thread = threading.Thread(
                target=self._write, args=(step, host_tree), daemon=True)
            self._async_thread.start()
        else:
            self._write(step, host_tree)

    def wait(self):
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    def _write(self, step: int, host_tree):
        leaves, treedef = jax.tree.flatten(host_tree)
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, f".tmp_{name}")
        final = os.path.join(self.dir, name)
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        counter = [0]
        skeleton = _make_skeleton(host_tree, counter)
        with open(os.path.join(tmp, "skeleton.json"), "w") as f:
            json.dump(skeleton, f)
        manifest = {"step": step, "treedef": str(treedef), "leaves": []}
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            fn = f"leaf_{i:04d}.npy"
            np.save(os.path.join(tmp, fn), arr)
            with open(os.path.join(tmp, fn), "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()[:16]
            manifest["leaves"].append(
                {"file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
                 "sha": digest})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- load ---------------------------------------------------------------
    def all_steps(self):
        out = []
        for n in os.listdir(self.dir):
            if n.startswith("step_"):
                try:
                    out.append(int(n[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def _validate(self, path) -> Optional[dict]:
        try:
            with open(os.path.join(path, "manifest.json")) as f:
                manifest = json.load(f)
            for rec in manifest["leaves"]:
                with open(os.path.join(path, rec["file"]), "rb") as f:
                    if hashlib.sha256(f.read()).hexdigest()[:16] != rec["sha"]:
                        return None
            return manifest
        except (OSError, json.JSONDecodeError, KeyError):
            return None

    def load_latest(self, shardings=None, example_tree=None):
        """Returns (step, pytree) or None. Corrupt checkpoints are skipped.
        ``shardings``: optional pytree of NamedSharding for elastic restore.
        ``example_tree``: pytree giving the treedef (else the saved structure
        is rebuilt via jax.tree.unflatten on the stored treedef repr, which
        requires example_tree for custom nodes — dicts/lists round-trip)."""
        for step in reversed(self.all_steps()):
            path = os.path.join(self.dir, f"step_{step:08d}")
            manifest = self._validate(path)
            if manifest is None:
                continue
            leaves = [np.load(os.path.join(path, rec["file"]))
                      for rec in manifest["leaves"]]
            if example_tree is not None:
                treedef = jax.tree.structure(example_tree)
            else:
                # saved trees here are nested dict/list/tuple: rebuild from
                # the stored treedef repr via eval of the structure of a
                # freshly flattened skeleton is fragile — instead store leaves
                # positionally against the CALLER's latest structure. We keep
                # a skeleton file for pure-dict trees:
                treedef = None
            if treedef is not None:
                tree = jax.tree.unflatten(treedef, leaves)
            else:
                with open(os.path.join(path, "skeleton.json")) as f:
                    skeleton = json.load(f)
                tree = _from_skeleton(skeleton, leaves)
            if shardings is not None:
                flat_s = jax.tree.leaves(shardings)
                flat_l, td = jax.tree.flatten(tree)
                flat_l = [jax.device_put(l, s)
                          for l, s in zip(flat_l, flat_s)]
                tree = jax.tree.unflatten(td, flat_l)
            return step, tree
        return None

def _make_skeleton(tree, counter):
    """JSON-serializable structure with leaf indices (dict/list/tuple trees)."""
    if isinstance(tree, dict):
        return {"__dict__": {k: _make_skeleton(tree[k], counter)
                             for k in sorted(tree)}}
    if isinstance(tree, (list, tuple)):
        kind = "__tuple__" if isinstance(tree, tuple) else "__list__"
        return {kind: [_make_skeleton(v, counter) for v in tree]}
    i = counter[0]
    counter[0] += 1
    return {"__leaf__": i}


def _from_skeleton(skel, leaves):
    if "__leaf__" in skel:
        return leaves[skel["__leaf__"]]
    if "__dict__" in skel:
        return {k: _from_skeleton(v, leaves)
                for k, v in skel["__dict__"].items()}
    if "__list__" in skel:
        return [_from_skeleton(v, leaves) for v in skel["__list__"]]
    if "__tuple__" in skel:
        return tuple(_from_skeleton(v, leaves) for v in skel["__tuple__"])
    raise ValueError(f"bad skeleton node: {skel}")
