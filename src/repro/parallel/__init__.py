from .collectives import reproducible_psum, quantize_tree, dequantize_tree
