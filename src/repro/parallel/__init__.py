from .collectives import (reproducible_psum, quantize_tree, dequantize_tree,
                          fdp_psum, quantized_psum, validate_overflow,
                          CompressedGradReducer, QuantizedGradReducer)
