"""Version shims for JAX APIs whose signatures changed across releases."""

from __future__ import annotations

import jax

try:  # jax >= 0.6 exports shard_map at top level
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map_unchecked(f, *, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking disabled.

    The flag was renamed ``check_rep`` -> ``check_vma`` across JAX releases;
    try the new name first so both old (0.4.x) and new JAX work."""
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
    except TypeError:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
