"""Version shims for JAX APIs whose signatures changed across releases."""

from __future__ import annotations

import jax

try:  # jax >= 0.6 exports shard_map at top level
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map_unchecked(f, *, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking disabled.

    The flag was renamed ``check_rep`` -> ``check_vma`` across JAX releases;
    try the new name first so both old (0.4.x) and new JAX work."""
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
    except TypeError:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)


def axis_size(axis_name) -> jax.Array:
    """Size of a bound mesh axis (or tuple of axes), as a traced scalar.

    Newer JAX exposes ``jax.lax.axis_size``; on older releases ``psum`` of a
    constant 1 constant-folds to the same static count inside shard_map (one
    scalar per call site — not a per-leaf ones-tensor reduction)."""
    try:
        return jax.lax.axis_size(axis_name)
    except AttributeError:  # pragma: no cover - depends on jax version
        return jax.lax.psum(1, axis_name)
