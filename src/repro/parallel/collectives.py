"""Distributed numerics: the paper's fixed-point accumulation applied to
cross-replica collectives.

Floating-point all-reduce is order-dependent: different reduction topologies
(ring vs tree, different replica counts after elastic rescale) give different
bits. ``reproducible_psum`` quantizes onto the ⟨ovf,msb,lsb⟩ grid and reduces
in int32/int64-free integer space — integer addition is associative, so the
result is bitwise identical for ANY reduction order, topology or replica
count (the paper's reproducibility property, lifted to the collective layer).

With a coarse grid (few bits) + error feedback this doubles as gradient
compression: see ``CompressedGradReducer``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.accumulator import AccumulatorSpec


def _grid_quantize(x: jax.Array, lsb: int, width: int, stochastic_key=None):
    """Round-to-nearest onto 2^lsb grid, clip to signed ``width`` bits."""
    scale = 2.0 ** lsb
    y = x.astype(jnp.float32) / scale
    if stochastic_key is not None:
        y = jnp.floor(y + jax.random.uniform(stochastic_key, y.shape))
    else:
        y = jnp.round(y)
    lim = 2.0 ** (width - 1) - 1
    return jnp.clip(y, -lim, lim).astype(jnp.int32)


def _grid_dequantize(q: jax.Array, lsb: int, dtype=jnp.float32):
    return (q.astype(jnp.float32) * 2.0 ** lsb).astype(dtype)


def quantize_tree(tree, spec: AccumulatorSpec):
    return jax.tree.map(
        lambda x: _grid_quantize(x, spec.lsb, spec.width), tree)


def dequantize_tree(tree, spec: AccumulatorSpec, like=None):
    if like is None:
        return jax.tree.map(lambda q: _grid_dequantize(q, spec.lsb), tree)
    return jax.tree.map(
        lambda q, l: _grid_dequantize(q, spec.lsb, l.dtype), tree, like)


def reproducible_psum(x: jax.Array, axis_name: str, spec: AccumulatorSpec,
                      mean: bool = False) -> jax.Array:
    """Order-invariant psum: quantize -> integer psum -> dequantize.

    Must be called inside shard_map/pmap with ``axis_name`` bound. The int32
    payload also halves wire bytes vs f32 when spec.width <= 16 (XLA packs
    int32; the width bound documents the *information* content — a production
    deployment would pack to int16/int8 wire format, which this emulates).
    """
    q = _grid_quantize(x, spec.lsb, spec.width)
    s = jax.lax.psum(q, axis_name)
    out = _grid_dequantize(s, spec.lsb, x.dtype)
    if mean:
        out = out / jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return out


@dataclasses.dataclass
class CompressedGradReducer:
    """Error-feedback gradient compression on the fixed-point grid
    (1-bit-Adam-style residual carrying, but with the paper's ⟨lsb,width⟩
    knob instead of sign-only)."""

    spec: AccumulatorSpec
    axis_name: str

    def init(self, params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def reduce(self, grads, residual):
        """Returns (reduced_grads, new_residual)."""
        def one(g, r):
            g32 = g.astype(jnp.float32) + r
            q = _grid_quantize(g32, self.spec.lsb, self.spec.width)
            sent = _grid_dequantize(q, self.spec.lsb)
            new_r = g32 - sent
            red = jax.lax.psum(q, self.axis_name)
            n = jax.lax.psum(jnp.ones((), jnp.float32), self.axis_name)
            return (_grid_dequantize(red, self.spec.lsb) / n).astype(g.dtype), new_r

        flat_g, td = jax.tree.flatten(grads)
        flat_r = jax.tree.leaves(residual)
        out = [one(g, r) for g, r in zip(flat_g, flat_r)]
        return (jax.tree.unflatten(td, [o[0] for o in out]),
                jax.tree.unflatten(td, [o[1] for o in out]))
