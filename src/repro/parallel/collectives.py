"""Distributed numerics: the paper's fixed-point accumulation applied to
cross-replica collectives.

Floating-point all-reduce is order-dependent: different reduction topologies
(ring vs tree, different replica counts after elastic rescale) give different
bits. ``reproducible_psum`` quantizes onto the ⟨ovf,msb,lsb⟩ grid and reduces
in int32/int64-free integer space — integer addition is associative, so the
result is bitwise identical for ANY reduction order, topology or replica
count (the paper's reproducibility property, lifted to the collective layer).

With a coarse grid (few bits) + error feedback this doubles as gradient
compression: see ``CompressedGradReducer``.
"""

from __future__ import annotations

import contextlib
import dataclasses
import warnings
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import accumulator as acc
from repro.core import qformat
from repro.core.accumulator import AccumulatorSpec
from repro.core.qformat import QuantConfig
from repro.obs.registry import default_registry as _obs_registry
from repro.parallel.compat import axis_size

_VALIDATE_OVERFLOW: Optional[str] = None     # None | "raise" | "warn"

# Saturation events land in the unified obs registry under the same family
# the GEMM envelope monitor uses, so "zero overflow events" is one number
# across accumulator wraps and collective spillover.
_OVERFLOW_EVENTS = _obs_registry().counter(
    "repro_overflow_events_total",
    "overflow/saturation events (accumulator wrap risk, non-finite "
    "outputs, quantized-collective spillover)", ("site", "source"))
_WARNED_SITES: set = set()


@contextlib.contextmanager
def validate_overflow(enabled: bool = True, *, mode: str = "raise"):
    """Validation mode: a quantized collective payload that would saturate
    its grid width is detected instead of silently clipping (clipping breaks
    the 'same bits as single device' contract).

    ``mode="raise"`` (default) raises ``OverflowError`` naming the offending
    site; ``mode="warn"`` is for monitoring-only production deployments —
    events still increment ``repro_overflow_events_total{source=collective}``
    and emit one ``RuntimeWarning`` per site, but serving keeps running.
    The mode is captured when a computation is *traced* (it is staged into
    the debug callback), like the check itself.
    """
    if mode not in ("raise", "warn"):
        raise ValueError(f"validate_overflow mode {mode!r} "
                         "(expected 'raise' or 'warn')")
    global _VALIDATE_OVERFLOW
    prev = _VALIDATE_OVERFLOW
    _VALIDATE_OVERFLOW = mode if enabled else None
    try:
        yield
    finally:
        _VALIDATE_OVERFLOW = prev


def _on_saturation(site: str, mode: str, saturated) -> None:
    if not saturated:
        return
    _OVERFLOW_EVENTS.inc(site=site, source="collective")
    msg = (f"[{site}] quantized collective payload saturates the grid "
           "width — the clipped reduction would not match single-device "
           "bits; widen the spec (ovf/msb) or rescale the payload")
    if mode == "warn":
        if site not in _WARNED_SITES:      # counter has the event count;
            _WARNED_SITES.add(site)        # warn once per site, not per step
            warnings.warn(msg, RuntimeWarning)
        return
    raise OverflowError(msg)


def _check_overflow(y: jax.Array, lim: float,
                    site: str = "collective") -> None:
    """Under ``validate_overflow()``: flag any |y| exceeding the signed
    range, attributed to ``site``. Works both eagerly and under trace (via
    debug.callback)."""
    mode = _VALIDATE_OVERFLOW
    if mode is None:
        return
    saturated = jnp.any(jnp.abs(y) > lim)
    jax.debug.callback(partial(_on_saturation, site, mode), saturated)


def _grid_quantize(x: jax.Array, lsb: int, width: int, stochastic_key=None,
                   site: str = "grid_quantize"):
    """Round-to-nearest onto 2^lsb grid, clip to signed ``width`` bits."""
    scale = 2.0 ** lsb
    y = x.astype(jnp.float32) / scale
    if stochastic_key is not None:
        y = jnp.floor(y + jax.random.uniform(stochastic_key, y.shape))
    else:
        y = jnp.round(y)
    lim = 2.0 ** (width - 1) - 1
    _check_overflow(y, lim, site)
    return jnp.clip(y, -lim, lim).astype(jnp.int32)


def _grid_dequantize(q: jax.Array, lsb: int, dtype=jnp.float32):
    return (q.astype(jnp.float32) * 2.0 ** lsb).astype(dtype)


def quantize_tree(tree, spec: AccumulatorSpec, site: str = "quantize_tree"):
    return jax.tree.map(
        lambda x: _grid_quantize(x, spec.lsb, spec.width, site=site), tree)


def dequantize_tree(tree, spec: AccumulatorSpec, like=None):
    if like is None:
        return jax.tree.map(lambda q: _grid_dequantize(q, spec.lsb), tree)
    return jax.tree.map(
        lambda q, l: _grid_dequantize(q, spec.lsb, l.dtype), tree, like)


def reproducible_psum(x: jax.Array, axis_name: str, spec: AccumulatorSpec,
                      mean: bool = False) -> jax.Array:
    """Order-invariant psum: quantize -> integer psum -> dequantize.

    Must be called inside shard_map/pmap with ``axis_name`` bound. The int32
    payload also halves wire bytes vs f32 when spec.width <= 16 (XLA packs
    int32; the width bound documents the *information* content — a production
    deployment would pack to int16/int8 wire format, which this emulates).
    """
    q = _grid_quantize(x, spec.lsb, spec.width,
                       site="reproducible_psum@coll")
    s = jax.lax.psum(q, axis_name)
    out = _grid_dequantize(s, spec.lsb, x.dtype)
    if mean:
        out = out / axis_size(axis_name)
    return out


def fdp_psum(limbs: jax.Array, axis_name, spec: AccumulatorSpec) -> jax.Array:
    """All-reduce of FDP accumulator registers in exact integer limb space.

    ``limbs`` is a carry-normalized partial-K state (trailing dim =
    ``spec.num_limbs``), e.g. from ``repro.core.fdp.fdp_gemm_limbs`` on a
    local K-shard, or any per-device exact partial accumulation. Integer limb
    addition is exact, associative and commutative, so the psum followed by
    one ``carry_normalize`` is bit-identical to accumulating everything on a
    single device — for ANY reduction order, ring/tree topology, or mesh
    factorization. No dequantized grid is involved: this reduces the
    *register itself*, so a K-sharded FDP GEMM lands on exactly the bits
    ``fdp_gemm`` would produce unsharded.

    Headroom: normalized digits 0..L-2 are in [0, 2^16) and the signed top
    limb carries the rest, so up to SAFE_CHUNK (2^13) device contributions
    sum without int32 digit overflow; top-limb int32 wrap is congruent to the
    register's own 2^ovf+msb wrap, preserving wrap-mode semantics. Call inside
    shard_map/pmap with ``axis_name`` bound.
    """
    assert limbs.shape[-1] == spec.num_limbs, (
        f"limb register has {limbs.shape[-1]} limbs, spec wants "
        f"{spec.num_limbs}")
    s = jax.lax.psum(limbs, axis_name)
    return acc.carry_normalize(spec, s)


def quantized_psum(x: jax.Array, axis_name: str, cfg: QuantConfig, *,
                   mean: bool = False, residual: Optional[jax.Array] = None,
                   site: str = qformat.GRAD_PSUM_SITE.key):
    """Block-scaled low-bit all-reduce — the bytes-*moved* counterpart to the
    optimizer's bytes-resident site (``CollectiveSite("grad_psum")``).

    Per-block shared exponents are agreed across devices first (pmax of the
    local block amax — max is exact and associative, so every device lands on
    the same exponent regardless of topology), then each device sends a
    ``cfg.bits``-wide integer payload on that 2^lsb grid and the reduction
    runs in exact integer space. Given the shared exponents, the result is
    order-invariant like ``reproducible_psum``, but the grid adapts per block
    instead of being fixed by an AccumulatorSpec — so 8-bit payloads survive
    the ~2^40 dynamic range a gradient tree spans. Wire cost is modeled by
    ``qformat.quant_bytes`` (bits/8 per element + one exponent byte per
    block) vs 4 bytes/element for the fp32 path.

    ``residual`` enables error feedback: what rounding/clipping dropped this
    step is returned and should be added back next step (1-bit-Adam-style).
    The grid is sized from ``x`` alone, NOT ``x + residual`` — accumulated
    residual that spills past the grid clips (and is re-carried), which is
    exactly what ``validate_overflow()`` + ``_check_overflow`` make loud.
    Returns ``out`` without residual, ``(out, new_residual)`` with.

    An fp32-mode cfg is the identity wire format (plain float psum).
    """
    if cfg.mode == "fp32":
        out = jax.lax.psum(x.astype(jnp.float32), axis_name)
        if mean:
            out = out / axis_size(axis_name)
        out = out.astype(x.dtype)
        if residual is None:
            return out
        return out, jnp.zeros(x.shape, jnp.float32)

    blocks = qformat._to_blocks(x, cfg.block)
    amax = jax.lax.pmax(jnp.max(jnp.abs(blocks), axis=1), axis_name)
    _, scale = qformat.block_scale(amax, cfg.bits)
    payload = blocks
    if residual is not None:
        payload = payload + qformat._to_blocks(residual, cfg.block)
    y = jnp.round(payload / scale[:, None])
    lim = 2.0 ** (cfg.bits - 1) - 1
    _check_overflow(y, lim, site)
    q = jnp.clip(y, -lim, lim).astype(jnp.int32)
    s = jax.lax.psum(q, axis_name)

    def unblock(b):
        return b.reshape(-1)[: x.size].reshape(x.shape)

    out = unblock(s.astype(jnp.float32) * scale[:, None])
    if mean:
        out = out / axis_size(axis_name)
    out = out.astype(x.dtype)
    if residual is None:
        return out
    sent = unblock(q.astype(jnp.float32) * scale[:, None])
    new_r = (x.astype(jnp.float32) + residual) - sent
    return out, new_r


@dataclasses.dataclass
class QuantizedGradReducer:
    """Error-feedback gradient averaging over ``quantized_psum`` — the
    block-scaled sibling of ``CompressedGradReducer`` (whose single global
    ⟨lsb,width⟩ grid can't span a whole gradient tree at low bits)."""

    cfg: QuantConfig
    axis_name: str

    def init(self, params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def reduce(self, grads, residual):
        """Returns (mean_grads, new_residual)."""
        def one(g, r):
            out, new_r = quantized_psum(g, self.axis_name, self.cfg,
                                        mean=True, residual=r)
            return out.astype(g.dtype), new_r

        flat_g, td = jax.tree.flatten(grads)
        flat_r = jax.tree.leaves(residual)
        out = [one(g, r) for g, r in zip(flat_g, flat_r)]
        return (jax.tree.unflatten(td, [o[0] for o in out]),
                jax.tree.unflatten(td, [o[1] for o in out]))


@dataclasses.dataclass
class CompressedGradReducer:
    """Error-feedback gradient compression on the fixed-point grid
    (1-bit-Adam-style residual carrying, but with the paper's ⟨lsb,width⟩
    knob instead of sign-only)."""

    spec: AccumulatorSpec
    axis_name: str

    def init(self, params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def reduce(self, grads, residual):
        """Returns (reduced_grads, new_residual)."""
        def one(g, r):
            g32 = g.astype(jnp.float32) + r
            q = _grid_quantize(g32, self.spec.lsb, self.spec.width,
                               site=qformat.GRAD_PSUM_SITE.key)
            sent = _grid_dequantize(q, self.spec.lsb)
            new_r = g32 - sent
            red = jax.lax.psum(q, self.axis_name)
            return (_grid_dequantize(red, self.spec.lsb) / n).astype(g.dtype), new_r

        n = axis_size(self.axis_name)
        flat_g, td = jax.tree.flatten(grads)
        flat_r = jax.tree.leaves(residual)
        out = [one(g, r) for g, r in zip(flat_g, flat_r)]
        return (jax.tree.unflatten(td, [o[0] for o in out]),
                jax.tree.unflatten(td, [o[1] for o in out]))
