"""GPipe-style pipeline parallelism via shard_map + collective_permute.

The layer stack is split into S stages along a "stage" mesh axis; microbatches
stream through with the classic 1F1B-ish schedule expressed as a scan over
(n_micro + S - 1) ticks, each tick running one stage body and ppermuting
activations to the next stage. This composes with the data/model axes (the
stage axis is just another mesh axis).

Provided as a first-class module with parity tests (tests/test_distributed.py)
— the production 40-cell dry-run uses DP x TP x SP, with PP available for
deeper-than-HBM models.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.compat import shard_map_unchecked


def pipeline_apply(body, params_stacked, x_micro, mesh, stage_axis="stage"):
    """Run x through a pipeline of stages.

    body(stage_params, x) -> x          (one stage's computation)
    params_stacked: leaves with leading dim n_stages (sharded over stage axis)
    x_micro: (n_micro, mb, ...) microbatched input (replicated over stages)
    Returns (n_micro, mb, ...) outputs.
    """
    S = mesh.shape[stage_axis]
    n_micro = x_micro.shape[0]
    assert n_micro >= S, "need at least S microbatches to fill the pipe"

    def stage_fn(params_local, xm):
        # params_local: this stage's slice (leading dim 1) ; xm replicated
        p = jax.tree.map(lambda a: a[0], params_local)
        idx = jax.lax.axis_index(stage_axis)
        ticks = n_micro + S - 1
        mb_shape = xm.shape[1:]

        def tick(carry, t):
            buf, outputs = carry
            # stage 0 ingests microbatch t (if in range), others take buf
            feed = jnp.where(t < n_micro, t, n_micro - 1)
            x_in = jnp.where(idx == 0, xm[feed], buf)
            y = body(p, x_in)
            # pass to next stage
            buf_next = jax.lax.ppermute(
                y, stage_axis, [(i, (i + 1) % S) for i in range(S)])
            # last stage emits microbatch t-(S-1)
            out_t = t - (S - 1)
            emit = jnp.where(out_t >= 0, out_t, 0)
            outputs = jax.lax.cond(
                out_t >= 0,
                lambda o: o.at[emit].set(y),
                lambda o: o, outputs)
            return (buf_next, outputs), None

        buf0 = jnp.zeros(mb_shape, xm.dtype)
        out0 = jnp.zeros((n_micro, *mb_shape), xm.dtype)
        (_, outputs), _ = jax.lax.scan(tick, (buf0, out0),
                                       jnp.arange(ticks))
        # only the LAST stage's outputs are real; broadcast them to all
        # stages so out_specs can be replicated
        outputs = jax.lax.all_gather(outputs, stage_axis)[S - 1]
        return outputs

    pspec = jax.tree.map(lambda _: P(stage_axis), params_stacked)
    return shard_map_unchecked(
        stage_fn, mesh=mesh,
        in_specs=(pspec, P()), out_specs=P(),
    )(params_stacked, x_micro)
