"""Deterministic synthetic LM data pipeline.

Production posture:每 (shard, step) batch is a pure function of
(seed, step, shard_index) — restart-reproducible with no iterator state to
checkpoint, and trivially elastic (a different shard count just re-partitions
the same global batch). A double-buffered prefetch iterator hides host time.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TokenBatch:
    tokens: jax.Array          # (B, S) int32
    targets: jax.Array         # (B, S) int32 (next-token)
    loss_mask: jax.Array       # (B, S) f32


@dataclasses.dataclass
class SyntheticLM:
    """Markov-ish synthetic token stream: structured enough that a model can
    reduce loss (bigram structure), deterministic per (seed, step, shard)."""

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    shard_index: int = 0
    num_shards: int = 1

    def __post_init__(self):
        assert self.global_batch % self.num_shards == 0
        self.local_batch = self.global_batch // self.num_shards
        r = np.random.default_rng(self.seed)
        # fixed bigram transition "table" via hashing — O(1) memory
        self._mix = int(r.integers(1, 2 ** 31 - 1))

    def batch(self, step: int) -> TokenBatch:
        """Batch for ``step`` on this shard (pure function)."""
        key = jax.random.key(
            (self.seed * 1_000_003 + step) * 65_537 + self.shard_index)
        b, s, v = self.local_batch, self.seq_len, self.vocab_size
        k1, k2 = jax.random.split(key)
        first = jax.random.randint(k1, (b, 1), 0, v, jnp.int32)
        noise = jax.random.randint(k2, (b, s), 0, v, jnp.int32)

        def step_fn(prev, n):
            # deterministic bigram: next = hash(prev) with 25% noise
            nxt = (prev * self._mix + 12345) % v
            use_noise = (n % 4) == 0
            tok = jnp.where(use_noise, n, nxt)
            return tok, tok

        _, toks = jax.lax.scan(step_fn, first[:, 0], noise.T)
        tokens = jnp.concatenate([first, toks.T[:, :-1]], axis=1)
        targets = toks.T
        mask = jnp.ones((b, s), jnp.float32)
        return TokenBatch(tokens, targets, mask)

    def iterator(self, start_step: int = 0,
                 prefetch: int = 2) -> Iterator[TokenBatch]:
        """Double-buffered prefetching iterator starting at ``start_step``."""
        q: queue.Queue = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def producer():
            step = start_step
            while not stop.is_set():
                try:
                    q.put(self.batch(step), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()


def eval_batch(vocab_size: int, seq_len: int, batch: int, seed: int = 1234):
    """Fixed eval batch (for accuracy-vs-energy sweeps)."""
    ds = SyntheticLM(vocab_size, seq_len, batch, seed=seed)
    return ds.batch(0)
