from .synthetic import SyntheticLM, TokenBatch
from .conditioned import gen_dot
