from .synthetic import SyntheticLM, TokenBatch
from .conditioned import gen_dot, gen_linear_system, residual_exact
