"""Ill-conditioned dot-product generator (Ogita, Rump & Oishi, SIAM J. Sci.
Comput. 2005, Algorithm 6.1) — the standard way to manufacture dot products
with a prescribed condition number.  This is the data substrate for the SSH
reproducibility experiment (paper Fig. 2): SSH reduces to long dot products
whose conditioning grows with vector size.
"""

from __future__ import annotations

import numpy as np


def gen_dot(n: int, cond: float, seed: int = 0):
    """Generate f32 vectors a, b (length n) with cond(a·b) ≈ ``cond``.

    Returns (a, b, exact) with ``exact`` the dot product evaluated with
    exact (Fraction) arithmetic, as float64.
    """
    assert n >= 6
    rng = np.random.default_rng(seed)
    half = n // 2
    b_exp = np.log2(cond) / 2.0
    # first half: exponents spread from 0 up to b_exp/... (ORO 6.1)
    e = np.rint(rng.uniform(0, b_exp, half)).astype(np.int64)
    e[0] = int(np.rint(b_exp)) + 1
    e[-1] = 0
    a = np.float32((rng.uniform(-1, 1, half)) * (2.0 ** e))
    x = np.float32((rng.uniform(-1, 1, half)) * (2.0 ** e))
    # second half (ORO 6.1 proper): steer the running exact sum down through
    # the e2 ladder — each step the sum is *set near* a fresh value of
    # magnitude 2^e2[i], not cancelled to rounding noise, so the final value
    # is O(1) and cond(a·b) = sum|a_i b_i| / |a·b| lands at the prescribed
    # cond instead of overshooting to ~1e46 (which made every cond argument
    # produce the same un-sweepable, beyond-f128 problem)
    e2 = np.rint(np.linspace(int(np.rint(b_exp)), 0, n - half)).astype(np.int64)
    a2 = np.zeros(n - half, np.float32)
    x2 = np.zeros(n - half, np.float32)
    from fractions import Fraction
    acc = _exact_dot(a, x)
    for i in range(n - half):
        a2[i] = np.float32(rng.uniform(-1, 1) * 2.0 ** e2[i])
        if a2[i] == 0:
            a2[i] = np.float32(2.0 ** e2[i])
        target = Fraction(np.float64(rng.uniform(-1, 1) * 2.0 ** e2[i]))
        x2[i] = np.float32(float((target - acc) / Fraction(np.float64(a2[i]))))
        acc += Fraction(np.float64(a2[i])) * Fraction(np.float64(x2[i]))
    a_full = np.concatenate([a, a2])
    x_full = np.concatenate([x, x2])
    perm = rng.permutation(n)
    a_full, x_full = a_full[perm], x_full[perm]
    exact = float(_exact_dot(a_full, x_full))
    return a_full, x_full, exact


def _exact_dot(a, b):
    from fractions import Fraction
    s = Fraction(0)
    for x, y in zip(np.asarray(a, np.float64).tolist(),
                    np.asarray(b, np.float64).tolist()):
        s += Fraction(x) * Fraction(y)
    return s


def gen_linear_system(n: int, cond: float, seed: int = 0):
    """Companion to ``gen_dot``: an (n, n) system with prescribed condition.

    A is built by scaled SVD — seeded orthogonal U, V (QR of gaussians) around
    log-spaced singular values 1 .. 1/cond — and x is the smallest singular
    direction plus a little noise, so the row dots of A·x cancel by ~cond and
    probing them exercises exactly the regime an ill-conditioned *solve*
    lives in. Everything is rounded to f32 first (the data a deployed kernel
    would actually see; past cond ~ 1e7 the achievable cancellation saturates
    at the f32 grid) and the reference is then computed on those f32 values
    in exact (Fraction) arithmetic.

    Returns ``(A, x, exact)`` with ``A`` (n, n) f32, ``x`` (n,) f32 and
    ``exact`` (n,) float64 — the exact-arithmetic value of each row dot
    A[i]·x. ``residual_exact`` turns this into an exact residual reference
    against any candidate solution/readout.
    """
    assert n >= 2
    rng = np.random.default_rng(seed)
    u, _ = np.linalg.qr(rng.standard_normal((n, n)))
    v, _ = np.linalg.qr(rng.standard_normal((n, n)))
    s = np.logspace(0.0, -np.log10(cond), n)
    A = np.float32(u @ np.diag(s) @ v.T)
    # noise scaled to the smallest singular value: the perturbation's image
    # through A stays at the ~1/cond level of s_min·u_min, so the row dots
    # keep their full ~log2(cond) bits of cancellation
    x = np.float32(v[:, -1] + (0.1 / cond) * rng.standard_normal(n))
    exact = np.array([float(_exact_dot(A[i], x)) for i in range(n)],
                     np.float64)
    return A, x, exact


def residual_exact(A, x, b):
    """Exact-arithmetic residual A·x - b of f32 data, as float64 — the
    reference a tailored-kernel residual computation is scored against."""
    from fractions import Fraction
    A = np.asarray(A)
    out = np.empty(A.shape[0], np.float64)
    for i in range(A.shape[0]):
        out[i] = float(_exact_dot(A[i], x) - Fraction(np.float64(b[i])))
    return out


def ssh_surrogate_batch(n: int, cond: float, m: int = 8, seed: int = 0):
    """A batch of m ill-conditioned dot products (the SSH stencil rows)."""
    out = [gen_dot(n, cond, seed + i) for i in range(m)]
    a = np.stack([o[0] for o in out])
    b = np.stack([o[1] for o in out])
    exact = np.array([o[2] for o in out], np.float64)
    return a, b, exact
