"""Ill-conditioned dot-product generator (Ogita, Rump & Oishi, SIAM J. Sci.
Comput. 2005, Algorithm 6.1) — the standard way to manufacture dot products
with a prescribed condition number.  This is the data substrate for the SSH
reproducibility experiment (paper Fig. 2): SSH reduces to long dot products
whose conditioning grows with vector size.
"""

from __future__ import annotations

import numpy as np


def gen_dot(n: int, cond: float, seed: int = 0):
    """Generate f32 vectors a, b (length n) with cond(a·b) ≈ ``cond``.

    Returns (a, b, exact) with ``exact`` the dot product evaluated with
    exact (Fraction) arithmetic, as float64.
    """
    assert n >= 6
    rng = np.random.default_rng(seed)
    half = n // 2
    b_exp = np.log2(cond) / 2.0
    # first half: exponents spread from 0 up to b_exp/... (ORO 6.1)
    e = np.rint(rng.uniform(0, b_exp, half)).astype(np.int64)
    e[0] = int(np.rint(b_exp)) + 1
    e[-1] = 0
    a = np.float32((rng.uniform(-1, 1, half)) * (2.0 ** e))
    x = np.float32((rng.uniform(-1, 1, half)) * (2.0 ** e))
    # second half: cancel progressively so the true value is tiny
    e2 = np.rint(np.linspace(int(np.rint(b_exp)), 0, n - half)).astype(np.int64)
    a2 = np.zeros(n - half, np.float32)
    x2 = np.zeros(n - half, np.float32)
    from fractions import Fraction
    acc = _exact_dot(a, x)
    for i in range(n - half):
        a2[i] = np.float32(rng.uniform(-1, 1) * 2.0 ** e2[i])
        if a2[i] == 0:
            a2[i] = np.float32(2.0 ** e2[i])
        # choose x2 to cancel the running exact sum
        x2[i] = np.float32(float(-acc / Fraction(np.float64(a2[i]))))
        acc += Fraction(np.float64(a2[i])) * Fraction(np.float64(x2[i]))
    a_full = np.concatenate([a, a2])
    x_full = np.concatenate([x, x2])
    perm = rng.permutation(n)
    a_full, x_full = a_full[perm], x_full[perm]
    exact = float(_exact_dot(a_full, x_full))
    return a_full, x_full, exact


def _exact_dot(a, b):
    from fractions import Fraction
    s = Fraction(0)
    for x, y in zip(np.asarray(a, np.float64).tolist(),
                    np.asarray(b, np.float64).tolist()):
        s += Fraction(x) * Fraction(y)
    return s


def ssh_surrogate_batch(n: int, cond: float, m: int = 8, seed: int = 0):
    """A batch of m ill-conditioned dot products (the SSH stencil rows)."""
    out = [gen_dot(n, cond, seed + i) for i in range(m)]
    a = np.stack([o[0] for o in out])
    b = np.stack([o[1] for o in out])
    exact = np.array([o[2] for o in out], np.float64)
    return a, b, exact
