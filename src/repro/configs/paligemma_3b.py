"""PaLI-Gemma 3B [arXiv:2407.07726]: SigLIP frontend (stubbed patch
embeddings) + gemma-style decoder. MQA (kv=1), prefix-LM attention over the
image tokens."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_ff=16384,
    vocab_size=257216, head_dim=256, act="gelu", n_patches=256,
)
