"""Zamba2 2.7B [arXiv:2411.15242]: Mamba2 backbone + one weight-shared
full-attention(+MLP) block invoked every 6 layers."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab_size=32000, head_dim=80, ssm_state=64, ssm_expand=2,
    ssm_head_dim=64, ssm_groups=1, ssm_conv=4, attn_every=6,
)
