"""Whisper large-v3 [arXiv:2212.04356]: encoder-decoder; the conv audio
frontend is a stub (input_specs provides (B, 1500, d) frame embeddings)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, d_ff=5120,
    vocab_size=51866, head_dim=64, act="gelu",
    n_enc_layers=32, enc_seq=1500,
)
