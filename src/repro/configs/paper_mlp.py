"""The paper's own workload family: a small dense transformer classifier used
for the Fig.-3 accuracy-vs-energy sweeps (the ResNet/ImageNet analogue at
laptop scale; see DESIGN.md §6)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paper-mlp", family="dense",
    n_layers=4, d_model=256, n_heads=4, n_kv_heads=4, d_ff=1024,
    vocab_size=512, head_dim=64,
)
