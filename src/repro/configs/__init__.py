"""Architecture registry: one module per assigned architecture."""

from importlib import import_module

ARCH_IDS = [
    "paligemma_3b", "grok_1_314b", "dbrx_132b", "zamba2_2p7b", "mamba2_1p3b",
    "whisper_large_v3", "stablelm_12b", "qwen1p5_4b", "qwen3_0p6b",
    "llama3p2_3b", "paper_mlp",
]

_ALIASES = {
    "paligemma-3b": "paligemma_3b", "grok-1-314b": "grok_1_314b",
    "dbrx-132b": "dbrx_132b", "zamba2-2.7b": "zamba2_2p7b",
    "mamba2-1.3b": "mamba2_1p3b", "whisper-large-v3": "whisper_large_v3",
    "stablelm-12b": "stablelm_12b", "qwen1.5-4b": "qwen1p5_4b",
    "qwen3-0.6b": "qwen3_0p6b", "llama3.2-3b": "llama3p2_3b",
    "paper-mlp": "paper_mlp",
}


def get_config(arch: str):
    mod = _ALIASES.get(arch, arch).replace("-", "_").replace(".", "p")
    return import_module(f"repro.configs.{mod}").CONFIG


def all_arch_names():
    return [a for a in _ALIASES if a != "paper-mlp"]
